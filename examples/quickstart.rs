//! Quickstart: the full three-layer stack on a real workload.
//!
//! Loads the AOT-compiled PrismNano artifacts (JAX model + Pallas
//! paged-attention kernel, lowered to HLO text by `make artifacts`), serves a
//! batch of timestamped requests through the Rust coordinator - shared
//! router queue, Moore-Hodgson admission, kvcached-paged KV - executing every
//! forward pass on the PJRT CPU client, and reports TTFT/TPOT/throughput.
//!
//! Run: `make artifacts && cargo run --release --example quickstart`
// Printing is the point of this target (see Cargo.toml lints.clippy).
#![allow(clippy::print_stdout, clippy::print_stderr)]

use prism::serve::{RealServer, ServeRequest, ServerConfig};
use prism::util::rng::Rng;

fn main() -> anyhow::Result<()> {
    let root = std::path::PathBuf::from(env!("CARGO_MANIFEST_DIR")).join("artifacts");
    let nano = root.join("prism-nano");
    let micro = root.join("prism-micro");
    if !nano.join("manifest.json").is_file() {
        anyhow::bail!("artifacts missing - run `make artifacts` first");
    }

    println!("loading artifacts + compiling HLO on the PJRT CPU client ...");
    let mut srv = RealServer::new(
        ServerConfig::default(),
        &[nano.as_path(), micro.as_path()],
        &[],
    )?;
    println!("initial device memory: {:?}", srv.kv_stats());

    // A small open-loop workload across both models.
    let mut rng = Rng::new(42);
    let reqs: Vec<ServeRequest> = (0..16)
        .map(|i| ServeRequest {
            model: if i % 3 == 0 { "prism-micro" } else { "prism-nano" }.into(),
            prompt: (0..(12 + rng.below(36))).map(|_| rng.below(255) as i32).collect(),
            max_new_tokens: 12,
            arrival: i as f64 * 0.02,
            ttft_slo: Some(2.5),
        })
        .collect();

    let t0 = std::time::Instant::now();
    let results = srv.serve(&reqs)?;
    let wall = t0.elapsed().as_secs_f64();

    let mut tokens = 0;
    let mut ttft_ok = 0;
    println!("\n req  model        ttft_ms  tpot_ms  e2e_ms  output");
    for (i, r) in results.iter().enumerate() {
        let r = r.as_ref().expect("request completed");
        tokens += r.generated.len();
        if r.ttft <= r.ttft_slo {
            ttft_ok += 1;
        }
        println!(
            "{i:>4}  {:<12} {:>7.1}  {:>7.1}  {:>6.0}  {:?}",
            r.model,
            r.ttft * 1e3,
            r.tpot * 1e3,
            r.e2e * 1e3,
            &r.generated[..r.generated.len().min(6)],
        );
    }
    println!(
        "\nserved {} requests / {tokens} tokens in {wall:.2}s -> {:.1} tok/s; \
         TTFT SLO attainment {:.0}%",
        reqs.len(),
        tokens as f64 / wall,
        100.0 * ttft_ok as f64 / reqs.len() as f64,
    );
    println!("final device memory: {:?}", srv.kv_stats());
    Ok(())
}
