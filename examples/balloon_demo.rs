//! Balloon-driver mechanism demo (the paper's Fig 4 narrative, no models):
//! reserve virtual space, map physical pages on demand, shrink one tenant's
//! balloon to fund another, and watch the pool accounting stay conserved.
//!
//! Run: `cargo run --release --example balloon_demo`
// Printing is the point of this target (see Cargo.toml lints.clippy).
#![allow(clippy::print_stdout, clippy::print_stderr)]

use prism::kvcached::{Kvcached, KvError};
use prism::model::spec::ModelId;

fn show(kvc: &Kvcached, label: &str) {
    let s = kvc.stats();
    println!(
        "{label:<38} weights {:>5.1} MB | kv mapped {:>5.1} MB (used {:>5.1}) | free {:>6.1} MB",
        s.weight_bytes as f64 / 1e6,
        s.kv_mapped_bytes as f64 / 1e6,
        s.kv_used_bytes as f64 / 1e6,
        s.free_bytes as f64 / 1e6,
    );
    assert!(kvc.check_conservation(), "page accounting must be conserved");
}

fn main() {
    let mb = 1024 * 1024;
    // A 256 MB "GPU" with 2 MB pages and a 8-page prealloc buffer.
    let mut kvc = Kvcached::new(256 * mb, 2 * mb, 8);
    let (a, b) = (ModelId(1), ModelId(2));

    println!("-- two tenants with different KV geometries share one device --");
    kvc.load_weights(a, 64 * mb).unwrap();
    kvc.load_weights(b, 48 * mb).unwrap();
    kvc.register_kv(a, 512 * 1024, u32::MAX); // 4 blocks per 2MB page
    kvc.register_kv(b, 2 * mb, u32::MAX); // 1 block per page
    show(&kvc, "after weight load");

    // Tenant A serves a burst: map blocks on demand.
    let mut a_blocks = Vec::new();
    for _ in 0..120 {
        a_blocks.push(kvc.alloc_block(a).unwrap());
    }
    show(&kvc, "A bursting (120 blocks)");

    // Tenant B wants memory: balloon A down to 10 pages.
    for blk in a_blocks.drain(40..) {
        kvc.free_block(blk).unwrap();
    }
    let over = kvc.set_kv_limit(a, 10).unwrap();
    show(&kvc, &format!("A ballooned to 10 pages (over target: {over})"));

    // B can now grow into the reclaimed space.
    let mut b_blocks = Vec::new();
    loop {
        match kvc.alloc_block(b) {
            Ok(blk) => b_blocks.push(blk),
            Err(KvError::OutOfPages(_)) => break,
            Err(e) => panic!("{e}"),
        }
    }
    show(&kvc, &format!("B grew into reclaimed space ({} blocks)", b_blocks.len()));

    // Evict A entirely (time sharing): weights + KV fund B's next burst.
    for blk in a_blocks {
        kvc.free_block(blk).unwrap();
    }
    kvc.unregister_kv(a);
    kvc.unload_weights(a);
    show(&kvc, "A evicted (weights + KV reclaimed)");

    let c = kvc.pool_counters();
    println!(
        "\npool counters: {} pages mapped, {} unmapped, prealloc hits {} / misses {}",
        c.pages_mapped, c.pages_unmapped, c.prealloc_hits, c.prealloc_misses
    );
    println!("balloon mechanics OK - same pool served spatial AND temporal sharing.");
}
