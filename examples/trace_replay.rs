//! Trace replay: the Fig 5-style comparison at example scale.
//!
//! Replays a novita-like synthetic trace (bursty groups, heavy-tailed idles,
//! volatile rates - SS3 statistics) over a simulated 4-GPU cluster under
//! Prism and all four baselines, printing the attainment table.
//!
//! Run: `cargo run --release --example trace_replay`

use prism::bench::harness::Table;
use prism::experiments::e2e::assign_ids;
use prism::model::spec::table3_catalog;
use prism::sim::{PolicyKind, SimConfig, Simulator};
use prism::trace::gen::{generate, TraceGenConfig};

fn main() {
    let cat = table3_catalog();
    let specs = assign_ids(
        cat.iter()
            .filter(|m| m.name.contains("8b") || m.name.contains("7b"))
            .take(8)
            .cloned()
            .collect(),
    );
    let trace = generate(&TraceGenConfig::novita_like(specs.len(), 600.0, 3)).scale_rate(2.0);
    println!(
        "trace: {} requests over {:.0}s across {} models",
        trace.events.len(),
        trace.duration,
        trace.n_models
    );

    let mut t = Table::new(
        "Prism vs baselines: novita-like trace, 8x7-8B models, 4 GPUs",
        &["system", "ttft_att", "tpot_att", "mean_ttft_s", "p95_ttft_s",
          "tok_tput_busy", "activ", "evict", "migr"],
    );
    for p in PolicyKind::all() {
        let mut cfg = SimConfig::new(p, 4);
        cfg.slo_scale = 8.0;
        let t0 = std::time::Instant::now();
        let (m, _) = Simulator::new(cfg, specs.clone()).run(&trace);
        eprintln!("  {} simulated in {:.2}s", p.name(), t0.elapsed().as_secs_f64());
        t.row(vec![
            p.name().into(),
            format!("{:.3}", m.ttft_attainment()),
            format!("{:.3}", m.tpot_attainment()),
            format!("{:.3}", m.mean_ttft()),
            format!("{:.3}", m.p95_ttft()),
            format!("{:.0}", m.token_throughput()),
            m.activations.to_string(),
            m.evictions.to_string(),
            m.migrations.to_string(),
        ]);
    }
    t.print();
}
