//! Trace replay: the Fig 5-style comparison at example scale.
//!
//! Replays a novita-like synthetic trace (bursty groups, heavy-tailed idles,
//! volatile rates - SS3 statistics) over a simulated 4-GPU cluster under
//! every registered policy (Prism, the four paper baselines, the seallm
//! latency-aware sharing baseline, and the melange cost-aware placer),
//! printing the attainment table.
//!
//! Run: `cargo run --release --example trace_replay`
// Printing is the point of this target (see Cargo.toml lints.clippy).
#![allow(clippy::print_stdout, clippy::print_stderr)]

use prism::bench::harness::Table;
use prism::experiments::e2e::assign_ids;
use prism::model::spec::table3_catalog;
use prism::sim::SimConfig;
use prism::sweep::{default_jobs, run_points, SweepGrid};
use prism::trace::gen::{generate, TraceGenConfig};

fn main() {
    let cat = table3_catalog();
    let specs = assign_ids(
        cat.iter()
            .filter(|m| m.name.contains("8b") || m.name.contains("7b"))
            .take(8)
            .cloned()
            .collect(),
    );
    let trace = generate(&TraceGenConfig::novita_like(specs.len(), 600.0, 3)).scale_rate(2.0);
    println!(
        "trace: {} requests over {:.0}s across {} models",
        trace.events.len(),
        trace.duration,
        trace.n_models
    );

    let mut t = Table::new(
        "Prism vs baselines: novita-like trace, 8x7-8B models, 4 GPUs",
        &["system", "ttft_att", "tpot_att", "mean_ttft_s", "p95_ttft_s",
          "tok_tput_busy", "activ", "evict", "migr"],
    );
    // One sweep point per policy, executed on the worker pool; results come
    // back keyed to points, so the table order never depends on scheduling.
    let points = SweepGrid::new().gpus(&[4]).points();
    let workers = default_jobs().min(points.len());
    let t0 = std::time::Instant::now();
    let results = run_points(&points, 0, |_, pt| {
        // The table prints a percentile column: full dump keeps it exact.
        let cfg = SimConfig::for_policy(pt.policy)
            .gpus(pt.n_gpus)
            .slo_scale(pt.slo_scale)
            .full_dump(true);
        pt.run_with(cfg, &specs, &trace)
    });
    eprintln!(
        "  {} policies simulated in {:.2}s on {} workers",
        points.len(),
        t0.elapsed().as_secs_f64(),
        workers
    );
    for (pt, m) in points.iter().zip(&results) {
        t.row(vec![
            pt.policy.into(),
            format!("{:.3}", m.ttft_attainment()),
            format!("{:.3}", m.tpot_attainment()),
            format!("{:.3}", m.mean_ttft()),
            format!("{:.3}", m.p95_ttft()),
            format!("{:.0}", m.token_throughput()),
            m.activations.to_string(),
            m.evictions.to_string(),
            m.migrations.to_string(),
        ]);
    }
    t.print();
}
