//! Colocation demo (Fig 6 in miniature, on REAL model execution).
//!
//! Two PrismNano models share one device's physical KV pool through
//! kvcached. Phase 1: both limited to half the pool (static partition).
//! Phase 2: the balloon shifts capacity to the busy model (Prism).
//! The busy model's achievable batch - and therefore throughput - grows.
//!
//! Run: `make artifacts && cargo run --release --example colocation`
// Printing is the point of this target (see Cargo.toml lints.clippy).
#![allow(clippy::print_stdout, clippy::print_stderr)]

use prism::serve::{RealServer, ServeRequest, ServerConfig};
use prism::util::rng::Rng;

fn workload(model: &str, n: usize, rng: &mut Rng) -> Vec<ServeRequest> {
    (0..n)
        .map(|_| ServeRequest {
            model: model.into(),
            prompt: (0..24).map(|_| rng.below(255) as i32).collect(),
            max_new_tokens: 10,
            arrival: 0.0,
            ttft_slo: Some(5.0),
        })
        .collect()
}

fn main() -> anyhow::Result<()> {
    let root = std::path::PathBuf::from(env!("CARGO_MANIFEST_DIR")).join("artifacts");
    let nano = root.join("prism-nano");
    let micro = root.join("prism-micro");
    if !nano.join("manifest.json").is_file() {
        anyhow::bail!("artifacts missing - run `make artifacts` first");
    }
    let mut rng = Rng::new(7);

    // Static partition: each model capped at a small equal share.
    let cfg = ServerConfig { max_batch: 8, ..Default::default() };
    let mut srv = RealServer::new(cfg, &[nano.as_path(), micro.as_path()], &[12, 12])?;

    println!("phase 1: static partition (12 slots each), burst on prism-nano");
    let burst = workload("prism-nano", 10, &mut rng);
    let t0 = std::time::Instant::now();
    let r1 = srv.serve(&burst)?;
    let t1 = t0.elapsed().as_secs_f64();
    let tok1: usize = r1.iter().flatten().map(|r| r.generated.len()).sum();
    println!("  static: {tok1} tokens in {t1:.2}s -> {:.1} tok/s", tok1 as f64 / t1);

    // Ballooning: idle micro shrinks to 2 slots, nano grows to 22.
    println!("phase 2: balloon - micro 12->2 slots, nano 12->22 slots");
    srv.set_limit("prism-micro", 2)?;
    srv.set_limit("prism-nano", 22)?;
    let burst = workload("prism-nano", 10, &mut rng);
    let t0 = std::time::Instant::now();
    let r2 = srv.serve(&burst)?;
    let t2 = t0.elapsed().as_secs_f64();
    let tok2: usize = r2.iter().flatten().map(|r| r.generated.len()).sum();
    println!("  balloon: {tok2} tokens in {t2:.2}s -> {:.1} tok/s", tok2 as f64 / t2);

    println!(
        "\nthroughput ratio (balloon/static): {:.2}x  - elastic memory lets the \
         busy model use the idle tenant's capacity (paper Fig 6).",
        (tok2 as f64 / t2) / (tok1 as f64 / t1)
    );
    Ok(())
}
