"""L2 correctness: PrismNano model semantics.

The core signal is teacher-forcing equivalence: running prefill over N
tokens, paging the KV, then decoding token N must produce exactly the logits
of a monolithic prefill over N+1 tokens. This proves the paged decode path
(kernel + merge + pool layout + block tables) is semantically identical to
dense attention.
"""

import numpy as np
import pytest
import jax.numpy as jnp
from hypothesis import given, settings, strategies as st

from compile import model as M


def scatter_kv_to_pool(cfg, kv, lens, pool_pages):
    """Mimic the Rust coordinator: write prefill KV into pool pages."""
    B = kv.shape[0]
    Tp = cfg.page_tokens
    pool = np.zeros(
        (pool_pages, Tp, cfg.n_layers, 2, cfg.n_kv_heads, cfg.d_head), np.float32
    )
    bt = np.zeros((B, cfg.max_pages), np.int32)
    nxt = 1  # page 0 kept as scratch so id 0 is never a real mapping
    for b in range(B):
        n = max(1, int(np.ceil(lens[b] / Tp)))
        for p in range(n):
            bt[b, p] = nxt
            lo, hi = p * Tp, min((p + 1) * Tp, int(lens[b]))
            if hi > lo:
                pool[nxt, : hi - lo] = kv[b, lo:hi]
            nxt += 1
    return pool, bt


@pytest.mark.parametrize("name", list(M.CONFIGS.keys()))
@pytest.mark.parametrize("use_kernel", [True, False])
def test_teacher_forcing_equivalence(name, use_kernel):
    cfg = M.CONFIGS[name]
    w = M.weights_list(cfg, M.init_weights(cfg, 0))
    rng = np.random.default_rng(7)
    B, T = 2, 20
    toks = rng.integers(0, cfg.vocab, size=(B, T + 1)).astype(np.int32)
    lens = np.array([T, 9], np.int32)

    logits_ref, _ = M.prefill(
        cfg, w, jnp.array(toks), jnp.array(lens + 1), use_kernel=False
    )
    _, kv = M.prefill(cfg, w, jnp.array(toks[:, :T]), jnp.array(lens),
                      use_kernel=use_kernel)
    pool, bt = scatter_kv_to_pool(cfg, np.array(kv), lens, pool_pages=32)
    nxt_tok = np.array([toks[b, lens[b]] for b in range(B)], np.int32)
    logits_dec, new_kv = M.decode(
        cfg, w, jnp.array(nxt_tok), jnp.array(lens), jnp.array(pool),
        jnp.array(bt), jnp.array(lens), use_kernel=use_kernel,
    )
    np.testing.assert_allclose(
        np.array(logits_dec), np.array(logits_ref), atol=5e-4, rtol=1e-3
    )
    assert new_kv.shape == (B, cfg.n_layers, 2, cfg.n_kv_heads, cfg.d_head)


def test_multi_step_decode_chain():
    """Decode 4 tokens sequentially writing new_kv into the pool each step;
    compare against monolithic prefill logits at each position."""
    cfg = M.CONFIGS["prism-nano"]
    w = M.weights_list(cfg, M.init_weights(cfg, 1))
    rng = np.random.default_rng(11)
    T0, steps = 6, 4
    toks = rng.integers(0, cfg.vocab, size=(1, T0 + steps)).astype(np.int32)
    lens0 = np.array([T0], np.int32)

    _, kv = M.prefill(cfg, w, jnp.array(toks[:, :T0]), jnp.array(lens0))
    pool, bt = scatter_kv_to_pool(cfg, np.array(kv), lens0, pool_pages=16)
    Tp = cfg.page_tokens
    cur = int(lens0[0])
    next_free_page = int(bt[0].max()) + 1
    for s in range(steps):
        tok = np.array([toks[0, cur]], np.int32)
        logits, new_kv = M.decode(
            cfg, w, jnp.array(tok), jnp.array([cur], np.int32), jnp.array(pool),
            jnp.array(bt), jnp.array([cur], np.int32),
        )
        ref_logits, _ = M.prefill(
            cfg, w, jnp.array(toks[:, : cur + 1]),
            jnp.array([cur + 1], np.int32), use_kernel=False,
        )
        np.testing.assert_allclose(
            np.array(logits), np.array(ref_logits), atol=5e-4, rtol=1e-3
        )
        # Rust-side bookkeeping: write new kv into the pool.
        page_idx, slot = cur // Tp, cur % Tp
        if bt[0, page_idx] == 0:
            bt[0, page_idx] = next_free_page
            next_free_page += 1
        pool[bt[0, page_idx], slot] = np.array(new_kv)[0]
        cur += 1


@settings(max_examples=8, deadline=None)
@given(st.integers(1, 4), st.integers(1, 24), st.integers(0, 2**31 - 1))
def test_prefill_shapes_and_padding_invariance(B, T, seed):
    """Padded tail tokens must not affect last-valid-token logits."""
    cfg = M.CONFIGS["prism-nano"]
    w = M.weights_list(cfg, M.init_weights(cfg, 0))
    rng = np.random.default_rng(seed)
    toks = rng.integers(0, cfg.vocab, size=(B, T)).astype(np.int32)
    lens = rng.integers(1, T + 1, size=(B,)).astype(np.int32)
    lg1, kv1 = M.prefill(cfg, w, jnp.array(toks), jnp.array(lens), use_kernel=False)
    # Scramble padding region.
    toks2 = toks.copy()
    for b in range(B):
        toks2[b, lens[b]:] = rng.integers(0, cfg.vocab, size=(T - lens[b],))
    lg2, _ = M.prefill(cfg, w, jnp.array(toks2), jnp.array(lens), use_kernel=False)
    np.testing.assert_allclose(np.array(lg1), np.array(lg2), atol=1e-4, rtol=1e-3)
    assert lg1.shape == (B, cfg.vocab)
    assert kv1.shape == (B, T, cfg.n_layers, 2, cfg.n_kv_heads, cfg.d_head)


def test_weight_catalog_consistency():
    for cfg in M.CONFIGS.values():
        names = cfg.weight_names()
        assert len(names) == len(set(names))
        w = M.init_weights(cfg)
        assert set(w.keys()) == set(names)
        for n in names:
            assert w[n].shape == cfg.weight_shape(n)
        # kv_bytes_per_token matches the physical pool slice size
        assert cfg.kv_bytes_per_token == cfg.n_layers * 2 * cfg.n_kv_heads * cfg.d_head * 4
        assert cfg.max_seq % cfg.page_tokens == 0


def test_init_deterministic():
    cfg = M.CONFIGS["prism-nano"]
    a = M.init_weights(cfg, 42)
    b = M.init_weights(cfg, 42)
    c = M.init_weights(cfg, 43)
    for n in cfg.weight_names():
        np.testing.assert_array_equal(a[n], b[n])
    assert any(not np.array_equal(a[n], c[n]) for n in cfg.weight_names())
