"""L1 correctness: Pallas kernels vs pure-jnp oracles.

Hypothesis sweeps shapes/dtypes for the paged-attention kernel and rmsnorm;
deterministic edge-case tests cover empty sequences, page boundaries, GQA
groupings, and the online-softmax merge.
"""

import numpy as np
import pytest
import jax
import jax.numpy as jnp
from hypothesis import given, settings, strategies as st

from compile.kernels.paged_attention import paged_attention, merge_with_current
from compile.kernels.rmsnorm import rmsnorm
from compile.kernels import ref


def make_case(rng, B, H, Hkv, Dh, Tp, L, P, maxp, dtype=jnp.float32):
    q = jnp.array(rng.normal(size=(B, H, Dh)), dtype)
    pool = jnp.array(rng.normal(size=(P, Tp, L, 2, Hkv, Dh)), dtype)
    bt = jnp.array(rng.integers(0, P, size=(B, maxp)), jnp.int32)
    lens = jnp.array(rng.integers(0, maxp * Tp + 1, size=(B,)), jnp.int32)
    return q, pool, bt, lens


def assert_match(q, pool, bt, lens, layer, atol):
    o_k, lse_k = paged_attention(q, pool, bt, lens, layer)
    o_r, lse_r = ref.paged_attention_ref(q, pool, bt, lens, layer)
    np.testing.assert_allclose(np.array(o_k), np.array(o_r), atol=atol, rtol=1e-3)
    # lse agreement only matters where some token is attended.
    m = np.array(lens)[:, None] > 0
    lk, lr = np.array(lse_k), np.array(lse_r)
    np.testing.assert_allclose(
        np.where(m, lk, 0.0), np.where(m, lr, 0.0), atol=atol, rtol=1e-3
    )


# ---------------------------------------------------------------- hypothesis

shape_strategy = st.tuples(
    st.integers(1, 4),                      # B
    st.sampled_from([(1, 1), (2, 1), (4, 2), (4, 4), (8, 2)]),  # (H, Hkv)
    st.sampled_from([4, 8, 16]),            # Dh
    st.sampled_from([2, 4, 16]),            # Tp
    st.integers(1, 3),                      # L
    st.integers(1, 4),                      # maxp
)


@settings(max_examples=25, deadline=None)
@given(shape_strategy, st.integers(0, 2**31 - 1))
def test_paged_attention_matches_ref_f32(shape, seed):
    B, (H, Hkv), Dh, Tp, L, maxp = shape
    rng = np.random.default_rng(seed)
    P = maxp * B + 2
    q, pool, bt, lens = make_case(rng, B, H, Hkv, Dh, Tp, L, P, maxp)
    layer = int(rng.integers(0, L))
    assert_match(q, pool, bt, lens, layer, atol=2e-5)


@settings(max_examples=10, deadline=None)
@given(st.integers(0, 2**31 - 1))
def test_paged_attention_matches_ref_bf16(seed):
    rng = np.random.default_rng(seed)
    q, pool, bt, lens = make_case(rng, 2, 4, 2, 8, 4, 2, 6, 2, dtype=jnp.bfloat16)
    o_k, _ = paged_attention(q, pool, bt, lens, 0)
    o_r, _ = ref.paged_attention_ref(q, pool, bt, lens, 0)
    np.testing.assert_allclose(
        np.array(o_k, np.float32), np.array(o_r, np.float32), atol=0.05, rtol=0.05
    )
    assert o_k.dtype == jnp.bfloat16


@settings(max_examples=25, deadline=None)
@given(
    st.integers(1, 6),  # rows
    st.sampled_from([4, 16, 64, 128]),  # d
    st.integers(0, 2**31 - 1),
)
def test_rmsnorm_matches_ref(rows, d, seed):
    rng = np.random.default_rng(seed)
    x = jnp.array(rng.normal(size=(rows, d)), jnp.float32)
    w = jnp.array(rng.normal(size=(d,)), jnp.float32)
    np.testing.assert_allclose(
        np.array(rmsnorm(x, w)), np.array(ref.rmsnorm_ref(x, w)), atol=1e-5
    )


# ------------------------------------------------------------- edge cases

def test_empty_sequence_returns_zero():
    rng = np.random.default_rng(0)
    q, pool, bt, _ = make_case(rng, 2, 4, 2, 8, 4, 2, 8, 2)
    lens = jnp.array([0, 0], jnp.int32)
    o, lse = paged_attention(q, pool, bt, lens, 0)
    assert np.allclose(np.array(o), 0.0)
    assert np.all(np.array(lse) <= -1e29)


def test_exact_page_boundary():
    """seq_len that exactly fills its pages must not read a phantom page."""
    rng = np.random.default_rng(1)
    Tp, maxp = 4, 3
    q, pool, bt, _ = make_case(rng, 1, 2, 2, 8, Tp, 1, 6, maxp)
    for n_tok in (Tp, 2 * Tp, 3 * Tp):
        lens = jnp.array([n_tok], jnp.int32)
        assert_match(q, pool, bt, lens, 0, atol=2e-5)


def test_single_token():
    rng = np.random.default_rng(2)
    q, pool, bt, _ = make_case(rng, 3, 4, 1, 16, 8, 2, 8, 2)
    lens = jnp.array([1, 1, 1], jnp.int32)
    assert_match(q, pool, bt, lens, 1, atol=2e-5)


def test_gqa_head_mapping():
    """Each q head must read its own kv group: craft a pool where groups differ."""
    B, H, Hkv, Dh, Tp = 1, 4, 2, 4, 2
    pool = np.zeros((2, Tp, 1, 2, Hkv, Dh), np.float32)
    pool[0, :, 0, 0, 0, :] = 1.0   # K for kv head 0
    pool[0, :, 0, 1, 0, :] = 5.0   # V for kv head 0
    pool[0, :, 0, 0, 1, :] = 1.0   # K for kv head 1
    pool[0, :, 0, 1, 1, :] = -7.0  # V for kv head 1
    q = jnp.ones((B, H, Dh), jnp.float32)
    bt = jnp.zeros((B, 1), jnp.int32)
    lens = jnp.array([2], jnp.int32)
    o, _ = paged_attention(q, jnp.array(pool), bt, lens, 0)
    o = np.array(o)
    # heads 0,1 -> kv head 0 (value 5); heads 2,3 -> kv head 1 (value -7)
    np.testing.assert_allclose(o[0, 0], 5.0, atol=1e-5)
    np.testing.assert_allclose(o[0, 1], 5.0, atol=1e-5)
    np.testing.assert_allclose(o[0, 2], -7.0, atol=1e-5)
    np.testing.assert_allclose(o[0, 3], -7.0, atol=1e-5)


def test_softmax_invariance_to_score_shift():
    """Adding a constant to all K along q direction shifts scores uniformly;
    attention output over identical V must be unchanged."""
    rng = np.random.default_rng(3)
    q, pool, bt, _ = make_case(rng, 1, 2, 2, 8, 4, 1, 4, 2)
    lens = jnp.array([6], jnp.int32)
    o1, _ = paged_attention(q, pool, bt, lens, 0)
    qn = q / jnp.linalg.norm(q, axis=-1, keepdims=True)
    # shift K by c * q_unit => scores shift by c*|q| (uniform per head)
    shifted = np.array(pool)
    o2, _ = paged_attention(q, pool, bt, lens, 0)
    np.testing.assert_allclose(np.array(o1), np.array(o2), atol=1e-6)


@settings(max_examples=20, deadline=None)
@given(st.integers(0, 2**31 - 1))
def test_merge_with_current_equals_full_softmax(seed):
    """merge_with_current(out_past, lse, q, k_cur, v_cur) must equal attention
    over past+current computed monolithically."""
    rng = np.random.default_rng(seed)
    B, H, Hkv, Dh, Tp, maxp = 2, 4, 2, 8, 4, 2
    P = 6
    q, pool, _, _ = make_case(rng, B, H, Hkv, Dh, Tp, 1, P, maxp)
    # Distinct pages per slot: the real system (kvcached) never double-maps a
    # physical page, and this test mutates the pool, so duplicates would
    # corrupt other sequences' KV.
    perm = rng.permutation(P)[: B * maxp]
    bt = jnp.array(perm.reshape(B, maxp), jnp.int32)
    lens = jnp.array(rng.integers(1, maxp * Tp, size=(B,)), jnp.int32)
    k_cur = jnp.array(rng.normal(size=(B, Hkv, Dh)), jnp.float32)
    v_cur = jnp.array(rng.normal(size=(B, Hkv, Dh)), jnp.float32)

    o_past, lse = paged_attention(q, pool, bt, lens, 0)
    merged = np.array(merge_with_current(o_past, lse, q, k_cur, v_cur))

    # Monolithic: write current kv into a fresh pool slot and extend lens.
    pool2 = np.array(pool)
    bt2 = np.array(bt)
    cur = np.array(lens)
    for b in range(B):
        page_idx = cur[b] // Tp
        slot = cur[b] % Tp
        pg = bt2[b, page_idx]
        pool2[pg, slot, 0, 0] = np.array(k_cur)[b]
        pool2[pg, slot, 0, 1] = np.array(v_cur)[b]
    o_full, _ = ref.paged_attention_ref(
        q, jnp.array(pool2), jnp.array(bt2), jnp.array(cur + 1), 0
    )
    np.testing.assert_allclose(merged, np.array(o_full), atol=3e-5, rtol=1e-3)


def test_prefill_ref_causality():
    """Future tokens must not influence earlier positions."""
    rng = np.random.default_rng(4)
    B, T, H, Hkv, Dh = 1, 6, 2, 1, 4
    q = jnp.array(rng.normal(size=(B, T, H, Dh)), jnp.float32)
    k = jnp.array(rng.normal(size=(B, T, Hkv, Dh)), jnp.float32)
    v = jnp.array(rng.normal(size=(B, T, Hkv, Dh)), jnp.float32)
    lens = jnp.array([T], jnp.int32)
    o1 = np.array(ref.attention_prefill_ref(q, k, v, lens))
    k2 = k.at[0, -1].set(99.0)
    v2 = v.at[0, -1].set(-99.0)
    o2 = np.array(ref.attention_prefill_ref(q, k2, v2, lens))
    np.testing.assert_allclose(o1[:, :-1], o2[:, :-1], atol=1e-6)
    assert not np.allclose(o1[:, -1], o2[:, -1])
