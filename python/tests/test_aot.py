"""AOT export path: HLO text well-formedness + manifest/weights consistency."""

import json
import os

import numpy as np
import pytest

from compile import aot
from compile import model as M

ART = os.path.join(os.path.dirname(__file__), "..", "..", "artifacts")


def entry_param_count(text: str) -> int:
    """Count parameters of the ENTRY computation only (nested computations
    introduced by while-loops also contain `parameter(` instructions).
    The ENTRY computation is the last block in the HLO text dump."""
    entry = text[text.index("ENTRY"):]
    return entry.count(" parameter(")


def test_lower_prefill_produces_hlo_text():
    cfg = M.CONFIGS["prism-nano"]
    text = aot.lower_prefill(cfg, 1, 16)
    assert "HloModule" in text
    assert "ENTRY" in text
    # One parameter per weight + tokens + lens.
    assert entry_param_count(text) == len(cfg.weight_names()) + 2


def test_lower_decode_produces_hlo_text():
    cfg = M.CONFIGS["prism-nano"]
    text = aot.lower_decode(cfg, 2)
    assert "HloModule" in text
    assert entry_param_count(text) == len(cfg.weight_names()) + 5  # tok, pos, pool, bt, lens
    # interpret-mode pallas must lower to plain HLO: no custom-call to mosaic
    assert "tpu_custom_call" not in text


@pytest.mark.skipif(not os.path.isdir(ART), reason="run `make artifacts` first")
@pytest.mark.parametrize("name", list(M.CONFIGS.keys()))
def test_exported_manifest_matches_weights(name):
    d = os.path.join(ART, name)
    if not os.path.isdir(d):
        pytest.skip("model not exported")
    with open(os.path.join(d, "manifest.json")) as f:
        man = json.load(f)
    cfg = M.CONFIGS[name]
    assert man["n_layers"] == cfg.n_layers
    assert man["kv_bytes_per_token"] == cfg.kv_bytes_per_token
    names = [e["name"] for e in man["weights"]]
    assert names == cfg.weight_names()
    size = os.path.getsize(os.path.join(d, man["weights_bin"]))
    assert size == sum(e["bytes"] for e in man["weights"])
    # offsets are contiguous and ordered
    off = 0
    for e in man["weights"]:
        assert e["offset"] == off
        expect = int(np.prod(e["shape"])) * 4
        assert e["bytes"] == expect
        off += e["bytes"]
    # every artifact file exists
    for ph in ("prefill", "decode"):
        for a in man["artifacts"][ph]:
            assert os.path.isfile(os.path.join(d, a["file"]))


@pytest.mark.skipif(not os.path.isdir(ART), reason="run `make artifacts` first")
def test_exported_weights_match_seeded_init():
    """weights.bin must be exactly init_weights(seed) in manifest order."""
    name = "prism-nano"
    d = os.path.join(ART, name)
    if not os.path.isdir(d):
        pytest.skip("model not exported")
    with open(os.path.join(d, "manifest.json")) as f:
        man = json.load(f)
    cfg = M.CONFIGS[name]
    w = M.init_weights(cfg, man["seed"])
    blob = np.fromfile(os.path.join(d, man["weights_bin"]), dtype="<f4")
    for e in man["weights"]:
        lo = e["offset"] // 4
        hi = lo + e["bytes"] // 4
        got = blob[lo:hi].reshape(e["shape"])
        np.testing.assert_array_equal(got, w[e["name"]])
