"""L2: PrismNano - a small decoder-only transformer served by the Rust stack.

Two entry points are AOT-lowered to HLO text (aot.py) and executed by the
Rust coordinator through PJRT:

  prefill(weights, tokens[B,T], lens[B])
      -> (last_logits[B,V], kv[B,T,L,2,Hkv,Dh])
     Full causal attention over the (right-padded) prompt. The Rust side
     scatters the returned contiguous KV into kvcached-managed 2MB pages.

  decode(weights, tokens[B], positions[B], pool[P,Tp,L,2,Hkv,Dh],
         block_tables[B,MAXP], seq_lens[B])
      -> (logits[B,V], new_kv[B,L,2,Hkv,Dh])
     One autoregressive step. Attention over past tokens goes through the
     Pallas paged-attention kernel (L1); the current token's contribution is
     merged in closed form; the Rust side writes new_kv into the pool slot
     chosen by kvcached.

Weights are *arguments*, not constants: the Rust runtime owns weight
residency (upload once per activation as PJRT device buffers), which is
exactly the paper's ballooning story - weights can be evicted to host DRAM
and re-uploaded on activation. Architecture: RMSNorm, GQA attention with
learned absolute position embeddings, SiLU-gated FFN.
"""

from __future__ import annotations

import dataclasses
from typing import Dict, List, Tuple

import jax
import jax.numpy as jnp
import numpy as np

from .kernels.paged_attention import paged_attention, merge_with_current
from .kernels.rmsnorm import rmsnorm
from .kernels import ref as kref


@dataclasses.dataclass(frozen=True)
class ModelConfig:
    name: str
    vocab: int = 256  # byte-level
    d_model: int = 64
    n_layers: int = 2
    n_heads: int = 4
    n_kv_heads: int = 2
    d_head: int = 16
    d_ff: int = 128
    max_seq: int = 256
    page_tokens: int = 16  # tokens per KV page (Tp)

    @property
    def max_pages(self) -> int:
        return self.max_seq // self.page_tokens

    @property
    def kv_bytes_per_token(self) -> int:
        # f32 K+V across all layers - matches the paper's token_size.
        return self.n_layers * 2 * self.n_kv_heads * self.d_head * 4

    def weight_names(self) -> List[str]:
        """Stable flat ordering of weight tensors (the AOT argument order)."""
        names = ["embed", "pos_embed", "final_norm", "lm_head"]
        for i in range(self.n_layers):
            for p in ("attn_norm", "wq", "wk", "wv", "wo", "ffn_norm", "w_gate", "w_up", "w_down"):
                names.append(f"layer{i}.{p}")
        return names

    def weight_shape(self, name: str) -> Tuple[int, ...]:
        c = self
        if name == "embed":
            return (c.vocab, c.d_model)
        if name == "pos_embed":
            return (c.max_seq, c.d_model)
        if name == "final_norm":
            return (c.d_model,)
        if name == "lm_head":
            return (c.d_model, c.vocab)
        p = name.split(".", 1)[1]
        return {
            "attn_norm": (c.d_model,),
            "ffn_norm": (c.d_model,),
            "wq": (c.d_model, c.n_heads * c.d_head),
            "wk": (c.d_model, c.n_kv_heads * c.d_head),
            "wv": (c.d_model, c.n_kv_heads * c.d_head),
            "wo": (c.n_heads * c.d_head, c.d_model),
            "w_gate": (c.d_model, c.d_ff),
            "w_up": (c.d_model, c.d_ff),
            "w_down": (c.d_ff, c.d_model),
        }[p]


# The model family used across examples/benches; the Rust catalog mirrors it.
CONFIGS: Dict[str, ModelConfig] = {
    "prism-nano": ModelConfig(name="prism-nano"),
    "prism-micro": ModelConfig(
        name="prism-micro", d_model=128, n_layers=4, n_heads=8,
        n_kv_heads=4, d_head=16, d_ff=256,
    ),
}


def init_weights(cfg: ModelConfig, seed: int = 0) -> Dict[str, np.ndarray]:
    """Deterministic scaled-gaussian init (serving fidelity, not quality)."""
    rng = np.random.default_rng(seed)
    out: Dict[str, np.ndarray] = {}
    for name in cfg.weight_names():
        shape = cfg.weight_shape(name)
        if name.endswith("norm"):
            w = np.ones(shape, np.float32)
        else:
            fan_in = shape[0] if len(shape) > 1 else 1
            w = rng.normal(0.0, 1.0 / np.sqrt(max(fan_in, 1)), size=shape).astype(np.float32)
        out[name] = w
    return out


def weights_list(cfg: ModelConfig, w: Dict[str, np.ndarray]) -> List[np.ndarray]:
    return [w[n] for n in cfg.weight_names()]


def _unflatten(cfg: ModelConfig, flat) -> Dict[str, jnp.ndarray]:
    return dict(zip(cfg.weight_names(), flat))


def _norm(x, w, use_kernel):
    return rmsnorm(x, w) if use_kernel else kref.rmsnorm_ref(x, w)


def _ffn(w, i, x, use_kernel):
    h = _norm(x, w[f"layer{i}.ffn_norm"], use_kernel)
    g = jax.nn.silu(h @ w[f"layer{i}.w_gate"]) * (h @ w[f"layer{i}.w_up"])
    return x + g @ w[f"layer{i}.w_down"]


def prefill(cfg: ModelConfig, flat_weights, tokens, lens, *, use_kernel: bool = True):
    """Prompt pass. tokens [B,T] int32 right-padded, lens [B] int32.

    Returns (last_logits [B,V], kv [B,T,L,2,Hkv,Dh]).
    """
    w = _unflatten(cfg, flat_weights)
    B, T = tokens.shape
    x = w["embed"][tokens] + w["pos_embed"][:T][None, :, :]
    kv_layers = []
    for i in range(cfg.n_layers):
        h = _norm(x, w[f"layer{i}.attn_norm"], use_kernel)
        q = (h @ w[f"layer{i}.wq"]).reshape(B, T, cfg.n_heads, cfg.d_head)
        k = (h @ w[f"layer{i}.wk"]).reshape(B, T, cfg.n_kv_heads, cfg.d_head)
        v = (h @ w[f"layer{i}.wv"]).reshape(B, T, cfg.n_kv_heads, cfg.d_head)
        o = kref.attention_prefill_ref(q, k, v, lens)
        x = x + o.reshape(B, T, -1) @ w[f"layer{i}.wo"]
        x = _ffn(w, i, x, use_kernel)
        kv_layers.append(jnp.stack([k, v], axis=2))  # [B,T,2,Hkv,Dh]
    kv = jnp.stack(kv_layers, axis=2)  # [B,T,L,2,Hkv,Dh]
    x = _norm(x, w["final_norm"], use_kernel)
    # Logits at each request's last valid token.
    idx = jnp.maximum(lens - 1, 0)
    last = jnp.take_along_axis(x, idx[:, None, None], axis=1)[:, 0, :]
    logits = last @ w["lm_head"]
    return logits, kv


def decode(cfg: ModelConfig, flat_weights, tokens, positions, pool, block_tables,
           seq_lens, *, use_kernel: bool = True):
    """One decode step. tokens/positions [B] int32; pool is the paged KV pool.

    Returns (logits [B,V], new_kv [B,L,2,Hkv,Dh]) - the caller (Rust) writes
    new_kv into the pool at the slot for position `positions[b]`.
    """
    w = _unflatten(cfg, flat_weights)
    B = tokens.shape[0]
    x = w["embed"][tokens] + w["pos_embed"][positions]  # [B, D]
    new_kv_layers = []
    for i in range(cfg.n_layers):
        h = _norm(x, w[f"layer{i}.attn_norm"], use_kernel)
        q = (h @ w[f"layer{i}.wq"]).reshape(B, cfg.n_heads, cfg.d_head)
        k = (h @ w[f"layer{i}.wk"]).reshape(B, cfg.n_kv_heads, cfg.d_head)
        v = (h @ w[f"layer{i}.wv"]).reshape(B, cfg.n_kv_heads, cfg.d_head)
        if use_kernel:
            o_past, lse = paged_attention(q, pool, block_tables, seq_lens, i)
        else:
            o_past, lse = kref.paged_attention_ref(q, pool, block_tables, seq_lens, i)
        o = merge_with_current(o_past, lse, q, k, v)
        x = x + o.reshape(B, -1) @ w[f"layer{i}.wo"]
        x = _ffn(w, i, x, use_kernel)
        new_kv_layers.append(jnp.stack([k, v], axis=1))  # [B,2,Hkv,Dh]
    new_kv = jnp.stack(new_kv_layers, axis=1)  # [B,L,2,Hkv,Dh]
    x = _norm(x, w["final_norm"], use_kernel)
    logits = x @ w["lm_head"]
    return logits, new_kv
