"""AOT compile path: lower PrismNano prefill/decode to HLO **text** + export weights.

Run once by `make artifacts`; python never runs on the request path.

Interchange is HLO text, NOT serialized HloModuleProto: jax >= 0.5 emits
protos with 64-bit instruction ids that the xla crate's xla_extension 0.5.1
rejects (`proto.id() <= INT_MAX`); the text parser reassigns ids and
round-trips cleanly (see /opt/xla-example/README.md). Lowered with
return_tuple=True; the Rust side unwraps the tuple.

Artifact layout (artifacts/<model>/):
  manifest.json                 - config, weight arg order/shapes, buckets
  weights.bin                   - all weights, little-endian f32, manifest order
  prefill_b{B}_t{T}.hlo.txt     - prefill executables per (batch, seq) bucket
  decode_b{B}.hlo.txt           - decode executables per batch bucket

Static shapes per bucket mirror production CUDA-graph practice: the Rust
coordinator picks the nearest bucket and pads.
"""

from __future__ import annotations

import argparse
import json
import os
from typing import List

import jax
import jax.numpy as jnp
import numpy as np
from jax._src.lib import xla_client as xc

from . import model as M

# Batch buckets compiled for each phase. Prefill runs one request at a time
# (chunked prefill admits requests individually); decode batches grow with load.
PREFILL_T_BUCKETS = [16, 64, 256]
DECODE_B_BUCKETS = [1, 2, 4, 8]
POOL_PAGES = 256  # pages in the compiled pool view (per-engine virtual slice)


def to_hlo_text(lowered) -> str:
    mlir_mod = lowered.compiler_ir("stablehlo")
    comp = xc._xla.mlir.mlir_module_to_xla_computation(
        str(mlir_mod), use_tuple_args=False, return_tuple=True
    )
    return comp.as_hlo_text()


def lower_prefill(cfg: M.ModelConfig, b: int, t: int) -> str:
    w_specs = [
        jax.ShapeDtypeStruct(cfg.weight_shape(n), jnp.float32)
        for n in cfg.weight_names()
    ]
    tok = jax.ShapeDtypeStruct((b, t), jnp.int32)
    lens = jax.ShapeDtypeStruct((b,), jnp.int32)

    def fn(*args):
        nw = len(w_specs)
        return M.prefill(cfg, list(args[:nw]), args[nw], args[nw + 1])

    return to_hlo_text(jax.jit(fn).lower(*w_specs, tok, lens))


def lower_decode(cfg: M.ModelConfig, b: int) -> str:
    w_specs = [
        jax.ShapeDtypeStruct(cfg.weight_shape(n), jnp.float32)
        for n in cfg.weight_names()
    ]
    tok = jax.ShapeDtypeStruct((b,), jnp.int32)
    pos = jax.ShapeDtypeStruct((b,), jnp.int32)
    pool = jax.ShapeDtypeStruct(
        (POOL_PAGES, cfg.page_tokens, cfg.n_layers, 2, cfg.n_kv_heads, cfg.d_head),
        jnp.float32,
    )
    bt = jax.ShapeDtypeStruct((b, cfg.max_pages), jnp.int32)
    lens = jax.ShapeDtypeStruct((b,), jnp.int32)

    def fn(*args):
        nw = len(w_specs)
        return M.decode(
            cfg, list(args[:nw]), args[nw], args[nw + 1], args[nw + 2],
            args[nw + 3], args[nw + 4],
        )

    return to_hlo_text(jax.jit(fn).lower(*w_specs, tok, pos, pool, bt, lens))


def export_model(cfg: M.ModelConfig, out_dir: str, seed: int = 0) -> None:
    os.makedirs(out_dir, exist_ok=True)
    weights = M.init_weights(cfg, seed)
    names = cfg.weight_names()

    blob_path = os.path.join(out_dir, "weights.bin")
    offset = 0
    entries = []
    with open(blob_path, "wb") as f:
        for n in names:
            arr = np.ascontiguousarray(weights[n], dtype="<f4")
            f.write(arr.tobytes())
            entries.append({
                "name": n,
                "shape": list(arr.shape),
                "offset": offset,
                "bytes": arr.nbytes,
            })
            offset += arr.nbytes

    artifacts = {"prefill": [], "decode": []}
    for t in PREFILL_T_BUCKETS:
        if t > cfg.max_seq:
            continue
        fname = f"prefill_b1_t{t}.hlo.txt"
        with open(os.path.join(out_dir, fname), "w") as f:
            f.write(lower_prefill(cfg, 1, t))
        artifacts["prefill"].append({"batch": 1, "tokens": t, "file": fname})
        print(f"  {cfg.name}: {fname}")
    for b in DECODE_B_BUCKETS:
        fname = f"decode_b{b}.hlo.txt"
        with open(os.path.join(out_dir, fname), "w") as f:
            f.write(lower_decode(cfg, b))
        artifacts["decode"].append({"batch": b, "file": fname})
        print(f"  {cfg.name}: {fname}")

    manifest = {
        "name": cfg.name,
        "vocab": cfg.vocab,
        "d_model": cfg.d_model,
        "n_layers": cfg.n_layers,
        "n_heads": cfg.n_heads,
        "n_kv_heads": cfg.n_kv_heads,
        "d_head": cfg.d_head,
        "d_ff": cfg.d_ff,
        "max_seq": cfg.max_seq,
        "page_tokens": cfg.page_tokens,
        "max_pages": cfg.max_pages,
        "pool_pages": POOL_PAGES,
        "kv_bytes_per_token": cfg.kv_bytes_per_token,
        "weights_bin": "weights.bin",
        "weights": entries,
        "artifacts": artifacts,
        "seed": seed,
    }
    with open(os.path.join(out_dir, "manifest.json"), "w") as f:
        json.dump(manifest, f, indent=2)


def main() -> None:
    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("--out", default="../artifacts", help="artifacts root dir")
    ap.add_argument("--models", nargs="*", default=list(M.CONFIGS.keys()))
    args = ap.parse_args()
    root = args.out
    os.makedirs(root, exist_ok=True)
    for name in args.models:
        cfg = M.CONFIGS[name]
        print(f"exporting {name} ...")
        export_model(cfg, os.path.join(root, name))
    # Stamp: lets `make artifacts` skip when inputs are unchanged.
    with open(os.path.join(root, "STAMP"), "w") as f:
        f.write("ok\n")
    print("artifacts complete")


if __name__ == "__main__":
    main()
