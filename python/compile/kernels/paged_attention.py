"""L1 Pallas kernel: paged attention for one decode step.

This is the compute hot-spot the paper's memory mechanism protects: attention
over a KV cache that lives in a *paged pool* shared by all co-located models
(kvcached, paper SS5). The pool layout follows the paper's D3 optimization -
all layers' K and V vectors of a token are contiguous within a page
([P, Tp, L, 2, Hkv, Dh]), so the Rust coordinator maps one physical page per
Tp tokens regardless of layer count.

TPU adaptation of the GPU original (PagedAttention CUDA kernel):
  * the block table drives an HBM->VMEM gather of one KV page per loop step
    (the role CUDA threadblock scheduling plays on GPU),
  * q.kT and p.v products per page are MXU-shaped [Tp, Dh] matmuls,
  * an online-softmax accumulator (m, l, acc) lives in registers/VMEM scratch,
  * grid = (B, H): each program owns one (sequence, query-head) pair.

Lowered with interpret=True: the CPU PJRT plugin cannot run Mosaic
custom-calls, so interpret mode turns the kernel into plain HLO (while-loops
and dynamic-slices) which executes anywhere. Real-TPU VMEM/MXU estimates are
documented in DESIGN.md SSPerf.
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

NEG_INF = -1e30


def _decode_kernel(
    bt_ref,  # [1, MAXP] int32 block table row for this sequence
    len_ref,  # [1] int32 seq length (past tokens in pool)
    q_ref,  # [1, 1, Dh] query for this (b, h)
    pool_ref,  # [P, Tp, L, 2, Hkv, Dh] full pool (no blocking)
    o_ref,  # [1, 1, Dh] out
    lse_ref,  # [1, 1] out log-sum-exp
    *,
    layer: int,
    kv_head: int,  # which kv head this q head reads (GQA), static per-h? no: computed
    tp: int,
    maxp: int,
    group: int,
):
    h = pl.program_id(1)
    kvh = h // group
    dh = q_ref.shape[-1]
    q = q_ref[0, 0, :].astype(jnp.float32)  # [Dh]
    seq_len = len_ref[0]
    scale = 1.0 / jnp.sqrt(jnp.float32(dh))

    # Number of pages that actually hold tokens.
    n_pages = (seq_len + tp - 1) // tp

    def body(i, carry):
        m_prev, l_prev, acc_prev = carry
        page = bt_ref[0, i]
        # Gather one KV page: K,V [Tp, Dh] for (layer, kvh).
        k = pl.load(
            pool_ref,
            (page, pl.dslice(0, tp), jnp.int32(layer), jnp.int32(0), kvh, pl.dslice(0, dh)),
        ).astype(jnp.float32)
        v = pl.load(
            pool_ref,
            (page, pl.dslice(0, tp), jnp.int32(layer), jnp.int32(1), kvh, pl.dslice(0, dh)),
        ).astype(jnp.float32)
        s = jnp.dot(k, q) * scale  # [Tp]  (MXU-shaped on real TPU)
        pos = i * tp + jax.lax.iota(jnp.int32, tp)
        s = jnp.where(pos < seq_len, s, NEG_INF)
        m_new = jnp.maximum(m_prev, jnp.max(s))
        alpha = jnp.exp(m_prev - m_new)
        p = jnp.exp(s - m_new)
        p = jnp.where(pos < seq_len, p, 0.0)
        l_new = l_prev * alpha + jnp.sum(p)
        acc_new = acc_prev * alpha + jnp.dot(p, v)
        return m_new, l_new, acc_new

    m0 = jnp.float32(NEG_INF)
    l0 = jnp.float32(0.0)
    acc0 = jnp.zeros((dh,), jnp.float32)
    m, l, acc = jax.lax.fori_loop(0, n_pages, body, (m0, l0, acc0))

    has = l > 0.0
    out = jnp.where(has, acc / jnp.maximum(l, 1e-30), 0.0)
    lse = jnp.where(has, m + jnp.log(jnp.maximum(l, 1e-30)), NEG_INF)
    o_ref[0, 0, :] = out.astype(o_ref.dtype)
    lse_ref[0, 0] = lse


def paged_attention(
    q: jnp.ndarray,  # [B, H, Dh]
    pool: jnp.ndarray,  # [P, Tp, L, 2, Hkv, Dh]
    block_tables: jnp.ndarray,  # [B, MAXP] int32
    seq_lens: jnp.ndarray,  # [B] int32
    layer: int,
    *,
    interpret: bool = True,
):
    """Pallas paged attention over past tokens; returns (out [B,H,Dh], lse [B,H])."""
    B, H, Dh = q.shape
    P, Tp, L, two, Hkv, Dh2 = pool.shape
    assert two == 2 and Dh2 == Dh and H % Hkv == 0, (pool.shape, q.shape)
    maxp = block_tables.shape[1]
    group = H // Hkv

    kernel = functools.partial(
        _decode_kernel,
        layer=layer,
        kv_head=0,
        tp=Tp,
        maxp=maxp,
        group=group,
    )
    out, lse = pl.pallas_call(
        kernel,
        grid=(B, H),
        in_specs=[
            pl.BlockSpec((1, maxp), lambda b, h: (b, 0)),  # block table row
            pl.BlockSpec((1,), lambda b, h: (b,)),  # seq len
            pl.BlockSpec((1, 1, Dh), lambda b, h: (b, h, 0)),  # q
            pl.BlockSpec((P, Tp, L, 2, Hkv, Dh), lambda b, h: (0, 0, 0, 0, 0, 0)),
        ],
        out_specs=[
            pl.BlockSpec((1, 1, Dh), lambda b, h: (b, h, 0)),
            pl.BlockSpec((1, 1), lambda b, h: (b, h)),
        ],
        out_shape=[
            jax.ShapeDtypeStruct((B, H, Dh), q.dtype),
            jax.ShapeDtypeStruct((B, H), jnp.float32),
        ],
        interpret=interpret,
    )(block_tables, seq_lens, q, pool)
    return out, lse


def merge_with_current(
    out_past: jnp.ndarray,  # [B, H, Dh] normalized attention over past tokens
    lse_past: jnp.ndarray,  # [B, H]
    q: jnp.ndarray,  # [B, H, Dh]
    k_cur: jnp.ndarray,  # [B, Hkv, Dh] current token's key
    v_cur: jnp.ndarray,  # [B, Hkv, Dh] current token's value
) -> jnp.ndarray:
    """Online-softmax merge of the past attention with the current token.

    The decode step computes the current token's K/V *inside* the step, but
    the Rust coordinator only writes them into the paged pool afterwards, so
    the kernel sees past tokens only. This closed-form merge is exact.
    """
    B, H, Dh = q.shape
    Hkv = k_cur.shape[1]
    group = H // Hkv
    kq = jnp.repeat(k_cur.astype(jnp.float32), group, axis=1)  # [B, H, Dh]
    vq = jnp.repeat(v_cur.astype(jnp.float32), group, axis=1)
    scale = 1.0 / jnp.sqrt(jnp.float32(Dh))
    s_cur = jnp.sum(q.astype(jnp.float32) * kq, axis=-1) * scale  # [B, H]
    m = jnp.maximum(lse_past, s_cur)
    w_past = jnp.exp(lse_past - m)
    w_cur = jnp.exp(s_cur - m)
    denom = w_past + w_cur
    out = (out_past.astype(jnp.float32) * w_past[..., None] + vq * w_cur[..., None]) / denom[..., None]
    return out.astype(q.dtype)
