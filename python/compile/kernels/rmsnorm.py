"""L1 Pallas kernel: RMSNorm over the hidden axis.

Small second kernel exercised by both the prefill and decode graphs; on real
TPU this is a pure-VPU kernel with one row of the activation per program.
Interpret mode (plain HLO) is used for CPU-PJRT execution.
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl


def _rmsnorm_kernel(x_ref, w_ref, o_ref, *, eps: float):
    x = x_ref[0, :].astype(jnp.float32)
    w = w_ref[...].astype(jnp.float32)
    var = jnp.mean(jnp.square(x))
    o_ref[0, :] = (x / jnp.sqrt(var + eps) * w).astype(o_ref.dtype)


def rmsnorm(x: jnp.ndarray, w: jnp.ndarray, eps: float = 1e-6, *, interpret: bool = True):
    """RMSNorm along the last axis for x of shape [..., D]; w is [D]."""
    orig_shape = x.shape
    d = orig_shape[-1]
    rows = 1
    for s in orig_shape[:-1]:
        rows *= s
    x2 = x.reshape(rows, d)
    out = pl.pallas_call(
        functools.partial(_rmsnorm_kernel, eps=eps),
        grid=(rows,),
        in_specs=[
            pl.BlockSpec((1, d), lambda r: (r, 0)),
            pl.BlockSpec((d,), lambda r: (0,)),
        ],
        out_specs=pl.BlockSpec((1, d), lambda r: (r, 0)),
        out_shape=jax.ShapeDtypeStruct((rows, d), x.dtype),
        interpret=interpret,
    )(x2, w)
    return out.reshape(orig_shape)
