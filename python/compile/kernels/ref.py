"""Pure-jnp oracles for the Pallas kernels.

These are the correctness references: every Pallas kernel in this package
must match its oracle to float tolerance under pytest/hypothesis sweeps
(python/tests/test_kernel.py). They are also used to build a kernel-free
reference model for end-to-end equivalence tests.
"""

from __future__ import annotations

import jax.numpy as jnp


def rmsnorm_ref(x: jnp.ndarray, w: jnp.ndarray, eps: float = 1e-6) -> jnp.ndarray:
    """RMSNorm over the last axis: x / rms(x) * w."""
    xf = x.astype(jnp.float32)
    var = jnp.mean(jnp.square(xf), axis=-1, keepdims=True)
    return (xf / jnp.sqrt(var + eps) * w.astype(jnp.float32)).astype(x.dtype)


def paged_attention_ref(
    q: jnp.ndarray,  # [B, H, Dh]
    pool: jnp.ndarray,  # [P, Tp, L, 2, Hkv, Dh] - the paged KV pool
    block_tables: jnp.ndarray,  # [B, MAXP] int32 page ids
    seq_lens: jnp.ndarray,  # [B] int32 tokens already in the pool
    layer: int,
):
    """Reference paged attention for one decode step over PAST tokens only.

    Returns (out, lse):
      out [B, H, Dh] - softmax(q.kT/sqrt(Dh)) @ v over the first seq_lens[b]
                       tokens addressed through block_tables.
      lse [B, H]     - log-sum-exp of the scaled scores (natural log), used by
                       the caller to merge the current token's contribution.
    Slots with seq_lens[b] == 0 return out = 0, lse = -1e30 (quasi -inf).
    """
    B, H, Dh = q.shape
    _, Tp, _, _, Hkv, _ = pool.shape
    maxp = block_tables.shape[1]
    group = H // Hkv

    # Gather the per-request K/V through the block table: [B, MAXP, Tp, Hkv, Dh]
    k = pool[block_tables, :, layer, 0]
    v = pool[block_tables, :, layer, 1]
    k = k.reshape(B, maxp * Tp, Hkv, Dh).astype(jnp.float32)
    v = v.reshape(B, maxp * Tp, Hkv, Dh).astype(jnp.float32)

    # Broadcast kv heads to q heads (GQA).
    kq = jnp.repeat(k, group, axis=2)  # [B, T, H, Dh]
    vq = jnp.repeat(v, group, axis=2)

    scale = 1.0 / jnp.sqrt(jnp.float32(Dh))
    scores = jnp.einsum("bhd,bthd->bht", q.astype(jnp.float32), kq) * scale
    pos = jnp.arange(maxp * Tp)[None, None, :]
    mask = pos < seq_lens[:, None, None]
    neg = jnp.float32(-1e30)
    scores = jnp.where(mask, scores, neg)

    m = jnp.max(scores, axis=-1)  # [B, H]
    safe_m = jnp.where(m <= neg / 2, 0.0, m)  # guard all-masked rows
    e = jnp.exp(scores - safe_m[..., None]) * mask
    denom = jnp.sum(e, axis=-1)  # [B, H]
    out = jnp.einsum("bht,bthd->bhd", e, vq)
    out = out / jnp.maximum(denom, 1e-30)[..., None]
    lse = jnp.where(denom > 0, safe_m + jnp.log(jnp.maximum(denom, 1e-30)), neg)
    out = jnp.where((denom > 0)[..., None], out, 0.0)
    return out.astype(q.dtype), lse.astype(jnp.float32)


def attention_prefill_ref(
    q: jnp.ndarray,  # [B, T, H, Dh]
    k: jnp.ndarray,  # [B, T, Hkv, Dh]
    v: jnp.ndarray,  # [B, T, Hkv, Dh]
    lens: jnp.ndarray,  # [B] int32 valid prompt lengths (<= T)
) -> jnp.ndarray:
    """Causal full attention with right-padding masks, GQA-aware. [B,T,H,Dh]."""
    B, T, H, Dh = q.shape
    Hkv = k.shape[2]
    group = H // Hkv
    kq = jnp.repeat(k.astype(jnp.float32), group, axis=2)
    vq = jnp.repeat(v.astype(jnp.float32), group, axis=2)
    scale = 1.0 / jnp.sqrt(jnp.float32(Dh))
    scores = jnp.einsum("bqhd,bkhd->bhqk", q.astype(jnp.float32), kq) * scale
    qpos = jnp.arange(T)[None, :, None]
    kpos = jnp.arange(T)[None, None, :]
    causal = kpos <= qpos  # [1, T, T]
    valid = kpos < lens[:, None, None]  # padded keys masked out
    mask = (causal & valid)[:, None, :, :]
    scores = jnp.where(mask, scores, jnp.float32(-1e30))
    probs = jnp.exp(scores - jnp.max(scores, axis=-1, keepdims=True))
    probs = probs * mask
    probs = probs / jnp.maximum(jnp.sum(probs, axis=-1, keepdims=True), 1e-30)
    out = jnp.einsum("bhqk,bkhd->bqhd", probs, vq)
    return out.astype(q.dtype)
