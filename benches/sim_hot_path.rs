//! Simulator hot-path macro-benchmark: simulated-events/sec at cluster
//! scale (50-100 models, 16-32 GPUs, hour-plus novita-like traces, every
//! policy), written to `BENCH_sim.json` so the perf trajectory is tracked
//! across changes. The `churn-*` scenarios squeeze a small-model fleet
//! into a fraction of its working set (high preemption, small KV blocks)
//! to isolate the kvcached allocator + engine per-token path. The
//! `faulty-churn-*` scenarios add a seeded fault plan (GPU crashes,
//! slowdowns, alloc faults, load failures - see `prism::fault`) on top of
//! the churn squeeze, timing the recovery paths. The `het-fleet-*`
//! scenarios run a mixed `FleetSpec` (A100s + L4s) so the per-GPU
//! perf/memory lookups and cost accounting on the heterogeneous path stay
//! on the perf radar too. The `giant-*` pair (full set) runs the same
//! 100-model/32-GPU/2-hour load once on the historical sequential event
//! loop and once on the GPU-group-sharded loop (`SimConfig::shards = 4`)
//! — the intra-run parallelism A/B; the sharded row's acceptance target is
//! >= 2x the sequential row's events/sec on an 8-core-plus runner. The
//! `barrier-heavy-*` scenarios pile dense timeline samples, slowdown-only
//! fault windows, and near-continuous (mostly no-op) control epochs onto
//! the sharded loop: before window batching and cached shard plans every
//! one of those control events forced a full worker recompose, so these
//! rows isolate exactly the batching/caching win (target >= 1.5x the
//! pre-batching sharded events/sec on an 8-core runner).
//!
//! Flags:
//!   --smoke              tiny CI configuration (seconds, not minutes)
//!   --prepush            ALSO time the legacy pre-pushed-arrival heap
//!                        (`SimConfig::stream_arrivals = false`) for an
//!                        in-binary A/B of the streamed event loop
//!   --sweep              ALSO run a policy x SLO sweep grid through the
//!                        parallel sweep engine (aggregate events/sec over
//!                        the whole grid; `--jobs` sets the worker count)
//!   --jobs N             sweep worker count (default: auto)
//!   --baseline <file>    report speedup vs a previously recorded
//!                        BENCH_sim.json (env PRISM_BENCH_BASELINE works
//!                        too); run the bench on the pre-change commit to
//!                        produce one
//!   --gate-pct <p>       with a baseline: exit non-zero if any row's
//!                        events/sec regressed more than p percent
//!                        (default 15). This is the CI perf gate.
//!   --policy <name>      only run policies whose name contains <name>
//!   --scenario <name>    only run scenarios whose name contains <name>
//!   --shards N           override every scenario's intra-run shard count
//!                        (0 = auto, 1 = sequential; default: per-scenario)
// Printing is the point of this target (see Cargo.toml lints.clippy).
#![allow(clippy::print_stdout, clippy::print_stderr)]

use std::collections::BTreeMap;
use std::time::Instant;

use prism::bench::harness::Table;
use prism::cluster::FleetSpec;
use prism::metrics::RunMetrics;
use prism::model::spec::{catalog_subset, ModelId, ModelSpec};
use prism::sim::{registry, SimConfig, Simulator};
use prism::sweep::{resolve_jobs, run_points, SweepGrid};
use prism::trace::gen::{generate, TraceGenConfig};
use prism::util::json::{self, Json};

struct Scenario {
    name: &'static str,
    n_models: usize,
    n_gpus: u32,
    duration: f64,
    /// Per-GPU memory. The churn scenarios shrink this far below the fleet's
    /// working set, so the run is dominated by KV alloc/free, preemption,
    /// and activation/eviction traffic — isolating the allocator hot path.
    gpu_bytes: u64,
    /// Restrict the fleet to sub-4B models (small KV blocks, cheap weights:
    /// maximum page-slot churn per byte of memory).
    small_models: bool,
    /// Fault spec resolved via `prism::fault::resolve` against this
    /// scenario's GPU count and duration (`None` = fault-free).
    faults: Option<&'static str>,
    /// Heterogeneous fleet spec (`prism::cluster::FleetSpec` grammar, e.g.
    /// `2xa100+4xl4`). When set it overrides `n_gpus` and `gpu_bytes` with
    /// the fleet's own size and per-kind memory; `None` = uniform H100
    /// cluster sized by `n_gpus`.
    fleet: Option<&'static str>,
    /// Intra-run shard count (`SimConfig::shards`): `1` = the historical
    /// sequential event loop, `N > 1` = GPU-group-sharded, `0` = auto.
    /// Overridden globally by the `--shards` flag.
    shards: u32,
    /// Timeline sample cadence (`SimConfig::sample_dt`); `0.0` keeps the
    /// config default (sampling off). Dense cadences make samples the
    /// dominant control event — the sharded loop's batch-internal pause
    /// fast path.
    sample_dt: f64,
    /// Control-epoch override (`SimConfig::control_epoch`); `0.0` keeps
    /// the config default. Short epochs over a stable placement are
    /// mostly no-ops — the cached-window-plan fast path.
    control_epoch: f64,
}

const GB: u64 = 1 << 30;

/// Single-GPU model fleet of size `n`: the Table-3 catalog tops out at 58
/// models, so larger fleets cycle it with fresh ids.
fn fleet(n: usize, small: bool) -> Vec<ModelSpec> {
    let base: Vec<ModelSpec> = catalog_subset(58)
        .into_iter()
        .filter(|m| !m.is_tp() && (!small || m.params < 4_000_000_000))
        .collect();
    (0..n)
        .map(|i| {
            let mut s = base[i % base.len()].clone();
            s.id = ModelId(i as u32);
            if i >= base.len() {
                s.name = format!("{}-r{}", s.name, i / base.len());
            }
            s
        })
        .collect()
}

type BaselineKey = (String, String, String); // (scenario, policy, mode)

fn load_baseline(path: &str) -> Option<BTreeMap<BaselineKey, f64>> {
    let j = json::parse_file(std::path::Path::new(path)).ok()?;
    let rows = j.get("rows").as_arr()?;
    let mut map = BTreeMap::new();
    for r in rows {
        // One malformed row must not discard the whole baseline (that would
        // silently disable the perf gate); skip it with a warning instead.
        let parsed = (|| {
            let key = (
                r.get("scenario").as_str()?.to_string(),
                r.get("policy").as_str()?.to_string(),
                r.get("mode").as_str()?.to_string(),
            );
            Some((key, r.get("events_per_sec").as_f64()?))
        })();
        match parsed {
            Some((key, eps)) => {
                map.insert(key, eps);
            }
            None => eprintln!("warning: skipping malformed baseline row in {path}"),
        }
    }
    if map.is_empty() { None } else { Some(map) }
}

fn main() {
    let args: Vec<String> = std::env::args().skip(1).collect();
    let smoke = args.iter().any(|a| a == "--smoke");
    let prepush = args.iter().any(|a| a == "--prepush");
    let sweep = args.iter().any(|a| a == "--sweep");
    // A present flag with no following value is an error, not a silent default.
    let opt = |flag: &str| -> Option<String> {
        args.iter().position(|a| a == flag).map(|i| {
            args.get(i + 1)
                .unwrap_or_else(|| panic!("{flag} requires a value"))
                .clone()
        })
    };
    let policy_filter = opt("--policy").unwrap_or_default();
    let scenario_filter = opt("--scenario").unwrap_or_default();
    let jobs = prism::sweep::parse_jobs_flag(&args);
    let shards_override: Option<u32> = opt("--shards")
        .map(|s| s.parse().expect("--shards expects a non-negative integer (0 = auto)"));
    let gate_pct: f64 = opt("--gate-pct")
        .map(|s| s.parse().expect("--gate-pct expects a number"))
        .unwrap_or(15.0);
    let baseline_path =
        opt("--baseline").or_else(|| std::env::var("PRISM_BENCH_BASELINE").ok());
    let baseline = baseline_path.as_ref().and_then(|p| load_baseline(p));
    if let (Some(p), None) = (&baseline_path, &baseline) {
        // An explicitly requested baseline that cannot be read must not
        // silently disable the perf gate and exit green.
        eprintln!("error: baseline {p} could not be read or parsed; refusing to run ungated");
        std::process::exit(2);
    }

    let scenarios: Vec<Scenario> = if smoke {
        vec![
            Scenario {
                name: "smoke-8m-4g-2min",
                n_models: 8,
                n_gpus: 4,
                duration: 120.0,
                gpu_bytes: 80 * GB,
                small_models: false,
                faults: None,
                fleet: None,
                shards: 1,
                sample_dt: 0.0,
                control_epoch: 0.0,
            },
            Scenario {
                name: "churn-12m-2g-2min",
                n_models: 12,
                n_gpus: 2,
                duration: 120.0,
                gpu_bytes: 8 * GB,
                small_models: true,
                faults: None,
                fleet: None,
                shards: 1,
                sample_dt: 0.0,
                control_epoch: 0.0,
            },
            // Churn squeeze + a seeded fault plan: crashes, slowdowns,
            // alloc faults, and load failures exercise the recovery paths
            // (re-routing, backoff retries, preempt-retry) under pressure.
            Scenario {
                name: "faulty-churn-12m-2g-2min",
                n_models: 12,
                n_gpus: 2,
                duration: 120.0,
                gpu_bytes: 8 * GB,
                small_models: true,
                faults: Some("churn:7"),
                fleet: None,
                shards: 1,
                sample_dt: 0.0,
                control_epoch: 0.0,
            },
            // Mixed-kind fleet churn: small models squeezed across two
            // A100s (40 GiB) and four L4s (24 GiB). Exercises the per-GPU
            // perf/memory indirection, kind-aware placement (melange), and
            // the CostLedger pricing on every step of the hot path.
            Scenario {
                name: "het-fleet-12m-6g-2min",
                n_models: 12,
                n_gpus: 6, // overridden by `fleet` (2 + 4 GPUs)
                duration: 120.0,
                gpu_bytes: 8 * GB, // overridden by `fleet` per-kind memory
                small_models: true,
                faults: None,
                fleet: Some("2xa100+4xl4"),
                shards: 1,
                sample_dt: 0.0,
                control_epoch: 0.0,
            },
            // Barrier-heavy smoke: dense samples + slowdown-only fault
            // windows + 2-second epochs on an uncontended fleet, so the
            // run is dominated by control events that the windowed sharded
            // loop turns into batch-internal pauses / cached-plan no-ops.
            Scenario {
                name: "barrier-heavy-12m-4g-2min",
                n_models: 12,
                n_gpus: 4,
                duration: 120.0,
                gpu_bytes: 80 * GB,
                small_models: false,
                faults: Some("slow@20-60:g0x2;slow@40-100:g2x1.5"),
                fleet: None,
                shards: 2,
                sample_dt: 0.25,
                control_epoch: 2.0,
            },
        ]
    } else {
        vec![
            Scenario {
                name: "novita-50m-16g-1h",
                n_models: 50,
                n_gpus: 16,
                duration: 3600.0,
                gpu_bytes: 80 * GB,
                small_models: false,
                faults: None,
                fleet: None,
                shards: 1,
                sample_dt: 0.0,
                control_epoch: 0.0,
            },
            Scenario {
                name: "novita-100m-32g-2h",
                n_models: 100,
                n_gpus: 32,
                duration: 7200.0,
                gpu_bytes: 80 * GB,
                small_models: false,
                faults: None,
                fleet: None,
                shards: 1,
                sample_dt: 0.0,
                control_epoch: 0.0,
            },
            // KV churn at scale: a small-model fleet squeezed onto GPUs with
            // a fraction of its working set, so the allocator (block
            // alloc/free, partial pages, preemption) dominates the profile.
            Scenario {
                name: "churn-48m-4g-1h",
                n_models: 48,
                n_gpus: 4,
                duration: 3600.0,
                gpu_bytes: 12 * GB,
                small_models: true,
                faults: None,
                fleet: None,
                shards: 1,
                sample_dt: 0.0,
                control_epoch: 0.0,
            },
            Scenario {
                name: "faulty-churn-48m-4g-1h",
                n_models: 48,
                n_gpus: 4,
                duration: 3600.0,
                gpu_bytes: 12 * GB,
                small_models: true,
                faults: Some("churn:7"),
                fleet: None,
                shards: 1,
                sample_dt: 0.0,
                control_epoch: 0.0,
            },
            // Full-scale heterogeneous fleet: mixed A100/L4 kinds under the
            // same hour-long small-model load as the churn scenarios.
            Scenario {
                name: "het-fleet-48m-12g-1h",
                n_models: 48,
                n_gpus: 12, // overridden by `fleet` (4 + 8 GPUs)
                duration: 3600.0,
                gpu_bytes: 12 * GB, // overridden by `fleet` per-kind memory
                small_models: true,
                faults: None,
                fleet: Some("4xa100+8xl4"),
                shards: 1,
                sample_dt: 0.0,
                control_epoch: 0.0,
            },
            // Intra-run parallelism A/B (see module docs): identical load
            // to novita-100m-32g-2h, sequential vs 4-shard event loop. The
            // pair shares a trace and fleet, so the events/sec ratio
            // giant-sharded : giant isolates the sharding win.
            Scenario {
                name: "giant-100m-32g-2h",
                n_models: 100,
                n_gpus: 32,
                duration: 7200.0,
                gpu_bytes: 80 * GB,
                small_models: false,
                faults: None,
                fleet: None,
                shards: 1,
                sample_dt: 0.0,
                control_epoch: 0.0,
            },
            Scenario {
                name: "giant-sharded-100m-32g-2h",
                n_models: 100,
                n_gpus: 32,
                duration: 7200.0,
                gpu_bytes: 80 * GB,
                small_models: false,
                faults: None,
                fleet: None,
                shards: 4,
                sample_dt: 0.0,
                control_epoch: 0.0,
            },
            // Barrier-heavy stress (see module docs): the giant sharded
            // load with a dense sample cadence, slowdown-only fault
            // windows, and 2-second control epochs (mostly no-ops). Before
            // window batching + plan caching, every one of these control
            // events was a full recompose barrier; this row isolates
            // exactly that win (acceptance: >= 1.5x the PR 7 sharded
            // events/sec on an 8-core runner).
            Scenario {
                name: "barrier-heavy-100m-32g-2h",
                n_models: 100,
                n_gpus: 32,
                duration: 7200.0,
                gpu_bytes: 80 * GB,
                small_models: false,
                faults: Some(
                    "slow@600-1800:g0x2;slow@2000-3200:g5x1.5;\
                     slow@3600-5400:g11x3;slow@5000-6600:g17x2.5",
                ),
                fleet: None,
                shards: 4,
                sample_dt: 1.0,
                control_epoch: 2.0,
            },
        ]
    };

    let mut table = Table::new(
        "sim hot path: simulated-events/sec",
        &["scenario", "policy", "mode", "requests", "events", "wall_s", "events/s", "vs_base"],
    );
    let mut rows: Vec<Json> = Vec::new();
    // Rows that regressed more than gate_pct vs the baseline: (key, speedup).
    let mut regressions: Vec<(BaselineKey, f64)> = Vec::new();
    // `gated = false` reports the speedup without enforcing the threshold
    // (the sweep row's aggregate events/sec scales with the machine's core
    // count, so it cannot gate across heterogeneous runners).
    let mut speedup_of = |key: &BaselineKey, eps: f64, gated: bool| -> Option<f64> {
        let s = baseline.as_ref().and_then(|b| b.get(key)).map(|&base| {
            if base > 0.0 { eps / base } else { f64::NAN }
        });
        if let Some(s) = s {
            if gated && s.is_finite() && s < 1.0 - gate_pct / 100.0 {
                regressions.push((key.clone(), s));
            }
        }
        s
    };

    for sc in &scenarios {
        if !scenario_filter.is_empty() && !sc.name.contains(&scenario_filter) {
            continue;
        }
        let trace = generate(&TraceGenConfig::novita_like(sc.n_models, sc.duration, 7));
        let specs = fleet(sc.n_models, sc.small_models);
        for policy in registry().names() {
            if !policy_filter.is_empty() && !policy.contains(&policy_filter) {
                continue;
            }
            let modes: &[bool] = if prepush { &[true, false] } else { &[true] };
            for &stream in modes {
                let mode = if stream { "streamed" } else { "prepush" };
                let mut cfg = SimConfig::new(policy, sc.n_gpus);
                cfg.slo_scale = 8.0;
                cfg.stream_arrivals = stream;
                cfg.gpu_bytes = sc.gpu_bytes;
                // Prepush mode predates streamed arrivals, which the sharded
                // loop requires; the simulator falls back to the sequential
                // loop there, so prepush rows time the historical path at
                // any shard count.
                cfg = cfg.shards(shards_override.unwrap_or(sc.shards));
                if sc.sample_dt > 0.0 {
                    cfg.sample_dt = sc.sample_dt;
                }
                if sc.control_epoch > 0.0 {
                    cfg.control_epoch = sc.control_epoch;
                }
                if let Some(fs) = sc.fleet {
                    cfg = cfg.fleet(FleetSpec::parse(fs).expect("scenario fleet spec"));
                }
                // Resolve faults against the post-fleet GPU count so fault
                // GPU indices stay valid on heterogeneous scenarios.
                if let Some(fs) = sc.faults {
                    cfg.faults = prism::fault::resolve(fs, cfg.n_gpus, sc.duration)
                        .expect("scenario fault spec");
                }
                // Smoke rows gate CI: take the best of 3 sub-second reps so
                // single-shot scheduler noise on shared runners does not trip
                // the threshold. Runs are deterministic, so metrics are
                // identical across reps - only wall time varies.
                let reps = if smoke { 3 } else { 1 };
                let mut wall = f64::INFINITY;
                let mut best: Option<RunMetrics> = None;
                for _ in 0..reps {
                    let t0 = Instant::now();
                    let (m, _) = Simulator::new(cfg.clone(), specs.clone()).run(&trace);
                    let w = t0.elapsed().as_secs_f64();
                    if w < wall {
                        wall = w;
                        best = Some(m);
                    }
                }
                let m = best.expect("at least one rep ran");
                let eps = m.sim_events as f64 / wall.max(1e-9);
                let key = (sc.name.to_string(), policy.to_string(), mode.to_string());
                let speedup = speedup_of(&key, eps, true);
                table.row(vec![
                    sc.name.into(),
                    policy.into(),
                    mode.into(),
                    trace.events.len().to_string(),
                    m.sim_events.to_string(),
                    format!("{wall:.2}"),
                    format!("{eps:.0}"),
                    speedup.map(|s| format!("{s:.2}x")).unwrap_or_else(|| "-".into()),
                ]);
                let mut row = Json::obj();
                row.set("scenario", Json::Str(sc.name.to_string()));
                row.set("policy", Json::Str(policy.to_string()));
                row.set("mode", Json::Str(mode.to_string()));
                row.set("requests", Json::from_f64(trace.events.len() as f64));
                row.set("completions", Json::from_f64(m.total() as f64));
                row.set("events", Json::from_f64(m.sim_events as f64));
                row.set("wall_s", Json::from_f64(wall));
                row.set("events_per_sec", Json::from_f64(eps));
                row.set("ttft_attainment", Json::from_f64(m.ttft_attainment()));
                if let Some(s) = speedup {
                    row.set("speedup_vs_baseline", Json::from_f64(s));
                }
                rows.push(row);
            }
        }

        // Parallel sweep scenario: the policy x SLO grid through the sweep
        // engine, reported as aggregate simulated-events/sec (this is the
        // number the worker pool is supposed to scale with cores). Honors
        // --policy like the per-policy rows. Churn scenarios are excluded:
        // SweepPoint runs with default GPU memory, so they would not churn.
        if sweep && !sc.small_models {
            let sweep_policies: Vec<&'static str> = registry()
                .names()
                .into_iter()
                .filter(|p| policy_filter.is_empty() || p.contains(&policy_filter))
                .collect();
            if sweep_policies.is_empty() {
                eprintln!("--sweep: no policies match --policy {policy_filter}; skipping");
                continue;
            }
            let grid = SweepGrid::new()
                .policies(&sweep_policies)
                .gpus(&[sc.n_gpus])
                .slo_scales(&[4.0, 8.0]);
            let points = grid.points();
            // Report the worker count run_points actually uses (it clamps
            // to the point count), not the raw resolved parallelism.
            let n_jobs = resolve_jobs(jobs).min(points.len());
            let t0 = Instant::now();
            let results = run_points(&points, jobs, |_, pt| pt.run(&specs, &trace));
            let wall = t0.elapsed().as_secs_f64();
            let events: u64 = results.iter().map(|m| m.sim_events).sum();
            let requests: usize = results.iter().map(|m| m.total()).sum();
            let eps = events as f64 / wall.max(1e-9);
            let key = (format!("sweep-{}", sc.name), "grid".to_string(), "sweep".to_string());
            let speedup = speedup_of(&key, eps, false);
            table.row(vec![
                key.0.clone(),
                format!("grid[{}]x{n_jobs}j", points.len()),
                "sweep".into(),
                requests.to_string(),
                events.to_string(),
                format!("{wall:.2}"),
                format!("{eps:.0}"),
                speedup.map(|s| format!("{s:.2}x")).unwrap_or_else(|| "-".into()),
            ]);
            let mut row = Json::obj();
            row.set("scenario", Json::Str(key.0.clone()));
            row.set("policy", Json::Str(key.1.clone()));
            row.set("mode", Json::Str(key.2.clone()));
            row.set("points", Json::from_f64(points.len() as f64));
            row.set("jobs", Json::from_f64(n_jobs as f64));
            row.set("requests", Json::from_f64(requests as f64));
            row.set("events", Json::from_f64(events as f64));
            row.set("wall_s", Json::from_f64(wall));
            row.set("events_per_sec", Json::from_f64(eps));
            if let Some(s) = speedup {
                row.set("speedup_vs_baseline", Json::from_f64(s));
            }
            rows.push(row);
        }
    }
    table.print();

    let mut out = Json::obj();
    out.set("bench", Json::Str("sim_hot_path".to_string()));
    out.set("smoke", Json::Bool(smoke));
    out.set("rows", Json::Arr(rows));
    std::fs::write("BENCH_sim.json", out.to_string_pretty()).expect("write BENCH_sim.json");
    println!("wrote BENCH_sim.json");

    // CI perf gate: fail the process (after writing BENCH_sim.json so the
    // artifact still uploads) when any row regressed beyond the threshold.
    if !regressions.is_empty() {
        eprintln!(
            "PERF REGRESSION: {} row(s) slower than baseline by >{gate_pct}%:",
            regressions.len()
        );
        for ((sc, pol, mode), s) in &regressions {
            eprintln!("  {sc}/{pol}/{mode}: {s:.2}x of baseline");
        }
        std::process::exit(1);
    }
}
