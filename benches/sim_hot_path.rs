//! Simulator hot-path macro-benchmark: simulated-events/sec at cluster
//! scale (50-100 models, 16-32 GPUs, hour-plus novita-like traces, every
//! policy), written to `BENCH_sim.json` so the perf trajectory is tracked
//! across changes.
//!
//! Flags:
//!   --smoke              tiny CI configuration (seconds, not minutes)
//!   --prepush            ALSO time the legacy pre-pushed-arrival heap
//!                        (`SimConfig::stream_arrivals = false`) for an
//!                        in-binary A/B of the streamed event loop
//!   --baseline <file>    report speedup vs a previously recorded
//!                        BENCH_sim.json (env PRISM_BENCH_BASELINE works
//!                        too); run the bench on the pre-change commit to
//!                        produce one
//!   --policy <name>      only run policies whose name contains <name>

use std::collections::BTreeMap;
use std::time::Instant;

use prism::bench::harness::Table;
use prism::model::spec::{catalog_subset, ModelId, ModelSpec};
use prism::sim::{PolicyKind, SimConfig, Simulator};
use prism::trace::gen::{generate, TraceGenConfig};
use prism::util::json::{self, Json};

struct Scenario {
    name: &'static str,
    n_models: usize,
    n_gpus: u32,
    duration: f64,
}

/// Single-GPU model fleet of size `n`: the Table-3 catalog tops out at 58
/// models, so larger fleets cycle it with fresh ids.
fn fleet(n: usize) -> Vec<ModelSpec> {
    let base: Vec<ModelSpec> =
        catalog_subset(58).into_iter().filter(|m| !m.is_tp()).collect();
    (0..n)
        .map(|i| {
            let mut s = base[i % base.len()].clone();
            s.id = ModelId(i as u32);
            if i >= base.len() {
                s.name = format!("{}-r{}", s.name, i / base.len());
            }
            s
        })
        .collect()
}

type BaselineKey = (String, String, String); // (scenario, policy, mode)

fn load_baseline(path: &str) -> Option<BTreeMap<BaselineKey, f64>> {
    let j = json::parse_file(std::path::Path::new(path)).ok()?;
    let rows = j.get("rows").as_arr()?;
    let mut map = BTreeMap::new();
    for r in rows {
        let key = (
            r.get("scenario").as_str()?.to_string(),
            r.get("policy").as_str()?.to_string(),
            r.get("mode").as_str()?.to_string(),
        );
        map.insert(key, r.get("events_per_sec").as_f64()?);
    }
    Some(map)
}

fn main() {
    let args: Vec<String> = std::env::args().skip(1).collect();
    let smoke = args.iter().any(|a| a == "--smoke");
    let prepush = args.iter().any(|a| a == "--prepush");
    let opt = |flag: &str| {
        args.iter().position(|a| a == flag).and_then(|i| args.get(i + 1).cloned())
    };
    let policy_filter = opt("--policy").unwrap_or_default();
    let baseline = opt("--baseline")
        .or_else(|| std::env::var("PRISM_BENCH_BASELINE").ok())
        .and_then(|p| {
            let b = load_baseline(&p);
            if b.is_none() {
                eprintln!("warning: could not read baseline {p}");
            }
            b
        });

    let scenarios: Vec<Scenario> = if smoke {
        vec![Scenario { name: "smoke-8m-4g-2min", n_models: 8, n_gpus: 4, duration: 120.0 }]
    } else {
        vec![
            Scenario { name: "novita-50m-16g-1h", n_models: 50, n_gpus: 16, duration: 3600.0 },
            Scenario { name: "novita-100m-32g-2h", n_models: 100, n_gpus: 32, duration: 7200.0 },
        ]
    };

    let mut table = Table::new(
        "sim hot path: simulated-events/sec",
        &["scenario", "policy", "mode", "requests", "events", "wall_s", "events/s", "vs_base"],
    );
    let mut rows: Vec<Json> = Vec::new();
    for sc in &scenarios {
        let trace = generate(&TraceGenConfig::novita_like(sc.n_models, sc.duration, 7));
        let specs = fleet(sc.n_models);
        for policy in PolicyKind::all() {
            if !policy_filter.is_empty() && !policy.name().contains(&policy_filter) {
                continue;
            }
            let modes: &[bool] = if prepush { &[true, false] } else { &[true] };
            for &stream in modes {
                let mode = if stream { "streamed" } else { "prepush" };
                let mut cfg = SimConfig::new(policy, sc.n_gpus);
                cfg.slo_scale = 8.0;
                cfg.stream_arrivals = stream;
                let t0 = Instant::now();
                let (m, _) = Simulator::new(cfg, specs.clone()).run(&trace);
                let wall = t0.elapsed().as_secs_f64();
                let eps = m.sim_events as f64 / wall.max(1e-9);
                let key =
                    (sc.name.to_string(), policy.name().to_string(), mode.to_string());
                let speedup = baseline.as_ref().and_then(|b| b.get(&key)).map(|&base| {
                    if base > 0.0 { eps / base } else { f64::NAN }
                });
                table.row(vec![
                    sc.name.into(),
                    policy.name().into(),
                    mode.into(),
                    trace.events.len().to_string(),
                    m.sim_events.to_string(),
                    format!("{wall:.2}"),
                    format!("{eps:.0}"),
                    speedup.map(|s| format!("{s:.2}x")).unwrap_or_else(|| "-".into()),
                ]);
                let mut row = Json::obj();
                row.set("scenario", Json::Str(sc.name.to_string()));
                row.set("policy", Json::Str(policy.name().to_string()));
                row.set("mode", Json::Str(mode.to_string()));
                row.set("requests", Json::from_f64(trace.events.len() as f64));
                row.set("completions", Json::from_f64(m.completions.len() as f64));
                row.set("events", Json::from_f64(m.sim_events as f64));
                row.set("wall_s", Json::from_f64(wall));
                row.set("events_per_sec", Json::from_f64(eps));
                row.set("ttft_attainment", Json::from_f64(m.ttft_attainment()));
                if let Some(s) = speedup {
                    row.set("speedup_vs_baseline", Json::from_f64(s));
                }
                rows.push(row);
            }
        }
    }
    table.print();

    let mut out = Json::obj();
    out.set("bench", Json::Str("sim_hot_path".to_string()));
    out.set("smoke", Json::Bool(smoke));
    out.set("rows", Json::Arr(rows));
    std::fs::write("BENCH_sim.json", out.to_string_pretty()).expect("write BENCH_sim.json");
    println!("wrote BENCH_sim.json");
}
