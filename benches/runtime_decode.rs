//! Real-path benchmark: PJRT prefill/decode step latency for the PrismNano
//! artifacts, plus the L3 bookkeeping overhead share (router + kvcached vs
//! raw PJRT execute) - the Fig 14 analog for the real stack.
// Printing is the point of this target (see Cargo.toml lints.clippy).
#![allow(clippy::print_stdout, clippy::print_stderr)]

use prism::bench::harness::{black_box, run};
use prism::runtime::exec::ModelRuntime;
use prism::serve::{RealServer, ServeRequest, ServerConfig};

fn main() {
    let root = std::path::PathBuf::from(env!("CARGO_MANIFEST_DIR")).join("artifacts");
    let nano = root.join("prism-nano");
    if !nano.join("manifest.json").is_file() {
        eprintln!("artifacts missing - run `make artifacts` first; skipping");
        return;
    }
    let client = xla::PjRtClient::cpu().expect("pjrt cpu client");
    let rt = ModelRuntime::load(&client, &nano).expect("load artifacts");
    println!(
        "weights uploaded in {:.1} ms",
        rt.weight_upload_seconds * 1e3
    );

    let m = &rt.manifest;
    let prompt: Vec<i32> = (0..16).map(|i| (i * 7 % 255) as i32).collect();
    run("runtime/prefill_16tok", 3, 30, |_| black_box(rt.prefill(&prompt).unwrap()));

    // Decode at each batch bucket.
    let pool = vec![0f32; m.pool_pages * m.slot_elems()];
    for &b in &[1usize, 4, 8] {
        let toks = vec![1i32; b];
        let pos = vec![8i32; b];
        let mut bt = vec![0i32; b * m.max_pages];
        for (j, v) in bt.iter_mut().enumerate().take(b * m.max_pages) {
            if j % m.max_pages == 0 {
                *v = 1;
            }
        }
        let lens = vec![8i32; b];
        run(&format!("runtime/decode_b{b}"), 3, 30, |_| {
            black_box(rt.decode(&toks, &pos, &pool, &bt, &lens).unwrap())
        });
    }

    // End-to-end served tokens/s through the full coordinator.
    let mut srv = RealServer::new(ServerConfig::default(), &[nano.as_path()], &[]).unwrap();
    let reqs: Vec<ServeRequest> = (0..8)
        .map(|i| ServeRequest {
            model: "prism-nano".into(),
            prompt: (0..16).map(|t| ((t + i) % 255) as i32).collect(),
            max_new_tokens: 8,
            arrival: 0.0,
            ttft_slo: None,
        })
        .collect();
    let t0 = std::time::Instant::now();
    let results = srv.serve(&reqs).unwrap();
    let wall = t0.elapsed().as_secs_f64();
    let tokens: usize = results.iter().flatten().map(|r| r.generated.len()).sum();
    println!(
        "serve/e2e_8reqs_8newtok: {tokens} tokens in {wall:.2}s -> {:.1} tok/s",
        tokens as f64 / wall
    );
}
