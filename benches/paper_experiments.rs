//! `cargo bench` target regenerating every paper table and figure at quick
//! scale (full scale via `prism exp <id>`), plus wall-clock timing per
//! experiment. Custom harness: criterion is not in the offline vendor set.
//!
//! Flags:
//!   <substr>    only run experiment ids containing <substr>
//!   --jobs N    sweep worker count (default: auto; 1 = sequential)
//!   --shards N  intra-run event-loop shard count applied to every
//!               experiment config (default: 1 = sequential; 0 = auto)
// Printing is the point of this target (see Cargo.toml lints.clippy).
#![allow(clippy::print_stdout, clippy::print_stderr)]

use std::time::Instant;

fn main() {
    let args: Vec<String> = std::env::args().skip(1).collect();
    let jobs = prism::sweep::parse_jobs_flag(&args);
    let shards: u32 = args
        .iter()
        .position(|a| a == "--shards")
        .map(|i| args.get(i + 1).expect("--shards requires a value").clone())
        .or_else(|| args.iter().find_map(|a| a.strip_prefix("--shards=").map(str::to_string)))
        .map(|v| v.parse().expect("--shards expects a non-negative integer (0 = auto)"))
        .unwrap_or(1);
    // Experiments construct their SimConfigs internally; the shard knob
    // travels as the process-wide construction default (set once, up front).
    prism::sim::SimConfig::set_default_shards(shards);
    let filter = args
        .iter()
        .enumerate()
        .filter(|(i, a)| {
            !a.starts_with('-')
                && !(*i > 0 && (args[i - 1] == "--jobs" || args[i - 1] == "--shards"))
        })
        .map(|(_, a)| a.clone())
        .next()
        .unwrap_or_default();
    println!(
        "== paper experiment bench (quick scale, {} sweep workers) ==",
        prism::sweep::resolve_jobs(jobs)
    );
    let mut total = 0.0;
    for id in prism::experiments::ids() {
        if !filter.is_empty() && !id.contains(&filter) {
            continue;
        }
        let t0 = Instant::now();
        match prism::experiments::run_jobs(id, true, jobs) {
            Ok(tables) => {
                let dt = t0.elapsed().as_secs_f64();
                total += dt;
                println!("{id:<10} {dt:>8.2}s  ({} tables)", tables.len());
            }
            Err(e) => println!("{id:<10} FAILED: {e}"),
        }
    }
    println!("total: {total:.1}s");
}
