//! `cargo bench` target regenerating every paper table and figure at quick
//! scale (full scale via `prism exp <id>`), plus wall-clock timing per
//! experiment. Custom harness: criterion is not in the offline vendor set.

use std::time::Instant;

fn main() {
    let filter = std::env::args()
        .skip(1)
        .find(|a| !a.starts_with('-'))
        .unwrap_or_default();
    println!("== paper experiment bench (quick scale) ==");
    let mut total = 0.0;
    for id in prism::experiments::ids() {
        if !filter.is_empty() && !id.contains(&filter) {
            continue;
        }
        let t0 = Instant::now();
        match prism::experiments::run(id, true) {
            Ok(tables) => {
                let dt = t0.elapsed().as_secs_f64();
                total += dt;
                println!("{id:<10} {dt:>8.2}s  ({} tables)", tables.len());
            }
            Err(e) => println!("{id:<10} FAILED: {e}"),
        }
    }
    println!("total: {total:.1}s");
}
