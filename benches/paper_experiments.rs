//! `cargo bench` target regenerating every paper table and figure at quick
//! scale (full scale via `prism exp <id>`), plus wall-clock timing per
//! experiment. Custom harness: criterion is not in the offline vendor set.
//!
//! Flags:
//!   <substr>    only run experiment ids containing <substr>
//!   --jobs N    sweep worker count (default: auto; 1 = sequential)

use std::time::Instant;

fn main() {
    let args: Vec<String> = std::env::args().skip(1).collect();
    let jobs = prism::sweep::parse_jobs_flag(&args);
    let filter = args
        .iter()
        .enumerate()
        .filter(|(i, a)| {
            !a.starts_with('-') && !(*i > 0 && args[i - 1] == "--jobs")
        })
        .map(|(_, a)| a.clone())
        .next()
        .unwrap_or_default();
    println!(
        "== paper experiment bench (quick scale, {} sweep workers) ==",
        prism::sweep::resolve_jobs(jobs)
    );
    let mut total = 0.0;
    for id in prism::experiments::ids() {
        if !filter.is_empty() && !id.contains(&filter) {
            continue;
        }
        let t0 = Instant::now();
        match prism::experiments::run_jobs(id, true, jobs) {
            Ok(tables) => {
                let dt = t0.elapsed().as_secs_f64();
                total += dt;
                println!("{id:<10} {dt:>8.2}s  ({} tables)", tables.len());
            }
            Err(e) => println!("{id:<10} FAILED: {e}"),
        }
    }
    println!("total: {total:.1}s");
}
