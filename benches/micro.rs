//! Microbenchmarks over the hot paths (custom harness; see DESIGN.md SSPerf):
//! kvcached page/block operations, Moore-Hodgson arbitration, Algorithm 1
//! placement, trace generation, and simulator event throughput.
// Printing is the point of this target (see Cargo.toml lints.clippy).
#![allow(clippy::print_stdout, clippy::print_stderr)]

use prism::bench::harness::{black_box, run};
use prism::kvcached::Kvcached;
use prism::model::spec::{table3_catalog, ModelId};
use prism::sched::arbitration::{moore_hodgson, Candidate};
use prism::sched::kvpr::ModelDemand;
use prism::sched::placement::{place, PlacementInput};
use prism::request::RequestId;
use prism::sim::{SimConfig, Simulator};
use prism::trace::gen::{generate, TraceGenConfig};
use prism::util::rng::Rng;

fn bench_kvcached() {
    let mb = 1024 * 1024;
    // Sustained alloc/free churn with partial-page reuse.
    run("kvcached/alloc_free_1k_blocks", 3, 30, |_| {
        let mut kvc = Kvcached::new(1024 * mb, 2 * mb, 16);
        kvc.register_kv(ModelId(0), 512 * 1024, u32::MAX);
        let mut live = Vec::with_capacity(1000);
        for _ in 0..1000 {
            live.push(kvc.alloc_block(ModelId(0)).unwrap());
        }
        for b in live {
            kvc.free_block(b).unwrap();
        }
        black_box(kvc.stats())
    });

    // Batched allocation: one model lookup + caller-owned buffer for the
    // whole batch (the engine's per-iteration demand path).
    run("kvcached/alloc_blocks_batched_1k", 3, 30, |_| {
        let mut kvc = Kvcached::new(1024 * mb, 2 * mb, 16);
        kvc.register_kv(ModelId(0), 512 * 1024, u32::MAX);
        let mut live = Vec::with_capacity(1000);
        kvc.alloc_blocks(ModelId(0), 1000, &mut live).unwrap();
        for b in live {
            kvc.free_block(b).unwrap();
        }
        black_box(kvc.stats())
    });

    // KV churn: the high-preemption, small-block pattern — random interleaved
    // alloc/free with heavy partial-page traffic and a breathing balloon
    // limit. Isolates the slot bitmap + O(1) partial tracking.
    run("kvcached/churn_small_blocks", 3, 20, |_| {
        let mut kvc = Kvcached::new(64 * mb, 2 * mb, 8);
        kvc.register_kv(ModelId(0), 128 * 1024, u32::MAX); // 16 slots/page
        let mut rng = Rng::new(7);
        let mut live: Vec<_> = Vec::new();
        for i in 0..4000 {
            if live.is_empty() || rng.below(3) > 0 {
                if let Ok(b) = kvc.alloc_block(ModelId(0)) {
                    live.push(b);
                }
            } else {
                let j = rng.below(live.len());
                let b = live.swap_remove(j);
                kvc.free_block(b).unwrap();
            }
            if i % 512 == 0 {
                // Balloon breathing forces empty-page unmaps (the partial
                // swap-remove path) and remaps.
                let limit = if i % 1024 == 0 { 8 } else { u32::MAX };
                let _ = kvc.set_kv_limit(ModelId(0), limit);
            }
        }
        for b in live {
            kvc.free_block(b).unwrap();
        }
        black_box(kvc.stats())
    });

    run("kvcached/balloon_shrink_grow", 3, 100, |_| {
        let mut kvc = Kvcached::new(256 * mb, 2 * mb, 8);
        kvc.register_kv(ModelId(0), mb, u32::MAX);
        for _ in 0..128 {
            let _ = kvc.alloc_block(ModelId(0));
        }
        black_box(kvc.set_kv_limit(ModelId(0), 16).unwrap());
        black_box(kvc.set_kv_limit(ModelId(0), u32::MAX).unwrap())
    });

    run("kvcached/weights_load_unload", 3, 200, |i| {
        let mut kvc = Kvcached::new(256 * mb, 2 * mb, 8);
        kvc.load_weights(ModelId(0), (64 + i as u64 % 32) * mb).unwrap();
        black_box(kvc.unload_weights(ModelId(0)))
    });
}

fn bench_arbitration() {
    let mut rng = Rng::new(1);
    for n in [100usize, 1000] {
        let cands: Vec<Candidate> = (0..n)
            .map(|i| Candidate {
                id: RequestId(i as u64),
                arrival: 0.0,
                deadline: rng.range_f64(0.1, 10.0),
                exec: rng.range_f64(0.01, 1.0),
            })
            .collect();
        run(&format!("arbitration/moore_hodgson_{n}"), 3, 100, |_| {
            black_box(moore_hodgson(0.0, &cands))
        });
    }
}

fn bench_placement() {
    let cat = table3_catalog();
    let inputs: Vec<PlacementInput> = cat
        .iter()
        .map(|m| PlacementInput {
            demand: ModelDemand {
                model: m.id,
                token_rate: 100.0,
                token_size: m.kv_bytes_per_token() as f64,
                slo: 0.03,
                weight_bytes_per_gpu: m.weight_bytes_per_gpu(),
                tp: m.tp,
            },
            current: vec![],
        })
        .collect();
    let caps = vec![80e9; 32];
    run("placement/alg1_58_models_32_gpus", 3, 200, |_| {
        black_box(place(&inputs, &caps, 0.2))
    });
}

fn bench_trace_and_sim() {
    run("trace/generate_novita_1h_16models", 1, 10, |i| {
        black_box(generate(&TraceGenConfig::novita_like(16, 3600.0, i as u64)).events.len())
    });

    let trace = generate(&TraceGenConfig::novita_like(8, 300.0, 3)).scale_rate(2.0);
    let specs = prism::experiments::e2e::assign_ids(
        table3_catalog()
            .into_iter()
            .filter(|m| m.name.contains("8b") || m.name.contains("7b"))
            .take(8)
            .collect(),
    );
    let n_events = trace.events.len();
    run(
        &format!("sim/prism_8models_2gpus_5min_{n_events}reqs"),
        1,
        8,
        |_| {
            let cfg = SimConfig::for_policy("prism").gpus(2);
            let (m, _) = Simulator::new(cfg, specs.clone()).run(&trace);
            black_box(m.total())
        },
    );
}

fn main() {
    let filter = std::env::args()
        .skip(1)
        .find(|a| !a.starts_with('-'))
        .unwrap_or_default();
    println!("== prism microbenches ==");
    if filter.is_empty() || "kvcached".contains(&filter) {
        bench_kvcached();
    }
    if filter.is_empty() || "arbitration".contains(&filter) {
        bench_arbitration();
    }
    if filter.is_empty() || "placement".contains(&filter) {
        bench_placement();
    }
    if filter.is_empty() || "trace_sim".contains(&filter) {
        bench_trace_and_sim();
    }
}
