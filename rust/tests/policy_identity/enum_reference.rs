//! Byte-for-byte reference copy of the PRE-REFACTOR enum-dispatch simulator
//! (`sim/simulator.rs` + `sim/policy.rs` as of commit 59e1467), with
//! `PolicyKind` matches hardwired exactly as they were. The A/B test in
//! `main.rs` replays identical traces through this reference and through the
//! trait-dispatch simulator and asserts bitwise-identical metrics, locking
//! the policy-API refactor to the historical behavior.
//!
//! Do not "improve" this module: its value is that it does NOT evolve with
//! the library. It only consumes public crate APIs (cluster, engines,
//! kvcached, sched, trace, metrics), so it stays compilable without keeping
//! any legacy code in the library itself.
#![allow(dead_code)]

/// The pre-refactor policy enum, verbatim.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum PolicyKind {
    Prism,
    StaticPartition,
    MuxServePlusPlus,
    Qlm,
    ServerlessLlm,
}

impl PolicyKind {
    pub fn name(self) -> &'static str {
        match self {
            PolicyKind::Prism => "prism",
            PolicyKind::StaticPartition => "s-partition",
            PolicyKind::MuxServePlusPlus => "muxserve++",
            PolicyKind::Qlm => "qlm",
            PolicyKind::ServerlessLlm => "serverlessllm",
        }
    }

    pub fn all() -> [PolicyKind; 5] {
        [
            PolicyKind::Prism,
            PolicyKind::StaticPartition,
            PolicyKind::MuxServePlusPlus,
            PolicyKind::Qlm,
            PolicyKind::ServerlessLlm,
        ]
    }

    pub fn static_residency(self) -> bool {
        matches!(self, PolicyKind::StaticPartition | PolicyKind::MuxServePlusPlus)
    }

    pub fn slack_aware(self) -> bool {
        matches!(self, PolicyKind::Prism)
    }
}

use std::cmp::Reverse;
use std::collections::{BTreeMap, BTreeSet, BinaryHeap, HashMap};

use prism::cluster::gpu::GroupAlloc;
use prism::cluster::{Cluster, GpuId};
use prism::engine::loading::LoadStrategy;
use prism::engine::perf::GpuPerf;
use prism::kvcached::KvError;
use prism::metrics::{RunMetrics, TimelineSample};
use prism::model::spec::{ModelId, ModelSpec};
use prism::request::{Phase, Request};
use prism::sched::arbitration::{moore_hodgson, Candidate};
use prism::sched::kvpr::{kvpr, ModelDemand, RateMonitor};
use prism::sched::placement::{place, EvictionPolicy, PlacementInput};
use prism::trace::{ScaledEvents, Trace, TraceEvent};

#[derive(Debug, Clone)]
pub struct SimConfig {
    pub policy: PolicyKind,
    pub n_gpus: u32,
    pub gpu_bytes: u64,
    pub gpus_per_node: u32,
    pub perf: GpuPerf,
    /// Placement/eviction control epoch (s).
    pub control_epoch: f64,
    /// KVPR monitoring window (s) - Fig 15b.
    pub monitor_window: f64,
    /// Migration threshold tau on KVPR improvement.
    pub tau: f64,
    pub eviction: EvictionPolicy,
    /// SLO scale factor applied to the per-model base SLOs.
    pub slo_scale: f64,
    /// Timeline sampling interval (s); 0 disables sampling.
    pub sample_dt: f64,
    /// Disable Prism idle eviction. Resolved once from `PRISM_NO_EVICT` at
    /// construction (the experiments CLI override) instead of re-reading the
    /// environment every control epoch.
    pub no_evict: bool,
    /// Disable Prism migration (env `PRISM_NO_MIGRATE`, resolved once).
    pub no_migrate: bool,
    /// Slack-aware (Moore-Hodgson) admission: the policy classification
    /// combined with the `PRISM_NO_MH` env override, resolved once.
    pub slack_aware: bool,
    /// Stream arrivals from a cursor over the time-sorted trace (default).
    /// `false` pre-pushes every arrival into the event heap - the legacy
    /// formulation, kept for A/B regression tests and heap-size benchmarks.
    pub stream_arrivals: bool,
    /// Retain every raw `Completion` (plus exact percentile views) in the
    /// run's `RunMetrics`. Off by default: the streaming sink keeps only
    /// counters and quantile sketches, so cluster-scale sweep points stop
    /// holding every completion in memory. Opt in for tests/figures that
    /// need exact percentiles or per-request records.
    pub metrics_full_dump: bool,
}

impl SimConfig {
    pub fn new(policy: PolicyKind, n_gpus: u32) -> Self {
        SimConfig {
            policy,
            n_gpus,
            gpu_bytes: 80 * (1 << 30),
            gpus_per_node: 8,
            perf: GpuPerf::default(),
            control_epoch: 5.0,
            monitor_window: 60.0,
            tau: 0.2,
            eviction: EvictionPolicy::default(),
            slo_scale: 5.0,
            sample_dt: 0.0,
            no_evict: std::env::var("PRISM_NO_EVICT").is_ok(),
            no_migrate: std::env::var("PRISM_NO_MIGRATE").is_ok(),
            slack_aware: policy.slack_aware() && std::env::var("PRISM_NO_MH").is_err(),
            stream_arrivals: true,
            metrics_full_dump: false,
        }
    }
}

/// Per-model base SLOs from dedicated-GPU latency (paper SS7.1: P95 TTFT
/// 0.04-0.13 s, P95 TPOT 5.2-50.9 ms measured on dedicated GPUs).
pub fn base_slos(perf: &GpuPerf, spec: &ModelSpec) -> (f64, f64) {
    // Dedicated prefill of a typical ~500-token prompt + one iteration overhead.
    let ttft = 0.02 + 500.0 / perf.prefill_tokens_per_sec(spec) + perf.iter_overhead;
    // Dedicated decode at moderate batch with a couple GB of KV.
    let tpot = perf.decode_tpot(spec, 8, 2 << 30);
    (ttft, tpot)
}

#[derive(Debug, Clone, Copy, PartialEq)]
struct Time(f64);

impl Eq for Time {}

impl PartialOrd for Time {
    fn partial_cmp(&self, other: &Self) -> Option<std::cmp::Ordering> {
        Some(self.cmp(other))
    }
}

impl Ord for Time {
    fn cmp(&self, other: &Self) -> std::cmp::Ordering {
        self.0.partial_cmp(&other.0).expect("no NaN times")
    }
}

#[derive(Debug, Clone, PartialEq, Eq)]
enum Ev {
    Arrival(usize),
    Step(ModelId),
    Epoch,
    Sample,
}

pub struct Simulator {
    pub cfg: SimConfig,
    pub specs: Vec<ModelSpec>,
    /// ModelId -> index into `specs`: O(1) hot-path lookups.
    model_index: HashMap<ModelId, usize>,
    slos: Vec<(f64, f64)>,
    cluster: Cluster,
    /// Per-GPU shared admission queues (lead GPU for TP groups).
    gpu_queues: Vec<Vec<Request>>,
    /// Requests waiting for model activation (policy-dependent).
    pending: Vec<Request>,
    monitors: Vec<RateMonitor>,
    last_request_at: Vec<f64>,
    /// Per-model w_token_rate snapshot valid at `demand_cache_at`: one
    /// O(models) refresh per distinct event time instead of recomputing
    /// (and formerly cloning a monitor) per GPU x per model.
    demand_rates: Vec<f64>,
    demand_cache_at: f64,
    metrics: RunMetrics,
    pub timeline: Vec<TimelineSample>,
    heap: BinaryHeap<Reverse<(Time, u64, u8, usize)>>, // (time, seq, kind, payload)
    step_scheduled: BTreeSet<ModelId>,
    seq: u64,
    next_req_id: u64,
    cum_violations: usize,
    tokens_since_sample: u64,
}

impl Simulator {
    pub fn new(cfg: SimConfig, specs: Vec<ModelSpec>) -> Self {
        let cluster = Cluster::new(cfg.n_gpus, cfg.gpu_bytes, cfg.gpus_per_node, cfg.perf.clone());
        let slos = specs
            .iter()
            .map(|s| {
                let (t, p) = base_slos(&cfg.perf, s);
                (t * cfg.slo_scale, p * cfg.slo_scale)
            })
            .collect();
        let monitors = specs.iter().map(|_| RateMonitor::new(cfg.monitor_window)).collect();
        let n = specs.len();
        let model_index: HashMap<ModelId, usize> =
            specs.iter().enumerate().map(|(i, s)| (s.id, i)).collect();
        assert_eq!(model_index.len(), n, "duplicate model ids in specs");
        Simulator {
            model_index,
            gpu_queues: (0..cfg.n_gpus).map(|_| Vec::new()).collect(),
            pending: Vec::new(),
            monitors,
            last_request_at: vec![f64::NEG_INFINITY; n],
            demand_rates: vec![0.0; n],
            demand_cache_at: f64::NEG_INFINITY,
            metrics: RunMetrics::with_full_dump(cfg.metrics_full_dump),
            timeline: Vec::new(),
            heap: BinaryHeap::new(),
            step_scheduled: BTreeSet::new(),
            seq: 0,
            next_req_id: 0,
            cum_violations: 0,
            tokens_since_sample: 0,
            cluster,
            slos,
            specs,
            cfg,
        }
    }

    pub fn slo_of(&self, model_idx: usize) -> (f64, f64) {
        self.slos[model_idx]
    }

    /// Override per-model (TTFT, TPOT) SLOs (Fig 8 sweeps them per model).
    pub fn set_slos(&mut self, slos: Vec<(f64, f64)>) {
        assert_eq!(slos.len(), self.specs.len());
        self.slos = slos;
        self.demand_cache_at = f64::NEG_INFINITY; // w_token_rate depends on SLOs
    }

    fn idx_of(&self, m: ModelId) -> usize {
        self.model_index[&m]
    }

    /// Recompute the per-model w_token_rate snapshot unless one is already
    /// valid for `now`. Callers that record new tokens reset
    /// `demand_cache_at`, so a hit is always exact.
    fn refresh_demand(&mut self, now: f64) {
        if self.demand_cache_at == now {
            return;
        }
        for i in 0..self.specs.len() {
            let spec = &self.specs[i];
            let token_size = spec.kv_bytes_per_token() as f64 * spec.tp as f64;
            self.demand_rates[i] =
                self.monitors[i].rate_at(now) * token_size / self.slos[i].1.max(1e-6);
        }
        self.demand_cache_at = now;
    }

    fn push_ev(&mut self, t: f64, ev: Ev) {
        let (kind, payload) = match ev {
            Ev::Arrival(i) => (0u8, i),
            Ev::Step(m) => (1, m.0 as usize),
            Ev::Epoch => (2, 0),
            Ev::Sample => (3, 0),
        };
        self.seq += 1;
        self.heap.push(Reverse((Time(t), self.seq, kind, payload)));
    }

    fn schedule_step(&mut self, m: ModelId, t: f64) {
        if self.step_scheduled.insert(m) {
            self.push_ev(t, Ev::Step(m));
        }
    }

    // ------------------------------------------------------------ placement

    /// Initial placement at t=0. Space-sharing policies (and Prism) pre-place
    /// everything that fits; time-sharing policies start empty.
    fn initial_placement(&mut self) {
        match self.cfg.policy {
            PolicyKind::Qlm | PolicyKind::ServerlessLlm => {}
            _ => {
                // Uniform-demand Algorithm 1 placement (no rate info yet).
                let caps: Vec<f64> = (0..self.cluster.n_gpus())
                    .map(|g| self.cluster.gpus[g].kvc.shared_kv_bytes() as f64)
                    .collect();
                let inputs: Vec<PlacementInput> = self
                    .specs
                    .iter()
                    .map(|s| PlacementInput {
                        demand: ModelDemand {
                            model: s.id,
                            token_rate: 1.0,
                            token_size: s.kv_bytes_per_token() as f64 * s.tp as f64,
                            slo: 0.05,
                            weight_bytes_per_gpu: s.weight_bytes_per_gpu(),
                            tp: s.tp,
                        },
                        current: vec![],
                    })
                    .collect();
                let result = place(&inputs, &caps, self.cfg.tau);
                for (i, p) in result.placements.iter().enumerate() {
                    let spec = self.specs[i].clone();
                    let gpus: Vec<GpuId> = p.gpus.iter().map(|&g| GpuId(g as u32)).collect();
                    let _ = self.cluster.activate(&spec, gpus, 0.0);
                }
                if self.cfg.policy == PolicyKind::StaticPartition {
                    self.apply_static_quotas();
                }
            }
        }
    }

    /// Static partition: divide each GPU's post-weight memory evenly among
    /// its resident models as hard KV quotas.
    fn apply_static_quotas(&mut self) {
        for g in 0..self.cluster.n_gpus() {
            let residents = self.cluster.residents_on(g).to_vec();
            if residents.is_empty() {
                continue;
            }
            let free = self.cluster.gpus[g].kvc.stats().free_bytes;
            let page = self.cluster.gpus[g].kvc.page_bytes();
            let quota_pages = (free / page / residents.len() as u64) as u32;
            for m in residents {
                let _ = self.cluster.gpus[g].kvc.set_kv_limit(m, quota_pages.max(1));
            }
        }
    }

    /// Pick GPUs for activating `spec` (lowest KVPR first, paper SS6.1).
    fn pick_gpus(&mut self, spec: &ModelSpec, now: f64) -> Vec<GpuId> {
        self.refresh_demand(now);
        let mut scored: Vec<(f64, usize)> = (0..self.cluster.n_gpus())
            .map(|g| {
                let shared = self.cluster.gpus[g].kvc.shared_kv_bytes() as f64;
                let w: f64 = self
                    .cluster
                    .residents_on(g)
                    .iter()
                    .map(|m| self.demand_rates[self.model_index[m]])
                    .sum();
                (kvpr(w, shared), g)
            })
            .collect();
        scored.sort_by(|a, b| a.0.partial_cmp(&b.0).unwrap());
        scored.iter().take(spec.tp as usize).map(|&(_, g)| GpuId(g as u32)).collect()
    }

    fn demand_of(&self, m: ModelId, now: f64) -> ModelDemand {
        let idx = self.idx_of(m);
        let spec = &self.specs[idx];
        ModelDemand {
            model: m,
            token_rate: self.monitors[idx].rate_at(now),
            token_size: spec.kv_bytes_per_token() as f64 * spec.tp as f64,
            slo: self.slos[idx].1,
            weight_bytes_per_gpu: spec.weight_bytes_per_gpu(),
            tp: spec.tp,
        }
    }

    /// Make `spec` resident, evicting idle models if memory is short.
    /// Returns ready time, or None if it cannot fit right now. Retries are
    /// bounded: each attempt re-picks GPUs only after a successful eviction
    /// freed memory; with no evictable victim it gives up immediately.
    fn ensure_resident(&mut self, idx: usize, now: f64) -> Option<f64> {
        let spec = self.specs[idx].clone();
        if let Some(r) = self.cluster.residency.get(&spec.id) {
            return Some(r.ready_at);
        }
        // Choose loading strategy per policy.
        self.cluster.load_strategy = match self.cfg.policy {
            PolicyKind::Prism => LoadStrategy::Parallel,
            PolicyKind::Qlm => LoadStrategy::Naive, // engine restart on swap
            PolicyKind::ServerlessLlm => LoadStrategy::Naive, // full cold start
            _ => LoadStrategy::Parallel,
        };
        const MAX_ACTIVATION_ATTEMPTS: usize = 8;
        for _ in 0..MAX_ACTIVATION_ATTEMPTS {
            let gpus = self.pick_gpus(&spec, now);
            if gpus.len() < spec.tp as usize {
                return None;
            }
            match self.cluster.activate(&spec, gpus, now) {
                Ok(ready) => return Some(ready),
                Err(KvError::OutOfPages(_)) => {
                    // Evict the least-recently-active other idle resident,
                    // then retry with freshly re-picked GPUs.
                    let victim = self
                        .cluster
                        .residency
                        .values()
                        .filter(|r| r.model != spec.id)
                        .filter(|r| !self.cluster.engines[r.engine_idx].has_work())
                        .min_by(|a, b| a.last_active.partial_cmp(&b.last_active).unwrap())
                        .map(|r| r.model);
                    match victim {
                        Some(v) => {
                            let reqs = self.evict_model(v);
                            self.pending.extend(reqs);
                        }
                        None => return None,
                    }
                }
                Err(_) => return None,
            }
        }
        None
    }

    fn evict_model(&mut self, m: ModelId) -> Vec<Request> {
        self.metrics.preemptions += self
            .cluster
            .residency
            .get(&m)
            .map(|r| self.cluster.engines[r.engine_idx].preemptions)
            .unwrap_or(0);
        self.cluster.evict(m)
    }

    // ------------------------------------------------------------- arrivals

    fn on_arrival(&mut self, e: &TraceEvent) {
        let now = e.t;
        let idx = e.model_idx;
        let (ttft_slo, tpot_slo) = self.slos[idx];
        let req = Request::new(
            self.next_req_id,
            self.specs[idx].id,
            now,
            e.prompt_tokens,
            e.output_tokens,
            ttft_slo,
            tpot_slo,
        );
        self.next_req_id += 1;
        self.monitors[idx].record(now, e.prompt_tokens as u64);
        self.demand_cache_at = f64::NEG_INFINITY; // rates changed
        self.last_request_at[idx] = now;
        if let Some(r) = self.cluster.residency.get_mut(&self.specs[idx].id) {
            r.last_active = now;
        }
        self.route(req, now);
    }

    fn route(&mut self, req: Request, now: f64) {
        let idx = self.idx_of(req.model);
        let resident = self.cluster.is_resident(req.model);
        match self.cfg.policy {
            PolicyKind::Qlm => {
                // Group queue; dispatch at epochs.
                if resident {
                    self.enqueue_on_gpu(req, now);
                } else {
                    self.pending.push(req);
                }
            }
            _ => {
                if resident {
                    self.enqueue_on_gpu(req, now);
                } else if self.cfg.policy.static_residency() {
                    // Static policies: model should have been placed at t=0;
                    // if it did not fit, requests wait (and violate SLOs).
                    self.pending.push(req);
                } else {
                    match self.ensure_resident(idx, now) {
                        Some(_) => self.enqueue_on_gpu(req, now),
                        None => self.pending.push(req),
                    }
                }
            }
        }
    }

    fn enqueue_on_gpu(&mut self, req: Request, now: f64) {
        let res = self.cluster.residency.get(&req.model).expect("resident");
        let lead = res.gpus[0].0 as usize;
        let ready = res.ready_at;
        let m = req.model;
        self.gpu_queues[lead].push(req);
        self.schedule_step(m, now.max(ready));
    }

    // ------------------------------------------------------------ admission

    /// Admit requests from a GPU's shared queue into resident engines.
    fn admit_gpu(&mut self, g: usize, now: f64) {
        if self.gpu_queues[g].is_empty() {
            return;
        }
        let queue = std::mem::take(&mut self.gpu_queues[g]);
        let (mut admit, mut keep): (Vec<Request>, Vec<Request>) = if self.cfg.slack_aware {
            // Algorithm 2: Moore-Hodgson over prefill deadlines.
            let cands: Vec<Candidate> = queue
                .iter()
                .map(|r| {
                    let idx = self.idx_of(r.model);
                    let c = self.cfg.perf.prefill_tokens_per_sec(&self.specs[idx]);
                    Candidate {
                        id: r.id,
                        arrival: r.arrival,
                        deadline: r.ttft_deadline(),
                        exec: r.prompt_tokens as f64 / c,
                    }
                })
                .collect();
            let sched = moore_hodgson(now, &cands);
            // Admit the feasible set in EDF order, then the deferred ones
            // behind them: Moore-Hodgson decides priority, not starvation -
            // deferred requests are served late, not dropped (SS6.2).
            let mut order: BTreeMap<prism::request::RequestId, usize> = BTreeMap::new();
            for (i, id) in sched.admitted.iter().chain(sched.deferred.iter()).enumerate() {
                order.insert(*id, i);
            }
            let mut adm: Vec<Request> = queue;
            adm.sort_by_key(|r| order[&r.id]);
            (adm, Vec::new())
        } else {
            // FCFS.
            (queue, Vec::new())
        };

        // Hand admitted requests to their engines (bounded by engine batch).
        let mut still: Vec<Request> = Vec::new();
        let mut moved: Vec<(usize, Request)> = Vec::new();
        for req in admit.drain(..) {
            // Migration may have relocated the model: move the request to
            // its current lead GPU's queue.
            if let Some(res) = self.cluster.residency.get(&req.model) {
                let lead = res.gpus[0].0 as usize;
                if lead != g {
                    let m = req.model;
                    let t = res.ready_at.max(now);
                    moved.push((lead, req));
                    self.schedule_step(m, t);
                    continue;
                }
            }
            match self.cluster.residency.get(&req.model) {
                Some(res) if res.ready_at <= now + 1e-9 => {
                    let eidx = res.engine_idx;
                    let cap = self.cluster.engines[eidx].max_batch as usize * 2;
                    let load = self.cluster.engines[eidx].queue_len()
                        + self.cluster.engines[eidx].running_len();
                    if load < cap {
                        let m = req.model;
                        self.cluster.engines[eidx].admit(req);
                        self.schedule_step(m, now);
                    } else {
                        still.push(req);
                    }
                }
                Some(res) => {
                    let t = res.ready_at;
                    let m = req.model;
                    still.push(req);
                    // Re-kick when the model becomes ready.
                    self.schedule_step(m, t);
                }
                None => still.push(req), // evicted meanwhile; epoch will fix
            }
        }
        keep.extend(still);
        self.gpu_queues[g] = keep;
        for (lead, req) in moved {
            self.gpu_queues[lead].push(req);
        }
    }

    // ----------------------------------------------------------- engine step

    fn on_step(&mut self, m: ModelId, now: f64) {
        self.step_scheduled.remove(&m);
        let Some(res) = self.cluster.residency.get(&m) else {
            return; // evicted; requests were re-queued
        };
        if res.ready_at > now + 1e-9 {
            let t = res.ready_at;
            self.schedule_step(m, t);
            return;
        }
        let lead = res.gpus[0].0 as usize;
        // Admit from the shared queue first (slack-aware or FCFS).
        self.admit_gpu(lead, now);

        let Some(res) = self.cluster.residency.get(&m) else {
            return;
        };
        let eidx = res.engine_idx;
        let group = res.gpus.clone();
        if !self.cluster.engines[eidx].has_work() {
            return; // idle; a future arrival re-kicks
        }
        let outcome = {
            let (engines, gpus) = (&mut self.cluster.engines, &mut self.cluster.gpus);
            let mut ga = GroupAlloc::new(gpus, &group, m);
            engines[eidx].step(now, &self.cfg.perf, &mut ga)
        };
        // Track violations for timelines, then stream each record into the
        // metrics sink (counters + sketches; raw retention is opt-in).
        if !outcome.completions.is_empty() {
            self.demand_cache_at = f64::NEG_INFINITY; // rates changed
        }
        for c in outcome.completions {
            if !c.ttft_ok() {
                self.cum_violations += 1;
            }
            self.tokens_since_sample += (c.prompt_tokens + c.output_tokens) as u64;
            // Decode-token production feeds the KVPR monitor (SS6.1).
            let idx = self.idx_of(c.model);
            self.monitors[idx].record(now, c.output_tokens as u64);
            self.metrics.record(c);
        }
        if let Some(r) = self.cluster.residency.get_mut(&m) {
            r.last_active = now;
        }
        if outcome.duration > 0.0 {
            self.schedule_step(m, now + outcome.duration);
        } else if self.cluster.engines[eidx].has_work() {
            self.schedule_step(m, now + self.cfg.perf.iter_overhead);
        }
    }

    // ---------------------------------------------------------------- epoch

    fn on_epoch(&mut self, now: f64) {
        // Monitor housekeeping: actually drop expired rate events once per
        // epoch (reads between epochs skip them without mutating).
        for mon in &mut self.monitors {
            mon.expire_to(now);
        }
        match self.cfg.policy {
            PolicyKind::Prism => {
                self.prism_evictions(now);
                self.prism_placement(now);
            }
            PolicyKind::Qlm => self.qlm_dispatch(now),
            PolicyKind::ServerlessLlm => self.serverless_evictions(now),
            _ => {}
        }
        // Retry pending requests whose models can now be activated.
        let pending = std::mem::take(&mut self.pending);
        for req in pending {
            self.route(req, now);
        }
        // Re-admit every GPU queue: migration may have moved a model away
        // from the GPU whose queue holds its requests, and no engine step on
        // the old GPU would otherwise re-examine them.
        for g in 0..self.gpu_queues.len() {
            self.admit_gpu(g, now);
        }
        // Background prealloc refill (kvcached prep thread).
        for g in 0..self.cluster.n_gpus() {
            self.cluster.gpus[g].kvc.tick_prealloc();
        }
    }

    fn prism_evictions(&mut self, now: f64) {
        if self.cfg.no_evict {
            return;
        }
        let candidates: Vec<(ModelId, f64, Vec<GpuId>)> = self
            .cluster
            .residency
            .values()
            .map(|r| (r.model, r.last_active, r.gpus.clone()))
            .collect();
        for (m, last_active, gpus) in candidates {
            let eidx = self.cluster.residency.get(&m).unwrap().engine_idx;
            if self.cluster.engines[eidx].has_work() {
                continue;
            }
            // "Constrained for others" = KV headroom (free + reclaimable)
            // is scarce; weight residency alone is not pressure, because
            // kvcached already lets co-tenants use the free pool.
            let min_free = gpus
                .iter()
                .map(|g| {
                    let st = self.cluster.gpus[g.0 as usize].kvc.stats();
                    self.cluster.gpus[g.0 as usize].kvc.shared_kv_bytes() as f64
                        / st.total_bytes as f64
                })
                .fold(1.0, f64::min);
            if self.cfg.eviction.should_evict(now, last_active, min_free) {
                let reqs = self.evict_model(m);
                self.pending.extend(reqs);
            }
        }
    }

    fn prism_placement(&mut self, now: f64) {
        if self.cfg.no_migrate {
            return;
        }
        // Build demand for resident models; migrate per Algorithm 1.
        let resident: Vec<ModelId> = self.cluster.residency.keys().copied().collect();
        if resident.len() < 2 {
            return;
        }
        self.refresh_demand(now);
        let caps: Vec<f64> = (0..self.cluster.n_gpus())
            .map(|g| {
                let st = self.cluster.gpus[g].kvc.stats();
                (st.total_bytes - st.kv_used_bytes) as f64
            })
            .collect();
        let inputs: Vec<PlacementInput> = resident
            .iter()
            .map(|&m| PlacementInput {
                demand: self.demand_of(m, now),
                current: self
                    .cluster
                    .residency
                    .get(&m)
                    .unwrap()
                    .gpus
                    .iter()
                    .map(|g| g.0 as usize)
                    .collect(),
            })
            .collect();
        let result = place(&inputs, &caps, self.cfg.tau);
        for (i, p) in result.placements.iter().enumerate() {
            if !p.migrated {
                continue;
            }
            let spec = self.specs[self.idx_of(inputs[i].demand.model)].clone();
            if spec.tp != 1 {
                continue; // TP migration out of scope (paper: anti-affinity only)
            }
            // Only migrate idle-engine models; busy ones keep serving (the
            // paper overlaps migration, we approximate by deferring).
            let eidx = self.cluster.residency.get(&spec.id).unwrap().engine_idx;
            if self.cluster.engines[eidx].has_work() {
                continue;
            }
            let to = GpuId(p.gpus[0] as u32);
            let from = self.cluster.residency.get(&spec.id).unwrap().gpus[0];
            // Migration is only worth its disruption when the source GPU is
            // actually pressured (paper SS6.1: avoid migrations with
            // marginal benefit). KVPR has units 1/s: a value above ~0.1
            // means demand would fill the GPU's free KV within ~10 s.
            let src_kvpr = {
                let shared = self.cluster.gpus[from.0 as usize].kvc.shared_kv_bytes() as f64;
                let w: f64 = self
                    .cluster
                    .residents_on(from.0 as usize)
                    .iter()
                    .map(|m| self.demand_rates[self.model_index[m]])
                    .sum();
                kvpr(w, shared)
            };
            if src_kvpr < 0.1 {
                continue;
            }
            if from != to {
                if self.cluster.migrate(&spec, to, now, true).is_ok() {
                    // Move this model's queued requests with it immediately;
                    // waiting for the next epoch would burn the TTFT budget.
                    let old_q = std::mem::take(&mut self.gpu_queues[from.0 as usize]);
                    let (mine, rest): (Vec<Request>, Vec<Request>) =
                        old_q.into_iter().partition(|r| r.model == spec.id);
                    self.gpu_queues[from.0 as usize] = rest;
                    if !mine.is_empty() {
                        self.gpu_queues[to.0 as usize].extend(mine);
                        let ready = self.cluster.residency.get(&spec.id).unwrap().ready_at;
                        self.schedule_step(spec.id, ready.max(now));
                    }
                }
            }
        }
    }

    fn qlm_dispatch(&mut self, now: f64) {
        // Group pending requests by model; dispatch the group whose head has
        // the earliest deadline onto each idle GPU, swapping models in.
        loop {
            // Find an idle GPU (no resident model with work).
            let idle_gpu = (0..self.cluster.n_gpus()).find(|&g| {
                !self.cluster.residents_on(g).iter().any(|m| {
                    let eidx = self.cluster.residency[m].engine_idx;
                    self.cluster.engines[eidx].has_work()
                })
            });
            let Some(g) = idle_gpu else { break };
            // Earliest-deadline pending group. (TP groups: QLM picks the
            // first tp idle GPUs; we simplify by requiring residency via
            // ensure_resident below.)
            let head = self
                .pending
                .iter()
                .min_by(|a, b| a.ttft_deadline().partial_cmp(&b.ttft_deadline()).unwrap())
                .map(|r| r.model);
            let Some(m) = head else { break };
            let idx = self.idx_of(m);
            // Swap: evict whatever is resident-and-idle on g, then activate.
            let victims: Vec<ModelId> = self
                .cluster
                .residents_on(g)
                .iter()
                .filter(|cand| {
                    let eidx = self.cluster.residency[*cand].engine_idx;
                    !self.cluster.engines[eidx].has_work()
                })
                .copied()
                .collect();
            for v in victims {
                let reqs = self.evict_model(v);
                self.pending.extend(reqs);
            }
            if self.ensure_resident(idx, now).is_none() {
                break;
            }
            // Dispatch the whole group.
            let group: Vec<Request> = {
                let (grp, rest): (Vec<Request>, Vec<Request>) =
                    std::mem::take(&mut self.pending).into_iter().partition(|r| r.model == m);
                self.pending = rest;
                grp
            };
            for r in group {
                self.enqueue_on_gpu(r, now);
            }
        }
    }

    fn serverless_evictions(&mut self, now: f64) {
        // Aggressive unloading: short idle threshold, no memory-pressure gate.
        let candidates: Vec<(ModelId, f64)> = self
            .cluster
            .residency
            .values()
            .map(|r| (r.model, r.last_active))
            .collect();
        for (m, last_active) in candidates {
            let eidx = self.cluster.residency.get(&m).unwrap().engine_idx;
            if self.cluster.engines[eidx].has_work() {
                continue;
            }
            if now - last_active > 3.0 {
                let reqs = self.evict_model(m);
                self.pending.extend(reqs);
            }
        }
    }

    fn on_sample(&mut self, now: f64) {
        let gpus: Vec<(u64, u64, u64, u64)> = (0..self.cluster.n_gpus())
            .map(|g| {
                let st = self.cluster.gpus[g].kvc.stats();
                (st.weight_bytes, st.kv_mapped_bytes, st.kv_used_bytes, st.free_bytes)
            })
            .collect();
        let queue_lens: Vec<usize> = (0..self.cluster.n_gpus())
            .map(|g| {
                self.gpu_queues[g].len()
                    + self
                        .cluster
                        .residents_on(g)
                        .iter()
                        .map(|m| &self.cluster.residency[m])
                        .filter(|r| r.gpus[0].0 as usize == g)
                        .map(|r| {
                            self.cluster.engines[r.engine_idx].queue_len()
                                + self.cluster.engines[r.engine_idx].running_len()
                        })
                        .sum::<usize>()
            })
            .collect();
        let tput = self.tokens_since_sample as f64 / self.cfg.sample_dt.max(1e-9);
        self.tokens_since_sample = 0;
        self.timeline.push(TimelineSample {
            t: now,
            gpus,
            queue_lens,
            cum_violations: self.cum_violations,
            inst_token_tput: tput,
        });
    }

    // ------------------------------------------------------------------ run

    pub fn run(self, trace: &Trace) -> (RunMetrics, Vec<TimelineSample>) {
        self.run_scaled(trace, 1.0)
    }

    /// As [`run`](Self::run), with the trace's request volume scaled by
    /// `rate_scale` LAZILY at the arrival cursor: identical output to
    /// `run(&trace.scale_rate(rate_scale))` (regression-tested) without ever
    /// materializing the scaled event vector, so sweep points over the same
    /// base trace share it read-only. The legacy pre-push formulation has no
    /// cursor to scale through, so it still materializes.
    pub fn run_scaled(self, trace: &Trace, rate_scale: f64) -> (RunMetrics, Vec<TimelineSample>) {
        let scaling = (rate_scale - 1.0).abs() > 1e-12;
        if scaling && (!self.cfg.stream_arrivals || !trace.is_sorted()) {
            // The lazy cursor needs the streaming loop AND a time-sorted
            // base: `scale_rate` sorts globally, and the cursor can only
            // reproduce that order when base events already arrive in time
            // order. Materialize (which sorts) for the legacy pre-push mode
            // and for unsorted traces.
            let scaled = trace.scale_rate(rate_scale);
            return self.run_inner(&scaled, None);
        }
        if scaling {
            let cursor = ScaledEvents::new(trace, rate_scale);
            return self.run_inner(trace, Some(cursor));
        }
        self.run_inner(trace, None)
    }

    fn run_inner<'a>(
        mut self,
        trace: &'a Trace,
        mut scaled: Option<ScaledEvents<'a>>,
    ) -> (RunMetrics, Vec<TimelineSample>) {
        self.initial_placement();

        // Arrivals stream from a cursor over the time-sorted trace, keeping
        // the heap at O(active events) instead of O(#trace events). An
        // unsorted trace (none of the generators produce one) gets a sorted
        // index so semantics never depend on input order. With a lazy
        // rate-scaling cursor (`scaled`), that cursor IS the arrival source
        // and emits in sorted order by construction.
        let stream = self.cfg.stream_arrivals;
        let order: Option<Vec<usize>> = if scaled.is_none() && stream && !trace.is_sorted() {
            let mut idx: Vec<usize> = (0..trace.events.len()).collect();
            idx.sort_by(|&a, &b| trace.events[a].t.partial_cmp(&trace.events[b].t).unwrap());
            Some(idx)
        } else {
            None
        };
        let arrival_at = |i: usize| order.as_ref().map_or(i, |o| o[i]);
        let mut next_arrival = 0usize;
        if !stream {
            // Legacy formulation (A/B regression + heap-size benchmarks).
            debug_assert!(scaled.is_none(), "pre-push mode materializes scaled traces");
            for (i, e) in trace.events.iter().enumerate() {
                self.push_ev(e.t, Ev::Arrival(i));
            }
            next_arrival = trace.events.len();
        }

        let mut t = 0.0;
        while t < trace.duration {
            t += self.cfg.control_epoch;
            self.push_ev(t, Ev::Epoch);
        }
        if self.cfg.sample_dt > 0.0 {
            let mut t = 0.0;
            while t < trace.duration {
                self.push_ev(t, Ev::Sample);
                t += self.cfg.sample_dt;
            }
        }

        // Drain: keep processing until no work remains (bounded tail).
        let tail_limit = trace.duration + 600.0;
        let mut last_now = 0.0;
        loop {
            // Arrivals win time ties: in the pre-push formulation they carry
            // the lowest sequence numbers, so `<=` preserves event order.
            let heap_head = self.heap.peek().map(|Reverse((Time(ht), ..))| *ht);
            let arrival_head = match &mut scaled {
                Some(c) => c.peek_t(),
                None => (next_arrival < trace.events.len())
                    .then(|| trace.events[arrival_at(next_arrival)].t),
            };
            let take_arrival = match (arrival_head, heap_head) {
                (Some(at), Some(ht)) => at <= ht,
                (Some(_), None) => true,
                (None, _) => false,
            };
            if take_arrival {
                let now = arrival_head.expect("take_arrival implies a head");
                if now > tail_limit {
                    break;
                }
                let e = match &mut scaled {
                    Some(c) => c.next_event().expect("peeked event exists"),
                    None => {
                        let i = arrival_at(next_arrival);
                        next_arrival += 1;
                        trace.events[i].clone()
                    }
                };
                last_now = now;
                self.metrics.sim_events += 1;
                self.on_arrival(&e);
                continue;
            }
            let Some(Reverse((Time(now), _, kind, payload))) = self.heap.pop() else {
                break;
            };
            if now > tail_limit {
                break;
            }
            last_now = now;
            self.metrics.sim_events += 1;
            match kind {
                0 => {
                    let e = trace.events[payload].clone();
                    self.on_arrival(&e);
                }
                1 => self.on_step(ModelId(payload as u32), now),
                2 => {
                    self.on_epoch(now);
                    // Keep epochs running through the tail drain.
                    if now + self.cfg.control_epoch <= tail_limit
                        && (self.has_outstanding() || now < trace.duration)
                    {
                        self.push_ev(now + self.cfg.control_epoch, Ev::Epoch);
                    }
                }
                3 => self.on_sample(now),
                _ => unreachable!(),
            }
        }

        // Unfinished requests at cutoff: record as dropped completions.
        let mut leftovers: Vec<Request> = std::mem::take(&mut self.pending);
        for q in &mut self.gpu_queues {
            leftovers.append(q);
        }
        for mut r in leftovers {
            r.phase = Phase::Dropped;
            self.metrics.record(prism::request::Completion::from_request(&r));
        }

        self.metrics.busy_seconds = self.cluster.engines.iter().map(|e| e.busy_seconds).sum();
        self.metrics.preemptions += self.cluster.engines.iter().map(|e| e.preemptions).sum::<u64>();
        self.metrics.wall_seconds = last_now;
        self.metrics.activations = self.cluster.activations;
        self.metrics.evictions = self.cluster.evictions;
        self.metrics.migrations = self.cluster.migrations;
        (self.metrics, self.timeline)
    }

    fn has_outstanding(&self) -> bool {
        !self.pending.is_empty()
            || self.gpu_queues.iter().any(|q| !q.is_empty())
            || self.cluster.engines.iter().any(|e| e.has_work())
    }
}
