//! Byte-identity regression for the policy-API refactor: the
//! trait-dispatch simulator must produce **bitwise-identical** fixed-seed
//! metrics to the pre-refactor enum-dispatch implementation, for each of
//! the five original policies. The reference lives in `enum_reference.rs`
//! — a frozen copy of the old simulator, compiled against the crate's
//! public cluster/engine/kvcached/sched APIs, so the comparison is a live
//! A/B run rather than a table of recorded constants.

mod enum_reference;

use enum_reference as refsim;
use prism::experiments::e2e::assign_ids;
use prism::metrics::RunMetrics;
use prism::model::spec::{catalog_subset, table3_catalog, ModelSpec};
use prism::sim::{SimConfig, Simulator};
use prism::trace::gen::{generate, TraceGenConfig};
use prism::trace::Trace;

/// (old enum variant, registry name) for the five original policies.
const POLICIES: [(refsim::PolicyKind, &str); 5] = [
    (refsim::PolicyKind::Prism, "prism"),
    (refsim::PolicyKind::StaticPartition, "s-partition"),
    (refsim::PolicyKind::MuxServePlusPlus, "muxserve++"),
    (refsim::PolicyKind::Qlm, "qlm"),
    (refsim::PolicyKind::ServerlessLlm, "serverlessllm"),
];

/// Exact (bit-level) digest of everything the sweep tables report:
/// attainments, exact p95 percentiles (full dump), counters, event and
/// wall/busy accounting. Floats compare via `to_bits` — no tolerance.
fn fingerprint(m: &RunMetrics) -> Vec<u64> {
    vec![
        m.total() as u64,
        m.completed() as u64,
        m.ttft_attainment().to_bits(),
        m.tpot_attainment().to_bits(),
        m.mean_ttft().to_bits(),
        m.mean_tpot().to_bits(),
        m.p95_ttft().to_bits(),
        m.p95_tpot().to_bits(),
        m.p95_e2e().to_bits(),
        m.sim_events,
        m.activations,
        m.evictions,
        m.migrations,
        m.preemptions,
        m.wall_seconds.to_bits(),
        m.busy_seconds.to_bits(),
    ]
}

fn compare_all_policies(
    specs: &[ModelSpec],
    trace: &Trace,
    n_gpus: u32,
    gpu_bytes: Option<u64>,
    slo_scale: f64,
) {
    for (kind, name) in POLICIES {
        let mut old_cfg = refsim::SimConfig::new(kind, n_gpus);
        let mut new_cfg = SimConfig::new(name, n_gpus);
        old_cfg.slo_scale = slo_scale;
        new_cfg.slo_scale = slo_scale;
        // Full dump keeps the p95 columns exact, not sketch estimates.
        old_cfg.metrics_full_dump = true;
        new_cfg.metrics_full_dump = true;
        if let Some(b) = gpu_bytes {
            old_cfg.gpu_bytes = b;
            new_cfg.gpu_bytes = b;
        }
        let (old_m, _) = refsim::Simulator::new(old_cfg, specs.to_vec()).run(trace);
        let (new_m, _) = Simulator::new(new_cfg, specs.to_vec()).run(trace);
        assert_eq!(
            fingerprint(&old_m),
            fingerprint(&new_m),
            "policy {name}: trait dispatch diverged from the enum-dispatch reference"
        );
    }
}

#[test]
fn trait_dispatch_matches_enum_reference_8x8b_2gpus() {
    // The SS7.2 contended regime: 8x 7-8B models on 2 GPUs at 2x rate —
    // exercises Prism eviction+migration, QLM swaps, serverless cold
    // starts, static quotas, and slack-aware vs FCFS admission.
    let specs = assign_ids(
        table3_catalog()
            .into_iter()
            .filter(|m| m.name.contains("8b") || m.name.contains("7b"))
            .take(8)
            .collect(),
    );
    let trace = generate(&TraceGenConfig::novita_like(8, 300.0, 1234)).scale_rate(2.0);
    compare_all_policies(&specs, &trace, 2, None, 8.0);
}

#[test]
fn empty_fault_plan_matches_enum_reference() {
    // Acceptance check for the fault-injection subsystem: an explicitly
    // resolved empty `--faults` spec must leave every policy
    // bitwise-identical to the PRE-fault-subsystem simulator. The frozen
    // enum reference predates the fault module entirely, so this proves
    // "no faults" means "no behavior change", not merely "same as another
    // faultless run of the new code".
    let specs = assign_ids(
        table3_catalog()
            .into_iter()
            .filter(|m| m.name.contains("8b") || m.name.contains("7b"))
            .take(8)
            .collect(),
    );
    let trace = generate(&TraceGenConfig::novita_like(8, 300.0, 1234)).scale_rate(2.0);
    for (kind, name) in POLICIES {
        let mut old_cfg = refsim::SimConfig::new(kind, 2);
        let mut new_cfg = SimConfig::new(name, 2);
        old_cfg.slo_scale = 8.0;
        new_cfg.slo_scale = 8.0;
        old_cfg.metrics_full_dump = true;
        new_cfg.metrics_full_dump = true;
        new_cfg.faults = prism::fault::resolve("", 2, trace.duration).expect("empty spec");
        assert!(new_cfg.faults.is_empty(), "empty spec must resolve to the empty plan");
        let (old_m, _) = refsim::Simulator::new(old_cfg, specs.to_vec()).run(&trace);
        let (new_m, _) = Simulator::new(new_cfg, specs.to_vec()).run(&trace);
        assert_eq!(
            fingerprint(&old_m),
            fingerprint(&new_m),
            "policy {name}: an empty FaultPlan changed behavior vs the pre-fault reference"
        );
    }
}

#[test]
fn uniform_h100_fleet_matches_enum_reference() {
    // Acceptance check for the heterogeneous-fleet subsystem: a
    // `FleetSpec::uniform(n, H100)` cluster must be bitwise-identical to
    // the pre-fleet uniform simulator. The frozen enum reference predates
    // `GpuKind`/`FleetSpec` entirely, so this proves the per-GPU perf/cost
    // threading changed no arithmetic on the uniform path — the H100 kind
    // IS the historical default (80 GiB, `GpuPerf::default()`), and
    // per-GPU profile lookups hit clones of the same values.
    let specs = assign_ids(
        table3_catalog()
            .into_iter()
            .filter(|m| m.name.contains("8b") || m.name.contains("7b"))
            .take(8)
            .collect(),
    );
    let trace = generate(&TraceGenConfig::novita_like(8, 300.0, 1234)).scale_rate(2.0);
    for (kind, name) in POLICIES {
        let mut old_cfg = refsim::SimConfig::new(kind, 2);
        old_cfg.slo_scale = 8.0;
        old_cfg.metrics_full_dump = true;
        let new_cfg = SimConfig::from_fleet(
            name,
            prism::cluster::FleetSpec::uniform(2, prism::cluster::GpuKind::H100),
        )
        .slo_scale(8.0)
        .full_dump(true);
        assert_eq!(new_cfg.n_gpus, 2, "{name}: fleet sizes the cluster");
        let (old_m, _) = refsim::Simulator::new(old_cfg, specs.to_vec()).run(&trace);
        let (new_m, _) = Simulator::new(new_cfg, specs.to_vec()).run(&trace);
        assert_eq!(
            fingerprint(&old_m),
            fingerprint(&new_m),
            "policy {name}: the uniform H100 fleet diverged from the pre-fleet reference"
        );
    }
}

#[test]
fn builder_matches_positional_config_against_enum_reference() {
    // The fluent `SimConfig` builder is a pure spelling change: configs
    // built with `for_policy(..).gpus(..).slo_scale(..)` must reproduce
    // the frozen reference exactly, like the positional constructor does.
    let specs = assign_ids(
        table3_catalog()
            .into_iter()
            .filter(|m| m.name.contains("8b") || m.name.contains("7b"))
            .take(8)
            .collect(),
    );
    let trace = generate(&TraceGenConfig::novita_like(8, 300.0, 1234)).scale_rate(2.0);
    for (kind, name) in POLICIES {
        let mut old_cfg = refsim::SimConfig::new(kind, 2);
        old_cfg.slo_scale = 8.0;
        old_cfg.metrics_full_dump = true;
        let new_cfg = SimConfig::for_policy(name).gpus(2).slo_scale(8.0).full_dump(true);
        let (old_m, _) = refsim::Simulator::new(old_cfg, specs.to_vec()).run(&trace);
        let (new_m, _) = Simulator::new(new_cfg, specs.to_vec()).run(&trace);
        assert_eq!(
            fingerprint(&old_m),
            fingerprint(&new_m),
            "policy {name}: the fluent builder diverged from the enum-dispatch reference"
        );
    }
}

#[test]
fn explicit_shards_one_matches_enum_reference() {
    // Acceptance check for the intra-run parallelism subsystem: an
    // explicitly requested sequential shard count (`--shards 1`) must be
    // bitwise-identical to the frozen PRE-shard enum-dispatch reference.
    // The sharded dispatch in `run_inner` never engages at `shards <= 1`,
    // so this proves the shard plumbing (config knob, dispatch guard,
    // `pub(crate)` surface changes) left the historical loop untouched —
    // not merely "same as another run of the new code".
    let specs = assign_ids(
        table3_catalog()
            .into_iter()
            .filter(|m| m.name.contains("8b") || m.name.contains("7b"))
            .take(8)
            .collect(),
    );
    let trace = generate(&TraceGenConfig::novita_like(8, 300.0, 1234)).scale_rate(2.0);
    for (kind, name) in POLICIES {
        let mut old_cfg = refsim::SimConfig::new(kind, 2);
        old_cfg.slo_scale = 8.0;
        old_cfg.metrics_full_dump = true;
        let new_cfg = SimConfig::for_policy(name).gpus(2).slo_scale(8.0).full_dump(true).shards(1);
        let (old_m, _) = refsim::Simulator::new(old_cfg, specs.to_vec()).run(&trace);
        let (new_m, _) = Simulator::new(new_cfg, specs.to_vec()).run(&trace);
        assert_eq!(
            fingerprint(&old_m),
            fingerprint(&new_m),
            "policy {name}: explicit --shards 1 diverged from the pre-shard reference"
        );
    }
}

#[test]
fn trait_dispatch_matches_enum_reference_under_memory_pressure() {
    // Small-model fleet squeezed onto undersized GPUs: activation retries,
    // bounded give-ups, and heavy eviction traffic — the paths where a
    // subtle dispatch-order difference would show up first.
    let specs = assign_ids(
        catalog_subset(30)
            .into_iter()
            .filter(|m| !m.is_tp() && m.params < 4_000_000_000)
            .take(10)
            .collect(),
    );
    let trace = generate(&TraceGenConfig::hyperbolic_like(10, 240.0, 77)).scale_rate(1.5);
    compare_all_policies(&specs, &trace, 2, Some(10 * (1 << 30)), 6.0);
}
