//! Lexer stress: every banned token below hides in a literal, a comment,
//! or test-only code, so a clean scan proves the masking works.

/* Instant::now() and SystemTime in a block comment /* nested too */ stay
invisible to rule matching. */

pub fn strings() -> (&'static str, &'static str, u8) {
    let plain = "Instant::now() inside a plain string";
    let raw = r#"env::var("PATH") inside a raw string with "quotes""#;
    let byte = b'x';
    let _lifetime: &'static str = plain;
    (plain, raw, byte)
}

#[cfg(test)]
mod tests {
    #[test]
    fn test_only_clock_is_exempt() {
        let _ = std::time::Instant::now();
        let _ = std::env::var("HOME");
    }
}
