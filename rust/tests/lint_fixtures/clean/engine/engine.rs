//! Clean D4 fixture: allocation counts match the allowlist exactly.

pub fn build() -> Vec<u32> {
    let mut v = Vec::new();
    v.push(7);
    v
}

pub fn label(n: u32) -> String {
    format!("engine-{n}")
}
