//! Clean D1 fixture: the env read is waived with a justification.

pub fn jobs() -> usize {
    // lint:allow(D1): worker-count knob only; results are count-invariant.
    std::env::var("JOBS").ok().and_then(|s| s.parse().ok()).unwrap_or(1)
}
