//! Clean D3 fixture: every panic site carries a justification, once via a
//! multi-line blessed comment run and once inside a method chain.

pub fn head(xs: &[u32]) -> u32 {
    // INVARIANT: callers only pass non-empty slices (checked at intake),
    // so the first element always exists.
    *xs.first().unwrap()
}

pub fn max_digit(s: &str) -> u32 {
    s.chars()
        .filter_map(|c| c.to_digit(10))
        // INVARIANT: the caller guarantees at least one digit.
        .max()
        .expect("digit present")
}
