//! Clean D2 fixture: lookup-only maps pass; an ordered drain is waived.

use std::collections::HashMap;

pub fn lookup(by_id: &HashMap<u32, u64>, id: u32) -> u64 {
    by_id.get(&id).copied().unwrap_or(0)
}

pub fn drain_sorted(by_id: &mut HashMap<u32, u64>) -> Vec<(u32, u64)> {
    // lint:allow(D2): drained pairs are key-sorted before any use.
    let mut pairs: Vec<(u32, u64)> = by_id.drain().collect();
    pairs.sort_unstable();
    pairs
}
