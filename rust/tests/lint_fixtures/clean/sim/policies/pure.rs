//! Clean D5 fixture: a pure scoring policy - no cells, locks, or globals.

pub fn score(load: u64, capacity: u64) -> u64 {
    capacity.saturating_sub(load)
}
