//! D5 fixture: interior mutability and global state in a policy module.

use std::cell::RefCell;

pub struct CachingPolicy {
    memo: RefCell<Vec<u64>>,
}

pub static mut LAST_SCORE: u64 = 0;
