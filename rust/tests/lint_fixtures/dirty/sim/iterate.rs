//! D2 fixture: unordered iteration over a hash container.

use std::collections::HashMap;

pub fn total(by_id: &HashMap<u32, u64>) -> u64 {
    let mut sum = 0;
    for (_, v) in by_id.iter() {
        sum += v;
    }
    sum
}
