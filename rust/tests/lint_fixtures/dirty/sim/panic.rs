//! D3 fixture: panic sites without a nearby justification.

pub fn head(xs: &[u32]) -> u32 {
    *xs.first().unwrap()
}

pub fn parse(s: &str) -> u32 {
    s.parse().expect("numeric")
}
