//! D1 fixture: nondeterminism sources in a fingerprinted module.

pub fn now_ms() -> u128 {
    let t = std::time::SystemTime::now();
    t.duration_since(std::time::UNIX_EPOCH).map(|d| d.as_millis()).unwrap_or(0)
}

pub fn jobs() -> usize {
    std::env::var("JOBS").ok().and_then(|s| s.parse().ok()).unwrap_or(1)
}
