//! D4 fixture: allocation inventory drift against the allowlist.

pub fn build() -> Vec<u32> {
    let mut v = Vec::new();
    v.push(1);
    v
}

pub fn rebuild() -> Vec<u32> {
    let w: Vec<u32> = Vec::new();
    w
}

pub fn label(n: u32) -> String {
    format!("engine-{n}")
}
