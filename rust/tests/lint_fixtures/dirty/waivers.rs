//! W0/W1 fixture: malformed and unused waivers.

// lint:allow(D7): not a real rule id.
pub fn a() {}

// lint:allow(D1): nothing nondeterministic within reach.
pub fn b() {}
