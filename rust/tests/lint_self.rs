//! Self-enforcement and fixture coverage for `prism lint`.
//!
//! `committed_tree_is_lint_clean` is the teeth: plain `cargo test` fails on
//! any D1-D5/W0/W1 violation in rust/src, with the same diagnostics the
//! `prism lint` subcommand prints. The fixture tests pin every rule family
//! both ways against the corpus under rust/tests/lint_fixtures/ (data-only
//! trees, never compiled as Rust targets).

use std::path::{Path, PathBuf};

use prism::lint::report::render_text;
use prism::lint::{run, LintConfig, LintReport, Rule};

fn repo_path(rel: &str) -> PathBuf {
    Path::new(env!("CARGO_MANIFEST_DIR")).join(rel)
}

fn lint(rel: &str) -> LintReport {
    run(&repo_path(rel), &LintConfig::prism()).expect("lint run")
}

#[test]
fn committed_tree_is_lint_clean() {
    let rep = lint("rust/src");
    assert!(
        rep.findings.is_empty(),
        "prism lint found {} violation(s) in rust/src:\n{}",
        rep.findings.len(),
        render_text(&rep)
    );
    assert!(rep.files_scanned >= 60, "suspiciously few files scanned: {}", rep.files_scanned);
}

#[test]
fn dirty_fixtures_fail_exactly_as_pinned() {
    let rep = lint("rust/tests/lint_fixtures/dirty");
    let got: Vec<(&str, usize, Rule)> = rep
        .findings
        .iter()
        .map(|f| {
            let rel = f
                .path
                .strip_prefix("rust/tests/lint_fixtures/dirty/")
                .unwrap_or(f.path.as_str());
            (rel, f.line, f.rule)
        })
        .collect();
    // Line 0 = file-level (D4 inventory). Order is the report order:
    // sorted by (path, line, rule).
    let want: Vec<(&str, usize, Rule)> = vec![
        ("engine/engine.rs", 0, Rule::D4),
        ("engine/engine.rs", 0, Rule::D4),
        ("engine/engine.rs", 0, Rule::D4),
        ("sim/clock.rs", 4, Rule::D1),
        ("sim/clock.rs", 9, Rule::D1),
        ("sim/iterate.rs", 7, Rule::D2),
        ("sim/panic.rs", 4, Rule::D3),
        ("sim/panic.rs", 8, Rule::D3),
        ("sim/policies/cell.rs", 3, Rule::D5),
        ("sim/policies/cell.rs", 6, Rule::D5),
        ("sim/policies/cell.rs", 9, Rule::D5),
        ("waivers.rs", 3, Rule::W0),
        ("waivers.rs", 6, Rule::W1),
    ];
    assert_eq!(got, want, "full report:\n{}", render_text(&rep));
    // The three D4 findings cover both drift directions.
    let d4: Vec<&str> = rep
        .findings
        .iter()
        .filter(|f| f.rule == Rule::D4)
        .map(|f| f.message.as_str())
        .collect();
    assert!(d4[0].contains("allocation inventory `Vec::new` = 2, allowlist 1"));
    assert!(d4[1].contains("stale allowlist: `format!` = 1, allowlist 3"));
    assert!(d4[2].contains("stale allowlist: `Box::new` absent, allowlist 2"));
}

#[test]
fn clean_fixtures_pass() {
    let rep = lint("rust/tests/lint_fixtures/clean");
    assert!(
        rep.findings.is_empty(),
        "clean fixtures must produce zero findings:\n{}",
        render_text(&rep)
    );
    assert_eq!(rep.files_scanned, 6);
}

#[test]
fn finding_paths_are_repo_root_relative() {
    // Paths are normalized against the enclosing Cargo package root, so the
    // report is identical no matter where the process was started.
    let rep = lint("rust/tests/lint_fixtures/dirty");
    assert!(!rep.findings.is_empty());
    for f in &rep.findings {
        assert!(
            f.path.starts_with("rust/tests/lint_fixtures/dirty/"),
            "path not repo-root-relative: {}",
            f.path
        );
    }
}

#[test]
fn report_is_sorted_and_text_matches_findings() {
    let rep = lint("rust/tests/lint_fixtures/dirty");
    let keys: Vec<(&str, usize, Rule)> =
        rep.findings.iter().map(|f| (f.path.as_str(), f.line, f.rule)).collect();
    let mut sorted = keys.clone();
    sorted.sort();
    assert_eq!(keys, sorted, "findings must be sorted by (path, line, rule)");
    let text = render_text(&rep);
    assert_eq!(text.lines().count(), rep.findings.len());
    for f in &rep.findings {
        assert!(text.contains(&format!("{}:{} {}:", f.path, f.line, f.rule.as_str())));
    }
}
