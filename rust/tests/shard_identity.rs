//! `--shards 1` ≡ `--shards N` identity regression for the GPU-group-
//! sharded event loop (`sim::shard`). Every registered policy runs the
//! same fixed-seed config twice — once on the historical sequential loop
//! (`shards = 1`) and once sharded four ways — and the metric fingerprints
//! must match exactly.
//!
//! The fingerprint is **order-insensitive**: it covers every integer
//! counter, count-ratio attainments, percentiles (exact under
//! `metrics_full_dump`, bucket-count sketches otherwise — both depend only
//! on the *set* of recorded values), and the master-side wall/busy/cost
//! accounting, all compared bitwise (`to_bits`, no tolerance). It excludes
//! f64 *means*, which sum records in record order — sharding merges
//! per-shard sinks in shard order, so sums can differ in the last ulp
//! while every individual record is identical. That summation-order
//! epsilon is the documented limit of the contract (see `sim/shard.rs`).
//!
//! Config coverage mirrors the regimes that stress shard seams: a
//! contended 2-GPU cluster (cross-shard queue/migration traffic), a
//! memory-pressure churn squeeze (preemption + eviction), a seeded
//! `churn:<seed>` fault plan (crash re-routing at fault barriers), and a
//! heterogeneous `2xa100+4xl4` fleet (per-GPU perf/cost threading) — plus
//! one config per windowed-loop fast path: dense samples + slowdown-only
//! fault windows (batch-internal pauses, timeline compared bitwise),
//! rapid no-op epochs (cached window plans), and a skewed-load fleet
//! (LPT dealing).

use prism::cluster::FleetSpec;
use prism::experiments::e2e::assign_ids;
use prism::metrics::RunMetrics;
use prism::model::spec::{catalog_subset, table3_catalog, ModelSpec};
use prism::sim::{registry, SimConfig, Simulator};
use prism::trace::gen::{generate, TraceGenConfig};
use prism::trace::Trace;

/// Order-insensitive bit-level digest: counters, attainments, percentiles,
/// wall/busy/cost, and the fault-recovery ledger. No f64 means (see module
/// docs).
fn fingerprint(m: &RunMetrics) -> Vec<u64> {
    vec![
        m.total() as u64,
        m.completed() as u64,
        m.ttft_attainment().to_bits(),
        m.tpot_attainment().to_bits(),
        m.p95_ttft().to_bits(),
        m.p95_tpot().to_bits(),
        m.p95_e2e().to_bits(),
        m.sim_events,
        m.activations,
        m.evictions,
        m.migrations,
        m.preemptions,
        m.wall_seconds.to_bits(),
        m.busy_seconds.to_bits(),
        m.cost.fleet_cost_per_hour.to_bits(),
        m.cost.cost_dollars.to_bits(),
        m.faults.gpu_crashes,
        m.faults.gpu_recoveries,
        m.faults.requests_restarted,
        m.faults.requests_dropped,
        m.faults.load_retries,
        m.faults.load_failures,
        m.faults.alloc_faults_injected,
        m.faults.models_recovered,
        m.faults.recovery_seconds.to_bits(),
    ]
}

/// Run `cfg` sequentially and with four shards; assert fingerprint
/// identity. The caller leaves `cfg.shards` at its default.
fn assert_shard_identity(cfg: &SimConfig, specs: &[ModelSpec], trace: &Trace, label: &str) {
    let (seq, _) = Simulator::new(cfg.clone().shards(1), specs.to_vec()).run(trace);
    let (par, _) = Simulator::new(cfg.clone().shards(4), specs.to_vec()).run(trace);
    assert_eq!(
        fingerprint(&seq),
        fingerprint(&par),
        "{label}: 4-shard run diverged from the sequential loop"
    );
}

/// 8x 7-8B models contended on 2 GPUs at 2x rate: eviction, migration,
/// and cross-shard queue traffic, with exact (full-dump) percentiles.
#[test]
fn contended_two_gpu_cluster_all_policies() {
    let specs = assign_ids(
        table3_catalog()
            .into_iter()
            .filter(|m| m.name.contains("8b") || m.name.contains("7b"))
            .take(8)
            .collect(),
    );
    let trace = generate(&TraceGenConfig::novita_like(8, 300.0, 1234)).scale_rate(2.0);
    for name in registry().names() {
        let mut cfg = SimConfig::new(name, 2);
        cfg.slo_scale = 8.0;
        cfg.metrics_full_dump = true;
        assert_shard_identity(&cfg, &specs, &trace, name);
    }
}

/// Small-model fleet squeezed onto undersized GPUs (streaming sketches):
/// activation retries, preemption storms, heavy eviction — the paths where
/// a shard-boundary ordering bug would surface first.
#[test]
fn memory_pressure_churn_all_policies() {
    let specs = assign_ids(
        catalog_subset(30)
            .into_iter()
            .filter(|m| !m.is_tp() && m.params < 4_000_000_000)
            .take(10)
            .collect(),
    );
    let trace = generate(&TraceGenConfig::hyperbolic_like(10, 240.0, 77)).scale_rate(1.5);
    for name in registry().names() {
        let mut cfg = SimConfig::new(name, 2);
        cfg.slo_scale = 6.0;
        cfg.gpu_bytes = 10 * (1 << 30);
        assert_shard_identity(&cfg, &specs, &trace, name);
    }
}

/// Seeded fault churn (GPU crashes, slowdowns, alloc faults, load
/// failures): faults are barrier events handled master-side, so the whole
/// recovery ledger must be shard-invariant.
#[test]
fn seeded_fault_churn_all_policies() {
    let specs = assign_ids(
        catalog_subset(30)
            .into_iter()
            .filter(|m| !m.is_tp() && m.params < 4_000_000_000)
            .take(12)
            .collect(),
    );
    let trace = generate(&TraceGenConfig::novita_like(12, 300.0, 7));
    for name in registry().names() {
        let mut cfg = SimConfig::new(name, 4);
        cfg.slo_scale = 8.0;
        cfg.gpu_bytes = 12 * (1 << 30);
        cfg.faults = prism::fault::resolve("churn:5", 4, trace.duration).expect("churn spec");
        assert_shard_identity(&cfg, &specs, &trace, name);
    }
}

/// Heterogeneous 2xa100+4xl4 fleet: per-GPU perf snapshots, kind-aware
/// placement (melange), and the cost ledger across shard merges.
#[test]
fn heterogeneous_fleet_all_policies() {
    let specs = assign_ids(
        catalog_subset(30)
            .into_iter()
            .filter(|m| !m.is_tp() && m.params < 4_000_000_000)
            .take(12)
            .collect(),
    );
    let trace = generate(&TraceGenConfig::novita_like(12, 300.0, 11));
    for name in registry().names() {
        let cfg = SimConfig::from_fleet(
            name,
            FleetSpec::parse("2xa100+4xl4").expect("fleet spec"),
        )
        .slo_scale(8.0);
        assert_shard_identity(&cfg, &specs, &trace, name);
    }
}

/// Like [`assert_shard_identity`], but additionally requires the timeline
/// to match bitwise — samples on the sharded path are reconstructed from
/// per-shard [`prism::metrics::PartialSample`]s at batch-internal pauses,
/// and every reconstructed field must equal the sequential read exactly.
fn assert_shard_identity_with_timeline(
    cfg: &SimConfig,
    specs: &[ModelSpec],
    trace: &Trace,
    label: &str,
) {
    let (seq, tl_seq) = Simulator::new(cfg.clone().shards(1), specs.to_vec()).run(trace);
    let (par, tl_par) = Simulator::new(cfg.clone().shards(4), specs.to_vec()).run(trace);
    assert_eq!(
        fingerprint(&seq),
        fingerprint(&par),
        "{label}: 4-shard run diverged from the sequential loop"
    );
    assert_eq!(tl_seq.len(), tl_par.len(), "{label}: timeline length diverged");
    for (a, b) in tl_seq.iter().zip(&tl_par) {
        assert_eq!(a.t.to_bits(), b.t.to_bits(), "{label}: sample time");
        assert_eq!(a.gpus, b.gpus, "{label}: per-GPU memory stats at t={}", a.t);
        assert_eq!(a.queue_lens, b.queue_lens, "{label}: queue lens at t={}", a.t);
        assert_eq!(a.cum_violations, b.cum_violations, "{label}: violations at t={}", a.t);
        assert_eq!(
            a.inst_token_tput.to_bits(),
            b.inst_token_tput.to_bits(),
            "{label}: throughput at t={}",
            a.t
        );
    }
}

/// Fast path 1 — window batching: a sample cadence dense enough that most
/// control events are batch-internal pauses, plus overlapping
/// slowdown-only fault windows (the other pause class). Workers pause
/// mid-window and the master reconstructs each `TimelineSample` from
/// disjoint per-shard partials; the timeline must match the sequential
/// loop bitwise.
#[test]
fn sample_dense_slowdown_batches_all_policies() {
    let specs = assign_ids(
        table3_catalog()
            .into_iter()
            .filter(|m| m.name.contains("8b") || m.name.contains("7b"))
            .take(8)
            .collect(),
    );
    let trace = generate(&TraceGenConfig::novita_like(8, 300.0, 42)).scale_rate(2.0);
    for name in registry().names() {
        let mut cfg = SimConfig::new(name, 2);
        cfg.slo_scale = 8.0;
        cfg.sample_dt = 0.5;
        cfg.faults = prism::fault::resolve(
            "slow@30-150:g0x2.5;slow@90-240:g1x1.5",
            2,
            trace.duration,
        )
        .expect("slowdown spec");
        assert_shard_identity_with_timeline(&cfg, &specs, &trace, name);
    }
}

/// Fast path 2 — cached window plans: control epochs dense enough that
/// most are no-ops over a stable placement, so consecutive windows reuse
/// the `(topo_version, queue_version)`-keyed plan verbatim, while the
/// epochs that *do* move models must invalidate it (the unit test for the
/// counter mechanics is `sim::shard::tests`).
#[test]
fn cached_plan_reuse_across_noop_epochs_all_policies() {
    let specs = assign_ids(
        catalog_subset(30)
            .into_iter()
            .filter(|m| !m.is_tp() && m.params < 4_000_000_000)
            .take(10)
            .collect(),
    );
    let trace = generate(&TraceGenConfig::novita_like(10, 240.0, 99));
    for name in registry().names() {
        let mut cfg = SimConfig::new(name, 4);
        cfg.slo_scale = 8.0;
        cfg.control_epoch = 2.0;
        assert_shard_identity(&cfg, &specs, &trace, name);
    }
}

/// Fast path 3 — LPT dealing: a skewed-popularity fleet (Zipf-ish trace at
/// 1.5x on 6 GPUs) where per-component loads differ sharply, so the
/// longest-processing-time-first deal diverges from the historical
/// round-robin. Metrics must be invariant to the dealing — shards only
/// group independent components.
#[test]
fn lpt_dealing_skewed_load_all_policies() {
    let specs = assign_ids(
        catalog_subset(30)
            .into_iter()
            .filter(|m| !m.is_tp() && m.params < 4_000_000_000)
            .take(12)
            .collect(),
    );
    let trace = generate(&TraceGenConfig::novita_like(12, 240.0, 5)).scale_rate(1.5);
    for name in registry().names() {
        let mut cfg = SimConfig::new(name, 6);
        cfg.slo_scale = 8.0;
        assert_shard_identity(&cfg, &specs, &trace, name);
    }
}

/// `shards = 0` resolves to available parallelism and must land on the
/// same fingerprints as the sequential loop (on a single-core runner it
/// degenerates to the sequential path, which is exactly the contract).
#[test]
fn auto_shard_count_matches_sequential() {
    let specs = assign_ids(
        table3_catalog()
            .into_iter()
            .filter(|m| m.name.contains("8b") || m.name.contains("7b"))
            .take(8)
            .collect(),
    );
    let trace = generate(&TraceGenConfig::novita_like(8, 300.0, 1234)).scale_rate(2.0);
    let mut cfg = SimConfig::new("prism", 2);
    cfg.slo_scale = 8.0;
    let (seq, _) = Simulator::new(cfg.clone().shards(1), specs.to_vec()).run(&trace);
    let (auto, _) = Simulator::new(cfg.shards(0), specs.to_vec()).run(&trace);
    assert_eq!(
        fingerprint(&seq),
        fingerprint(&auto),
        "prism: auto shard count diverged from the sequential loop"
    );
}
