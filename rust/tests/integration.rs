//! Cross-module integration tests: trace -> simulator -> metrics under every
//! policy, the paper's qualitative orderings, the real PJRT serving path,
//! and experiment-driver smoke coverage.
// Printing is the point of this target (see Cargo.toml lints.clippy).
#![allow(clippy::print_stdout, clippy::print_stderr)]

use prism::experiments::e2e::assign_ids;
use prism::model::spec::{table3_catalog, ModelId};
use prism::sim::{SimConfig, Simulator};
use prism::trace::gen::{generate, TraceGenConfig};

fn models_8x8b() -> Vec<prism::model::spec::ModelSpec> {
    assign_ids(
        table3_catalog()
            .into_iter()
            .filter(|m| m.name.contains("8b") || m.name.contains("7b"))
            .take(8)
            .collect(),
    )
}

#[test]
fn paper_ordering_prism_dominates_time_sharing() {
    // SS7.2: QLM and ServerlessLLM time sharing must lose badly on TTFT
    // against Prism under interleaved multi-model load.
    let specs = models_8x8b();
    let trace = generate(&TraceGenConfig::hyperbolic_like(8, 300.0, 99)).scale_rate(2.0);
    let run = |p| {
        let mut cfg = SimConfig::new(p, 2);
        cfg.slo_scale = 8.0;
        Simulator::new(cfg, specs.clone()).run(&trace).0
    };
    let prism = run("prism");
    let qlm = run("qlm");
    let sls = run("serverlessllm");
    assert!(
        prism.ttft_attainment() > qlm.ttft_attainment() + 0.1,
        "prism {} vs qlm {}",
        prism.ttft_attainment(),
        qlm.ttft_attainment()
    );
    assert!(
        prism.ttft_attainment() > sls.ttft_attainment(),
        "prism {} vs serverless {}",
        prism.ttft_attainment(),
        sls.ttft_attainment()
    );
}

#[test]
fn paper_ordering_elasticity_beats_static_quotas_under_pressure() {
    // Table 2 shape: kvcached sharing >> static quotas when memory binds.
    let specs = assign_ids(
        table3_catalog()
            .into_iter()
            .filter(|m| m.name.contains("8b"))
            .take(3)
            .collect(),
    );
    // Long sequences on one GPU make quotas bind.
    let mut rng = prism::util::rng::Rng::new(5);
    let mut events = Vec::new();
    for m in 0..3usize {
        let mut t = 0.0;
        while t < 180.0 {
            t += rng.exp(if m == 0 { 3.0 } else { 1.0 });
            events.push(prism::trace::TraceEvent {
                t,
                model_idx: m,
                prompt_tokens: 600 + rng.below(1400) as u32,
                output_tokens: 300 + rng.below(900) as u32,
            });
        }
    }
    events.sort_by(|a, b| a.t.partial_cmp(&b.t).unwrap());
    let trace = prism::trace::Trace {
        name: "pressure".into(),
        n_models: 3,
        events,
        duration: 180.0,
    };
    let run = |p| {
        let mut cfg = SimConfig::new(p, 1);
        cfg.slo_scale = 8.0;
        Simulator::new(cfg, specs.clone()).run(&trace).0
    };
    let elastic = run("muxserve++");
    let quotas = run("s-partition");
    assert!(
        elastic.mean_ttft() < quotas.mean_ttft(),
        "elastic {} vs quotas {}",
        elastic.mean_ttft(),
        quotas.mean_ttft()
    );
}

#[test]
fn tp_models_serve_correctly_across_gpus() {
    let specs = assign_ids(vec![
        table3_catalog().into_iter().find(|m| m.tp == 4).unwrap(),
        table3_catalog()[0].clone(),
    ]);
    let mut rng = prism::util::rng::Rng::new(8);
    let events: Vec<prism::trace::TraceEvent> = (0..60)
        .map(|i| prism::trace::TraceEvent {
            t: i as f64,
            model_idx: (rng.below(2)) as usize,
            prompt_tokens: 100,
            output_tokens: 30,
        })
        .collect();
    let trace = prism::trace::Trace { name: "tp".into(), n_models: 2, events, duration: 60.0 };
    let mut cfg = SimConfig::new("prism", 4);
    cfg.slo_scale = 10.0;
    let (m, _) = Simulator::new(cfg, specs).run(&trace);
    assert_eq!(m.completed(), 60, "all TP-model requests served");
}

#[test]
fn per_model_attainment_accounting() {
    let specs = models_8x8b();
    let trace = generate(&TraceGenConfig::novita_like(8, 240.0, 17));
    let mut cfg = SimConfig::new("prism", 2);
    cfg.slo_scale = 12.0;
    let (m, _) = Simulator::new(cfg, specs).run(&trace);
    // Per-model attainments aggregate consistently with the global one.
    let mut total = 0.0;
    let mut n = 0usize;
    for i in 0..8u32 {
        if let Some(s) = m.model_stats(ModelId(i)) {
            total += m.ttft_attainment_for(ModelId(i)) * s.total as f64;
            n += s.total as usize;
        }
    }
    assert_eq!(n, m.total());
    assert!((total / n as f64 - m.ttft_attainment()).abs() < 1e-9);
}

#[test]
fn determinism_regression_fixed_seed() {
    // Guards the hot-path refactor against behavior drift: fixed-seed runs
    // must produce bitwise-identical headline metrics across repeats, and
    // the streamed-arrival event loop must match the pre-pushed heap
    // formulation exactly, for Prism and a time-sharing baseline.
    let specs = models_8x8b();
    let trace = generate(&TraceGenConfig::novita_like(8, 300.0, 1234)).scale_rate(2.0);
    for p in ["prism", "serverlessllm"] {
        let run = |stream: bool| {
            let mut cfg = SimConfig::new(p, 2);
            cfg.slo_scale = 8.0;
            cfg.stream_arrivals = stream;
            Simulator::new(cfg, specs.clone()).run(&trace).0
        };
        let a = run(true);
        for other in [run(true), run(false)] {
            assert_eq!(a.total(), other.total(), "{}", p);
            assert_eq!(a.ttft_attainment().to_bits(), other.ttft_attainment().to_bits(), "{}", p);
            assert_eq!(a.tpot_attainment().to_bits(), other.tpot_attainment().to_bits(), "{}", p);
            assert_eq!(
                (a.activations, a.evictions, a.migrations, a.preemptions),
                (other.activations, other.evictions, other.migrations, other.preemptions),
                "{}",
                p
            );
            assert_eq!(a.sim_events, other.sim_events, "{}", p);
        }
    }
}

#[test]
fn sweep_jobs_byte_identical_fig5() {
    // The sweep-engine determinism contract: `--jobs 1` (the historical
    // sequential path) and `--jobs 8` (worker pool) must emit byte-identical
    // fig5 tables - same point keys, same seeds, same row order, regardless
    // of the order workers finish points in.
    let seq = prism::experiments::e2e::fig5_end_to_end(true, 1);
    let par = prism::experiments::e2e::fig5_end_to_end(true, 8);
    assert_eq!(seq.len(), par.len());
    for (a, b) in seq.iter().zip(&par) {
        assert_eq!(a.title, b.title);
        assert_eq!(
            a.render(),
            b.render(),
            "table '{}' differs between --jobs 1 and --jobs 8",
            a.title
        );
        assert_eq!(a.to_csv(), b.to_csv(), "CSV for '{}' differs", a.title);
    }
}

#[test]
fn fault_sweep_jobs_byte_identical() {
    // Faults are data: adding a seeded fault axis to a sweep must preserve
    // the engine's `--jobs` identity contract. Every fault plan (including
    // the `churn:<seed>` shorthand) is resolved before its simulator is
    // constructed, so faulty points are as pure as fault-free ones and
    // `--jobs 1` vs `--jobs 8` stays byte-identical, fault counters and all.
    let specs = models_8x8b();
    let trace = generate(&TraceGenConfig::novita_like(8, 240.0, 42)).scale_rate(1.5);
    let grid = prism::sweep::SweepGrid::new()
        .gpus(&[2])
        .slo_scales(&[8.0])
        .faults(&["churn:3", "crash@60:g0+30;slow@100-180:g1x2"]);
    let points = grid.points();
    assert_eq!(points.len(), 2 * prism::sim::registry().names().len());
    let digest = |jobs: usize| -> Vec<(String, Vec<u64>)> {
        prism::sweep::run_points(&points, jobs, |_, pt| pt.run(&specs, &trace))
            .iter()
            .zip(&points)
            .map(|(m, pt)| {
                (
                    pt.key(),
                    vec![
                        m.total() as u64,
                        m.completed() as u64,
                        m.ttft_attainment().to_bits(),
                        m.mean_ttft().to_bits(),
                        m.sim_events,
                        m.preemptions,
                        m.faults.gpu_crashes,
                        m.faults.gpu_recoveries,
                        m.faults.requests_restarted,
                        m.faults.load_retries,
                        m.faults.alloc_faults_injected,
                        m.faults.recovery_seconds.to_bits(),
                    ],
                )
            })
            .collect()
    };
    assert_eq!(digest(1), digest(8), "fault sweep diverged between --jobs 1 and --jobs 8");
}

#[test]
fn fleet_sweep_jobs_byte_identical() {
    // Fleets are data: a heterogeneous fleet axis must preserve the sweep
    // engine's `--jobs` identity contract. Kind profiles are static tables
    // expanded before the simulator is constructed, so heterogeneous points
    // are as pure as uniform ones and `--jobs 1` vs `--jobs 8` stays
    // byte-identical — cost ledger included.
    let specs = models_8x8b();
    let trace = generate(&TraceGenConfig::novita_like(8, 240.0, 42)).scale_rate(1.5);
    let grid = prism::sweep::SweepGrid::new()
        .slo_scales(&[8.0])
        .fleets(&["2xa100", "1xh100+1xl4"]);
    let points = grid.points();
    assert_eq!(points.len(), 2 * prism::sim::registry().names().len());
    let digest = |jobs: usize| -> Vec<(String, Vec<u64>)> {
        prism::sweep::run_points(&points, jobs, |_, pt| pt.run(&specs, &trace))
            .iter()
            .zip(&points)
            .map(|(m, pt)| {
                (
                    pt.key(),
                    vec![
                        m.total() as u64,
                        m.completed() as u64,
                        m.ttft_attainment().to_bits(),
                        m.mean_ttft().to_bits(),
                        m.sim_events,
                        m.activations,
                        m.evictions,
                        m.migrations,
                        m.preemptions,
                        m.cost.fleet_cost_per_hour.to_bits(),
                        m.cost.cost_dollars.to_bits(),
                    ],
                )
            })
            .collect()
    };
    let d1 = digest(1);
    assert_eq!(d1, digest(8), "fleet sweep diverged between --jobs 1 and --jobs 8");
    // Sanity: the two fleets actually price differently, and keys are unique.
    let rate_of = |key_frag: &str| {
        d1.iter()
            .find(|(k, _)| k.contains(key_frag))
            .map(|(_, v)| v[9])
            .expect("fleet key present")
    };
    assert_ne!(rate_of("-F2xa100"), rate_of("-F1xh100+1xl4"));
    let mut keys: Vec<&String> = d1.iter().map(|(k, _)| k).collect();
    keys.sort();
    keys.dedup();
    assert_eq!(keys.len(), points.len(), "fleet keys must be unique");
}

#[test]
fn gpu_crash_recovery_accounting_across_policies() {
    // A crash + recovery window mid-run must leave no accounting leaks for
    // ANY registered policy: every admitted request reaches a terminal
    // state (completed, or dropped-by-crash in drop mode), and the crash /
    // recovery counters fire exactly once each.
    let specs = models_8x8b();
    let trace = generate(&TraceGenConfig::novita_like(8, 300.0, 11)).scale_rate(2.0);
    for name in prism::sim::registry().names() {
        let mut cfg = SimConfig::new(name, 2);
        cfg.slo_scale = 8.0;
        cfg.faults = prism::fault::resolve("crash@60:g0+40", 2, trace.duration).unwrap();
        let (m, _) = Simulator::new(cfg, specs.clone()).run(&trace);
        assert_eq!(m.faults.gpu_crashes, 1, "{name}");
        assert_eq!(m.faults.gpu_recoveries, 1, "{name}");
        assert_eq!(m.faults.requests_dropped, 0, "{name}: restart mode must not drop at crash");
        // No leak: every admitted request reaches a terminal record, whether
        // completed, restarted-then-completed, or tail-cutoff dropped.
        assert_eq!(m.total(), trace.events.len(), "{name}: request accounting leak");
    }
    // Drop mode: crashed in-flight work is counted, not silently lost.
    let mut cfg = SimConfig::new("prism", 2);
    cfg.slo_scale = 8.0;
    cfg.faults = prism::fault::resolve("crash@60:g0+40;drop", 2, trace.duration).unwrap();
    let (m, _) = Simulator::new(cfg, specs.clone()).run(&trace);
    assert!(m.faults.requests_dropped > 0, "drop mode saw no in-flight work at crash time");
    // >= because the tail cutoff can also drop stragglers unrelated to the crash.
    assert!(m.dropped() as u64 >= m.faults.requests_dropped);
    assert_eq!(m.total(), trace.events.len(), "drop mode: completed + dropped != admitted");
}

#[test]
fn experiment_drivers_smoke() {
    // The cheapest three drivers run end to end and save CSVs.
    for id in ["fig10", "fig13", "overhead"] {
        let tables = prism::experiments::run(id, true).unwrap();
        assert!(!tables.is_empty(), "{id} produced no tables");
        for t in &tables {
            assert!(!t.rows.is_empty(), "{id}: empty table {}", t.title);
        }
    }
}

#[test]
fn real_serving_path_composes() {
    // Full three-layer check (skipped when artifacts are absent).
    let root = std::path::PathBuf::from(env!("CARGO_MANIFEST_DIR")).join("artifacts");
    let nano = root.join("prism-nano");
    if !nano.join("manifest.json").is_file() {
        eprintln!("skipping: run `make artifacts` first");
        return;
    }
    let mut srv = prism::serve::RealServer::new(
        prism::serve::ServerConfig::default(),
        &[nano.as_path()],
        &[],
    )
    .unwrap();
    let reqs = vec![prism::serve::ServeRequest {
        model: "prism-nano".into(),
        prompt: vec![10, 20, 30, 40, 50],
        max_new_tokens: 4,
        arrival: 0.0,
        ttft_slo: Some(5.0),
    }];
    let out = srv.serve(&reqs).unwrap();
    let r = out[0].as_ref().unwrap();
    assert_eq!(r.generated.len(), 4);
    assert!(r.ttft < 5.0);
}
