//! `prism` CLI - the Layer-3 coordinator entrypoint.
//!
//! Subcommands:
//!   serve      - real PJRT serving of the PrismNano artifacts
//!   sim        - run one simulator experiment (policy x trace x GPUs)
//!   trace      - generate a synthetic trace and print its SS3 statistics
//!   exp <id>   - regenerate a paper table/figure (tab1, fig1..fig15, all)
//!   models     - print the Table-3 model catalog
//!   lint       - contract-enforcing static analysis over rust/src

// The CLI's entire job is printing; the print lints guard the library.
#![allow(clippy::print_stdout, clippy::print_stderr)]

use anyhow::Result;
use prism::bench::harness::Table;
use prism::experiments;
use prism::model::spec::{catalog_subset, table3_catalog};
use prism::sim::{registry, SimConfig, Simulator};
use prism::trace::gen::{generate, TraceGenConfig};
use prism::util::cli::Cli;

fn main() {
    prism::util::logger::init();
    let mut args = std::env::args().skip(1);
    let cmd = args.next().unwrap_or_else(|| "help".to_string());
    let code = match cmd.as_str() {
        "serve" => cmd_serve(),
        "sim" => cmd_sim(),
        "trace" => cmd_trace(),
        "exp" => cmd_exp(),
        "models" => cmd_models(),
        "lint" => cmd_lint(),
        _ => {
            eprintln!(
                "prism - cost-efficient multi-LLM serving via GPU memory ballooning\n\n\
                 usage: prism <serve|sim|trace|exp|models|lint> [options]\n\
                 \n  prism serve --models prism-nano,prism-micro --requests 12\
                 \n  prism sim --policy prism --gpus 4 --trace novita --minutes 10\
                 \n  prism sim --policy prism --gpus 4 --faults churn:7\
                 \n  prism sim --fleet 4xh100+8xl4 --policy melange\
                 \n  prism sim --gpus 32 --models 100 --shards 4\
                 \n  prism trace --kind novita --hours 2\
                 \n  prism exp fig5 [--quick] [--jobs N] [--shards N]\
                 \n  prism exp all --quick --jobs 8\
                 \n  prism lint [--src rust/src] [--json]\n"
            );
            Ok(())
        }
    }
    .map(|_| 0)
    .unwrap_or_else(|e| {
        eprintln!("error: {e}");
        1
    });
    std::process::exit(code);
}

fn cmd_serve() -> Result<()> {
    let cli = Cli::new("prism serve", "real PJRT serving of AOT artifacts")
        .opt("models", "prism-nano,prism-micro", "comma-separated artifact names")
        .opt("requests", "12", "number of synthetic requests")
        .opt("new-tokens", "8", "tokens to generate per request")
        .opt("artifacts", "artifacts", "artifacts root dir")
        .flag("fcfs", "disable slack-aware admission");
    let a = cli.parse_env(1).map_err(anyhow::Error::msg)?;
    let root = std::path::PathBuf::from(a.get_or("artifacts", "artifacts"));
    let names: Vec<String> =
        a.get_or("models", "").split(',').map(|s| s.trim().to_string()).collect();
    let dirs: Vec<std::path::PathBuf> = names.iter().map(|n| root.join(n)).collect();
    let dir_refs: Vec<&std::path::Path> = dirs.iter().map(|p| p.as_path()).collect();
    let cfg = prism::serve::ServerConfig {
        slack_aware: !a.has_flag("fcfs"),
        ..Default::default()
    };
    let mut srv = prism::serve::RealServer::new(cfg, &dir_refs, &[])?;

    let n = a.get_usize("requests", 12);
    let new_tokens = a.get_usize("new-tokens", 8);
    let mut rng = prism::util::rng::Rng::new(1);
    let reqs: Vec<prism::serve::ServeRequest> = (0..n)
        .map(|i| prism::serve::ServeRequest {
            model: names[i % names.len()].clone(),
            prompt: (0..(8 + rng.below(24))).map(|_| rng.below(255) as i32).collect(),
            max_new_tokens: new_tokens,
            arrival: i as f64 * 0.01,
            ttft_slo: Some(2.0),
        })
        .collect();
    let t0 = std::time::Instant::now();
    let results = srv.serve(&reqs)?;
    let wall = t0.elapsed().as_secs_f64();

    let mut t = Table::new(
        "Real serving results (PJRT CPU, interpret-mode Pallas)",
        &["req", "model", "ttft_ms", "tpot_ms", "e2e_ms", "tokens"],
    );
    let mut tokens = 0usize;
    let mut ok = 0usize;
    for (i, r) in results.iter().enumerate() {
        if let Some(r) = r {
            tokens += r.generated.len();
            if r.ttft <= r.ttft_slo {
                ok += 1;
            }
            t.row(vec![
                i.to_string(),
                r.model.clone(),
                format!("{:.1}", r.ttft * 1e3),
                format!("{:.1}", r.tpot * 1e3),
                format!("{:.1}", r.e2e * 1e3),
                r.generated.len().to_string(),
            ]);
        }
    }
    t.print();
    println!(
        "served {n} requests, {tokens} tokens in {wall:.2}s  ({:.1} tok/s, TTFT SLO attainment {:.0}%)",
        tokens as f64 / wall,
        100.0 * ok as f64 / n as f64
    );
    Ok(())
}

fn cmd_sim() -> Result<()> {
    // The help string is generated from the registry, so the accepted-name
    // list can never drift from what the lookup below resolves.
    let cli = Cli::new("prism sim", "simulate a policy on a synthetic trace")
        .opt("policy", "prism", registry().names_joined())
        .opt("gpus", "2", "GPU count (uniform H100 cluster; see --fleet)")
        .opt(
            "fleet",
            "",
            "heterogeneous fleet spec, e.g. 4xh100+8xl4 (kinds: l4|a10g|a100|h100; \
             overrides --gpus; empty = uniform cluster)",
        )
        .opt("models", "8", "number of models")
        .opt("trace", "novita", "novita|hyperbolic|arena-chat|arena-battle")
        .opt("minutes", "10", "trace duration")
        .opt("rate-scale", "1.0", "request-rate multiplier")
        .opt("slo-scale", "8.0", "SLO scale factor")
        .opt("seed", "1", "trace seed")
        .opt(
            "shards",
            "1",
            "intra-run event-loop shards: 1 = historical sequential loop, \
             0 = auto (available parallelism), N>1 = GPU-group-sharded",
        )
        .opt(
            "faults",
            "",
            "fault spec: crash@t:gN[+dur];slow@a-b:gNxF;loadfail@o1,o2;allocfail@a-b:gN/k;drop \
             or churn:<seed> (empty = fault-free)",
        );
    let a = cli.parse_env(1).map_err(anyhow::Error::msg)?;
    let policy_name = a.get_or("policy", "prism");
    let policy = registry().lookup(&policy_name).ok_or_else(|| {
        anyhow::anyhow!("unknown policy {policy_name} (valid: {})", registry().names_joined())
    })?;
    let n_models = a.get_usize("models", 8);
    let dur = a.get_f64("minutes", 10.0) * 60.0;
    let seed = a.get_u64("seed", 1);
    let gen_cfg = match a.get_or("trace", "novita").as_str() {
        "novita" => TraceGenConfig::novita_like(n_models, dur, seed),
        "hyperbolic" => TraceGenConfig::hyperbolic_like(n_models, dur, seed),
        "arena-chat" => TraceGenConfig::arena_chat_like(n_models, dur, seed),
        "arena-battle" => TraceGenConfig::arena_battle_like(n_models, dur, seed),
        other => anyhow::bail!("unknown trace {other}"),
    };
    let trace = generate(&gen_cfg).scale_rate(a.get_f64("rate-scale", 1.0));
    let specs = prism::experiments::e2e::assign_ids(
        catalog_subset(30)
            .into_iter()
            .filter(|m| !m.is_tp())
            .take(n_models)
            .collect(),
    );
    let n_gpus = a.get_usize("gpus", 2) as u32;
    let mut cfg = SimConfig::with_policy(policy, n_gpus);
    let fleet_spec = a.get_or("fleet", "");
    if !fleet_spec.is_empty() {
        let f = prism::cluster::FleetSpec::parse(&fleet_spec)
            .map_err(|e| anyhow::anyhow!("invalid --fleet spec: {e}"))?;
        cfg = cfg.fleet(f);
    }
    cfg.slo_scale = a.get_f64("slo-scale", 8.0);
    cfg = cfg.shards(a.get_usize("shards", 1) as u32);
    let fault_spec = a.get_or("faults", "");
    cfg.faults = prism::fault::resolve(&fault_spec, cfg.n_gpus, trace.duration)
        .map_err(|e| anyhow::anyhow!("invalid --faults spec: {e}"))?;
    // Single run whose table prints percentile columns: keep them exact
    // rather than sketch estimates.
    cfg.metrics_full_dump = true;
    let t0 = std::time::Instant::now();
    let (m, _) = Simulator::new(cfg, specs).run(&trace);
    let mut t = Table::new(
        &format!(
            "Simulation: {} on {} ({} requests)",
            policy_name,
            trace.name,
            trace.events.len()
        ),
        &["metric", "value"],
    );
    t.row(vec!["ttft_attainment".into(), format!("{:.3}", m.ttft_attainment())]);
    t.row(vec!["tpot_attainment".into(), format!("{:.3}", m.tpot_attainment())]);
    t.row(vec!["mean_ttft_s".into(), format!("{:.3}", m.mean_ttft())]);
    t.row(vec!["p95_ttft_s".into(), format!("{:.3}", m.p95_ttft())]);
    t.row(vec!["mean_tpot_ms".into(), format!("{:.2}", m.mean_tpot() * 1e3)]);
    t.row(vec!["req_tput_busy".into(), format!("{:.2}", m.req_throughput())]);
    t.row(vec!["token_tput_busy".into(), format!("{:.0}", m.token_throughput())]);
    t.row(vec!["activations".into(), m.activations.to_string()]);
    t.row(vec!["evictions".into(), m.evictions.to_string()]);
    t.row(vec!["migrations".into(), m.migrations.to_string()]);
    t.row(vec!["preemptions".into(), m.preemptions.to_string()]);
    // Cost ledger: fleet rate x simulated wall time, plus the $-per-quality
    // ratios (kind-less uniform clusters price at the H100 rate).
    t.row(vec!["fleet_cost_per_hr".into(), format!("${:.2}", m.cost.fleet_cost_per_hour)]);
    t.row(vec!["run_cost".into(), format!("${:.4}", m.cost.cost_dollars)]);
    t.row(vec![
        "cost_per_1k_req_slo".into(),
        format!("${:.4}", m.cost_per_1k_requests_at_slo()),
    ]);
    t.row(vec![
        "cost_per_attain_pt".into(),
        format!("${:.5}", m.cost_per_attainment_point()),
    ]);
    if m.faults.any() {
        t.row(vec!["gpu_crashes".into(), m.faults.gpu_crashes.to_string()]);
        t.row(vec!["gpu_recoveries".into(), m.faults.gpu_recoveries.to_string()]);
        t.row(vec!["reqs_restarted".into(), m.faults.requests_restarted.to_string()]);
        t.row(vec!["reqs_dropped_by_crash".into(), m.faults.requests_dropped.to_string()]);
        t.row(vec!["load_retries".into(), m.faults.load_retries.to_string()]);
        t.row(vec!["load_failures".into(), m.faults.load_failures.to_string()]);
        t.row(vec!["alloc_faults".into(), m.faults.alloc_faults_injected.to_string()]);
        t.row(vec!["models_recovered".into(), m.faults.models_recovered.to_string()]);
        t.row(vec!["recovery_s".into(), format!("{:.2}", m.faults.recovery_seconds)]);
    }
    let wall = t0.elapsed().as_secs_f64();
    t.row(vec!["sim_wall_s".into(), format!("{wall:.2}")]);
    t.row(vec!["sim_events".into(), m.sim_events.to_string()]);
    t.row(vec![
        "sim_events_per_s".into(),
        format!("{:.0}", m.sim_events as f64 / wall.max(1e-9)),
    ]);
    t.print();
    Ok(())
}

fn cmd_trace() -> Result<()> {
    let cli = Cli::new("prism trace", "generate + characterize a synthetic trace")
        .opt("kind", "novita", "novita|hyperbolic|arena-chat|arena-battle")
        .opt("models", "16", "number of models")
        .opt("hours", "2", "duration in hours")
        .opt("seed", "1", "seed");
    let a = cli.parse_env(1).map_err(anyhow::Error::msg)?;
    let n = a.get_usize("models", 16);
    let dur = a.get_f64("hours", 2.0) * 3600.0;
    let seed = a.get_u64("seed", 1);
    let cfg = match a.get_or("kind", "novita").as_str() {
        "novita" => TraceGenConfig::novita_like(n, dur, seed),
        "hyperbolic" => TraceGenConfig::hyperbolic_like(n, dur, seed),
        "arena-chat" => TraceGenConfig::arena_chat_like(n, dur, seed),
        "arena-battle" => TraceGenConfig::arena_battle_like(n, dur, seed),
        other => anyhow::bail!("unknown trace kind {other}"),
    };
    let tr = generate(&cfg);
    use prism::trace::stats as ts;
    let mut t = Table::new(&format!("Trace statistics: {}", cfg.name), &["metric", "value"]);
    t.row(vec!["requests".into(), tr.events.len().to_string()]);
    t.row(vec!["models".into(), tr.n_models.to_string()]);
    t.row(vec![
        "mean_active_frac".into(),
        format!("{:.2}", ts::mean_active_fraction(&tr, 120.0)),
    ]);
    t.row(vec![
        "switches_per_hour".into(),
        format!("{:.0}", ts::switches_per_hour(&tr, 120.0)),
    ]);
    let cvs = ts::per_model_rate_cv(&tr, 60.0);
    t.row(vec![
        "frac_models_cv>1".into(),
        format!(
            "{:.2}",
            cvs.iter().filter(|&&c| c > 1.0).count() as f64 / cvs.len().max(1) as f64
        ),
    ]);
    let idles = ts::per_model_idle_intervals_per_hour(&tr, 10.0);
    t.row(vec![
        "p90_idle_intervals_hr".into(),
        format!("{:.1}", prism::util::stats::percentile(&idles, 90.0)),
    ]);
    t.print();
    Ok(())
}

fn cmd_exp() -> Result<()> {
    let raw: Vec<String> = std::env::args().skip(2).collect();
    let mut quick = false;
    // Sweep worker count: 0 = auto (PRISM_JOBS or available parallelism);
    // --jobs 1 reproduces the sequential behavior bit-for-bit.
    let mut jobs = 0usize;
    // Intra-run shard count (SimConfig::shards): 1 = historical sequential
    // event loop, 0 = auto, N>1 = GPU-group-sharded. Sharded runs keep
    // metric-fingerprint identity to --shards 1, but full-dump f64 means can
    // differ in the last ulp (summation order), so experiment tables are
    // byte-stable only at a fixed shard count.
    let mut shards = 1u32;
    let mut id: Option<String> = None;
    let mut it = raw.into_iter();
    while let Some(tok) = it.next() {
        if tok == "--quick" {
            quick = true;
        } else if tok == "--jobs" {
            let v = it.next().ok_or_else(|| anyhow::anyhow!("--jobs requires a value"))?;
            jobs = parse_jobs(&v)?;
        } else if let Some(v) = tok.strip_prefix("--jobs=") {
            jobs = parse_jobs(v)?;
        } else if tok == "--shards" {
            let v = it.next().ok_or_else(|| anyhow::anyhow!("--shards requires a value"))?;
            shards = parse_shards(&v)?;
        } else if let Some(v) = tok.strip_prefix("--shards=") {
            shards = parse_shards(v)?;
        } else if tok.starts_with("--") {
            anyhow::bail!("unknown option {tok} (expected --quick, --jobs N, or --shards N)");
        } else if id.is_none() {
            id = Some(tok);
        } else {
            anyhow::bail!("unexpected extra argument {tok}");
        }
    }
    let id = id.unwrap_or_else(|| "all".to_string());
    // Experiments build their SimConfigs internally, so the shard knob
    // travels as the process-wide construction default (set once, up front).
    SimConfig::set_default_shards(shards);
    experiments::run_jobs(&id, quick, jobs)?;
    eprintln!("valid experiment ids: {:?}", experiments::ids());
    Ok(())
}

fn parse_jobs(v: &str) -> Result<usize> {
    // 0 = auto, matching the bench binaries and the run_jobs docs.
    v.parse().map_err(|_| {
        anyhow::anyhow!("--jobs expects a non-negative integer (0 = auto), got {v}")
    })
}

fn parse_shards(v: &str) -> Result<u32> {
    // 0 = auto, 1 = the historical sequential event loop.
    v.parse().map_err(|_| {
        anyhow::anyhow!("--shards expects a non-negative integer (0 = auto), got {v}")
    })
}

fn cmd_lint() -> Result<()> {
    let cli = Cli::new("prism lint", "contract-enforcing static analysis over the crate sources")
        .opt("src", "rust/src", "scan root")
        .flag("json", "emit the stable JSON report on stdout");
    let a = cli.parse_env(1).map_err(anyhow::Error::msg)?;
    let root = std::path::PathBuf::from(a.get_or("src", "rust/src"));
    let rep = prism::lint::run(&root, &prism::lint::LintConfig::prism())?;
    if a.has_flag("json") {
        println!("{}", prism::lint::report::to_json(&rep).to_string_pretty());
    } else {
        print!("{}", prism::lint::report::render_text(&rep));
    }
    if rep.findings.is_empty() {
        eprintln!("prism lint: clean ({} files scanned)", rep.files_scanned);
        Ok(())
    } else {
        anyhow::bail!("{} lint finding(s)", rep.findings.len())
    }
}

fn cmd_models() -> Result<()> {
    let mut t = Table::new(
        "Table 3 model catalog (58 LLMs)",
        &["id", "name", "params_B", "layers", "kv_B/token", "weights_GB", "tp"],
    );
    for m in table3_catalog() {
        t.row(vec![
            m.id.to_string(),
            m.name.clone(),
            format!("{:.1}", m.params as f64 / 1e9),
            m.n_layers.to_string(),
            m.kv_bytes_per_token().to_string(),
            format!("{:.1}", m.weight_bytes() as f64 / 1e9),
            m.tp.to_string(),
        ]);
    }
    t.print();
    Ok(())
}
