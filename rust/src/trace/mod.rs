//! Multi-LLM workload traces: schema, synthetic generation matching the
//! paper's published production statistics (SS3, Appendix A.1), loading, and
//! the statistics used in Figs 1, 12, 13.

pub mod gen;
pub mod stats;

/// One inference request arrival.
#[derive(Debug, Clone, PartialEq)]
pub struct TraceEvent {
    /// Arrival time in seconds from trace start.
    pub t: f64,
    /// Index into the trace's model list.
    pub model_idx: usize,
    pub prompt_tokens: u32,
    pub output_tokens: u32,
}

/// A workload trace over `n_models` models.
#[derive(Debug, Clone)]
pub struct Trace {
    pub name: String,
    pub n_models: usize,
    /// Events sorted by arrival time.
    pub events: Vec<TraceEvent>,
    /// Trace duration in seconds.
    pub duration: f64,
}

impl Trace {
    /// Scale request volume by `factor` by replicating/thinning events while
    /// preserving temporal pattern (the paper's rate-scaling methodology).
    pub fn scale_rate(&self, factor: f64) -> Trace {
        assert!(factor > 0.0);
        let mut events = Vec::new();
        let mut rng = crate::util::rng::Rng::new(0x5CA1E ^ self.events.len() as u64);
        for e in &self.events {
            let mut copies = factor.floor() as usize;
            if rng.f64() < factor - copies as f64 {
                copies += 1;
            }
            for c in 0..copies {
                let mut e2 = e.clone();
                // Jitter replicas slightly so they are not simultaneous.
                if c > 0 {
                    e2.t += rng.range_f64(0.0, 0.200);
                }
                events.push(e2);
            }
        }
        // INVARIANT: event times plus bounded jitter stay finite, so
        // partial_cmp is total.
        events.sort_by(|a, b| a.t.partial_cmp(&b.t).unwrap());
        Trace {
            name: format!("{}-x{:.2}", self.name, factor),
            n_models: self.n_models,
            events,
            duration: self.duration,
        }
    }

    /// Restrict to a time window [t0, t1), re-based to 0.
    pub fn window(&self, t0: f64, t1: f64) -> Trace {
        let events = self
            .events
            .iter()
            .filter(|e| e.t >= t0 && e.t < t1)
            .map(|e| TraceEvent { t: e.t - t0, ..e.clone() })
            .collect();
        Trace {
            name: format!("{}-w", self.name),
            n_models: self.n_models,
            events,
            duration: t1 - t0,
        }
    }

    /// Restrict to a subset of models (indices remapped to 0..k).
    pub fn select_models(&self, keep: &[usize]) -> Trace {
        let map: std::collections::BTreeMap<usize, usize> =
            keep.iter().enumerate().map(|(new, &old)| (old, new)).collect();
        let events = self
            .events
            .iter()
            .filter_map(|e| {
                map.get(&e.model_idx).map(|&m| TraceEvent { model_idx: m, ..e.clone() })
            })
            .collect();
        Trace {
            name: format!("{}-sel", self.name),
            n_models: keep.len(),
            events,
            duration: self.duration,
        }
    }

    /// True when events are non-decreasing in time. Generators uphold this
    /// by construction; the simulator's streamed-arrival cursor relies on it
    /// (and builds a sorted index when it does not hold).
    pub fn is_sorted(&self) -> bool {
        self.events.windows(2).all(|w| w[0].t <= w[1].t)
    }

    pub fn events_per_model(&self) -> Vec<usize> {
        let mut counts = vec![0usize; self.n_models];
        for e in &self.events {
            counts[e.model_idx] += 1;
        }
        counts
    }
}

/// A replica awaiting emission from the lazy scaled view, ordered by
/// `(t, seq)` — exactly the order `scale_rate`'s stable time sort produces
/// (`seq` is generation order, which stable sorting preserves on ties).
#[derive(Debug)]
struct PendingReplica {
    t: f64,
    seq: u64,
    ev: TraceEvent,
}

impl PartialEq for PendingReplica {
    fn eq(&self, other: &Self) -> bool {
        self.t == other.t && self.seq == other.seq
    }
}
impl Eq for PendingReplica {}
impl PartialOrd for PendingReplica {
    fn partial_cmp(&self, other: &Self) -> Option<std::cmp::Ordering> {
        Some(self.cmp(other))
    }
}
impl Ord for PendingReplica {
    fn cmp(&self, other: &Self) -> std::cmp::Ordering {
        // INVARIANT: replica times are trace times plus bounded jitter —
        // never NaN — so partial_cmp is total.
        self.t.partial_cmp(&other.t).expect("no NaN event times").then(self.seq.cmp(&other.seq))
    }
}

/// Lazy rate-scaled view over a trace: emits the EXACT event sequence
/// `trace.scale_rate(factor).events` would contain (same RNG stream, same
/// stable time ordering) without materializing the scaled vector. Sweep
/// grids share one base trace read-only across points; each point's cursor
/// holds only the replicas inside one 200 ms jitter lookahead window.
pub struct ScaledEvents<'a> {
    base: &'a [TraceEvent],
    factor: f64,
    next_base: usize,
    seq: u64,
    rng: crate::util::rng::Rng,
    pending: std::collections::BinaryHeap<std::cmp::Reverse<PendingReplica>>,
}

impl<'a> ScaledEvents<'a> {
    /// The base trace must be time-sorted (`Trace::is_sorted`): the cursor
    /// only has a 200 ms jitter lookahead, so an out-of-order base event
    /// would be emitted late where `scale_rate`'s global sort would not.
    /// Callers with possibly-unsorted traces materialize instead (see
    /// `Simulator::run_scaled`).
    pub fn new(trace: &'a Trace, factor: f64) -> Self {
        assert!(factor > 0.0);
        debug_assert!(trace.is_sorted(), "ScaledEvents requires a time-sorted base trace");
        ScaledEvents {
            base: &trace.events,
            factor,
            next_base: 0,
            seq: 0,
            // Same seed derivation as `Trace::scale_rate`.
            rng: crate::util::rng::Rng::new(0x5CA1E ^ trace.events.len() as u64),
            pending: std::collections::BinaryHeap::new(),
        }
    }

    /// Expand the next base event into its replicas (possibly zero when
    /// thinning with factor < 1), consuming the RNG exactly as
    /// `scale_rate` does.
    fn expand_one(&mut self) {
        let e = self.base[self.next_base].clone();
        self.next_base += 1;
        let mut copies = self.factor.floor() as usize;
        if self.rng.f64() < self.factor - copies as f64 {
            copies += 1;
        }
        for c in 0..copies {
            let t = if c > 0 { e.t + self.rng.range_f64(0.0, 0.200) } else { e.t };
            self.pending.push(std::cmp::Reverse(PendingReplica {
                t,
                seq: self.seq,
                ev: TraceEvent { t, ..e.clone() },
            }));
            self.seq += 1;
        }
    }

    /// Arrival time of the next event, if any. Jitter only moves replicas
    /// LATER than their base event, so the head is final once every base
    /// event at or before it has been expanded (ties expand too, but their
    /// replicas carry higher `seq` and sort after the head).
    pub fn peek_t(&mut self) -> Option<f64> {
        loop {
            match self.pending.peek().map(|std::cmp::Reverse(p)| p.t) {
                Some(t) => {
                    if self.next_base < self.base.len() && self.base[self.next_base].t <= t {
                        self.expand_one();
                    } else {
                        return Some(t);
                    }
                }
                None => {
                    if self.next_base < self.base.len() {
                        self.expand_one();
                    } else {
                        return None;
                    }
                }
            }
        }
    }

    /// Emit the next event in scaled-trace order.
    pub fn next_event(&mut self) -> Option<TraceEvent> {
        self.peek_t()?;
        self.pending.pop().map(|std::cmp::Reverse(p)| p.ev)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn tiny() -> Trace {
        Trace {
            name: "t".into(),
            n_models: 2,
            events: vec![
                TraceEvent { t: 1.0, model_idx: 0, prompt_tokens: 10, output_tokens: 5 },
                TraceEvent { t: 2.0, model_idx: 1, prompt_tokens: 20, output_tokens: 5 },
                TraceEvent { t: 3.0, model_idx: 0, prompt_tokens: 30, output_tokens: 5 },
            ],
            duration: 10.0,
        }
    }

    #[test]
    fn scale_rate_doubles() {
        let t = tiny().scale_rate(2.0);
        assert_eq!(t.events.len(), 6);
        // Sorted by time.
        assert!(t.events.windows(2).all(|w| w[0].t <= w[1].t));
    }

    #[test]
    fn scale_rate_fractional_statistical() {
        let mut base = tiny();
        // Make a bigger base for the statistical check.
        for i in 0..1000 {
            base.events.push(TraceEvent {
                t: i as f64 * 0.01,
                model_idx: 0,
                prompt_tokens: 1,
                output_tokens: 1,
            });
        }
        let n0 = base.events.len() as f64;
        let t = base.scale_rate(1.5);
        assert!((t.events.len() as f64 / n0 - 1.5).abs() < 0.1);
    }

    #[test]
    fn lazy_scaled_view_matches_materialized_exactly() {
        // The lazy cursor must reproduce scale_rate's output event-for-event
        // (bitwise-equal times), including fractional thinning/replication
        // and jitter-induced reordering near 200 ms boundaries.
        let base = gen::generate(&gen::TraceGenConfig::novita_like(4, 120.0, 9));
        assert!(base.events.len() > 100);
        for factor in [0.4, 1.0, 1.5, 2.0, 3.7] {
            let materialized = base.scale_rate(factor);
            let mut lazy = ScaledEvents::new(&base, factor);
            let mut got = Vec::new();
            while let Some(e) = lazy.next_event() {
                got.push(e);
            }
            assert_eq!(got, materialized.events, "factor {factor}");
        }
    }

    #[test]
    fn lazy_scaled_view_peek_is_stable() {
        let base = tiny();
        let mut lazy = ScaledEvents::new(&base, 2.0);
        while let Some(t) = lazy.peek_t() {
            assert_eq!(lazy.peek_t(), Some(t), "peek must not consume");
            let e = lazy.next_event().unwrap();
            assert_eq!(e.t, t);
        }
        assert_eq!(lazy.next_event(), None);
    }

    #[test]
    fn sortedness_detected() {
        let mut t = tiny();
        assert!(t.is_sorted());
        t.events.swap(0, 2);
        assert!(!t.is_sorted());
    }

    #[test]
    fn window_rebases() {
        let t = tiny().window(1.5, 3.5);
        assert_eq!(t.events.len(), 2);
        assert!((t.events[0].t - 0.5).abs() < 1e-12);
        assert!((t.duration - 2.0).abs() < 1e-12);
    }

    #[test]
    fn select_models_remaps() {
        let t = tiny().select_models(&[1]);
        assert_eq!(t.n_models, 1);
        assert_eq!(t.events.len(), 1);
        assert_eq!(t.events[0].model_idx, 0);
    }
}
