//! Multi-LLM workload traces: schema, synthetic generation matching the
//! paper's published production statistics (SS3, Appendix A.1), loading, and
//! the statistics used in Figs 1, 12, 13.

pub mod gen;
pub mod stats;

/// One inference request arrival.
#[derive(Debug, Clone, PartialEq)]
pub struct TraceEvent {
    /// Arrival time in seconds from trace start.
    pub t: f64,
    /// Index into the trace's model list.
    pub model_idx: usize,
    pub prompt_tokens: u32,
    pub output_tokens: u32,
}

/// A workload trace over `n_models` models.
#[derive(Debug, Clone)]
pub struct Trace {
    pub name: String,
    pub n_models: usize,
    /// Events sorted by arrival time.
    pub events: Vec<TraceEvent>,
    /// Trace duration in seconds.
    pub duration: f64,
}

impl Trace {
    /// Scale request volume by `factor` by replicating/thinning events while
    /// preserving temporal pattern (the paper's rate-scaling methodology).
    pub fn scale_rate(&self, factor: f64) -> Trace {
        assert!(factor > 0.0);
        let mut events = Vec::new();
        let mut rng = crate::util::rng::Rng::new(0x5CA1E ^ self.events.len() as u64);
        for e in &self.events {
            let mut copies = factor.floor() as usize;
            if rng.f64() < factor - copies as f64 {
                copies += 1;
            }
            for c in 0..copies {
                let mut e2 = e.clone();
                // Jitter replicas slightly so they are not simultaneous.
                if c > 0 {
                    e2.t += rng.range_f64(0.0, 0.200);
                }
                events.push(e2);
            }
        }
        events.sort_by(|a, b| a.t.partial_cmp(&b.t).unwrap());
        Trace {
            name: format!("{}-x{:.2}", self.name, factor),
            n_models: self.n_models,
            events,
            duration: self.duration,
        }
    }

    /// Restrict to a time window [t0, t1), re-based to 0.
    pub fn window(&self, t0: f64, t1: f64) -> Trace {
        let events = self
            .events
            .iter()
            .filter(|e| e.t >= t0 && e.t < t1)
            .map(|e| TraceEvent { t: e.t - t0, ..e.clone() })
            .collect();
        Trace {
            name: format!("{}-w", self.name),
            n_models: self.n_models,
            events,
            duration: t1 - t0,
        }
    }

    /// Restrict to a subset of models (indices remapped to 0..k).
    pub fn select_models(&self, keep: &[usize]) -> Trace {
        let map: std::collections::BTreeMap<usize, usize> =
            keep.iter().enumerate().map(|(new, &old)| (old, new)).collect();
        let events = self
            .events
            .iter()
            .filter_map(|e| {
                map.get(&e.model_idx).map(|&m| TraceEvent { model_idx: m, ..e.clone() })
            })
            .collect();
        Trace {
            name: format!("{}-sel", self.name),
            n_models: keep.len(),
            events,
            duration: self.duration,
        }
    }

    /// True when events are non-decreasing in time. Generators uphold this
    /// by construction; the simulator's streamed-arrival cursor relies on it
    /// (and builds a sorted index when it does not hold).
    pub fn is_sorted(&self) -> bool {
        self.events.windows(2).all(|w| w[0].t <= w[1].t)
    }

    pub fn events_per_model(&self) -> Vec<usize> {
        let mut counts = vec![0usize; self.n_models];
        for e in &self.events {
            counts[e.model_idx] += 1;
        }
        counts
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn tiny() -> Trace {
        Trace {
            name: "t".into(),
            n_models: 2,
            events: vec![
                TraceEvent { t: 1.0, model_idx: 0, prompt_tokens: 10, output_tokens: 5 },
                TraceEvent { t: 2.0, model_idx: 1, prompt_tokens: 20, output_tokens: 5 },
                TraceEvent { t: 3.0, model_idx: 0, prompt_tokens: 30, output_tokens: 5 },
            ],
            duration: 10.0,
        }
    }

    #[test]
    fn scale_rate_doubles() {
        let t = tiny().scale_rate(2.0);
        assert_eq!(t.events.len(), 6);
        // Sorted by time.
        assert!(t.events.windows(2).all(|w| w[0].t <= w[1].t));
    }

    #[test]
    fn scale_rate_fractional_statistical() {
        let mut base = tiny();
        // Make a bigger base for the statistical check.
        for i in 0..1000 {
            base.events.push(TraceEvent {
                t: i as f64 * 0.01,
                model_idx: 0,
                prompt_tokens: 1,
                output_tokens: 1,
            });
        }
        let n0 = base.events.len() as f64;
        let t = base.scale_rate(1.5);
        assert!((t.events.len() as f64 / n0 - 1.5).abs() < 0.1);
    }

    #[test]
    fn sortedness_detected() {
        let mut t = tiny();
        assert!(t.is_sorted());
        t.events.swap(0, 2);
        assert!(!t.is_sorted());
    }

    #[test]
    fn window_rebases() {
        let t = tiny().window(1.5, 3.5);
        assert_eq!(t.events.len(), 2);
        assert!((t.events[0].t - 0.5).abs() < 1e-12);
        assert!((t.duration - 2.0).abs() < 1e-12);
    }

    #[test]
    fn select_models_remaps() {
        let t = tiny().select_models(&[1]);
        assert_eq!(t.n_models, 1);
        assert_eq!(t.events.len(), 1);
        assert_eq!(t.events[0].model_idx, 0);
    }
}
