//! Synthetic trace generator reproducing the paper's production statistics.
//!
//! Substitution (DESIGN.md SS2): the real Novita/Hyperbolic/Arena traces are
//! proprietary; this generator is tuned so the *published* aggregates hold:
//!   * bursty groups: 23-50% of models concurrently active on average, with
//!     54-766 active-set switches/hour (SS3.1, Fig 12a);
//!   * heterogeneous activation: a few hot always-on models (central
//!     reasoning LLMs), many warm/cold fine-tunes with sporadic bursts;
//!   * volatility: request-rate CV > 1 for many models and 40-100 idle
//!     intervals (>10 s) per hour (Fig 13);
//!   * unpredictability: near-zero day-over-day Pearson correlation (Fig 12b).
//!
//! Mechanism: each model runs an on/off renewal process (gamma busy periods,
//! Pareto idle gaps - heavy tails create long idles) modulated by a global
//! regime process that re-draws which warm models are "in the bursty group"
//! at exponentially-distributed epochs. Within a busy period, arrivals are
//! Poisson with per-burst intensity drawn lognormally (rate volatility).

use crate::trace::{Trace, TraceEvent};
use crate::util::rng::{Rng, Zipf};

#[derive(Debug, Clone)]
pub struct TraceGenConfig {
    pub name: String,
    pub n_models: usize,
    pub duration: f64,
    pub seed: u64,
    /// Fraction of models that are hot (near-continuously active).
    pub hot_frac: f64,
    /// Mean busy-period length (s) for warm models.
    pub busy_mean: f64,
    /// Pareto tail index for idle gaps (smaller = heavier tail = longer idles).
    pub idle_alpha: f64,
    /// Minimum idle gap (s).
    pub idle_min: f64,
    /// Base request rate (req/s) of the hottest model during a burst.
    pub peak_rate: f64,
    /// Zipf exponent for per-model popularity.
    pub zipf_s: f64,
    /// Mean regime (bursty-group) duration in seconds.
    pub regime_mean: f64,
    /// Fraction of warm models in the bursty group at any time.
    pub group_frac: f64,
    /// Lognormal (mu, sigma) for prompt tokens.
    pub prompt_lognorm: (f64, f64),
    /// Lognormal (mu, sigma) for output tokens.
    pub output_lognorm: (f64, f64),
}

impl TraceGenConfig {
    /// Novita-like: 16 models, >70% idle time, moderate switching (~54/hr).
    pub fn novita_like(n_models: usize, duration: f64, seed: u64) -> Self {
        TraceGenConfig {
            name: "novita-like".into(),
            n_models,
            duration,
            seed,
            hot_frac: 0.13,
            busy_mean: 90.0,
            idle_alpha: 1.1,
            idle_min: 180.0,
            peak_rate: 2.0,
            zipf_s: 1.0,
            regime_mean: 180.0,
            group_frac: 0.25,
            prompt_lognorm: (5.3, 0.8),  // median ~200 tokens
            output_lognorm: (4.6, 0.7),  // median ~100 tokens
        }
    }

    /// Hyperbolic-like: 24 models, burstier and heavier request patterns.
    pub fn hyperbolic_like(n_models: usize, duration: f64, seed: u64) -> Self {
        TraceGenConfig {
            name: "hyperbolic-like".into(),
            n_models,
            duration,
            seed,
            hot_frac: 0.10,
            busy_mean: 45.0,
            idle_alpha: 1.05,
            idle_min: 40.0,
            peak_rate: 4.0,
            zipf_s: 1.1,
            regime_mean: 120.0,
            group_frac: 0.35,
            prompt_lognorm: (5.8, 1.0),
            output_lognorm: (5.0, 0.8),
        }
    }

    /// Arena-chat-like: many models, fast active-set churn (~766 switches/hr).
    pub fn arena_chat_like(n_models: usize, duration: f64, seed: u64) -> Self {
        TraceGenConfig {
            name: "arena-chat-like".into(),
            n_models,
            duration,
            seed,
            hot_frac: 0.05,
            busy_mean: 15.0,
            idle_alpha: 1.3,
            idle_min: 90.0,
            peak_rate: 1.0,
            zipf_s: 0.8,
            regime_mean: 45.0,
            group_frac: 0.4,
            prompt_lognorm: (5.0, 0.9),
            output_lognorm: (5.2, 0.7),
        }
    }

    /// Arena-battle-like: long-horizon evaluation platform trace.
    pub fn arena_battle_like(n_models: usize, duration: f64, seed: u64) -> Self {
        TraceGenConfig {
            name: "arena-battle-like".into(),
            n_models,
            duration,
            seed,
            hot_frac: 0.08,
            busy_mean: 30.0,
            idle_alpha: 1.25,
            idle_min: 10.0,
            peak_rate: 0.8,
            zipf_s: 0.9,
            regime_mean: 90.0,
            group_frac: 0.35,
            prompt_lognorm: (5.1, 0.9),
            output_lognorm: (5.1, 0.8),
        }
    }
}

pub fn generate(cfg: &TraceGenConfig) -> Trace {
    let mut root = Rng::new(cfg.seed);
    let zipf = Zipf::new(cfg.n_models, cfg.zipf_s);
    let n_hot = ((cfg.n_models as f64 * cfg.hot_frac).round() as usize).max(1);

    // Regime process: which warm models are in the bursty group, re-drawn at
    // exponential epochs. Membership biases busy-period starts.
    let mut regime_rng = root.fork(0xE9);
    let mut regimes: Vec<(f64, Vec<bool>)> = Vec::new();
    let mut t = 0.0;
    while t < cfg.duration {
        let mut members = vec![false; cfg.n_models];
        let k = ((cfg.n_models - n_hot) as f64 * cfg.group_frac).round() as usize;
        for idx in regime_rng.sample_indices(cfg.n_models - n_hot, k) {
            members[n_hot + idx] = true;
        }
        regimes.push((t, members));
        t += regime_rng.exp(1.0 / cfg.regime_mean);
    }
    let regime_at = |time: f64| -> &Vec<bool> {
        let i = regimes.partition_point(|(t0, _)| *t0 <= time);
        &regimes[i.saturating_sub(1)].1
    };

    let mut events: Vec<TraceEvent> = Vec::new();
    for m in 0..cfg.n_models {
        let mut rng = root.fork(m as u64 + 1);
        let hot = m < n_hot;
        // Popularity scales this model's in-burst intensity.
        let pop = zipf.pmf(m) * cfg.n_models as f64; // ~1.0 on average
        let base_rate = cfg.peak_rate * pop.max(0.02);

        let mut t = rng.range_f64(0.0, if hot { 5.0 } else { cfg.idle_min });
        while t < cfg.duration {
            // Busy period.
            let busy_len = if hot {
                rng.gamma(4.0, cfg.busy_mean) // long sustained activity
            } else {
                rng.gamma(1.5, cfg.busy_mean / 1.5)
            };
            // Burst intensity varies per burst (rate volatility, CV > 1).
            let intensity = base_rate * rng.lognormal(0.0, 0.8);
            let busy_end = (t + busy_len).min(cfg.duration);
            while t < busy_end {
                let gap = rng.exp(intensity.max(1e-4));
                t += gap;
                if t >= busy_end {
                    break;
                }
                let prompt = rng
                    .lognormal(cfg.prompt_lognorm.0, cfg.prompt_lognorm.1)
                    .clamp(8.0, 8192.0) as u32;
                let output = rng
                    .lognormal(cfg.output_lognorm.0, cfg.output_lognorm.1)
                    .clamp(4.0, 4096.0) as u32;
                events.push(TraceEvent {
                    t,
                    model_idx: m,
                    prompt_tokens: prompt,
                    output_tokens: output,
                });
            }
            t = busy_end;
            if hot {
                // Hot models take only brief pauses.
                t += rng.exp(1.0 / (cfg.idle_min * 0.5 + 1.0));
            } else {
                // Warm/cold: heavy-tailed idle; models outside the current
                // bursty group stay idle longer (group membership check).
                let mut idle = rng.pareto(cfg.idle_min, cfg.idle_alpha);
                // Retry-bias: if the model is in the current regime's group,
                // shorten the idle so its bursts align with the group.
                if *regime_at(t).get(m).unwrap_or(&false) {
                    idle = idle.min(rng.range_f64(cfg.idle_min * 1.5, cfg.idle_min * 6.0));
                }
                t += idle;
            }
        }
    }

    // INVARIANT: event times are finite sums of finite inter-arrival and
    // idle samples, so partial_cmp is total.
    events.sort_by(|a, b| a.t.partial_cmp(&b.t).unwrap());
    Trace { name: cfg.name.clone(), n_models: cfg.n_models, events, duration: cfg.duration }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::trace::stats;

    #[test]
    fn deterministic_by_seed() {
        let cfg = TraceGenConfig::novita_like(8, 1800.0, 7);
        let a = generate(&cfg);
        let b = generate(&cfg);
        assert_eq!(a.events.len(), b.events.len());
        assert_eq!(a.events.first(), b.events.first());
        let c = generate(&TraceGenConfig::novita_like(8, 1800.0, 8));
        assert_ne!(a.events.len(), c.events.len());
    }

    #[test]
    fn novita_like_statistics_match_paper() {
        let cfg = TraceGenConfig::novita_like(16, 4.0 * 3600.0, 42);
        let t = generate(&cfg);
        assert!(t.events.len() > 1000, "len={}", t.events.len());

        // SS3.1: models idle >70% of the time on average (2-min activity cells).
        let idle_frac = stats::mean_idle_fraction(&t, 120.0);
        assert!(idle_frac > 0.55, "idle_frac={idle_frac}");

        // SS3.1: 23-50% concurrently active on average.
        let active_frac = stats::mean_active_fraction(&t, 120.0);
        assert!((0.10..=0.55).contains(&active_frac), "active_frac={active_frac}");

        // Fig 12a: tens of switches per hour.
        let sw = stats::switches_per_hour(&t, 120.0);
        assert!(sw > 20.0 && sw < 2000.0, "switches/hr={sw}");

        // Fig 13b: many models with CV > 1 over per-minute rates.
        let cvs = stats::per_model_rate_cv(&t, 60.0);
        let n_volatile = cvs.iter().filter(|&&c| c > 1.0).count();
        assert!(n_volatile * 2 >= cvs.len(), "volatile {n_volatile}/{}", cvs.len());
    }

    #[test]
    fn hot_models_dominate_volume() {
        let cfg = TraceGenConfig::novita_like(16, 7200.0, 1);
        let t = generate(&cfg);
        let counts = t.events_per_model();
        let hot: usize = counts[..2].iter().sum();
        let total: usize = counts.iter().sum();
        assert!(hot as f64 / total as f64 > 0.2, "hot frac {}", hot as f64 / total as f64);
        // But tail models do appear.
        assert!(counts[8..].iter().filter(|&&c| c > 0).count() >= 4);
    }

    #[test]
    fn arena_chat_churns_faster_than_novita() {
        let nov = generate(&TraceGenConfig::novita_like(16, 7200.0, 3));
        let arena = generate(&TraceGenConfig::arena_chat_like(16, 7200.0, 3));
        let sw_n = stats::switches_per_hour(&nov, 120.0);
        let sw_a = stats::switches_per_hour(&arena, 120.0);
        assert!(sw_a > sw_n, "arena {sw_a} <= novita {sw_n}");
    }

    #[test]
    fn day_over_day_unpredictable() {
        // Two days with different seeds = different realizations; the paper's
        // Fig 12b near-zero Pearson corresponds to no daily periodicity.
        let d1 = generate(&TraceGenConfig::novita_like(12, 6.0 * 3600.0, 100));
        let d2 = generate(&TraceGenConfig::novita_like(12, 6.0 * 3600.0, 101));
        let cors = stats::day_over_day_pearson(&d1, &d2, 600.0);
        let mean_abs: f64 =
            cors.iter().map(|c| c.abs()).sum::<f64>() / cors.len().max(1) as f64;
        assert!(mean_abs < 0.45, "mean |pearson| = {mean_abs}");
    }
}
