//! Trace statistics from the paper's SS3 / Appendix A.1 characterization:
//! activity cells (Fig 1a), active-set switches (Fig 12a), day-over-day
//! Pearson (Fig 12b), idle intervals (Fig 13a), request-rate CV (Fig 13b).

use crate::trace::Trace;
use crate::util::stats::{cv, pearson};

/// Activity matrix: `cells[m][i]` = true if model m received >=1 request in
/// cell i of width `cell_seconds` (Fig 1a's dark/light shading).
pub fn activity_matrix(trace: &Trace, cell_seconds: f64) -> Vec<Vec<bool>> {
    let n_cells = (trace.duration / cell_seconds).ceil() as usize;
    let mut cells = vec![vec![false; n_cells]; trace.n_models];
    for e in &trace.events {
        let c = ((e.t / cell_seconds) as usize).min(n_cells.saturating_sub(1));
        cells[e.model_idx][c] = true;
    }
    cells
}

/// Mean fraction of models active per cell (paper: 23-50%).
pub fn mean_active_fraction(trace: &Trace, cell_seconds: f64) -> f64 {
    let m = activity_matrix(trace, cell_seconds);
    if m.is_empty() || m[0].is_empty() {
        return 0.0;
    }
    let n_cells = m[0].len();
    let mut acc = 0.0;
    for c in 0..n_cells {
        let active = m.iter().filter(|row| row[c]).count();
        acc += active as f64 / m.len() as f64;
    }
    acc / n_cells as f64
}

/// Mean fraction of time a model is idle (paper: >70% for Novita).
pub fn mean_idle_fraction(trace: &Trace, cell_seconds: f64) -> f64 {
    1.0 - mean_active_fraction(trace, cell_seconds)
}

/// Active-set switches per hour (Fig 12a): a switch is counted whenever the
/// set of active models (>=1 request in the past `window` seconds) changes,
/// evaluated at event granularity.
pub fn switches_per_hour(trace: &Trace, window: f64) -> f64 {
    if trace.events.is_empty() || trace.duration <= 0.0 {
        return 0.0;
    }
    // Sweep: for each model, activity intervals [t, t+window) per event; the
    // active set changes at event times and at expiry boundaries. Evaluate on
    // a fine grid for robustness.
    let step = (window / 40.0).max(1.0);
    let n_steps = (trace.duration / step) as usize;
    let mut last_expiry = vec![f64::NEG_INFINITY; trace.n_models];
    let mut set_prev: Vec<bool> = vec![false; trace.n_models];
    let mut switches = 0u64;
    let mut ei = 0;
    for s in 0..n_steps {
        let now = s as f64 * step;
        while ei < trace.events.len() && trace.events[ei].t <= now {
            let e = &trace.events[ei];
            last_expiry[e.model_idx] = last_expiry[e.model_idx].max(e.t + window);
            ei += 1;
        }
        let set_now: Vec<bool> = last_expiry.iter().map(|&x| x > now).collect();
        if set_now != set_prev {
            switches += 1;
            set_prev = set_now;
        }
    }
    switches as f64 / (trace.duration / 3600.0)
}

/// Per-model idle intervals (> `min_gap` seconds) per hour (Fig 13a).
pub fn per_model_idle_intervals_per_hour(trace: &Trace, min_gap: f64) -> Vec<f64> {
    let hours = trace.duration / 3600.0;
    let mut last: Vec<Option<f64>> = vec![None; trace.n_models];
    let mut counts = vec![0usize; trace.n_models];
    for e in &trace.events {
        if let Some(prev) = last[e.model_idx] {
            if e.t - prev > min_gap {
                counts[e.model_idx] += 1;
            }
        }
        last[e.model_idx] = Some(e.t);
    }
    counts.iter().map(|&c| c as f64 / hours.max(1e-9)).collect()
}

/// Per-model CV of requests-per-bucket (Fig 13b; bucket = 60 s in the paper).
pub fn per_model_rate_cv(trace: &Trace, bucket_seconds: f64) -> Vec<f64> {
    let n_buckets = (trace.duration / bucket_seconds).ceil() as usize;
    let mut series = vec![vec![0.0f64; n_buckets]; trace.n_models];
    for e in &trace.events {
        let b = ((e.t / bucket_seconds) as usize).min(n_buckets.saturating_sub(1));
        series[e.model_idx][b] += 1.0;
    }
    series
        .iter()
        .filter(|s| s.iter().sum::<f64>() > 0.0)
        .map(|s| cv(s))
        .collect()
}

/// Day-over-day Pearson correlation per model (Fig 12b): correlate each
/// model's request-rate series across two traces (two "days") bucketed at
/// `bucket_seconds`.
pub fn day_over_day_pearson(day1: &Trace, day2: &Trace, bucket_seconds: f64) -> Vec<f64> {
    assert_eq!(day1.n_models, day2.n_models);
    let dur = day1.duration.min(day2.duration);
    let n_buckets = (dur / bucket_seconds).floor() as usize;
    let mut out = Vec::new();
    for m in 0..day1.n_models {
        let mut s1 = vec![0.0; n_buckets];
        let mut s2 = vec![0.0; n_buckets];
        for e in day1.events.iter().filter(|e| e.model_idx == m) {
            let b = (e.t / bucket_seconds) as usize;
            if b < n_buckets {
                s1[b] += 1.0;
            }
        }
        for e in day2.events.iter().filter(|e| e.model_idx == m) {
            let b = (e.t / bucket_seconds) as usize;
            if b < n_buckets {
                s2[b] += 1.0;
            }
        }
        if s1.iter().sum::<f64>() > 0.0 && s2.iter().sum::<f64>() > 0.0 {
            out.push(pearson(&s1, &s2));
        }
    }
    out
}

/// Per-model normalized request-rate heat rows (Fig 1b): rates bucketed and
/// normalized to each model's max.
pub fn normalized_rate_rows(trace: &Trace, bucket_seconds: f64) -> Vec<Vec<f64>> {
    let n_buckets = (trace.duration / bucket_seconds).ceil() as usize;
    let mut rows = vec![vec![0.0f64; n_buckets]; trace.n_models];
    for e in &trace.events {
        let b = ((e.t / bucket_seconds) as usize).min(n_buckets.saturating_sub(1));
        rows[e.model_idx][b] += 1.0;
    }
    for row in &mut rows {
        let mx = row.iter().cloned().fold(0.0, f64::max);
        if mx > 0.0 {
            for v in row.iter_mut() {
                *v /= mx;
            }
        }
    }
    rows
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::trace::TraceEvent;

    fn mk(events: Vec<(f64, usize)>, n_models: usize, duration: f64) -> Trace {
        Trace {
            name: "t".into(),
            n_models,
            events: events
                .into_iter()
                .map(|(t, m)| TraceEvent { t, model_idx: m, prompt_tokens: 10, output_tokens: 5 })
                .collect(),
            duration,
        }
    }

    #[test]
    fn activity_matrix_marks_cells() {
        let t = mk(vec![(5.0, 0), (125.0, 1)], 2, 240.0);
        let m = activity_matrix(&t, 120.0);
        assert_eq!(m[0], vec![true, false]);
        assert_eq!(m[1], vec![false, true]);
    }

    #[test]
    fn active_fraction_half() {
        let t = mk(vec![(5.0, 0), (125.0, 0)], 2, 240.0);
        // model 0 active in both cells, model 1 never -> 50%.
        assert!((mean_active_fraction(&t, 120.0) - 0.5).abs() < 1e-9);
    }

    #[test]
    fn switches_counted() {
        // Model 0 active early, model 1 later: at least 2 set changes.
        let t = mk(vec![(10.0, 0), (1000.0, 1)], 2, 3600.0);
        let sw = switches_per_hour(&t, 120.0);
        assert!(sw >= 2.0, "sw={sw}");
    }

    #[test]
    fn idle_intervals_per_model() {
        let t = mk(vec![(0.0, 0), (100.0, 0), (105.0, 0), (3600.0, 0)], 1, 3600.0);
        let v = per_model_idle_intervals_per_hour(&t, 10.0);
        // gaps: 100 (counted), 5 (no), 3495 (counted) => 2 per hour.
        assert!((v[0] - 2.0).abs() < 1e-9);
    }

    #[test]
    fn cv_zero_for_constant_rate() {
        let events: Vec<(f64, usize)> = (0..60).map(|i| (i as f64 * 60.0 + 1.0, 0)).collect();
        let t = mk(events, 1, 3600.0);
        let cvs = per_model_rate_cv(&t, 60.0);
        assert!(cvs[0] < 0.2, "cv={}", cvs[0]);
    }

    #[test]
    fn pearson_identical_days_is_one() {
        let d = mk(vec![(10.0, 0), (500.0, 0), (1000.0, 0)], 1, 3600.0);
        let cors = day_over_day_pearson(&d, &d, 600.0);
        assert!((cors[0] - 1.0).abs() < 1e-9);
    }

    #[test]
    fn normalized_rows_max_one() {
        let t = mk(vec![(1.0, 0), (2.0, 0), (700.0, 0)], 1, 1200.0);
        let rows = normalized_rate_rows(&t, 600.0);
        assert_eq!(rows[0], vec![1.0, 0.5]);
    }
}
