//! Infrastructure utilities (the offline environment vendors no serde/clap/
//! criterion/proptest, so Prism ships its own minimal equivalents).

pub mod cli;
pub mod json;
pub mod logger;
pub mod parallelism;
pub mod prop;
pub mod rng;
pub mod stats;

pub use parallelism::parallelism;
