//! Descriptive statistics used by trace analysis, metrics, and benches:
//! mean/std/CV, percentiles, Pearson correlation, histograms, and a
//! streaming reservoir-free percentile recorder.

/// Mean of a slice (0.0 for empty).
pub fn mean(xs: &[f64]) -> f64 {
    if xs.is_empty() {
        return 0.0;
    }
    xs.iter().sum::<f64>() / xs.len() as f64
}

/// Population standard deviation.
pub fn std_dev(xs: &[f64]) -> f64 {
    if xs.len() < 2 {
        return 0.0;
    }
    let m = mean(xs);
    (xs.iter().map(|x| (x - m).powi(2)).sum::<f64>() / xs.len() as f64).sqrt()
}

/// Coefficient of variation sigma/mu (paper Fig 13b). 0 when mean is 0.
pub fn cv(xs: &[f64]) -> f64 {
    let m = mean(xs);
    if m.abs() < 1e-12 {
        0.0
    } else {
        std_dev(xs) / m
    }
}

/// Percentile via linear interpolation on a sorted copy; p in [0, 100].
pub fn percentile(xs: &[f64], p: f64) -> f64 {
    if xs.is_empty() {
        return 0.0;
    }
    let mut v: Vec<f64> = xs.to_vec();
    v.sort_by(|a, b| a.partial_cmp(b).unwrap());
    percentile_sorted(&v, p)
}

/// Percentile of an already-sorted slice.
pub fn percentile_sorted(v: &[f64], p: f64) -> f64 {
    if v.is_empty() {
        return 0.0;
    }
    let rank = (p / 100.0) * (v.len() - 1) as f64;
    let lo = rank.floor() as usize;
    let hi = rank.ceil() as usize;
    if lo == hi {
        v[lo]
    } else {
        let w = rank - lo as f64;
        v[lo] * (1.0 - w) + v[hi] * w
    }
}

/// Pearson correlation coefficient (paper Fig 12b day-over-day predictability).
/// Returns 0.0 when either series is constant.
pub fn pearson(xs: &[f64], ys: &[f64]) -> f64 {
    assert_eq!(xs.len(), ys.len());
    let n = xs.len();
    if n < 2 {
        return 0.0;
    }
    let mx = mean(xs);
    let my = mean(ys);
    let mut cov = 0.0;
    let mut vx = 0.0;
    let mut vy = 0.0;
    for i in 0..n {
        let dx = xs[i] - mx;
        let dy = ys[i] - my;
        cov += dx * dy;
        vx += dx * dx;
        vy += dy * dy;
    }
    if vx < 1e-24 || vy < 1e-24 {
        return 0.0;
    }
    cov / (vx.sqrt() * vy.sqrt())
}

/// Fixed-width histogram over [lo, hi) with n bins; out-of-range clamps.
pub fn histogram(xs: &[f64], lo: f64, hi: f64, bins: usize) -> Vec<usize> {
    let mut h = vec![0usize; bins];
    if bins == 0 || hi <= lo {
        return h;
    }
    let w = (hi - lo) / bins as f64;
    for &x in xs {
        let mut b = ((x - lo) / w) as isize;
        b = b.clamp(0, bins as isize - 1);
        h[b as usize] += 1;
    }
    h
}

/// Accumulates samples and reports summary stats; used by metrics and benches.
#[derive(Debug, Default, Clone)]
pub struct Summary {
    samples: Vec<f64>,
    sorted: bool,
}

impl Summary {
    pub fn new() -> Self {
        Self::default()
    }

    pub fn add(&mut self, x: f64) {
        self.samples.push(x);
        self.sorted = false;
    }

    pub fn extend(&mut self, xs: &[f64]) {
        self.samples.extend_from_slice(xs);
        self.sorted = false;
    }

    pub fn len(&self) -> usize {
        self.samples.len()
    }

    pub fn is_empty(&self) -> bool {
        self.samples.is_empty()
    }

    fn ensure_sorted(&mut self) {
        if !self.sorted {
            self.samples.sort_by(|a, b| a.partial_cmp(b).unwrap());
            self.sorted = true;
        }
    }

    pub fn mean(&self) -> f64 {
        mean(&self.samples)
    }

    pub fn p(&mut self, pct: f64) -> f64 {
        self.ensure_sorted();
        percentile_sorted(&self.samples, pct)
    }

    pub fn min(&mut self) -> f64 {
        self.ensure_sorted();
        self.samples.first().copied().unwrap_or(0.0)
    }

    pub fn max(&mut self) -> f64 {
        self.ensure_sorted();
        self.samples.last().copied().unwrap_or(0.0)
    }

    /// Fraction of samples <= threshold (SLO attainment).
    pub fn frac_le(&self, thr: f64) -> f64 {
        if self.samples.is_empty() {
            return 1.0;
        }
        self.samples.iter().filter(|&&x| x <= thr).count() as f64 / self.samples.len() as f64
    }

    pub fn samples(&self) -> &[f64] {
        &self.samples
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn mean_std_cv() {
        let xs = [2.0, 4.0, 4.0, 4.0, 5.0, 5.0, 7.0, 9.0];
        assert!((mean(&xs) - 5.0).abs() < 1e-12);
        assert!((std_dev(&xs) - 2.0).abs() < 1e-12);
        assert!((cv(&xs) - 0.4).abs() < 1e-12);
        assert_eq!(mean(&[]), 0.0);
        assert_eq!(cv(&[0.0, 0.0]), 0.0);
    }

    #[test]
    fn percentiles() {
        let xs = [1.0, 2.0, 3.0, 4.0, 5.0];
        assert_eq!(percentile(&xs, 0.0), 1.0);
        assert_eq!(percentile(&xs, 50.0), 3.0);
        assert_eq!(percentile(&xs, 100.0), 5.0);
        assert_eq!(percentile(&xs, 25.0), 2.0);
        // interpolation
        let ys = [0.0, 10.0];
        assert_eq!(percentile(&ys, 95.0), 9.5);
        assert_eq!(percentile(&[], 50.0), 0.0);
    }

    #[test]
    fn pearson_basics() {
        let xs = [1.0, 2.0, 3.0, 4.0];
        let ys = [2.0, 4.0, 6.0, 8.0];
        assert!((pearson(&xs, &ys) - 1.0).abs() < 1e-12);
        let zs = [8.0, 6.0, 4.0, 2.0];
        assert!((pearson(&xs, &zs) + 1.0).abs() < 1e-12);
        assert_eq!(pearson(&xs, &[5.0, 5.0, 5.0, 5.0]), 0.0);
    }

    #[test]
    fn pearson_uncorrelated_near_zero() {
        use crate::util::rng::Rng;
        let mut r = Rng::new(1);
        let xs: Vec<f64> = (0..5000).map(|_| r.f64()).collect();
        let ys: Vec<f64> = (0..5000).map(|_| r.f64()).collect();
        assert!(pearson(&xs, &ys).abs() < 0.05);
    }

    #[test]
    fn histogram_clamps() {
        let h = histogram(&[-1.0, 0.5, 1.5, 99.0], 0.0, 2.0, 2);
        assert_eq!(h, vec![2, 2]);
    }

    #[test]
    fn summary_attainment() {
        let mut s = Summary::new();
        s.extend(&[1.0, 2.0, 3.0, 4.0]);
        assert_eq!(s.frac_le(2.5), 0.5);
        assert_eq!(s.frac_le(0.5), 0.0);
        assert_eq!(s.frac_le(4.0), 1.0);
        assert_eq!(s.p(50.0), 2.5);
        assert_eq!(s.min(), 1.0);
        assert_eq!(s.max(), 4.0);
        assert_eq!(Summary::new().frac_le(1.0), 1.0);
    }
}
