//! Minimal property-based testing framework (no proptest in the vendor set).
//!
//! `check(cases, seed, gen, prop)` runs `prop` on `cases` generated inputs;
//! on failure it greedily shrinks via the input's `Shrink` implementation and
//! panics with the minimal counterexample. Generators are plain closures over
//! `Rng`, so any domain type can be generated inline.

use crate::util::rng::Rng;

/// Types that can propose smaller versions of themselves for shrinking.
pub trait Shrink: Sized + Clone + std::fmt::Debug {
    /// Candidate strictly-smaller values, in decreasing order of aggressiveness.
    fn shrink(&self) -> Vec<Self> {
        Vec::new()
    }
}

impl Shrink for u8 {
    fn shrink(&self) -> Vec<Self> {
        let mut out = Vec::new();
        if *self > 0 {
            out.push(0);
            out.push(self / 2);
            out.push(self - 1);
        }
        out.dedup();
        out
    }
}

impl Shrink for usize {
    fn shrink(&self) -> Vec<Self> {
        let mut out = Vec::new();
        if *self > 0 {
            out.push(0);
            out.push(self / 2);
            out.push(self - 1);
        }
        out.dedup();
        out
    }
}

impl Shrink for u64 {
    fn shrink(&self) -> Vec<Self> {
        let mut out = Vec::new();
        if *self > 0 {
            out.push(0);
            out.push(self / 2);
            out.push(self - 1);
        }
        out.dedup();
        out
    }
}

impl Shrink for f64 {
    fn shrink(&self) -> Vec<Self> {
        let mut out = Vec::new();
        if self.abs() > 1e-9 {
            out.push(0.0);
            out.push(self / 2.0);
        }
        out
    }
}

impl<T: Shrink> Shrink for Vec<T> {
    fn shrink(&self) -> Vec<Self> {
        let mut out = Vec::new();
        if self.is_empty() {
            return out;
        }
        // Remove halves, then single elements, then shrink one element.
        out.push(self[..self.len() / 2].to_vec());
        out.push(self[self.len() / 2..].to_vec());
        if self.len() > 1 {
            let mut v = self.clone();
            v.pop();
            out.push(v);
            let mut v = self.clone();
            v.remove(0);
            out.push(v);
        }
        for (i, x) in self.iter().enumerate() {
            for sx in x.shrink().into_iter().take(2) {
                let mut v = self.clone();
                v[i] = sx;
                out.push(v);
            }
        }
        out
    }
}

impl<A: Shrink, B: Shrink> Shrink for (A, B) {
    fn shrink(&self) -> Vec<Self> {
        let mut out: Vec<Self> = self.0.shrink().into_iter().map(|a| (a, self.1.clone())).collect();
        out.extend(self.1.shrink().into_iter().map(|b| (self.0.clone(), b)));
        out
    }
}

impl<A: Shrink, B: Shrink, C: Shrink> Shrink for (A, B, C) {
    fn shrink(&self) -> Vec<Self> {
        let mut out: Vec<Self> = self
            .0
            .shrink()
            .into_iter()
            .map(|a| (a, self.1.clone(), self.2.clone()))
            .collect();
        out.extend(self.1.shrink().into_iter().map(|b| (self.0.clone(), b, self.2.clone())));
        out.extend(self.2.shrink().into_iter().map(|c| (self.0.clone(), self.1.clone(), c)));
        out
    }
}

/// Run `prop` on `cases` inputs drawn by `gen`; shrink + panic on failure.
pub fn check<T, G, P>(cases: usize, seed: u64, mut gen: G, prop: P)
where
    T: Shrink,
    G: FnMut(&mut Rng) -> T,
    P: Fn(&T) -> Result<(), String>,
{
    let mut rng = Rng::new(seed);
    for case in 0..cases {
        let input = gen(&mut rng);
        if let Err(msg) = prop(&input) {
            let (min_input, min_msg) = shrink_loop(input, msg, &prop);
            panic!(
                "property failed (case {case}/{cases}, seed {seed})\n  minimal input: {:?}\n  error: {}",
                min_input, min_msg
            );
        }
    }
}

fn shrink_loop<T: Shrink, P: Fn(&T) -> Result<(), String>>(
    mut cur: T,
    mut msg: String,
    prop: &P,
) -> (T, String) {
    // Bounded greedy descent.
    for _ in 0..200 {
        let mut advanced = false;
        for cand in cur.shrink() {
            if let Err(m) = prop(&cand) {
                cur = cand;
                msg = m;
                advanced = true;
                break;
            }
        }
        if !advanced {
            break;
        }
    }
    (cur, msg)
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn passing_property() {
        check(200, 1, |r| r.below(100), |&x| {
            if x < 100 {
                Ok(())
            } else {
                Err("out of range".into())
            }
        });
    }

    #[test]
    #[should_panic(expected = "minimal input: 10")]
    fn shrinks_to_boundary() {
        // Fails for x >= 10; shrinker should land exactly on 10.
        check(500, 2, |r| r.below(1000), |&x| {
            if x < 10 {
                Ok(())
            } else {
                Err(format!("{x} too big"))
            }
        });
    }

    #[test]
    fn vec_shrink_produces_smaller() {
        let v = vec![3usize, 4, 5];
        assert!(v
            .shrink()
            .iter()
            .all(|s| s.len() < v.len() || s.iter().sum::<usize>() <= v.iter().sum::<usize>()));
    }
}
