//! Shared auto-parallelism detection for every `0 = auto` worker knob.
//!
//! Two knobs fan work across threads: the sweep engine's `--jobs` (workers
//! across `SweepPoint`s) and the simulator's `--shards` (GPU-group shards
//! inside one run). Both treat `0` as "auto"; both MUST resolve "auto" the
//! same way, or the two knobs drift (e.g. one honoring `PRISM_JOBS`, the
//! other not). This module is the single resolution point.

/// Worker/shard count used when a caller passes `0 = auto`: the
/// `PRISM_JOBS` env var if set to a positive integer, else the machine's
/// available parallelism (1 if that cannot be determined).
pub fn parallelism() -> usize {
    // lint:allow(D1): PRISM_JOBS only picks worker counts; results are
    // worker-count-invariant by the sweep determinism contract.
    std::env::var("PRISM_JOBS")
        .ok()
        .and_then(|s| s.parse::<usize>().ok())
        .filter(|&n| n > 0)
        .unwrap_or_else(|| {
            std::thread::available_parallelism().map(|n| n.get()).unwrap_or(1)
        })
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn parallelism_is_positive() {
        assert!(parallelism() >= 1);
    }

    #[test]
    fn sweep_default_jobs_delegates_here() {
        // The two auto knobs must resolve identically (no drift): the sweep
        // engine's default is this helper, observed under whatever
        // PRISM_JOBS environment the test process happens to run in.
        assert_eq!(parallelism(), crate::sweep::default_jobs());
    }
}
