//! Deterministic PRNG + distributions for trace synthesis and simulation.
//!
//! SplitMix64 core (fast, well-distributed, trivially seedable) with the
//! distributions the trace generator needs: uniform, exponential (Poisson
//! inter-arrivals), gamma (burst sizes), Pareto (heavy-tailed idle gaps),
//! Zipf (model popularity), lognormal (request lengths), and categorical.

/// SplitMix64: passes BigCrush, one u64 of state, splittable by reseeding.
#[derive(Debug, Clone)]
pub struct Rng {
    state: u64,
}

impl Rng {
    pub fn new(seed: u64) -> Self {
        // Avoid the all-zeros fixpoint neighborhood by pre-mixing.
        let mut r = Rng { state: seed.wrapping_add(0x9E37_79B9_7F4A_7C15) };
        r.next_u64();
        r
    }

    /// Derive an independent stream (e.g., one per model) from this one.
    pub fn fork(&mut self, salt: u64) -> Rng {
        Rng::new(self.next_u64() ^ salt.wrapping_mul(0xBF58_476D_1CE4_E5B9))
    }

    pub fn next_u64(&mut self) -> u64 {
        self.state = self.state.wrapping_add(0x9E37_79B9_7F4A_7C15);
        let mut z = self.state;
        z = (z ^ (z >> 30)).wrapping_mul(0xBF58_476D_1CE4_E5B9);
        z = (z ^ (z >> 27)).wrapping_mul(0x94D0_49BB_1331_11EB);
        z ^ (z >> 31)
    }

    /// Uniform in [0, 1).
    pub fn f64(&mut self) -> f64 {
        (self.next_u64() >> 11) as f64 * (1.0 / (1u64 << 53) as f64)
    }

    /// Uniform integer in [0, n).
    pub fn below(&mut self, n: usize) -> usize {
        debug_assert!(n > 0);
        // Lemire-style rejection-free mapping is fine here (non-crypto).
        ((self.next_u64() as u128 * n as u128) >> 64) as usize
    }

    /// Uniform in [lo, hi).
    pub fn range_f64(&mut self, lo: f64, hi: f64) -> f64 {
        lo + self.f64() * (hi - lo)
    }

    pub fn range_usize(&mut self, lo: usize, hi: usize) -> usize {
        debug_assert!(hi > lo);
        lo + self.below(hi - lo)
    }

    pub fn bool(&mut self, p: f64) -> bool {
        self.f64() < p
    }

    /// Exponential with rate lambda (mean 1/lambda).
    pub fn exp(&mut self, lambda: f64) -> f64 {
        debug_assert!(lambda > 0.0);
        let u = 1.0 - self.f64(); // (0, 1]
        -u.ln() / lambda
    }

    /// Standard normal via Box-Muller.
    pub fn normal(&mut self) -> f64 {
        let u1 = 1.0 - self.f64();
        let u2 = self.f64();
        (-2.0 * u1.ln()).sqrt() * (2.0 * std::f64::consts::PI * u2).cos()
    }

    /// Lognormal with given log-space mu/sigma.
    pub fn lognormal(&mut self, mu: f64, sigma: f64) -> f64 {
        (mu + sigma * self.normal()).exp()
    }

    /// Gamma(shape k, scale theta) via Marsaglia-Tsang (k >= 1) with boost for k < 1.
    pub fn gamma(&mut self, k: f64, theta: f64) -> f64 {
        debug_assert!(k > 0.0 && theta > 0.0);
        if k < 1.0 {
            // Boost: Gamma(k) = Gamma(k+1) * U^(1/k)
            let g = self.gamma(k + 1.0, 1.0);
            let u = 1.0 - self.f64();
            return g * u.powf(1.0 / k) * theta;
        }
        let d = k - 1.0 / 3.0;
        let c = 1.0 / (9.0 * d).sqrt();
        loop {
            let x = self.normal();
            let v = (1.0 + c * x).powi(3);
            if v <= 0.0 {
                continue;
            }
            let u = self.f64();
            if u < 1.0 - 0.0331 * x.powi(4)
                || u.ln() < 0.5 * x * x + d * (1.0 - v + v.ln())
            {
                return d * v * theta;
            }
        }
    }

    /// Pareto with minimum xm and tail index alpha (heavy-tailed idle gaps).
    pub fn pareto(&mut self, xm: f64, alpha: f64) -> f64 {
        let u = 1.0 - self.f64();
        xm / u.powf(1.0 / alpha)
    }

    /// Sample an index from unnormalized weights.
    pub fn categorical(&mut self, weights: &[f64]) -> usize {
        let total: f64 = weights.iter().sum();
        debug_assert!(total > 0.0);
        let mut x = self.f64() * total;
        for (i, w) in weights.iter().enumerate() {
            x -= w;
            if x <= 0.0 {
                return i;
            }
        }
        weights.len() - 1
    }

    /// Fisher-Yates shuffle.
    pub fn shuffle<T>(&mut self, xs: &mut [T]) {
        for i in (1..xs.len()).rev() {
            let j = self.below(i + 1);
            xs.swap(i, j);
        }
    }

    /// Sample k distinct indices from [0, n).
    pub fn sample_indices(&mut self, n: usize, k: usize) -> Vec<usize> {
        let mut idx: Vec<usize> = (0..n).collect();
        self.shuffle(&mut idx);
        idx.truncate(k.min(n));
        idx
    }
}

/// Zipf distribution over ranks 1..=n with exponent s (model popularity).
#[derive(Debug, Clone)]
pub struct Zipf {
    cdf: Vec<f64>,
}

impl Zipf {
    pub fn new(n: usize, s: f64) -> Self {
        let mut cdf = Vec::with_capacity(n);
        let mut acc = 0.0;
        for r in 1..=n {
            acc += 1.0 / (r as f64).powf(s);
            cdf.push(acc);
        }
        let total = acc;
        for v in &mut cdf {
            *v /= total;
        }
        Zipf { cdf }
    }

    /// Sample a 0-based rank.
    pub fn sample(&self, rng: &mut Rng) -> usize {
        let u = rng.f64();
        self.cdf.partition_point(|&c| c < u).min(self.cdf.len() - 1)
    }

    /// Probability mass of 0-based rank r.
    pub fn pmf(&self, r: usize) -> f64 {
        if r == 0 {
            self.cdf[0]
        } else {
            self.cdf[r] - self.cdf[r - 1]
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn deterministic_and_seed_sensitive() {
        let mut a = Rng::new(7);
        let mut b = Rng::new(7);
        let mut c = Rng::new(8);
        let xs: Vec<u64> = (0..10).map(|_| a.next_u64()).collect();
        let ys: Vec<u64> = (0..10).map(|_| b.next_u64()).collect();
        let zs: Vec<u64> = (0..10).map(|_| c.next_u64()).collect();
        assert_eq!(xs, ys);
        assert_ne!(xs, zs);
    }

    #[test]
    fn f64_in_unit_interval() {
        let mut r = Rng::new(1);
        for _ in 0..10_000 {
            let x = r.f64();
            assert!((0.0..1.0).contains(&x));
        }
    }

    #[test]
    fn below_bounds_and_coverage() {
        let mut r = Rng::new(2);
        let mut seen = [false; 7];
        for _ in 0..1000 {
            seen[r.below(7)] = true;
        }
        assert!(seen.iter().all(|&s| s));
    }

    #[test]
    fn exp_mean() {
        let mut r = Rng::new(3);
        let n = 50_000;
        let mean: f64 = (0..n).map(|_| r.exp(2.0)).sum::<f64>() / n as f64;
        assert!((mean - 0.5).abs() < 0.02, "mean={mean}");
    }

    #[test]
    fn gamma_mean_var() {
        let mut r = Rng::new(4);
        let (k, theta) = (3.0, 2.0);
        let n = 50_000;
        let xs: Vec<f64> = (0..n).map(|_| r.gamma(k, theta)).collect();
        let mean = xs.iter().sum::<f64>() / n as f64;
        let var = xs.iter().map(|x| (x - mean).powi(2)).sum::<f64>() / n as f64;
        assert!((mean - k * theta).abs() < 0.15, "mean={mean}");
        assert!((var - k * theta * theta).abs() < 1.0, "var={var}");
    }

    #[test]
    fn gamma_small_shape() {
        let mut r = Rng::new(5);
        let n = 50_000;
        let mean: f64 = (0..n).map(|_| r.gamma(0.5, 1.0)).sum::<f64>() / n as f64;
        assert!((mean - 0.5).abs() < 0.03, "mean={mean}");
    }

    #[test]
    fn pareto_min_respected() {
        let mut r = Rng::new(6);
        for _ in 0..1000 {
            assert!(r.pareto(3.0, 1.5) >= 3.0);
        }
    }

    #[test]
    fn zipf_monotone_popularity() {
        let z = Zipf::new(20, 1.1);
        let mut r = Rng::new(7);
        let mut counts = [0usize; 20];
        for _ in 0..100_000 {
            counts[z.sample(&mut r)] += 1;
        }
        assert!(counts[0] > counts[5] && counts[5] > counts[19]);
        let total_pmf: f64 = (0..20).map(|i| z.pmf(i)).sum();
        assert!((total_pmf - 1.0).abs() < 1e-9);
    }

    #[test]
    fn categorical_respects_weights() {
        let mut r = Rng::new(8);
        let w = [1.0, 0.0, 3.0];
        let mut counts = [0usize; 3];
        for _ in 0..40_000 {
            counts[r.categorical(&w)] += 1;
        }
        assert_eq!(counts[1], 0);
        let ratio = counts[2] as f64 / counts[0] as f64;
        assert!((ratio - 3.0).abs() < 0.3, "ratio={ratio}");
    }

    #[test]
    fn shuffle_is_permutation() {
        let mut r = Rng::new(9);
        let mut xs: Vec<usize> = (0..50).collect();
        r.shuffle(&mut xs);
        let mut sorted = xs.clone();
        sorted.sort_unstable();
        assert_eq!(sorted, (0..50).collect::<Vec<_>>());
    }

    #[test]
    fn fork_streams_independent() {
        let mut root = Rng::new(10);
        let mut a = root.fork(1);
        let mut b = root.fork(2);
        let xs: Vec<u64> = (0..5).map(|_| a.next_u64()).collect();
        let ys: Vec<u64> = (0..5).map(|_| b.next_u64()).collect();
        assert_ne!(xs, ys);
    }
}
