//! Leveled stderr logger with relative timestamps.
//!
//! `PRISM_LOG={error|warn|info|debug|trace}` controls verbosity (default
//! info). Thread-safe; cheap when filtered (level check before formatting
//! via macros).

use std::io::Write;
use std::sync::atomic::{AtomicU8, Ordering};
use std::time::Instant;

#[derive(Debug, Clone, Copy, PartialEq, Eq, PartialOrd, Ord)]
#[repr(u8)]
pub enum Level {
    Error = 0,
    Warn = 1,
    Info = 2,
    Debug = 3,
    Trace = 4,
}

impl Level {
    pub fn tag(self) -> &'static str {
        match self {
            Level::Error => "ERROR",
            Level::Warn => "WARN ",
            Level::Info => "INFO ",
            Level::Debug => "DEBUG",
            Level::Trace => "TRACE",
        }
    }
}

static MAX_LEVEL: AtomicU8 = AtomicU8::new(2);
static START: std::sync::OnceLock<Instant> = std::sync::OnceLock::new();

/// Initialize from PRISM_LOG; idempotent.
pub fn init() {
    START.get_or_init(Instant::now);
    if let Ok(v) = std::env::var("PRISM_LOG") {
        set_level(match v.to_ascii_lowercase().as_str() {
            "error" => Level::Error,
            "warn" => Level::Warn,
            "debug" => Level::Debug,
            "trace" => Level::Trace,
            _ => Level::Info,
        });
    }
}

pub fn set_level(l: Level) {
    MAX_LEVEL.store(l as u8, Ordering::Relaxed);
}

pub fn enabled(l: Level) -> bool {
    (l as u8) <= MAX_LEVEL.load(Ordering::Relaxed)
}

pub fn log(l: Level, target: &str, msg: std::fmt::Arguments<'_>) {
    if !enabled(l) {
        return;
    }
    let t = START.get_or_init(Instant::now).elapsed();
    let mut err = std::io::stderr().lock();
    let _ = writeln!(
        err,
        "[{:>9.3}s {} {}] {}",
        t.as_secs_f64(),
        l.tag(),
        target,
        msg
    );
}

#[macro_export]
macro_rules! log_info {
    ($target:expr, $($arg:tt)*) => {
        $crate::util::logger::log($crate::util::logger::Level::Info, $target, format_args!($($arg)*))
    };
}

#[macro_export]
macro_rules! log_warn {
    ($target:expr, $($arg:tt)*) => {
        $crate::util::logger::log($crate::util::logger::Level::Warn, $target, format_args!($($arg)*))
    };
}

#[macro_export]
macro_rules! log_debug {
    ($target:expr, $($arg:tt)*) => {
        $crate::util::logger::log($crate::util::logger::Level::Debug, $target, format_args!($($arg)*))
    };
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn level_filtering() {
        init();
        set_level(Level::Warn);
        assert!(enabled(Level::Error));
        assert!(enabled(Level::Warn));
        assert!(!enabled(Level::Info));
        set_level(Level::Info);
        assert!(enabled(Level::Info));
        assert!(!enabled(Level::Debug));
    }
}
