//! Minimal JSON parser/serializer.
//!
//! The offline build environment vendors no serde, so Prism ships its own
//! JSON support: enough for artifact manifests, cluster/workload configs,
//! trace files, and experiment reports. Strict on structure, permissive on
//! whitespace; numbers are parsed as f64 (as in JavaScript).

use std::collections::BTreeMap;
use std::fmt;

/// A JSON value. Objects use BTreeMap for deterministic serialization.
#[derive(Debug, Clone, PartialEq)]
pub enum Json {
    Null,
    Bool(bool),
    Num(f64),
    Str(String),
    Arr(Vec<Json>),
    Obj(BTreeMap<String, Json>),
}

#[derive(Debug)]
pub struct JsonError {
    pub msg: String,
    pub pos: usize,
}

impl fmt::Display for JsonError {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        write!(f, "json error at byte {}: {}", self.pos, self.msg)
    }
}

impl std::error::Error for JsonError {}

impl Json {
    // ------------------------------------------------------------ accessors

    pub fn as_f64(&self) -> Option<f64> {
        match self {
            Json::Num(n) => Some(*n),
            _ => None,
        }
    }

    pub fn as_u64(&self) -> Option<u64> {
        self.as_f64().and_then(|n| {
            if n >= 0.0 && n.fract() == 0.0 && n <= u64::MAX as f64 {
                Some(n as u64)
            } else {
                None
            }
        })
    }

    pub fn as_usize(&self) -> Option<usize> {
        self.as_u64().map(|n| n as usize)
    }

    pub fn as_str(&self) -> Option<&str> {
        match self {
            Json::Str(s) => Some(s),
            _ => None,
        }
    }

    pub fn as_bool(&self) -> Option<bool> {
        match self {
            Json::Bool(b) => Some(*b),
            _ => None,
        }
    }

    pub fn as_arr(&self) -> Option<&[Json]> {
        match self {
            Json::Arr(a) => Some(a),
            _ => None,
        }
    }

    pub fn as_obj(&self) -> Option<&BTreeMap<String, Json>> {
        match self {
            Json::Obj(o) => Some(o),
            _ => None,
        }
    }

    /// Object field lookup; returns Null for missing keys on non-objects too.
    pub fn get(&self, key: &str) -> &Json {
        static NULL: Json = Json::Null;
        match self {
            Json::Obj(o) => o.get(key).unwrap_or(&NULL),
            _ => &NULL,
        }
    }

    /// `obj.get(key)` chain helper: `j.at(&["artifacts", "decode"])`.
    pub fn at(&self, path: &[&str]) -> &Json {
        let mut cur = self;
        for k in path {
            cur = cur.get(k);
        }
        cur
    }

    // --------------------------------------------------------- construction

    pub fn obj() -> Json {
        Json::Obj(BTreeMap::new())
    }

    pub fn set(&mut self, key: &str, val: Json) -> &mut Json {
        if let Json::Obj(o) = self {
            o.insert(key.to_string(), val);
        }
        self
    }

    pub fn from_f64(v: f64) -> Json {
        Json::Num(v)
    }

    // --------------------------------------------------------- serialization

    pub fn to_string_pretty(&self) -> String {
        let mut s = String::new();
        self.write(&mut s, Some(0));
        s
    }

    fn write(&self, out: &mut String, indent: Option<usize>) {
        match self {
            Json::Null => out.push_str("null"),
            Json::Bool(b) => out.push_str(if *b { "true" } else { "false" }),
            Json::Num(n) => {
                if n.fract() == 0.0 && n.abs() < 1e15 {
                    out.push_str(&format!("{}", *n as i64));
                } else {
                    out.push_str(&format!("{}", n));
                }
            }
            Json::Str(s) => write_escaped(out, s),
            Json::Arr(a) => {
                out.push('[');
                for (i, v) in a.iter().enumerate() {
                    if i > 0 {
                        out.push(',');
                    }
                    if let Some(lvl) = indent {
                        out.push('\n');
                        out.push_str(&"  ".repeat(lvl + 1));
                        v.write(out, Some(lvl + 1));
                    } else {
                        v.write(out, None);
                    }
                }
                if let (Some(lvl), false) = (indent, a.is_empty()) {
                    out.push('\n');
                    out.push_str(&"  ".repeat(lvl));
                }
                out.push(']');
            }
            Json::Obj(o) => {
                out.push('{');
                for (i, (k, v)) in o.iter().enumerate() {
                    if i > 0 {
                        out.push(',');
                    }
                    if let Some(lvl) = indent {
                        out.push('\n');
                        out.push_str(&"  ".repeat(lvl + 1));
                        write_escaped(out, k);
                        out.push_str(": ");
                        v.write(out, Some(lvl + 1));
                    } else {
                        write_escaped(out, k);
                        out.push(':');
                        v.write(out, None);
                    }
                }
                if let (Some(lvl), false) = (indent, o.is_empty()) {
                    out.push('\n');
                    out.push_str(&"  ".repeat(lvl));
                }
                out.push('}');
            }
        }
    }
}

impl fmt::Display for Json {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        let mut s = String::new();
        self.write(&mut s, None);
        f.write_str(&s)
    }
}

fn write_escaped(out: &mut String, s: &str) {
    out.push('"');
    for c in s.chars() {
        match c {
            '"' => out.push_str("\\\""),
            '\\' => out.push_str("\\\\"),
            '\n' => out.push_str("\\n"),
            '\r' => out.push_str("\\r"),
            '\t' => out.push_str("\\t"),
            c if (c as u32) < 0x20 => out.push_str(&format!("\\u{:04x}", c as u32)),
            c => out.push(c),
        }
    }
    out.push('"');
}

// ------------------------------------------------------------------ parsing

pub fn parse(text: &str) -> Result<Json, JsonError> {
    let b = text.as_bytes();
    let mut p = Parser { b, pos: 0 };
    p.skip_ws();
    let v = p.value()?;
    p.skip_ws();
    if p.pos != b.len() {
        return Err(p.err("trailing content"));
    }
    Ok(v)
}

struct Parser<'a> {
    b: &'a [u8],
    pos: usize,
}

impl<'a> Parser<'a> {
    fn err(&self, msg: &str) -> JsonError {
        JsonError { msg: msg.to_string(), pos: self.pos }
    }

    fn skip_ws(&mut self) {
        while self.pos < self.b.len() && matches!(self.b[self.pos], b' ' | b'\t' | b'\n' | b'\r') {
            self.pos += 1;
        }
    }

    fn peek(&self) -> Option<u8> {
        self.b.get(self.pos).copied()
    }

    fn expect(&mut self, c: u8) -> Result<(), JsonError> {
        if self.peek() == Some(c) {
            self.pos += 1;
            Ok(())
        } else {
            Err(self.err(&format!("expected '{}'", c as char)))
        }
    }

    fn value(&mut self) -> Result<Json, JsonError> {
        match self.peek() {
            Some(b'{') => self.object(),
            Some(b'[') => self.array(),
            Some(b'"') => Ok(Json::Str(self.string()?)),
            Some(b't') => self.lit("true", Json::Bool(true)),
            Some(b'f') => self.lit("false", Json::Bool(false)),
            Some(b'n') => self.lit("null", Json::Null),
            Some(c) if c == b'-' || c.is_ascii_digit() => self.number(),
            _ => Err(self.err("unexpected character")),
        }
    }

    fn lit(&mut self, word: &str, val: Json) -> Result<Json, JsonError> {
        if self.b[self.pos..].starts_with(word.as_bytes()) {
            self.pos += word.len();
            Ok(val)
        } else {
            Err(self.err("invalid literal"))
        }
    }

    fn number(&mut self) -> Result<Json, JsonError> {
        let start = self.pos;
        if self.peek() == Some(b'-') {
            self.pos += 1;
        }
        while matches!(
            self.peek(),
            Some(c) if c.is_ascii_digit() || matches!(c, b'.' | b'e' | b'E' | b'+' | b'-')
        ) {
            self.pos += 1;
        }
        let s = std::str::from_utf8(&self.b[start..self.pos]).map_err(|_| self.err("utf8"))?;
        s.parse::<f64>().map(Json::Num).map_err(|_| self.err("bad number"))
    }

    fn string(&mut self) -> Result<String, JsonError> {
        self.expect(b'"')?;
        let mut out = String::new();
        loop {
            match self.peek() {
                None => return Err(self.err("unterminated string")),
                Some(b'"') => {
                    self.pos += 1;
                    return Ok(out);
                }
                Some(b'\\') => {
                    self.pos += 1;
                    match self.peek() {
                        Some(b'"') => out.push('"'),
                        Some(b'\\') => out.push('\\'),
                        Some(b'/') => out.push('/'),
                        Some(b'n') => out.push('\n'),
                        Some(b't') => out.push('\t'),
                        Some(b'r') => out.push('\r'),
                        Some(b'b') => out.push('\u{8}'),
                        Some(b'f') => out.push('\u{c}'),
                        Some(b'u') => {
                            if self.pos + 4 >= self.b.len() {
                                return Err(self.err("bad \\u escape"));
                            }
                            let hex = std::str::from_utf8(&self.b[self.pos + 1..self.pos + 5])
                                .map_err(|_| self.err("bad \\u escape"))?;
                            let cp = u32::from_str_radix(hex, 16)
                                .map_err(|_| self.err("bad \\u escape"))?;
                            out.push(char::from_u32(cp).unwrap_or('\u{fffd}'));
                            self.pos += 4;
                        }
                        _ => return Err(self.err("bad escape")),
                    }
                    self.pos += 1;
                }
                Some(_) => {
                    // Copy a UTF-8 run verbatim.
                    let start = self.pos;
                    while matches!(self.peek(), Some(c) if c != b'"' && c != b'\\') {
                        self.pos += 1;
                    }
                    out.push_str(
                        std::str::from_utf8(&self.b[start..self.pos])
                            .map_err(|_| self.err("utf8"))?,
                    );
                }
            }
        }
    }

    fn array(&mut self) -> Result<Json, JsonError> {
        self.expect(b'[')?;
        let mut items = Vec::new();
        self.skip_ws();
        if self.peek() == Some(b']') {
            self.pos += 1;
            return Ok(Json::Arr(items));
        }
        loop {
            self.skip_ws();
            items.push(self.value()?);
            self.skip_ws();
            match self.peek() {
                Some(b',') => self.pos += 1,
                Some(b']') => {
                    self.pos += 1;
                    return Ok(Json::Arr(items));
                }
                _ => return Err(self.err("expected ',' or ']'")),
            }
        }
    }

    fn object(&mut self) -> Result<Json, JsonError> {
        self.expect(b'{')?;
        let mut map = BTreeMap::new();
        self.skip_ws();
        if self.peek() == Some(b'}') {
            self.pos += 1;
            return Ok(Json::Obj(map));
        }
        loop {
            self.skip_ws();
            let key = self.string()?;
            self.skip_ws();
            self.expect(b':')?;
            self.skip_ws();
            let val = self.value()?;
            map.insert(key, val);
            self.skip_ws();
            match self.peek() {
                Some(b',') => self.pos += 1,
                Some(b'}') => {
                    self.pos += 1;
                    return Ok(Json::Obj(map));
                }
                _ => return Err(self.err("expected ',' or '}'")),
            }
        }
    }
}

/// Parse a JSON file from disk.
pub fn parse_file(path: &std::path::Path) -> anyhow::Result<Json> {
    let text = std::fs::read_to_string(path)
        .map_err(|e| anyhow::anyhow!("reading {}: {e}", path.display()))?;
    parse(&text).map_err(|e| anyhow::anyhow!("parsing {}: {e}", path.display()))
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn parse_scalars() {
        assert_eq!(parse("null").unwrap(), Json::Null);
        assert_eq!(parse("true").unwrap(), Json::Bool(true));
        assert_eq!(parse("false").unwrap(), Json::Bool(false));
        assert_eq!(parse("42").unwrap(), Json::Num(42.0));
        assert_eq!(parse("-3.5e2").unwrap(), Json::Num(-350.0));
        assert_eq!(parse("\"hi\"").unwrap(), Json::Str("hi".into()));
    }

    #[test]
    fn parse_nested() {
        let j = parse(r#"{"a": [1, 2, {"b": null}], "c": "x\ny"}"#).unwrap();
        assert_eq!(j.get("a").as_arr().unwrap().len(), 3);
        assert_eq!(j.at(&["a"]).as_arr().unwrap()[0].as_f64(), Some(1.0));
        assert_eq!(j.get("c").as_str(), Some("x\ny"));
        assert_eq!(j.get("missing"), &Json::Null);
    }

    #[test]
    fn parse_unicode_escape() {
        let j = parse(r#""Aé""#).unwrap();
        assert_eq!(j.as_str(), Some("Aé"));
    }

    #[test]
    fn rejects_garbage() {
        assert!(parse("{").is_err());
        assert!(parse("[1,]").is_err());
        assert!(parse("1 2").is_err());
        assert!(parse("{'a':1}").is_err());
        assert!(parse("").is_err());
    }

    #[test]
    fn roundtrip() {
        let src = r#"{"arr":[1,2.5,"s"],"b":true,"n":null,"o":{"k":-7}}"#;
        let j = parse(src).unwrap();
        let out = j.to_string();
        assert_eq!(parse(&out).unwrap(), j);
        // pretty form also round-trips
        assert_eq!(parse(&j.to_string_pretty()).unwrap(), j);
    }

    #[test]
    fn escapes_roundtrip() {
        let j = Json::Str("a\"b\\c\nd\te\u{1}".into());
        assert_eq!(parse(&j.to_string()).unwrap(), j);
    }

    #[test]
    fn builder_api() {
        let mut j = Json::obj();
        j.set("x", Json::Num(1.0));
        j.set("y", Json::Arr(vec![Json::Bool(false)]));
        assert_eq!(j.get("x").as_usize(), Some(1));
        assert_eq!(j.get("y").as_arr().unwrap()[0].as_bool(), Some(false));
    }

    #[test]
    fn real_manifest_shape() {
        let src = r#"{
          "name": "prism-nano", "n_layers": 2,
          "weights": [{"name": "embed", "shape": [256, 64], "offset": 0, "bytes": 65536}],
          "artifacts": {"decode": [{"batch": 1, "file": "decode_b1.hlo.txt"}]}
        }"#;
        let j = parse(src).unwrap();
        assert_eq!(j.get("name").as_str(), Some("prism-nano"));
        assert_eq!(
            j.at(&["artifacts", "decode"]).as_arr().unwrap()[0]
                .get("file")
                .as_str(),
            Some("decode_b1.hlo.txt")
        );
        let w = &j.get("weights").as_arr().unwrap()[0];
        assert_eq!(w.get("shape").as_arr().unwrap()[1].as_usize(), Some(64));
    }
}
