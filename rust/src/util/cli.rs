//! Tiny declarative CLI argument parser (no clap in the offline vendor set).
//!
//! Supports `--flag`, `--key value`, `--key=value`, positional args, and
//! generates usage text. Used by the `prism` binary and the examples.

use std::collections::BTreeMap;

#[derive(Debug, Clone)]
pub struct ArgSpec {
    pub name: &'static str,
    pub help: &'static str,
    pub default: Option<&'static str>,
    pub is_flag: bool,
}

#[derive(Debug, Default)]
pub struct Args {
    values: BTreeMap<String, String>,
    flags: Vec<String>,
    pub positional: Vec<String>,
}

impl Args {
    pub fn get(&self, key: &str) -> Option<&str> {
        self.values.get(key).map(|s| s.as_str())
    }

    pub fn get_or(&self, key: &str, default: &str) -> String {
        self.get(key).unwrap_or(default).to_string()
    }

    pub fn get_f64(&self, key: &str, default: f64) -> f64 {
        self.get(key).and_then(|s| s.parse().ok()).unwrap_or(default)
    }

    pub fn get_usize(&self, key: &str, default: usize) -> usize {
        self.get(key).and_then(|s| s.parse().ok()).unwrap_or(default)
    }

    pub fn get_u64(&self, key: &str, default: u64) -> u64 {
        self.get(key).and_then(|s| s.parse().ok()).unwrap_or(default)
    }

    pub fn has_flag(&self, key: &str) -> bool {
        self.flags.iter().any(|f| f == key)
    }
}

pub struct Cli {
    pub name: &'static str,
    pub about: &'static str,
    pub specs: Vec<ArgSpec>,
}

impl Cli {
    pub fn new(name: &'static str, about: &'static str) -> Self {
        Cli { name, about, specs: Vec::new() }
    }

    pub fn opt(mut self, name: &'static str, default: &'static str, help: &'static str) -> Self {
        self.specs.push(ArgSpec { name, help, default: Some(default), is_flag: false });
        self
    }

    pub fn flag(mut self, name: &'static str, help: &'static str) -> Self {
        self.specs.push(ArgSpec { name, help, default: None, is_flag: true });
        self
    }

    pub fn usage(&self) -> String {
        let mut s = format!("{} - {}\n\nOptions:\n", self.name, self.about);
        for spec in &self.specs {
            let lhs = if spec.is_flag {
                format!("  --{}", spec.name)
            } else {
                format!("  --{} <v>", spec.name)
            };
            let def = spec.default.map(|d| format!(" [default: {d}]")).unwrap_or_default();
            s.push_str(&format!("{lhs:—<0}{}\n", format!("  {}{}", spec.help, def)));
        }
        s
    }

    /// Parse an iterator of raw args (excluding argv[0]).
    pub fn parse<I: IntoIterator<Item = String>>(&self, raw: I) -> Result<Args, String> {
        let mut out = Args::default();
        // Seed defaults.
        for spec in &self.specs {
            if let Some(d) = spec.default {
                out.values.insert(spec.name.to_string(), d.to_string());
            }
        }
        let mut it = raw.into_iter().peekable();
        while let Some(tok) = it.next() {
            if tok == "--help" || tok == "-h" {
                return Err(self.usage());
            }
            if let Some(body) = tok.strip_prefix("--") {
                let (key, inline_val) = match body.split_once('=') {
                    Some((k, v)) => (k.to_string(), Some(v.to_string())),
                    None => (body.to_string(), None),
                };
                let spec = self
                    .specs
                    .iter()
                    .find(|s| s.name == key)
                    .ok_or_else(|| format!("unknown option --{key}\n\n{}", self.usage()))?;
                if spec.is_flag {
                    if inline_val.is_some() {
                        return Err(format!("--{key} is a flag and takes no value"));
                    }
                    out.flags.push(key);
                } else {
                    let val = match inline_val {
                        Some(v) => v,
                        None => it
                            .next()
                            .ok_or_else(|| format!("--{key} requires a value"))?,
                    };
                    out.values.insert(key, val);
                }
            } else {
                out.positional.push(tok);
            }
        }
        Ok(out)
    }

    /// Parse process args after the given number of leading positionals to skip.
    pub fn parse_env(&self, skip: usize) -> Result<Args, String> {
        self.parse(std::env::args().skip(1 + skip))
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn cli() -> Cli {
        Cli::new("t", "test")
            .opt("rate", "1.0", "request rate")
            .opt("gpus", "2", "gpu count")
            .flag("verbose", "chatty")
    }

    fn sv(xs: &[&str]) -> Vec<String> {
        xs.iter().map(|s| s.to_string()).collect()
    }

    #[test]
    fn defaults_and_overrides() {
        let a = cli().parse(sv(&["--rate", "3.5"])).unwrap();
        assert_eq!(a.get_f64("rate", 0.0), 3.5);
        assert_eq!(a.get_usize("gpus", 0), 2);
        assert!(!a.has_flag("verbose"));
    }

    #[test]
    fn equals_form_and_flags() {
        let a = cli().parse(sv(&["--gpus=8", "--verbose", "pos1"])).unwrap();
        assert_eq!(a.get_usize("gpus", 0), 8);
        assert!(a.has_flag("verbose"));
        assert_eq!(a.positional, vec!["pos1"]);
    }

    #[test]
    fn errors() {
        assert!(cli().parse(sv(&["--nope"])).is_err());
        assert!(cli().parse(sv(&["--rate"])).is_err());
        assert!(cli().parse(sv(&["--verbose=1"])).is_err());
        assert!(cli().parse(sv(&["--help"])).is_err()); // usage via Err
    }
}
