//! Deterministic fault injection: seeded, schedulable fault plans for the
//! simulator (GPU crash/spot-preemption, slowdown windows, model-load
//! failures, transient KV-allocation faults).
//!
//! # Determinism / purity contract
//!
//! **Faults are data, never RNG-in-the-loop.** A [`FaultPlan`] is fully
//! materialized *before* `Simulator::run` starts: every crash, recovery,
//! slowdown window, failing load attempt, and transient allocation fault
//! is a plain value carried on `SimConfig` (and, as a spec string, on
//! `SweepPoint`). The seeded generator ([`FaultPlan::seeded_churn`]) draws
//! all of its randomness at plan-construction time from the crate's
//! SplitMix64 PRNG; the simulator never samples randomness while events
//! are in flight. A fixed `(config, trace, plan)` triple therefore replays
//! bitwise-identically, and the sweep engine's `--jobs 1` ≡ `--jobs N`
//! byte-identity contract extends to fault sweeps: the fault axis is just
//! another pure input baked into the point key.
//!
//! An empty plan is the explicit no-op: the simulator pushes no fault
//! events and arms none of the injection hooks, so zero-fault runs are
//! bitwise-identical to runs from before this module existed (guarded by
//! the `policy_identity` A/B tests).
//!
//! The faults-are-data contract is machine-checked by `prism lint` (see
//! ROADMAP "Static analysis"): rule D1 bans in-loop randomness and clock
//! reads here, and rule D3 requires an INVARIANT: comment at every
//! unwrap/expect in this module.
//!
//! # Spec grammar
//!
//! Plans parse from compact `;`-separated clause strings:
//!
//! ```text
//! crash@<t>:g<N>[+<dur>]      GPU N dies at t; with +dur it rejoins at t+dur
//! slow@<a>-<b>:g<N>x<f>       GPU N runs f >= 1.0 times slower during [a, b)
//! loadfail@<o1>,<o2>,...      global model-load attempt ordinals that fail
//! allocfail@<a>-<b>:g<N>/<k>  every k-th (k >= 2) KV block alloc on GPU N
//!                             fails during [a, b)
//! drop                        drop a crashed GPU's in-flight requests
//!                             (default: restart prefill elsewhere)
//! churn:<seed>                seeded random churn (resolve() only: needs
//!                             the fleet shape)
//! ```
//!
//! Example: `crash@60:g0+90;slow@30-120:g1x2.0;loadfail@2,5`.

use crate::util::rng::Rng;

/// A GPU crash (hard failure or spot preemption) at `at`, optionally
/// rejoining the placement pool at `recover_at`.
#[derive(Debug, Clone, Copy, PartialEq)]
pub struct GpuCrash {
    pub gpu: u32,
    pub at: f64,
    pub recover_at: Option<f64>,
}

/// A degraded-performance window: iterations on `gpu` take `factor` times
/// longer while `from <= t < until`.
#[derive(Debug, Clone, Copy, PartialEq)]
pub struct Slowdown {
    pub gpu: u32,
    pub from: f64,
    pub until: f64,
    /// Iteration-time multiplier, `>= 1.0`.
    pub factor: f64,
}

/// A transient KV-allocation fault window: while armed, every `every`-th
/// block allocation on `gpu` fails with an injected error (the engine
/// treats it like memory pressure and retries).
#[derive(Debug, Clone, Copy, PartialEq)]
pub struct AllocFault {
    pub gpu: u32,
    pub from: f64,
    pub until: f64,
    /// Injection period, `>= 2` (1 would fail every alloc and stall all
    /// progress for the whole window).
    pub every: u32,
}

/// What happens to requests in flight on a crashed GPU.
#[derive(Debug, Clone, Copy, PartialEq, Eq, Default)]
pub enum CrashedRequests {
    /// Re-queue them for a fresh prefill on surviving GPUs (default).
    #[default]
    Restart,
    /// Drop them; they count as failed completions.
    Drop,
}

/// A complete, pure description of every fault a run will experience.
#[derive(Debug, Clone, PartialEq, Default)]
pub struct FaultPlan {
    pub crashes: Vec<GpuCrash>,
    pub slowdowns: Vec<Slowdown>,
    /// Sorted, deduplicated global load-attempt ordinals (0-based, counted
    /// across the whole run) whose model load fails and must be retried.
    pub load_fail_attempts: Vec<u64>,
    pub alloc_faults: Vec<AllocFault>,
    pub on_crash: CrashedRequests,
}

/// One scheduled state transition, produced by [`FaultPlan::schedule`] and
/// applied by the simulator when its event fires.
#[derive(Debug, Clone, Copy, PartialEq)]
pub enum FaultAction {
    Crash(u32),
    Recover(u32),
    SlowStart(u32, f64),
    SlowEnd(u32),
    AllocArm(u32, u32),
    AllocDisarm(u32),
}

impl FaultAction {
    /// True for actions that only scale step latency (`SlowStart` /
    /// `SlowEnd` -> `Cluster::set_gpu_slow`) and can never change model
    /// residency, GPU grouping, queue contents, or worker-owned allocator
    /// state. The sharded event loop treats these as batch-internal
    /// *pauses* (workers apply the factor locally and keep running on the
    /// same window plan); everything else — crash/recover re-routing and
    /// alloc-fault arming — stays a full recompose barrier.
    pub fn is_slowdown_only(&self) -> bool {
        matches!(self, FaultAction::SlowStart(..) | FaultAction::SlowEnd(_))
    }
}

impl FaultPlan {
    /// True when the plan injects nothing; the simulator takes the
    /// pre-fault code path bit for bit.
    pub fn is_empty(&self) -> bool {
        self.crashes.is_empty()
            && self.slowdowns.is_empty()
            && self.load_fail_attempts.is_empty()
            && self.alloc_faults.is_empty()
    }

    /// Flatten the plan into a time-sorted action list for the event heap.
    /// The sort is stable over finite times (guaranteed by `parse` and the
    /// generators), so same-time actions keep plan order and the schedule
    /// is deterministic.
    pub fn schedule(&self) -> Vec<(f64, FaultAction)> {
        let mut s = Vec::new();
        for c in &self.crashes {
            s.push((c.at, FaultAction::Crash(c.gpu)));
            if let Some(r) = c.recover_at {
                s.push((r, FaultAction::Recover(c.gpu)));
            }
        }
        for w in &self.slowdowns {
            s.push((w.from, FaultAction::SlowStart(w.gpu, w.factor)));
            s.push((w.until, FaultAction::SlowEnd(w.gpu)));
        }
        for a in &self.alloc_faults {
            s.push((a.from, FaultAction::AllocArm(a.gpu, a.every)));
            s.push((a.until, FaultAction::AllocDisarm(a.gpu)));
        }
        s.sort_by(|a, b| a.0.total_cmp(&b.0));
        s
    }

    /// Parse the explicit clause grammar (everything except `churn:`,
    /// which needs the fleet shape — see [`resolve`]). An empty or
    /// whitespace-only spec is the empty plan.
    pub fn parse(spec: &str) -> Result<FaultPlan, String> {
        let mut plan = FaultPlan::default();
        for raw in spec.split(';') {
            let clause = raw.trim();
            if clause.is_empty() {
                continue;
            }
            if clause == "drop" {
                plan.on_crash = CrashedRequests::Drop;
            } else if let Some(rest) = clause.strip_prefix("crash@") {
                let (t, g) = split2(rest, ':', clause)?;
                let (g, dur) = match g.split_once('+') {
                    Some((g, d)) => (g, Some(num(d, clause)?)),
                    None => (g, None),
                };
                let at = num(t, clause)?;
                plan.crashes.push(GpuCrash {
                    gpu: gpu_idx(g, clause)?,
                    at,
                    recover_at: dur.map(|d| at + d),
                });
            } else if let Some(rest) = clause.strip_prefix("slow@") {
                let (window, g) = split2(rest, ':', clause)?;
                let (from, until) = window_of(window, clause)?;
                let (g, f) = split2(g, 'x', clause)?;
                let factor = num(f, clause)?;
                if factor < 1.0 {
                    return Err(format!("{clause:?}: slowdown factor must be >= 1.0"));
                }
                plan.slowdowns.push(Slowdown { gpu: gpu_idx(g, clause)?, from, until, factor });
            } else if let Some(rest) = clause.strip_prefix("loadfail@") {
                for o in rest.split(',') {
                    let ord: u64 = o
                        .trim()
                        .parse()
                        .map_err(|_| format!("{clause:?}: bad load-attempt ordinal {o:?}"))?;
                    plan.load_fail_attempts.push(ord);
                }
                plan.load_fail_attempts.sort_unstable();
                plan.load_fail_attempts.dedup();
            } else if let Some(rest) = clause.strip_prefix("allocfail@") {
                let (window, g) = split2(rest, ':', clause)?;
                let (from, until) = window_of(window, clause)?;
                let (g, k) = split2(g, '/', clause)?;
                let every: u32 = k
                    .trim()
                    .parse()
                    .map_err(|_| format!("{clause:?}: bad injection period {k:?}"))?;
                if every < 2 {
                    return Err(format!("{clause:?}: injection period must be >= 2"));
                }
                plan.alloc_faults.push(AllocFault { gpu: gpu_idx(g, clause)?, from, until, every });
            } else {
                return Err(format!(
                    "unknown fault clause {clause:?} (expected crash@/slow@/loadfail@/allocfail@/drop)"
                ));
            }
        }
        Ok(plan)
    }

    /// Check plan invariants against the fleet shape: GPU indices in
    /// range, windows well-formed. `parse` enforces the rest.
    pub fn validate(&self, n_gpus: u32) -> Result<(), String> {
        let gpu_ok = |g: u32| -> Result<(), String> {
            if g >= n_gpus {
                return Err(format!("fault targets GPU g{g} but the fleet has {n_gpus} GPUs"));
            }
            Ok(())
        };
        for c in &self.crashes {
            gpu_ok(c.gpu)?;
            if let Some(r) = c.recover_at {
                if r <= c.at {
                    return Err(format!(
                        "crash of g{} recovers at {r} <= crash time {}",
                        c.gpu, c.at
                    ));
                }
            }
        }
        for w in &self.slowdowns {
            gpu_ok(w.gpu)?;
            if w.until <= w.from {
                return Err(format!(
                    "slowdown window [{}, {}) on g{} is empty",
                    w.from, w.until, w.gpu
                ));
            }
        }
        for a in &self.alloc_faults {
            gpu_ok(a.gpu)?;
            if a.until <= a.from {
                return Err(format!(
                    "allocfail window [{}, {}) on g{} is empty",
                    a.from, a.until, a.gpu
                ));
            }
        }
        Ok(())
    }

    /// Seeded "churny fleet" generator: a few spot preemptions with
    /// recovery, one slowdown window, one transient-alloc window, and a
    /// handful of failing load attempts, all drawn here from a SplitMix64
    /// stream — randomness is consumed at construction, never during the
    /// run, so the same `(seed, n_gpus, duration)` always yields the same
    /// plan.
    pub fn seeded_churn(seed: u64, n_gpus: u32, duration: f64) -> FaultPlan {
        let mut rng = Rng::new(seed ^ 0xFA17_0000_FA17_0000);
        let n = n_gpus.max(1) as usize;
        let mut plan = FaultPlan::default();
        let n_crashes = (n / 4).clamp(1, 4);
        for g in rng.sample_indices(n, n_crashes) {
            let at = rng.range_f64(0.2, 0.6) * duration;
            let outage = rng.range_f64(0.1, 0.25) * duration;
            plan.crashes.push(GpuCrash { gpu: g as u32, at, recover_at: Some(at + outage) });
        }
        let from = rng.range_f64(0.1, 0.5) * duration;
        plan.slowdowns.push(Slowdown {
            gpu: rng.below(n) as u32,
            from,
            until: from + 0.2 * duration,
            factor: rng.range_f64(1.5, 3.0),
        });
        let from = rng.range_f64(0.1, 0.6) * duration;
        plan.alloc_faults.push(AllocFault {
            gpu: rng.below(n) as u32,
            from,
            until: from + 0.25 * duration,
            every: rng.range_usize(5, 12) as u32,
        });
        let mut fails: Vec<u64> = (0..3).map(|_| rng.below(40) as u64).collect();
        fails.sort_unstable();
        fails.dedup();
        plan.load_fail_attempts = fails;
        plan
    }
}

/// Resolve a spec string into a concrete, validated plan. Handles the
/// `churn:<seed>` shorthand (which needs the fleet shape) in addition to
/// the explicit [`FaultPlan::parse`] grammar.
pub fn resolve(spec: &str, n_gpus: u32, duration: f64) -> Result<FaultPlan, String> {
    let plan = if let Some(seed) = spec.trim().strip_prefix("churn:") {
        let seed: u64 = seed
            .trim()
            .parse()
            .map_err(|_| format!("churn: expects an integer seed, got {spec:?}"))?;
        FaultPlan::seeded_churn(seed, n_gpus, duration)
    } else {
        FaultPlan::parse(spec)?
    };
    plan.validate(n_gpus)?;
    Ok(plan)
}

fn split2<'a>(s: &'a str, sep: char, clause: &str) -> Result<(&'a str, &'a str), String> {
    s.split_once(sep).ok_or_else(|| format!("{clause:?}: expected {sep:?} separator"))
}

fn window_of(s: &str, clause: &str) -> Result<(f64, f64), String> {
    let (a, b) = split2(s, '-', clause)?;
    Ok((num(a, clause)?, num(b, clause)?))
}

fn num(s: &str, clause: &str) -> Result<f64, String> {
    let v: f64 = s
        .trim()
        .parse()
        .map_err(|_| format!("{clause:?}: expected a number, got {s:?}"))?;
    if !v.is_finite() || v < 0.0 {
        return Err(format!("{clause:?}: expected a finite non-negative number, got {s:?}"));
    }
    Ok(v)
}

fn gpu_idx(s: &str, clause: &str) -> Result<u32, String> {
    s.trim()
        .strip_prefix('g')
        .and_then(|g| g.parse().ok())
        .ok_or_else(|| format!("{clause:?}: expected a GPU as gN, got {s:?}"))
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn empty_spec_is_empty_plan() {
        let p = FaultPlan::parse("").unwrap();
        assert!(p.is_empty());
        assert_eq!(p, FaultPlan::default());
        assert!(p.schedule().is_empty());
        assert!(FaultPlan::parse("  ; ;").unwrap().is_empty());
    }

    #[test]
    fn parse_full_grammar() {
        let p = FaultPlan::parse(
            "crash@60:g0+90; slow@30-120:g1x2.5; loadfail@5,2,5; allocfail@10-40:g1/7; drop",
        )
        .unwrap();
        assert_eq!(p.crashes, vec![GpuCrash { gpu: 0, at: 60.0, recover_at: Some(150.0) }]);
        assert_eq!(p.slowdowns, vec![Slowdown { gpu: 1, from: 30.0, until: 120.0, factor: 2.5 }]);
        assert_eq!(p.load_fail_attempts, vec![2, 5], "sorted and deduplicated");
        assert_eq!(p.alloc_faults, vec![AllocFault { gpu: 1, from: 10.0, until: 40.0, every: 7 }]);
        assert_eq!(p.on_crash, CrashedRequests::Drop);
        p.validate(2).unwrap();
    }

    #[test]
    fn crash_without_recovery_is_permanent() {
        let p = FaultPlan::parse("crash@10:g3").unwrap();
        assert_eq!(p.crashes[0].recover_at, None);
        assert_eq!(p.schedule(), vec![(10.0, FaultAction::Crash(3))]);
    }

    #[test]
    fn schedule_is_time_sorted() {
        let p = FaultPlan::parse("crash@100:g0+50; slow@20-80:g1x2.0; allocfail@60-90:g0/3")
            .unwrap();
        let s = p.schedule();
        assert_eq!(s.len(), 6);
        assert!(s.windows(2).all(|w| w[0].0 <= w[1].0), "schedule not sorted: {s:?}");
        assert_eq!(s[0], (20.0, FaultAction::SlowStart(1, 2.0)));
        assert_eq!(s[5], (150.0, FaultAction::Recover(0)));
    }

    #[test]
    fn parse_rejects_malformed_clauses() {
        for bad in [
            "explode@5:g0",         // unknown clause
            "crash@x:g0",           // non-numeric time
            "crash@5:q0",           // not a GPU
            "slow@30-120:g0x0.5",   // speedup, not slowdown
            "slow@30:g0x2.0",       // missing window end
            "allocfail@0-10:g0/1",  // period 1 stalls the whole window
            "loadfail@two",         // non-integer ordinal
            "crash@-5:g0",          // negative time
        ] {
            assert!(FaultPlan::parse(bad).is_err(), "accepted {bad:?}");
        }
    }

    #[test]
    fn validate_rejects_out_of_range_gpus_and_empty_windows() {
        assert!(FaultPlan::parse("crash@5:g4").unwrap().validate(4).is_err());
        assert!(FaultPlan::parse("crash@5:g3").unwrap().validate(4).is_ok());
        let mut p = FaultPlan::parse("slow@30-120:g0x2.0").unwrap();
        p.slowdowns[0].until = 30.0;
        assert!(p.validate(4).is_err());
        let mut p = FaultPlan::parse("crash@5:g0+1").unwrap();
        p.crashes[0].recover_at = Some(5.0);
        assert!(p.validate(4).is_err());
    }

    #[test]
    fn seeded_churn_is_deterministic_and_seed_sensitive() {
        let a = FaultPlan::seeded_churn(7, 8, 3600.0);
        let b = FaultPlan::seeded_churn(7, 8, 3600.0);
        let c = FaultPlan::seeded_churn(8, 8, 3600.0);
        assert_eq!(a, b);
        assert_ne!(a, c);
        assert!(!a.is_empty());
        a.validate(8).unwrap();
        // Every generated crash recovers (churn, not permanent loss).
        assert!(a.crashes.iter().all(|cr| cr.recover_at.is_some()));
    }

    #[test]
    fn resolve_handles_churn_shorthand() {
        let a = resolve("churn:7", 4, 600.0).unwrap();
        assert_eq!(a, FaultPlan::seeded_churn(7, 4, 600.0));
        assert!(resolve("churn:x", 4, 600.0).is_err());
        // Explicit clauses go through parse + validate.
        assert!(resolve("crash@5:g9", 4, 600.0).is_err());
        assert!(resolve("", 4, 600.0).unwrap().is_empty());
    }
}
