//! `prism lint` — a contract-enforcing static-analysis pass over the
//! crate's own sources.
//!
//! The simulator's determinism guarantees (byte-stable experiment tables,
//! shard-count-invariant metric fingerprints, seeded fault plans) are
//! contracts that ordinary tests probe only pointwise. This pass enforces
//! their *preconditions* syntactically, on every build, with no external
//! tooling: a comment/string-aware lexer (see `lexer`), five rule families
//! with stable IDs (see `rules`), an in-source waiver syntax with mandatory
//! justifications (see `waivers`), and a two-sided allocation budget for
//! the hot-path modules (see `inventory`).
//!
//! Three enforcement points share this module: the `prism lint` subcommand
//! (human + `--json` CI output), the `lint_self` integration test (plain
//! `cargo test` fails on a violation), and the `static-analysis` CI leg
//! (uploads the JSON report as an artifact). All three call [`run`].
//!
//! Diagnostic paths are normalized relative to the enclosing Cargo package
//! root regardless of the process working directory, so reports are
//! byte-identical wherever the binary is invoked from.

pub mod inventory;
pub mod lexer;
pub mod report;
pub mod rules;
pub mod waivers;

use std::fs;
use std::path::Path;

use anyhow::{Context, Result};

pub use rules::Rule;

/// One diagnostic: `path:line rule: message`. D4 findings use line 0 (the
/// inventory is a file-level fact).
#[derive(Debug, Clone, PartialEq, Eq)]
pub struct Finding {
    /// Repo-root-relative path after [`run`] (scan-root-relative inside
    /// `rules::scan_file`).
    pub path: String,
    /// 1-based line number; 0 for file-level findings.
    pub line: usize,
    pub rule: Rule,
    pub message: String,
}

/// Which paths (relative to the scan root, `/`-separated, directories with
/// a trailing `/`) each rule family applies to. [`LintConfig::prism`] is
/// the crate's own contract surface; fixture tests build their own.
#[derive(Debug)]
pub struct LintConfig {
    /// Modules allowed to touch wall clocks / env / OS randomness: the
    /// I/O shell, not the deterministic core.
    pub d1_exempt: &'static [&'static str],
    /// Fingerprinted modules where hash-order must not leak into results.
    pub d2_surface: &'static [&'static str],
    /// Contract surface where every unwrap/expect needs an INVARIANT:.
    pub d3_surface: &'static [&'static str],
    /// Per-token hot-path modules with a checked-in allocation budget.
    pub d4_budgeted: &'static [&'static str],
    /// Placement-policy modules that must stay pure.
    pub d5_surface: &'static [&'static str],
    /// D4 allowlist path, relative to the scan root.
    pub allowlist_file: &'static str,
}

impl LintConfig {
    /// The crate's own rule surfaces (scan root: `rust/src`).
    pub fn prism() -> LintConfig {
        LintConfig {
            d1_exempt: &["util/logger.rs", "bench/", "serve/", "runtime/", "main.rs"],
            d2_surface: &[
                "sim/",
                "sweep/",
                "metrics/",
                "fault/",
                "engine/",
                "kvcached/",
                "cluster/",
                "sched/",
            ],
            d3_surface: &[
                "sim/",
                "engine/",
                "kvcached/",
                "cluster/",
                "fault/",
                "sched/",
                "metrics/",
                "sweep/",
                "trace/",
                "model/",
                "request.rs",
            ],
            d4_budgeted: &[
                "engine/engine.rs",
                "kvcached/manager.rs",
                "kvcached/pool.rs",
                "sim/simulator.rs",
                "sim/shard.rs",
            ],
            d5_surface: &["sim/policies/"],
            allowlist_file: "lint/hot_alloc_allowlist.txt",
        }
    }
}

/// The full result of one lint pass.
#[derive(Debug)]
pub struct LintReport {
    /// Sorted by (path, line, rule); empty means the tree is clean.
    pub findings: Vec<Finding>,
    pub files_scanned: usize,
}

/// Scan every `.rs` file under `root` (recursively, sorted), apply the
/// rule surfaces in `cfg`, diff the D4 inventory, and return the findings
/// sorted by (path, line, rule) with display-normalized paths.
pub fn run(root: &Path, cfg: &LintConfig) -> Result<LintReport> {
    let files = walk(root)?;
    let allow = inventory::parse_allowlist_file(&root.join(cfg.allowlist_file))?;
    let mut findings: Vec<Finding> = Vec::new();
    let mut d4_counts = inventory::D4Counts::new();
    for rel in &files {
        let path = root.join(rel);
        let text =
            fs::read_to_string(&path).with_context(|| format!("reading {}", path.display()))?;
        let out = rules::scan_file(rel, &text, cfg);
        findings.extend(out.findings);
        if let Some(counts) = out.d4_counts {
            d4_counts.insert(rel.clone(), counts);
        }
    }
    findings.extend(inventory::diff(&allow, &d4_counts));
    findings
        .sort_by(|a, b| (a.path.as_str(), a.line, a.rule).cmp(&(b.path.as_str(), b.line, b.rule)));
    let prefix = display_prefix(root);
    if !prefix.is_empty() {
        for f in &mut findings {
            f.path = format!("{prefix}/{}", f.path);
        }
    }
    Ok(LintReport { findings, files_scanned: files.len() })
}

/// All `.rs` files under `root` as sorted `/`-separated relative paths.
fn walk(root: &Path) -> Result<Vec<String>> {
    let mut out = Vec::new();
    walk_into(root, root, &mut out)?;
    out.sort();
    Ok(out)
}

fn walk_into(dir: &Path, root: &Path, out: &mut Vec<String>) -> Result<()> {
    for entry in fs::read_dir(dir).with_context(|| format!("reading {}", dir.display()))? {
        let path = entry.with_context(|| format!("reading {}", dir.display()))?.path();
        if path.is_dir() {
            walk_into(&path, root, out)?;
        } else if path.extension().is_some_and(|e| e == "rs") {
            out.push(rel_slashed(&path, root));
        }
    }
    Ok(())
}

/// `path` relative to `base`, joined with `/` (falls back to the full path
/// when `path` is not under `base`).
fn rel_slashed(path: &Path, base: &Path) -> String {
    let rel = path.strip_prefix(base).unwrap_or(path);
    rel.components()
        .map(|c| c.as_os_str().to_string_lossy().into_owned())
        .collect::<Vec<_>>()
        .join("/")
}

/// Display prefix for findings: the scan root rewritten relative to the
/// nearest ancestor directory holding a Cargo.toml, so `prism lint` prints
/// `rust/src/...` no matter where it is invoked from. Falls back to the
/// canonical root when no package root encloses it.
fn display_prefix(root: &Path) -> String {
    let canon = root.canonicalize().unwrap_or_else(|_| root.to_path_buf());
    let mut anc = canon.parent();
    while let Some(a) = anc {
        if a.join("Cargo.toml").is_file() {
            return rel_slashed(&canon, a);
        }
        anc = a.parent();
    }
    canon.to_string_lossy().into_owned()
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn prism_config_covers_all_budgeted_modules_with_d3() {
        // Every D4-budgeted module sits inside the D3 surface too: a module
        // hot enough to budget allocations is hot enough to audit panics.
        let cfg = LintConfig::prism();
        for m in cfg.d4_budgeted {
            assert!(
                cfg.d3_surface.iter().any(|p| m.starts_with(p)),
                "budgeted module {m} escapes the D3 surface"
            );
        }
    }

    #[test]
    fn scan_root_is_self_describing() {
        let cfg = LintConfig::prism();
        assert!(cfg.allowlist_file.starts_with("lint/"));
    }
}
