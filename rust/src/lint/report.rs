//! Rendering: one-line-per-finding text and the stable JSON report.

use std::fmt::Write as _;

use crate::lint::LintReport;
use crate::util::json::Json;

/// One line per finding: `<path>:<line> <rule>: <message>`. Findings are
/// already sorted by (path, line, rule) with repo-root-relative paths, so
/// the output is byte-stable across machines and working directories.
pub fn render_text(report: &LintReport) -> String {
    let mut out = String::new();
    for f in &report.findings {
        let _ = writeln!(out, "{}:{} {}: {}", f.path, f.line, f.rule.as_str(), f.message);
    }
    out
}

/// Stable JSON form for the CI artifact: findings in the same sorted order,
/// object keys sorted (BTreeMap), plus summary counts.
pub fn to_json(report: &LintReport) -> Json {
    let findings: Vec<Json> = report
        .findings
        .iter()
        .map(|f| {
            let mut o = Json::obj();
            o.set("path", Json::Str(f.path.clone()));
            o.set("line", Json::Num(f.line as f64));
            o.set("rule", Json::Str(f.rule.as_str().to_string()));
            o.set("message", Json::Str(f.message.clone()));
            o
        })
        .collect();
    let mut root = Json::obj();
    root.set("count", Json::Num(report.findings.len() as f64));
    root.set("files_scanned", Json::Num(report.files_scanned as f64));
    root.set("findings", Json::Arr(findings));
    root
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::lint::{Finding, LintReport, Rule};

    fn report() -> LintReport {
        LintReport {
            findings: vec![Finding {
                path: "rust/src/sim/x.rs".to_string(),
                line: 7,
                rule: Rule::D1,
                message: "nondeterminism source `SystemTime` in contract-surface module"
                    .to_string(),
            }],
            files_scanned: 3,
        }
    }

    #[test]
    fn text_format_is_path_line_rule_message() {
        let t = render_text(&report());
        assert_eq!(
            t,
            "rust/src/sim/x.rs:7 D1: nondeterminism source `SystemTime` \
             in contract-surface module\n"
        );
    }

    #[test]
    fn json_round_trips_and_keeps_counts() {
        let j = to_json(&report());
        let parsed = crate::util::json::parse(&j.to_string_pretty()).unwrap();
        assert_eq!(parsed.get("count").as_usize(), Some(1));
        assert_eq!(parsed.get("files_scanned").as_usize(), Some(3));
        let arr = parsed.get("findings").as_arr().unwrap();
        assert_eq!(arr[0].get("rule").as_str(), Some("D1"));
        assert_eq!(arr[0].get("line").as_usize(), Some(7));
    }
}
