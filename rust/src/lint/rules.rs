//! The five rule families and the per-file scanner.
//!
//! Every matcher runs on the masked code view from [`crate::lint::lexer`],
//! so tokens inside string literals or comments never fire (D1's own
//! pattern table below is the proof: this module passes its own scan).
//! Rule IDs are stable and documented in ROADMAP.md:
//!
//! - D1 no-nondeterminism: wall clocks, OS randomness, and environment
//!   reads are banned outside the exempt shell modules.
//! - D2 ordered-iteration: iterating a HashMap/HashSet in a fingerprinted
//!   module needs a waiver; lookup-only maps pass.
//! - D3 panic-audit: every unwrap/expect in the contract surface needs an
//!   INVARIANT: comment within 3 lines (or on its contiguous comment run).
//! - D4 hot-path allocation inventory: allocation tokens in the budgeted
//!   modules are counted and diffed against the checked-in allowlist.
//! - D5 policy purity: placement policies hold no interior mutability or
//!   global state.
//!
//! W0 (malformed waiver) and W1 (unused waiver) guard the waiver syntax
//! itself in every scanned file.

use std::collections::{BTreeMap, BTreeSet};

use crate::lint::lexer::{lex, test_lines};
use crate::lint::waivers::{parse_waivers, ParsedWaivers};
use crate::lint::{Finding, LintConfig};

/// Stable rule identifier. Variant order matches the lexicographic order of
/// the ID strings, so sorting by `Rule` equals sorting by rendered ID.
#[derive(Debug, Clone, Copy, PartialEq, Eq, PartialOrd, Ord)]
pub enum Rule {
    /// Nondeterminism source in a contract-surface module.
    D1,
    /// Unordered hash-container iteration in a fingerprinted module.
    D2,
    /// unwrap/expect without a nearby INVARIANT: comment.
    D3,
    /// Hot-path allocation inventory drift against the allowlist.
    D4,
    /// Interior mutability / global state in a policy module.
    D5,
    /// Malformed waiver comment.
    W0,
    /// Waiver that matched no finding.
    W1,
}

impl Rule {
    pub fn as_str(self) -> &'static str {
        match self {
            Rule::D1 => "D1",
            Rule::D2 => "D2",
            Rule::D3 => "D3",
            Rule::D4 => "D4",
            Rule::D5 => "D5",
            Rule::W0 => "W0",
            Rule::W1 => "W1",
        }
    }

    /// Parse a waivable rule ID (only the five D-rules can be waived).
    pub fn waivable(s: &str) -> Option<Rule> {
        match s {
            "D1" => Some(Rule::D1),
            "D2" => Some(Rule::D2),
            "D3" => Some(Rule::D3),
            "D4" => Some(Rule::D4),
            "D5" => Some(Rule::D5),
            _ => None,
        }
    }
}

/// Pattern table entry: (token, check-boundary-before, check-boundary-after).
type Pat = (&'static str, bool, bool);

const D1_PATTERNS: &[Pat] = &[
    ("Instant::now", true, false),
    ("SystemTime", true, true),
    ("thread_rng", true, true),
    ("RandomState", true, true),
    ("rand::", true, false),
    ("env::var", true, false),
    ("Utc::now", true, false),
    ("Local::now", true, false),
];

const D5_PATTERNS: &[Pat] = &[
    ("&mut Simulator", false, true),
    ("static mut", true, true),
    ("thread_local!", true, false),
    ("OnceLock", true, true),
    ("Lazy", true, true),
    ("RefCell", true, true),
    ("UnsafeCell", true, true),
    ("Cell<", true, false),
    ("Mutex", true, true),
    ("RwLock", true, true),
    ("Atomic", true, false),
    ("sync::atomic", true, false),
];

const ALLOC_PATTERNS: &[Pat] = &[
    ("Vec::new", true, false),
    ("vec![", true, false),
    ("Box::new", true, false),
    (".collect", false, true),
    (".to_vec", false, true),
    ("String::from", true, false),
    ("format!", true, false),
];

const D2_METHODS: &[&str] = &[
    ".iter()",
    ".iter_mut()",
    ".keys()",
    ".values()",
    ".values_mut()",
    ".drain(",
    ".into_iter()",
    ".retain(",
];

fn is_ident(b: u8) -> bool {
    b.is_ascii_alphanumeric() || b == b'_'
}

fn find_sub(hay: &[u8], needle: &[u8]) -> Option<usize> {
    if needle.len() > hay.len() {
        return None;
    }
    hay.windows(needle.len()).position(|w| w == needle)
}

/// All match offsets of `pat` in `line`, with identifier-boundary checks on
/// the requested sides (so `rand::` does not fire inside `operand::`).
pub(crate) fn find_bounded(line: &str, pat: &str, before: bool, after: bool) -> Vec<usize> {
    let lb = line.as_bytes();
    let pb = pat.as_bytes();
    let mut out = Vec::new();
    let mut start = 0usize;
    while let Some(off) = find_sub(&lb[start..], pb) {
        let p = start + off;
        let okb = !before || p == 0 || !is_ident(lb[p - 1]);
        let q = p + pb.len();
        let oka = !after || q >= lb.len() || !is_ident(lb[q]);
        if okb && oka {
            out.push(p);
        }
        start = p + 1;
    }
    out
}

/// Names bound to HashMap/HashSet values in non-test code: `name: HashMap`
/// struct fields / fn params (nearest `ident:` left of the match) and
/// `let [mut] name = HashMap::new()` locals. D2 only flags iteration calls
/// on these names, so lookup-only maps pass without a waiver.
fn collect_hash_names(code: &[String], is_test: &[bool]) -> BTreeSet<String> {
    let mut names = BTreeSet::new();
    for (idx, line) in code.iter().enumerate() {
        if is_test[idx] {
            continue;
        }
        for kind in ["HashMap", "HashSet"] {
            for p in find_bounded(line, kind, true, true) {
                let head = &line.as_bytes()[..p];
                let mut best: Option<String> = None;
                for q in (0..head.len()).rev() {
                    let lone_colon = head[q] == b':'
                        && (q == 0 || head[q - 1] != b':')
                        && (q + 1 >= head.len() || head[q + 1] != b':');
                    if !lone_colon {
                        continue;
                    }
                    let mut r = q as i64 - 1;
                    while r >= 0 && head[r as usize] == b' ' {
                        r -= 1;
                    }
                    let e = r;
                    while r >= 0 && is_ident(head[r as usize]) {
                        r -= 1;
                    }
                    if r < e {
                        let s = &head[(r + 1) as usize..=e as usize];
                        best = Some(String::from_utf8_lossy(s).into_owned());
                    }
                    break;
                }
                if let Some(name) = best {
                    names.insert(name);
                    continue;
                }
                let head_str = &line[..p];
                if let Some(lp) = head_str.find("let ") {
                    let mut tail = head_str[lp + 4..].trim();
                    if let Some(t) = tail.strip_prefix("mut ") {
                        tail = t.trim();
                    }
                    let name: String =
                        tail.bytes().take_while(|&b| is_ident(b)).map(char::from).collect();
                    if !name.is_empty() {
                        names.insert(name);
                    }
                }
            }
        }
    }
    names
}

/// Result of scanning one file: findings (with `path` = the relative path)
/// plus, for D4-budgeted modules, the allocation-token counts the caller
/// diffs against the allowlist.
pub struct ScanOutput {
    pub findings: Vec<Finding>,
    pub d4_counts: Option<BTreeMap<&'static str, usize>>,
}

/// Scan one file's text. `rel` is the path relative to the scan root with
/// `/` separators; it selects which rule surfaces apply.
pub fn scan_file(rel: &str, text: &str, cfg: &LintConfig) -> ScanOutput {
    let lexed = lex(text);
    let is_test = test_lines(&lexed.code);
    let ParsedWaivers { waivers, malformed } = parse_waivers(&lexed.comments);
    let mut findings: Vec<Finding> = Vec::new();
    for (idx, com) in &malformed {
        let shown: String = com.chars().take(60).collect();
        findings.push(Finding {
            path: rel.to_string(),
            line: idx + 1,
            rule: Rule::W0,
            message: format!("malformed waiver `{shown}` (want lint:allow(<rule>): <why>)"),
        });
    }

    // A waiver covers its own line plus the 3 lines below; the first waiver
    // to claim a (rule, line) cell wins, and claims are tracked so unused
    // waivers surface as W1.
    let mut cover: BTreeMap<(Rule, usize), (usize, Rule)> = BTreeMap::new();
    for w in &waivers {
        for &r in &w.rules {
            for l in w.line..w.line + 4 {
                cover.entry((r, l)).or_insert((w.line, r));
            }
        }
    }
    let mut used: BTreeSet<(usize, Rule)> = BTreeSet::new();
    let mut emitted: BTreeSet<(usize, Rule)> = BTreeSet::new();
    let mut emit = |idx: usize, rule: Rule, message: String| {
        if emitted.contains(&(idx, rule)) {
            return;
        }
        if let Some(&w) = cover.get(&(rule, idx)) {
            used.insert(w);
            return;
        }
        emitted.insert((idx, rule));
        findings.push(Finding { path: rel.to_string(), line: idx + 1, rule, message });
    };
    let in_surface = |prefixes: &[&str]| prefixes.iter().any(|p| rel.starts_with(p));

    // D1: nondeterminism sources.
    if !in_surface(cfg.d1_exempt) {
        for (idx, line) in lexed.code.iter().enumerate() {
            if is_test[idx] {
                continue;
            }
            for &(pat, b, a) in D1_PATTERNS {
                for _ in find_bounded(line, pat, b, a) {
                    emit(
                        idx,
                        Rule::D1,
                        format!("nondeterminism source `{pat}` in contract-surface module"),
                    );
                }
            }
        }
    }

    // D2: unordered iteration over hash containers.
    if in_surface(cfg.d2_surface) {
        let names = collect_hash_names(&lexed.code, &is_test);
        for (idx, line) in lexed.code.iter().enumerate() {
            if is_test[idx] {
                continue;
            }
            for name in &names {
                for m in D2_METHODS {
                    let pat = format!("{name}{m}");
                    for _ in find_bounded(line, &pat, true, false) {
                        emit(
                            idx,
                            Rule::D2,
                            format!("unordered iteration `{pat}` over a hash container"),
                        );
                    }
                }
                let loops =
                    [format!("in &{name}"), format!("in &mut {name}"), format!("in {name}")];
                for fpat in loops {
                    for _ in find_bounded(line, &fpat, true, true) {
                        emit(
                            idx,
                            Rule::D2,
                            format!("unordered iteration `for .. {fpat}` over a hash container"),
                        );
                    }
                }
            }
        }
    }

    // D3: panic audit. A contiguous run of comment lines containing
    // INVARIANT: blesses every line of the run, so a multi-line invariant
    // comment (or one placed inside a method chain) satisfies the window.
    if in_surface(cfg.d3_surface) {
        let n = lexed.comments.len();
        let mut blessed = vec![false; n];
        let mut i = 0usize;
        while i < n {
            if lexed.comments[i].trim().is_empty() {
                i += 1;
                continue;
            }
            let mut j = i;
            while j < n && !lexed.comments[j].trim().is_empty() {
                j += 1;
            }
            if lexed.comments[i..j].iter().any(|c| c.contains("INVARIANT:")) {
                blessed[i..j].fill(true);
            }
            i = j;
        }
        for (idx, line) in lexed.code.iter().enumerate() {
            if is_test[idx] {
                continue;
            }
            let hits = find_bounded(line, ".unwrap()", false, true).len()
                + find_bounded(line, ".expect(", false, false).len();
            if hits == 0 {
                continue;
            }
            let lo = idx.saturating_sub(3);
            if blessed[lo..=idx].iter().any(|&b| b) {
                continue;
            }
            emit(
                idx,
                Rule::D3,
                "unwrap/expect without an INVARIANT: comment within 3 lines".to_string(),
            );
        }
    }

    // D4: count allocation tokens in budgeted modules (diffed by the caller).
    let d4_counts = if cfg.d4_budgeted.iter().any(|p| *p == rel) {
        let mut counts: BTreeMap<&'static str, usize> = BTreeMap::new();
        for (idx, line) in lexed.code.iter().enumerate() {
            if is_test[idx] {
                continue;
            }
            for &(pat, b, _) in ALLOC_PATTERNS {
                for q in find_bounded(line, pat, b, false) {
                    let tail = &line.as_bytes()[q + pat.len()..];
                    if pat == ".collect" && !(tail.starts_with(b"(") || tail.starts_with(b"::")) {
                        continue;
                    }
                    if pat == ".to_vec" && !tail.starts_with(b"(") {
                        continue;
                    }
                    *counts.entry(pat).or_insert(0) += 1;
                }
            }
        }
        Some(counts)
    } else {
        None
    };

    // D5: policy purity.
    if in_surface(cfg.d5_surface) {
        for (idx, line) in lexed.code.iter().enumerate() {
            if is_test[idx] {
                continue;
            }
            for &(pat, b, a) in D5_PATTERNS {
                for _ in find_bounded(line, pat, b, a) {
                    emit(
                        idx,
                        Rule::D5,
                        format!("interior mutability / global state `{pat}` in a policy module"),
                    );
                }
            }
        }
    }

    // W1: waivers that matched nothing.
    for w in &waivers {
        for &r in &w.rules {
            if !used.contains(&(w.line, r)) {
                findings.push(Finding {
                    path: rel.to_string(),
                    line: w.line + 1,
                    rule: Rule::W1,
                    message: format!("unused waiver for {}", r.as_str()),
                });
            }
        }
    }
    ScanOutput { findings, d4_counts }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn bounded_matching_respects_ident_edges() {
        assert_eq!(find_bounded("x = rand::foo()", "rand::", true, false), vec![4]);
        assert!(find_bounded("x = operand::foo()", "rand::", true, false).is_empty());
        assert_eq!(find_bounded("a.unwrap()", ".unwrap()", false, true), vec![1]);
        assert!(find_bounded("a.unwrap()x", ".unwrap()", false, true).is_empty());
    }

    #[test]
    fn hash_names_from_fields_and_lets() {
        let code = vec![
            "struct S { by_id: HashMap<u32, u32> }".to_string(),
            "let mut seen = HashSet::new();".to_string(),
        ];
        let names = collect_hash_names(&code, &[false, false]);
        assert!(names.contains("by_id"));
        assert!(names.contains("seen"));
    }

    #[test]
    fn rule_order_matches_string_order() {
        let rules = [Rule::D1, Rule::D2, Rule::D3, Rule::D4, Rule::D5, Rule::W0, Rule::W1];
        for w in rules.windows(2) {
            assert!(w[0] < w[1]);
            assert!(w[0].as_str() < w[1].as_str());
        }
    }
}
