//! D4 hot-path allocation inventory: allowlist parsing and diffing.
//!
//! The allowlist (`rust/src/lint/hot_alloc_allowlist.txt`) is the checked-in
//! budget: one `<module> <token> <count>` line per allocation token per
//! budgeted module. The diff is two-sided — a live count above its line is a
//! new allocation site that needs review, and a live count below (or a line
//! whose token vanished) is a stale budget that must be ratcheted down so
//! the headroom cannot be silently reclaimed later.

use std::collections::{BTreeMap, BTreeSet};
use std::fs;
use std::path::Path;

use anyhow::{Context, Result};

use crate::lint::{Finding, Rule};

/// `(module relpath, token) -> budgeted count`.
pub type Allowlist = BTreeMap<(String, String), usize>;

/// Live counts per budgeted module: `relpath -> token -> count`.
pub type D4Counts = BTreeMap<String, BTreeMap<&'static str, usize>>;

/// Parse allowlist text: `#`-comments and blank lines are skipped; any
/// other line must be `<relpath> <token> <count>` (unparseable lines are
/// ignored, matching a missing entry, so they surface as inventory drift).
pub fn parse_allowlist(text: &str) -> Allowlist {
    let mut allow = Allowlist::new();
    for ln in text.lines() {
        let ln = ln.trim();
        if ln.is_empty() || ln.starts_with('#') {
            continue;
        }
        let parts: Vec<&str> = ln.split_whitespace().collect();
        if parts.len() == 3 {
            if let Ok(count) = parts[2].parse::<usize>() {
                allow.insert((parts[0].to_string(), parts[1].to_string()), count);
            }
        }
    }
    allow
}

/// Load the allowlist from disk; a missing file is an empty budget (every
/// counted token then reads as a new allocation site).
pub fn parse_allowlist_file(path: &Path) -> Result<Allowlist> {
    if !path.is_file() {
        return Ok(Allowlist::new());
    }
    let text =
        fs::read_to_string(path).with_context(|| format!("reading {}", path.display()))?;
    Ok(parse_allowlist(&text))
}

/// Diff live counts against the allowlist. D4 findings carry line 0 (they
/// are file-level facts), which sorts them ahead of per-line findings.
pub fn diff(allow: &Allowlist, counts: &D4Counts) -> Vec<Finding> {
    let mut findings = Vec::new();
    let mut seen: BTreeSet<(String, String)> = BTreeSet::new();
    for (rel, per_tok) in counts {
        for (pat, &c) in per_tok {
            let key = (rel.clone(), (*pat).to_string());
            let want = allow.get(&key).copied().unwrap_or(0);
            seen.insert(key);
            if c > want {
                findings.push(Finding {
                    path: rel.clone(),
                    line: 0,
                    rule: Rule::D4,
                    message: format!("allocation inventory `{pat}` = {c}, allowlist {want}"),
                });
            } else if c < want {
                findings.push(Finding {
                    path: rel.clone(),
                    line: 0,
                    rule: Rule::D4,
                    message: format!("stale allowlist: `{pat}` = {c}, allowlist {want}"),
                });
            }
        }
    }
    for ((rel, pat), &want) in allow {
        if want > 0 && !seen.contains(&(rel.clone(), pat.clone())) {
            findings.push(Finding {
                path: rel.clone(),
                line: 0,
                rule: Rule::D4,
                message: format!("stale allowlist: `{pat}` absent, allowlist {want}"),
            });
        }
    }
    findings
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn parses_and_skips_comments() {
        let a = parse_allowlist("# header\n\nsim/shard.rs Vec::new 12\nsim/shard.rs vec![ 2\n");
        assert_eq!(a.len(), 2);
        assert_eq!(a[&("sim/shard.rs".to_string(), "Vec::new".to_string())], 12);
    }

    #[test]
    fn diff_flags_exceed_stale_and_absent() {
        let a = parse_allowlist("m.rs Vec::new 2\nm.rs format! 3\nm.rs Box::new 1\n");
        let mut counts = D4Counts::new();
        let mut per = BTreeMap::new();
        per.insert("Vec::new", 4usize); // exceeds 2
        per.insert("format!", 1usize); // below 3: stale
        counts.insert("m.rs".to_string(), per); // Box::new absent: stale
        let f = diff(&a, &counts);
        assert_eq!(f.len(), 3);
        assert!(f.iter().all(|x| x.rule == Rule::D4 && x.line == 0));
        assert!(f.iter().any(|x| x.message.contains("`Vec::new` = 4, allowlist 2")));
        assert!(f.iter().any(|x| x.message.contains("stale allowlist: `format!` = 1")));
        assert!(f.iter().any(|x| x.message.contains("`Box::new` absent")));
    }

    #[test]
    fn matching_counts_are_silent() {
        let a = parse_allowlist("m.rs Vec::new 2\n");
        let mut counts = D4Counts::new();
        counts.insert("m.rs".to_string(), BTreeMap::from([("Vec::new", 2usize)]));
        assert!(diff(&a, &counts).is_empty());
    }
}
