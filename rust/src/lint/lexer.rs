//! Comment/string/char-literal-aware lexer over one Rust source file.
//!
//! Splits a file into per-line *code text* and per-line *comment text*. In
//! the code view, the interiors of string literals, raw strings, byte
//! strings, char literals, and comments are masked with spaces (delimiters
//! are kept), so rule matching never fires on a banned token that only
//! appears inside a literal or a comment — which is what lets the lint
//! module lint itself, pattern tables and all. In the comment view, each
//! line carries the text of any comment on it, which is the only place the
//! waiver grammar is recognized.
//!
//! The state machine understands nested block comments, `r"…"`/`r#"…"#` raw
//! strings with arbitrary hash counts, `b"…"`/`br#"…"#` byte strings,
//! `b'x'` byte chars, and the char-literal vs. lifetime ambiguity (two
//! characters of lookahead: `'a'` is a char, `'a ` is a lifetime).

/// Per-line views of one source file produced by [`lex`].
#[derive(Debug)]
pub struct Lexed {
    /// Code with literal/comment interiors masked to spaces.
    pub code: Vec<String>,
    /// Comment text per line (empty when the line has no comment).
    pub comments: Vec<String>,
}

#[derive(Debug, Clone, Copy, PartialEq, Eq)]
enum St {
    Normal,
    Line,
    Block,
    Str,
    RawStr,
    Char,
}

fn flush(out: &mut Lexed, code: &mut String, comment: &mut String, st: &mut St) {
    out.code.push(code.trim_end_matches('\r').to_string());
    out.comments.push(std::mem::take(comment));
    code.clear();
    if *st == St::Line {
        *st = St::Normal;
    }
}

/// Lex `text` into masked code lines and comment lines (same line count).
pub fn lex(text: &str) -> Lexed {
    let chars: Vec<char> = text.chars().collect();
    let n = chars.len();
    let mut out = Lexed { code: Vec::new(), comments: Vec::new() };
    let mut code = String::new();
    let mut comment = String::new();
    let mut st = St::Normal;
    let mut depth = 0usize;
    let mut hashes = 0usize;
    let mut i = 0usize;
    while i < n {
        let c = chars[i];
        if c == '\n' {
            flush(&mut out, &mut code, &mut comment, &mut st);
            i += 1;
            continue;
        }
        match st {
            St::Normal => {
                let nxt = if i + 1 < n { chars[i + 1] } else { '\0' };
                if c == '/' && nxt == '/' {
                    st = St::Line;
                    code.push_str("  ");
                    i += 2;
                } else if c == '/' && nxt == '*' {
                    st = St::Block;
                    depth = 1;
                    code.push_str("  ");
                    i += 2;
                } else if c == '"' {
                    st = St::Str;
                    code.push('"');
                    i += 1;
                } else if c == 'r' || c == 'b' {
                    // Raw strings r".."/r#".."#, byte strings b"..",
                    // br#".."#, and byte char literals b'x'.
                    let mut j = i + 1;
                    let mut raw = c == 'r';
                    if c == 'b' && j < n && chars[j] == 'r' {
                        raw = true;
                        j += 1;
                    }
                    let mut handled = false;
                    if raw {
                        let mut k = j;
                        while k < n && chars[k] == '#' {
                            k += 1;
                        }
                        if k < n && chars[k] == '"' {
                            hashes = k - j;
                            for &ch in &chars[i..=k] {
                                code.push(ch);
                            }
                            i = k + 1;
                            st = St::RawStr;
                            handled = true;
                        }
                    }
                    if !handled && c == 'b' && i + 1 < n && chars[i + 1] == '"' {
                        code.push_str("b\"");
                        i += 2;
                        st = St::Str;
                        handled = true;
                    }
                    if !handled && c == 'b' && i + 1 < n && chars[i + 1] == '\'' {
                        // Byte char literal b'x': emit the prefix, then let
                        // the quote arm below classify the rest next round.
                        code.push('b');
                        i += 1;
                        handled = true;
                    }
                    if !handled {
                        code.push(c);
                        i += 1;
                    }
                } else if c == '\'' {
                    let nxt2 = if i + 2 < n { chars[i + 2] } else { '\0' };
                    if nxt == '\\' || (nxt2 == '\'' && nxt != '\'') {
                        st = St::Char;
                    }
                    code.push('\'');
                    i += 1;
                } else {
                    code.push(c);
                    i += 1;
                }
            }
            St::Line => {
                comment.push(c);
                code.push(' ');
                i += 1;
            }
            St::Block => {
                let nxt = if i + 1 < n { chars[i + 1] } else { '\0' };
                if c == '/' && nxt == '*' {
                    depth += 1;
                    comment.push_str("  ");
                    code.push_str("  ");
                    i += 2;
                } else if c == '*' && nxt == '/' {
                    depth -= 1;
                    code.push_str("  ");
                    i += 2;
                    if depth == 0 {
                        st = St::Normal;
                    } else {
                        comment.push_str("  ");
                    }
                } else {
                    comment.push(c);
                    code.push(' ');
                    i += 1;
                }
            }
            St::Str => {
                if c == '\\' {
                    code.push_str("  ");
                    i += 2;
                } else if c == '"' {
                    code.push('"');
                    st = St::Normal;
                    i += 1;
                } else {
                    code.push(' ');
                    i += 1;
                }
            }
            St::RawStr => {
                let mut closed = false;
                if c == '"' {
                    let mut k = i + 1;
                    let mut m = 0usize;
                    while k < n && chars[k] == '#' && m < hashes {
                        k += 1;
                        m += 1;
                    }
                    if m == hashes {
                        code.push('"');
                        for _ in 0..m {
                            code.push('#');
                        }
                        i = k;
                        st = St::Normal;
                        closed = true;
                    }
                }
                if !closed {
                    code.push(' ');
                    i += 1;
                }
            }
            St::Char => {
                if c == '\\' {
                    code.push_str("  ");
                    i += 2;
                } else if c == '\'' {
                    code.push('\'');
                    st = St::Normal;
                    i += 1;
                } else {
                    code.push(' ');
                    i += 1;
                }
            }
        }
    }
    if !code.is_empty() || !comment.is_empty() {
        flush(&mut out, &mut code, &mut comment, &mut st);
    }
    out
}

/// One flag per line: true when the line sits inside a `#[cfg(test)]` item.
///
/// Walks from just after each attribute to the end of the annotated item by
/// brace matching (a `;` before the first `{` ends an item-less form, e.g. a
/// cfg-gated `use`). Test-only code is exempt from every rule family.
pub fn test_lines(code: &[String]) -> Vec<bool> {
    const ATTR: &str = "#[cfg(test)]";
    let mut out = vec![false; code.len()];
    let mut li = 0usize;
    while li < code.len() {
        let col = match code[li].find(ATTR) {
            Some(c) => c,
            None => {
                li += 1;
                continue;
            }
        };
        let mut depth = 0i64;
        let mut started = false;
        let mut end = code.len() - 1;
        let mut done = false;
        let mut j = li;
        while j < code.len() && !done {
            let line = code[j].as_bytes();
            let mut k = if j == li { col + ATTR.len() } else { 0 };
            while k < line.len() {
                match line[k] {
                    b'{' => {
                        depth += 1;
                        started = true;
                    }
                    b'}' => {
                        depth -= 1;
                        if started && depth == 0 {
                            end = j;
                            done = true;
                            break;
                        }
                    }
                    b';' if !started => {
                        end = j;
                        done = true;
                        break;
                    }
                    _ => {}
                }
                k += 1;
            }
            if !done {
                j += 1;
            }
        }
        if !done {
            end = code.len() - 1;
        }
        out[li..=end].fill(true);
        li = end + 1;
    }
    out
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn masks_line_comments() {
        let l = lex("let x = 1; // Instant::now() here\n");
        assert_eq!(l.code[0], "let x = 1;                       ");
        assert_eq!(l.comments[0], " Instant::now() here");
    }

    #[test]
    fn masks_string_interiors_keeps_delimiters() {
        let l = lex("let s = \"Instant::now\";\n");
        assert_eq!(l.code[0], "let s = \"            \";");
        assert!(l.comments[0].is_empty());
    }

    #[test]
    fn escaped_quote_does_not_end_string() {
        let l = lex("let s = \"a\\\"b\"; let t = 1;\n");
        assert_eq!(l.code[0], "let s = \"    \"; let t = 1;");
    }

    #[test]
    fn raw_strings_with_hashes() {
        let l = lex("let s = r#\"x \" y\"#; let u = 2;\n");
        assert_eq!(l.code[0], "let s = r#\"     \"#; let u = 2;");
        let l = lex("let s = br##\"q\"##;\n");
        assert_eq!(l.code[0], "let s = br##\" \"##;");
    }

    #[test]
    fn byte_strings_and_byte_chars() {
        let l = lex("let s = b\"abc\"; let c = b'x';\n");
        assert_eq!(l.code[0], "let s = b\"   \"; let c = b' ';");
    }

    #[test]
    fn lifetimes_are_not_char_literals() {
        let l = lex("fn f<'a>(x: &'a str) -> &'a str { x }\n");
        assert_eq!(l.code[0], "fn f<'a>(x: &'a str) -> &'a str { x }");
        let l = lex("let c = 'z'; let d = '\\n';\n");
        assert_eq!(l.code[0], "let c = ' '; let d = '  ';");
    }

    #[test]
    fn nested_block_comments() {
        let l = lex("a /* one /* two */ still */ b\n");
        assert_eq!(l.code[0].replace(' ', ""), "ab");
        assert!(l.comments[0].contains("still"));
    }

    #[test]
    fn multiline_block_comment_spans_lines() {
        let l = lex("x /* c1\nc2 */ y\n");
        assert_eq!(l.comments[0], " c1");
        assert!(l.code[1].contains('y'));
        assert_eq!(l.comments[1], "c2 ");
    }

    #[test]
    fn last_line_without_newline_is_kept() {
        let l = lex("let a = 1;");
        assert_eq!(l.code.len(), 1);
        assert_eq!(l.code[0], "let a = 1;");
    }

    #[test]
    fn cfg_test_items_are_marked() {
        let src = "fn live() {}\n#[cfg(test)]\nmod tests {\n  fn t() {}\n}\nfn live2() {}\n";
        let l = lex(src);
        let t = test_lines(&l.code);
        assert_eq!(t, vec![false, true, true, true, true, false]);
    }

    #[test]
    fn cfg_test_use_item_ends_at_semicolon() {
        let src = "#[cfg(test)]\nuse foo::bar;\nfn live() {}\n";
        let l = lex(src);
        let t = test_lines(&l.code);
        assert_eq!(t, vec![true, true, false]);
    }
}
