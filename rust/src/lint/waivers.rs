//! In-source waiver syntax.
//!
//! A waiver is a comment whose trimmed text *starts with* the token
//! `lint:allow` followed by a parenthesized rule list, a colon, and a
//! non-empty justification — e.g.
//! `// lint:allow(D1): ablation switch, read once at config build.`
//! It silences those rules on its own line and the 3 lines below. The
//! start-anchor means prose that merely mentions the syntax mid-sentence
//! (like this paragraph) is not a waiver. Anything that starts like a
//! waiver but fails to parse is reported as W0, and a waiver that silences
//! nothing is reported as W1 — waivers must carry their weight.

use crate::lint::rules::Rule;

/// One well-formed waiver comment.
#[derive(Debug)]
pub struct Waiver {
    /// 0-based line index of the comment.
    pub line: usize,
    /// The rules it waives (only D1..D5 are waivable).
    pub rules: Vec<Rule>,
}

/// Output of [`parse_waivers`]: the well-formed waivers plus the comments
/// that start like a waiver but fail the grammar (reported as W0).
#[derive(Debug)]
pub struct ParsedWaivers {
    pub waivers: Vec<Waiver>,
    /// (0-based line index, trimmed comment text).
    pub malformed: Vec<(usize, String)>,
}

const MARK: &str = "lint:allow";

/// Parse every comment line of one file for waivers.
pub fn parse_waivers(comments: &[String]) -> ParsedWaivers {
    let mut waivers = Vec::new();
    let mut malformed = Vec::new();
    for (idx, com) in comments.iter().enumerate() {
        let stripped = com.trim();
        let rest = match stripped.strip_prefix(MARK) {
            Some(r) => r,
            None => continue,
        };
        let mut ok = false;
        if let Some(body) = rest.strip_prefix('(') {
            if let Some(close) = body.find(')') {
                let ids: Option<Vec<Rule>> =
                    body[..close].split(',').map(|r| Rule::waivable(r.trim())).collect();
                let tail = body[close + 1..].trim_start();
                if let (Some(rules), Some(just)) = (ids, tail.strip_prefix(':')) {
                    if !rules.is_empty() && !just.trim().is_empty() {
                        waivers.push(Waiver { line: idx, rules });
                        ok = true;
                    }
                }
            }
        }
        if !ok {
            malformed.push((idx, stripped.to_string()));
        }
    }
    ParsedWaivers { waivers, malformed }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn com(lines: &[&str]) -> Vec<String> {
        lines.iter().map(|s| s.to_string()).collect()
    }

    #[test]
    fn well_formed_single_and_multi_rule() {
        let p = parse_waivers(&com(&[
            " lint:allow(D1): reads a worker-count knob.",
            " lint:allow(D2, D3): justified twice over.",
        ]));
        assert!(p.malformed.is_empty());
        assert_eq!(p.waivers.len(), 2);
        assert_eq!(p.waivers[0].rules, vec![Rule::D1]);
        assert_eq!(p.waivers[1].rules, vec![Rule::D2, Rule::D3]);
        assert_eq!(p.waivers[1].line, 1);
    }

    #[test]
    fn malformed_variants_are_rejected() {
        let bad = [
            " lint:allow(D9): unknown rule.",
            " lint:allow(D1) missing colon",
            " lint:allow(D1):",
            " lint:allow D1: no parens",
            " lint:allow(): empty list.",
        ];
        for b in bad {
            let p = parse_waivers(&com(&[b]));
            assert!(p.waivers.is_empty(), "accepted: {b}");
            assert_eq!(p.malformed.len(), 1, "not flagged: {b}");
        }
    }

    #[test]
    fn mid_sentence_mentions_are_not_waivers() {
        let p = parse_waivers(&com(&[" the lint:allow(D1): syntax is described here"]));
        assert!(p.waivers.is_empty());
        assert!(p.malformed.is_empty());
    }

    #[test]
    fn non_comment_lines_are_ignored() {
        let p = parse_waivers(&com(&["", "   ", " plain comment"]));
        assert!(p.waivers.is_empty());
        assert!(p.malformed.is_empty());
    }
}
