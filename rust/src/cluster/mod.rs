//! Cluster state: GPUs with kvcached instances, engine pools, model
//! residency, TP GPU groups, and activation/eviction/migration mechanics
//! (paper SS4, SS5.3, SS6.1).

pub mod gpu;

pub use gpu::{Cluster, FleetSpec, GpuDevice, GpuId, GpuKind, Residency};
