//! GPU devices, engine pools, and cluster-level model residency.
//!
//! A `GpuDevice` owns one `Kvcached` (the balloon driver instance for its
//! physical memory) and a reusable engine pool (paper SS5.3: engines are
//! pre-initialized with virtual address space; model activation draws one
//! from the pool and only pays weight loading + a one-time realignment).
//!
//! Model instances may span multiple GPUs (TP groups); the group is the
//! strict scheduling boundary (paper SS4). `Cluster` tracks residency and
//! performs the activation / eviction / migration mechanics whose latencies
//! come from `engine::loading`.
//!
//! ## Heterogeneous fleets: `GpuKind` + `FleetSpec`
//!
//! A fleet is an **ordered list of `(GpuKind, count)` segments** — e.g.
//! `4xh100+8xl4` — parsed by `FleetSpec::parse` (grammar mirrors the fault
//! spec: CSV-safe, `+`-separated, strict errors) and expanded left-to-right
//! into per-GPU profiles: memory bytes, a `GpuPerf` roofline, and $/hour.
//! `Cluster::from_fleet` is the general constructor; the historical
//! positional `Cluster::new(n_gpus, gpu_bytes, gpus_per_node, perf)` stays
//! as a uniform-fleet wrapper (prefer `from_fleet`; kept so frozen
//! byte-identity references compile unchanged — it prices GPUs at the H100
//! rate and records no kind).
//!
//! **Determinism rule:** kind profiles are *static data* — a `GpuKind`'s
//! memory/perf/cost tables are compile-time constants, never
//! runtime-configured per-GPU mutation. A `FleetSpec` therefore fully
//! determines the cluster, so fleet specs can ride sweep keys the way fault
//! specs do and `--jobs 1` ≡ `--jobs N` byte-identity extends to the fleet
//! axis. `FleetSpec::uniform(n, GpuKind::H100)` performs bit-identical
//! arithmetic to the historical uniform path (same memory bytes, same
//! `GpuPerf` values through the same operations).

use std::collections::BTreeMap;

use crate::engine::engine::{SimEngine, BLOCK_TOKENS};
use crate::engine::loading::{
    activation_seconds, retry_backoff_seconds, LoadStrategy, MAX_LOAD_ATTEMPTS,
};
use crate::engine::perf::GpuPerf;
use crate::kvcached::Kvcached;
use crate::model::spec::{ModelId, ModelSpec};

#[derive(Debug, Clone, Copy, PartialEq, Eq, PartialOrd, Ord, Hash)]
pub struct GpuId(pub u32);

impl std::fmt::Display for GpuId {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        write!(f, "gpu{}", self.0)
    }
}

/// A GPU SKU with a static profile: memory, roofline perf, and $/hour.
///
/// Profiles are compile-time constants (see the module-level determinism
/// rule). Rates are representative on-demand cloud prices — they only need
/// to be *relatively* right for cost-aware placement and the `CostLedger`.
#[derive(Debug, Clone, Copy, PartialEq, Eq, PartialOrd, Ord, Hash)]
pub enum GpuKind {
    L4,
    A10G,
    A100,
    H100,
}

impl GpuKind {
    pub const ALL: [GpuKind; 4] = [GpuKind::L4, GpuKind::A10G, GpuKind::A100, GpuKind::H100];

    /// Lower-case spec-grammar name (`4xh100` etc.).
    pub fn name(self) -> &'static str {
        match self {
            GpuKind::L4 => "l4",
            GpuKind::A10G => "a10g",
            GpuKind::A100 => "a100",
            GpuKind::H100 => "h100",
        }
    }

    pub fn parse(s: &str) -> Option<GpuKind> {
        GpuKind::ALL.into_iter().find(|k| k.name() == s)
    }

    /// Device memory available to kvcached.
    pub fn mem_bytes(self) -> u64 {
        match self {
            GpuKind::L4 => 24 * (1 << 30),
            GpuKind::A10G => 24 * (1 << 30),
            GpuKind::A100 => 40 * (1 << 30),
            // Exactly the historical uniform default (80 GiB) — load-bearing
            // for the `FleetSpec::uniform(n, H100)` bitwise-identity contract.
            GpuKind::H100 => 80 * (1 << 30),
        }
    }

    /// Roofline profile feeding activation/step/admission timing.
    pub fn perf(self) -> GpuPerf {
        match self {
            GpuKind::L4 => GpuPerf::l4(),
            GpuKind::A10G => GpuPerf::a10g(),
            GpuKind::A100 => GpuPerf::a100_40g(),
            GpuKind::H100 => GpuPerf::h100(),
        }
    }

    /// Representative on-demand rate, $/hour.
    pub fn cost_per_hour(self) -> f64 {
        match self {
            GpuKind::L4 => 0.70,
            GpuKind::A10G => 1.20,
            GpuKind::A100 => 2.40,
            GpuKind::H100 => 4.80,
        }
    }
}

impl std::fmt::Display for GpuKind {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        f.write_str(self.name())
    }
}

/// An ordered heterogeneous fleet: `(kind, count)` segments, expanded
/// left-to-right into GPU ids. Parsed from / displayed as the CSV-safe
/// grammar `<count>x<kind>[+<count>x<kind>…]`, e.g. `4xh100+8xl4` — safe to
/// embed in sweep point keys (no `,`/`;`/whitespace).
#[derive(Debug, Clone, PartialEq, Eq)]
pub struct FleetSpec {
    pub segments: Vec<(GpuKind, u32)>,
}

impl FleetSpec {
    /// The historical uniform cluster, as a fleet.
    pub fn uniform(n: u32, kind: GpuKind) -> Self {
        FleetSpec { segments: vec![(kind, n)] }
    }

    /// Parse `4xh100+8xl4`. Rejects empty specs, zero counts, unknown
    /// kinds, and malformed segments — errors name the offending segment,
    /// like the fault grammar.
    pub fn parse(spec: &str) -> Result<FleetSpec, String> {
        let spec = spec.trim();
        if spec.is_empty() {
            return Err("empty fleet spec (want e.g. `4xh100+8xl4`)".into());
        }
        let mut segments = Vec::new();
        for seg in spec.split('+') {
            let seg = seg.trim();
            let Some((count, kind)) = seg.split_once('x') else {
                return Err(format!("{seg:?}: want `<count>x<kind>`, e.g. `4xh100`"));
            };
            let count: u32 = count
                .parse()
                .map_err(|_| format!("{seg:?}: bad count {count:?}"))?;
            if count == 0 {
                return Err(format!("{seg:?}: count must be >= 1"));
            }
            let kind = GpuKind::parse(kind).ok_or_else(|| {
                let known: Vec<&str> = GpuKind::ALL.iter().map(|k| k.name()).collect();
                format!("{seg:?}: unknown GPU kind {kind:?} (known: {})", known.join(", "))
            })?;
            segments.push((kind, count));
        }
        Ok(FleetSpec { segments })
    }

    pub fn n_gpus(&self) -> u32 {
        self.segments.iter().map(|&(_, n)| n).sum()
    }

    /// Total fleet rate, $/hour (feeds the `CostLedger`).
    pub fn cost_per_hour(&self) -> f64 {
        self.segments.iter().map(|&(k, n)| k.cost_per_hour() * n as f64).sum()
    }

    /// Per-GPU kinds in id order (segment expansion).
    pub fn kinds(&self) -> Vec<GpuKind> {
        let mut v = Vec::with_capacity(self.n_gpus() as usize);
        for &(k, n) in &self.segments {
            for _ in 0..n {
                v.push(k);
            }
        }
        v
    }

    /// The reference kind for fleet-wide defaults (SLO baselines are derived
    /// from one profile per run): the first segment's kind.
    pub fn reference_kind(&self) -> GpuKind {
        self.segments[0].0
    }
}

impl std::fmt::Display for FleetSpec {
    /// Canonical form re-parses to the same spec (round-trip tested).
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        for (i, (k, n)) in self.segments.iter().enumerate() {
            if i > 0 {
                f.write_str("+")?;
            }
            write!(f, "{n}x{k}")?;
        }
        Ok(())
    }
}

/// Where a model instance currently lives.
#[derive(Debug, Clone)]
pub struct Residency {
    pub model: ModelId,
    /// GPUs of the (TP) group; length = spec.tp.
    pub gpus: Vec<GpuId>,
    /// Engine serving it (index into Cluster::engines).
    pub engine_idx: usize,
    /// Simulation time at which activation completes (requests wait until then).
    pub ready_at: f64,
    pub last_active: f64,
}

#[derive(Debug)]
pub struct GpuDevice {
    pub id: GpuId,
    pub kvc: Kvcached,
    /// Pre-initialized engines available on this GPU (paper SS5.3).
    pub engine_pool_free: u32,
    /// Node this GPU belongs to (parallel loading uses node-local lanes).
    pub node: u32,
}

#[derive(Debug)]
pub struct Cluster {
    pub gpus: Vec<GpuDevice>,
    /// Reusable engine pool per NODE (paper SS5.3): engines are processes
    /// with pre-reserved virtual address space; any GPU on the node can
    /// adopt one, so migrations on a node never deplete a single GPU's pool.
    pub node_pools: Vec<u32>,
    pub engines: Vec<SimEngine>,
    pub residency: BTreeMap<ModelId, Residency>,
    /// GPU -> resident models (reverse of `residency`), kept sorted by id so
    /// iteration order matches a residency-map scan. Maintained by
    /// activate/evict (and therefore migrate); lets per-GPU queries run in
    /// O(residents on that GPU) instead of scanning every model.
    gpu_residents: Vec<Vec<ModelId>>,
    /// Fleet-reference roofline (uniform fleets: THE perf; heterogeneous
    /// fleets: the first segment's kind). Per-GPU timing uses `perf_of`.
    pub perf: GpuPerf,
    /// Per-GPU rooflines in id order. Uniform fleets hold clones of `perf`,
    /// so per-GPU lookups do bit-identical arithmetic to the historical
    /// single-perf path. `pub(crate)` so the simulator's step loop can take
    /// a disjoint field borrow alongside `&mut engines`/`&mut gpus`.
    pub(crate) gpu_perfs: Vec<GpuPerf>,
    /// Per-GPU $/hour (static kind data; H100 rate for the kind-less
    /// positional constructor).
    gpu_costs: Vec<f64>,
    /// Per-GPU kind; `None` for clusters built via the positional
    /// constructor (arbitrary perf/memory, no SKU attached).
    gpu_kinds: Vec<Option<GpuKind>>,
    pub gpus_per_node: u32,
    pub load_strategy: LoadStrategy,
    /// Counters for SS7.5-style reporting.
    pub activations: u64,
    pub evictions: u64,
    pub migrations: u64,
    /// Fault-injection state (all inert by default; see `crate::fault`).
    /// Down GPUs are crashed or spot-preempted: nothing may be placed on
    /// them until the matching recovery event clears the flag.
    gpu_down: Vec<bool>,
    /// Per-GPU slowdown factor (>= 1.0; 1.0 = healthy). Engines serving a
    /// group take the max factor over the group's GPUs.
    gpu_slow: Vec<f64>,
    /// Monotonic count of weight-load attempts (the injector's clock).
    pub load_attempts: u64,
    /// Sorted, deduped attempt ordinals that fail (from the `FaultPlan`).
    load_fail_attempts: Vec<u64>,
    load_fail_cursor: usize,
    /// Backoff retries attempted after a failed load attempt.
    pub load_retries: u64,
    /// Loads that exhausted `MAX_LOAD_ATTEMPTS` and aborted the activation.
    pub load_failures: u64,
    /// Monotonic residency-topology version: bumped whenever the set of
    /// resident models or their GPU groups changes (activate/evict; migrate
    /// composes both). Together with the simulator's queue version it keys
    /// the sharded loop's `WindowPlan` cache — the plan partitions GPUs by
    /// residency TP-groups plus queue edges, so an unchanged version means
    /// the cached partition is still exact. Data-only: never read on the
    /// sequential (`shards = 1`) path.
    pub(crate) topo_version: u64,
}

impl Cluster {
    /// Uniform positional constructor (pre-`FleetSpec` API). Prefer
    /// `from_fleet`; this stays so frozen byte-identity references and
    /// existing call sites compile unchanged. Kind-less: GPUs are priced at
    /// the H100 rate and report `kind_of == None`.
    pub fn new(n_gpus: u32, gpu_bytes: u64, gpus_per_node: u32, perf: GpuPerf) -> Self {
        let per_gpu: Vec<(u64, GpuPerf, f64, Option<GpuKind>)> = (0..n_gpus)
            .map(|_| (gpu_bytes, perf.clone(), GpuKind::H100.cost_per_hour(), None))
            .collect();
        Cluster::build(per_gpu, gpus_per_node, perf)
    }

    /// Build a (possibly heterogeneous) cluster from a `FleetSpec`: GPU ids
    /// are assigned by left-to-right segment expansion, each with its kind's
    /// static memory/perf/cost profile. The fleet-reference `perf` is the
    /// first segment's kind (feeds fleet-wide SLO baselines).
    pub fn from_fleet(fleet: &FleetSpec, gpus_per_node: u32) -> Self {
        let per_gpu: Vec<(u64, GpuPerf, f64, Option<GpuKind>)> = fleet
            .kinds()
            .into_iter()
            .map(|k| (k.mem_bytes(), k.perf(), k.cost_per_hour(), Some(k)))
            .collect();
        Cluster::build(per_gpu, gpus_per_node, fleet.reference_kind().perf())
    }

    fn build(
        per_gpu: Vec<(u64, GpuPerf, f64, Option<GpuKind>)>,
        gpus_per_node: u32,
        perf: GpuPerf,
    ) -> Self {
        let n_gpus = per_gpu.len() as u32;
        let gpus = per_gpu
            .iter()
            .enumerate()
            .map(|(i, (bytes, _, _, _))| GpuDevice {
                id: GpuId(i as u32),
                kvc: Kvcached::new(*bytes, crate::kvcached::DEFAULT_PAGE_BYTES, 64),
                engine_pool_free: 8,
                node: i as u32 / gpus_per_node.max(1),
            })
            .collect();
        let n_nodes = n_gpus.div_ceil(gpus_per_node.max(1));
        let mut gpu_perfs = Vec::with_capacity(per_gpu.len());
        let mut gpu_costs = Vec::with_capacity(per_gpu.len());
        let mut gpu_kinds = Vec::with_capacity(per_gpu.len());
        for (_, p, c, k) in per_gpu {
            gpu_perfs.push(p);
            gpu_costs.push(c);
            gpu_kinds.push(k);
        }
        Cluster {
            gpus,
            node_pools: vec![8 * gpus_per_node.max(1); n_nodes as usize],
            engines: Vec::new(),
            residency: BTreeMap::new(),
            gpu_residents: vec![Vec::new(); n_gpus as usize],
            perf,
            gpu_perfs,
            gpu_costs,
            gpu_kinds,
            gpus_per_node,
            load_strategy: LoadStrategy::Parallel,
            activations: 0,
            evictions: 0,
            migrations: 0,
            gpu_down: vec![false; n_gpus as usize],
            gpu_slow: vec![1.0; n_gpus as usize],
            load_attempts: 0,
            load_fail_attempts: Vec::new(),
            load_fail_cursor: 0,
            load_retries: 0,
            load_failures: 0,
            topo_version: 0,
        }
    }

    /// Roofline of GPU `g` (uniform fleets: a clone of `perf`).
    pub fn perf_of(&self, g: usize) -> &GpuPerf {
        &self.gpu_perfs[g]
    }

    /// $/hour of GPU `g`.
    pub fn cost_per_hour_of(&self, g: usize) -> f64 {
        self.gpu_costs[g]
    }

    /// Kind of GPU `g` (`None` on kind-less positional clusters).
    pub fn kind_of(&self, g: usize) -> Option<GpuKind> {
        self.gpu_kinds[g]
    }

    /// Total fleet rate, $/hour — the `CostLedger` numerator's rate.
    pub fn fleet_cost_per_hour(&self) -> f64 {
        self.gpu_costs.iter().sum()
    }

    /// Mark GPU `g` crashed (true) or recovered (false).
    pub fn set_gpu_down(&mut self, g: usize, down: bool) {
        self.gpu_down[g] = down;
    }

    pub fn gpu_available(&self, g: usize) -> bool {
        !self.gpu_down[g]
    }

    pub fn any_gpu_down(&self) -> bool {
        self.gpu_down.iter().any(|&d| d)
    }

    /// Set the slowdown factor for GPU `g` (1.0 restores full speed).
    pub fn set_gpu_slow(&mut self, g: usize, factor: f64) {
        self.gpu_slow[g] = factor;
    }

    pub fn gpu_slow_factor(&self, g: usize) -> f64 {
        self.gpu_slow[g]
    }

    /// Max slowdown factor over a TP group (the whole group runs at the pace
    /// of its slowest shard).
    pub fn group_slow_factor(&self, gpus: &[GpuId]) -> f64 {
        gpus.iter().map(|g| self.gpu_slow[g.0 as usize]).fold(1.0, f64::max)
    }

    /// Install the plan's failing load-attempt ordinals (sorted, deduped).
    pub fn set_load_fail_attempts(&mut self, attempts: Vec<u64>) {
        debug_assert!(attempts.windows(2).all(|w| w[0] < w[1]), "ordinals must be sorted/deduped");
        self.load_fail_attempts = attempts;
        self.load_fail_cursor = 0;
    }

    /// Advance the load-attempt clock; true if this attempt is scheduled to
    /// fail. O(1): the ordinal list is sorted, so a cursor suffices. With an
    /// empty list this only bumps a counter - behavior is otherwise
    /// bit-identical to a fault-free run.
    fn next_load_attempt_fails(&mut self) -> bool {
        let ord = self.load_attempts;
        self.load_attempts += 1;
        if self.load_fail_cursor < self.load_fail_attempts.len()
            && self.load_fail_attempts[self.load_fail_cursor] == ord
        {
            self.load_fail_cursor += 1;
            return true;
        }
        false
    }

    pub fn n_gpus(&self) -> usize {
        self.gpus.len()
    }

    pub fn is_resident(&self, m: ModelId) -> bool {
        self.residency.contains_key(&m)
    }

    /// Models resident on GPU `g`, sorted by id (reverse residency index).
    pub fn residents_on(&self, g: usize) -> &[ModelId] {
        &self.gpu_residents[g]
    }

    /// Verify the reverse index agrees with `residency` (test support).
    pub fn check_residency_index(&self) -> bool {
        for (g, models) in self.gpu_residents.iter().enumerate() {
            if models.windows(2).any(|w| w[0] >= w[1]) {
                return false; // must stay sorted and duplicate-free
            }
            for m in models {
                match self.residency.get(m) {
                    Some(r) if r.gpus.contains(&GpuId(g as u32)) => {}
                    _ => return false,
                }
            }
        }
        let indexed: usize = self.gpu_residents.iter().map(|v| v.len()).sum();
        let expected: usize = self.residency.values().map(|r| r.gpus.len()).sum();
        indexed == expected
    }

    /// Activate `spec` on the given GPU group at time `now`.
    /// Returns the residency ready time, or an error if memory is short or
    /// the load failed terminally (`KvError::LoadFailed`, fault injection).
    pub fn activate(
        &mut self,
        spec: &ModelSpec,
        gpus: Vec<GpuId>,
        now: f64,
    ) -> Result<f64, crate::kvcached::KvError> {
        self.activate_inner(spec, gpus, now, true)
    }

    fn activate_inner(
        &mut self,
        spec: &ModelSpec,
        gpus: Vec<GpuId>,
        now: f64,
        inject_load_faults: bool,
    ) -> Result<f64, crate::kvcached::KvError> {
        assert_eq!(gpus.len(), spec.tp as usize, "group size must equal TP degree");
        assert!(!self.is_resident(spec.id), "{} already resident", spec.id);

        // Injected load failures are consulted BEFORE any memory is mapped,
        // so a terminal failure needs no rollback: nothing was touched. Each
        // non-terminal failure retries after exponential backoff, which is
        // added to the ready latency. With no ordinals installed this loop
        // exits on its first probe and `retry_delay` stays exactly 0.0.
        let mut retry_delay = 0.0;
        if inject_load_faults {
            let mut attempt = 1u32;
            while self.next_load_attempt_fails() {
                if attempt >= MAX_LOAD_ATTEMPTS {
                    self.load_failures += 1;
                    return Err(crate::kvcached::KvError::LoadFailed { model: spec.id });
                }
                self.load_retries += 1;
                retry_delay += retry_backoff_seconds(attempt);
                attempt += 1;
            }
        }

        // Map weights on every GPU of the group.
        let per_gpu = spec.weight_bytes_per_gpu();
        let block_bytes = spec.kv_bytes_per_token() * BLOCK_TOKENS as u64;
        for (i, g) in gpus.iter().enumerate() {
            let dev = &mut self.gpus[g.0 as usize];
            if let Err(e) = dev.kvc.load_weights(spec.id, per_gpu) {
                // Roll back prior GPUs.
                for g2 in &gpus[..i] {
                    self.gpus[g2.0 as usize].kvc.unload_weights(spec.id);
                    self.gpus[g2.0 as usize].kvc.unregister_kv(spec.id);
                }
                return Err(e);
            }
            dev.kvc.register_kv(spec.id, block_bytes, u32::MAX);
        }

        // Engine from the node pool if available; else pay full init.
        let node = self.gpus[gpus[0].0 as usize].node as usize;
        let strategy = if self.node_pools[node] > 0 {
            self.node_pools[node] -= 1;
            self.load_strategy
        } else {
            LoadStrategy::Naive
        };
        let node_gpus = self.gpus_per_node;
        // Load timing follows the lead GPU's profile (PCIe/NVLink bandwidth
        // differs by kind); on uniform fleets this is a clone of `perf`, so
        // the arithmetic — and the result bits — match the historical path.
        let lead_perf = &self.gpu_perfs[gpus[0].0 as usize];
        let latency = activation_seconds(lead_perf, strategy, spec.weight_bytes(), node_gpus);
        // `t0 == now` bitwise when no retries fired (x + 0.0 is exact for
        // the non-negative times used here), preserving zero-fault identity.
        let t0 = now + retry_delay;

        let engine_idx = self.engines.len();
        self.engines.push(SimEngine::new(spec.clone()));
        for g in &gpus {
            let v = &mut self.gpu_residents[g.0 as usize];
            let pos = v.binary_search(&spec.id).unwrap_or_else(|p| p);
            v.insert(pos, spec.id);
        }
        self.residency.insert(
            spec.id,
            Residency {
                model: spec.id,
                gpus,
                engine_idx,
                ready_at: t0 + latency,
                last_active: now,
            },
        );
        self.activations += 1;
        self.topo_version += 1;
        Ok(t0 + latency)
    }

    /// Evict a model: drain its engine, unmap weights + KV, return the engine
    /// to the pool. Returns the drained (re-queueable) requests.
    pub fn evict(&mut self, m: ModelId) -> Vec<crate::request::Request> {
        let Some(res) = self.residency.remove(&m) else {
            return Vec::new();
        };
        for g in &res.gpus {
            self.gpu_residents[g.0 as usize].retain(|&x| x != m);
        }
        let engine = &mut self.engines[res.engine_idx];
        // Free all KV blocks via a group allocator view.
        let mut reqs = {
            let mut ga = GroupAlloc::new(&mut self.gpus, &res.gpus, m);
            engine.drain(&mut ga)
        };
        for g in &res.gpus {
            let dev = &mut self.gpus[g.0 as usize];
            dev.kvc.unload_weights(m);
            dev.kvc.unregister_kv(m);
        }
        let node = self.gpus[res.gpus[0].0 as usize].node as usize;
        self.node_pools[node] += 1;
        self.evictions += 1;
        self.topo_version += 1;
        for r in &mut reqs {
            r.phase = crate::request::Phase::Queued;
        }
        reqs
    }

    /// Migrate a resident single-GPU model to another GPU (paper SS6.1):
    /// overlapped with serving, only the switch-over is exposed. Returns the
    /// drained in-flight requests (they resume on the target) + ready time.
    pub fn migrate(
        &mut self,
        spec: &ModelSpec,
        to: GpuId,
        now: f64,
        nvlink: bool,
    ) -> Result<(Vec<crate::request::Request>, f64), crate::kvcached::KvError> {
        // INVARIANT: callers (policy migration hooks) only migrate models they
        // just observed in `residency`, and nothing runs between observation
        // and this call (crash events are separate heap events).
        let res = self.residency.get(&spec.id).expect("model resident").clone();
        assert_eq!(spec.tp, 1, "migration modelled for single-GPU models");
        let kv_bytes = self.engines[res.engine_idx].active_kv_bytes();
        let reqs = self.evict(spec.id);
        // Migrations copy already-materialized weights over NVLink while the
        // source keeps serving (paper SS6.1) - there is no cold load, so the
        // load-fault injector does not apply. (This also guarantees injected
        // faults can never strand the drained requests on the Err path.)
        let ready = match self.activate_inner(spec, vec![to], now, false) {
            Ok(_) => {
                // Overlapped migration: the exposed latency is the switch-over,
                // not the full reload (paper SS7.5: ~tens of ms over NVLink).
                // Switch-over is bounded by the *target* GPU's link speed.
                let sw = crate::engine::loading::migration_switchover_seconds(
                    &self.gpu_perfs[to.0 as usize],
                    spec.weight_bytes() + kv_bytes,
                    nvlink,
                );
                // INVARIANT: `activate_inner` just re-inserted this model's
                // residency entry on the Ok path.
                let r = self.residency.get_mut(&spec.id).unwrap();
                r.ready_at = now + sw;
                self.migrations += 1;
                self.activations -= 1; // counted as migration, not activation
                now + sw
            }
            Err(e) => return Err(e),
        };
        Ok((reqs, ready))
    }

}

/// Allocates KV blocks on every GPU of a TP group, atomically per block.
/// One instance lives per engine step: the scratch buffer makes multi-GPU
/// group allocation heap-free per token.
pub struct GroupAlloc<'a> {
    gpus: &'a mut [GpuDevice],
    group: &'a [GpuId],
    model: ModelId,
    /// Staging for one group block (width > 1 only); reused across the step.
    scratch: Vec<crate::kvcached::BlockRef>,
}

impl<'a> GroupAlloc<'a> {
    pub fn new(gpus: &'a mut [GpuDevice], group: &'a [GpuId], model: ModelId) -> Self {
        GroupAlloc { gpus, group, model, scratch: Vec::new() }
    }
}

impl<'a> crate::engine::engine::KvAlloc for GroupAlloc<'a> {
    fn width(&self) -> usize {
        self.group.len()
    }

    fn alloc_n(
        &mut self,
        n: u32,
        out: &mut Vec<crate::kvcached::BlockRef>,
    ) -> Result<(), crate::kvcached::KvError> {
        if self.group.len() == 1 {
            // Fast path (single-GPU groups, the common fleet): one batched
            // kvcached call amortizes the model lookup over the whole batch;
            // blocks allocated before a failure stay in `out` per the trait
            // contract.
            let g = self.group[0].0 as usize;
            return self.gpus[g].kvc.alloc_blocks(self.model, n, out);
        }
        // TP groups: block by block, so each appended block is complete on
        // every shard or rolled back entirely.
        for _ in 0..n {
            self.scratch.clear();
            for g in self.group.iter() {
                match self.gpus[g.0 as usize].kvc.alloc_block(self.model) {
                    Ok(b) => self.scratch.push(b),
                    Err(e) => {
                        // Roll back this block's partial group allocation.
                        for (j, b) in self.scratch.drain(..).enumerate() {
                            let gj = self.group[j];
                            let _ = self.gpus[gj.0 as usize].kvc.free_block(b);
                        }
                        return Err(e);
                    }
                }
            }
            out.extend_from_slice(&self.scratch);
        }
        Ok(())
    }

    fn free_run(&mut self, refs: &[crate::kvcached::BlockRef]) {
        let width = self.group.len();
        for (i, &r) in refs.iter().enumerate() {
            let g = self.group[i % width];
            // INVARIANT: refs come from this group's own alloc_n in
            // block-major order, so ref i maps back to the GPU that issued it.
            self.gpus[g.0 as usize].kvc.free_block(r).expect("group free");
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::engine::engine::KvAlloc;
    use crate::model::spec::{catalog_subset, GB};

    fn cluster(n: u32) -> Cluster {
        Cluster::new(n, 80 * GB, 8, GpuPerf::default())
    }

    #[test]
    fn activate_and_evict_roundtrip() {
        let mut c = cluster(2);
        let spec = &catalog_subset(8)[2]; // an 8B model
        assert_eq!(spec.tp, 1);
        let ready = c.activate(spec, vec![GpuId(0)], 100.0).unwrap();
        assert!(ready > 100.0 && ready < 101.0, "pooled parallel load is sub-second");
        assert!(c.is_resident(spec.id));
        let w = c.gpus[0].kvc.stats().weight_bytes;
        assert!(w >= spec.weight_bytes_per_gpu());
        let reqs = c.evict(spec.id);
        assert!(reqs.is_empty());
        assert!(!c.is_resident(spec.id));
        assert_eq!(c.gpus[0].kvc.stats().weight_bytes, 0);
        assert!(c.gpus[0].kvc.check_conservation());
    }

    #[test]
    fn tp_group_spans_gpus() {
        let mut c = cluster(4);
        let cat = catalog_subset(8);
        let tp_model = cat.iter().find(|m| m.is_tp()).unwrap();
        let gpus: Vec<GpuId> = (0..tp_model.tp).map(GpuId).collect();
        c.activate(tp_model, gpus.clone(), 0.0).unwrap();
        for g in &gpus {
            assert!(c.gpus[g.0 as usize].kvc.stats().weight_bytes > 0);
        }
        // Group-wide block allocation touches all shards, block-major.
        let res = c.residency.get(&tp_model.id).unwrap().clone();
        let mut ga = GroupAlloc::new(&mut c.gpus, &res.gpus, tp_model.id);
        let mut b = Vec::new();
        ga.alloc_n(2, &mut b).unwrap();
        assert_eq!(b.len(), 2 * tp_model.tp as usize);
        for (i, r) in b.iter().enumerate() {
            assert_eq!(r.model, tp_model.id, "ref {i} belongs to the model");
        }
        ga.free_run(&b);
        for g in &gpus {
            assert_eq!(c.gpus[g.0 as usize].kvc.kv_used_blocks(tp_model.id), 0);
        }
    }

    #[test]
    fn engine_pool_exhaustion_forces_cold_start() {
        let mut c = cluster(1);
        c.node_pools[0] = 1;
        let cat = catalog_subset(8);
        let m1 = cat.iter().find(|m| m.name.contains("1b-ft00")).unwrap();
        let m2 = cat.iter().find(|m| m.name.contains("1b-ft01")).unwrap();
        let r1 = c.activate(m1, vec![GpuId(0)], 0.0).unwrap();
        let r2 = c.activate(m2, vec![GpuId(0)], 0.0).unwrap();
        assert!(r1 < 1.0, "pooled activation fast: {r1}");
        assert!(r2 > 5.0, "cold start pays engine init: {r2}");
    }

    #[test]
    fn oom_on_activation_rolls_back() {
        let mut c = Cluster::new(1, 4 * GB, 8, GpuPerf::default());
        let cat = catalog_subset(8);
        let big = cat.iter().find(|m| m.name.contains("8b")).unwrap(); // 16 GB > 4 GB
        assert!(c.activate(big, vec![GpuId(0)], 0.0).is_err());
        assert!(!c.is_resident(big.id));
        assert!(c.gpus[0].kvc.check_conservation());
        assert_eq!(c.gpus[0].kvc.stats().weight_bytes, 0);
    }

    #[test]
    fn reverse_index_tracks_residency() {
        let mut c = cluster(2);
        let cat = catalog_subset(8);
        let m1 = cat.iter().find(|m| m.name.contains("1b-ft00")).unwrap();
        let m2 = cat.iter().find(|m| m.name.contains("1b-ft01")).unwrap();
        c.activate(m1, vec![GpuId(0)], 0.0).unwrap();
        c.activate(m2, vec![GpuId(0)], 0.0).unwrap();
        let mut both = vec![m1.id, m2.id];
        both.sort();
        assert_eq!(c.residents_on(0).to_vec(), both);
        assert!(c.residents_on(1).is_empty());
        assert!(c.check_residency_index());
        c.migrate(m1, GpuId(1), 1.0, true).unwrap();
        assert_eq!(c.residents_on(0).to_vec(), vec![m2.id]);
        assert_eq!(c.residents_on(1).to_vec(), vec![m1.id]);
        assert!(c.check_residency_index());
        c.evict(m2.id);
        assert!(c.residents_on(0).is_empty());
        assert!(c.check_residency_index());
    }

    #[test]
    fn reverse_index_covers_tp_groups() {
        let mut c = cluster(4);
        let cat = catalog_subset(8);
        let tp_model = cat.iter().find(|m| m.is_tp()).unwrap();
        let gpus: Vec<GpuId> = (0..tp_model.tp).map(GpuId).collect();
        c.activate(tp_model, gpus.clone(), 0.0).unwrap();
        for g in &gpus {
            assert_eq!(c.residents_on(g.0 as usize).to_vec(), vec![tp_model.id]);
        }
        assert!(c.check_residency_index());
        c.evict(tp_model.id);
        for g in &gpus {
            assert!(c.residents_on(g.0 as usize).is_empty());
        }
        assert!(c.check_residency_index());
    }

    #[test]
    fn injected_load_failures_retry_with_backoff_then_abort() {
        let cat = catalog_subset(8);
        let m1 = cat.iter().find(|m| m.name.contains("1b-ft00")).unwrap();
        let m2 = cat.iter().find(|m| m.name.contains("1b-ft01")).unwrap();

        // Fault-free baseline for the same activation.
        let mut healthy = cluster(2);
        let r_ok = healthy.activate(m1, vec![GpuId(0)], 0.0).unwrap();

        let mut c = cluster(2);
        // Attempt ordinal 0 fails once (retry succeeds on ordinal 1);
        // ordinals 2..=4 exhaust MAX_LOAD_ATTEMPTS for the next load.
        c.set_load_fail_attempts(vec![0, 2, 3, 4]);
        let r_retry = c.activate(m1, vec![GpuId(0)], 0.0).unwrap();
        assert!(
            (r_retry - r_ok - retry_backoff_seconds(1)).abs() < 1e-12,
            "one retry adds exactly one base backoff: {r_retry} vs {r_ok}"
        );
        assert_eq!(c.load_retries, 1);
        assert_eq!(c.load_failures, 0);

        match c.activate(m2, vec![GpuId(1)], 10.0) {
            Err(crate::kvcached::KvError::LoadFailed { model }) => assert_eq!(model, m2.id),
            other => panic!("expected terminal LoadFailed, got {other:?}"),
        }
        assert_eq!(c.load_retries, 3);
        assert_eq!(c.load_failures, 1);
        assert!(!c.is_resident(m2.id));
        // Terminal failure happens before any mapping: GPU 1 stays pristine.
        assert_eq!(c.gpus[1].kvc.stats().weight_bytes, 0);
        assert!(c.gpus[1].kvc.check_conservation());

        // Migrations copy live weights (no cold load): exempt from injection.
        c.set_load_fail_attempts(vec![c.load_attempts]);
        c.migrate(m1, GpuId(1), 20.0, true).unwrap();
        assert_eq!(c.load_failures, 1, "migration must not consume fault ordinals");
    }

    #[test]
    fn gpu_down_mask_and_slow_factors() {
        let mut c = cluster(4);
        assert!(c.gpu_available(2));
        assert!(!c.any_gpu_down());
        c.set_gpu_down(2, true);
        assert!(!c.gpu_available(2));
        assert!(c.any_gpu_down());
        c.set_gpu_down(2, false);
        assert!(!c.any_gpu_down());
        c.set_gpu_slow(1, 2.5);
        assert_eq!(c.group_slow_factor(&[GpuId(0), GpuId(1)]), 2.5);
        assert_eq!(c.group_slow_factor(&[GpuId(0)]), 1.0);
        c.set_gpu_slow(1, 1.0);
        assert_eq!(c.group_slow_factor(&[GpuId(0), GpuId(1)]), 1.0);
    }

    #[test]
    fn fleet_spec_round_trips_through_display() {
        for spec in ["4xh100", "4xh100+8xl4", "2xa100+4xl4+1xa10g", "1xl4+1xl4"] {
            let f = FleetSpec::parse(spec).unwrap();
            assert_eq!(f.to_string(), spec, "canonical form");
            assert_eq!(FleetSpec::parse(&f.to_string()).unwrap(), f, "round trip");
        }
        let f = FleetSpec::parse(" 2xh100 + 1xl4 ").unwrap();
        assert_eq!(f.to_string(), "2xh100+1xl4", "whitespace normalizes away");
    }

    #[test]
    fn fleet_spec_rejects_malformed() {
        for bad in ["", "0xh100", "4xh200", "h100", "4x", "x4", "4xh100+", "-1xl4", "4xh100;1xl4"]
        {
            assert!(FleetSpec::parse(bad).is_err(), "{bad:?} must be rejected");
        }
    }

    #[test]
    fn fleet_spec_accounting() {
        let f = FleetSpec::parse("2xa100+4xl4").unwrap();
        assert_eq!(f.n_gpus(), 6);
        assert_eq!(f.reference_kind(), GpuKind::A100);
        let want = 2.0 * GpuKind::A100.cost_per_hour() + 4.0 * GpuKind::L4.cost_per_hour();
        assert_eq!(f.cost_per_hour().to_bits(), want.to_bits());
        assert_eq!(
            f.kinds(),
            vec![GpuKind::A100, GpuKind::A100, GpuKind::L4, GpuKind::L4, GpuKind::L4, GpuKind::L4]
        );
        // Uniform shorthand expands like a single segment.
        let u = FleetSpec::uniform(3, GpuKind::H100);
        assert_eq!(u.to_string(), "3xh100");
        assert_eq!(u.n_gpus(), 3);
    }

    #[test]
    fn from_fleet_builds_per_kind_profiles() {
        let f = FleetSpec::parse("1xh100+2xl4").unwrap();
        let c = Cluster::from_fleet(&f, 8);
        assert_eq!(c.n_gpus(), 3);
        assert_eq!(c.kind_of(0), Some(GpuKind::H100));
        assert_eq!(c.kind_of(1), Some(GpuKind::L4));
        assert_eq!(c.kind_of(2), Some(GpuKind::L4));
        assert!(c.gpus[0].kvc.stats().total_bytes > c.gpus[1].kvc.stats().total_bytes);
        assert_eq!(c.cost_per_hour_of(0), GpuKind::H100.cost_per_hour());
        assert_eq!(c.fleet_cost_per_hour().to_bits(), f.cost_per_hour().to_bits());
        // Reference perf = first segment's kind; per-GPU perf follows kinds.
        assert_eq!(c.perf.peak_flops.to_bits(), GpuPerf::h100().peak_flops.to_bits());
        assert_eq!(c.perf_of(2).peak_flops.to_bits(), GpuPerf::l4().peak_flops.to_bits());
        // Kind-less positional clusters: no kind, H100 pricing.
        let legacy = cluster(2);
        assert_eq!(legacy.kind_of(0), None);
        assert_eq!(legacy.cost_per_hour_of(1), GpuKind::H100.cost_per_hour());
    }

    #[test]
    fn migration_exposes_only_switchover() {
        let mut c = cluster(2);
        let cat = catalog_subset(8);
        let m = cat.iter().find(|m| m.name.contains("1b-ft00")).unwrap();
        c.activate(m, vec![GpuId(0)], 0.0).unwrap();
        let (reqs, ready) = c.migrate(m, GpuId(1), 50.0, true).unwrap();
        assert!(reqs.is_empty());
        assert!(ready - 50.0 < 0.05, "switch-over must be tens of ms: {}", ready - 50.0);
        assert_eq!(c.residency.get(&m.id).unwrap().gpus, vec![GpuId(1)]);
        assert_eq!(c.migrations, 1);
        assert_eq!(c.gpus[0].kvc.stats().weight_bytes, 0);
    }
}
