//! The discrete-event cluster simulator.
//!
//! Replays a `Trace` against a `Cluster` under a
//! [`SchedulingPolicy`](crate::sim::policies::SchedulingPolicy), producing
//! `RunMetrics` + timeline samples. Event kinds: request arrivals, engine
//! iterations (variable duration from the perf model), control epochs
//! (placement/eviction), and timeline samples.
//!
//! The simulator core is policy-agnostic: every policy decision (initial
//! placement, non-resident routing, the control epoch, load strategy,
//! admission classification) dispatches through the policy trait, with
//! hooks operating on a [`PolicyCtx`] facade over this module's state. The
//! policies themselves live in `sim/policies/`.
//!
//! # Hot-path complexity budget
//!
//! The event loop is sized for cluster-scale replays (50-100 models on
//! 16-32 GPUs over hour-long traces), so per-event work is bounded:
//!
//! * **O(log heap)** heap pop/push per event, with the heap held to
//!   O(active events): arrivals stream from the time-sorted trace through a
//!   cursor instead of being pre-pushed (`SimConfig::stream_arrivals`).
//! * **O(1)** `ModelId -> specs index` via `model_index`, built once at
//!   construction - never a linear scan of `specs`.
//! * **O(residents on that GPU)** for per-GPU queries via the cluster's
//!   reverse index (`Cluster::residents_on`), kept in sync by
//!   activate/evict/migrate - never a scan of the full residency map.
//! * **O(models)** demand refresh at most once per distinct event time
//!   (`refresh_demand`, invalidated when token rates record); the monitor
//!   read (`RateMonitor::rate_at`) is non-mutating and clone-free.
//! * **O(models + gpus)** control-epoch overhead on top of the placement
//!   algorithm itself (Algorithm 1 is O(models x gpus) by design).
//! * **O(lookahead)** arrival memory under lazy rate scaling
//!   ([`Simulator::run_scaled`]): scaled replicas are generated at the
//!   cursor, never materialized as a per-point trace copy.
//!
//! The layers below carry their own per-token budgets (see the module docs
//! of `engine::engine` and `kvcached::manager`): one engine iteration does
//! O(1) amortized, allocation-free block alloc/free per decode token —
//! no O(batch²) rescans, no O(slots) bitmap scans, no O(partial) retains.
//!
//! Anything super-linear in models x gpus per *event* is a regression; the
//! trend is tracked by `benches/sim_hot_path.rs` (simulated-events/sec,
//! recorded in BENCH_sim.json; the KV-churn scenario isolates the
//! allocator under preemption pressure).
//!
//! SLO assignment follows the paper's methodology (SS7.1): per-model base
//! SLOs correspond to dedicated-GPU latency (computed from the perf model),
//! then scaled by `slo_scale`.

use std::cmp::Reverse;
use std::collections::{BTreeMap, BTreeSet, BinaryHeap, HashMap};
use std::sync::Arc;

use crate::cluster::gpu::GroupAlloc;
use crate::cluster::{Cluster, FleetSpec, GpuId, GpuKind, Residency};
use crate::engine::perf::GpuPerf;
use crate::fault::{CrashedRequests, FaultAction, FaultPlan};
use crate::kvcached::{KvError, MemStats};
use crate::metrics::{RunMetrics, TimelineSample};
use crate::model::spec::{ModelId, ModelSpec};
use crate::request::{Phase, Request};
use crate::sched::arbitration::{moore_hodgson, Candidate};
use crate::sched::kvpr::{kvpr, ModelDemand, RateMonitor};
use crate::sched::placement::EvictionPolicy;
use crate::sim::policies::{by_name, PolicyHandle};
use crate::trace::{ScaledEvents, Trace, TraceEvent};

#[derive(Debug, Clone)]
pub struct SimConfig {
    /// The scheduling policy driving this run, shared and stateless; see
    /// `sim/policies/`. Resolved from a registry name by
    /// [`SimConfig::new`].
    pub policy: PolicyHandle,
    pub n_gpus: u32,
    pub gpu_bytes: u64,
    pub gpus_per_node: u32,
    pub perf: GpuPerf,
    /// Placement/eviction control epoch (s).
    pub control_epoch: f64,
    /// KVPR monitoring window (s) - Fig 15b.
    pub monitor_window: f64,
    /// Migration threshold tau on KVPR improvement.
    pub tau: f64,
    pub eviction: EvictionPolicy,
    /// SLO scale factor applied to the per-model base SLOs.
    pub slo_scale: f64,
    /// Timeline sampling interval (s); 0 disables sampling.
    pub sample_dt: f64,
    /// Disable Prism idle eviction. Resolved once from `PRISM_NO_EVICT` at
    /// construction (the experiments CLI override) instead of re-reading the
    /// environment every control epoch.
    pub no_evict: bool,
    /// Disable Prism migration (env `PRISM_NO_MIGRATE`, resolved once).
    pub no_migrate: bool,
    /// Slack-aware (Moore-Hodgson) admission: the policy classification
    /// combined with the `PRISM_NO_MH` env override, resolved once.
    pub slack_aware: bool,
    /// Stream arrivals from a cursor over the time-sorted trace (default).
    /// `false` pre-pushes every arrival into the event heap - the legacy
    /// formulation, kept for A/B regression tests and heap-size benchmarks.
    pub stream_arrivals: bool,
    /// Retain every raw `Completion` (plus exact percentile views) in the
    /// run's `RunMetrics`. Off by default: the streaming sink keeps only
    /// counters and quantile sketches, so cluster-scale sweep points stop
    /// holding every completion in memory. Opt in for tests/figures that
    /// need exact percentiles or per-request records.
    pub metrics_full_dump: bool,
    /// Deterministic fault schedule (see `crate::fault`): faults are pure
    /// config data, resolved before the run, never drawn from RNG inside
    /// the event loop. The default (empty) plan is bit-identical to a
    /// fault-free simulator.
    pub faults: FaultPlan,
    /// Heterogeneous fleet (ordered `GpuKind` segments, see
    /// `crate::cluster::FleetSpec`). `None` — the historical default —
    /// builds the uniform cluster from `n_gpus`/`gpu_bytes`/`perf`. Set via
    /// the [`fleet`](Self::fleet) builder, which also syncs `n_gpus`,
    /// `gpu_bytes`, and `perf` (fleet-wide SLO baselines derive from the
    /// fleet's reference kind: its first segment).
    pub fleet: Option<FleetSpec>,
    /// Intra-run shard count for the GPU-group-sharded event loop (see
    /// `sim::shard`): `1` — the default — is the historical single-threaded
    /// loop, bit-for-bit; `0` resolves to [`crate::util::parallelism`] (the
    /// same auto rule as the sweep engine's `--jobs 0`); `N > 1` runs
    /// per-GPU-group event streams on N worker threads between control-epoch
    /// barriers, with metric-fingerprint identity to `shards = 1`
    /// (regression-tested in `tests/shard_identity.rs`).
    pub shards: u32,
}

/// Process-wide default for [`SimConfig::shards`], consumed at config
/// construction time (see [`SimConfig::set_default_shards`]).
static DEFAULT_SHARDS: std::sync::atomic::AtomicU32 = std::sync::atomic::AtomicU32::new(1);

impl SimConfig {
    /// Config for the named policy, resolved against the global
    /// [`registry`](crate::sim::policies::registry); panics on an unknown
    /// name (CLI surfaces pre-validate via the registry to report a proper
    /// error). Use [`with_policy`](Self::with_policy) for a policy object
    /// that is not globally registered.
    pub fn new(policy: &str, n_gpus: u32) -> Self {
        Self::with_policy(by_name(policy), n_gpus)
    }

    pub fn with_policy(policy: PolicyHandle, n_gpus: u32) -> Self {
        SimConfig {
            n_gpus,
            gpu_bytes: 80 * (1 << 30),
            gpus_per_node: 8,
            perf: GpuPerf::default(),
            control_epoch: 5.0,
            monitor_window: 60.0,
            tau: 0.2,
            eviction: EvictionPolicy::default(),
            slo_scale: 5.0,
            sample_dt: 0.0,
            // lint:allow(D1): ablation switches, read once at config build.
            no_evict: std::env::var("PRISM_NO_EVICT").is_ok(),
            no_migrate: std::env::var("PRISM_NO_MIGRATE").is_ok(),
            slack_aware: policy.slack_aware() && std::env::var("PRISM_NO_MH").is_err(),
            stream_arrivals: true,
            metrics_full_dump: false,
            faults: FaultPlan::default(),
            fleet: None,
            shards: DEFAULT_SHARDS.load(std::sync::atomic::Ordering::Relaxed),
            policy,
        }
    }

    // --------------------------------------------------------- fluent builder
    //
    // `SimConfig::for_policy("prism").gpus(4).slo_scale(8.0)` replaces the
    // field-poking sprawl at call sites. The positional constructors above
    // stay as thin wrappers so frozen byte-identity references compile
    // unchanged; new code should prefer the builder.

    /// Builder entry point: the named policy with every other knob at its
    /// default (1 GPU until [`gpus`](Self::gpus) or [`fleet`](Self::fleet)
    /// sizes the cluster).
    pub fn for_policy(policy: &str) -> Self {
        Self::new(policy, 1)
    }

    /// Builder entry point for a heterogeneous fleet:
    /// `SimConfig::from_fleet("melange", FleetSpec::parse("4xh100+8xl4")?)`.
    pub fn from_fleet(policy: &str, fleet: FleetSpec) -> Self {
        Self::for_policy(policy).fleet(fleet)
    }

    /// Uniform cluster size (ignored when a [`fleet`](Self::fleet) is set —
    /// the fleet's own GPU count wins).
    pub fn gpus(mut self, n_gpus: u32) -> Self {
        self.n_gpus = n_gpus;
        self
    }

    /// Serve on this fleet. Syncs the uniform knobs to the fleet's
    /// *reference kind* (first segment): `n_gpus`, `gpu_bytes`, and `perf`
    /// — fleet-wide SLO baselines derive from that reference profile, while
    /// per-GPU timing follows each GPU's own kind.
    pub fn fleet(mut self, fleet: FleetSpec) -> Self {
        let k = fleet.reference_kind();
        self.n_gpus = fleet.n_gpus();
        self.gpu_bytes = k.mem_bytes();
        self.perf = k.perf();
        self.fleet = Some(fleet);
        self
    }

    /// Deterministic fault schedule for this run.
    pub fn faults(mut self, plan: FaultPlan) -> Self {
        self.faults = plan;
        self
    }

    /// SLO scale factor applied to the per-model base SLOs.
    pub fn slo_scale(mut self, scale: f64) -> Self {
        self.slo_scale = scale;
        self
    }

    /// Uniform per-GPU memory (positional-cluster path only; a fleet's
    /// per-kind memory always wins).
    pub fn gpu_bytes(mut self, bytes: u64) -> Self {
        self.gpu_bytes = bytes;
        self
    }

    /// Timeline sampling interval (s); 0 disables sampling.
    pub fn sample_dt(mut self, dt: f64) -> Self {
        self.sample_dt = dt;
        self
    }

    /// Retain every raw `Completion` in the run's metrics (tests/figures).
    pub fn full_dump(mut self, on: bool) -> Self {
        self.metrics_full_dump = on;
        self
    }

    /// Stream arrivals from the trace cursor (default true; `false` is the
    /// legacy pre-push formulation kept for A/B regression).
    pub fn stream(mut self, on: bool) -> Self {
        self.stream_arrivals = on;
        self
    }

    /// Intra-run shard count: `1` = historical single-threaded loop,
    /// `0` = auto (`util::parallelism`), `N > 1` = sharded event loop.
    pub fn shards(mut self, n: u32) -> Self {
        self.shards = n;
        self
    }

    /// Set the process-wide default for [`shards`](Self::shards), applied to
    /// every `SimConfig` constructed afterwards. This is how
    /// `prism exp --shards N` reaches the experiment sweeps, whose configs
    /// are built deep inside the experiment modules; explicit `.shards(n)`
    /// calls and a non-default `SweepPoint` shard axis still override it.
    /// Call once, before any simulations run — flipping it mid-process would
    /// make config construction order-dependent.
    pub fn set_default_shards(n: u32) {
        DEFAULT_SHARDS.store(n, std::sync::atomic::Ordering::Relaxed);
    }
}

/// Per-model base SLOs from dedicated-GPU latency (paper SS7.1: P95 TTFT
/// 0.04-0.13 s, P95 TPOT 5.2-50.9 ms measured on dedicated GPUs).
pub fn base_slos(perf: &GpuPerf, spec: &ModelSpec) -> (f64, f64) {
    // Dedicated prefill of a typical ~500-token prompt + one iteration overhead.
    let ttft = 0.02 + 500.0 / perf.prefill_tokens_per_sec(spec) + perf.iter_overhead;
    // Dedicated decode at moderate batch with a couple GB of KV.
    let tpot = perf.decode_tpot(spec, 8, 2 << 30);
    (ttft, tpot)
}

#[derive(Debug, Clone, Copy, PartialEq)]
pub(crate) struct Time(pub(crate) f64);

impl Eq for Time {}

impl PartialOrd for Time {
    fn partial_cmp(&self, other: &Self) -> Option<std::cmp::Ordering> {
        Some(self.cmp(other))
    }
}

impl Ord for Time {
    fn cmp(&self, other: &Self) -> std::cmp::Ordering {
        // INVARIANT: every event time is derived from finite trace
        // timestamps, finite perf-model durations, and finite validated
        // fault times (`FaultPlan::validate` rejects non-finite input), so
        // a NaN here is a construction bug, not a runtime state.
        self.0.partial_cmp(&other.0).expect("no NaN times")
    }
}

#[derive(Debug, Clone, PartialEq, Eq)]
pub(crate) enum Ev {
    Arrival(usize),
    Step(ModelId),
    Epoch,
    Sample,
    /// Index into `Simulator::fault_schedule`; pushed only when the plan is
    /// non-empty, so a zero-fault run's heap is untouched.
    Fault(usize),
}

pub struct Simulator {
    pub cfg: SimConfig,
    pub specs: Vec<ModelSpec>,
    /// ModelId -> index into `specs`: O(1) hot-path lookups. Lookup-only
    /// (never iterated), so hash order cannot leak into results — D2-clean.
    /// (Fields below are `pub(crate)` for the sharded event loop in
    /// `sim::shard`, which distributes disjoint `&mut` borrows of them to
    /// worker threads between barriers; everything else stays private.)
    pub(crate) model_index: HashMap<ModelId, usize>,
    pub(crate) slos: Vec<(f64, f64)>,
    pub(crate) cluster: Cluster,
    /// Per-GPU shared admission queues (lead GPU for TP groups).
    pub(crate) gpu_queues: Vec<Vec<Request>>,
    /// Requests waiting for model activation (policy-dependent).
    pub(crate) pending: Vec<Request>,
    pub(crate) monitors: Vec<RateMonitor>,
    pub(crate) last_request_at: Vec<f64>,
    /// Per-model w_token_rate snapshot valid at `demand_cache_at`: one
    /// O(models) refresh per distinct event time instead of recomputing
    /// (and formerly cloning a monitor) per GPU x per model.
    demand_rates: Vec<f64>,
    pub(crate) demand_cache_at: f64,
    pub(crate) metrics: RunMetrics,
    pub timeline: Vec<TimelineSample>,
    pub(crate) heap: BinaryHeap<Reverse<(Time, u64, u8, usize)>>, // (time, seq, kind, payload)
    pub(crate) step_scheduled: BTreeSet<ModelId>,
    /// Time-sorted fault actions from `SimConfig::faults` (empty = no-op).
    pub(crate) fault_schedule: Vec<(f64, FaultAction)>,
    /// True iff the plan is non-empty: gates the (tiny) per-step degraded-
    /// mode bookkeeping so zero-fault runs skip it entirely.
    pub(crate) faults_enabled: bool,
    /// Crash time per evicted-by-crash model, until it is re-placed.
    crashed_at: BTreeMap<ModelId, f64>,
    pub(crate) seq: u64,
    pub(crate) next_req_id: u64,
    pub(crate) cum_violations: usize,
    pub(crate) tokens_since_sample: u64,
    /// Monotonic master-side queue-topology version: bumped whenever a
    /// request is *added* to a shared GPU queue outside the shard workers
    /// (`enqueue_on_gpu`, `PolicyCtx::{put,extend}_gpu_queue`). Removals
    /// never invalidate a cached `WindowPlan` (fewer edges only coarsen the
    /// union-find partition, which stays a valid superset-grouping), so
    /// `take_gpu_queue` and worker-side pops don't bump. Paired with
    /// `Cluster::topo_version` to key the sharded loop's plan cache; never
    /// read on the sequential path.
    pub(crate) queue_version: u64,
}

impl Simulator {
    pub fn new(cfg: SimConfig, specs: Vec<ModelSpec>) -> Self {
        let mut cfg = cfg;
        if let Some(f) = &cfg.fleet {
            // The fleet is authoritative for cluster size even if a caller
            // poked `n_gpus` after setting it.
            cfg.n_gpus = f.n_gpus();
        }
        let mut cluster = match &cfg.fleet {
            Some(f) => Cluster::from_fleet(f, cfg.gpus_per_node),
            None => Cluster::new(cfg.n_gpus, cfg.gpu_bytes, cfg.gpus_per_node, cfg.perf.clone()),
        };
        if let Err(e) = cfg.faults.validate(cfg.n_gpus) {
            panic!("invalid fault plan: {e}"); // CLI/sweep surfaces pre-validate
        }
        cluster.set_load_fail_attempts(cfg.faults.load_fail_attempts.clone());
        let fault_schedule = cfg.faults.schedule();
        let faults_enabled = !cfg.faults.is_empty();
        let slos = specs
            .iter()
            .map(|s| {
                let (t, p) = base_slos(&cfg.perf, s);
                (t * cfg.slo_scale, p * cfg.slo_scale)
            })
            .collect();
        let monitors = specs.iter().map(|_| RateMonitor::new(cfg.monitor_window)).collect();
        let n = specs.len();
        let model_index: HashMap<ModelId, usize> =
            specs.iter().enumerate().map(|(i, s)| (s.id, i)).collect();
        assert_eq!(model_index.len(), n, "duplicate model ids in specs");
        Simulator {
            model_index,
            gpu_queues: (0..cfg.n_gpus).map(|_| Vec::new()).collect(),
            pending: Vec::new(),
            monitors,
            last_request_at: vec![f64::NEG_INFINITY; n],
            demand_rates: vec![0.0; n],
            demand_cache_at: f64::NEG_INFINITY,
            metrics: RunMetrics::with_full_dump(cfg.metrics_full_dump),
            timeline: Vec::new(),
            heap: BinaryHeap::new(),
            step_scheduled: BTreeSet::new(),
            fault_schedule,
            faults_enabled,
            crashed_at: BTreeMap::new(),
            seq: 0,
            next_req_id: 0,
            cum_violations: 0,
            tokens_since_sample: 0,
            queue_version: 0,
            cluster,
            slos,
            specs,
            cfg,
        }
    }

    pub fn slo_of(&self, model_idx: usize) -> (f64, f64) {
        self.slos[model_idx]
    }

    /// Override per-model (TTFT, TPOT) SLOs (Fig 8 sweeps them per model).
    pub fn set_slos(&mut self, slos: Vec<(f64, f64)>) {
        assert_eq!(slos.len(), self.specs.len());
        self.slos = slos;
        self.demand_cache_at = f64::NEG_INFINITY; // w_token_rate depends on SLOs
    }

    pub(crate) fn idx_of(&self, m: ModelId) -> usize {
        self.model_index[&m]
    }

    /// Recompute the per-model w_token_rate snapshot unless one is already
    /// valid for `now`. Callers that record new tokens reset
    /// `demand_cache_at`, so a hit is always exact.
    fn refresh_demand(&mut self, now: f64) {
        if self.demand_cache_at == now {
            return;
        }
        for i in 0..self.specs.len() {
            let spec = &self.specs[i];
            let token_size = spec.kv_bytes_per_token() as f64 * spec.tp as f64;
            self.demand_rates[i] =
                self.monitors[i].rate_at(now) * token_size / self.slos[i].1.max(1e-6);
        }
        self.demand_cache_at = now;
    }

    /// Push a heap event.
    ///
    /// # Tie-break contract (load-bearing for the sharded loop)
    ///
    /// The heap key is `(time, seq, kind, payload)`: at equal timestamps
    /// events pop in **push order** (`seq` is a monotone counter bumped per
    /// push), NOT by kind priority — `kind` exists in the key only to break
    /// the (impossible, since `seq` is unique) tie deterministically. The
    /// canonical same-timestamp order Arrival < Step < Epoch < Sample <
    /// Fault therefore comes from the *push sites*, not this function: the
    /// preamble in `run_inner` pushes arrivals (pre-push mode), then
    /// epochs, then samples, then faults, and the streamed-arrival cursor
    /// wins time ties against the heap head (`at <= ht`) because pre-pushed
    /// arrivals would carry the lowest seqs. `sim::shard` reconstructs
    /// per-shard event order from exactly this FIFO-at-equal-time rule
    /// (seed events keep their master seqs; intra-window pushes get local
    /// seqs above the master snapshot), so changing the key — e.g. to
    /// kind-major — would silently break `--shards 1 ≡ --shards N`.
    /// Regression-tested by `event_heap_ties_pop_in_push_order`.
    pub(crate) fn push_ev(&mut self, t: f64, ev: Ev) {
        let (kind, payload) = match ev {
            Ev::Arrival(i) => (0u8, i),
            Ev::Step(m) => (1, m.0 as usize),
            Ev::Epoch => (2, 0),
            Ev::Sample => (3, 0),
            Ev::Fault(i) => (4, i),
        };
        self.seq += 1;
        self.heap.push(Reverse((Time(t), self.seq, kind, payload)));
    }

    pub(crate) fn schedule_step(&mut self, m: ModelId, t: f64) {
        if self.step_scheduled.insert(m) {
            self.push_ev(t, Ev::Step(m));
        }
    }

    // ------------------------------------------------------------ placement

    /// Pick GPUs for activating `spec` (lowest KVPR first, paper SS6.1).
    /// Crashed/preempted GPUs are excluded entirely (degraded mode); with
    /// every GPU healthy the filter passes everything through unchanged.
    fn pick_gpus(&mut self, spec: &ModelSpec, now: f64) -> Vec<GpuId> {
        self.refresh_demand(now);
        let mut scored: Vec<(f64, usize)> = (0..self.cluster.n_gpus())
            .filter(|&g| self.cluster.gpu_available(g))
            .map(|g| {
                let shared = self.cluster.gpus[g].kvc.shared_kv_bytes() as f64;
                let w: f64 = self
                    .cluster
                    .residents_on(g)
                    .iter()
                    .map(|m| self.demand_rates[self.model_index[m]])
                    .sum();
                (kvpr(w, shared), g)
            })
            .collect();
        // INVARIANT: kvpr() maps empty supply to +inf, never NaN, and
        // demand rates are finite — partial_cmp is total.
        scored.sort_by(|a, b| a.0.partial_cmp(&b.0).unwrap());
        scored.iter().take(spec.tp as usize).map(|&(_, g)| GpuId(g as u32)).collect()
    }

    fn demand_of(&self, m: ModelId, now: f64) -> ModelDemand {
        let idx = self.idx_of(m);
        let spec = &self.specs[idx];
        ModelDemand {
            model: m,
            token_rate: self.monitors[idx].rate_at(now),
            token_size: spec.kv_bytes_per_token() as f64 * spec.tp as f64,
            slo: self.slos[idx].1,
            weight_bytes_per_gpu: spec.weight_bytes_per_gpu(),
            tp: spec.tp,
        }
    }

    /// Make `spec` resident, evicting idle models if memory is short.
    /// Returns ready time, or None if it cannot fit right now. Retries are
    /// bounded: each attempt re-picks GPUs only after a successful eviction
    /// freed memory; with no evictable victim it gives up immediately.
    fn ensure_resident(&mut self, idx: usize, now: f64) -> Option<f64> {
        let spec = self.specs[idx].clone();
        if let Some(r) = self.cluster.residency.get(&spec.id) {
            return Some(r.ready_at);
        }
        // Loading strategy is a policy classification (e.g. QLM restarts
        // engines on swap, ServerlessLLM pays the full cold start).
        self.cluster.load_strategy = self.cfg.policy.load_strategy();
        const MAX_ACTIVATION_ATTEMPTS: usize = 8;
        for _ in 0..MAX_ACTIVATION_ATTEMPTS {
            let gpus = self.pick_gpus(&spec, now);
            if gpus.len() < spec.tp as usize {
                return None;
            }
            match self.cluster.activate(&spec, gpus, now) {
                Ok(ready) => {
                    self.note_recovered(spec.id, now);
                    return Some(ready);
                }
                Err(KvError::OutOfPages(_)) => {
                    // Evict the least-recently-active other idle resident,
                    // then retry with freshly re-picked GPUs.
                    let victim = self
                        .cluster
                        .residency
                        .values()
                        .filter(|r| r.model != spec.id)
                        .filter(|r| !self.cluster.engines[r.engine_idx].has_work())
                        // INVARIANT: `last_active` holds finite event times,
                        // so the comparison cannot hit NaN.
                        .min_by(|a, b| a.last_active.partial_cmp(&b.last_active).unwrap())
                        .map(|r| r.model);
                    match victim {
                        Some(v) => {
                            let reqs = self.evict_model(v);
                            self.pending.extend(reqs);
                        }
                        None => return None,
                    }
                }
                Err(_) => return None,
            }
        }
        None
    }

    fn evict_model(&mut self, m: ModelId) -> Vec<Request> {
        self.metrics.preemptions += self
            .cluster
            .residency
            .get(&m)
            .map(|r| self.cluster.engines[r.engine_idx].preemptions)
            .unwrap_or(0);
        self.cluster.evict(m)
    }

    // --------------------------------------------------------------- faults

    /// A model evicted by a GPU crash just became resident again: close its
    /// outage window. No-op (empty map) in fault-free runs.
    fn note_recovered(&mut self, m: ModelId, now: f64) {
        if let Some(t0) = self.crashed_at.remove(&m) {
            self.metrics.faults.models_recovered += 1;
            self.metrics.faults.recovery_seconds += now - t0;
        }
    }

    /// Apply one scheduled [`FaultAction`] (event kind 4). All state it
    /// touches is plain simulator/cluster data - determinism is inherited,
    /// faults never consult a clock or RNG at apply time.
    pub(crate) fn on_fault(&mut self, idx: usize, now: f64) {
        let (_, action) = self.fault_schedule[idx];
        match action {
            FaultAction::Crash(g) => self.on_gpu_crash(g as usize, now),
            FaultAction::Recover(g) => {
                self.cluster.set_gpu_down(g as usize, false);
                self.metrics.faults.gpu_recoveries += 1;
            }
            FaultAction::SlowStart(g, factor) => self.cluster.set_gpu_slow(g as usize, factor),
            FaultAction::SlowEnd(g) => self.cluster.set_gpu_slow(g as usize, 1.0),
            FaultAction::AllocArm(g, every) => {
                self.cluster.gpus[g as usize].kvc.arm_alloc_faults(every);
            }
            FaultAction::AllocDisarm(g) => {
                self.cluster.gpus[g as usize].kvc.disarm_alloc_faults();
            }
        }
    }

    /// GPU `g` crashed (or was spot-preempted): every model whose TP group
    /// touches it loses residency. In-flight and queued requests either
    /// restart from scratch via `pending` (re-routed by the policy at the
    /// next epoch, typically onto surviving GPUs) or are dropped and
    /// recorded, per `FaultPlan::on_crash` - never silently lost, so
    /// `completed + dropped == admitted` holds through crashes.
    fn on_gpu_crash(&mut self, g: usize, now: f64) {
        self.cluster.set_gpu_down(g, true);
        self.metrics.faults.gpu_crashes += 1;
        let victims: Vec<ModelId> = self.cluster.residents_on(g).to_vec();
        let drop_mode = self.cfg.faults.on_crash == CrashedRequests::Drop;
        for m in victims {
            // Queued requests live on the group's lead GPU (not always `g`).
            let lead = self.cluster.residency[&m].gpus[0].0 as usize;
            let (mine, rest): (Vec<Request>, Vec<Request>) =
                std::mem::take(&mut self.gpu_queues[lead]).into_iter().partition(|r| r.model == m);
            self.gpu_queues[lead] = rest;
            let mut reqs = self.evict_model(m);
            reqs.extend(mine);
            if drop_mode {
                self.metrics.faults.requests_dropped += reqs.len() as u64;
                for mut r in reqs {
                    r.phase = Phase::Dropped;
                    self.metrics.record(crate::request::Completion::from_request(&r));
                }
            } else {
                // Restart-prefill semantics: `Cluster::evict` drained the
                // engine and reset per-request progress; the requests
                // re-route at the next epoch.
                self.metrics.faults.requests_restarted += reqs.len() as u64;
                self.pending.extend(reqs);
            }
            self.crashed_at.entry(m).or_insert(now);
        }
    }

    // ------------------------------------------------------------- arrivals

    pub(crate) fn on_arrival(&mut self, e: &TraceEvent) {
        let now = e.t;
        let idx = e.model_idx;
        let (ttft_slo, tpot_slo) = self.slos[idx];
        let req = Request::new(
            self.next_req_id,
            self.specs[idx].id,
            now,
            e.prompt_tokens,
            e.output_tokens,
            ttft_slo,
            tpot_slo,
        );
        self.next_req_id += 1;
        self.monitors[idx].record(now, e.prompt_tokens as u64);
        self.demand_cache_at = f64::NEG_INFINITY; // rates changed
        self.last_request_at[idx] = now;
        if let Some(r) = self.cluster.residency.get_mut(&self.specs[idx].id) {
            r.last_active = now;
        }
        self.route(req, now);
    }

    fn route(&mut self, req: Request, now: f64) {
        if self.cluster.is_resident(req.model) {
            self.enqueue_on_gpu(req, now);
        } else {
            // Policy decision: activate on demand, park in `pending` (for
            // an epoch retry), or group-queue for epoch dispatch.
            let policy = Arc::clone(&self.cfg.policy);
            policy.route_nonresident(&mut PolicyCtx::new(self), req, now);
        }
    }

    fn enqueue_on_gpu(&mut self, req: Request, now: f64) {
        // INVARIANT: callers route here only after observing residency
        // (`route` checks `is_resident`; policies use `enqueue_resident`
        // under the same contract), and nothing between that check and
        // this call can evict - crash events are separate heap events,
        // never concurrent with routing.
        let res = self.cluster.residency.get(&req.model).expect("resident");
        let lead = res.gpus[0].0 as usize;
        let ready = res.ready_at;
        let m = req.model;
        self.gpu_queues[lead].push(req);
        self.queue_version += 1;
        self.schedule_step(m, now.max(ready));
    }

    // ------------------------------------------------------------ admission

    /// Admit requests from a GPU's shared queue into resident engines.
    fn admit_gpu(&mut self, g: usize, now: f64) {
        if self.gpu_queues[g].is_empty() {
            return;
        }
        let queue = std::mem::take(&mut self.gpu_queues[g]);
        let (mut admit, mut keep): (Vec<Request>, Vec<Request>) = if self.cfg.slack_aware {
            // Algorithm 2: Moore-Hodgson over prefill deadlines.
            // Deadline feasibility uses THIS GPU's roofline (uniform fleets:
            // a clone of `cfg.perf`, so the arithmetic is bit-identical).
            let gpu_perf = self.cluster.perf_of(g);
            let cands: Vec<Candidate> = queue
                .iter()
                .map(|r| {
                    let idx = self.idx_of(r.model);
                    let c = gpu_perf.prefill_tokens_per_sec(&self.specs[idx]);
                    Candidate {
                        id: r.id,
                        arrival: r.arrival,
                        deadline: r.ttft_deadline(),
                        exec: r.prompt_tokens as f64 / c,
                    }
                })
                .collect();
            let sched = moore_hodgson(now, &cands);
            // Admit the feasible set in EDF order, then the deferred ones
            // behind them: Moore-Hodgson decides priority, not starvation -
            // deferred requests are served late, not dropped (SS6.2).
            let mut order: BTreeMap<crate::request::RequestId, usize> = BTreeMap::new();
            for (i, id) in sched.admitted.iter().chain(sched.deferred.iter()).enumerate() {
                order.insert(*id, i);
            }
            let mut adm: Vec<Request> = queue;
            // Invariant (documented panic): `moore_hodgson` partitions its
            // candidate set, so admitted + deferred is exactly the queue and
            // the index covers every id.
            adm.sort_by_key(|r| order[&r.id]);
            (adm, Vec::new())
        } else {
            // FCFS.
            (queue, Vec::new())
        };

        // Hand admitted requests to their engines (bounded by engine batch).
        let mut still: Vec<Request> = Vec::new();
        let mut moved: Vec<(usize, Request)> = Vec::new();
        for req in admit.drain(..) {
            // Migration may have relocated the model: move the request to
            // its current lead GPU's queue.
            if let Some(res) = self.cluster.residency.get(&req.model) {
                let lead = res.gpus[0].0 as usize;
                if lead != g {
                    let m = req.model;
                    let t = res.ready_at.max(now);
                    moved.push((lead, req));
                    self.schedule_step(m, t);
                    continue;
                }
            }
            match self.cluster.residency.get(&req.model) {
                Some(res) if res.ready_at <= now + 1e-9 => {
                    let eidx = res.engine_idx;
                    let cap = self.cluster.engines[eidx].max_batch as usize * 2;
                    let load = self.cluster.engines[eidx].queue_len()
                        + self.cluster.engines[eidx].running_len();
                    if load < cap {
                        let m = req.model;
                        self.cluster.engines[eidx].admit(req);
                        self.schedule_step(m, now);
                    } else {
                        still.push(req);
                    }
                }
                Some(res) => {
                    let t = res.ready_at;
                    let m = req.model;
                    still.push(req);
                    // Re-kick when the model becomes ready.
                    self.schedule_step(m, t);
                }
                None => still.push(req), // evicted meanwhile; epoch will fix
            }
        }
        keep.extend(still);
        self.gpu_queues[g] = keep;
        for (lead, req) in moved {
            self.gpu_queues[lead].push(req);
        }
    }

    // ----------------------------------------------------------- engine step

    fn on_step(&mut self, m: ModelId, now: f64) {
        self.step_scheduled.remove(&m);
        let Some(res) = self.cluster.residency.get(&m) else {
            return; // evicted; requests were re-queued
        };
        if res.ready_at > now + 1e-9 {
            let t = res.ready_at;
            self.schedule_step(m, t);
            return;
        }
        let lead = res.gpus[0].0 as usize;
        // Admit from the shared queue first (slack-aware or FCFS).
        self.admit_gpu(lead, now);

        let Some(res) = self.cluster.residency.get(&m) else {
            return;
        };
        let eidx = res.engine_idx;
        let group = res.gpus.clone();
        if !self.cluster.engines[eidx].has_work() {
            return; // idle; a future arrival re-kicks
        }
        if self.faults_enabled {
            // Degraded mode: the group runs at its slowest shard's pace.
            // Gated on `faults_enabled` so zero-fault runs never touch
            // `time_scale` (which stays at its bitwise-identity default 1.0).
            let scale = self.cluster.group_slow_factor(&group);
            self.cluster.engines[eidx].time_scale = scale;
        }
        let outcome = {
            // Iteration timing follows the lead GPU's roofline (disjoint
            // field borrows: `gpu_perfs` is read-only while `engines`/`gpus`
            // are mutated). Uniform fleets hold clones of `cfg.perf`, so the
            // step arithmetic — and the result bits — match the historical
            // single-perf path.
            let lead_perf = &self.cluster.gpu_perfs[lead];
            let (engines, gpus) = (&mut self.cluster.engines, &mut self.cluster.gpus);
            let mut ga = GroupAlloc::new(gpus, &group, m);
            engines[eidx].step(now, lead_perf, &mut ga)
        };
        // Track violations for timelines, then stream each record into the
        // metrics sink (counters + sketches; raw retention is opt-in).
        if !outcome.completions.is_empty() {
            self.demand_cache_at = f64::NEG_INFINITY; // rates changed
        }
        for c in outcome.completions {
            if !c.ttft_ok() {
                self.cum_violations += 1;
            }
            self.tokens_since_sample += (c.prompt_tokens + c.output_tokens) as u64;
            // Decode-token production feeds the KVPR monitor (SS6.1).
            let idx = self.idx_of(c.model);
            self.monitors[idx].record(now, c.output_tokens as u64);
            self.metrics.record(c);
        }
        if let Some(r) = self.cluster.residency.get_mut(&m) {
            r.last_active = now;
        }
        if outcome.duration > 0.0 {
            self.schedule_step(m, now + outcome.duration);
        } else if self.cluster.engines[eidx].has_work() {
            let t = now + self.cluster.gpu_perfs[lead].iter_overhead;
            self.schedule_step(m, t);
        }
    }

    // ---------------------------------------------------------------- epoch

    pub(crate) fn on_epoch(&mut self, now: f64) {
        // Monitor housekeeping: actually drop expired rate events once per
        // epoch (reads between epochs skip them without mutating).
        for mon in &mut self.monitors {
            mon.expire_to(now);
        }
        // Policy decision: placement / eviction / group dispatch.
        let policy = Arc::clone(&self.cfg.policy);
        policy.on_epoch(&mut PolicyCtx::new(self), now);
        // Retry pending requests whose models can now be activated.
        let pending = std::mem::take(&mut self.pending);
        for req in pending {
            self.route(req, now);
        }
        // Re-admit every GPU queue: migration may have moved a model away
        // from the GPU whose queue holds its requests, and no engine step on
        // the old GPU would otherwise re-examine them.
        for g in 0..self.gpu_queues.len() {
            self.admit_gpu(g, now);
        }
        // Background prealloc refill (kvcached prep thread).
        for g in 0..self.cluster.n_gpus() {
            self.cluster.gpus[g].kvc.tick_prealloc();
        }
    }

    pub(crate) fn on_sample(&mut self, now: f64) {
        let gpus: Vec<(u64, u64, u64, u64)> = (0..self.cluster.n_gpus())
            .map(|g| {
                let st = self.cluster.gpus[g].kvc.stats();
                (st.weight_bytes, st.kv_mapped_bytes, st.kv_used_bytes, st.free_bytes)
            })
            .collect();
        let queue_lens: Vec<usize> = (0..self.cluster.n_gpus())
            .map(|g| {
                self.gpu_queues[g].len()
                    + self
                        .cluster
                        .residents_on(g)
                        .iter()
                        .map(|m| &self.cluster.residency[m])
                        .filter(|r| r.gpus[0].0 as usize == g)
                        .map(|r| {
                            self.cluster.engines[r.engine_idx].queue_len()
                                + self.cluster.engines[r.engine_idx].running_len()
                        })
                        .sum::<usize>()
            })
            .collect();
        let tput = self.tokens_since_sample as f64 / self.cfg.sample_dt.max(1e-9);
        self.tokens_since_sample = 0;
        self.timeline.push(TimelineSample {
            t: now,
            gpus,
            queue_lens,
            cum_violations: self.cum_violations,
            inst_token_tput: tput,
        });
    }

    // ------------------------------------------------------------------ run

    pub fn run(self, trace: &Trace) -> (RunMetrics, Vec<TimelineSample>) {
        self.run_scaled(trace, 1.0)
    }

    /// As [`run`](Self::run), with the trace's request volume scaled by
    /// `rate_scale` LAZILY at the arrival cursor: identical output to
    /// `run(&trace.scale_rate(rate_scale))` (regression-tested) without ever
    /// materializing the scaled event vector, so sweep points over the same
    /// base trace share it read-only. The legacy pre-push formulation has no
    /// cursor to scale through, so it still materializes.
    pub fn run_scaled(self, trace: &Trace, rate_scale: f64) -> (RunMetrics, Vec<TimelineSample>) {
        let scaling = (rate_scale - 1.0).abs() > 1e-12;
        if scaling && (!self.cfg.stream_arrivals || !trace.is_sorted()) {
            // The lazy cursor needs the streaming loop AND a time-sorted
            // base: `scale_rate` sorts globally, and the cursor can only
            // reproduce that order when base events already arrive in time
            // order. Materialize (which sorts) for the legacy pre-push mode
            // and for unsorted traces.
            let scaled = trace.scale_rate(rate_scale);
            return self.run_inner(&scaled, None);
        }
        if scaling {
            let cursor = ScaledEvents::new(trace, rate_scale);
            return self.run_inner(trace, Some(cursor));
        }
        self.run_inner(trace, None)
    }

    fn run_inner<'a>(
        mut self,
        trace: &'a Trace,
        mut scaled: Option<ScaledEvents<'a>>,
    ) -> (RunMetrics, Vec<TimelineSample>) {
        // Intra-run parallelism (`--shards`): the GPU-group-sharded loop in
        // `sim::shard` handles shards > 1. It needs the streamed-arrival
        // formulation over a time-sorted source (the lazy cursor is sorted
        // by construction); the legacy pre-push mode and unsorted traces
        // silently fall back to this sequential loop. `shards <= 1` never
        // enters the sharded path, so the historical loop below is the
        // bit-for-bit `--shards 1` reference by construction.
        let shards = match self.cfg.shards {
            0 => crate::util::parallelism(),
            n => n as usize,
        };
        if shards > 1 && self.cfg.stream_arrivals && (scaled.is_some() || trace.is_sorted()) {
            return self.run_sharded(trace, scaled, shards);
        }

        // Policy decision: t=0 placement (space sharers pre-place
        // everything that fits; time sharers start empty).
        let policy = Arc::clone(&self.cfg.policy);
        policy.initial_placement(&mut PolicyCtx::new(&mut self));

        // Arrivals stream from a cursor over the time-sorted trace, keeping
        // the heap at O(active events) instead of O(#trace events). An
        // unsorted trace (none of the generators produce one) gets a sorted
        // index so semantics never depend on input order. With a lazy
        // rate-scaling cursor (`scaled`), that cursor IS the arrival source
        // and emits in sorted order by construction.
        let stream = self.cfg.stream_arrivals;
        let order: Option<Vec<usize>> = if scaled.is_none() && stream && !trace.is_sorted() {
            let mut idx: Vec<usize> = (0..trace.events.len()).collect();
            // INVARIANT: trace event times are finite by generation.
            idx.sort_by(|&a, &b| trace.events[a].t.partial_cmp(&trace.events[b].t).unwrap());
            Some(idx)
        } else {
            None
        };
        let arrival_at = |i: usize| order.as_ref().map_or(i, |o| o[i]);
        let mut next_arrival = 0usize;
        if !stream {
            // Legacy formulation (A/B regression + heap-size benchmarks).
            debug_assert!(scaled.is_none(), "pre-push mode materializes scaled traces");
            for (i, e) in trace.events.iter().enumerate() {
                self.push_ev(e.t, Ev::Arrival(i));
            }
            next_arrival = trace.events.len();
        }

        let mut t = 0.0;
        while t < trace.duration {
            t += self.cfg.control_epoch;
            self.push_ev(t, Ev::Epoch);
        }
        if self.cfg.sample_dt > 0.0 {
            let mut t = 0.0;
            while t < trace.duration {
                self.push_ev(t, Ev::Sample);
                t += self.cfg.sample_dt;
            }
        }

        // Drain: keep processing until no work remains (bounded tail).
        let tail_limit = trace.duration + 600.0;

        // Fault actions become ordinary heap events (kind 4). An empty plan
        // pushes nothing, keeping the zero-fault heap (and `sim_events`)
        // bit-identical to a build without fault support.
        for i in 0..self.fault_schedule.len() {
            let t = self.fault_schedule[i].0;
            if t <= tail_limit {
                self.push_ev(t, Ev::Fault(i));
            }
        }

        let mut last_now = 0.0;
        loop {
            // Arrivals win time ties: in the pre-push formulation they carry
            // the lowest sequence numbers, so `<=` preserves event order.
            let heap_head = self.heap.peek().map(|Reverse((Time(ht), ..))| *ht);
            let arrival_head = match &mut scaled {
                Some(c) => c.peek_t(),
                None => (next_arrival < trace.events.len())
                    .then(|| trace.events[arrival_at(next_arrival)].t),
            };
            let take_arrival = match (arrival_head, heap_head) {
                (Some(at), Some(ht)) => at <= ht,
                (Some(_), None) => true,
                (None, _) => false,
            };
            if take_arrival {
                // INVARIANT: take_arrival is only true in match arms where
                // arrival_head is Some.
                let now = arrival_head.expect("take_arrival implies a head");
                if now > tail_limit {
                    break;
                }
                let e = match &mut scaled {
                    // INVARIANT: peek_time() returned Some above, and
                    // nothing advanced the cursor since.
                    Some(c) => c.next_event().expect("peeked event exists"),
                    None => {
                        let i = arrival_at(next_arrival);
                        next_arrival += 1;
                        trace.events[i].clone()
                    }
                };
                last_now = now;
                self.metrics.sim_events += 1;
                self.on_arrival(&e);
                continue;
            }
            let Some(Reverse((Time(now), _, kind, payload))) = self.heap.pop() else {
                break;
            };
            if now > tail_limit {
                break;
            }
            last_now = now;
            self.metrics.sim_events += 1;
            match kind {
                0 => {
                    let e = trace.events[payload].clone();
                    self.on_arrival(&e);
                }
                1 => self.on_step(ModelId(payload as u32), now),
                2 => {
                    self.on_epoch(now);
                    // Keep epochs running through the tail drain.
                    if now + self.cfg.control_epoch <= tail_limit
                        && (self.has_outstanding() || now < trace.duration)
                    {
                        self.push_ev(now + self.cfg.control_epoch, Ev::Epoch);
                    }
                }
                3 => self.on_sample(now),
                4 => self.on_fault(payload, now),
                _ => unreachable!(),
            }
        }

        // Unfinished requests at cutoff: record as dropped completions.
        let mut leftovers: Vec<Request> = std::mem::take(&mut self.pending);
        for q in &mut self.gpu_queues {
            leftovers.append(q);
        }
        for mut r in leftovers {
            r.phase = Phase::Dropped;
            self.metrics.record(crate::request::Completion::from_request(&r));
        }

        self.metrics.busy_seconds = self.cluster.engines.iter().map(|e| e.busy_seconds).sum();
        self.metrics.preemptions += self.cluster.engines.iter().map(|e| e.preemptions).sum::<u64>();
        self.metrics.wall_seconds = last_now;
        self.metrics.activations = self.cluster.activations;
        self.metrics.evictions = self.cluster.evictions;
        self.metrics.migrations = self.cluster.migrations;
        // Fault/recovery accounting (all zero - the `FaultStats` default -
        // in a fault-free run).
        self.metrics.faults.load_retries = self.cluster.load_retries;
        self.metrics.faults.load_failures = self.cluster.load_failures;
        self.metrics.faults.alloc_faults_injected = self
            .cluster
            .gpus
            .iter()
            .map(|d| d.kvc.alloc_faults_injected())
            .sum();
        // Cost ledger: the fleet's $/hour rate x simulated wall time.
        // Kind-less positional clusters price at the H100 rate, so every run
        // is comparable; metric fingerprints exclude cost, so the historical
        // byte-identity contracts are unaffected.
        self.metrics.cost.fleet_cost_per_hour = self.cluster.fleet_cost_per_hour();
        self.metrics.cost.cost_dollars = self.metrics.cost.fleet_cost_per_hour * last_now / 3600.0;
        (self.metrics, self.timeline)
    }

    pub(crate) fn has_outstanding(&self) -> bool {
        !self.pending.is_empty()
            || self.gpu_queues.iter().any(|q| !q.is_empty())
            || self.cluster.engines.iter().any(|e| e.has_work())
    }
}

/// The facade [`SchedulingPolicy`](crate::sim::policies::SchedulingPolicy)
/// hooks operate through: a curated view of the simulator state policies
/// actually need — demand snapshots, the residency map and its per-GPU
/// reverse index, pending/GPU queues, and kvcached memory pressure —
/// instead of `&mut Simulator` internals.
///
/// Every accessor is deterministic (ordered views only: residency is a
/// `BTreeMap`, the reverse index is sorted by id) and every mutation keeps
/// the simulator's internal indexes consistent, so policy hooks stay pure
/// w.r.t. this facade and the sweep engine's `--jobs 1` ≡ `--jobs N`
/// byte-identity contract survives (see `sweep/mod.rs`).
pub struct PolicyCtx<'a> {
    sim: &'a mut Simulator,
}

impl<'a> PolicyCtx<'a> {
    pub(crate) fn new(sim: &'a mut Simulator) -> Self {
        PolicyCtx { sim }
    }

    // ------------------------------------------------------------- queries

    pub fn n_gpus(&self) -> usize {
        self.sim.cluster.n_gpus()
    }

    /// The model catalog of this run; placement index `i` in
    /// [`activate`](Self::activate) refers to `specs()[i]`.
    pub fn specs(&self) -> &[ModelSpec] {
        &self.sim.specs
    }

    pub fn spec(&self, idx: usize) -> &ModelSpec {
        &self.sim.specs[idx]
    }

    /// O(1) `ModelId -> specs index`.
    pub fn model_idx(&self, m: ModelId) -> usize {
        self.sim.idx_of(m)
    }

    /// Migration threshold tau on KVPR improvement (`SimConfig::tau`).
    pub fn tau(&self) -> f64 {
        self.sim.cfg.tau
    }

    /// Idle-eviction tuning (`SimConfig::eviction`).
    pub fn eviction(&self) -> &EvictionPolicy {
        &self.sim.cfg.eviction
    }

    /// Ablation env override `PRISM_NO_EVICT`, resolved at construction.
    pub fn no_evict(&self) -> bool {
        self.sim.cfg.no_evict
    }

    /// Ablation env override `PRISM_NO_MIGRATE`, resolved at construction.
    pub fn no_migrate(&self) -> bool {
        self.sim.cfg.no_migrate
    }

    /// The residency map (model -> where it lives), in `ModelId` order.
    pub fn residency(&self) -> &BTreeMap<ModelId, Residency> {
        &self.sim.cluster.residency
    }

    pub fn residency_of(&self, m: ModelId) -> Option<&Residency> {
        self.sim.cluster.residency.get(&m)
    }

    /// Models resident on GPU `g`, sorted by id (the reverse index).
    pub fn residents_on(&self, g: usize) -> &[ModelId] {
        self.sim.cluster.residents_on(g)
    }

    /// Does the resident model's engine hold queued or running work?
    /// Panics if `m` is not resident (mirrors the policies' invariant that
    /// they only ask about models they just observed in `residency()`).
    pub fn engine_has_work(&self, m: ModelId) -> bool {
        // INVARIANT: documented panic (see doc comment above) — callers
        // only ask about models they just observed in residency().
        let r = self.sim.cluster.residency.get(&m).expect("model resident");
        self.sim.cluster.engines[r.engine_idx].has_work()
    }

    /// Is GPU `g` healthy (not crashed/spot-preempted)? Policies must not
    /// place, migrate to, or count capacity on unavailable GPUs; the
    /// simulator's own placement paths already filter them out.
    pub fn gpu_available(&self, g: usize) -> bool {
        self.sim.cluster.gpu_available(g)
    }

    /// Any GPU currently down? Cheap degraded-mode gate: `false` for every
    /// fault-free run, letting policies skip availability masking entirely.
    pub fn any_gpu_down(&self) -> bool {
        self.sim.cluster.any_gpu_down()
    }

    /// Kind of GPU `g` (`None` on kind-less uniform clusters built through
    /// the positional constructor). Static fleet data — safe for policies
    /// to branch on without breaking determinism.
    pub fn gpu_kind(&self, g: usize) -> Option<GpuKind> {
        self.sim.cluster.kind_of(g)
    }

    /// $/hour of GPU `g` (static kind data; H100 rate on kind-less
    /// clusters). Cost-aware policies rank GPUs by this.
    pub fn gpu_cost_per_hour(&self, g: usize) -> f64 {
        self.sim.cluster.cost_per_hour_of(g)
    }

    /// Total device memory of GPU `g` (heterogeneous fleets differ per GPU).
    pub fn gpu_mem_bytes(&self, g: usize) -> u64 {
        self.sim.cluster.gpus[g].kvc.stats().total_bytes
    }

    /// Roofline profile of GPU `g` (per-kind on heterogeneous fleets).
    pub fn gpu_perf(&self, g: usize) -> &GpuPerf {
        self.sim.cluster.perf_of(g)
    }

    /// kvcached memory stats for GPU `g`.
    pub fn kv_stats(&self, g: usize) -> MemStats {
        self.sim.cluster.gpus[g].kvc.stats()
    }

    /// Reclaimable KV headroom (free + idle-reclaimable) on GPU `g`.
    pub fn shared_kv_bytes(&self, g: usize) -> u64 {
        self.sim.cluster.gpus[g].kvc.shared_kv_bytes()
    }

    pub fn page_bytes(&self, g: usize) -> u64 {
        self.sim.cluster.gpus[g].kvc.page_bytes()
    }

    /// Requests parked for a later activation/dispatch, in arrival order.
    pub fn pending(&self) -> &[Request] {
        &self.sim.pending
    }

    /// Memory demand of model `m` from the KVPR monitor (paper SS6.1).
    pub fn demand_of(&self, m: ModelId, now: f64) -> ModelDemand {
        self.sim.demand_of(m, now)
    }

    /// Recompute the per-model `w_token_rate` snapshot unless one is
    /// already valid for `now` (cached per distinct event time).
    pub fn refresh_demand(&mut self, now: f64) {
        self.sim.refresh_demand(now);
    }

    /// KVPR of GPU `g` at `now` (demand-weighted pressure, units 1/s).
    pub fn gpu_kvpr(&mut self, g: usize, now: f64) -> f64 {
        self.sim.refresh_demand(now);
        let shared = self.sim.cluster.gpus[g].kvc.shared_kv_bytes() as f64;
        let w: f64 = self
            .sim
            .cluster
            .residents_on(g)
            .iter()
            .map(|m| self.sim.demand_rates[self.sim.model_index[m]])
            .sum();
        kvpr(w, shared)
    }

    // ----------------------------------------------------------- mutations

    /// Cap model `m`'s KV quota on GPU `g` (static-partition policies).
    /// Best-effort: an unknown model on `g` is ignored.
    pub fn set_kv_limit(&mut self, g: usize, m: ModelId, pages: u32) {
        let _ = self.sim.cluster.gpus[g].kvc.set_kv_limit(m, pages);
    }

    /// Activate `specs()[idx]` on `gpus`. Best-effort: if memory is short,
    /// the load fails terminally (fault injection), or any requested GPU is
    /// down, the model simply stays non-resident (t=0 placement semantics).
    pub fn activate(&mut self, idx: usize, gpus: Vec<GpuId>, now: f64) {
        if gpus.iter().any(|g| !self.sim.cluster.gpu_available(g.0 as usize)) {
            return;
        }
        let spec = self.sim.specs[idx].clone();
        if self.sim.cluster.activate(&spec, gpus, now).is_ok() {
            self.sim.note_recovered(spec.id, now);
        }
    }

    /// Make `specs()[idx]` resident (picking GPUs by lowest KVPR, evicting
    /// idle victims if memory is short). Returns the ready time, or `None`
    /// if it cannot fit right now.
    pub fn ensure_resident(&mut self, idx: usize, now: f64) -> Option<f64> {
        self.sim.ensure_resident(idx, now)
    }

    /// Evict model `m`, moving its in-flight and queued requests to
    /// `pending` (they re-route at the next epoch).
    pub fn evict_to_pending(&mut self, m: ModelId) {
        let reqs = self.sim.evict_model(m);
        self.sim.pending.extend(reqs);
    }

    pub fn push_pending(&mut self, req: Request) {
        self.sim.pending.push(req);
    }

    /// Remove and return every pending request of model `m`, preserving
    /// the relative order of the rest.
    pub fn take_pending_of(&mut self, m: ModelId) -> Vec<Request> {
        let (grp, rest): (Vec<Request>, Vec<Request>) =
            std::mem::take(&mut self.sim.pending).into_iter().partition(|r| r.model == m);
        self.sim.pending = rest;
        grp
    }

    /// Enqueue a request on its (resident) model's lead-GPU shared queue
    /// and schedule an engine step. Panics if the model is not resident.
    pub fn enqueue_resident(&mut self, req: Request, now: f64) {
        self.sim.enqueue_on_gpu(req, now);
    }

    /// Migrate resident model `m` to GPU `to`; returns success. A crashed
    /// target is refused outright. The caller is responsible for moving
    /// `m`'s queued requests (see [`take_gpu_queue`](Self::take_gpu_queue)).
    pub fn migrate(&mut self, m: ModelId, to: GpuId, now: f64) -> bool {
        if !self.sim.cluster.gpu_available(to.0 as usize) {
            return false;
        }
        let spec = self.sim.specs[self.sim.model_index[&m]].clone();
        self.sim.cluster.migrate(&spec, to, now, true).is_ok()
    }

    /// Detach GPU `g`'s shared admission queue (for filtering/moving).
    pub fn take_gpu_queue(&mut self, g: usize) -> Vec<Request> {
        std::mem::take(&mut self.sim.gpu_queues[g])
    }

    /// Re-attach a queue taken via [`take_gpu_queue`](Self::take_gpu_queue).
    pub fn put_gpu_queue(&mut self, g: usize, q: Vec<Request>) {
        self.sim.gpu_queues[g] = q;
        self.sim.queue_version += 1;
    }

    pub fn extend_gpu_queue(&mut self, g: usize, reqs: Vec<Request>) {
        self.sim.gpu_queues[g].extend(reqs);
        self.sim.queue_version += 1;
    }

    /// Schedule an engine step for model `m` at time `t` (deduplicated:
    /// at most one outstanding step event per model).
    pub fn schedule_step(&mut self, m: ModelId, t: f64) {
        self.sim.schedule_step(m, t);
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::model::spec::catalog_subset;
    use crate::trace::gen::{generate, TraceGenConfig};

    fn small_trace(n_models: usize, dur: f64, seed: u64) -> Trace {
        generate(&TraceGenConfig::novita_like(n_models, dur, seed))
    }

    fn specs_for(trace: &Trace) -> Vec<ModelSpec> {
        // Small models only so everything fits comfortably in tests.
        let cat = catalog_subset(30);
        (0..trace.n_models)
            .map(|i| {
                let mut s = cat[3 + i].clone(); // skip the big ones
                s.id = ModelId(i as u32);
                s
            })
            .collect()
    }

    fn run_policy(p: &str, n_gpus: u32, trace: &Trace) -> RunMetrics {
        let specs = specs_for(trace);
        let mut cfg = SimConfig::new(p, n_gpus);
        cfg.slo_scale = 10.0;
        let sim = Simulator::new(cfg, specs);
        let (m, _) = sim.run(trace);
        m
    }

    #[test]
    fn prism_serves_all_requests() {
        let trace = small_trace(4, 300.0, 11);
        let n = trace.events.len();
        assert!(n > 50);
        let m = run_policy("prism", 2, &trace);
        let done = m.completed();
        assert!(done as f64 > 0.95 * n as f64, "done {done}/{n}");
        assert!(m.ttft_attainment() > 0.5, "ttft att {}", m.ttft_attainment());
        assert!(m.busy_seconds > 0.0);
    }

    #[test]
    fn all_policies_complete_without_hanging() {
        let trace = small_trace(4, 180.0, 5);
        for p in crate::sim::policies::registry().names() {
            let m = run_policy(p, 2, &trace);
            assert!(m.total() > 0, "{} produced no completions", p);
            assert!(m.completed() > 0, "{} finished nothing", p);
        }
    }

    #[test]
    fn seallm_sixth_policy_runs_end_to_end() {
        // The first policy added purely through the SchedulingPolicy API
        // (no simulator edits): it must serve a trace like any built-in.
        let trace = small_trace(4, 240.0, 9);
        let n = trace.events.len();
        let m = run_policy("seallm", 2, &trace);
        assert!(m.total() > 0, "seallm recorded nothing");
        assert!(m.completed() as f64 > 0.9 * n as f64, "done {}/{n}", m.completed());
        assert!(m.busy_seconds > 0.0);
    }

    #[test]
    fn prism_beats_serverless_on_ttft() {
        let trace = small_trace(6, 600.0, 21);
        let prism = run_policy("prism", 2, &trace);
        let sls = run_policy("serverlessllm", 2, &trace);
        assert!(
            prism.ttft_attainment() > sls.ttft_attainment(),
            "prism {} <= serverless {}",
            prism.ttft_attainment(),
            sls.ttft_attainment()
        );
    }

    #[test]
    fn more_gpus_do_not_hurt() {
        let trace = small_trace(6, 300.0, 31).scale_rate(2.0);
        let a2 = run_policy("prism", 2, &trace).ttft_attainment();
        let a4 = run_policy("prism", 4, &trace).ttft_attainment();
        assert!(a4 >= a2 - 0.08, "2gpu={a2} 4gpu={a4}");
    }

    #[test]
    fn determinism_fixed_seed_metrics_identical() {
        let trace = small_trace(6, 400.0, 13);
        for p in ["prism", "qlm", "serverlessllm"] {
            let a = run_policy(p, 2, &trace);
            let b = run_policy(p, 2, &trace);
            assert_eq!(a.total(), b.total(), "{}", p);
            assert_eq!(a.ttft_attainment().to_bits(), b.ttft_attainment().to_bits(), "{}", p);
            assert_eq!(
                (a.activations, a.evictions, a.migrations, a.preemptions),
                (b.activations, b.evictions, b.migrations, b.preemptions),
                "{}",
                p
            );
            assert_eq!(a.sim_events, b.sim_events, "{}", p);
            assert!(a.sim_events > 0, "{}", p);
        }
    }

    #[test]
    fn streamed_arrivals_match_prepushed_heap() {
        // The streamed-cursor event loop must be observationally identical
        // to the legacy pre-pushed-arrival heap, for every policy.
        let trace = small_trace(6, 400.0, 29);
        for p in crate::sim::policies::registry().names() {
            let specs = specs_for(&trace);
            let mut cfg = SimConfig::new(p, 2);
            cfg.slo_scale = 10.0;
            let mut legacy_cfg = cfg.clone();
            legacy_cfg.stream_arrivals = false;
            let (a, _) = Simulator::new(cfg, specs.clone()).run(&trace);
            let (b, _) = Simulator::new(legacy_cfg, specs).run(&trace);
            assert_eq!(a.total(), b.total(), "{}", p);
            assert_eq!(a.ttft_attainment().to_bits(), b.ttft_attainment().to_bits(), "{}", p);
            assert_eq!(
                (a.activations, a.evictions, a.migrations, a.preemptions),
                (b.activations, b.evictions, b.migrations, b.preemptions),
                "{}",
                p
            );
            assert_eq!(a.sim_events, b.sim_events, "{}", p);
            assert_eq!(a.wall_seconds.to_bits(), b.wall_seconds.to_bits(), "{}", p);
        }
    }

    #[test]
    fn lazy_rate_scaling_matches_materialized_run() {
        // run_scaled(trace, f) must be observationally identical to
        // run(&trace.scale_rate(f)) — same arrivals in the same order, so
        // bitwise-equal metrics — for both streamed and pre-push loops.
        let trace = small_trace(5, 300.0, 23);
        let materialized = trace.scale_rate(2.5);
        for p in ["prism", "serverlessllm"] {
            for stream in [true, false] {
                let specs = specs_for(&trace);
                let mut cfg = SimConfig::new(p, 2);
                cfg.slo_scale = 10.0;
                cfg.stream_arrivals = stream;
                let (a, _) = Simulator::new(cfg.clone(), specs.clone()).run_scaled(&trace, 2.5);
                let (b, _) = Simulator::new(cfg, specs).run(&materialized);
                assert_eq!(a.total(), b.total(), "{} stream={stream}", p);
                assert_eq!(
                    a.ttft_attainment().to_bits(),
                    b.ttft_attainment().to_bits(),
                    "{} stream={stream}",
                    p
                );
                assert_eq!(a.sim_events, b.sim_events, "{} stream={stream}", p);
                assert_eq!(
                    (a.activations, a.evictions, a.migrations, a.preemptions),
                    (b.activations, b.evictions, b.migrations, b.preemptions),
                    "{} stream={stream}",
                    p
                );
                assert_eq!(
                    a.wall_seconds.to_bits(),
                    b.wall_seconds.to_bits(),
                    "{} stream={stream}",
                    p
                );
            }
        }
    }

    #[test]
    fn lazy_rate_scaling_unsorted_trace_falls_back_to_materializing() {
        // An unsorted base trace must not go through the lazy cursor (which
        // assumes time order); run_scaled still matches the materialized run.
        let mut trace = small_trace(4, 200.0, 37);
        assert!(trace.events.len() > 4);
        let n = trace.events.len();
        trace.events.swap(1, n - 2); // break time order
        assert!(!trace.is_sorted());
        let specs = specs_for(&trace);
        let mut cfg = SimConfig::new("prism", 2);
        cfg.slo_scale = 10.0;
        let (a, _) = Simulator::new(cfg.clone(), specs.clone()).run_scaled(&trace, 2.0);
        let (b, _) = Simulator::new(cfg, specs).run(&trace.scale_rate(2.0));
        assert_eq!(a.total(), b.total());
        assert_eq!(a.sim_events, b.sim_events);
        assert_eq!(a.ttft_attainment().to_bits(), b.ttft_attainment().to_bits());
    }

    #[test]
    fn ensure_resident_bounded_retries_under_pressure() {
        // GPUs too small for any model's weights: activation must give up
        // (None), not spin.
        let trace = small_trace(3, 60.0, 2);
        let specs = specs_for(&trace);
        let mut cfg = SimConfig::new("prism", 1);
        cfg.gpu_bytes = 1 << 28; // 256 MiB
        let mut sim = Simulator::new(cfg, specs);
        assert_eq!(sim.ensure_resident(0, 0.0), None);
    }

    #[test]
    fn memory_pressure_activation_terminates() {
        // A full run on undersized GPUs completes (requests drop at cutoff)
        // instead of hanging in the activation retry loop.
        let trace = small_trace(4, 120.0, 3);
        let specs = specs_for(&trace);
        let mut cfg = SimConfig::new("prism", 1);
        cfg.gpu_bytes = 1 << 28; // 256 MiB
        let sim = Simulator::new(cfg, specs);
        let (m, _) = sim.run(&trace);
        assert!(m.total() > 0);
        assert_eq!(m.completed(), 0, "all requests must be recorded as dropped");
    }

    #[test]
    fn streaming_sink_matches_full_dump_aggregates() {
        // Exact stats (counters, means) are identical between the default
        // streaming sink and the opt-in full dump; percentiles agree to the
        // sketch's documented resolution; only the full dump retains records.
        let trace = small_trace(4, 240.0, 19);
        let specs = specs_for(&trace);
        let run = |full: bool| {
            let mut cfg = SimConfig::new("prism", 2);
            cfg.slo_scale = 10.0;
            cfg.metrics_full_dump = full;
            Simulator::new(cfg, specs.clone()).run(&trace).0
        };
        let s = run(false);
        let f = run(true);
        assert_eq!(s.total(), f.total());
        assert!(s.completions().is_empty());
        assert_eq!(f.completions().len(), f.total());
        assert_eq!(s.ttft_attainment().to_bits(), f.ttft_attainment().to_bits());
        assert_eq!(s.tpot_attainment().to_bits(), f.tpot_attainment().to_bits());
        assert_eq!(s.mean_ttft().to_bits(), f.mean_ttft().to_bits());
        assert_eq!(s.sim_events, f.sim_events);
        let (sp, fp) = (s.p95_ttft(), f.p95_ttft());
        assert!(
            (sp - fp).abs() <= 0.01 * fp.max(1e-9),
            "sketch p95 {sp} vs exact {fp}"
        );
    }

    fn run_with_faults(p: &str, n_gpus: u32, trace: &Trace, faults: &str) -> RunMetrics {
        let specs = specs_for(trace);
        let mut cfg = SimConfig::new(p, n_gpus);
        cfg.slo_scale = 10.0;
        cfg.faults = crate::fault::resolve(faults, n_gpus, trace.duration).unwrap();
        let (m, _) = Simulator::new(cfg, specs).run(trace);
        m
    }

    #[test]
    fn gpu_crash_reroutes_requests_and_recovers() {
        let trace = small_trace(4, 300.0, 11).scale_rate(2.0);
        let m = run_with_faults("prism", 2, &trace, "crash@60:g0+40");
        assert_eq!(m.faults.gpu_crashes, 1);
        assert_eq!(m.faults.gpu_recoveries, 1);
        assert!(m.faults.requests_restarted > 0, "crash at t=60 must catch work in flight");
        assert_eq!(m.faults.requests_dropped, 0);
        // No accounting leaks: every admitted request is recorded once.
        assert_eq!(m.total(), trace.events.len());
        assert_eq!(m.completed() + m.dropped(), m.total());
        // Crashed models were re-placed on the surviving GPU.
        assert!(m.faults.models_recovered > 0);
        assert!(m.faults.recovery_seconds > 0.0);
    }

    #[test]
    fn crash_drop_mode_records_dropped_completions() {
        let trace = small_trace(4, 300.0, 11).scale_rate(2.0);
        let m = run_with_faults("prism", 2, &trace, "crash@60:g0+40;drop");
        assert_eq!(m.faults.gpu_crashes, 1);
        assert!(m.faults.requests_dropped > 0);
        assert_eq!(m.faults.requests_restarted, 0);
        assert_eq!(m.total(), trace.events.len());
        assert_eq!(m.completed() + m.dropped(), m.total());
        assert!(m.dropped() as u64 >= m.faults.requests_dropped);
    }

    #[test]
    fn slowdown_window_degrades_latency_but_completes() {
        let trace = small_trace(4, 300.0, 11);
        let base = run_with_faults("prism", 2, &trace, "");
        let slow = run_with_faults("prism", 2, &trace, "slow@0-300:g0x8;slow@0-300:g1x8");
        assert_eq!(slow.total(), base.total());
        assert!(slow.completed() > 0);
        assert!(
            slow.mean_ttft() > base.mean_ttft(),
            "8x slowdown must hurt TTFT: {} vs {}",
            slow.mean_ttft(),
            base.mean_ttft()
        );
    }

    #[test]
    fn alloc_fault_window_counts_injections_and_recovers() {
        let trace = small_trace(4, 300.0, 11);
        let m = run_with_faults("prism", 2, &trace, "allocfail@0-300:g0/3;allocfail@0-300:g1/3");
        assert!(m.faults.alloc_faults_injected > 0);
        assert_eq!(m.total(), trace.events.len());
        assert!(m.completed() > 0, "transient alloc faults must not wedge the engine");
    }

    #[test]
    fn terminal_load_failure_is_retried_at_next_epoch() {
        let trace = small_trace(4, 300.0, 11);
        // Ordinals 0..=2 exhaust MAX_LOAD_ATTEMPTS on the very first
        // activation; the model re-activates successfully later.
        let m = run_with_faults("prism", 2, &trace, "loadfail@0,1,2");
        assert_eq!(m.faults.load_failures, 1);
        assert_eq!(m.faults.load_retries, 2);
        assert_eq!(m.total(), trace.events.len());
        assert!(m.completed() > 0);
    }

    #[test]
    fn timeline_sampling_works() {
        let trace = small_trace(3, 120.0, 41);
        let specs = specs_for(&trace);
        let mut cfg = SimConfig::new("prism", 2);
        cfg.sample_dt = 5.0;
        let sim = Simulator::new(cfg, specs);
        let (_, tl) = sim.run(&trace);
        assert!(tl.len() >= 20, "timeline {} samples", tl.len());
        assert!(tl.iter().any(|s| s.gpus.iter().any(|g| g.0 > 0)), "weights visible");
    }

    #[test]
    fn builder_matches_positional_constructor_bitwise() {
        // The fluent builder must be a pure spelling change: same config,
        // same run, same bits — for every registered policy.
        let trace = small_trace(4, 240.0, 17);
        for p in crate::sim::policies::registry().names() {
            let mut old = SimConfig::new(p, 2);
            old.slo_scale = 10.0;
            let new = SimConfig::for_policy(p).gpus(2).slo_scale(10.0);
            let (a, _) = Simulator::new(old, specs_for(&trace)).run(&trace);
            let (b, _) = Simulator::new(new, specs_for(&trace)).run(&trace);
            assert_eq!(a.total(), b.total(), "{p}");
            assert_eq!(a.ttft_attainment().to_bits(), b.ttft_attainment().to_bits(), "{p}");
            assert_eq!(a.sim_events, b.sim_events, "{p}");
            assert_eq!(a.wall_seconds.to_bits(), b.wall_seconds.to_bits(), "{p}");
        }
    }

    #[test]
    fn uniform_h100_fleet_matches_legacy_cluster_bitwise() {
        // `FleetSpec::uniform(n, H100)` must reproduce the historical
        // uniform cluster bitwise for every registered policy: same memory,
        // same perf values, through the same arithmetic.
        let trace = small_trace(4, 240.0, 7);
        for p in crate::sim::policies::registry().names() {
            let legacy = SimConfig::for_policy(p).gpus(2).slo_scale(10.0);
            let fleet = SimConfig::from_fleet(p, FleetSpec::uniform(2, GpuKind::H100))
                .slo_scale(10.0);
            let (a, _) = Simulator::new(legacy, specs_for(&trace)).run(&trace);
            let (b, _) = Simulator::new(fleet, specs_for(&trace)).run(&trace);
            assert_eq!(a.total(), b.total(), "{p}");
            assert_eq!(a.completed(), b.completed(), "{p}");
            assert_eq!(a.ttft_attainment().to_bits(), b.ttft_attainment().to_bits(), "{p}");
            assert_eq!(a.mean_ttft().to_bits(), b.mean_ttft().to_bits(), "{p}");
            assert_eq!(a.sim_events, b.sim_events, "{p}");
            assert_eq!(a.wall_seconds.to_bits(), b.wall_seconds.to_bits(), "{p}");
            assert_eq!(
                (a.activations, a.evictions, a.migrations, a.preemptions),
                (b.activations, b.evictions, b.migrations, b.preemptions),
                "{p}"
            );
            // Same rate (H100 pricing either way), same wall time, same cost.
            assert_eq!(
                a.cost.fleet_cost_per_hour.to_bits(),
                b.cost.fleet_cost_per_hour.to_bits(),
                "{p}"
            );
            assert_eq!(a.cost.cost_dollars.to_bits(), b.cost.cost_dollars.to_bits(), "{p}");
        }
    }

    #[test]
    fn het_fleet_runs_end_to_end_with_cost_ledger() {
        let trace = small_trace(4, 240.0, 13);
        let fleet = FleetSpec::parse("1xa100+1xl4").unwrap();
        let want_rate = fleet.cost_per_hour();
        for p in crate::sim::policies::registry().names() {
            let cfg = SimConfig::from_fleet(p, fleet.clone()).slo_scale(10.0);
            assert_eq!(cfg.n_gpus, 2, "{p}: fleet sizes the cluster");
            let (m, _) = Simulator::new(cfg, specs_for(&trace)).run(&trace);
            assert!(m.total() > 0, "{p} recorded nothing");
            assert!(m.completed() > 0, "{p} finished nothing on the het fleet");
            assert!(m.cost.is_priced(), "{p}: ledger must carry the fleet rate");
            assert_eq!(m.cost.fleet_cost_per_hour.to_bits(), want_rate.to_bits(), "{p}");
            let want_dollars = want_rate * m.wall_seconds / 3600.0;
            assert_eq!(m.cost.cost_dollars.to_bits(), want_dollars.to_bits(), "{p}");
            assert!(m.cost_per_1k_requests_at_slo() > 0.0, "{p}");
        }
    }

    #[test]
    fn event_heap_ties_pop_in_push_order() {
        // The tie-break contract documented on `push_ev`: the heap key is
        // (time, seq, kind, payload), so same-timestamp events pop in FIFO
        // push order — seq dominates kind. Pushing the canonical preamble
        // order Arrival, Step, Epoch, Sample, Fault at one timestamp must
        // pop in exactly that order...
        let canonical = [
            Ev::Arrival(7),
            Ev::Step(ModelId(3)),
            Ev::Epoch,
            Ev::Sample,
            Ev::Fault(0),
        ];
        let pop_kinds = |evs: &[Ev]| -> Vec<(u8, usize)> {
            let mut sim = Simulator::new(SimConfig::new("prism", 1), Vec::new());
            for ev in evs {
                sim.push_ev(42.0, ev.clone());
            }
            let mut out = Vec::new();
            while let Some(Reverse((Time(t), _, kind, payload))) = sim.heap.pop() {
                assert_eq!(t, 42.0);
                out.push((kind, payload));
            }
            out
        };
        assert_eq!(
            pop_kinds(&canonical),
            vec![(0, 7), (1, 3), (2, 0), (3, 0), (4, 0)],
            "Arrival < Step < Epoch < Sample < Fault at equal time"
        );
        // ...and reversing the push order reverses the pop order, proving
        // the ordering is seq-FIFO (push order), not kind priority. A
        // kind-major key would pass the first assertion and fail this one.
        let reversed: Vec<Ev> = canonical.iter().rev().cloned().collect();
        assert_eq!(
            pop_kinds(&reversed),
            vec![(4, 0), (3, 0), (2, 0), (1, 3), (0, 7)],
            "equal-time ordering must be FIFO push order, not kind-major"
        );
    }

    /// Companion of `event_heap_ties_pop_in_push_order` for the windowed
    /// sharded loop: batch-internal pauses (samples, slowdown-only fault
    /// actions) must not perturb local event order. With a sample cadence
    /// dense enough that hundreds of pauses land *between* step events —
    /// plus overlapping slowdown windows — shard workers keep their local
    /// heaps live across each pause; a survivor re-push at a paused
    /// (non-recompose) barrier would re-sequence equal-time `(time, seq)`
    /// pairs and shift the bits asserted here.
    #[test]
    fn paused_barriers_preserve_local_event_order() {
        let trace = small_trace(4, 300.0, 11).scale_rate(2.0);
        let run = |shards: u32| {
            let mut cfg = SimConfig::new("prism", 2).shards(shards);
            cfg.slo_scale = 10.0;
            cfg.sample_dt = 0.25; // ~1200 samples, nearly all mid-window
            cfg.faults =
                crate::fault::resolve("slow@20-120:g0x3;slow@60-180:g1x1.5", 2, trace.duration)
                    .unwrap();
            Simulator::new(cfg, specs_for(&trace)).run(&trace)
        };
        let (a, tla) = run(1);
        let (b, tlb) = run(4);
        assert_eq!(a.sim_events, b.sim_events);
        assert_eq!(a.total(), b.total());
        assert_eq!(a.completed(), b.completed());
        assert_eq!(a.ttft_attainment().to_bits(), b.ttft_attainment().to_bits());
        assert_eq!(a.wall_seconds.to_bits(), b.wall_seconds.to_bits());
        assert_eq!(a.busy_seconds.to_bits(), b.busy_seconds.to_bits());
        assert_eq!(tla.len(), tlb.len());
        for (sa, sb) in tla.iter().zip(&tlb) {
            assert_eq!(sa.t.to_bits(), sb.t.to_bits());
            assert_eq!(sa.gpus, sb.gpus);
            assert_eq!(sa.queue_lens, sb.queue_lens);
            assert_eq!(sa.cum_violations, sb.cum_violations);
            assert_eq!(sa.inst_token_tput.to_bits(), sb.inst_token_tput.to_bits());
        }
    }

    #[test]
    fn slo_bases_in_paper_range() {
        let perf = GpuPerf::default();
        for s in catalog_subset(18) {
            let (ttft, tpot) = base_slos(&perf, &s);
            assert!(ttft > 0.02 && ttft < 0.3, "{}: ttft {ttft}", s.name);
            assert!(tpot > 0.004 && tpot < 0.08, "{}: tpot {tpot}", s.name);
        }
    }
}
