//! Full Prism: kvcached ballooning + KVPR placement + Moore-Hodgson
//! arbitration + idle eviction + engine pools + parallel loading.

use crate::cluster::GpuId;
use crate::model::spec::ModelId;
use crate::request::Request;
use crate::sched::placement::{place, PlacementInput};

use super::{PolicyCtx, SchedulingPolicy};

#[derive(Debug, Clone, Copy, Default)]
pub struct Prism;

impl SchedulingPolicy for Prism {
    fn name(&self) -> &'static str {
        "prism"
    }

    fn slack_aware(&self) -> bool {
        true
    }

    fn on_epoch(&self, ctx: &mut PolicyCtx<'_>, now: f64) {
        idle_evictions(ctx, now);
        kvpr_placement(ctx, now);
    }
}

/// Evict idle models when their GPUs are constrained for others (SS6.1):
/// KV headroom scarcity is pressure, weight residency alone is not,
/// because kvcached already lets co-tenants use the free pool.
fn idle_evictions(ctx: &mut PolicyCtx<'_>, now: f64) {
    if ctx.no_evict() {
        return;
    }
    let candidates: Vec<(ModelId, f64, Vec<GpuId>)> =
        ctx.residency().values().map(|r| (r.model, r.last_active, r.gpus.clone())).collect();
    for (m, last_active, gpus) in candidates {
        if ctx.engine_has_work(m) {
            continue;
        }
        let min_free = gpus
            .iter()
            .map(|g| {
                let st = ctx.kv_stats(g.0 as usize);
                ctx.shared_kv_bytes(g.0 as usize) as f64 / st.total_bytes as f64
            })
            .fold(1.0, f64::min);
        if ctx.eviction().should_evict(now, last_active, min_free) {
            ctx.evict_to_pending(m);
        }
    }
}

/// Re-place resident models per Algorithm 1 and migrate where the KVPR
/// improvement clears tau and the source GPU is actually pressured.
fn kvpr_placement(ctx: &mut PolicyCtx<'_>, now: f64) {
    if ctx.no_migrate() {
        return;
    }
    let resident: Vec<ModelId> = ctx.residency().keys().copied().collect();
    if resident.len() < 2 {
        return;
    }
    ctx.refresh_demand(now);
    let caps: Vec<f64> = (0..ctx.n_gpus())
        .map(|g| {
            if !ctx.gpu_available(g) {
                // Crashed/preempted GPU: zero capacity makes Algorithm 1
                // steer every placement (and migration target) away from it.
                return 0.0;
            }
            let st = ctx.kv_stats(g);
            (st.total_bytes - st.kv_used_bytes) as f64
        })
        .collect();
    let inputs: Vec<PlacementInput> = resident
        .iter()
        .map(|&m| PlacementInput {
            demand: ctx.demand_of(m, now),
            // INVARIANT: `m` came from the resident set captured above.
            current: ctx.residency_of(m).unwrap().gpus.iter().map(|g| g.0 as usize).collect(),
        })
        .collect();
    let result = place(&inputs, &caps, ctx.tau());
    for (i, p) in result.placements.iter().enumerate() {
        if !p.migrated {
            continue;
        }
        let idx = ctx.model_idx(inputs[i].demand.model);
        let spec = ctx.spec(idx).clone();
        if spec.tp != 1 {
            continue; // TP migration out of scope (paper: anti-affinity only)
        }
        // Only migrate idle-engine models; busy ones keep serving (the
        // paper overlaps migration, we approximate by deferring).
        if ctx.engine_has_work(spec.id) {
            continue;
        }
        let to = GpuId(p.gpus[0] as u32);
        // INVARIANT: this placement input was built from the resident set,
        // and nothing evicted `spec.id` since (migrations happen below).
        let from = ctx.residency_of(spec.id).unwrap().gpus[0];
        // Migration is only worth its disruption when the source GPU is
        // actually pressured (paper SS6.1: avoid migrations with marginal
        // benefit). KVPR has units 1/s: a value above ~0.1 means demand
        // would fill the GPU's free KV within ~10 s.
        if ctx.gpu_kvpr(from.0 as usize, now) < 0.1 {
            continue;
        }
        if from != to && ctx.migrate(spec.id, to, now) {
            // Move this model's queued requests with it immediately;
            // waiting for the next epoch would burn the TTFT budget.
            let old_q = ctx.take_gpu_queue(from.0 as usize);
            let (mine, rest): (Vec<Request>, Vec<Request>) =
                old_q.into_iter().partition(|r| r.model == spec.id);
            ctx.put_gpu_queue(from.0 as usize, rest);
            if !mine.is_empty() {
                ctx.extend_gpu_queue(to.0 as usize, mine);
                // INVARIANT: migrate() returned true, so the model is
                // resident on `to` with a fresh ready_at.
                let ready = ctx.residency_of(spec.id).unwrap().ready_at;
                ctx.schedule_step(spec.id, ready.max(now));
            }
        }
    }
}
