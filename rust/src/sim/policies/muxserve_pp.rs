//! MuxServe++: spatial sharing through kvcached (models share KV memory on
//! their GPU) but no eviction, no migration, FCFS admission.

use super::SchedulingPolicy;

#[derive(Debug, Clone, Copy, Default)]
pub struct MuxServePlusPlus;

impl SchedulingPolicy for MuxServePlusPlus {
    fn name(&self) -> &'static str {
        "muxserve++"
    }

    fn static_residency(&self) -> bool {
        true
    }

    // Everything else is the trait default: uniform t=0 placement, no
    // epoch action, FCFS admission — the kvcached elasticity it is named
    // for lives below the policy layer, in the shared KV pool.
}
