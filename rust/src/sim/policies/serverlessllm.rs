//! ServerlessLLM-style: models unloaded when idle; reactivation pays the
//! cold-start path; unbounded batching.

use crate::engine::loading::LoadStrategy;
use crate::model::spec::ModelId;

use super::{PolicyCtx, SchedulingPolicy};

/// Aggressive unloading: idle this long means the model is released, with
/// no memory-pressure gate at all.
const IDLE_UNLOAD_SECONDS: f64 = 3.0;

#[derive(Debug, Clone, Copy, Default)]
pub struct ServerlessLlm;

impl SchedulingPolicy for ServerlessLlm {
    fn name(&self) -> &'static str {
        "serverlessllm"
    }

    fn load_strategy(&self) -> LoadStrategy {
        LoadStrategy::Naive // full cold start
    }

    /// Serverless starts cold: nothing is resident until requested.
    fn initial_placement(&self, _ctx: &mut PolicyCtx<'_>) {}

    fn on_epoch(&self, ctx: &mut PolicyCtx<'_>, now: f64) {
        let candidates: Vec<(ModelId, f64)> =
            ctx.residency().values().map(|r| (r.model, r.last_active)).collect();
        for (m, last_active) in candidates {
            if ctx.engine_has_work(m) {
                continue;
            }
            if now - last_active > IDLE_UNLOAD_SECONDS {
                ctx.evict_to_pending(m);
            }
        }
    }
}
