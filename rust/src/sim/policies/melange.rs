//! Mélange-inspired cost-aware placement for heterogeneous fleets.
//!
//! The paper's fleets are uniform H100 boxes; real deployments mix SKUs
//! because $/hour spans ~7x between an L4 and an H100 (see
//! `cluster::gpu::GpuKind`). This policy exploits that spread: long-tail
//! (idle) models drift onto cheap GPUs, big iron is reserved for hot
//! models. On a kind-less uniform cluster every GPU costs the same and the
//! rebalance pass is a no-op, so melange degrades to on-demand activation
//! plus Prism-style idle eviction.
//!
//! Like every policy, hooks are pure functions of the `PolicyCtx` view:
//! GPU $/hour is static kind data (module docs in `cluster/gpu.rs`), so
//! branching on it preserves the sweep engine's byte-identity contract.

use crate::cluster::GpuId;
use crate::model::spec::ModelId;
use crate::request::Request;

use super::{PolicyCtx, SchedulingPolicy};

/// Max migrations per control epoch: rebalancing is a slow background
/// drift, not a thrash source (same spirit as Prism's tau threshold).
const MIGRATION_BUDGET: usize = 2;

#[derive(Debug, Clone, Copy, Default)]
pub struct Melange;

impl SchedulingPolicy for Melange {
    fn name(&self) -> &'static str {
        "melange"
    }

    fn slack_aware(&self) -> bool {
        true
    }

    /// Cost-aware greedy at t=0: no rate information exists yet, so each
    /// model (largest weights first) takes the *cheapest* healthy GPU that
    /// fits. Big models fail the fit check on 24G cards and fall through to
    /// big iron; small models pack the cheap tier — exactly the split the
    /// epoch rebalance maintains once rates are known.
    fn initial_placement(&self, ctx: &mut PolicyCtx<'_>) {
        let mut order: Vec<usize> = (0..ctx.specs().len()).collect();
        order.sort_by(|&a, &b| {
            ctx.spec(b)
                .weight_bytes()
                .cmp(&ctx.spec(a).weight_bytes())
                .then(a.cmp(&b))
        });
        for i in order {
            let spec = ctx.spec(i).clone();
            // Fit = weights + ~1k tokens of KV headroom, so nothing is
            // placed with zero serving room.
            let need = spec.weight_bytes_per_gpu() + spec.kv_bytes_per_token() * 1024;
            let mut fits: Vec<usize> = (0..ctx.n_gpus())
                .filter(|&g| ctx.gpu_available(g) && ctx.shared_kv_bytes(g) >= need)
                .collect();
            sort_by_cost(ctx, &mut fits, CostOrder::CheapFirst);
            if fits.len() < spec.tp as usize {
                continue; // cannot fit now; on-demand routing handles it later
            }
            let group: Vec<GpuId> =
                fits.iter().take(spec.tp as usize).map(|&g| GpuId(g as u32)).collect();
            ctx.activate(i, group, 0.0);
        }
    }

    fn on_epoch(&self, ctx: &mut PolicyCtx<'_>, now: f64) {
        idle_evictions(ctx, now);
        cost_rebalance(ctx, now);
    }
}

#[derive(Clone, Copy, PartialEq)]
enum CostOrder {
    CheapFirst,
    ExpensiveFirst,
}

/// Order GPU indices by $/hour (ties by id, so the order is total and
/// deterministic).
fn sort_by_cost(ctx: &PolicyCtx<'_>, gpus: &mut [usize], order: CostOrder) {
    gpus.sort_by(|&a, &b| {
        let (ca, cb) = (ctx.gpu_cost_per_hour(a), ctx.gpu_cost_per_hour(b));
        // INVARIANT: fleet costs are finite by FleetSpec validation, so
        // partial_cmp is total on both arms.
        let by_cost = match order {
            CostOrder::CheapFirst => ca.partial_cmp(&cb).unwrap(),
            CostOrder::ExpensiveFirst => cb.partial_cmp(&ca).unwrap(),
        };
        by_cost.then(a.cmp(&b))
    });
}

/// Prism-style idle eviction (SS6.1): idle models on pressured GPUs give
/// their memory back to the shared pool.
fn idle_evictions(ctx: &mut PolicyCtx<'_>, now: f64) {
    if ctx.no_evict() {
        return;
    }
    let candidates: Vec<(ModelId, f64, Vec<GpuId>)> =
        ctx.residency().values().map(|r| (r.model, r.last_active, r.gpus.clone())).collect();
    for (m, last_active, gpus) in candidates {
        if ctx.engine_has_work(m) {
            continue;
        }
        let min_free = gpus
            .iter()
            .map(|g| {
                let st = ctx.kv_stats(g.0 as usize);
                ctx.shared_kv_bytes(g.0 as usize) as f64 / st.total_bytes as f64
            })
            .fold(1.0, f64::min);
        if ctx.eviction().should_evict(now, last_active, min_free) {
            ctx.evict_to_pending(m);
        }
    }
}

/// Drift models across cost tiers: hot models (above-mean memory demand)
/// sitting on cheap GPUs move up to big iron; models with zero traffic in
/// the monitor window sitting on expensive GPUs move down to the cheap
/// tier. Only idle-engine single-GPU models move (migration is modelled
/// for tp=1, and busy engines keep serving), and at most
/// [`MIGRATION_BUDGET`] per epoch.
fn cost_rebalance(ctx: &mut PolicyCtx<'_>, now: f64) {
    if ctx.no_migrate() {
        return;
    }
    let costs: Vec<f64> = (0..ctx.n_gpus()).map(|g| ctx.gpu_cost_per_hour(g)).collect();
    let min_cost = costs.iter().copied().fold(f64::INFINITY, f64::min);
    let max_cost = costs.iter().copied().fold(0.0, f64::max);
    if max_cost <= min_cost {
        return; // uniform fleet: every GPU costs the same, nothing to drift
    }
    ctx.refresh_demand(now);
    let resident: Vec<(ModelId, GpuId)> = ctx
        .residency()
        .values()
        .filter(|r| r.gpus.len() == 1)
        .map(|r| (r.model, r.gpus[0]))
        .collect();
    if resident.is_empty() {
        return;
    }
    // Hotness threshold: mean w_token_rate over residents (the same
    // demand-weighted pressure KVPR uses, units bytes/s).
    let ws: Vec<f64> = resident
        .iter()
        .map(|&(m, _)| {
            let d = ctx.demand_of(m, now);
            d.token_rate * d.token_size / d.slo.max(1e-6)
        })
        .collect();
    let mean_w = ws.iter().sum::<f64>() / ws.len() as f64;

    let mut budget = MIGRATION_BUDGET;
    for (&(m, from), &w) in resident.iter().zip(&ws) {
        if budget == 0 {
            break;
        }
        if ctx.engine_has_work(m) {
            continue;
        }
        let d = ctx.demand_of(m, now);
        let from_cost = costs[from.0 as usize];
        let order = if w > mean_w && from_cost < max_cost {
            CostOrder::ExpensiveFirst // hot on cheap: move up
        } else if d.token_rate == 0.0 && from_cost > min_cost {
            CostOrder::CheapFirst // cold on big iron: move down
        } else {
            continue;
        };
        let need = d.weight_bytes_per_gpu + d.token_size as u64 * 1024;
        let mut fits: Vec<usize> = (0..ctx.n_gpus())
            .filter(|&g| g != from.0 as usize)
            .filter(|&g| ctx.gpu_available(g) && ctx.shared_kv_bytes(g) >= need)
            .collect();
        sort_by_cost(ctx, &mut fits, order);
        let Some(&to) = fits.first() else { continue };
        // Migration must actually cross a cost tier in the right direction.
        let dir_ok = match order {
            CostOrder::ExpensiveFirst => costs[to] > from_cost,
            CostOrder::CheapFirst => costs[to] < from_cost,
        };
        if !dir_ok {
            continue;
        }
        let to = GpuId(to as u32);
        if ctx.migrate(m, to, now) {
            budget -= 1;
            // Move queued requests with the model (same as Prism): waiting
            // an epoch would burn the TTFT budget.
            let old_q = ctx.take_gpu_queue(from.0 as usize);
            let (mine, rest): (Vec<Request>, Vec<Request>) =
                old_q.into_iter().partition(|r| r.model == m);
            ctx.put_gpu_queue(from.0 as usize, rest);
            if !mine.is_empty() {
                ctx.extend_gpu_queue(to.0 as usize, mine);
                // INVARIANT: migrate() returned true, so `m` is resident.
                let ready = ctx.residency_of(m).unwrap().ready_at;
                ctx.schedule_step(m, ready.max(now));
            }
        }
    }
}
