//! Static partition: fixed placement, fixed per-model KV quotas, FCFS.

use super::{place_all_uniform, PolicyCtx, SchedulingPolicy};

#[derive(Debug, Clone, Copy, Default)]
pub struct StaticPartition;

impl SchedulingPolicy for StaticPartition {
    fn name(&self) -> &'static str {
        "s-partition"
    }

    fn static_residency(&self) -> bool {
        true
    }

    fn initial_placement(&self, ctx: &mut PolicyCtx<'_>) {
        place_all_uniform(ctx);
        apply_static_quotas(ctx);
    }
}

/// Divide each GPU's post-weight memory evenly among its resident models
/// as hard KV quotas.
fn apply_static_quotas(ctx: &mut PolicyCtx<'_>) {
    for g in 0..ctx.n_gpus() {
        let residents = ctx.residents_on(g).to_vec();
        if residents.is_empty() {
            continue;
        }
        let free = ctx.kv_stats(g).free_bytes;
        let page = ctx.page_bytes(g);
        let quota_pages = (free / page / residents.len() as u64) as u32;
        for m in residents {
            ctx.set_kv_limit(g, m, quota_pages.max(1));
        }
    }
}
