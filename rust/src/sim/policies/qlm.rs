//! QLM-style time sharing: per-model request groups dispatched to GPUs
//! under EDF; swapping evicts the resident model and pays an engine
//! restart (QLM restarts engines on swap [37]).

use crate::engine::loading::LoadStrategy;
use crate::model::spec::ModelId;
use crate::request::Request;

use super::{PolicyCtx, SchedulingPolicy};

#[derive(Debug, Clone, Copy, Default)]
pub struct Qlm;

impl SchedulingPolicy for Qlm {
    fn name(&self) -> &'static str {
        "qlm"
    }

    fn load_strategy(&self) -> LoadStrategy {
        LoadStrategy::Naive // engine restart on swap
    }

    /// Time sharing starts with an empty cluster; groups swap in at epochs.
    fn initial_placement(&self, _ctx: &mut PolicyCtx<'_>) {}

    /// Group queue; dispatch happens at epochs, never on arrival.
    fn route_nonresident(&self, ctx: &mut PolicyCtx<'_>, req: Request, _now: f64) {
        ctx.push_pending(req);
    }

    fn on_epoch(&self, ctx: &mut PolicyCtx<'_>, now: f64) {
        dispatch_groups(ctx, now);
    }
}

/// Group pending requests by model; dispatch the group whose head has the
/// earliest deadline onto each idle GPU, swapping models in.
fn dispatch_groups(ctx: &mut PolicyCtx<'_>, now: f64) {
    loop {
        // Find an idle GPU (no resident model with work).
        let idle_gpu = (0..ctx.n_gpus())
            .find(|&g| !ctx.residents_on(g).iter().any(|&m| ctx.engine_has_work(m)));
        let Some(g) = idle_gpu else { break };
        // Earliest-deadline pending group. (TP groups: QLM picks the first
        // tp idle GPUs; we simplify by requiring residency via
        // ensure_resident below.)
        let head = ctx
            .pending()
            .iter()
            // INVARIANT: deadlines are finite (arrival + SLO scale), so
            // partial_cmp is total.
            .min_by(|a, b| a.ttft_deadline().partial_cmp(&b.ttft_deadline()).unwrap())
            .map(|r| r.model);
        let Some(m) = head else { break };
        let idx = ctx.model_idx(m);
        // Swap: evict whatever is resident-and-idle on g, then activate.
        let victims: Vec<ModelId> = ctx
            .residents_on(g)
            .iter()
            .filter(|cand| !ctx.engine_has_work(**cand))
            .copied()
            .collect();
        for v in victims {
            ctx.evict_to_pending(v);
        }
        if ctx.ensure_resident(idx, now).is_none() {
            break;
        }
        // Dispatch the whole group.
        let group = ctx.take_pending_of(m);
        for r in group {
            ctx.enqueue_resident(r, now);
        }
    }
}
