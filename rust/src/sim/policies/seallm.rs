//! SeaLLM-inspired latency-aware sharing baseline (PAPERS.md:
//! "SeaLLM: Service-Aware and Latency-Optimized Resource Sharing for Large
//! Language Model Inference").
//!
//! The sixth registered policy, and the first added *through* the
//! [`SchedulingPolicy`] API rather than by editing the simulator: every
//! model is placed up front and shares KV elastically (like `muxserve++`),
//! admission is latency-optimized via slack-aware ordering (like `prism`),
//! and the only control-epoch action is a conservative latency-aware
//! unload of long-idle models once their GPU's free-KV headroom turns
//! scarce — no migration, no static quota walls. Evicted models reactivate
//! on demand through the default routing hook.

use crate::cluster::GpuId;
use crate::model::spec::ModelId;

use super::{PolicyCtx, SchedulingPolicy};

/// Unload only when the free-KV fraction on one of the model's GPUs drops
/// below this: sharing stays maximal while memory is plentiful.
const PRESSURE_FREE_FRACTION: f64 = 0.15;

/// Idle grace before an unload (s) — far longer than ServerlessLLM's
/// aggressive 3 s, so latency is not repeatedly spent on cold starts.
const IDLE_GRACE_SECONDS: f64 = 30.0;

#[derive(Debug, Clone, Copy, Default)]
pub struct SeaLlm;

impl SchedulingPolicy for SeaLlm {
    fn name(&self) -> &'static str {
        "seallm"
    }

    fn slack_aware(&self) -> bool {
        true // latency-optimized admission
    }

    fn on_epoch(&self, ctx: &mut PolicyCtx<'_>, now: f64) {
        let candidates: Vec<(ModelId, f64, Vec<GpuId>)> =
            ctx.residency().values().map(|r| (r.model, r.last_active, r.gpus.clone())).collect();
        for (m, last_active, gpus) in candidates {
            if ctx.engine_has_work(m) {
                continue;
            }
            if now - last_active <= IDLE_GRACE_SECONDS {
                continue;
            }
            let min_free = gpus
                .iter()
                .map(|g| {
                    let st = ctx.kv_stats(g.0 as usize);
                    st.free_bytes as f64 / st.total_bytes as f64
                })
                .fold(1.0, f64::min);
            if min_free < PRESSURE_FREE_FRACTION {
                ctx.evict_to_pending(m);
            }
        }
    }
}
