//! The pluggable two-level scheduling-policy API.
//!
//! Prism's core contribution is a *two-level scheduling policy* — cluster
//! level placement/eviction plus GPU-level admission — layered over
//! cross-model memory coordination (paper SS6). This module makes that
//! surface a first-class API: every policy implements [`SchedulingPolicy`]
//! and is selected **by name** through the [`PolicyRegistry`], so adding a
//! new system (another baseline, an ablation) never touches the simulator
//! core in `sim/simulator.rs`.
//!
//! # Trait contract
//!
//! Hooks operate exclusively through the [`PolicyCtx`] facade, which
//! exposes the simulator state policies actually need — demand snapshots,
//! the residency map and its per-GPU reverse index, pending/GPU queues,
//! and kvcached memory pressure — never `&mut Simulator` itself. Two rules
//! keep the sweep engine's `--jobs 1` ≡ `--jobs N` byte-identity guarantee
//! (see `sweep/mod.rs`) intact:
//!
//! * **Deterministic**: a hook's behavior must be a pure function of the
//!   `PolicyCtx` state and its arguments. No RNG, no wall-clock reads, no
//!   global mutable state, no iteration over unordered containers (the
//!   facade only hands out deterministically ordered views — residency is
//!   a `BTreeMap`, the reverse index is sorted).
//! * **Scoped**: all mutations go through `PolicyCtx` methods
//!   (activate/evict/migrate, queue moves, step scheduling), which keep the
//!   simulator's internal indexes consistent.
//!
//! Policies must also be stateless (`Send + Sync`, shared via
//! [`PolicyHandle`]): one instance is reused across every simulation run
//! and across sweep worker threads. Per-run state belongs in the simulator
//! (extend `PolicyCtx` if a new policy needs a view of it).
//!
//! Both rules are machine-checked by `prism lint` (see ROADMAP "Static
//! analysis"): rule D5 bans interior mutability and global state under
//! `sim/policies/` (the registry's write-once cell carries the one
//! justified waiver), and rules D1/D2 keep clocks, randomness, and
//! hash-order iteration out of policy hooks.
//!
//! # Registry
//!
//! [`registry()`] is the process-wide instance holding the seven built-ins
//! in fixed order: the paper's five systems (`prism`, `s-partition`,
//! `muxserve++`, `qlm`, `serverlessllm`), the SeaLLM-inspired
//! latency-aware sharing baseline (`seallm`), and the Mélange-inspired
//! cost-aware heterogeneous-fleet policy (`melange`). `prism sim
//! --policy`, `SweepGrid`'s default policy axis, and the benches all
//! resolve names against it, so the accepted-name list cannot drift
//! between surfaces.

mod melange;
mod muxserve_pp;
mod prism;
mod qlm;
mod s_partition;
mod seallm;
mod serverlessllm;

// lint:allow(D5): OnceLock backs the immutable built-in policy registry —
// written once at first use, read-only afterwards, so policy purity holds.
use std::sync::{Arc, OnceLock};

use crate::cluster::GpuId;
use crate::engine::loading::LoadStrategy;
use crate::request::Request;
use crate::sched::kvpr::ModelDemand;
use crate::sched::placement::{place, PlacementInput};

pub use crate::sim::simulator::PolicyCtx;
pub use melange::Melange;
pub use muxserve_pp::MuxServePlusPlus;
pub use prism::Prism;
pub use qlm::Qlm;
pub use s_partition::StaticPartition;
pub use seallm::SeaLlm;
pub use serverlessllm::ServerlessLlm;

/// Shared, clonable handle to a policy implementation. Cheap to clone
/// (`Arc`), safe to share across sweep worker threads.
pub type PolicyHandle = Arc<dyn SchedulingPolicy>;

/// A two-level serving policy: cluster-level hooks (initial placement,
/// routing/residency decisions, the control epoch) plus GPU-level
/// admission classification. See the module docs for the determinism
/// contract every implementation must uphold.
pub trait SchedulingPolicy: Send + Sync + std::fmt::Debug {
    /// Registry key — also the CLI `--policy` name and the table label.
    /// Must be unique across the registry.
    fn name(&self) -> &'static str;

    /// Keep every model resident from t=0 (space sharing)? When true, a
    /// request for a non-resident model waits in `pending` (the model
    /// simply did not fit at t=0) instead of triggering activation.
    fn static_residency(&self) -> bool {
        false
    }

    /// GPU-level admission: order each GPU's shared queue by prefill slack
    /// (Moore-Hodgson, Algorithm 2) instead of FCFS? The classification is
    /// resolved once into `SimConfig::slack_aware` at construction
    /// (combined with the `PRISM_NO_MH` env override), never re-read on
    /// the admission hot path.
    fn slack_aware(&self) -> bool {
        false
    }

    /// Weight-loading strategy paid on every activation of a model.
    fn load_strategy(&self) -> LoadStrategy {
        LoadStrategy::Parallel
    }

    /// Cluster-level hook: place models at t=0, before any arrival.
    /// Default: uniform-demand Algorithm-1 placement of everything that
    /// fits (no rate information exists yet). Time-sharing policies
    /// override this to start with an empty cluster.
    fn initial_placement(&self, ctx: &mut PolicyCtx<'_>) {
        place_all_uniform(ctx);
    }

    /// Cluster-level hook: a request arrived (or is being retried at an
    /// epoch) for a model that is not currently resident. Default:
    /// space-sharing policies park it in `pending` (see
    /// [`static_residency`](Self::static_residency)); everyone else
    /// activates on demand, parking the request only if the model cannot
    /// fit right now.
    fn route_nonresident(&self, ctx: &mut PolicyCtx<'_>, req: Request, now: f64) {
        if self.static_residency() {
            ctx.push_pending(req);
            return;
        }
        let idx = ctx.model_idx(req.model);
        match ctx.ensure_resident(idx, now) {
            Some(_) => ctx.enqueue_resident(req, now),
            None => ctx.push_pending(req),
        }
    }

    /// Cluster-level hook: the control epoch (placement, eviction, group
    /// dispatch). Runs after monitor housekeeping and before the
    /// simulator's policy-agnostic pending-retry and re-admission pass.
    fn on_epoch(&self, _ctx: &mut PolicyCtx<'_>, _now: f64) {}
}

/// Uniform-demand Algorithm-1 placement of every model (no rate info at
/// t=0): the default [`SchedulingPolicy::initial_placement`] body, shared
/// by all space-sharing policies.
fn place_all_uniform(ctx: &mut PolicyCtx<'_>) {
    // Crashed GPUs offer zero capacity: `place` scores them at infinite
    // KVPR and routes around them. With every GPU healthy (every fault-free
    // run) this is exactly the old capacity vector.
    let caps: Vec<f64> = (0..ctx.n_gpus())
        .map(|g| if ctx.gpu_available(g) { ctx.shared_kv_bytes(g) as f64 } else { 0.0 })
        .collect();
    let inputs: Vec<PlacementInput> = ctx
        .specs()
        .iter()
        .map(|s| PlacementInput {
            demand: ModelDemand {
                model: s.id,
                token_rate: 1.0,
                token_size: s.kv_bytes_per_token() as f64 * s.tp as f64,
                slo: 0.05,
                weight_bytes_per_gpu: s.weight_bytes_per_gpu(),
                tp: s.tp,
            },
            current: vec![],
        })
        .collect();
    let result = place(&inputs, &caps, ctx.tau());
    for (i, p) in result.placements.iter().enumerate() {
        let gpus: Vec<GpuId> = p.gpus.iter().map(|&g| GpuId(g as u32)).collect();
        ctx.activate(i, gpus, 0.0);
    }
}

/// Name-keyed policy registry. Registration order is enumeration order
/// (it fixes table row order in sweeps), and duplicate names are rejected.
#[derive(Debug)]
pub struct PolicyRegistry {
    entries: Vec<PolicyHandle>,
    /// `"name|name|…"` in registration order, for CLI help/error text.
    joined: String,
}

impl Default for PolicyRegistry {
    fn default() -> Self {
        Self::new()
    }
}

impl PolicyRegistry {
    /// An empty registry. Most callers want [`registry()`] (the global
    /// instance with the built-ins) instead.
    pub fn new() -> Self {
        PolicyRegistry { entries: Vec::new(), joined: String::new() }
    }

    /// All seven built-in policies in fixed order: the paper's five
    /// systems, the `seallm` baseline, then the cost-aware `melange`.
    pub fn with_builtins() -> Self {
        let mut r = Self::new();
        let builtins: [PolicyHandle; 7] = [
            Arc::new(Prism),
            Arc::new(StaticPartition),
            Arc::new(MuxServePlusPlus),
            Arc::new(Qlm),
            Arc::new(ServerlessLlm),
            Arc::new(SeaLlm),
            Arc::new(Melange),
        ];
        for p in builtins {
            // INVARIANT: the seven built-in names are distinct string
            // literals, so register() cannot see a duplicate here.
            r.register(p).expect("built-in policy names are unique");
        }
        r
    }

    /// Register a policy under its [`SchedulingPolicy::name`]. Rejects
    /// duplicates: two policies answering to one name would make
    /// name-keyed sweep results ambiguous.
    pub fn register(&mut self, p: PolicyHandle) -> Result<(), String> {
        if self.lookup(p.name()).is_some() {
            return Err(format!("policy {:?} is already registered", p.name()));
        }
        self.entries.push(p);
        self.joined = self.entries.iter().map(|e| e.name()).collect::<Vec<_>>().join("|");
        Ok(())
    }

    /// Resolve a policy by name.
    pub fn lookup(&self, name: &str) -> Option<PolicyHandle> {
        self.entries.iter().find(|e| e.name() == name).cloned()
    }

    /// Registered names, in registration order.
    pub fn names(&self) -> Vec<&'static str> {
        self.entries.iter().map(|e| e.name()).collect()
    }

    /// `"name|name|…"` in registration order — ready-made for CLI help
    /// strings and unknown-name errors.
    pub fn names_joined(&self) -> &str {
        &self.joined
    }

    pub fn len(&self) -> usize {
        self.entries.len()
    }

    pub fn is_empty(&self) -> bool {
        self.entries.is_empty()
    }
}

/// The process-wide registry holding the seven built-in policies, built
/// once on first use.
pub fn registry() -> &'static PolicyRegistry {
    // lint:allow(D5): write-once registry cell; policies read it immutably.
    static REG: OnceLock<PolicyRegistry> = OnceLock::new();
    REG.get_or_init(PolicyRegistry::with_builtins)
}

/// Resolve a built-in policy by name, panicking with the valid-name list on
/// an unknown name — the ergonomic path for tests, benches, and experiment
/// code. CLI surfaces use [`registry()`]`.lookup(..)` to report a proper
/// error instead.
pub fn by_name(name: &str) -> PolicyHandle {
    registry().lookup(name).unwrap_or_else(|| {
        panic!("unknown policy {:?} (valid: {})", name, registry().names_joined())
    })
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn registry_round_trips_every_builtin_name() {
        // register → lookup → name() round-trip, for all seven policies
        // including the cost-aware `melange`.
        let names = registry().names();
        assert_eq!(
            names,
            vec!["prism", "s-partition", "muxserve++", "qlm", "serverlessllm", "seallm", "melange"]
        );
        for name in names {
            let p = registry().lookup(name).expect("registered name resolves");
            assert_eq!(p.name(), name);
            assert_eq!(by_name(name).name(), name, "lookup and by_name agree");
        }
        assert_eq!(registry().len(), 7);
        assert!(!registry().is_empty());
        assert_eq!(
            registry().names_joined(),
            "prism|s-partition|muxserve++|qlm|serverlessllm|seallm|melange"
        );
    }

    #[test]
    fn duplicate_name_registration_rejected() {
        let mut r = PolicyRegistry::with_builtins();
        let before = r.len();
        let err = r.register(Arc::new(Prism)).unwrap_err();
        assert!(err.contains("prism"), "error names the colliding policy: {err}");
        assert_eq!(r.len(), before, "failed registration must not grow the registry");
    }

    #[test]
    fn lookup_unknown_name_is_none() {
        assert!(registry().lookup("no-such-policy").is_none());
    }

    #[test]
    fn classification_matches_paper() {
        assert!(by_name("s-partition").static_residency());
        assert!(by_name("muxserve++").static_residency());
        assert!(!by_name("prism").static_residency());
        assert!(by_name("prism").slack_aware());
        assert!(by_name("seallm").slack_aware());
        assert!(by_name("melange").slack_aware());
        assert!(!by_name("melange").static_residency());
        assert!(!by_name("qlm").slack_aware());
        assert!(matches!(by_name("qlm").load_strategy(), LoadStrategy::Naive));
        assert!(matches!(by_name("serverlessllm").load_strategy(), LoadStrategy::Naive));
        assert!(matches!(by_name("prism").load_strategy(), LoadStrategy::Parallel));
    }

    #[test]
    fn names_unique() {
        let names = registry().names();
        let mut d = names.clone();
        d.sort_unstable();
        d.dedup();
        assert_eq!(d.len(), names.len());
    }
}
