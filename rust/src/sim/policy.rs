//! Serving policies: Prism and the paper's four baselines (SS7.1).

/// Which coordination policy governs the run.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum PolicyKind {
    /// Full Prism: kvcached ballooning + KVPR placement + Moore-Hodgson
    /// arbitration + idle eviction + engine pools + parallel loading.
    Prism,
    /// Static partition: fixed placement, fixed per-model KV quotas, FCFS.
    StaticPartition,
    /// MuxServe++: spatial sharing through kvcached (models share KV memory
    /// on their GPU) but no eviction, no migration, FCFS admission.
    MuxServePlusPlus,
    /// QLM-style time sharing: per-model request groups dispatched to GPUs
    /// under EDF; swapping evicts the resident model and pays an engine
    /// restart (QLM restarts engines on swap [37]).
    Qlm,
    /// ServerlessLLM-style: models unloaded when idle; reactivation pays the
    /// cold-start path; unbounded batching.
    ServerlessLlm,
}

impl PolicyKind {
    pub fn name(self) -> &'static str {
        match self {
            PolicyKind::Prism => "prism",
            PolicyKind::StaticPartition => "s-partition",
            PolicyKind::MuxServePlusPlus => "muxserve++",
            PolicyKind::Qlm => "qlm",
            PolicyKind::ServerlessLlm => "serverlessllm",
        }
    }

    pub fn all() -> [PolicyKind; 5] {
        [
            PolicyKind::Prism,
            PolicyKind::StaticPartition,
            PolicyKind::MuxServePlusPlus,
            PolicyKind::Qlm,
            PolicyKind::ServerlessLlm,
        ]
    }

    /// Does this policy keep all models resident from t=0 (space sharing)?
    pub fn static_residency(self) -> bool {
        matches!(self, PolicyKind::StaticPartition | PolicyKind::MuxServePlusPlus)
    }

    /// Does this policy use slack-aware (Moore-Hodgson) admission?
    /// Pure classification; the `PRISM_NO_MH` env override is resolved once
    /// into `SimConfig::slack_aware` at construction, not re-read per
    /// admission on the hot path.
    pub fn slack_aware(self) -> bool {
        matches!(self, PolicyKind::Prism)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn names_unique() {
        let names: Vec<&str> = PolicyKind::all().iter().map(|p| p.name()).collect();
        let mut d = names.clone();
        d.sort_unstable();
        d.dedup();
        assert_eq!(d.len(), names.len());
    }

    #[test]
    fn classification() {
        assert!(PolicyKind::StaticPartition.static_residency());
        assert!(PolicyKind::MuxServePlusPlus.static_residency());
        assert!(!PolicyKind::Prism.static_residency());
        assert!(PolicyKind::Prism.slack_aware());
        assert!(!PolicyKind::Qlm.slack_aware());
    }
}
