//! Intra-run parallelism: the GPU-group-sharded event loop (`--shards N`).
//!
//! Between two consecutive *recompose barriers*, the simulator's event
//! stream factors into independent per-GPU-group sub-streams: an engine
//! step for model `m` touches only `m`'s TP group (engine, KV allocators,
//! lead-GPU queue, monitor), and a resident arrival touches only its
//! model's group. This module exploits that: it partitions the GPUs into
//! shards, replays each shard's slice of the window on its own thread with
//! disjoint `&mut` borrows of the simulator state, and re-merges at every
//! barrier before the control event runs globally on the master.
//!
//! The fingerprint-identity contract below is machine-checked by
//! `prism lint` (see ROADMAP "Static analysis"): rules D1/D2 keep
//! nondeterminism and hash-order out of this module, D3 audits every
//! panic site, and D4 budgets its steady-state allocations against
//! `lint/hot_alloc_allowlist.txt` (the persistent-scratch design is what
//! keeps that budget flat).
//!
//! # Barrier classes
//!
//! Control events are classified by what they can actually mutate:
//!
//! * **Recompose barriers** — epochs, crash/recover/alloc fault actions,
//!   and non-resident arrivals. These can move models, change GPU
//!   grouping, or touch worker-owned allocator state, so workers must
//!   join, state must re-merge, and the event runs via the ordinary
//!   sequential `&mut self` methods.
//! * **Batch-internal pauses** — timeline samples and slowdown-only fault
//!   actions (`FaultAction::is_slowdown_only`). A sample only *reads*
//!   per-GPU memory/queue state plus two master counters; a slowdown only
//!   scales step latency. Neither changes residency or grouping, so the
//!   master records them (with their heap `(time, seq)` key) while
//!   building the window and keeps popping seeds. Workers fire each pause
//!   exactly where the sequential loop would have popped it — when the
//!   next event's `(time, class, seq)` key exceeds the pause's — emitting
//!   a [`PartialSample`] of their owned GPUs (samples) or updating their
//!   local slow-factor copy (slowdowns), then continue on the *same*
//!   window plan with no join. After the window the master replays the
//!   pauses in order: merged-on-demand partials become `TimelineSample`s
//!   (disjoint integer slot-sums — bitwise equal to the sequential read)
//!   and slow factors are applied to the master cluster.
//!
//! # Why the result is the same as `--shards 1`
//!
//! * **Residency is frozen inside a window.** Activation, eviction, and
//!   migration happen only in `on_epoch`, residency-mutating `on_fault`
//!   arms, and non-resident arrival routing — all recompose barriers.
//!   Shard workers only run `on_step`, resident-arrival enqueue,
//!   admission, and pause reads, none of which move models.
//! * **The shard partition closes over every cross-GPU edge.** A union-find
//!   over GPUs links (a) each resident model's full TP group and (b) each
//!   GPU queue to the *current* lead GPU of every queued request's model
//!   (admission's "model moved, re-route the request" arm crosses exactly
//!   that edge after a barrier migration). Components are numbered by
//!   their minimum GPU index and dealt longest-processing-time-first onto
//!   shards (see "LPT dealing" below), so the assignment is a pure
//!   function of pre-window state.
//! * **The window plan is cached across barriers.** The plan is a pure
//!   function of (residency topology, master-side queue contents), so it
//!   is keyed by `(Cluster::topo_version, Simulator::queue_version)` and
//!   reused verbatim while the key is unchanged. The invalidation rule:
//!   every master-side mutation that can *add* a cross-GPU edge bumps a
//!   version — activate/evict (and migrate, which composes them) bump
//!   `topo_version`; `enqueue_on_gpu` and `PolicyCtx::{put,extend}_gpu_queue`
//!   bump `queue_version`. Mutations that only *remove* edges (queue pops,
//!   worker-side admission, `take_gpu_queue`) never bump: a plan built
//!   from an edge superset is coarser-or-equal, which is still a valid
//!   disjoint partition. Worker-side enqueues are self-edges (a request
//!   only ever lands on its model's current lead, inside the model's own
//!   component), so windows never invalidate their own plan.
//! * **LPT dealing is deterministic.** Each component's load estimate —
//!   queued requests plus resident engines' queue + running slots, summed
//!   over member GPUs — is integer arithmetic over pre-window state.
//!   Components are processed in (load descending, min-GPU-index
//!   ascending) order and each goes to the shard minimizing the strict
//!   total order (assigned load, assigned count, shard index); no float
//!   compares, no iteration-order dependence, and with all-zero loads it
//!   degenerates to the historical round-robin deal. Any deterministic
//!   dealing yields the same metrics (shards only group *independent*
//!   components); LPT just stops one hot component's shard from capping
//!   the window.
//! * **Window events are seeded in exact sequential order.** The master
//!   pops its heap and arrival cursor with the very same merge rule as the
//!   sequential loop (arrivals win time ties; heap key `(time, seq, ...)`
//!   pops FIFO at equal times — see `Simulator::push_ev`) until it meets a
//!   *recompose* barrier, recording pauses in pop order as it goes. Each
//!   popped event is appended to its shard's seed queue, so per shard the
//!   seeds are already sorted by `(time, class, seq)` with class arrival=0
//!   < step=1. A pause keeps its heap key `(t, class 1, master seq)`,
//!   which is below every local seq (preamble pushes precede the window's
//!   `seq` snapshot) — so "fire every pause whose key precedes the next
//!   event's key" reproduces exactly the sequential pop position of the
//!   sample/fault event, including same-time ties against seeds and
//!   intra-window pushes.
//! * **Intra-window pushes sort after every seed.** A shard's local event
//!   heap orders by `(time, seq)` with a local counter starting at the
//!   master's sequence snapshot, which is ≥ every seed's seq — exactly the
//!   order the sequential loop would have used for the same pushes.
//! * **Request ids are pre-assigned.** The master assigns `next_req_id` to
//!   resident arrivals while building the window, in global consumption
//!   order, so ids are independent of shard interleaving.
//! * **Barriers recompose in a fixed order**: union the `step_scheduled`
//!   partitions, re-push surviving (post-barrier) local events shard-major
//!   through `push_ev` (fresh master seqs — relative survivor order is
//!   preserved, and barrier-time pushes sort after them, as sequentially),
//!   fold the `sim_events`/violation/token deltas (commutative integer
//!   adds; `on_sample` reads them at barriers), take the max `last_now`,
//!   and invalidate the demand cache (`refresh_demand` is a pure function
//!   of monitor state at a given time, so an extra recompute is bitwise
//!   harmless). Then the control event runs via the ordinary sequential
//!   `&mut self` methods.
//! * **Per-shard metric sinks merge exactly.** Shard sinks receive only
//!   `record()` data; completion counters and quantile sketches merge
//!   order-independently (bucket-wise adds — see `metrics::sketch`), and
//!   whole-run scalars (busy/wall/cost/counters) are assigned master-side
//!   in the finale, identical to the sequential loop.
//!
//! Batch-internal pauses re-push nothing: workers keep their local heaps
//! live across a pause, so local events that straddle a sample or slowdown
//! keep their exact `(time, seq)` order — the survivor re-push (and its
//! epsilon below) happens only at recompose barriers, same as before
//! batching (regression-tested next to `event_heap_ties_pop_in_push_order`).
//!
//! One documented epsilon: two *surviving* events from different shards at
//! bitwise-equal times are re-pushed shard-major rather than in original
//! push order. The orders can differ only if a barrier later re-colocates
//! their models onto one GPU *and* the equal-time steps then contend for
//! the same KV pool — beyond realistic (generated traces have distinct
//! float arrival times, and step times include per-model durations), and
//! accepted as out of contract; the identity tests cover policies, faults,
//! and heterogeneous fleets, not adversarially-equal timestamps.
//!
//! # Complexity budget (extends the one in `sim::simulator`)
//!
//! * **O(log heap)** per window event at build (one master pop each — the
//!   same pops the sequential loop would do) plus O(log local-heap) per
//!   intra-window push on the worker.
//! * **O(gpus · α + queued requests + components log components)** plan
//!   rebuild — union-find plus the LPT sort — paid only on a
//!   `(topo_version, queue_version)` miss; a cache hit is O(1). Samples
//!   and slowdown-only faults never miss (they mutate neither key), so
//!   sample-dense runs rebuild at most once per epoch/crash.
//! * **O(shards · (gpus + engines + models))** borrow distribution per
//!   window — linear bookkeeping, no clones of engines/GPUs/queues. (The
//!   per-slot `Option<&mut _>` vectors are rebuilt each window by
//!   necessity: they hold window-lifetime borrows and cannot outlive the
//!   `thread::scope`.)
//! * **O(shards · gpus)** per sample pause (each worker reads its owned
//!   GPUs; the master sums disjoint slots) — no join, no recompose.
//! * **Amortized zero allocation** in the steady state: seed queues,
//!   local heaps, survivor buffers, slow-factor copies, partial-sample
//!   buffers, KV-alloc scratch, and plan scratch are all persistent
//!   per-worker/master scratch recycled across windows.
//! * **Zero per-event synchronization**: workers share nothing mutable;
//!   the only joins are the per-window `std::thread::scope` barriers.
//!
//! Anything super-linear per window in models × gpus, or any per-event
//! locking, is a regression (`benches/sim_hot_path.rs`, giant-* and
//! barrier-heavy-* scenarios).

use std::cmp::Reverse;
use std::collections::{BTreeMap, BTreeSet, BinaryHeap, HashMap, VecDeque};
use std::sync::Arc;

use crate::cluster::gpu::GpuDevice;
use crate::cluster::{Cluster, GpuId, Residency};
use crate::engine::engine::{KvAlloc, SimEngine};
use crate::engine::perf::GpuPerf;
use crate::fault::FaultAction;
use crate::kvcached::BlockRef;
use crate::metrics::{merge_partial_samples, MetricsSink, PartialSample, RunMetrics, TimelineSample};
use crate::model::spec::{ModelId, ModelSpec};
use crate::request::{Phase, Request, RequestId};
use crate::sched::arbitration::{moore_hodgson, Candidate};
use crate::sched::kvpr::RateMonitor;
use crate::sim::simulator::{Ev, PolicyCtx, Simulator, Time};
use crate::trace::{ScaledEvents, Trace, TraceEvent};

// --------------------------------------------------------------- partition

/// Union-find with path halving; roots are kept at the smallest member
/// index so component identity is a pure function of the edge set.
struct Dsu(Vec<usize>);

impl Dsu {
    fn new(n: usize) -> Self {
        Dsu((0..n).collect())
    }

    /// Reset to `n` singleton sets, reusing the parent vector's capacity.
    fn reset(&mut self, n: usize) {
        self.0.clear();
        self.0.extend(0..n);
    }

    fn find(&mut self, mut x: usize) -> usize {
        while self.0[x] != x {
            self.0[x] = self.0[self.0[x]];
            x = self.0[x];
        }
        x
    }

    fn union(&mut self, a: usize, b: usize) {
        let (ra, rb) = (self.find(a), self.find(b));
        if ra != rb {
            let (lo, hi) = if ra < rb { (ra, rb) } else { (rb, ra) };
            self.0[hi] = lo;
        }
    }
}

/// The per-window shard assignment: GPU -> shard, derived from the
/// union-find described in the module docs. Built through [`PlanCache`],
/// which memoizes it across barriers while the residency/queue topology
/// version is unchanged.
struct WindowPlan {
    gpu_shard: Vec<usize>,
}

impl WindowPlan {
    /// One-shot build (tests): a throwaway cache forced to rebuild.
    #[cfg(test)]
    fn build(cluster: &Cluster, gpu_queues: &[Vec<Request>], n_shards: usize) -> Self {
        let mut cache = PlanCache::new();
        cache.plan_for(cluster, gpu_queues, 0, n_shards);
        WindowPlan { gpu_shard: cache.plan.gpu_shard.clone() }
    }

    /// Shard owning model `m`'s events: its lead GPU's shard if resident,
    /// else shard 0 (a step for an evicted model is a no-op everywhere, it
    /// just needs exactly one deterministic home; its `step_scheduled`
    /// entry is partitioned by the same rule).
    fn shard_of_model(&self, m: ModelId, residency: &BTreeMap<ModelId, Residency>) -> usize {
        residency.get(&m).map_or(0, |r| self.gpu_shard[r.gpus[0].0 as usize])
    }
}

/// Memoized window plan + reusable build scratch. The plan is a pure
/// function of residency topology and master-side queue contents, both
/// version-counted (`Cluster::topo_version`, `Simulator::queue_version`);
/// an unchanged key across a barrier reuses the previous assignment
/// verbatim — a no-op epoch, a timeline sample, or a slowdown window no
/// longer costs a union-find. All intermediate vectors are hoisted here so
/// even a rebuild allocates nothing in the steady state.
struct PlanCache {
    plan: WindowPlan,
    /// `(topo_version, queue_version)` the plan was built at.
    key: Option<(u64, u64)>,
    /// Rebuild count (exposed for the invalidation unit tests and the
    /// bench-side cache-hit accounting).
    rebuilds: u64,
    dsu: Dsu,
    /// DSU root -> dense component index (min-GPU-index order).
    comp_idx: Vec<usize>,
    /// Per-component deterministic load estimate: queued requests plus
    /// resident engines' queue + running slots over member GPUs.
    comp_load: Vec<u64>,
    /// Component indices in (load desc, component asc) deal order.
    comp_order: Vec<usize>,
    comp_shard: Vec<usize>,
    shard_load: Vec<u64>,
    shard_cnt: Vec<u32>,
}

impl PlanCache {
    fn new() -> Self {
        PlanCache {
            plan: WindowPlan { gpu_shard: Vec::new() },
            key: None,
            rebuilds: 0,
            dsu: Dsu::new(0),
            comp_idx: Vec::new(),
            comp_load: Vec::new(),
            comp_order: Vec::new(),
            comp_shard: Vec::new(),
            shard_load: Vec::new(),
            shard_cnt: Vec::new(),
        }
    }

    /// The window plan for the current topology: cached when
    /// `(cluster.topo_version, queue_version)` matches the last build,
    /// rebuilt into the reusable scratch otherwise.
    fn plan_for(
        &mut self,
        cluster: &Cluster,
        gpu_queues: &[Vec<Request>],
        queue_version: u64,
        n_shards: usize,
    ) -> &WindowPlan {
        let key = (cluster.topo_version, queue_version);
        if self.key != Some(key) {
            self.rebuild(cluster, gpu_queues, n_shards);
            self.key = Some(key);
        }
        &self.plan
    }

    fn rebuild(&mut self, cluster: &Cluster, gpu_queues: &[Vec<Request>], n_shards: usize) {
        self.rebuilds += 1;
        let n = cluster.n_gpus();
        self.dsu.reset(n);
        for res in cluster.residency.values() {
            let lead = res.gpus[0].0 as usize;
            for g in &res.gpus[1..] {
                self.dsu.union(lead, g.0 as usize);
            }
        }
        // Close the admission "moved" edge: a queued request's model may
        // have migrated; re-routing walks from the queue's GPU to the
        // model's current lead.
        for (g, q) in gpu_queues.iter().enumerate() {
            for req in q {
                if let Some(res) = cluster.residency.get(&req.model) {
                    self.dsu.union(g, res.gpus[0].0 as usize);
                }
            }
        }
        // Components in min-GPU-index order; `gpu_shard` temporarily holds
        // the dense component index until the deal below remaps it.
        self.comp_idx.clear();
        self.comp_idx.resize(n, usize::MAX);
        self.plan.gpu_shard.clear();
        self.plan.gpu_shard.resize(n, 0);
        let mut next_comp = 0usize;
        for g in 0..n {
            let r = self.dsu.find(g);
            if self.comp_idx[r] == usize::MAX {
                self.comp_idx[r] = next_comp;
                next_comp += 1;
            }
            self.plan.gpu_shard[g] = self.comp_idx[r];
        }
        // Deterministic per-component load: queued requests + resident
        // engines' queue/running slots (integer counts of pre-window state).
        self.comp_load.clear();
        self.comp_load.resize(next_comp, 0);
        for g in 0..n {
            let mut load = gpu_queues[g].len() as u64;
            for m in cluster.residents_on(g) {
                let r = &cluster.residency[m];
                if r.gpus[0].0 as usize == g {
                    let e = &cluster.engines[r.engine_idx];
                    load += (e.queue_len() + e.running_len()) as u64;
                }
            }
            self.comp_load[self.plan.gpu_shard[g]] += load;
        }
        // LPT deal: heaviest component first (min-GPU-index breaks load
        // ties), each onto the shard minimizing (load, count, index). With
        // all-zero loads this is exactly the historical round-robin deal.
        self.comp_order.clear();
        self.comp_order.extend(0..next_comp);
        let loads = &self.comp_load;
        self.comp_order.sort_by_key(|&c| (Reverse(loads[c]), c));
        self.shard_load.clear();
        self.shard_load.resize(n_shards, 0);
        self.shard_cnt.clear();
        self.shard_cnt.resize(n_shards, 0);
        self.comp_shard.clear();
        self.comp_shard.resize(next_comp, 0);
        for &c in &self.comp_order {
            let mut best = 0usize;
            for s in 1..n_shards {
                if (self.shard_load[s], self.shard_cnt[s], s)
                    < (self.shard_load[best], self.shard_cnt[best], best)
                {
                    best = s;
                }
            }
            self.comp_shard[c] = best;
            self.shard_load[best] += self.comp_load[c];
            self.shard_cnt[best] += 1;
        }
        for g in 0..n {
            self.plan.gpu_shard[g] = self.comp_shard[self.plan.gpu_shard[g]];
        }
    }
}

// ------------------------------------------------------------------ pauses

/// A batch-internal control event: recorded by the master at window build
/// (in heap pop order, keeping its `(time, seq)` key), fired by every
/// worker at exactly its sequential pop position, replayed by the master
/// after the window. See "Barrier classes" in the module docs.
struct Pause {
    t: f64,
    /// Master heap seq — below the window's `seq` snapshot, so the pause
    /// key `(t, class 1, seq)` sorts against seeds and intra-window pushes
    /// exactly as the heap event itself would have.
    seq: u64,
    kind: PauseKind,
}

enum PauseKind {
    /// Timeline sample: workers emit a [`PartialSample`]; the master
    /// merges them on demand at replay.
    Sample,
    /// Slowdown-only fault action, pre-resolved to the factor
    /// `Cluster::set_gpu_slow` would receive (`SlowEnd` -> 1.0).
    Slow { g: usize, factor: f64 },
}

// ------------------------------------------------------------------ events

/// A window event seeded by the master, already in sequential merged order.
enum SeedEv {
    /// Resident arrival: the request is pre-built (id pre-assigned in
    /// global order). `raw_prompt_tokens` is the *trace* token count —
    /// `Request::new` clamps to ≥ 1 but the monitor records the raw value.
    Arrival { model_idx: usize, raw_prompt_tokens: u32, req: Request },
    /// Engine step popped from the master heap; keeps its master seq.
    Step { t: f64, seq: u64, model: ModelId },
}

impl SeedEv {
    /// Merge key vs intra-window pushes: arrivals (class 0) win time ties,
    /// matching the sequential cursor's `at <= ht` rule; steps carry their
    /// master seq, which is below every local seq (see module docs).
    fn key(&self) -> (Time, u8, u64) {
        match self {
            SeedEv::Arrival { req, .. } => (Time(req.arrival), 0, 0),
            SeedEv::Step { t, seq, .. } => (Time(*t), 1, *seq),
        }
    }
}

/// The control event that ended a window, processed on the master after
/// recompose.
enum Boundary {
    /// Epoch / sample / fault popped from the master heap.
    Heap { t: f64, kind: u8, payload: usize },
    /// Arrival for a non-resident model: routing is a policy decision that
    /// may activate (residency change), so it is a barrier.
    Arrival(TraceEvent),
    /// Sources exhausted or past the drain tail.
    End,
}

// ----------------------------------------------------------------- alloc

/// [`KvAlloc`] over a shard's distributed GPU borrows. Mirrors
/// `cluster::gpu::GroupAlloc` operation-for-operation (same fast path,
/// same rollback, same free fan-out) so allocator behavior — and failure
/// order — is identical; it only differs in holding `Option<&mut
/// GpuDevice>` slots instead of the whole `[GpuDevice]` slice. GroupAlloc
/// itself stays untouched: wrapping the sequential path in per-GPU
/// `Option`s would tax the `--shards 1` hot loop.
struct ShardAlloc<'s, 'a> {
    gpus: &'s mut [Option<&'a mut GpuDevice>],
    group: &'s [GpuId],
    model: ModelId,
    /// Per-worker persistent scratch (one TP group's block refs per alloc
    /// round); lives in [`WorkerScratch`] so repeated steps — and repeated
    /// windows — reuse one allocation instead of a fresh `Vec` per step.
    scratch: &'s mut Vec<BlockRef>,
}

impl<'s, 'a> ShardAlloc<'s, 'a> {
    fn new(
        gpus: &'s mut [Option<&'a mut GpuDevice>],
        group: &'s [GpuId],
        model: ModelId,
        scratch: &'s mut Vec<BlockRef>,
    ) -> Self {
        scratch.clear();
        ShardAlloc { gpus, group, model, scratch }
    }

    fn dev(&mut self, g: usize) -> &mut GpuDevice {
        // INVARIANT: the dealer hands each shard exactly the devices of its
        // groups, and `g` comes from this alloc's own `group` slice.
        self.gpus[g].as_deref_mut().expect("group GPU owned by this shard")
    }
}

impl<'s, 'a> KvAlloc for ShardAlloc<'s, 'a> {
    fn width(&self) -> usize {
        self.group.len()
    }

    fn alloc_n(&mut self, n: u32, out: &mut Vec<BlockRef>) -> Result<(), crate::kvcached::KvError> {
        if self.group.len() == 1 {
            let g = self.group[0].0 as usize;
            let model = self.model;
            return self.dev(g).kvc.alloc_blocks(model, n, out);
        }
        for _ in 0..n {
            self.scratch.clear();
            for i in 0..self.group.len() {
                let g = self.group[i].0 as usize;
                let model = self.model;
                match self.dev(g).kvc.alloc_block(model) {
                    Ok(b) => self.scratch.push(b),
                    Err(e) => {
                        let partial: Vec<BlockRef> = self.scratch.drain(..).collect();
                        for (j, b) in partial.into_iter().enumerate() {
                            let gj = self.group[j].0 as usize;
                            let _ = self.dev(gj).kvc.free_block(b);
                        }
                        return Err(e);
                    }
                }
            }
            out.extend_from_slice(&self.scratch);
        }
        Ok(())
    }

    fn free_run(&mut self, refs: &[BlockRef]) {
        let width = self.group.len();
        for (i, &r) in refs.iter().enumerate() {
            let g = self.group[i % width].0 as usize;
            // INVARIANT: refs come from this group's own alloc_n in
            // block-major order, so ref i maps back to its issuing GPU.
            self.dev(g).kvc.free_block(r).expect("group free");
        }
    }
}

// ----------------------------------------------------------------- worker

/// Persistent per-worker scratch, recycled across windows (tentpole
/// "scratch reuse"): the master refills these each window instead of
/// allocating fresh containers, and workers hand them back through
/// [`ShardOut`]. Capacities grow to the run's high-water mark once and
/// stay there.
#[derive(Default)]
struct WorkerScratch {
    /// Seed queue (master-filled, worker-drained; empty between windows).
    seeds: VecDeque<SeedEv>,
    /// Intra-window local heap storage (empty between windows).
    local: BinaryHeap<Reverse<(Time, u64, u32)>>,
    /// Survivor buffer (drained by the master at recompose).
    survivors: Vec<(f64, ModelId)>,
    /// Worker-local copy of the per-GPU slow factors (master-refreshed at
    /// window start; mutated by `Slow` pauses mid-window).
    slow: Vec<f64>,
    /// One partial per `Sample` pause fired this window, in pause order.
    partials: Vec<PartialSample>,
    /// KV block-ref scratch for `ShardAlloc` (see there).
    alloc: Vec<BlockRef>,
}

/// What a shard hands back at the barrier: the window's deltas plus the
/// recycled scratch containers (moved back into [`WorkerScratch`]).
struct ShardOut {
    /// This shard's partition of `step_scheduled` (post-window).
    step_scheduled: BTreeSet<ModelId>,
    sim_events: u64,
    violations: usize,
    tokens: u64,
    /// Time of the last processed event; `NEG_INFINITY` if none.
    last_t: f64,
    /// Returned scratch. `scratch.survivors` holds the local events
    /// at/after the barrier, in pop order; re-pushed into the master heap
    /// (always Steps — shards only push via `schedule_step`).
    /// `scratch.partials[k]` is this shard's contribution to the window's
    /// k-th sample pause.
    scratch: WorkerScratch,
}

/// One shard's disjoint view of the simulator between two barriers. Every
/// method is a line-for-line replica of the corresponding
/// `sim::simulator` method (`on_arrival` resident path, `admit_gpu`,
/// `on_step`, `schedule_step`) against distributed borrows — behavioral
/// drift between the two is a correctness bug, caught by
/// `tests/shard_identity.rs`.
struct ShardCtx<'a> {
    specs: &'a [ModelSpec],
    /// Lookup-only (never iterated): hash order cannot reach the metric
    /// fingerprint, so this stays D2-clean without a waiver.
    model_index: &'a HashMap<ModelId, usize>,
    gpu_perfs: &'a [GpuPerf],
    slack_aware: bool,
    faults_enabled: bool,
    engines: Vec<Option<&'a mut SimEngine>>,
    gpus: Vec<Option<&'a mut GpuDevice>>,
    queues: Vec<Option<&'a mut Vec<Request>>>,
    monitors: Vec<Option<&'a mut RateMonitor>>,
    last_request_at: Vec<Option<&'a mut f64>>,
    residency: BTreeMap<ModelId, &'a mut Residency>,
    metrics: &'a mut RunMetrics,
    step_scheduled: BTreeSet<ModelId>,
    /// This window's batch-internal pauses (shared, read-only), and the
    /// cursor over them. `scratch.slow` starts as the window-start
    /// snapshot and tracks `Slow` pauses as they fire; `scratch.partials`
    /// gains one entry per `Sample` pause fired.
    pauses: &'a [Pause],
    pause_idx: usize,
    /// Sample pauses fired this window == valid prefix of
    /// `scratch.partials` (the vector itself is recycled, never truncated).
    sample_no: usize,
    /// Owned per-worker scratch: seed queue (`scratch.seeds`), local heap
    /// of intra-window pushes `(time, local seq, model id)`
    /// (`scratch.local`), survivor buffer, slow factors, sample partials,
    /// and KV-alloc scratch. Returned via `ShardOut` for recycling.
    scratch: WorkerScratch,
    seq: u64,
    sim_events: u64,
    violations: usize,
    tokens: u64,
    last_t: f64,
}

impl<'a> ShardCtx<'a> {
    /// Replay this shard's window slice. `limit` is the barrier time:
    /// local events run while `t < limit` (a local push at exactly the
    /// barrier time has a seq above the barrier's, so sequentially it
    /// would pop *after* the barrier — it must survive). For the final
    /// drain (`inclusive`), events run while `t <= limit` (the tail
    /// cutoff), matching the sequential `now > tail_limit` break. Seeds
    /// are always fully consumed: the master already popped them in
    /// pre-barrier merged order.
    fn run_window(mut self, limit: f64, inclusive: bool) -> ShardOut {
        loop {
            let seed_key = self.scratch.seeds.front().map(SeedEv::key);
            let local_key = self.scratch.local.peek().map(|Reverse((t, s, _))| (*t, 1u8, *s));
            let take_local = match (&seed_key, &local_key) {
                (None, None) => break,
                (Some(_), None) => false,
                (None, Some(_)) => true,
                (Some(sk), Some(lk)) => lk < sk,
            };
            // Fire every pause the sequential loop would have popped before
            // this event (pause keys `(t, 1, master seq)` sort below every
            // local push at equal times — master seqs predate the window's
            // seq snapshot — and against seeds in exact heap pop order).
            // INVARIANT: the match above only sets take_local when the
            // corresponding key is Some.
            let next_key = if take_local { local_key.unwrap() } else { seed_key.unwrap() };
            self.fire_pauses_before(next_key);
            if take_local {
                // INVARIANT: local_key was Some, and nothing popped between.
                let &Reverse((Time(t), _, mid)) = self.scratch.local.peek().expect("peeked");
                let past = if inclusive { t > limit } else { t >= limit };
                if past {
                    // Only local (post-barrier) events can remain: a seed
                    // never sorts after a local event past the barrier.
                    debug_assert!(seed_key.is_none(), "seed past the window barrier");
                    break;
                }
                self.scratch.local.pop();
                self.sim_events += 1;
                self.last_t = t;
                self.on_step(ModelId(mid), t);
            } else {
                // INVARIANT: seed_key was Some, and nothing popped between.
                match self.scratch.seeds.pop_front().expect("peeked") {
                    SeedEv::Arrival { model_idx, raw_prompt_tokens, req } => {
                        self.sim_events += 1;
                        self.last_t = req.arrival;
                        self.on_arrival(model_idx, raw_prompt_tokens, req);
                    }
                    SeedEv::Step { t, model, .. } => {
                        self.sim_events += 1;
                        self.last_t = t;
                        self.on_step(model, t);
                    }
                }
            }
        }
        // Pauses not overtaken by any event (trailing samples/slowdowns, or
        // an entirely idle shard) fire now: every pause key precedes the
        // window boundary, which precedes every surviving local event.
        while self.pause_idx < self.pauses.len() {
            self.fire_pause(self.pause_idx);
            self.pause_idx += 1;
        }
        self.scratch.survivors.clear();
        while let Some(Reverse((Time(t), _, mid))) = self.scratch.local.pop() {
            self.scratch.survivors.push((t, ModelId(mid)));
        }
        ShardOut {
            step_scheduled: self.step_scheduled,
            sim_events: self.sim_events,
            violations: self.violations,
            tokens: self.tokens,
            last_t: self.last_t,
            scratch: self.scratch,
        }
    }

    /// Fire pauses whose key `(t, class 1, master seq)` precedes `key`.
    fn fire_pauses_before(&mut self, key: (Time, u8, u64)) {
        while self.pause_idx < self.pauses.len() {
            let p = &self.pauses[self.pause_idx];
            if (Time(p.t), 1u8, p.seq) >= key {
                break;
            }
            self.fire_pause(self.pause_idx);
            self.pause_idx += 1;
        }
    }

    /// Apply pause `i` shard-locally: `Slow` updates this worker's slow-
    /// factor copy (replica of `Cluster::set_gpu_slow`); `Sample` captures
    /// a [`PartialSample`] of the owned GPUs — the replica of the
    /// `Simulator::on_sample` reads restricted to slots this shard owns,
    /// plus the window-cumulative violation/token counters the master
    /// needs to reconstruct `cum_violations` / `inst_token_tput` exactly.
    fn fire_pause(&mut self, i: usize) {
        match self.pauses[i].kind {
            PauseKind::Slow { g, factor } => {
                self.scratch.slow[g] = factor;
            }
            PauseKind::Sample => {
                let t = self.pauses[i].t;
                let n = self.gpus.len();
                // `partials` is never truncated: entry `k` (and its inner
                // buffers) is recycled window after window; `sample_no`
                // bounds the entries valid for THIS window.
                let k = self.sample_no;
                self.sample_no += 1;
                if self.scratch.partials.len() <= k {
                    self.scratch.partials.push(PartialSample::default());
                }
                let mut part = std::mem::take(&mut self.scratch.partials[k]);
                part.reset(t, n);
                for g in 0..n {
                    if let Some(dev) = self.gpus[g].as_deref_mut() {
                        let st = dev.kvc.stats();
                        part.gpus[g] =
                            (st.weight_bytes, st.kv_mapped_bytes, st.kv_used_bytes, st.free_bytes);
                    }
                    if let Some(q) = self.queues[g].as_deref() {
                        part.queue_lens[g] = q.len();
                    }
                }
                for r in self.residency.values() {
                    let lead = r.gpus[0].0 as usize;
                    // INVARIANT: engines are dealt alongside their residency.
                    let eng = self.engines[r.engine_idx].as_deref().expect("engine owned");
                    part.queue_lens[lead] += eng.queue_len() + eng.running_len();
                }
                part.window_violations = self.violations;
                part.window_tokens = self.tokens;
                self.scratch.partials[k] = part;
            }
        }
    }

    /// Replica of `Simulator::schedule_step` against the local heap.
    fn schedule_step(&mut self, m: ModelId, t: f64) {
        if self.step_scheduled.insert(m) {
            self.seq += 1;
            self.scratch.local.push(Reverse((Time(t), self.seq, m.0)));
        }
    }

    /// Replica of `Simulator::on_arrival`'s resident path (the request is
    /// pre-built master-side; non-resident arrivals are barriers and never
    /// reach a shard). The demand-cache invalidation is represented by the
    /// master's unconditional invalidation at recompose.
    fn on_arrival(&mut self, model_idx: usize, raw_prompt_tokens: u32, req: Request) {
        let now = req.arrival;
        // INVARIANT: the window plan deals every arrival model's monitor
        // slot to this shard.
        self.monitors[model_idx]
            .as_deref_mut()
            .expect("arrival model's monitor owned by this shard")
            .record(now, raw_prompt_tokens as u64);
        // INVARIANT: same dealing as the monitor above.
        *self.last_request_at[model_idx]
            .as_deref_mut()
            .expect("arrival model's last_request_at owned by this shard") = now;
        if let Some(r) = self.residency.get_mut(&req.model) {
            r.last_active = now;
        }
        // INVARIANT: seeded arrivals were resident at window build and
        // residency is frozen until the barrier (enqueue_on_gpu replica).
        let res = self.residency.get(&req.model).expect("resident");
        let lead = res.gpus[0].0 as usize;
        let ready = res.ready_at;
        let m = req.model;
        // INVARIANT: the plan deals each resident model's lead queue here.
        self.queues[lead].as_deref_mut().expect("lead queue owned by this shard").push(req);
        self.schedule_step(m, now.max(ready));
    }

    /// Replica of `Simulator::admit_gpu`.
    fn admit_gpu(&mut self, g: usize, now: f64) {
        // INVARIANT: admit_gpu runs only for GPUs in this shard's groups,
        // whose queues the plan dealt to this worker.
        if self.queues[g].as_deref().expect("queue owned by this shard").is_empty() {
            return;
        }
        // INVARIANT: same queue ownership as the emptiness check above.
        let queue = std::mem::take(self.queues[g].as_deref_mut().expect("queue owned"));
        let (mut admit, mut keep): (Vec<Request>, Vec<Request>) = if self.slack_aware {
            let gpu_perf = &self.gpu_perfs[g];
            let cands: Vec<Candidate> = queue
                .iter()
                .map(|r| {
                    let idx = self.model_index[&r.model];
                    let c = gpu_perf.prefill_tokens_per_sec(&self.specs[idx]);
                    Candidate {
                        id: r.id,
                        arrival: r.arrival,
                        deadline: r.ttft_deadline(),
                        exec: r.prompt_tokens as f64 / c,
                    }
                })
                .collect();
            let sched = moore_hodgson(now, &cands);
            let mut order: BTreeMap<RequestId, usize> = BTreeMap::new();
            for (i, id) in sched.admitted.iter().chain(sched.deferred.iter()).enumerate() {
                order.insert(*id, i);
            }
            let mut adm: Vec<Request> = queue;
            adm.sort_by_key(|r| order[&r.id]);
            (adm, Vec::new())
        } else {
            (queue, Vec::new())
        };

        let mut still: Vec<Request> = Vec::new();
        let mut moved: Vec<(usize, Request)> = Vec::new();
        for req in admit.drain(..) {
            // An in-shard residency miss means *globally* non-resident: the
            // window plan links every queue to its queued models' current
            // lead GPUs, so "resident on another shard" cannot occur here.
            if let Some(res) = self.residency.get(&req.model) {
                let lead = res.gpus[0].0 as usize;
                if lead != g {
                    let m = req.model;
                    let t = res.ready_at.max(now);
                    moved.push((lead, req));
                    self.schedule_step(m, t);
                    continue;
                }
            }
            match self.residency.get(&req.model) {
                Some(res) if res.ready_at <= now + 1e-9 => {
                    let eidx = res.engine_idx;
                    // INVARIANT: every resident model's engine is dealt to
                    // the shard owning its lead GPU — this one.
                    let eng = self.engines[eidx].as_deref().expect("engine owned");
                    let cap = eng.max_batch as usize * 2;
                    let load = eng.queue_len() + eng.running_len();
                    if load < cap {
                        let m = req.model;
                        // INVARIANT: engine ownership as above.
                        self.engines[eidx].as_deref_mut().expect("engine owned").admit(req);
                        self.schedule_step(m, now);
                    } else {
                        still.push(req);
                    }
                }
                Some(res) => {
                    let t = res.ready_at;
                    let m = req.model;
                    still.push(req);
                    self.schedule_step(m, t);
                }
                None => still.push(req),
            }
        }
        keep.extend(still);
        // INVARIANT: queue ownership as checked at entry; moved requests'
        // lead queues are dealt alongside their residency links.
        *self.queues[g].as_deref_mut().expect("queue owned") = keep;
        for (lead, req) in moved {
            self.queues[lead].as_deref_mut().expect("lead queue owned").push(req);
        }
    }

    /// Replica of `Simulator::on_step`.
    fn on_step(&mut self, m: ModelId, now: f64) {
        self.step_scheduled.remove(&m);
        let Some(res) = self.residency.get(&m) else {
            return;
        };
        if res.ready_at > now + 1e-9 {
            let t = res.ready_at;
            self.schedule_step(m, t);
            return;
        }
        let lead = res.gpus[0].0 as usize;
        self.admit_gpu(lead, now);

        let Some(res) = self.residency.get(&m) else {
            return;
        };
        let eidx = res.engine_idx;
        let group = res.gpus.clone();
        // INVARIANT: a resident model's engine is dealt with its lead GPU.
        if !self.engines[eidx].as_deref().expect("engine owned").has_work() {
            return;
        }
        if self.faults_enabled {
            // Replica of `Cluster::group_slow_factor` over the worker-local
            // copy (updated in place by `Slow` pauses mid-window).
            let scale =
                group.iter().map(|g| self.scratch.slow[g.0 as usize]).fold(1.0, f64::max);
            // INVARIANT: engine ownership as above.
            self.engines[eidx].as_deref_mut().expect("engine owned").time_scale = scale;
        }
        let outcome = {
            let lead_perf = &self.gpu_perfs[lead];
            let (engines, gpus, alloc) =
                (&mut self.engines, &mut self.gpus, &mut self.scratch.alloc);
            let mut ga = ShardAlloc::new(gpus, &group, m, alloc);
            // INVARIANT: engine ownership as above.
            engines[eidx].as_deref_mut().expect("engine owned").step(now, lead_perf, &mut ga)
        };
        for c in outcome.completions {
            if !c.ttft_ok() {
                self.violations += 1;
            }
            self.tokens += (c.prompt_tokens + c.output_tokens) as u64;
            let idx = self.model_index[&c.model];
            // INVARIANT: completions come from this shard's own engines, so
            // their models' monitors were dealt here.
            self.monitors[idx]
                .as_deref_mut()
                .expect("completion model's monitor owned by this shard")
                .record(now, c.output_tokens as u64);
            self.metrics.record(c);
        }
        if let Some(r) = self.residency.get_mut(&m) {
            r.last_active = now;
        }
        // INVARIANT: engine ownership as above.
        if outcome.duration > 0.0 {
            self.schedule_step(m, now + outcome.duration);
        } else if self.engines[eidx].as_deref().expect("engine owned").has_work() {
            let t = now + self.gpu_perfs[lead].iter_overhead;
            self.schedule_step(m, t);
        }
    }
}

// ----------------------------------------------------------------- driver

impl Simulator {
    /// The sharded counterpart of `run_inner`'s streamed event loop.
    /// Dispatched from `run_inner` when `shards > 1` (streamed arrivals
    /// over a sorted source only); preamble and finale are statement-for-
    /// statement the sequential ones.
    pub(crate) fn run_sharded<'a>(
        mut self,
        trace: &'a Trace,
        mut scaled: Option<ScaledEvents<'a>>,
        n_shards: usize,
    ) -> (RunMetrics, Vec<TimelineSample>) {
        let policy = Arc::clone(&self.cfg.policy);
        policy.initial_placement(&mut PolicyCtx::new(&mut self));

        let mut next_arrival = 0usize;
        let mut t = 0.0;
        while t < trace.duration {
            t += self.cfg.control_epoch;
            self.push_ev(t, Ev::Epoch);
        }
        if self.cfg.sample_dt > 0.0 {
            let mut t = 0.0;
            while t < trace.duration {
                self.push_ev(t, Ev::Sample);
                t += self.cfg.sample_dt;
            }
        }
        let tail_limit = trace.duration + 600.0;
        for i in 0..self.fault_schedule.len() {
            let t = self.fault_schedule[i].0;
            if t <= tail_limit {
                self.push_ev(t, Ev::Fault(i));
            }
        }

        // One sink per shard for the whole run (merged in the finale);
        // per-window they are lent to the shard contexts.
        let mut shard_sinks: Vec<RunMetrics> = (0..n_shards)
            .map(|_| RunMetrics::with_full_dump(self.cfg.metrics_full_dump))
            .collect();

        // Run-lifetime plan cache + per-worker scratch + pause list, all
        // recycled window after window (tentpoles 2 and 4).
        let mut plan_cache = PlanCache::new();
        let mut scratch: Vec<WorkerScratch> =
            (0..n_shards).map(|_| WorkerScratch::default()).collect();
        let mut pauses: Vec<Pause> = Vec::new();

        let mut last_now = 0.0f64;
        loop {
            // -------- window build: pop sources in sequential merged order
            let plan =
                plan_cache.plan_for(&self.cluster, &self.gpu_queues, self.queue_version, n_shards);
            pauses.clear();
            let boundary = loop {
                let heap_head = self.heap.peek().map(|Reverse((t, ..))| t.0);
                let arrival_head = match &mut scaled {
                    Some(c) => c.peek_t(),
                    None => (next_arrival < trace.events.len())
                        .then(|| trace.events[next_arrival].t),
                };
                let take_arrival = match (arrival_head, heap_head) {
                    (Some(at), Some(ht)) => at <= ht,
                    (Some(_), None) => true,
                    (None, _) => false,
                };
                if take_arrival {
                    // INVARIANT: take_arrival is only true in match arms
                    // where arrival_head is Some.
                    let at = arrival_head.expect("take_arrival implies a head");
                    if at > tail_limit {
                        break Boundary::End;
                    }
                    let e = match &mut scaled {
                        // INVARIANT: peek_t() returned Some above, and
                        // nothing advanced the cursor since.
                        Some(c) => c.next_event().expect("peeked event exists"),
                        None => {
                            let i = next_arrival;
                            next_arrival += 1;
                            trace.events[i].clone()
                        }
                    };
                    let idx = e.model_idx;
                    let m = self.specs[idx].id;
                    if !self.cluster.is_resident(m) {
                        break Boundary::Arrival(e);
                    }
                    // Pre-build the request exactly as `on_arrival` would,
                    // assigning ids in global consumption order.
                    let (ttft_slo, tpot_slo) = self.slos[idx];
                    let req = Request::new(
                        self.next_req_id,
                        m,
                        e.t,
                        e.prompt_tokens,
                        e.output_tokens,
                        ttft_slo,
                        tpot_slo,
                    );
                    self.next_req_id += 1;
                    let lead = self.cluster.residency[&m].gpus[0].0 as usize;
                    scratch[plan.gpu_shard[lead]].seeds.push_back(SeedEv::Arrival {
                        model_idx: idx,
                        raw_prompt_tokens: e.prompt_tokens,
                        req,
                    });
                    continue;
                }
                let Some(head) = self.heap.peek().map(|Reverse((t, s, k, p))| (t.0, *s, *k, *p))
                else {
                    break Boundary::End;
                };
                let (ht, seq, kind, payload) = head;
                if ht > tail_limit {
                    break Boundary::End;
                }
                self.heap.pop();
                match kind {
                    1 => {
                        let m = ModelId(payload as u32);
                        let s = plan.shard_of_model(m, &self.cluster.residency);
                        scratch[s].seeds.push_back(SeedEv::Step { t: ht, seq, model: m });
                    }
                    // Timeline samples never mutate residency/grouping:
                    // batch-internal pause, keep popping on the same plan.
                    3 => pauses.push(Pause { t: ht, seq, kind: PauseKind::Sample }),
                    // Slowdown-only fault actions likewise; resolve the
                    // factor `on_fault` would pass to `set_gpu_slow` now.
                    4 if self.fault_schedule[payload].1.is_slowdown_only() => {
                        let (g, factor) = match self.fault_schedule[payload].1 {
                            FaultAction::SlowStart(g, f) => (g as usize, f),
                            FaultAction::SlowEnd(g) => (g as usize, 1.0),
                            _ => unreachable!("is_slowdown_only"),
                        };
                        pauses.push(Pause { t: ht, seq, kind: PauseKind::Slow { g, factor } });
                    }
                    // Epochs and residency/allocator-mutating faults stay
                    // full recompose barriers.
                    2 | 4 => break Boundary::Heap { t: ht, kind, payload },
                    // Pre-pushed arrivals (kind 0) only exist in the legacy
                    // `stream_arrivals = false` mode, which never dispatches
                    // to the sharded loop.
                    _ => unreachable!("unexpected heap event kind in sharded loop"),
                }
            };

            // -------- run the window on worker threads
            let window_events: usize = scratch.iter().map(|s| s.seeds.len()).sum();
            // Window-base counter snapshots: partial samples report
            // *window-cumulative* violations/tokens, so pause replay below
            // reconstructs each sequential sample read as base + Σ shards.
            let base_violations = self.cum_violations;
            let base_tokens = self.tokens_since_sample;
            if window_events > 0 {
                let (limit, inclusive) = match &boundary {
                    Boundary::End => (tail_limit, true),
                    Boundary::Arrival(e) => (e.t, false),
                    Boundary::Heap { t, .. } => (*t, false),
                };
                // Partition `step_scheduled` by the same model -> shard rule
                // as Step events, before taking field borrows.
                let mut ss_parts: Vec<BTreeSet<ModelId>> =
                    (0..n_shards).map(|_| BTreeSet::new()).collect();
                for m in std::mem::take(&mut self.step_scheduled) {
                    ss_parts[plan.shard_of_model(m, &self.cluster.residency)].insert(m);
                }
                let seq_snapshot = self.seq;
                let n_gpus = self.cluster.n_gpus();
                let n_eng = self.cluster.engines.len();
                let n_models = self.specs.len();
                // Per-worker slow-factor copies (not one shared snapshot):
                // `Slow` pauses mutate them mid-window, worker-locally.
                for ws in &mut scratch {
                    ws.slow.clear();
                    ws.slow.extend((0..n_gpus).map(|g| self.cluster.gpu_slow_factor(g)));
                }
                let mut eng_shard = vec![usize::MAX; n_eng];
                let mut model_shard = vec![usize::MAX; n_models];
                for (m, r) in &self.cluster.residency {
                    let s = plan.gpu_shard[r.gpus[0].0 as usize];
                    eng_shard[r.engine_idx] = s;
                    model_shard[self.model_index[m]] = s;
                }

                let outs: Vec<ShardOut> = {
                    // Disjoint borrow distribution: every `&mut` lands in
                    // exactly one shard's context (per-slot `Option`s built
                    // from one `iter_mut` pass each).
                    let specs: &[ModelSpec] = &self.specs;
                    let model_index = &self.model_index;
                    let slack_aware = self.cfg.slack_aware;
                    let faults_enabled = self.faults_enabled;
                    let cluster = &mut self.cluster;
                    let gpu_perfs: &[GpuPerf] = &cluster.gpu_perfs;
                    let mut eng_refs: Vec<Vec<Option<&mut SimEngine>>> =
                        (0..n_shards).map(|_| (0..n_eng).map(|_| None).collect()).collect();
                    for (i, e) in cluster.engines.iter_mut().enumerate() {
                        if eng_shard[i] != usize::MAX {
                            eng_refs[eng_shard[i]][i] = Some(e);
                        }
                    }
                    let mut gpu_refs: Vec<Vec<Option<&mut GpuDevice>>> =
                        (0..n_shards).map(|_| (0..n_gpus).map(|_| None).collect()).collect();
                    for (g, d) in cluster.gpus.iter_mut().enumerate() {
                        gpu_refs[plan.gpu_shard[g]][g] = Some(d);
                    }
                    let mut queue_refs: Vec<Vec<Option<&mut Vec<Request>>>> =
                        (0..n_shards).map(|_| (0..n_gpus).map(|_| None).collect()).collect();
                    for (g, q) in self.gpu_queues.iter_mut().enumerate() {
                        queue_refs[plan.gpu_shard[g]][g] = Some(q);
                    }
                    let mut mon_refs: Vec<Vec<Option<&mut RateMonitor>>> =
                        (0..n_shards).map(|_| (0..n_models).map(|_| None).collect()).collect();
                    for (i, mo) in self.monitors.iter_mut().enumerate() {
                        if model_shard[i] != usize::MAX {
                            mon_refs[model_shard[i]][i] = Some(mo);
                        }
                    }
                    let mut lra_refs: Vec<Vec<Option<&mut f64>>> =
                        (0..n_shards).map(|_| (0..n_models).map(|_| None).collect()).collect();
                    for (i, v) in self.last_request_at.iter_mut().enumerate() {
                        if model_shard[i] != usize::MAX {
                            lra_refs[model_shard[i]][i] = Some(v);
                        }
                    }
                    let mut res_maps: Vec<BTreeMap<ModelId, &mut Residency>> =
                        (0..n_shards).map(|_| BTreeMap::new()).collect();
                    for (m, r) in cluster.residency.iter_mut() {
                        res_maps[plan.gpu_shard[r.gpus[0].0 as usize]].insert(*m, r);
                    }

                    let mut ctxs: Vec<ShardCtx<'_>> = Vec::with_capacity(n_shards);
                    let mut eng_it = eng_refs.into_iter();
                    let mut gpu_it = gpu_refs.into_iter();
                    let mut q_it = queue_refs.into_iter();
                    let mut mon_it = mon_refs.into_iter();
                    let mut lra_it = lra_refs.into_iter();
                    let mut res_it = res_maps.into_iter();
                    let mut ss_it = ss_parts.into_iter();
                    let mut sink_it = shard_sinks.iter_mut();
                    let mut scratch_it = scratch.iter_mut();
                    let pauses: &[Pause] = &pauses;
                    for _ in 0..n_shards {
                        ctxs.push(ShardCtx {
                            specs,
                            model_index,
                            gpu_perfs,
                            slack_aware,
                            faults_enabled,
                            // INVARIANT: every dealt iterator yields exactly
                            // n_shards entries (built just above).
                            engines: eng_it.next().expect("one per shard"),
                            gpus: gpu_it.next().expect("one per shard"),
                            queues: q_it.next().expect("one per shard"),
                            // INVARIANT: one entry per shard, as above.
                            monitors: mon_it.next().expect("one per shard"),
                            last_request_at: lra_it.next().expect("one per shard"),
                            residency: res_it.next().expect("one per shard"),
                            // INVARIANT: one entry per shard, as above.
                            metrics: sink_it.next().expect("one per shard"),
                            step_scheduled: ss_it.next().expect("one per shard"),
                            pauses,
                            pause_idx: 0,
                            sample_no: 0,
                            // INVARIANT: one entry per shard, as above.
                            scratch: std::mem::take(scratch_it.next().expect("one per shard")),
                            seq: seq_snapshot,
                            sim_events: 0,
                            violations: 0,
                            tokens: 0,
                            last_t: f64::NEG_INFINITY,
                        });
                    }
                    let active = ctxs.iter().filter(|c| !c.scratch.seeds.is_empty()).count();
                    if active <= 1 {
                        // Nothing to overlap: run inline, no thread spawns.
                        // (Empty-seed shards still run: they fire every
                        // pause, contributing their owned GPUs' partials.)
                        ctxs.into_iter().map(|c| c.run_window(limit, inclusive)).collect()
                    } else {
                        std::thread::scope(|scope| {
                            let handles: Vec<_> = ctxs
                                .into_iter()
                                .map(|c| {
                                    if c.scratch.seeds.is_empty() {
                                        // Trivially empty: resolve inline.
                                        Err(c.run_window(limit, inclusive))
                                    } else {
                                        Ok(scope.spawn(move || c.run_window(limit, inclusive)))
                                    }
                                })
                                .collect();
                            handles
                                .into_iter()
                                .map(|h| match h {
                                    // INVARIANT: propagating a worker panic
                                    // is the intended failure mode.
                                    Ok(j) => j.join().expect("shard worker panicked"),
                                    Err(o) => o,
                                })
                                .collect()
                        })
                    }
                };

                // -------- recompose (order matters; see module docs)
                for (s, out) in outs.into_iter().enumerate() {
                    self.step_scheduled.extend(out.step_scheduled);
                    self.metrics.sim_events += out.sim_events;
                    self.cum_violations += out.violations;
                    self.tokens_since_sample += out.tokens;
                    if out.last_t > last_now {
                        last_now = out.last_t;
                    }
                    for &(t, m) in &out.scratch.survivors {
                        // The model is still in the merged `step_scheduled`
                        // (its shard never removed it), so push directly.
                        self.push_ev(t, Ev::Step(m));
                    }
                    // Hand the scratch containers back for the next window.
                    scratch[s] = out.scratch;
                }
                self.demand_cache_at = f64::NEG_INFINITY;

                // -------- pause replay: apply the batch-internal control
                // events in pop order, exactly as the sequential loop
                // interleaved them (each already *observed* mid-window by
                // the workers; this is the master-side half).
                let mut consumed: u64 = 0;
                let mut sample_no = 0usize;
                for p in &pauses {
                    self.metrics.sim_events += 1;
                    if p.t > last_now {
                        last_now = p.t;
                    }
                    match p.kind {
                        PauseKind::Slow { g, factor } => self.cluster.set_gpu_slow(g, factor),
                        PauseKind::Sample => {
                            let k = sample_no;
                            sample_no += 1;
                            // Sequential reads at this sample, recomposed
                            // from disjoint integer parts: cumulative
                            // counters are window base + Σ shard deltas at
                            // pause k; the throughput numerator is "tokens
                            // since the previous sample" = cumulative at k
                            // minus what earlier samples consumed.
                            let cum_viol = base_violations
                                + scratch
                                    .iter()
                                    .map(|ws| ws.partials[k].window_violations)
                                    .sum::<usize>();
                            let cum_tok = base_tokens
                                + scratch.iter().map(|ws| ws.partials[k].window_tokens).sum::<u64>();
                            let tput =
                                (cum_tok - consumed) as f64 / self.cfg.sample_dt.max(1e-9);
                            consumed = cum_tok;
                            self.timeline.push(merge_partial_samples(
                                p.t,
                                self.cluster.n_gpus(),
                                scratch.iter().map(|ws| &ws.partials[k]),
                                cum_viol,
                                tput,
                            ));
                        }
                    }
                }
                // The recompose fold above re-added every window token;
                // settle the "since last sample" counter to its sequential
                // value (total minus what the samples consumed).
                self.tokens_since_sample -= consumed;
            } else {
                // No window events: the batch was pure control traffic.
                // Replay pauses with the ordinary sequential methods — the
                // master owns all state, so `on_sample` reads it directly.
                for i in 0..pauses.len() {
                    let (t, kind) = (pauses[i].t, &pauses[i].kind);
                    self.metrics.sim_events += 1;
                    if t > last_now {
                        last_now = t;
                    }
                    match *kind {
                        PauseKind::Sample => self.on_sample(t),
                        PauseKind::Slow { g, factor } => self.cluster.set_gpu_slow(g, factor),
                    }
                }
            }

            // -------- the control event itself, sequentially on the master
            match boundary {
                Boundary::End => break,
                Boundary::Arrival(e) => {
                    last_now = e.t;
                    self.metrics.sim_events += 1;
                    self.on_arrival(&e);
                }
                Boundary::Heap { t, kind, payload } => {
                    last_now = t;
                    self.metrics.sim_events += 1;
                    match kind {
                        2 => {
                            self.on_epoch(t);
                            if t + self.cfg.control_epoch <= tail_limit
                                && (self.has_outstanding() || t < trace.duration)
                            {
                                self.push_ev(t + self.cfg.control_epoch, Ev::Epoch);
                            }
                        }
                        // Samples (kind 3) and slowdown-only faults are
                        // batch-internal pauses now — they never break a
                        // window, so only hard fault actions land here.
                        4 => self.on_fault(payload, t),
                        _ => unreachable!(),
                    }
                }
            }
        }

        // -------- finale: statement-for-statement `run_inner`'s, plus the
        // shard-sink fold (record-only data; the whole-run scalars below
        // are assigned afterwards, overwriting the fold's zero-valued
        // contributions to them).
        let mut leftovers: Vec<Request> = std::mem::take(&mut self.pending);
        for q in &mut self.gpu_queues {
            leftovers.append(q);
        }
        for mut r in leftovers {
            r.phase = Phase::Dropped;
            self.metrics.record(crate::request::Completion::from_request(&r));
        }
        for sink in shard_sinks {
            self.metrics.merge(sink);
        }

        self.metrics.busy_seconds = self.cluster.engines.iter().map(|e| e.busy_seconds).sum();
        self.metrics.preemptions += self.cluster.engines.iter().map(|e| e.preemptions).sum::<u64>();
        self.metrics.wall_seconds = last_now;
        self.metrics.activations = self.cluster.activations;
        self.metrics.evictions = self.cluster.evictions;
        self.metrics.migrations = self.cluster.migrations;
        self.metrics.faults.load_retries = self.cluster.load_retries;
        self.metrics.faults.load_failures = self.cluster.load_failures;
        self.metrics.faults.alloc_faults_injected = self
            .cluster
            .gpus
            .iter()
            .map(|d| d.kvc.alloc_faults_injected())
            .sum();
        self.metrics.cost.fleet_cost_per_hour = self.cluster.fleet_cost_per_hour();
        self.metrics.cost.cost_dollars = self.metrics.cost.fleet_cost_per_hour * last_now / 3600.0;
        (self.metrics, self.timeline)
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::model::spec::{catalog_subset, GB};
    use crate::sim::simulator::SimConfig;
    use crate::trace::gen::{generate, TraceGenConfig};

    #[test]
    fn dsu_roots_at_min_index() {
        let mut d = Dsu::new(6);
        d.union(4, 2);
        d.union(2, 5);
        d.union(1, 3);
        assert_eq!(d.find(4), 2);
        assert_eq!(d.find(5), 2);
        assert_eq!(d.find(3), 1);
        assert_eq!(d.find(0), 0);
        // Merge the two components: the root is the global min member.
        d.union(5, 1);
        for g in [1, 2, 3, 4, 5] {
            assert_eq!(d.find(g), 1);
        }
    }

    #[test]
    fn empty_cluster_plan_deals_gpus_round_robin() {
        let cluster = Cluster::new(5, 80 * GB, 8, GpuPerf::default());
        let queues: Vec<Vec<Request>> = (0..5).map(|_| Vec::new()).collect();
        let plan = WindowPlan::build(&cluster, &queues, 2);
        // No residency, no queues: each GPU is its own component, numbered
        // by index, dealt alternately.
        assert_eq!(plan.gpu_shard, vec![0, 1, 0, 1, 0]);
        let plan1 = WindowPlan::build(&cluster, &queues, 1);
        assert!(plan1.gpu_shard.iter().all(|&s| s == 0));
    }

    #[test]
    fn lpt_deal_splits_skewed_queue_load() {
        let cluster = Cluster::new(5, 80 * GB, 8, GpuPerf::default());
        let mut queues: Vec<Vec<Request>> = (0..5).map(|_| Vec::new()).collect();
        let mut id = 0u64;
        for (g, n) in [5usize, 0, 3, 1, 0].into_iter().enumerate() {
            for _ in 0..n {
                queues[g].push(Request::new(id, ModelId(99), 0.0, 64, 16, 1.0, 0.1));
                id += 1;
            }
        }
        let plan = WindowPlan::build(&cluster, &queues, 2);
        // Loads [5, 0, 3, 1, 0]: LPT isolates hot GPU 0 on shard 0 and
        // groups the rest (3 + 1 + 0 + 0) on shard 1. The historical
        // round-robin deal [0, 1, 0, 1, 0] would have stacked 8 of the 9
        // queued requests on shard 0.
        assert_eq!(plan.gpu_shard, vec![0, 1, 1, 1, 1]);
    }

    #[test]
    fn plan_cache_invalidates_on_topology_and_queue_versions() {
        let mut cluster = Cluster::new(2, 80 * GB, 8, GpuPerf::default());
        let queues: Vec<Vec<Request>> = (0..2).map(|_| Vec::new()).collect();
        let mut cache = PlanCache::new();
        cache.plan_for(&cluster, &queues, 0, 2);
        assert_eq!(cache.rebuilds, 1);
        // Same key across a no-op barrier: the plan is reused verbatim.
        cache.plan_for(&cluster, &queues, 0, 2);
        cache.plan_for(&cluster, &queues, 0, 2);
        assert_eq!(cache.rebuilds, 1);
        // A master-side enqueue bumps `queue_version` -> rebuild.
        cache.plan_for(&cluster, &queues, 1, 2);
        assert_eq!(cache.rebuilds, 2);
        // A residency-mutating epoch (activation) bumps `topo_version`.
        let spec = catalog_subset(30).into_iter().find(|s| s.tp == 1).unwrap();
        let v0 = cluster.topo_version;
        cluster.activate(&spec, vec![GpuId(0)], 0.0).unwrap();
        assert!(cluster.topo_version > v0);
        cache.plan_for(&cluster, &queues, 1, 2);
        assert_eq!(cache.rebuilds, 3);
        // ... and so does eviction.
        cluster.evict(spec.id);
        cache.plan_for(&cluster, &queues, 1, 2);
        assert_eq!(cache.rebuilds, 4);
    }

    #[test]
    fn nonresident_model_routes_to_shard_zero() {
        let cluster = Cluster::new(4, 80 * GB, 8, GpuPerf::default());
        let queues: Vec<Vec<Request>> = (0..4).map(|_| Vec::new()).collect();
        let plan = WindowPlan::build(&cluster, &queues, 4);
        assert_eq!(plan.shard_of_model(ModelId(7), &cluster.residency), 0);
    }

    /// Fast in-module smoke of the headline contract (`--shards 1` vs
    /// `--shards 4` identical metrics); the cross-policy / fault / fleet
    /// matrix lives in `tests/shard_identity.rs`.
    #[test]
    fn sharded_run_matches_sequential_smoke() {
        let trace = generate(&TraceGenConfig::novita_like(6, 240.0, 17));
        let cat = catalog_subset(30);
        let specs: Vec<ModelSpec> = (0..trace.n_models)
            .map(|i| {
                let mut s = cat[3 + i].clone();
                s.id = ModelId(i as u32);
                s
            })
            .collect();
        let run = |shards: u32| {
            let mut cfg = SimConfig::new("prism", 2).shards(shards);
            cfg.slo_scale = 10.0;
            let (m, tl) = Simulator::new(cfg, specs.clone()).run(&trace);
            (m, tl)
        };
        let (a, tla) = run(1);
        let (b, tlb) = run(4);
        assert_eq!(a.total(), b.total());
        assert_eq!(a.completed(), b.completed());
        assert_eq!(a.sim_events, b.sim_events);
        assert_eq!(a.ttft_attainment().to_bits(), b.ttft_attainment().to_bits());
        assert_eq!(a.tpot_attainment().to_bits(), b.tpot_attainment().to_bits());
        assert_eq!(a.busy_seconds.to_bits(), b.busy_seconds.to_bits());
        assert_eq!(a.wall_seconds.to_bits(), b.wall_seconds.to_bits());
        assert_eq!(
            (a.activations, a.evictions, a.migrations, a.preemptions),
            (b.activations, b.evictions, b.migrations, b.preemptions)
        );
        assert_eq!(tla.len(), tlb.len());
        for (sa, sb) in tla.iter().zip(&tlb) {
            assert_eq!(sa.t.to_bits(), sb.t.to_bits());
            assert_eq!(sa.cum_violations, sb.cum_violations);
            assert_eq!(sa.queue_lens, sb.queue_lens);
            assert_eq!(sa.inst_token_tput.to_bits(), sb.inst_token_tput.to_bits());
        }
    }
}
