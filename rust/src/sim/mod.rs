//! Discrete-event cluster simulator binding engines, kvcached, and the
//! control plane, with Prism and the four baselines as policy variants.

pub mod policy;
pub mod simulator;

pub use policy::PolicyKind;
pub use simulator::{SimConfig, Simulator};
