//! Discrete-event cluster simulator binding engines, kvcached, and the
//! control plane, with serving policies as pluggable [`SchedulingPolicy`]
//! implementations selected by name through the [`PolicyRegistry`].

pub mod policies;
pub mod shard;
pub mod simulator;

pub use policies::{by_name, registry, PolicyHandle, PolicyRegistry, SchedulingPolicy};
pub use simulator::{PolicyCtx, SimConfig, Simulator};
