//! Request model: arrivals, SLOs, lifecycle states, and latency records.
//!
//! Times are simulation seconds (f64). TTFT is measured from arrival to
//! first output token (queueing + any activation + prefill); TPOT is the
//! mean inter-token latency over the decode phase (paper SS2).

use crate::model::spec::ModelId;

#[derive(Debug, Clone, Copy, PartialEq, Eq, Hash, PartialOrd, Ord)]
pub struct RequestId(pub u64);

/// Sentinel for [`Request::kv_slot`]: the request holds no KV blocks.
pub const NO_KV_SLOT: u32 = u32::MAX;

#[derive(Debug, Clone, Copy, PartialEq)]
pub enum Phase {
    Queued,
    Prefill,
    Decode,
    Finished,
    Dropped,
}

#[derive(Debug, Clone)]
pub struct Request {
    pub id: RequestId,
    pub model: ModelId,
    pub arrival: f64,
    pub prompt_tokens: u32,
    pub output_tokens: u32,
    /// TTFT SLO in seconds; deadline = arrival + ttft_slo.
    pub ttft_slo: f64,
    /// TPOT SLO in seconds per output token.
    pub tpot_slo: f64,

    // ---- runtime state ----
    pub phase: Phase,
    pub prefill_done_tokens: u32,
    /// Tokens decoded so far. **Stale while the request is in an engine's
    /// decode batch**: `SimEngine` keeps the live count in its flat
    /// struct-of-arrays slot tables (indexed by [`kv_slot`](Self::kv_slot))
    /// and syncs this field back whenever the request leaves the batch
    /// (completion, preemption, drain).
    pub decoded_tokens: u32,
    pub first_token_time: Option<f64>,
    pub finish_time: Option<f64>,
    /// Accumulated decode-phase seconds. Stale while decoding in an engine,
    /// exactly like [`decoded_tokens`](Self::decoded_tokens): the live value
    /// is the engine's `slot_accum` entry, assigned back on batch exit.
    pub decode_time_accum: f64,
    /// Times this request was preempted (memory pressure).
    pub preemptions: u32,
    /// Dense slot in the serving engine's block table while the request
    /// holds KV blocks there ([`NO_KV_SLOT`] otherwise). Engine-local
    /// bookkeeping: assigned on first block allocation, reset whenever the
    /// engine releases the request's blocks.
    pub kv_slot: u32,
}

impl Request {
    pub fn new(
        id: u64,
        model: ModelId,
        arrival: f64,
        prompt_tokens: u32,
        output_tokens: u32,
        ttft_slo: f64,
        tpot_slo: f64,
    ) -> Self {
        Request {
            id: RequestId(id),
            model,
            arrival,
            prompt_tokens: prompt_tokens.max(1),
            output_tokens: output_tokens.max(1),
            ttft_slo,
            tpot_slo,
            phase: Phase::Queued,
            prefill_done_tokens: 0,
            decoded_tokens: 0,
            first_token_time: None,
            finish_time: None,
            decode_time_accum: 0.0,
            preemptions: 0,
            kv_slot: NO_KV_SLOT,
        }
    }

    pub fn ttft_deadline(&self) -> f64 {
        self.arrival + self.ttft_slo
    }

    /// Total tokens whose KV must be resident while decoding.
    pub fn total_tokens(&self) -> u32 {
        self.prompt_tokens + self.output_tokens
    }

    pub fn ttft(&self) -> Option<f64> {
        self.first_token_time.map(|t| t - self.arrival)
    }

    /// Mean time per output token over the decode phase.
    pub fn tpot(&self) -> Option<f64> {
        if self.decoded_tokens > 1 {
            Some(self.decode_time_accum / (self.decoded_tokens - 1) as f64)
        } else if self.phase == Phase::Finished {
            Some(0.0) // single-token outputs trivially meet TPOT
        } else {
            None
        }
    }

    pub fn ttft_ok(&self) -> bool {
        match self.ttft() {
            Some(t) => t <= self.ttft_slo + 1e-9,
            None => false,
        }
    }

    pub fn tpot_ok(&self) -> bool {
        match self.tpot() {
            Some(t) => t <= self.tpot_slo + 1e-9,
            None => false,
        }
    }
}

/// Finished-request record kept by the metrics collector.
#[derive(Debug, Clone)]
pub struct Completion {
    pub id: RequestId,
    pub model: ModelId,
    pub arrival: f64,
    pub finish: f64,
    pub prompt_tokens: u32,
    pub output_tokens: u32,
    pub ttft: f64,
    pub tpot: f64,
    pub ttft_slo: f64,
    pub tpot_slo: f64,
    pub dropped: bool,
    pub preemptions: u32,
}

impl Completion {
    pub fn from_request(r: &Request) -> Self {
        Completion {
            id: r.id,
            model: r.model,
            arrival: r.arrival,
            finish: r.finish_time.unwrap_or(f64::INFINITY),
            prompt_tokens: r.prompt_tokens,
            output_tokens: r.decoded_tokens,
            ttft: r.ttft().unwrap_or(f64::INFINITY),
            tpot: r.tpot().unwrap_or(f64::INFINITY),
            ttft_slo: r.ttft_slo,
            tpot_slo: r.tpot_slo,
            dropped: r.phase == Phase::Dropped,
            preemptions: r.preemptions,
        }
    }

    pub fn ttft_ok(&self) -> bool {
        !self.dropped && self.ttft <= self.ttft_slo + 1e-9
    }

    pub fn tpot_ok(&self) -> bool {
        !self.dropped && self.tpot <= self.tpot_slo + 1e-9
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn ttft_and_tpot_math() {
        let mut r = Request::new(1, ModelId(0), 10.0, 100, 5, 0.5, 0.05);
        assert_eq!(r.ttft(), None);
        r.first_token_time = Some(10.4);
        assert!((r.ttft().unwrap() - 0.4).abs() < 1e-12);
        assert!(r.ttft_ok());
        r.decoded_tokens = 5;
        r.decode_time_accum = 0.16; // 4 inter-token gaps
        assert!((r.tpot().unwrap() - 0.04).abs() < 1e-12);
        assert!(r.tpot_ok());
        r.decode_time_accum = 0.4;
        assert!(!r.tpot_ok());
    }

    #[test]
    fn completion_of_dropped_request_fails_slos() {
        let mut r = Request::new(2, ModelId(0), 0.0, 10, 10, 1.0, 0.1);
        r.phase = Phase::Dropped;
        let c = Completion::from_request(&r);
        assert!(c.dropped && !c.ttft_ok() && !c.tpot_ok());
    }

    #[test]
    fn zero_token_requests_clamped() {
        let r = Request::new(3, ModelId(0), 0.0, 0, 0, 1.0, 0.1);
        assert_eq!(r.prompt_tokens, 1);
        assert_eq!(r.output_tokens, 1);
    }
}
