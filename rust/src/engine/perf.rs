//! Analytic GPU performance model (H100-80G default).
//!
//! The paper's SLO dynamics are governed by queueing + memory contention, not
//! kernel micro-detail, so a roofline model suffices (DESIGN.md SS2):
//!   * prefill is compute-bound:   t = tokens * 2P / (eff_mxu * peak_flops)
//!   * decode is bandwidth-bound:  t = (weights + active KV) / (eff * hbm_bw)
//!     amortized over the batch, with a flops floor for large batches
//!   * a fixed per-iteration framework overhead (kernel launch, scheduler)
//!
//! Calibrated so an 8B model yields ~2-6k prefill tok/s and ~15-40 ms TPOT at
//! moderate batch - the regime the paper's SLO scales (0.04-0.13 s TTFT,
//! 5-51 ms TPOT measured on dedicated H100s) imply.

use crate::model::spec::ModelSpec;

#[derive(Debug, Clone)]
pub struct GpuPerf {
    /// Peak dense bf16 throughput per GPU, FLOP/s.
    pub peak_flops: f64,
    /// HBM bandwidth per GPU, bytes/s.
    pub hbm_bw: f64,
    /// Achievable fraction of peak for prefill GEMMs.
    pub eff_compute: f64,
    /// Achievable fraction of HBM bandwidth for decode.
    pub eff_mem: f64,
    /// Fixed per-iteration overhead, seconds (launch + python/driver).
    pub iter_overhead: f64,
    /// Host->GPU copy bandwidth for one pageable stream, bytes/s.
    pub pcie_stream_bw: f64,
    /// Aggregate NVLink bandwidth, bytes/s.
    pub nvlink_bw: f64,
}

impl Default for GpuPerf {
    fn default() -> Self {
        GpuPerf {
            peak_flops: 990e12, // H100 SXM bf16 dense
            hbm_bw: 3.35e12,
            eff_compute: 0.45,
            eff_mem: 0.65,
            iter_overhead: 4e-3,
            pcie_stream_bw: 25e9, // pageable cudaMemcpyAsync, single target GPU
            nvlink_bw: 600e9,
        }
    }
}

impl GpuPerf {
    /// H100-80G profile. Bit-identical to `GpuPerf::default()` — the
    /// `GpuKind::H100` fleet path must reproduce the historical uniform
    /// cluster bitwise, so this constructor IS the default, spelled out.
    pub fn h100() -> Self {
        GpuPerf::default()
    }

    /// A100-40G variant (used by the Fig 14 overhead experiment and the
    /// `GpuKind::A100` fleet profile).
    pub fn a100_40g() -> Self {
        GpuPerf {
            peak_flops: 312e12,
            hbm_bw: 1.55e12,
            ..Default::default()
        }
    }

    /// A10G-24G profile (`GpuKind::A10G`): mid-tier inference card. No
    /// NVLink — peer transfers fall back to PCIe-class bandwidth.
    pub fn a10g() -> Self {
        GpuPerf {
            peak_flops: 125e12, // dense bf16
            hbm_bw: 600e9,      // GDDR6
            pcie_stream_bw: 12e9,
            nvlink_bw: 12e9,
            ..Default::default()
        }
    }

    /// L4-24G profile (`GpuKind::L4`): cheap long-tail card. No NVLink.
    pub fn l4() -> Self {
        GpuPerf {
            peak_flops: 60e12, // dense bf16
            hbm_bw: 300e9,     // GDDR6
            pcie_stream_bw: 12e9,
            nvlink_bw: 12e9,
            ..Default::default()
        }
    }

    /// Chunked-prefill speed in tokens/s for `m` (the paper's c_i).
    /// TP splits the GEMMs across the group.
    pub fn prefill_tokens_per_sec(&self, m: &ModelSpec) -> f64 {
        let flops_per_token = 2.0 * m.params as f64;
        self.eff_compute * self.peak_flops * m.tp as f64 / flops_per_token
    }

    /// Time for one engine iteration that prefills `chunk_tokens` and decodes
    /// one token for each of `decode_batch` requests holding `kv_bytes` of
    /// active KV on this GPU.
    pub fn iteration_seconds(
        &self,
        m: &ModelSpec,
        chunk_tokens: u32,
        decode_batch: u32,
        kv_bytes: u64,
    ) -> f64 {
        let mut t = self.iter_overhead;
        if chunk_tokens > 0 {
            t += chunk_tokens as f64 / self.prefill_tokens_per_sec(m);
        }
        if decode_batch > 0 {
            // One pass over resident weights + active KV, amortized over batch.
            let bytes = m.weight_bytes_per_gpu() as f64 + kv_bytes as f64;
            let t_mem = bytes / (self.eff_mem * self.hbm_bw);
            // Flops floor: batch x 2P / peak (per GPU of the TP group).
            let t_flops = decode_batch as f64 * 2.0 * m.params as f64
                / (self.eff_compute * self.peak_flops * m.tp as f64);
            t += t_mem.max(t_flops);
        }
        t
    }

    /// Pure decode TPOT for a batch (convenience for SLO baseline setting).
    pub fn decode_tpot(&self, m: &ModelSpec, batch: u32, kv_bytes: u64) -> f64 {
        self.iteration_seconds(m, 0, batch, kv_bytes)
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::model::spec::{table3_catalog, SizeClass};

    fn model_8b() -> ModelSpec {
        table3_catalog()
            .into_iter()
            .find(|m| m.name == "llama-3.1-8b-ft00")
            .unwrap()
    }

    #[test]
    fn prefill_speed_realistic_for_8b() {
        let p = GpuPerf::default();
        let c = p.prefill_tokens_per_sec(&model_8b());
        // H100 8B prefill: ~20-30k tokens/s region.
        assert!(c > 10_000.0 && c < 60_000.0, "c={c}");
    }

    #[test]
    fn decode_tpot_realistic_for_8b() {
        let p = GpuPerf::default();
        let m = model_8b();
        let t1 = p.decode_tpot(&m, 1, 0);
        // Dedicated GPU, tiny batch: ~10-15ms (weights pass + overhead).
        assert!(t1 > 0.005 && t1 < 0.03, "t1={t1}");
        // Bigger batch with KV grows latency but sublinearly.
        let t32 = p.decode_tpot(&m, 32, 8 << 30);
        assert!(t32 > t1 && t32 < 10.0 * t1, "t32={t32}");
    }

    #[test]
    fn tp_speeds_up_prefill_and_decode() {
        let p = GpuPerf::default();
        let cat = table3_catalog();
        let b70 = cat.iter().find(|m| m.name == "llama-3.3-70b").unwrap();
        let mut solo = b70.clone();
        solo.tp = 1;
        assert!(p.prefill_tokens_per_sec(b70) > 4.0 * p.prefill_tokens_per_sec(&solo));
        assert!(p.decode_tpot(b70, 1, 0) < p.decode_tpot(&solo, 1, 0));
    }

    #[test]
    fn iteration_combines_prefill_and_decode() {
        let p = GpuPerf::default();
        let m = model_8b();
        let pre = p.iteration_seconds(&m, 512, 0, 0);
        let dec = p.iteration_seconds(&m, 0, 4, 1 << 30);
        let both = p.iteration_seconds(&m, 512, 4, 1 << 30);
        assert!(both > pre.max(dec));
        assert!(both < pre + dec); // overhead charged once
    }

    #[test]
    fn kind_profiles_are_ordered_and_h100_is_default() {
        let m = model_8b();
        let h100 = GpuPerf::h100();
        let d = GpuPerf::default();
        // The fleet path's bitwise-identity contract: h100 == default, exactly.
        assert_eq!(h100.peak_flops.to_bits(), d.peak_flops.to_bits());
        assert_eq!(h100.hbm_bw.to_bits(), d.hbm_bw.to_bits());
        let tiers = [GpuPerf::l4(), GpuPerf::a10g(), GpuPerf::a100_40g(), h100];
        for w in tiers.windows(2) {
            assert!(
                w[0].prefill_tokens_per_sec(&m) < w[1].prefill_tokens_per_sec(&m),
                "prefill speed must rise with the tier"
            );
            assert!(
                w[0].decode_tpot(&m, 8, 1 << 30) > w[1].decode_tpot(&m, 8, 1 << 30),
                "decode latency must fall with the tier"
            );
        }
    }

    #[test]
    fn small_models_much_faster() {
        let p = GpuPerf::default();
        let cat = table3_catalog();
        let b1 = cat.iter().find(|m| m.class == SizeClass::B1to3).unwrap();
        let b8 = model_8b();
        assert!(p.decode_tpot(b1, 1, 0) < p.decode_tpot(&b8, 1, 0) / 2.0);
    }
}
