//! Serving engines: the iteration-level execution model (chunked prefill +
//! continuous batching), the analytic GPU perf model, and the model
//! activation latency model (engine pools + parallel weight loading).

pub mod engine;
pub mod loading;
pub mod perf;

pub use engine::{KvAlloc, SimEngine, StepOutcome, BLOCK_TOKENS, CHUNK_TOKENS};
pub use perf::GpuPerf;
