//! Simulated serving engine: chunked prefill + continuous batching over
//! kvcached-managed KV blocks (SGLang/vLLM-style iteration loop).
//!
//! One `SimEngine` serves one model instance on one GPU group. Each call to
//! `step` executes one engine iteration: a chunk of prefill for the head of
//! the admitted queue plus one decode token per running request, allocating
//! KV blocks on demand through the caller-supplied group allocator. When
//! allocation fails (pool exhausted or balloon limit), the engine preempts
//! the longest-running decode request (recompute-style, matching SGLang's
//! policy the paper builds on) and retries once.
//!
//! # Per-token complexity budget
//!
//! `step` runs once per engine iteration and its decode phase touches every
//! running request, so per-token work is O(1) amortized and heap-free:
//!
//! * the decode loop iterates `running` **by index** — preemption only ever
//!   pops the youngest (last) entry, so indices below the cursor stay
//!   stable and no `ids` snapshot or O(batch) `position()` rescan exists
//!   (the old formulation was O(batch²) per iteration);
//! * per-request KV blocks live in an arena (`BlockTable`) keyed by the
//!   request's dense `kv_slot` — block runs are flat block-major
//!   `Vec<BlockRef>`s whose capacity is recycled across requests, so
//!   steady-state decode performs no hashing and no allocation;
//! * an iteration's block demand goes through ONE batched
//!   [`KvAlloc::alloc_n`] call per request, not a `Vec`-returning call per
//!   block;
//! * the prefill queue is a `VecDeque`, so a preemption's re-queue at the
//!   front is O(1) instead of shifting the whole queue;
//! * hot per-request decode state lives in struct-of-arrays form indexed
//!   by the request's dense `kv_slot` (`slot_tokens`/`slot_goal`/
//!   `slot_accum`, with `running_slots` parallel to `running`): the decode
//!   scan, the finish test, the KV-token sum, and latency accrual all walk
//!   flat arrays instead of chasing 100+-byte `Request` structs — the
//!   layout the sharded event loop's per-shard decode scans are sized for.
//!   A running request's `decoded_tokens`/`decode_time_accum` fields are
//!   stale while it runs; they are synced **by assignment** (not
//!   re-derivation) when the request leaves `running` (completion,
//!   preemption, drain), so the f64 accrual stream is bit-identical to the
//!   historical per-request layout.
//!
//! Work proportional to the batch is allowed only per *iteration* (timing,
//! latency accrual) or per *completion* (order-preserving removal), never
//! per token. Regressions show up in `benches/sim_hot_path.rs` (KV-churn
//! scenario) and `benches/micro.rs`.

use std::collections::VecDeque;

use crate::engine::perf::GpuPerf;
use crate::kvcached::{BlockRef, KvError};
use crate::model::spec::ModelSpec;
use crate::request::{Completion, Phase, Request, RequestId, NO_KV_SLOT};

/// Tokens per KV block (SGLang default page size is 16-64 tokens).
pub const BLOCK_TOKENS: u32 = 16;
/// Prefill chunk per iteration (chunked prefill, paper SS6.2).
pub const CHUNK_TOKENS: u32 = 512;
/// Maximum concurrent decode batch per engine.
pub const MAX_BATCH: u32 = 64;

/// Group-wide KV allocation interface provided by the cluster. A "group
/// block" is one KV block replicated across every GPU of the engine's TP
/// group: `width()` refs, laid out contiguously in block-major order.
pub trait KvAlloc {
    /// Refs per group block (= the TP degree of the engine's group).
    fn width(&self) -> usize;

    /// Allocate `n` group blocks, appending `n * width()` refs to `out`
    /// (block `b`'s refs occupy `out[start + b*width .. start + (b+1)*width]`).
    /// Every appended block is group-complete: allocated on ALL GPUs of the
    /// group or not appended at all. On `Err`, complete blocks allocated
    /// before the failure remain in `out` — callers keep partial progress
    /// across preemption retries, exactly as repeated single-block calls
    /// would.
    fn alloc_n(&mut self, n: u32, out: &mut Vec<BlockRef>) -> Result<(), KvError>;

    /// Free a block-major run previously produced by `alloc_n`.
    fn free_run(&mut self, refs: &[BlockRef]);
}

/// Arena of per-request block runs. Each request holding KV owns one dense
/// slot (`Request::kv_slot`); the slot's run is that request's flat
/// block-major `BlockRef` sequence. Released slots keep their `Vec`
/// capacity and are recycled, so steady-state decode appends into
/// already-grown buffers without touching the allocator.
#[derive(Debug, Default)]
struct BlockTable {
    runs: Vec<Vec<BlockRef>>,
    free: Vec<u32>,
}

impl BlockTable {
    fn acquire(&mut self) -> u32 {
        match self.free.pop() {
            Some(s) => s,
            None => {
                self.runs.push(Vec::new());
                (self.runs.len() - 1) as u32
            }
        }
    }

    fn release(&mut self, slot: u32) {
        self.runs[slot as usize].clear(); // keep capacity for the next tenant
        self.free.push(slot);
    }

    fn total_refs(&self) -> usize {
        self.runs.iter().map(|r| r.len()).sum()
    }
}

/// Grow `r`'s block run to cover `tokens_needed` tokens. Free function so
/// call sites can borrow the table and a request from disjoint engine
/// fields simultaneously.
fn ensure_blocks(
    table: &mut BlockTable,
    kv: &mut dyn KvAlloc,
    r: &mut Request,
    tokens_needed: u32,
) -> Result<(), KvError> {
    let width = kv.width().max(1);
    let need = tokens_needed.div_ceil(BLOCK_TOKENS) as usize;
    let slot = if r.kv_slot == NO_KV_SLOT {
        let s = table.acquire();
        r.kv_slot = s;
        s
    } else {
        r.kv_slot
    };
    let have = table.runs[slot as usize].len() / width;
    let res = if need > have {
        kv.alloc_n((need - have) as u32, &mut table.runs[slot as usize])
    } else {
        Ok(())
    };
    if table.runs[slot as usize].is_empty() {
        // Nothing allocated (first block failed): don't hold an empty slot,
        // so `kv_slot != NO_KV_SLOT` always means "holds at least one block".
        table.release(slot);
        r.kv_slot = NO_KV_SLOT;
    }
    res
}

/// Return all of `r`'s blocks to the allocator and recycle its arena slot.
fn release_blocks(table: &mut BlockTable, kv: &mut dyn KvAlloc, r: &mut Request) {
    if r.kv_slot != NO_KV_SLOT {
        let slot = r.kv_slot;
        r.kv_slot = NO_KV_SLOT;
        kv.free_run(&table.runs[slot as usize]);
        table.release(slot);
    }
}

#[derive(Debug, Clone, Default)]
pub struct StepOutcome {
    /// Wall-clock duration of this iteration (0 if the engine was idle).
    pub duration: f64,
    pub completions: Vec<Completion>,
    pub preempted: u32,
    /// True if any request made progress (engine should be rescheduled).
    pub active: bool,
}

#[derive(Debug)]
pub struct SimEngine {
    pub spec: ModelSpec,
    /// Admitted requests awaiting (or mid-) prefill, in admission order.
    /// Deque: preemption re-queues at the front in O(1).
    queue: VecDeque<Request>,
    /// Requests in decode.
    running: Vec<Request>,
    /// `kv_slot` of each running request, parallel to `running` (every
    /// running request holds KV: promotion requires a completed — hence
    /// block-backed — prefill, and preemption/drain remove from `running`).
    running_slots: Vec<u32>,
    /// Struct-of-arrays decode state, indexed by `kv_slot`: resident
    /// tokens (prompt + decoded), finish goal (prompt + output), and the
    /// decode-latency accumulator. Seeded at promotion, authoritative
    /// while the request runs, synced back by assignment at exit.
    slot_tokens: Vec<u32>,
    slot_goal: Vec<u32>,
    slot_accum: Vec<f64>,
    /// Per-request KV block runs, keyed by each request's dense `kv_slot`.
    table: BlockTable,
    pub chunk_tokens: u32,
    pub max_batch: u32,
    /// Total iterations and busy seconds (throughput accounting excl. idle).
    pub iterations: u64,
    pub busy_seconds: f64,
    pub preemptions: u64,
    /// Iteration-duration multiplier for degraded GPUs (fault injection's
    /// slowdown windows). 1.0 — the default — is exact IEEE identity
    /// (`x * 1.0 == x` bitwise for finite x), so fault-free runs are
    /// unchanged bit for bit.
    pub time_scale: f64,
}

impl SimEngine {
    pub fn new(spec: ModelSpec) -> Self {
        SimEngine {
            spec,
            queue: VecDeque::new(),
            running: Vec::new(),
            running_slots: Vec::new(),
            slot_tokens: Vec::new(),
            slot_goal: Vec::new(),
            slot_accum: Vec::new(),
            table: BlockTable::default(),
            chunk_tokens: CHUNK_TOKENS,
            max_batch: MAX_BATCH,
            iterations: 0,
            busy_seconds: 0.0,
            preemptions: 0,
            time_scale: 1.0,
        }
    }

    /// Admit a request (arbitration has already decided it should run here).
    pub fn admit(&mut self, mut r: Request) {
        debug_assert_eq!(r.kv_slot, NO_KV_SLOT, "admitted request holds foreign KV");
        r.phase = Phase::Prefill;
        self.queue.push_back(r);
    }

    pub fn has_work(&self) -> bool {
        !self.queue.is_empty() || !self.running.is_empty()
    }

    pub fn queue_len(&self) -> usize {
        self.queue.len()
    }

    pub fn running_len(&self) -> usize {
        self.running.len()
    }

    /// Tokens of KV currently resident (for KVPR / memory plots). The
    /// running half reads the slot table (`slot_tokens[s]` == prompt +
    /// decoded), which is current mid-iteration too — the per-request
    /// fields are stale while a request runs.
    pub fn active_kv_tokens(&self) -> u64 {
        let q: u64 = self.queue.iter().map(|r| r.prefill_done_tokens as u64).sum();
        let d: u64 =
            self.running_slots.iter().map(|&s| self.slot_tokens[s as usize] as u64).sum();
        q + d
    }

    pub fn active_kv_bytes(&self) -> u64 {
        self.active_kv_tokens() * self.spec.kv_bytes_per_token() * self.spec.tp as u64
    }

    /// Blocks held across all requests (used by drains/migration).
    pub fn held_blocks(&self) -> usize {
        self.table.total_refs() / (self.spec.tp as usize).max(1)
    }

    /// Preempt a decode request *promoted after* the requester at
    /// `requester_idx` (LIFO, recompute-style - the vLLM/SGLang discipline).
    /// The age ordering is what makes this livelock-free: a request may only
    /// evict strictly younger ones, so the oldest running request always
    /// progresses, finishes, and releases memory. (Both "preempt the
    /// longest-decoded" and plain "preempt anyone but me" livelock: the
    /// victim re-prefills, gets promoted, and immediately preempts its
    /// preemptor.)
    fn preempt_younger(&mut self, kv: &mut dyn KvAlloc, requester_idx: usize) -> bool {
        if requester_idx + 1 >= self.running.len() {
            return false; // requester is the youngest: it must wait instead
        }
        // INVARIANT: the bound above guarantees a victim behind the
        // requester, and running_slots is maintained parallel to running.
        let mut r = self.running.pop().expect("younger victim exists");
        let s = self.running_slots.pop().expect("slot parallel to running");
        self.sync_from_slot(&mut r, s);
        release_blocks(&mut self.table, kv, &mut r);
        r.preemptions += 1;
        r.preemptions_apply();
        self.queue.push_front(r);
        self.preemptions += 1;
        true
    }

    /// Steal partial-prefill KV from the back of the queue (only safe when
    /// nothing is running; used so the queue head can make progress).
    fn steal_from_queue_tail(&mut self, kv: &mut dyn KvAlloc, protect: RequestId) -> bool {
        let qv = self
            .queue
            .iter()
            .enumerate()
            .rev()
            .find(|(_, r)| r.id != protect && r.kv_slot != NO_KV_SLOT)
            .map(|(i, _)| i);
        if let Some(i) = qv {
            // INVARIANT: `i` came from enumerate() over this same queue, with
            // no mutation in between.
            let mut r = self.queue.remove(i).expect("victim index in range");
            release_blocks(&mut self.table, kv, &mut r);
            r.preemptions += 1;
            r.preemptions_apply();
            self.queue.push_back(r);
            self.preemptions += 1;
            return true;
        }
        false
    }

    /// Drain everything (engine eviction): frees all KV; returns the requests
    /// (callers re-queue them elsewhere). Completed stats are preserved.
    pub fn drain(&mut self, kv: &mut dyn KvAlloc) -> Vec<Request> {
        let mut out: Vec<Request> = Vec::new();
        for mut r in std::mem::take(&mut self.queue) {
            release_blocks(&mut self.table, kv, &mut r);
            r.phase = Phase::Queued;
            r.prefill_done_tokens = 0;
            out.push(r);
        }
        let slots = std::mem::take(&mut self.running_slots);
        for (mut r, s) in std::mem::take(&mut self.running).into_iter().zip(slots) {
            self.sync_from_slot(&mut r, s);
            release_blocks(&mut self.table, kv, &mut r);
            r.phase = Phase::Queued;
            r.preemptions += 1;
            r.preemptions_apply();
            out.push(r);
        }
        out
    }

    /// Sync a request leaving `running`: copy its slot's decode state back
    /// **by assignment** (bit-exact — never re-derived arithmetic; see the
    /// module docs). Must run before `release_blocks` clears `kv_slot`.
    fn sync_from_slot(&self, r: &mut Request, slot: u32) {
        let s = slot as usize;
        r.decoded_tokens = self.slot_tokens[s] - r.prompt_tokens;
        r.decode_time_accum = self.slot_accum[s];
    }

    /// Grow the slot-indexed arrays to cover `slot` (recycled slots reuse
    /// their entries; seeding at promotion overwrites stale state).
    fn ensure_slot(&mut self, slot: u32) {
        let need = slot as usize + 1;
        if self.slot_tokens.len() < need {
            self.slot_tokens.resize(need, 0);
            self.slot_goal.resize(need, 0);
            self.slot_accum.resize(need, 0.0);
        }
    }

    /// Execute one iteration at simulation time `now`.
    pub fn step(&mut self, now: f64, perf: &GpuPerf, kv: &mut dyn KvAlloc) -> StepOutcome {
        if !self.has_work() {
            return StepOutcome::default();
        }
        let mut out = StepOutcome { active: true, ..Default::default() };

        // ---- Phase 1: one decode token per running request --------------
        // Decode runs BEFORE prefill: running requests must get their KV
        // first, or prefill of waiting requests consumes every block that a
        // preemption frees and decode livelocks (vLLM/SGLang likewise give
        // the running batch priority over admission).
        //
        // Index-based iteration, robust to mid-scan preemption: victims are
        // only ever popped off the END of `running` (strictly younger than
        // the scan cursor), so every index at or below the cursor — and
        // every recorded `finished` index — stays valid for the whole scan.
        let mut finished: Vec<usize> = Vec::new();
        // Set when decode hit memory pressure this iteration: prefill
        // admission is then suppressed so it cannot re-consume the blocks
        // that preemption just freed (that re-consumption livelocks).
        let mut pressure = false;
        let mut i = 0usize;
        debug_assert_eq!(self.running.len(), self.running_slots.len());
        while i < self.running.len() {
            // Hot scan over the flat slot arrays (`slot_tokens[s]` ==
            // prompt + decoded), not the Request structs.
            let s = self.running_slots[i] as usize;
            let tokens_after = self.slot_tokens[s] + 1;
            let mut attempts = 0;
            loop {
                match ensure_blocks(&mut self.table, kv, &mut self.running[i], tokens_after) {
                    Ok(()) => {
                        self.slot_tokens[s] += 1;
                        if self.slot_tokens[s] >= self.slot_goal[s] {
                            finished.push(i);
                        }
                        break;
                    }
                    Err(KvError::OutOfPages(_))
                    | Err(KvError::LimitReached { .. })
                    | Err(KvError::FaultInjected { .. }) => {
                        // Injected transient faults route through the same
                        // pressure path as real exhaustion: the stall /
                        // preempt-and-retry discipline IS the recovery.
                        pressure = true;
                        // Victim order: a younger runner, else a queued
                        // partial prefill (not yet served, so younger in
                        // service order by definition). Retry after a
                        // successful preemption.
                        let protect = self.running[i].id;
                        if attempts < 4
                            && (self.preempt_younger(kv, i)
                                || self.steal_from_queue_tail(kv, protect))
                        {
                            out.preempted += 1;
                            attempts += 1;
                            continue;
                        }
                        // This (youngest) request stalls one iteration;
                        // older requests keep decoding and release memory.
                        break;
                    }
                    // Invariant (documented panic): UnknownModel/LoadFailed
                    // cannot reach a stepping engine — the cluster registers
                    // KV before constructing the engine and load failures
                    // abort activation before any engine exists.
                    Err(e) => panic!("unexpected kv error: {e}"),
                }
            }
            i += 1;
        }

        // ---- Phase 2: chunked prefill for the queue head(s) -------------
        // Suppressed entirely under decode memory pressure (see above).
        let mut chunk_left = if pressure { 0 } else { self.chunk_tokens };
        let mut prefill_tokens_done = 0u32;
        let mut qi = 0usize;
        while chunk_left > 0
            && qi < self.queue.len()
            && (self.running.len() as u32) < self.max_batch
        {
            let id = self.queue[qi].id;
            let total_prefill = self.queue[qi].prompt_tokens + self.queue[qi].decoded_tokens;
            let done = self.queue[qi].prefill_done_tokens;
            let take = chunk_left.min(total_prefill - done);
            // KV for the newly prefetched tokens.
            match ensure_blocks(&mut self.table, kv, &mut self.queue[qi], done + take) {
                Ok(()) => {}
                Err(KvError::OutOfPages(_))
                | Err(KvError::LimitReached { .. })
                | Err(KvError::FaultInjected { .. }) => {
                    // Memory pressure (real or injected-transient). Prefill
                    // never preempts active decodes (decode progress
                    // guarantees memory is eventually freed; preempting it
                    // would allow prefill/decode livelock). With nothing
                    // running, steal partial-prefill KV from the queue tail
                    // so the head can make progress.
                    if self.running.is_empty() && self.steal_from_queue_tail(kv, id) {
                        out.preempted += 1;
                        continue;
                    }
                    break;
                }
                // Invariant (documented panic): see the decode-loop arm.
                Err(e) => panic!("unexpected kv error: {e}"),
            }
            let r = &mut self.queue[qi];
            r.prefill_done_tokens += take;
            chunk_left -= take;
            prefill_tokens_done += take;
            if r.prefill_done_tokens >= total_prefill {
                qi += 1; // completed prefill; promoted below
            }
        }

        // ---- Iteration timing -------------------------------------------
        let decode_batch = self.running.len() as u32;
        let base_duration = perf.iteration_seconds(
            &self.spec,
            prefill_tokens_done,
            decode_batch,
            self.active_kv_bytes() / self.spec.tp as u64,
        );
        // Degraded-GPU slowdown; 1.0 (the default) is bitwise identity.
        let duration = base_duration * self.time_scale;
        let end = now + duration;
        self.iterations += 1;
        self.busy_seconds += duration;
        out.duration = duration;

        // Decode latency accounting: every running request accrues the
        // iteration duration. (Every running request has decoded at least
        // one token — promotion guarantees `decoded_tokens >= 1` — so the
        // historical `decoded_tokens > 0` guard was always true here; the
        // accrual stream over the slot array is the same f64 sequence.)
        for &s in &self.running_slots {
            self.slot_accum[s as usize] += duration;
        }

        // Completions: `finished` holds increasing, still-valid indices
        // (victim pops only ever removed entries above the scan cursor).
        // Order-preserving removal keeps the age ordering the preemption
        // discipline relies on; O(batch) per completion, not per token.
        let mut removed = 0usize;
        for &fi in &finished {
            let mut r = self.running.remove(fi - removed);
            let s = self.running_slots.remove(fi - removed);
            removed += 1;
            self.sync_from_slot(&mut r, s);
            r.phase = Phase::Finished;
            r.finish_time = Some(end);
            if r.first_token_time.is_none() {
                r.first_token_time = Some(end);
            }
            release_blocks(&mut self.table, kv, &mut r);
            out.completions.push(Completion::from_request(&r));
        }

        // Promote queue heads whose prefill completed: first token emitted at
        // the end of this iteration.
        let mut i = 0;
        while i < self.queue.len() {
            let total_prefill = self.queue[i].prompt_tokens + self.queue[i].decoded_tokens;
            if self.queue[i].prefill_done_tokens >= total_prefill
                && (self.running.len() as u32) < self.max_batch
            {
                // INVARIANT: the while condition bounds `i < queue.len()`.
                let mut r = self.queue.remove(i).expect("promotion index in range");
                if r.first_token_time.is_none() {
                    r.first_token_time = Some(end);
                }
                // The first generated token is produced by the prefill pass.
                if r.decoded_tokens == 0 {
                    r.decoded_tokens = 1;
                }
                if r.decoded_tokens >= r.output_tokens {
                    r.phase = Phase::Finished;
                    r.finish_time = Some(end);
                    release_blocks(&mut self.table, kv, &mut r);
                    out.completions.push(Completion::from_request(&r));
                } else {
                    r.phase = Phase::Decode;
                    // Seed the slot arrays: the request's fields are
                    // authoritative up to this point, the slot entries from
                    // here until it leaves `running`.
                    let slot = r.kv_slot;
                    debug_assert_ne!(slot, NO_KV_SLOT, "promoted request holds KV");
                    self.ensure_slot(slot);
                    let s = slot as usize;
                    self.slot_tokens[s] = r.prompt_tokens + r.decoded_tokens;
                    self.slot_goal[s] = r.prompt_tokens + r.output_tokens;
                    self.slot_accum[s] = r.decode_time_accum;
                    self.running_slots.push(slot);
                    self.running.push(r);
                }
            } else {
                i += 1;
            }
        }

        out
    }
}

impl Request {
    /// After a recompute-style preemption, generated tokens must be
    /// re-prefetched: reset prefill progress (prompt + decoded become the new
    /// prefill span) but keep decode stats.
    pub fn preemptions_apply(&mut self) {
        self.phase = Phase::Prefill;
        self.prefill_done_tokens = 0;
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::kvcached::Kvcached;
    use crate::model::spec::{ModelId, ModelSpec, SizeClass};

    fn nano_spec() -> ModelSpec {
        ModelSpec {
            id: ModelId(0),
            name: "test-1b".into(),
            class: SizeClass::B1to3,
            params: 1_000_000_000,
            n_layers: 16,
            n_heads: 32,
            n_kv_heads: 8,
            d_head: 64,
            dtype_bytes: 2,
            tp: 1,
        }
    }

    /// Single-GPU allocator over one Kvcached.
    struct OneGpu<'a> {
        kvc: &'a mut Kvcached,
        model: ModelId,
    }

    impl<'a> KvAlloc for OneGpu<'a> {
        fn width(&self) -> usize {
            1
        }
        fn alloc_n(&mut self, n: u32, out: &mut Vec<BlockRef>) -> Result<(), KvError> {
            self.kvc.alloc_blocks(self.model, n, out)
        }
        fn free_run(&mut self, refs: &[BlockRef]) {
            for &r in refs {
                self.kvc.free_block(r).unwrap();
            }
        }
    }

    fn setup(capacity_mb: u64) -> (SimEngine, Kvcached) {
        let spec = nano_spec();
        let mut kvc = Kvcached::new(capacity_mb * 1024 * 1024, 2 * 1024 * 1024, 0);
        let block_bytes = spec.kv_bytes_per_token() * BLOCK_TOKENS as u64;
        kvc.register_kv(spec.id, block_bytes, u32::MAX);
        (SimEngine::new(spec), kvc)
    }

    fn req(id: u64, prompt: u32, out: u32) -> Request {
        Request::new(id, ModelId(0), 0.0, prompt, out, 5.0, 0.5)
    }

    #[test]
    fn request_completes_with_correct_latencies() {
        let (mut e, mut kvc) = setup(1024);
        e.admit(req(1, 100, 5));
        let perf = GpuPerf::default();
        let mut now = 0.0;
        let mut comps = Vec::new();
        for _ in 0..50 {
            let mut kv = OneGpu { kvc: &mut kvc, model: ModelId(0) };
            let o = e.step(now, &perf, &mut kv);
            now += o.duration;
            comps.extend(o.completions);
            if !e.has_work() {
                break;
            }
        }
        assert_eq!(comps.len(), 1);
        let c = &comps[0];
        assert!(c.ttft > 0.0 && c.ttft.is_finite());
        assert!(c.tpot > 0.0 && c.tpot.is_finite());
        assert_eq!(c.output_tokens, 5);
        // All KV released.
        assert_eq!(kvc.kv_used_blocks(ModelId(0)), 0);
        assert_eq!(e.held_blocks(), 0);
    }

    #[test]
    fn chunked_prefill_spreads_over_iterations() {
        let (mut e, mut kvc) = setup(1024);
        e.admit(req(1, CHUNK_TOKENS * 3, 2));
        let perf = GpuPerf::default();
        let mut iters = 0;
        let mut now = 0.0;
        while e.has_work() && iters < 20 {
            let mut kv = OneGpu { kvc: &mut kvc, model: ModelId(0) };
            let o = e.step(now, &perf, &mut kv);
            now += o.duration;
            iters += 1;
        }
        assert!(iters >= 4, "prefill must take >=3 chunks + decode, got {iters}");
    }

    #[test]
    fn batch_of_requests_all_complete() {
        let (mut e, mut kvc) = setup(2048);
        for i in 0..10 {
            e.admit(req(i, 64, 8));
        }
        let perf = GpuPerf::default();
        let mut now = 0.0;
        let mut done = 0;
        for _ in 0..500 {
            let mut kv = OneGpu { kvc: &mut kvc, model: ModelId(0) };
            let o = e.step(now, &perf, &mut kv);
            now += o.duration;
            done += o.completions.len();
            if !e.has_work() {
                break;
            }
        }
        assert_eq!(done, 10);
        assert_eq!(kvc.kv_used_blocks(ModelId(0)), 0);
    }

    #[test]
    fn memory_pressure_triggers_preemption_not_deadlock() {
        // 24 MiB = 12 pages = 48 blocks = 768 tokens of KV capacity; demand is
        // 4 requests x 320 tokens = 1280 tokens, so pressure is guaranteed.
        let (mut e, mut kvc) = setup(24);
        for i in 0..4 {
            e.admit(req(i, 256, 64));
        }
        let perf = GpuPerf::default();
        let mut now = 0.0;
        let mut done = 0;
        let mut preempted = 0;
        for _ in 0..30_000 {
            let mut kv = OneGpu { kvc: &mut kvc, model: ModelId(0) };
            let o = e.step(now, &perf, &mut kv);
            now += o.duration;
            done += o.completions.len();
            preempted += o.preempted;
            if !e.has_work() {
                break;
            }
        }
        assert_eq!(done, 4, "all requests must eventually finish");
        assert!(preempted > 0, "workload must have triggered preemption");
        assert_eq!(kvc.kv_used_blocks(ModelId(0)), 0);
    }

    /// Regression (satellite of the fault-injection PR): the
    /// `Kvcached::alloc_blocks` partial-progress-on-failure contract,
    /// exercised end-to-end through the engine's decode loop rather than
    /// against the manager alone. Every failed batched allocation leaves
    /// its partial progress in the request's block run; the decode loop's
    /// retry must build on it without leaking or double-counting blocks.
    #[test]
    fn decode_loop_keeps_partial_progress_across_failed_batch_allocs() {
        // Same pressure-cooker shape as the preemption test, with the
        // transient injector armed on top so batched allocs ALSO fail
        // mid-batch (not only at pool/limit boundaries).
        let (mut e, mut kvc) = setup(24);
        kvc.arm_alloc_faults(5);
        for i in 0..4 {
            e.admit(req(i, 256, 64));
        }
        let perf = GpuPerf::default();
        let mut now = 0.0;
        let mut done = 0;
        for _ in 0..30_000 {
            let mut kv = OneGpu { kvc: &mut kvc, model: ModelId(0) };
            let o = e.step(now, &perf, &mut kv);
            now += o.duration;
            done += o.completions.len();
            // Conservation after every iteration: the engine's view of held
            // blocks must equal the manager's, even right after a batched
            // alloc failed with partial progress.
            assert_eq!(
                e.held_blocks() as u64,
                kvc.kv_used_blocks(ModelId(0)),
                "engine/manager block accounting drifted"
            );
            assert!(kvc.check_conservation());
            if !e.has_work() {
                break;
            }
        }
        assert_eq!(done, 4, "all requests finish despite injected faults");
        assert!(kvc.alloc_faults_injected() > 0, "injector never fired");
        assert_eq!(kvc.kv_used_blocks(ModelId(0)), 0);
        assert_eq!(e.held_blocks(), 0);
    }

    #[test]
    fn time_scale_stretches_iteration_duration() {
        let run = |scale: f64| {
            let (mut e, mut kvc) = setup(1024);
            e.time_scale = scale;
            e.admit(req(1, 100, 5));
            let perf = GpuPerf::default();
            let mut now = 0.0;
            for _ in 0..50 {
                let mut kv = OneGpu { kvc: &mut kvc, model: ModelId(0) };
                let o = e.step(now, &perf, &mut kv);
                now += o.duration;
                if !e.has_work() {
                    break;
                }
            }
            now
        };
        let base = run(1.0);
        let slow = run(2.5);
        assert!(base > 0.0);
        assert!(
            (slow - base * 2.5).abs() < 1e-9,
            "slowdown must scale duration: base {base}, slow {slow}"
        );
    }

    #[test]
    fn preemption_is_lifo_oldest_completes_first() {
        // LIFO (preempt-younger-only) discipline: the oldest admitted
        // request is never a victim, so under sustained memory pressure it
        // must be the first to complete.
        let (mut e, mut kvc) = setup(24);
        for i in 0..4 {
            e.admit(req(i, 256, 64));
        }
        let perf = GpuPerf::default();
        let mut now = 0.0;
        let mut comps = Vec::new();
        let mut preempted = 0;
        for _ in 0..30_000 {
            let mut kv = OneGpu { kvc: &mut kvc, model: ModelId(0) };
            let o = e.step(now, &perf, &mut kv);
            now += o.duration;
            preempted += o.preempted;
            comps.extend(o.completions);
            if !e.has_work() {
                break;
            }
        }
        assert!(preempted > 0, "workload must have triggered preemption");
        assert_eq!(comps.len(), 4);
        assert_eq!(comps[0].id, RequestId(0), "oldest request finishes first");
        assert_eq!(e.held_blocks(), 0);
    }

    #[test]
    fn drain_returns_requests_and_frees_kv() {
        let (mut e, mut kvc) = setup(1024);
        for i in 0..3 {
            e.admit(req(i, 200, 10));
        }
        let perf = GpuPerf::default();
        for _ in 0..3 {
            let mut kv = OneGpu { kvc: &mut kvc, model: ModelId(0) };
            e.step(0.0, &perf, &mut kv);
        }
        let mut kv = OneGpu { kvc: &mut kvc, model: ModelId(0) };
        let reqs = e.drain(&mut kv);
        assert_eq!(reqs.len(), 3);
        assert_eq!(kvc.kv_used_blocks(ModelId(0)), 0);
        assert!(!e.has_work());
        // Drained requests restart prefill from zero.
        assert!(reqs.iter().all(|r| r.prefill_done_tokens == 0));
    }

    #[test]
    fn active_kv_accounting_matches_tokens() {
        let (mut e, mut kvc) = setup(1024);
        e.admit(req(1, 32, 4));
        let perf = GpuPerf::default();
        let mut kv = OneGpu { kvc: &mut kvc, model: ModelId(0) };
        e.step(0.0, &perf, &mut kv);
        // After one step: 32 prompt tokens + 1 decoded resident.
        assert_eq!(e.active_kv_tokens(), 33);
        assert!(e.active_kv_bytes() > 0);
    }
}

impl SimEngine {
    /// Debug helper: (id, decoded_tokens) of the oldest running request
    /// (decoded count read from the slot table — the `Request` field is
    /// stale while it runs).
    pub fn debug_oldest(&self) -> Option<(u64, u32)> {
        self.running.first().map(|r| {
            let s = self.running_slots[0] as usize;
            (r.id.0, self.slot_tokens[s] - r.prompt_tokens)
        })
    }
}
