//! Simulated serving engine: chunked prefill + continuous batching over
//! kvcached-managed KV blocks (SGLang/vLLM-style iteration loop).
//!
//! One `SimEngine` serves one model instance on one GPU group. Each call to
//! `step` executes one engine iteration: a chunk of prefill for the head of
//! the admitted queue plus one decode token per running request, allocating
//! KV blocks on demand through the caller-supplied group allocator. When
//! allocation fails (pool exhausted or balloon limit), the engine preempts
//! the longest-running decode request (recompute-style, matching SGLang's
//! policy the paper builds on) and retries once.

use std::collections::HashMap;

use crate::engine::perf::GpuPerf;
use crate::kvcached::{BlockRef, KvError};
use crate::model::spec::ModelSpec;
use crate::request::{Completion, Phase, Request, RequestId};

/// Tokens per KV block (SGLang default page size is 16-64 tokens).
pub const BLOCK_TOKENS: u32 = 16;
/// Prefill chunk per iteration (chunked prefill, paper SS6.2).
pub const CHUNK_TOKENS: u32 = 512;
/// Maximum concurrent decode batch per engine.
pub const MAX_BATCH: u32 = 64;

/// One block replicated across the engine's TP group (one BlockRef per GPU).
pub type GroupBlock = Vec<BlockRef>;

/// Group-wide KV allocation interface provided by the cluster: allocates one
/// block on EVERY GPU of the engine's group or fails atomically.
pub trait KvAlloc {
    fn alloc(&mut self) -> Result<GroupBlock, KvError>;
    fn free(&mut self, b: GroupBlock);
}

#[derive(Debug, Clone, Default)]
pub struct StepOutcome {
    /// Wall-clock duration of this iteration (0 if the engine was idle).
    pub duration: f64,
    pub completions: Vec<Completion>,
    pub preempted: u32,
    /// True if any request made progress (engine should be rescheduled).
    pub active: bool,
}

#[derive(Debug)]
pub struct SimEngine {
    pub spec: ModelSpec,
    /// Admitted requests awaiting (or mid-) prefill, in admission order.
    queue: Vec<Request>,
    /// Requests in decode.
    running: Vec<Request>,
    blocks: HashMap<RequestId, Vec<GroupBlock>>,
    pub chunk_tokens: u32,
    pub max_batch: u32,
    /// Total iterations and busy seconds (throughput accounting excl. idle).
    pub iterations: u64,
    pub busy_seconds: f64,
    pub preemptions: u64,
}

impl SimEngine {
    pub fn new(spec: ModelSpec) -> Self {
        SimEngine {
            spec,
            queue: Vec::new(),
            running: Vec::new(),
            blocks: HashMap::new(),
            chunk_tokens: CHUNK_TOKENS,
            max_batch: MAX_BATCH,
            iterations: 0,
            busy_seconds: 0.0,
            preemptions: 0,
        }
    }

    /// Admit a request (arbitration has already decided it should run here).
    pub fn admit(&mut self, mut r: Request) {
        r.phase = Phase::Prefill;
        self.queue.push(r);
    }

    pub fn has_work(&self) -> bool {
        !self.queue.is_empty() || !self.running.is_empty()
    }

    pub fn queue_len(&self) -> usize {
        self.queue.len()
    }

    pub fn running_len(&self) -> usize {
        self.running.len()
    }

    /// Tokens of KV currently resident (for KVPR / memory plots).
    pub fn active_kv_tokens(&self) -> u64 {
        let q: u64 = self.queue.iter().map(|r| r.prefill_done_tokens as u64).sum();
        let d: u64 = self
            .running
            .iter()
            .map(|r| (r.prompt_tokens + r.decoded_tokens) as u64)
            .sum();
        q + d
    }

    pub fn active_kv_bytes(&self) -> u64 {
        self.active_kv_tokens() * self.spec.kv_bytes_per_token() * self.spec.tp as u64
    }

    /// Blocks held per request (used by drains/migration).
    pub fn held_blocks(&self) -> usize {
        self.blocks.values().map(|v| v.len()).sum()
    }

    fn ensure_blocks(
        &mut self,
        id: RequestId,
        tokens_needed: u32,
        kv: &mut dyn KvAlloc,
    ) -> Result<(), KvError> {
        let have = self.blocks.get(&id).map(|v| v.len() as u32).unwrap_or(0);
        let need = tokens_needed.div_ceil(BLOCK_TOKENS);
        for _ in have..need {
            let b = kv.alloc()?;
            self.blocks.entry(id).or_default().push(b);
        }
        Ok(())
    }

    fn release_blocks(&mut self, id: RequestId, kv: &mut dyn KvAlloc) {
        if let Some(bs) = self.blocks.remove(&id) {
            for b in bs {
                kv.free(b);
            }
        }
    }

    /// Preempt a decode request *promoted after* `requester` (LIFO,
    /// recompute-style - the vLLM/SGLang discipline). The age ordering is
    /// what makes this livelock-free: a request may only evict strictly
    /// younger ones, so the oldest running request always progresses,
    /// finishes, and releases memory. (Both "preempt the longest-decoded"
    /// and plain "preempt anyone but me" livelock: the victim re-prefills,
    /// gets promoted, and immediately preempts its preemptor.)
    fn preempt_younger(&mut self, kv: &mut dyn KvAlloc, requester: RequestId) -> bool {
        let Some(pos) = self.running.iter().position(|r| r.id == requester) else {
            return false;
        };
        if pos + 1 >= self.running.len() {
            return false; // requester is the youngest: it must wait instead
        }
        let mut r = self.running.pop().expect("younger victim exists");
        self.release_blocks(r.id, kv);
        r.preemptions += 1;
        r.preemptions_apply();
        self.queue.insert(0, r);
        self.preemptions += 1;
        true
    }

    /// Steal partial-prefill KV from the back of the queue (only safe when
    /// nothing is running; used so the queue head can make progress).
    fn steal_from_queue_tail(&mut self, kv: &mut dyn KvAlloc, protect: RequestId) -> bool {
        let qv = self
            .queue
            .iter()
            .enumerate()
            .rev()
            .find(|(_, r)| r.id != protect && self.blocks.contains_key(&r.id))
            .map(|(i, _)| i);
        if let Some(i) = qv {
            let id = self.queue[i].id;
            self.release_blocks(id, kv);
            let mut r = self.queue.remove(i);
            r.preemptions += 1;
            r.preemptions_apply();
            self.queue.push(r);
            self.preemptions += 1;
            return true;
        }
        false
    }

    /// Drain everything (engine eviction): frees all KV; returns the requests
    /// (callers re-queue them elsewhere). Completed stats are preserved.
    pub fn drain(&mut self, kv: &mut dyn KvAlloc) -> Vec<Request> {
        let ids: Vec<RequestId> = self.blocks.keys().copied().collect();
        for id in ids {
            self.release_blocks(id, kv);
        }
        let mut out: Vec<Request> = Vec::new();
        for mut r in self.queue.drain(..) {
            r.phase = Phase::Queued;
            r.prefill_done_tokens = 0;
            out.push(r);
        }
        for mut r in self.running.drain(..) {
            r.phase = Phase::Queued;
            r.preemptions += 1;
            r.preemptions_apply();
            out.push(r);
        }
        out
    }

    /// Execute one iteration at simulation time `now`.
    pub fn step(&mut self, now: f64, perf: &GpuPerf, kv: &mut dyn KvAlloc) -> StepOutcome {
        if !self.has_work() {
            return StepOutcome::default();
        }
        let mut out = StepOutcome { active: true, ..Default::default() };

        // ---- Phase 1: one decode token per running request --------------
        // Decode runs BEFORE prefill: running requests must get their KV
        // first, or prefill of waiting requests consumes every block that a
        // preemption frees and decode livelocks (vLLM/SGLang likewise give
        // the running batch priority over admission).
        // Iterate by id: preemption removes entries from `running` mid-scan.
        let mut finished: Vec<RequestId> = Vec::new();
        // Set when decode hit memory pressure this iteration: prefill
        // admission is then suppressed so it cannot re-consume the blocks
        // that preemption just freed (that re-consumption livelocks).
        let mut pressure = false;
        let ids: Vec<RequestId> = self.running.iter().map(|r| r.id).collect();
        for id in ids {
            let Some(idx) = self.running.iter().position(|r| r.id == id) else {
                continue; // preempted earlier this iteration
            };
            let tokens_after =
                self.running[idx].prompt_tokens + self.running[idx].decoded_tokens + 1;
            let mut attempts = 0;
            loop {
                match self.ensure_blocks(id, tokens_after, kv) {
                    Ok(()) => {
                        let r = self.running.iter_mut().find(|r| r.id == id).unwrap();
                        r.decoded_tokens += 1;
                        if r.decoded_tokens >= r.output_tokens {
                            finished.push(id);
                        }
                        break;
                    }
                    Err(KvError::OutOfPages(_)) | Err(KvError::LimitReached { .. }) => {
                        pressure = true;
                        // Victim order: a younger runner, else a queued
                        // partial prefill (not yet served, so younger in
                        // service order by definition). Retry after a
                        // successful preemption.
                        if attempts < 4
                            && (self.preempt_younger(kv, id)
                                || self.steal_from_queue_tail(kv, id))
                        {
                            out.preempted += 1;
                            attempts += 1;
                            continue;
                        }
                        // This (youngest) request stalls one iteration;
                        // older requests keep decoding and release memory.
                        break;
                    }
                    Err(e) => panic!("unexpected kv error: {e}"),
                }
            }
        }

        // ---- Phase 2: chunked prefill for the queue head(s) -------------
        // Suppressed entirely under decode memory pressure (see above).
        let mut chunk_left = if pressure { 0 } else { self.chunk_tokens };
        let mut prefill_tokens_done = 0u32;
        let mut qi = 0usize;
        while chunk_left > 0
            && qi < self.queue.len()
            && (self.running.len() as u32) < self.max_batch
        {
            let id = self.queue[qi].id;
            let total_prefill =
                self.queue[qi].prompt_tokens + self.queue[qi].decoded_tokens;
            let done = self.queue[qi].prefill_done_tokens;
            let take = chunk_left.min(total_prefill - done);
            // KV for the newly prefetched tokens.
            match self.ensure_blocks(id, done + take, kv) {
                Ok(()) => {}
                Err(KvError::OutOfPages(_)) | Err(KvError::LimitReached { .. }) => {
                    // Memory pressure. Prefill never preempts active decodes
                    // (decode progress guarantees memory is eventually freed;
                    // preempting it would allow prefill/decode livelock).
                    // With nothing running, steal partial-prefill KV from the
                    // queue tail so the head can make progress.
                    if self.running.is_empty() && self.steal_from_queue_tail(kv, id) {
                        out.preempted += 1;
                        continue;
                    }
                    break;
                }
                Err(e) => panic!("unexpected kv error: {e}"),
            }
            let r = &mut self.queue[qi];
            r.prefill_done_tokens += take;
            chunk_left -= take;
            prefill_tokens_done += take;
            if r.prefill_done_tokens >= total_prefill {
                qi += 1; // completed prefill; promoted below
            }
        }

        // ---- Iteration timing -------------------------------------------
        let decode_batch = self.running.len() as u32;
        let duration = perf.iteration_seconds(
            &self.spec,
            prefill_tokens_done,
            decode_batch,
            self.active_kv_bytes() / self.spec.tp as u64,
        );
        let end = now + duration;
        self.iterations += 1;
        self.busy_seconds += duration;
        out.duration = duration;

        // Decode latency accounting: every running request that decoded this
        // iteration accrues the iteration duration.
        for r in self.running.iter_mut() {
            if r.decoded_tokens > 0 {
                r.decode_time_accum += duration;
            }
        }

        // Completions.
        for id in finished {
            let Some(i) = self.running.iter().position(|r| r.id == id) else {
                continue; // finished request preempted later in the scan
            };
            let mut r = self.running.remove(i);
            r.phase = Phase::Finished;
            r.finish_time = Some(end);
            if r.first_token_time.is_none() {
                r.first_token_time = Some(end);
            }
            self.release_blocks(r.id, kv);
            out.completions.push(Completion::from_request(&r));
        }

        // Promote queue heads whose prefill completed: first token emitted at
        // the end of this iteration.
        let mut i = 0;
        while i < self.queue.len() {
            let total_prefill = self.queue[i].prompt_tokens + self.queue[i].decoded_tokens;
            if self.queue[i].prefill_done_tokens >= total_prefill
                && (self.running.len() as u32) < self.max_batch
            {
                let mut r = self.queue.remove(i);
                if r.first_token_time.is_none() {
                    r.first_token_time = Some(end);
                }
                // The first generated token is produced by the prefill pass.
                if r.decoded_tokens == 0 {
                    r.decoded_tokens = 1;
                }
                if r.decoded_tokens >= r.output_tokens {
                    r.phase = Phase::Finished;
                    r.finish_time = Some(end);
                    self.release_blocks(r.id, kv);
                    out.completions.push(Completion::from_request(&r));
                } else {
                    r.phase = Phase::Decode;
                    self.running.push(r);
                }
            } else {
                i += 1;
            }
        }

        out
    }
}

impl Request {
    /// After a recompute-style preemption, generated tokens must be
    /// re-prefetched: reset prefill progress (prompt + decoded become the new
    /// prefill span) but keep decode stats.
    pub fn preemptions_apply(&mut self) {
        self.phase = Phase::Prefill;
        self.prefill_done_tokens = 0;
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::kvcached::Kvcached;
    use crate::model::spec::{ModelId, ModelSpec, SizeClass};

    fn nano_spec() -> ModelSpec {
        ModelSpec {
            id: ModelId(0),
            name: "test-1b".into(),
            class: SizeClass::B1to3,
            params: 1_000_000_000,
            n_layers: 16,
            n_heads: 32,
            n_kv_heads: 8,
            d_head: 64,
            dtype_bytes: 2,
            tp: 1,
        }
    }

    /// Single-GPU allocator over one Kvcached.
    struct OneGpu<'a> {
        kvc: &'a mut Kvcached,
        model: ModelId,
    }

    impl<'a> KvAlloc for OneGpu<'a> {
        fn alloc(&mut self) -> Result<GroupBlock, KvError> {
            Ok(vec![self.kvc.alloc_block(self.model)?])
        }
        fn free(&mut self, b: GroupBlock) {
            for r in b {
                self.kvc.free_block(r).unwrap();
            }
        }
    }

    fn setup(capacity_mb: u64) -> (SimEngine, Kvcached) {
        let spec = nano_spec();
        let mut kvc = Kvcached::new(capacity_mb * 1024 * 1024, 2 * 1024 * 1024, 0);
        let block_bytes = spec.kv_bytes_per_token() * BLOCK_TOKENS as u64;
        kvc.register_kv(spec.id, block_bytes, u32::MAX);
        (SimEngine::new(spec), kvc)
    }

    fn req(id: u64, prompt: u32, out: u32) -> Request {
        Request::new(id, ModelId(0), 0.0, prompt, out, 5.0, 0.5)
    }

    #[test]
    fn request_completes_with_correct_latencies() {
        let (mut e, mut kvc) = setup(1024);
        e.admit(req(1, 100, 5));
        let perf = GpuPerf::default();
        let mut now = 0.0;
        let mut comps = Vec::new();
        for _ in 0..50 {
            let mut kv = OneGpu { kvc: &mut kvc, model: ModelId(0) };
            let o = e.step(now, &perf, &mut kv);
            now += o.duration;
            comps.extend(o.completions);
            if !e.has_work() {
                break;
            }
        }
        assert_eq!(comps.len(), 1);
        let c = &comps[0];
        assert!(c.ttft > 0.0 && c.ttft.is_finite());
        assert!(c.tpot > 0.0 && c.tpot.is_finite());
        assert_eq!(c.output_tokens, 5);
        // All KV released.
        assert_eq!(kvc.kv_used_blocks(ModelId(0)), 0);
        assert_eq!(e.held_blocks(), 0);
    }

    #[test]
    fn chunked_prefill_spreads_over_iterations() {
        let (mut e, mut kvc) = setup(1024);
        e.admit(req(1, CHUNK_TOKENS * 3, 2));
        let perf = GpuPerf::default();
        let mut iters = 0;
        let mut now = 0.0;
        while e.has_work() && iters < 20 {
            let mut kv = OneGpu { kvc: &mut kvc, model: ModelId(0) };
            let o = e.step(now, &perf, &mut kv);
            now += o.duration;
            iters += 1;
        }
        assert!(iters >= 4, "prefill must take >=3 chunks + decode, got {iters}");
    }

    #[test]
    fn batch_of_requests_all_complete() {
        let (mut e, mut kvc) = setup(2048);
        for i in 0..10 {
            e.admit(req(i, 64, 8));
        }
        let perf = GpuPerf::default();
        let mut now = 0.0;
        let mut done = 0;
        for _ in 0..500 {
            let mut kv = OneGpu { kvc: &mut kvc, model: ModelId(0) };
            let o = e.step(now, &perf, &mut kv);
            now += o.duration;
            done += o.completions.len();
            if !e.has_work() {
                break;
            }
        }
        assert_eq!(done, 10);
        assert_eq!(kvc.kv_used_blocks(ModelId(0)), 0);
    }

    #[test]
    fn memory_pressure_triggers_preemption_not_deadlock() {
        // 24 MiB = 12 pages = 48 blocks = 768 tokens of KV capacity; demand is
        // 4 requests x 320 tokens = 1280 tokens, so pressure is guaranteed.
        let (mut e, mut kvc) = setup(24);
        for i in 0..4 {
            e.admit(req(i, 256, 64));
        }
        let perf = GpuPerf::default();
        let mut now = 0.0;
        let mut done = 0;
        let mut preempted = 0;
        for _ in 0..30_000 {
            let mut kv = OneGpu { kvc: &mut kvc, model: ModelId(0) };
            let o = e.step(now, &perf, &mut kv);
            now += o.duration;
            done += o.completions.len();
            preempted += o.preempted;
            if !e.has_work() {
                break;
            }
        }
        assert_eq!(done, 4, "all requests must eventually finish");
        assert!(preempted > 0, "workload must have triggered preemption");
        assert_eq!(kvc.kv_used_blocks(ModelId(0)), 0);
    }

    #[test]
    fn drain_returns_requests_and_frees_kv() {
        let (mut e, mut kvc) = setup(1024);
        for i in 0..3 {
            e.admit(req(i, 200, 10));
        }
        let perf = GpuPerf::default();
        for _ in 0..3 {
            let mut kv = OneGpu { kvc: &mut kvc, model: ModelId(0) };
            e.step(0.0, &perf, &mut kv);
        }
        let mut kv = OneGpu { kvc: &mut kvc, model: ModelId(0) };
        let reqs = e.drain(&mut kv);
        assert_eq!(reqs.len(), 3);
        assert_eq!(kvc.kv_used_blocks(ModelId(0)), 0);
        assert!(!e.has_work());
        // Drained requests restart prefill from zero.
        assert!(reqs.iter().all(|r| r.prefill_done_tokens == 0));
    }

    #[test]
    fn active_kv_accounting_matches_tokens() {
        let (mut e, mut kvc) = setup(1024);
        e.admit(req(1, 32, 4));
        let perf = GpuPerf::default();
        let mut kv = OneGpu { kvc: &mut kvc, model: ModelId(0) };
        e.step(0.0, &perf, &mut kv);
        // After one step: 32 prompt tokens + 1 decoded resident.
        assert_eq!(e.active_kv_tokens(), 33);
        assert!(e.active_kv_bytes() > 0);
    }
}

impl SimEngine {
    /// Debug helper: (id, decoded_tokens) of the oldest running request.
    pub fn debug_oldest(&self) -> Option<(u64, u32)> {
        self.running.first().map(|r| (r.id.0, r.decoded_tokens))
    }
}
