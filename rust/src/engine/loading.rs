//! Model weight loading / activation latency model (paper SS5.3, Fig 10).
//!
//! Three strategies:
//!   * `Naive` - full cold start: engine init + single-stream pageable
//!     cudaMemcpyAsync to one GPU (the "tens of seconds" path).
//!   * `PooledNaive` - reusable engine pool (no init) but single-stream copy.
//!   * `Parallel` - Prism: engine pool + weights chunked across all node
//!     GPUs' PCIe links in parallel, aggregated to the target over NVLink in
//!     a streaming fashion (per-tensor granularity, ~30 MB buffers), so the
//!     NVLink hop overlaps with PCIe and adds only a small tail.

use crate::engine::perf::GpuPerf;

/// Full engine (re)initialization: process spawn, CUDA context, virtual
/// address-space reservation, distributed init. Paper: "tens of seconds"
/// dominated by this when done naively.
pub const ENGINE_INIT_SECONDS: f64 = 8.0;
/// One-time virtual-space realignment when an engine from the pool adopts a
/// model with a different KV layout (paper SS5.3).
pub const REALIGN_SECONDS: f64 = 0.050;
/// Streaming buffer per GPU for parallel loading.
pub const STREAM_BUFFER_BYTES: u64 = 30 << 20;

/// First-retry delay after a failed weight load (fault-injection PR).
pub const LOAD_RETRY_BASE_SECONDS: f64 = 0.5;
/// Cap on any single retry delay.
pub const LOAD_RETRY_MAX_SECONDS: f64 = 8.0;
/// Attempts (initial + retries) before a load is declared failed and the
/// activation aborts with `KvError::LoadFailed`.
pub const MAX_LOAD_ATTEMPTS: u32 = 3;

/// Exponential backoff before retry number `attempt` (1-based: `attempt = 1`
/// is the delay between the first failure and the second try). Deterministic
/// by design - no jitter, so injected load failures replay identically.
pub fn retry_backoff_seconds(attempt: u32) -> f64 {
    let shift = attempt.saturating_sub(1).min(30);
    (LOAD_RETRY_BASE_SECONDS * (1u64 << shift) as f64).min(LOAD_RETRY_MAX_SECONDS)
}

#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum LoadStrategy {
    Naive,
    PooledNaive,
    Parallel,
}

/// Seconds to make a model with `weight_bytes` (per target GPU) serve-ready.
/// `node_gpus` = GPUs on the node usable as parallel PCIe lanes.
pub fn activation_seconds(
    perf: &GpuPerf,
    strategy: LoadStrategy,
    weight_bytes: u64,
    node_gpus: u32,
) -> f64 {
    let w = weight_bytes as f64;
    match strategy {
        LoadStrategy::Naive => ENGINE_INIT_SECONDS + w / perf.pcie_stream_bw,
        LoadStrategy::PooledNaive => REALIGN_SECONDS + w / perf.pcie_stream_bw,
        LoadStrategy::Parallel => {
            let lanes = node_gpus.max(1) as f64;
            let pcie = w / (perf.pcie_stream_bw * lanes);
            // NVLink aggregation is streamed/overlapped; only the final
            // buffer flush is exposed, plus the link time for the last chunk.
            let nvlink_tail = (STREAM_BUFFER_BYTES as f64 * lanes) / perf.nvlink_bw;
            REALIGN_SECONDS + pcie.max(w / perf.nvlink_bw) + nvlink_tail
        }
    }
}

/// Migration switch-over latency (paper SS6.1/SS7.5): the source instance
/// keeps serving while the target warms, so only the hand-off is exposed.
/// With NVLink, weights + resident KV move at link speed (~20 ms for 8B).
pub fn migration_switchover_seconds(perf: &GpuPerf, moved_bytes: u64, nvlink: bool) -> f64 {
    if nvlink {
        1e-3 + moved_bytes as f64 / perf.nvlink_bw
    } else {
        // Fallback: staged eviction + reactivation, but off the critical path;
        // exposed switch-over is one streaming buffer.
        1e-3 + (2 * STREAM_BUFFER_BYTES) as f64 / perf.pcie_stream_bw
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::model::spec::{table3_catalog, GB};

    fn perf() -> GpuPerf {
        GpuPerf::default()
    }

    #[test]
    fn fig10_shape_small_models_subsecond() {
        // Paper Fig 10: 1B-8B activate < 0.7 s, 14B ~1.3 s, 70B ~1.5 s with
        // parallel loading on an 8-GPU node.
        let cat = table3_catalog();
        let p = perf();
        let b1 = cat.iter().find(|m| m.name.contains("1b")).unwrap();
        let b8 = cat.iter().find(|m| m.name.contains("8b")).unwrap();
        let b14 = cat.iter().find(|m| m.name.contains("14b")).unwrap();
        let b70 = cat.iter().find(|m| m.name == "llama-3.3-70b").unwrap();
        let t1 = activation_seconds(&p, LoadStrategy::Parallel, b1.weight_bytes(), 8);
        let t8 = activation_seconds(&p, LoadStrategy::Parallel, b8.weight_bytes(), 8);
        let t14 = activation_seconds(&p, LoadStrategy::Parallel, b14.weight_bytes(), 8);
        let t70 = activation_seconds(&p, LoadStrategy::Parallel, b70.weight_bytes_per_gpu() * 8, 8);
        assert!(t1 < 0.7, "t1={t1}");
        assert!(t8 < 0.7, "t8={t8}");
        assert!(t14 < 1.5, "t14={t14}");
        assert!(t70 < 2.5, "t70={t70}");
        assert!(t1 < t8 && t8 < t14 && t14 < t70);
    }

    #[test]
    fn naive_dominated_by_engine_init() {
        let p = perf();
        let t = activation_seconds(&p, LoadStrategy::Naive, 16 * GB, 8);
        assert!(t > ENGINE_INIT_SECONDS);
        // Engine pool removes the init cost.
        let tp = activation_seconds(&p, LoadStrategy::PooledNaive, 16 * GB, 8);
        assert!(t - tp > 0.9 * ENGINE_INIT_SECONDS);
    }

    #[test]
    fn parallel_beats_single_stream() {
        let p = perf();
        let naive = activation_seconds(&p, LoadStrategy::PooledNaive, 28 * GB, 8);
        let par = activation_seconds(&p, LoadStrategy::Parallel, 28 * GB, 8);
        assert!(par < naive / 3.0, "par={par} naive={naive}");
    }

    #[test]
    fn retry_backoff_is_exponential_and_capped() {
        assert_eq!(retry_backoff_seconds(1), 0.5);
        assert_eq!(retry_backoff_seconds(2), 1.0);
        assert_eq!(retry_backoff_seconds(3), 2.0);
        assert_eq!(retry_backoff_seconds(4), 4.0);
        assert_eq!(retry_backoff_seconds(5), 8.0);
        assert_eq!(retry_backoff_seconds(6), LOAD_RETRY_MAX_SECONDS);
        assert_eq!(retry_backoff_seconds(200), LOAD_RETRY_MAX_SECONDS);
        // attempt 0 is treated as attempt 1 (defensive, not a real call site)
        assert_eq!(retry_backoff_seconds(0), LOAD_RETRY_BASE_SECONDS);
    }

    #[test]
    fn migration_fast_over_nvlink() {
        let p = perf();
        // ~20 ms for an 8B model + small KV (paper SS7.5).
        let t = migration_switchover_seconds(&p, 16 * GB / 2 + GB, true);
        assert!(t < 0.03, "t={t}");
        let t2 = migration_switchover_seconds(&p, 16 * GB, false);
        assert!(t2 < 0.01); // only the exposed switch-over, not the full copy
    }
}
