//! Elastic tensor (paper D4): the serving-side facade over kvcached.
//!
//! The real PJRT serving path passes the paged KV pool to the decode
//! executable as a dense `[P, Tp, L, 2, Hkv, Dh]` f32 array. `ElasticTensor`
//! reserves that full *virtual* extent up front (one contiguous host buffer,
//! the "large virtual address space") while *physical* commitment is governed
//! by a `Kvcached` instance: a pool slot may only be written after
//! `alloc_slot` maps a block for it, and `free_slot` returns the backing.
//!
//! The serving engine uses slot ids directly as page ids in block tables, so
//! the attention kernel is untouched by any of this - exactly the paper's
//! transparency requirement (R4/D4).

use crate::kvcached::manager::{BlockRef, Kvcached, KvError};
use crate::model::spec::ModelId;

#[derive(Debug)]
pub struct ElasticTensor {
    model: ModelId,
    /// Elements per pool slot (= Tp * L * 2 * Hkv * Dh).
    slot_elems: usize,
    /// The full virtual extent; physical commitment tracked via kvcached.
    data: Vec<f32>,
    /// slot -> backing block (None = virtual only, not writable).
    backing: Vec<Option<BlockRef>>,
    free_slots: Vec<u32>, // stack of unmapped slot ids
}

impl ElasticTensor {
    /// Reserve `pool_slots` virtual slots; registers the model's KV geometry
    /// with `kvc` using one block per slot (block_bytes = slot bytes).
    pub fn reserve(
        kvc: &mut Kvcached,
        model: ModelId,
        pool_slots: u32,
        slot_elems: usize,
        limit_pages: u32,
    ) -> Self {
        kvc.register_kv(model, (slot_elems * 4) as u64, limit_pages);
        ElasticTensor {
            model,
            slot_elems,
            data: vec![0.0; pool_slots as usize * slot_elems],
            backing: vec![None; pool_slots as usize],
            free_slots: (0..pool_slots).rev().collect(),
        }
    }

    pub fn pool_slots(&self) -> u32 {
        self.backing.len() as u32
    }

    pub fn mapped_slots(&self) -> u32 {
        self.backing.iter().filter(|b| b.is_some()).count() as u32
    }

    /// Commit physical backing for one slot; returns the slot id to use as a
    /// page id in block tables.
    pub fn alloc_slot(&mut self, kvc: &mut Kvcached) -> Result<u32, KvError> {
        let slot = match self.free_slots.pop() {
            Some(s) => s,
            None => {
                return Err(KvError::OutOfPages(crate::kvcached::pool::OutOfPages {
                    requested: 1,
                    available: 0,
                }))
            }
        };
        match kvc.alloc_block(self.model) {
            Ok(b) => {
                self.backing[slot as usize] = Some(b);
                Ok(slot)
            }
            Err(e) => {
                self.free_slots.push(slot);
                Err(e)
            }
        }
    }

    /// Batched commit: map `n` slots through one kvcached call (single
    /// model lookup amortized over the batch), appending the slot ids to
    /// `out`. Atomic: on `Err` nothing is committed and `out` is untouched.
    pub fn alloc_slots(
        &mut self,
        kvc: &mut Kvcached,
        n: usize,
        out: &mut Vec<u32>,
    ) -> Result<(), KvError> {
        if self.free_slots.len() < n {
            return Err(KvError::OutOfPages(crate::kvcached::pool::OutOfPages {
                requested: n as u32,
                available: self.free_slots.len() as u32,
            }));
        }
        let mut blocks = Vec::with_capacity(n);
        if let Err(e) = kvc.alloc_blocks(self.model, n as u32, &mut blocks) {
            // alloc_blocks keeps partial progress; roll it back for slot
            // atomicity (a request needs its whole span or nothing).
            for b in blocks {
                let _ = kvc.free_block(b);
            }
            return Err(e);
        }
        for b in blocks {
            // INVARIANT: the free_slots.len() >= n guard above still holds —
            // nothing pops free_slots between the check and this loop.
            let slot = self.free_slots.pop().expect("count checked above");
            self.backing[slot as usize] = Some(b);
            out.push(slot);
        }
        Ok(())
    }

    /// Release a slot's physical backing; the virtual slot is reusable.
    pub fn free_slot(&mut self, kvc: &mut Kvcached, slot: u32) -> Result<(), KvError> {
        let b = self.backing[slot as usize]
            .take()
            .ok_or(KvError::UnknownModel(self.model))?;
        kvc.free_block(b)?;
        // Zero for hygiene: evicted tenants must not leak KV to later reads.
        let lo = slot as usize * self.slot_elems;
        self.data[lo..lo + self.slot_elems].fill(0.0);
        self.free_slots.push(slot);
        Ok(())
    }

    /// Write one token's KV vectors into `slot` at `tok_in_slot`.
    /// `kv` is the token's [L, 2, Hkv, Dh] flattened; `tp` = tokens per slot.
    pub fn write_token(&mut self, slot: u32, tok_in_slot: usize, tp: usize, kv: &[f32]) {
        assert!(
            self.backing[slot as usize].is_some(),
            "write to unmapped slot {slot} (virtual-only memory)"
        );
        let per_tok = self.slot_elems / tp;
        assert_eq!(kv.len(), per_tok);
        let lo = slot as usize * self.slot_elems + tok_in_slot * per_tok;
        self.data[lo..lo + per_tok].copy_from_slice(kv);
    }

    /// The dense pool view handed to PJRT as the decode pool argument.
    pub fn as_slice(&self) -> &[f32] {
        &self.data
    }

    pub fn slot_elems(&self) -> usize {
        self.slot_elems
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn setup() -> (Kvcached, ElasticTensor) {
        // Page = slot bytes so 1 block per page; 8 physical pages available.
        let slot_elems = 64;
        let mut kvc = Kvcached::new(8 * 64 * 4, 64 * 4, 0);
        let et = ElasticTensor::reserve(&mut kvc, ModelId(1), 16, slot_elems, u32::MAX);
        (kvc, et)
    }

    #[test]
    fn virtual_exceeds_physical() {
        let (mut kvc, mut et) = setup();
        assert_eq!(et.pool_slots(), 16); // virtual
        let mut slots = Vec::new();
        loop {
            match et.alloc_slot(&mut kvc) {
                Ok(s) => slots.push(s),
                Err(_) => break,
            }
        }
        assert_eq!(slots.len(), 8); // physical bound
        assert_eq!(et.mapped_slots(), 8);
        // Freeing one re-enables allocation.
        et.free_slot(&mut kvc, slots[0]).unwrap();
        assert!(et.alloc_slot(&mut kvc).is_ok());
    }

    #[test]
    fn batched_alloc_slots_is_atomic() {
        let (mut kvc, mut et) = setup(); // 8 physical, 16 virtual slots
        let mut slots = Vec::new();
        et.alloc_slots(&mut kvc, 6, &mut slots).unwrap();
        assert_eq!(slots.len(), 6);
        assert_eq!(et.mapped_slots(), 6);
        // 3 more don't fit (2 physical left): nothing is committed.
        assert!(et.alloc_slots(&mut kvc, 3, &mut slots).is_err());
        assert_eq!(slots.len(), 6);
        assert_eq!(et.mapped_slots(), 6);
        assert!(kvc.check_conservation());
        // The remaining 2 still allocate.
        et.alloc_slots(&mut kvc, 2, &mut slots).unwrap();
        assert_eq!(et.mapped_slots(), 8);
        for s in slots {
            et.free_slot(&mut kvc, s).unwrap();
        }
        assert_eq!(et.mapped_slots(), 0);
    }

    #[test]
    fn write_and_zero_on_free() {
        let (mut kvc, mut et) = setup();
        let s = et.alloc_slot(&mut kvc).unwrap();
        let tp = 4;
        let per_tok = 64 / tp;
        et.write_token(s, 1, tp, &vec![2.5; per_tok]);
        let lo = s as usize * 64 + per_tok;
        assert!(et.as_slice()[lo..lo + per_tok].iter().all(|&x| x == 2.5));
        et.free_slot(&mut kvc, s).unwrap();
        assert!(et.as_slice()[lo..lo + per_tok].iter().all(|&x| x == 0.0));
    }

    #[test]
    #[should_panic(expected = "unmapped slot")]
    fn write_to_unmapped_slot_panics() {
        let (_kvc, mut et) = setup();
        et.write_token(3, 0, 4, &vec![1.0; 16]);
    }

    #[test]
    fn limit_bounds_mapping() {
        let slot_elems = 64;
        let mut kvc = Kvcached::new(8 * 64 * 4, 64 * 4, 0);
        let mut et = ElasticTensor::reserve(&mut kvc, ModelId(7), 16, slot_elems, 2);
        assert!(et.alloc_slot(&mut kvc).is_ok());
        assert!(et.alloc_slot(&mut kvc).is_ok());
        assert!(matches!(et.alloc_slot(&mut kvc), Err(KvError::LimitReached { .. })));
        // Balloon up.
        kvc.set_kv_limit(ModelId(7), 4).unwrap();
        assert!(et.alloc_slot(&mut kvc).is_ok());
    }
}
