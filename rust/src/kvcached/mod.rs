//! kvcached: the GPU memory balloon driver (paper SS5).
//!
//! Decouples virtual and physical GPU memory for multi-LLM serving: engines
//! see large static reservations (elastic tensors); physical 2 MB pages are
//! mapped on demand and can be reclaimed *across models*, unifying space- and
//! time-sharing under one mechanism.

pub mod etensor;
pub mod manager;
pub mod pool;

pub use etensor::ElasticTensor;
pub use manager::{BlockRef, Kvcached, KvError, MemStats};
pub use pool::{PagePool, PhysPage, DEFAULT_PAGE_BYTES};

#[cfg(test)]
mod prop_tests {
    //! Property tests over the balloon driver's invariants.
    use super::*;
    use crate::model::spec::ModelId;
    use crate::util::prop::{check, Shrink};
    use crate::util::rng::Rng;

    /// A random workload script: per-step ops over a small set of models.
    #[derive(Debug, Clone)]
    struct Script {
        ops: Vec<Op>,
    }

    #[derive(Debug, Clone)]
    enum Op {
        Alloc(u8),
        FreeOldest(u8),
        SetLimit(u8, u32),
        LoadWeights(u8, u64),
        UnloadWeights(u8),
        Tick,
    }

    impl Shrink for Script {
        fn shrink(&self) -> Vec<Self> {
            let mut out = Vec::new();
            if self.ops.len() > 1 {
                out.push(Script { ops: self.ops[..self.ops.len() / 2].to_vec() });
                out.push(Script { ops: self.ops[self.ops.len() / 2..].to_vec() });
                let mut v = self.ops.clone();
                v.pop();
                out.push(Script { ops: v });
            }
            out
        }
    }

    fn gen_script(r: &mut Rng) -> Script {
        let n = r.range_usize(1, 120);
        let ops = (0..n)
            .map(|_| match r.below(12) {
                0..=4 => Op::Alloc(r.below(3) as u8),
                5..=7 => Op::FreeOldest(r.below(3) as u8),
                8 => Op::SetLimit(r.below(3) as u8, r.below(40) as u32),
                9 => Op::LoadWeights(r.below(3) as u8, (1 + r.below(20)) as u64 * 1024 * 1024),
                10 => Op::UnloadWeights(r.below(3) as u8),
                _ => Op::Tick,
            })
            .collect();
        Script { ops }
    }

    fn run_script(s: &Script) -> Result<(), String> {
        let mb = 1024 * 1024;
        let mut kvc = Kvcached::new(64 * mb, 2 * mb, 2);
        let models = [ModelId(0), ModelId(1), ModelId(2)];
        // Distinct block geometries per model (R2: heterogeneous layouts).
        kvc.register_kv(models[0], 512 * 1024, u32::MAX);
        kvc.register_kv(models[1], 256 * 1024, u32::MAX);
        kvc.register_kv(models[2], 2 * mb, u32::MAX);
        let mut live: Vec<Vec<BlockRef>> = vec![Vec::new(); 3];

        for op in &s.ops {
            match op {
                Op::Alloc(m) => {
                    if let Ok(b) = kvc.alloc_block(models[*m as usize]) {
                        live[*m as usize].push(b);
                    }
                }
                Op::FreeOldest(m) => {
                    if !live[*m as usize].is_empty() {
                        let b = live[*m as usize].remove(0);
                        kvc.free_block(b).map_err(|e| e.to_string())?;
                    }
                }
                Op::SetLimit(m, l) => {
                    kvc.set_kv_limit(models[*m as usize], *l).map_err(|e| e.to_string())?;
                }
                Op::LoadWeights(m, bytes) => {
                    let _ = kvc.load_weights(models[*m as usize], *bytes);
                }
                Op::UnloadWeights(m) => {
                    let _ = kvc.unload_weights(models[*m as usize]);
                }
                Op::Tick => {
                    kvc.tick_prealloc();
                }
            }
            // Invariant 1: conservation of physical pages.
            if !kvc.check_conservation() {
                return Err(format!("conservation violated after {op:?}: {:?}", kvc.stats()));
            }
            // Invariant 2: used KV never exceeds mapped KV.
            let st = kvc.stats();
            if st.kv_used_bytes > st.kv_mapped_bytes {
                return Err(format!("used > mapped after {op:?}: {st:?}"));
            }
            // Invariant 3: live block count matches manager accounting.
            for (i, m) in models.iter().enumerate() {
                if kvc.kv_used_blocks(*m) != live[i].len() as u64 {
                    return Err(format!(
                        "block accounting drift for {m}: kvc={} live={}",
                        kvc.kv_used_blocks(*m),
                        live[i].len()
                    ));
                }
            }
        }
        Ok(())
    }

    #[test]
    fn balloon_driver_invariants_hold_under_random_workloads() {
        check(60, 0xB411_00, gen_script, run_script);
    }

    // ------------------------------------------------------------------
    // Reference-model equivalence: the production bitmap/arena allocator
    // vs a naive Vec<bool> + linear-scan implementation with the same
    // selection policy (top-of-partial-stack pages, lowest free slot,
    // swap-remove membership). Every alloc/free/limit/balloon outcome —
    // the exact (page_idx, slot) refs, mapped-page counts, over-limit
    // reports, error kinds — must match op for op.
    // ------------------------------------------------------------------

    struct RefPool {
        free: u32,
    }

    struct RefKv {
        slots_per_page: u32,
        /// page_idx -> (slot occupancy, used_count); None = unmapped.
        pages: Vec<Option<(Vec<bool>, u32)>>,
        free_idx: Vec<u32>,
        partial: Vec<u32>,
        limit: u32,
        mapped: u32,
    }

    impl RefKv {
        fn new(slots_per_page: u32) -> Self {
            RefKv {
                slots_per_page,
                pages: Vec::new(),
                free_idx: Vec::new(),
                partial: Vec::new(),
                limit: u32::MAX,
                mapped: 0,
            }
        }

        fn partial_remove(&mut self, pi: u32) {
            if let Some(pos) = self.partial.iter().position(|&x| x == pi) {
                self.partial.swap_remove(pos);
            }
        }

        fn alloc(&mut self, pool: &mut RefPool) -> Result<(u32, u32), &'static str> {
            if let Some(&pi) = self.partial.last() {
                let (used, cnt) = self.pages[pi as usize].as_mut().unwrap();
                let slot = used.iter().position(|u| !*u).unwrap() as u32;
                used[slot as usize] = true;
                *cnt += 1;
                if *cnt == self.slots_per_page {
                    self.partial.pop();
                }
                return Ok((pi, slot));
            }
            if self.mapped >= self.limit {
                return Err("limit");
            }
            if pool.free == 0 {
                return Err("oom");
            }
            pool.free -= 1;
            let mut used = vec![false; self.slots_per_page as usize];
            used[0] = true;
            let pi = match self.free_idx.pop() {
                Some(i) => {
                    self.pages[i as usize] = Some((used, 1));
                    i
                }
                None => {
                    self.pages.push(Some((used, 1)));
                    (self.pages.len() - 1) as u32
                }
            };
            self.mapped += 1;
            if self.slots_per_page > 1 {
                self.partial.push(pi);
            }
            Ok((pi, 0))
        }

        fn free(&mut self, pool: &mut RefPool, pi: u32, slot: u32) {
            let (used, cnt) = self.pages[pi as usize].as_mut().unwrap();
            assert!(used[slot as usize], "ref model double free");
            used[slot as usize] = false;
            let was_full = *cnt == self.slots_per_page;
            *cnt -= 1;
            if *cnt == 0 && self.mapped > self.limit {
                self.pages[pi as usize] = None;
                self.free_idx.push(pi);
                self.partial_remove(pi);
                self.mapped -= 1;
                pool.free += 1;
                return;
            }
            if was_full {
                self.partial.push(pi);
            }
        }

        fn set_limit(&mut self, pool: &mut RefPool, limit: u32) -> u32 {
            self.limit = limit;
            let mut freed = 0u32;
            if self.mapped > limit {
                for i in 0..self.pages.len() {
                    if self.mapped - freed <= limit {
                        break;
                    }
                    if matches!(&self.pages[i], Some((_, 0))) {
                        self.pages[i] = None;
                        self.free_idx.push(i as u32);
                        self.partial_remove(i as u32);
                        freed += 1;
                    }
                }
                self.mapped -= freed;
                pool.free += freed;
            }
            self.mapped.saturating_sub(limit)
        }
    }

    #[derive(Debug, Clone)]
    enum EqOp {
        Alloc(u8),
        Free(u8, usize),   // free the live block at index (mod len)
        SetLimit(u8, u32),
        Batch(u8, u8),     // alloc_blocks(n)
        Tick,
    }

    fn gen_eq_script(r: &mut Rng) -> Vec<EqOp> {
        let n = r.range_usize(1, 160);
        (0..n)
            .map(|_| match r.below(16) {
                0..=5 => EqOp::Alloc(r.below(2) as u8),
                6..=9 => EqOp::Free(r.below(2) as u8, r.below(64)),
                10..=11 => EqOp::SetLimit(r.below(2) as u8, r.below(24) as u32),
                12..=14 => EqOp::Batch(r.below(2) as u8, (1 + r.below(12)) as u8),
                _ => EqOp::Tick,
            })
            .collect()
    }

    fn run_eq_script(ops: &[EqOp]) -> Result<(), String> {
        let mb = 1024 * 1024;
        let mut kvc = Kvcached::new(32 * mb, 2 * mb, 2); // 16 pages
        let mut pool = RefPool { free: 16 };
        let models = [ModelId(0), ModelId(1)];
        kvc.register_kv(models[0], 512 * 1024, u32::MAX); // 4 slots/page
        kvc.register_kv(models[1], 2 * mb, u32::MAX); // 1 slot/page
        let mut refs = [RefKv::new(4), RefKv::new(1)];
        let mut live: Vec<Vec<BlockRef>> = vec![Vec::new(); 2];

        for op in ops {
            match op {
                EqOp::Alloc(m) => {
                    let mi = *m as usize;
                    let got = kvc.alloc_block(models[mi]);
                    let want = refs[mi].alloc(&mut pool);
                    match (got, want) {
                        (Ok(b), Ok((pi, slot))) => {
                            if (b.page_idx, b.slot) != (pi, slot) {
                                return Err(format!(
                                    "alloc drift: got {:?}, ref ({pi},{slot})",
                                    b
                                ));
                            }
                            live[mi].push(b);
                        }
                        (Err(KvError::LimitReached { .. }), Err("limit"))
                        | (Err(KvError::OutOfPages(_)), Err("oom")) => {}
                        (g, w) => return Err(format!("error drift: got {g:?}, ref {w:?}")),
                    }
                }
                EqOp::Batch(m, n) => {
                    let mi = *m as usize;
                    let before = live[mi].len();
                    let got = kvc.alloc_blocks(models[mi], *n as u32, &mut live[mi]);
                    // Drive the reference until it fails too; outcomes and
                    // every appended (page, slot) must line up pairwise.
                    let mut want: Result<(), &'static str> = Ok(());
                    let mut want_refs: Vec<(u32, u32)> = Vec::new();
                    for _ in 0..*n {
                        match refs[mi].alloc(&mut pool) {
                            Ok(b) => want_refs.push(b),
                            Err(e) => {
                                want = Err(e);
                                break;
                            }
                        }
                    }
                    let appended: Vec<(u32, u32)> =
                        live[mi][before..].iter().map(|b| (b.page_idx, b.slot)).collect();
                    if appended != want_refs {
                        return Err(format!(
                            "batch drift: got {appended:?}, ref {want_refs:?}"
                        ));
                    }
                    match (&got, &want) {
                        (Ok(()), Ok(())) => {}
                        (Err(KvError::LimitReached { .. }), Err(e)) if *e == "limit" => {}
                        (Err(KvError::OutOfPages(_)), Err(e)) if *e == "oom" => {}
                        (g, w) => {
                            return Err(format!("batch error drift: got {g:?}, ref {w:?}"))
                        }
                    }
                }
                EqOp::Free(m, k) => {
                    let mi = *m as usize;
                    if live[mi].is_empty() {
                        continue;
                    }
                    let b = live[mi].remove(k % live[mi].len());
                    kvc.free_block(b).map_err(|e| e.to_string())?;
                    refs[mi].free(&mut pool, b.page_idx, b.slot);
                }
                EqOp::SetLimit(m, l) => {
                    let mi = *m as usize;
                    let got = kvc.set_kv_limit(models[mi], *l).map_err(|e| e.to_string())?;
                    let want = refs[mi].set_limit(&mut pool, *l);
                    if got != want {
                        return Err(format!("over-limit drift: got {got}, ref {want}"));
                    }
                }
                EqOp::Tick => {
                    kvc.tick_prealloc();
                }
            }
            for (mi, m) in models.iter().enumerate() {
                if kvc.kv_mapped_pages(*m) != refs[mi].mapped {
                    return Err(format!(
                        "mapped-page drift for {m} after {op:?}: kvc={} ref={}",
                        kvc.kv_mapped_pages(*m),
                        refs[mi].mapped
                    ));
                }
                if kvc.kv_used_blocks(*m) != live[mi].len() as u64 {
                    return Err(format!("used-block drift for {m} after {op:?}"));
                }
            }
            if !kvc.check_conservation() {
                return Err(format!("conservation violated after {op:?}"));
            }
        }
        Ok(())
    }

    // Element-wise shrinking is pointless for ops; the blanket `Vec<T>`
    // impl handles prefix/suffix/element removal.
    impl Shrink for EqOp {}

    #[test]
    fn bitmap_allocator_matches_reference_model() {
        check(80, 0xB411_02, gen_eq_script, |s| run_eq_script(s.as_slice()));
    }

    #[test]
    fn shared_kv_never_exceeds_capacity() {
        check(
            30,
            0xB411_01,
            |r| {
                let n = r.range_usize(1, 60);
                (0..n).map(|_| r.below(6) as u8).collect::<Vec<u8>>()
            },
            |ops| {
                let mb = 1024 * 1024;
                let mut kvc = Kvcached::new(32 * mb, 2 * mb, 1);
                let m = ModelId(0);
                kvc.register_kv(m, mb, u32::MAX);
                let mut live = Vec::new();
                for op in ops {
                    match op {
                        0..=3 => {
                            if let Ok(b) = kvc.alloc_block(m) {
                                live.push(b);
                            }
                        }
                        _ => {
                            if let Some(b) = live.pop() {
                                kvc.free_block(b).map_err(|e| e.to_string())?;
                            }
                        }
                    }
                    if kvc.shared_kv_bytes() > 32 * mb {
                        return Err("shared_kv exceeds capacity".into());
                    }
                }
                Ok(())
            },
        );
    }
}
