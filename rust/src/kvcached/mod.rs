//! kvcached: the GPU memory balloon driver (paper SS5).
//!
//! Decouples virtual and physical GPU memory for multi-LLM serving: engines
//! see large static reservations (elastic tensors); physical 2 MB pages are
//! mapped on demand and can be reclaimed *across models*, unifying space- and
//! time-sharing under one mechanism.

pub mod etensor;
pub mod manager;
pub mod pool;

pub use etensor::ElasticTensor;
pub use manager::{BlockRef, Kvcached, KvError, MemStats};
pub use pool::{PagePool, PhysPage, DEFAULT_PAGE_BYTES};

#[cfg(test)]
mod prop_tests {
    //! Property tests over the balloon driver's invariants.
    use super::*;
    use crate::model::spec::ModelId;
    use crate::util::prop::{check, Shrink};
    use crate::util::rng::Rng;

    /// A random workload script: per-step ops over a small set of models.
    #[derive(Debug, Clone)]
    struct Script {
        ops: Vec<Op>,
    }

    #[derive(Debug, Clone)]
    enum Op {
        Alloc(u8),
        FreeOldest(u8),
        SetLimit(u8, u32),
        LoadWeights(u8, u64),
        UnloadWeights(u8),
        Tick,
    }

    impl Shrink for Script {
        fn shrink(&self) -> Vec<Self> {
            let mut out = Vec::new();
            if self.ops.len() > 1 {
                out.push(Script { ops: self.ops[..self.ops.len() / 2].to_vec() });
                out.push(Script { ops: self.ops[self.ops.len() / 2..].to_vec() });
                let mut v = self.ops.clone();
                v.pop();
                out.push(Script { ops: v });
            }
            out
        }
    }

    fn gen_script(r: &mut Rng) -> Script {
        let n = r.range_usize(1, 120);
        let ops = (0..n)
            .map(|_| match r.below(12) {
                0..=4 => Op::Alloc(r.below(3) as u8),
                5..=7 => Op::FreeOldest(r.below(3) as u8),
                8 => Op::SetLimit(r.below(3) as u8, r.below(40) as u32),
                9 => Op::LoadWeights(r.below(3) as u8, (1 + r.below(20)) as u64 * 1024 * 1024),
                10 => Op::UnloadWeights(r.below(3) as u8),
                _ => Op::Tick,
            })
            .collect();
        Script { ops }
    }

    fn run_script(s: &Script) -> Result<(), String> {
        let mb = 1024 * 1024;
        let mut kvc = Kvcached::new(64 * mb, 2 * mb, 2);
        let models = [ModelId(0), ModelId(1), ModelId(2)];
        // Distinct block geometries per model (R2: heterogeneous layouts).
        kvc.register_kv(models[0], 512 * 1024, u32::MAX);
        kvc.register_kv(models[1], 256 * 1024, u32::MAX);
        kvc.register_kv(models[2], 2 * mb, u32::MAX);
        let mut live: Vec<Vec<BlockRef>> = vec![Vec::new(); 3];

        for op in &s.ops {
            match op {
                Op::Alloc(m) => {
                    if let Ok(b) = kvc.alloc_block(models[*m as usize]) {
                        live[*m as usize].push(b);
                    }
                }
                Op::FreeOldest(m) => {
                    if !live[*m as usize].is_empty() {
                        let b = live[*m as usize].remove(0);
                        kvc.free_block(b).map_err(|e| e.to_string())?;
                    }
                }
                Op::SetLimit(m, l) => {
                    kvc.set_kv_limit(models[*m as usize], *l).map_err(|e| e.to_string())?;
                }
                Op::LoadWeights(m, bytes) => {
                    let _ = kvc.load_weights(models[*m as usize], *bytes);
                }
                Op::UnloadWeights(m) => {
                    let _ = kvc.unload_weights(models[*m as usize]);
                }
                Op::Tick => {
                    kvc.tick_prealloc();
                }
            }
            // Invariant 1: conservation of physical pages.
            if !kvc.check_conservation() {
                return Err(format!("conservation violated after {op:?}: {:?}", kvc.stats()));
            }
            // Invariant 2: used KV never exceeds mapped KV.
            let st = kvc.stats();
            if st.kv_used_bytes > st.kv_mapped_bytes {
                return Err(format!("used > mapped after {op:?}: {st:?}"));
            }
            // Invariant 3: live block count matches manager accounting.
            for (i, m) in models.iter().enumerate() {
                if kvc.kv_used_blocks(*m) != live[i].len() as u64 {
                    return Err(format!(
                        "block accounting drift for {m}: kvc={} live={}",
                        kvc.kv_used_blocks(*m),
                        live[i].len()
                    ));
                }
            }
        }
        Ok(())
    }

    #[test]
    fn balloon_driver_invariants_hold_under_random_workloads() {
        check(60, 0xB411_00, gen_script, run_script);
    }

    #[test]
    fn shared_kv_never_exceeds_capacity() {
        check(
            30,
            0xB411_01,
            |r| {
                let n = r.range_usize(1, 60);
                (0..n).map(|_| r.below(6) as u8).collect::<Vec<u8>>()
            },
            |ops| {
                let mb = 1024 * 1024;
                let mut kvc = Kvcached::new(32 * mb, 2 * mb, 1);
                let m = ModelId(0);
                kvc.register_kv(m, mb, u32::MAX);
                let mut live = Vec::new();
                for op in ops {
                    match op {
                        0..=3 => {
                            if let Ok(b) = kvc.alloc_block(m) {
                                live.push(b);
                            }
                        }
                        _ => {
                            if let Some(b) = live.pop() {
                                kvc.free_block(b).map_err(|e| e.to_string())?;
                            }
                        }
                    }
                    if kvc.shared_kv_bytes() > 32 * mb {
                        return Err("shared_kv exceeds capacity".into());
                    }
                }
                Ok(())
            },
        );
    }
}
