//! Physical GPU page pool - the bottom of the kvcached balloon driver.
//!
//! Models one GPU's physical memory as an array of fixed-size pages (2 MB by
//! default, matching CUDA VMM granularity and the paper's D3). Supports the
//! prealloc buffer optimization: an asynchronously-refilled stash of ready
//! pages so the hot path rarely pays the full map cost (paper SS5.2 D3).
//!
//! The pool is pure bookkeeping plus a timing model; the simulator charges
//! `alloc_cost`/`free_cost` to its clock, and the real serving path uses the
//! same pool (with small pages) to govern its PJRT-backed KV tensor.
//!
//! # Per-token complexity budget
//!
//! The pool sits under `Kvcached::alloc_block`, which the engine calls on
//! the per-decode-token path, so every operation here is O(1) and
//! allocation-free: [`PagePool::alloc_one`] pops one page id off a stack
//! (prealloc buffer first) without constructing a `Vec`, and
//! [`PagePool::alloc_n`] appends into a caller-owned buffer. The
//! `(Vec<PhysPage>, cost)`-returning [`PagePool::alloc`] remains as a
//! convenience wrapper for cold paths (weight loading, tests).

/// Default physical page size: 2 MiB (CUDA VMM minimum granularity).
pub const DEFAULT_PAGE_BYTES: u64 = 2 * 1024 * 1024;

/// Per-page map/unmap latency in microseconds (CUDA VMM map + TLB update;
/// the paper reports millisecond-level redistribution for GB-scale moves,
/// i.e. ~thousands of pages per ms-scale operation).
pub const MAP_US_PER_PAGE: f64 = 2.0;
/// Fixed per-batch syscall/driver overhead in microseconds.
pub const MAP_US_BATCH: f64 = 10.0;

#[derive(Debug, Clone, Copy, PartialEq, Eq, Hash)]
pub struct PhysPage(pub u32);

/// Counters for overhead accounting (Fig 14 analysis).
#[derive(Debug, Default, Clone)]
pub struct PoolCounters {
    pub map_batches: u64,
    pub pages_mapped: u64,
    pub pages_unmapped: u64,
    pub prealloc_hits: u64,
    pub prealloc_misses: u64,
}

#[derive(Debug)]
pub struct PagePool {
    page_bytes: u64,
    total: u32,
    free: Vec<u32>,
    /// Prealloc buffer: pages already prepared by the background thread.
    prealloc: Vec<u32>,
    prealloc_target: u32,
    pub counters: PoolCounters,
}

#[derive(Debug, Clone, PartialEq, Eq)]
pub struct OutOfPages {
    pub requested: u32,
    pub available: u32,
}

impl std::fmt::Display for OutOfPages {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        write!(f, "out of GPU pages: requested {}, available {}", self.requested, self.available)
    }
}

impl std::error::Error for OutOfPages {}

impl PagePool {
    pub fn new(capacity_bytes: u64, page_bytes: u64, prealloc_target: u32) -> Self {
        let total = (capacity_bytes / page_bytes) as u32;
        PagePool {
            page_bytes,
            total,
            free: (0..total).rev().collect(),
            prealloc: Vec::new(),
            prealloc_target,
            counters: PoolCounters::default(),
        }
    }

    pub fn page_bytes(&self) -> u64 {
        self.page_bytes
    }

    pub fn total_pages(&self) -> u32 {
        self.total
    }

    pub fn free_pages(&self) -> u32 {
        (self.free.len() + self.prealloc.len()) as u32
    }

    pub fn used_pages(&self) -> u32 {
        self.total - self.free_pages()
    }

    pub fn free_bytes(&self) -> u64 {
        self.free_pages() as u64 * self.page_bytes
    }

    /// Allocate `n` physical pages, drawing from the prealloc buffer first.
    /// Returns the pages and the modelled latency in microseconds.
    pub fn alloc(&mut self, n: u32) -> Result<(Vec<PhysPage>, f64), OutOfPages> {
        let mut out = Vec::with_capacity(n as usize);
        let cost = self.alloc_n(n, &mut out)?;
        Ok((out, cost))
    }

    /// Allocate `n` pages, appending them to `out` (no per-call `Vec`; the
    /// caller owns and reuses the buffer). Returns the modelled latency in
    /// microseconds; on `Err`, `out` is untouched.
    pub fn alloc_n(&mut self, n: u32, out: &mut Vec<PhysPage>) -> Result<f64, OutOfPages> {
        if n == 0 {
            return Ok(0.0);
        }
        if self.free_pages() < n {
            return Err(OutOfPages { requested: n, available: self.free_pages() });
        }
        let from_buf = (n as usize).min(self.prealloc.len());
        for _ in 0..from_buf {
            // INVARIANT: from_buf <= prealloc.len() by the min() above.
            out.push(PhysPage(self.prealloc.pop().unwrap()));
        }
        self.counters.prealloc_hits += from_buf as u64;
        let remaining = n as usize - from_buf;
        let mut cost = 0.0;
        if remaining > 0 {
            self.counters.prealloc_misses += remaining as u64;
            self.counters.map_batches += 1;
            cost = MAP_US_BATCH + MAP_US_PER_PAGE * remaining as f64;
            for _ in 0..remaining {
                // INVARIANT: free_pages() >= n was checked on entry, and
                // from_buf pages came off prealloc, not free.
                out.push(PhysPage(self.free.pop().unwrap()));
            }
        }
        self.counters.pages_mapped += n as u64;
        Ok(cost)
    }

    /// Allocate exactly one page without touching the heap (per-token hot
    /// path). Identical accounting and cost model to `alloc(1)`.
    pub fn alloc_one(&mut self) -> Result<(PhysPage, f64), OutOfPages> {
        if let Some(p) = self.prealloc.pop() {
            self.counters.prealloc_hits += 1;
            self.counters.pages_mapped += 1;
            return Ok((PhysPage(p), 0.0));
        }
        match self.free.pop() {
            Some(p) => {
                self.counters.prealloc_misses += 1;
                self.counters.map_batches += 1;
                self.counters.pages_mapped += 1;
                Ok((PhysPage(p), MAP_US_BATCH + MAP_US_PER_PAGE))
            }
            None => Err(OutOfPages { requested: 1, available: 0 }),
        }
    }

    /// Return pages; they land in the prealloc buffer up to its target, the
    /// rest are physically freed (paper D3: released pages are buffered).
    pub fn free(&mut self, pages: &[PhysPage]) -> f64 {
        let mut to_release = 0usize;
        for p in pages {
            debug_assert!(p.0 < self.total);
            if (self.prealloc.len() as u32) < self.prealloc_target {
                self.prealloc.push(p.0);
            } else {
                self.free.push(p.0);
                to_release += 1;
            }
        }
        self.counters.pages_unmapped += pages.len() as u64;
        if to_release > 0 {
            MAP_US_BATCH + MAP_US_PER_PAGE * to_release as f64
        } else {
            0.0
        }
    }

    /// Background refill of the prealloc buffer (the paper's prep thread).
    /// Call from the idle loop; returns refilled count.
    pub fn refill_prealloc(&mut self) -> u32 {
        let mut n = 0;
        while (self.prealloc.len() as u32) < self.prealloc_target {
            match self.free.pop() {
                Some(p) => {
                    self.prealloc.push(p);
                    n += 1;
                }
                None => break,
            }
        }
        n
    }

    /// Drain the prealloc buffer back to the free list (memory reclaim for a
    /// new model's weights - "only physically freed if ... memory must be
    /// reclaimed", paper D3).
    pub fn drain_prealloc(&mut self) -> u32 {
        let n = self.prealloc.len() as u32;
        self.free.append(&mut self.prealloc);
        n
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn pool() -> PagePool {
        // 64 MiB with 2 MiB pages = 32 pages, prealloc target 4.
        PagePool::new(64 * 1024 * 1024, DEFAULT_PAGE_BYTES, 4)
    }

    #[test]
    fn alloc_free_roundtrip() {
        let mut p = pool();
        assert_eq!(p.total_pages(), 32);
        let (pages, cost) = p.alloc(10).unwrap();
        assert_eq!(pages.len(), 10);
        assert!(cost > 0.0);
        assert_eq!(p.free_pages(), 22);
        p.free(&pages);
        assert_eq!(p.free_pages(), 32);
    }

    #[test]
    fn oom_reports_availability() {
        let mut p = pool();
        let (a, _) = p.alloc(30).unwrap();
        let err = p.alloc(5).unwrap_err();
        assert_eq!(err, OutOfPages { requested: 5, available: 2 });
        p.free(&a);
    }

    #[test]
    fn prealloc_hit_is_cheap() {
        let mut p = pool();
        p.refill_prealloc();
        let (pages, cost) = p.alloc(3).unwrap();
        assert_eq!(cost, 0.0); // fully served from buffer
        assert_eq!(p.counters.prealloc_hits, 3);
        p.free(&pages);
        // Freed pages replenish the buffer first.
        assert!(p.counters.pages_unmapped == 3);
    }

    #[test]
    fn prealloc_miss_charges_batch_cost() {
        let mut p = pool();
        let (_, cost) = p.alloc(5).unwrap();
        assert!((cost - (MAP_US_BATCH + 5.0 * MAP_US_PER_PAGE)).abs() < 1e-9);
        assert_eq!(p.counters.map_batches, 1);
    }

    #[test]
    fn unique_pages_across_allocs() {
        let mut p = pool();
        let (a, _) = p.alloc(16).unwrap();
        let (b, _) = p.alloc(16).unwrap();
        let mut all: Vec<u32> = a.iter().chain(b.iter()).map(|x| x.0).collect();
        all.sort_unstable();
        all.dedup();
        assert_eq!(all.len(), 32);
    }

    #[test]
    fn drain_prealloc_reclaims() {
        let mut p = pool();
        p.refill_prealloc();
        assert_eq!(p.drain_prealloc(), 4);
        assert_eq!(p.free_pages(), 32);
    }

    #[test]
    fn zero_alloc_is_free() {
        let mut p = pool();
        let (pages, cost) = p.alloc(0).unwrap();
        assert!(pages.is_empty() && cost == 0.0);
    }

    #[test]
    fn alloc_one_matches_alloc_1_accounting() {
        let mut a = pool();
        let mut b = pool();
        a.refill_prealloc();
        b.refill_prealloc();
        // Drain through the prealloc buffer into cold pages on both paths.
        for _ in 0..8 {
            let (pa, ca) = a.alloc_one().unwrap();
            let (pb, cb) = b.alloc(1).unwrap();
            assert_eq!(pa, pb[0]);
            assert_eq!(ca, cb);
        }
        assert_eq!(a.counters.prealloc_hits, b.counters.prealloc_hits);
        assert_eq!(a.counters.map_batches, b.counters.map_batches);
        assert_eq!(a.counters.pages_mapped, b.counters.pages_mapped);
        assert_eq!(a.free_pages(), b.free_pages());
        let err = {
            let mut x = pool();
            while x.alloc_one().is_ok() {}
            x.alloc_one().unwrap_err()
        };
        assert_eq!(err, OutOfPages { requested: 1, available: 0 });
    }

    #[test]
    fn alloc_n_appends_and_is_atomic_on_err() {
        let mut p = pool();
        let mut buf = Vec::new();
        let cost = p.alloc_n(10, &mut buf).unwrap();
        assert_eq!(buf.len(), 10);
        assert!(cost > 0.0);
        // A failing alloc_n leaves the buffer untouched.
        assert!(p.alloc_n(64, &mut buf).is_err());
        assert_eq!(buf.len(), 10);
        p.free(&buf);
        assert_eq!(p.free_pages(), 32);
    }
}
