//! The kvcached device manager: unified weight + KV memory for one GPU.
//!
//! Implements the paper's SS5.2 designs over `PagePool`:
//!   D1 unified weights/KV - both draw from the same physical pool, so
//!      releasing one immediately funds the other;
//!   D2 automatic token-block mapping - per-model block geometry (token size
//!      differs per architecture), pages never shared across models;
//!   D3 overhead/fragmentation optimizations - contiguous-layer layout means
//!      ONE page allocation covers all 2L per-layer tensors of a token block
//!      (the 2Lx speedup), the pool's prealloc buffer absorbs map cost, and
//!      partially-filled pages are preferred for new blocks;
//!   D4 transparency - the serving side sees only opaque `BlockRef`s
//!      (virtual KV block handles); geometry changes never touch kernels.
//!
//! Ballooning: `set_kv_limit` bounds a model's mapped KV pages; shrinking a
//! limit makes the manager release free pages immediately and report how many
//! *used* pages must be vacated by the engine (via preemption) before the
//! target is met.
//!
//! # Per-token complexity budget
//!
//! `alloc_block`/`free_block` sit on the engine's per-decode-token path, so
//! both are O(1) amortized and heap-allocation-free:
//!
//! * slot occupancy is an inline `u64` bitmap per page (`SlotBits`);
//!   first-free is one `trailing_zeros`, never a `Vec<bool>` scan (geometries
//!   with more than 64 slots per page spill to a boxed word array, still
//!   O(slots/64) at worst and allocated only when the page is mapped);
//! * partial-page membership is position-indexed (`partial_pos`), so removal
//!   is an O(1) swap-remove — never a `partial.retain` scan;
//! * [`Kvcached::alloc_blocks`] batches an iteration's demand through ONE
//!   model lookup, appending into a caller-owned buffer.
//!
//! Anything O(slots), O(partial), or O(pages) on the alloc/free path is a
//! regression (`set_kv_limit` alone may scan pages: ballooning is a control
//! action, not a per-token one). Tracked by `benches/micro.rs`
//! (`kvcached/*`) and the KV-churn scenario in `benches/sim_hot_path.rs`.

use std::collections::BTreeMap;

use crate::kvcached::pool::{OutOfPages, PagePool, PhysPage};
use crate::model::spec::ModelId;

/// Handle to one mapped token block (Tp tokens x all layers' K+V).
#[derive(Debug, Clone, Copy, PartialEq, Eq, Hash)]
pub struct BlockRef {
    pub model: ModelId,
    pub page_idx: u32, // index into the model's page list
    pub slot: u32,     // block slot within the page
}

/// Slot-occupancy bitmap for one page (set bit = used). Pages with at most
/// 64 slots — the norm at simulator geometry (2 MiB pages, 32 KiB+ blocks) —
/// use one inline word with zero heap allocation; finer geometries (the real
/// server's KiB-scale slots) spill to a boxed word array allocated once at
/// page map time.
#[derive(Debug, Clone)]
enum SlotBits {
    Inline(u64),
    Spill(Box<[u64]>),
}

impl SlotBits {
    fn new(slots: u32) -> Self {
        if slots <= 64 {
            SlotBits::Inline(0)
        } else {
            SlotBits::Spill(vec![0u64; slots.div_ceil(64) as usize].into_boxed_slice())
        }
    }

    fn get(&self, slot: u32) -> bool {
        match self {
            SlotBits::Inline(w) => (w >> slot) & 1 == 1,
            SlotBits::Spill(ws) => (ws[slot as usize / 64] >> (slot % 64)) & 1 == 1,
        }
    }

    fn set(&mut self, slot: u32) {
        match self {
            SlotBits::Inline(w) => *w |= 1u64 << slot,
            SlotBits::Spill(ws) => ws[slot as usize / 64] |= 1u64 << (slot % 64),
        }
    }

    fn clear(&mut self, slot: u32) {
        match self {
            SlotBits::Inline(w) => *w &= !(1u64 << slot),
            SlotBits::Spill(ws) => ws[slot as usize / 64] &= !(1u64 << (slot % 64)),
        }
    }

    /// Lowest free slot below `slots` via `trailing_zeros` — the same slot a
    /// linear first-free scan would pick.
    fn first_free(&self, slots: u32) -> Option<u32> {
        match self {
            SlotBits::Inline(w) => {
                let free = !w & mask_below(slots);
                (free != 0).then(|| free.trailing_zeros())
            }
            SlotBits::Spill(ws) => {
                for (i, w) in ws.iter().enumerate() {
                    let free = !w;
                    if free != 0 {
                        let slot = i as u32 * 64 + free.trailing_zeros();
                        // Bits at/above `slots` in the tail word are never
                        // set, so they read as free: reject them.
                        return (slot < slots).then_some(slot);
                    }
                }
                None
            }
        }
    }
}

/// Bitmask of the `slots` low bits (all ones when `slots >= 64`).
fn mask_below(slots: u32) -> u64 {
    if slots >= 64 {
        u64::MAX
    } else {
        (1u64 << slots) - 1
    }
}

#[derive(Debug, Clone)]
struct PageState {
    phys: PhysPage,
    bits: SlotBits, // slot occupancy bitmap
    used_count: u32,
}

/// `partial_pos` sentinel: the page is not in the partial list.
const NOT_PARTIAL: u32 = u32::MAX;

/// Per-model KV state: geometry + mapped pages.
#[derive(Debug)]
struct ModelKv {
    block_bytes: u64,
    slots_per_page: u32,
    pages: Vec<Option<PageState>>, // index = page_idx; None = unmapped slot reuse
    free_page_indices: Vec<u32>,   // reusable page_idx values
    /// page indices with at least one free slot (partial-page priority);
    /// allocation draws from the top.
    partial: Vec<u32>,
    /// page_idx -> position in `partial` (NOT_PARTIAL when absent): O(1)
    /// membership removal by swap-remove instead of `partial.retain`.
    partial_pos: Vec<u32>,
    limit_pages: u32,
    mapped_pages: u32,
    used_blocks: u64,
}

impl ModelKv {
    fn partial_push(&mut self, pi: u32) {
        debug_assert_eq!(self.partial_pos[pi as usize], NOT_PARTIAL);
        self.partial_pos[pi as usize] = self.partial.len() as u32;
        self.partial.push(pi);
    }

    fn partial_remove(&mut self, pi: u32) {
        let pos = std::mem::replace(&mut self.partial_pos[pi as usize], NOT_PARTIAL);
        if pos == NOT_PARTIAL {
            return;
        }
        self.partial.swap_remove(pos as usize);
        if let Some(&moved) = self.partial.get(pos as usize) {
            self.partial_pos[moved as usize] = pos;
        }
    }
}

/// One block allocation over (pool, per-model state): the shared core of
/// `alloc_block` and the batched `alloc_blocks`. Returns the block plus the
/// map cost accrued (nonzero only when a fresh physical page was mapped).
fn alloc_block_in(
    pool: &mut PagePool,
    mk: &mut ModelKv,
    model: ModelId,
) -> Result<(BlockRef, f64), KvError> {
    // Partial-page priority (D3): top of the partial stack.
    if let Some(&pi) = mk.partial.last() {
        // INVARIANT: the partial list only ever holds live pages with at
        // least one free slot (entries are removed the moment they fill).
        let page = mk.pages[pi as usize].as_mut().expect("partial page exists");
        debug_assert!(page.used_count < mk.slots_per_page, "full page in partial list");
        let slot = page.bits.first_free(mk.slots_per_page).expect("slot free");
        page.bits.set(slot);
        page.used_count += 1;
        mk.used_blocks += 1;
        if page.used_count == mk.slots_per_page {
            mk.partial_remove(pi); // top of stack: swap-remove is a pop
        }
        return Ok((BlockRef { model, page_idx: pi, slot }, 0.0));
    }

    // Need a fresh page.
    if mk.mapped_pages >= mk.limit_pages {
        return Err(KvError::LimitReached { model, limit_pages: mk.limit_pages });
    }
    let (phys, cost) = pool.alloc_one().map_err(KvError::OutOfPages)?;
    let mut bits = SlotBits::new(mk.slots_per_page);
    bits.set(0);
    let state = PageState { phys, bits, used_count: 1 };
    let pi = match mk.free_page_indices.pop() {
        Some(i) => {
            mk.pages[i as usize] = Some(state);
            i
        }
        None => {
            mk.pages.push(Some(state));
            mk.partial_pos.push(NOT_PARTIAL);
            (mk.pages.len() - 1) as u32
        }
    };
    mk.mapped_pages += 1;
    mk.used_blocks += 1;
    if mk.slots_per_page > 1 {
        mk.partial_push(pi);
    }
    Ok((BlockRef { model, page_idx: pi, slot: 0 }, cost))
}

/// GPU-level memory statistics (drives KVPR's `shared_kv` and Fig 6/14).
#[derive(Debug, Clone, PartialEq)]
pub struct MemStats {
    pub total_bytes: u64,
    pub weight_bytes: u64,
    pub kv_mapped_bytes: u64,
    pub kv_used_bytes: u64,
    pub free_bytes: u64,
    /// Mapped-but-unused KV bytes (internal fragmentation the balloon can reclaim).
    pub kv_fragmented_bytes: u64,
}

#[derive(Debug)]
pub struct Kvcached {
    pool: PagePool,
    weights: BTreeMap<ModelId, Vec<PhysPage>>,
    kv: BTreeMap<ModelId, ModelKv>,
    /// Microseconds of map/unmap work performed (timing model output).
    pub accrued_cost_us: f64,
    /// Deterministic transient-fault injector: every `fault_every`-th block
    /// allocation fails while armed (0 = disarmed, the default — one branch
    /// of overhead on the hot path).
    fault_every: u32,
    /// Allocation attempts observed since the injector was (re)armed.
    fault_counter: u64,
    /// Total faults injected (harvested into `RunMetrics::faults`).
    faults_injected: u64,
}

#[derive(Debug, Clone, PartialEq)]
pub enum KvError {
    OutOfPages(OutOfPages),
    LimitReached { model: ModelId, limit_pages: u32 },
    UnknownModel(ModelId),
    /// Deterministic fault injection (`fault::AllocFault`): a transient
    /// allocation fault fired. Transient by construction — every retry
    /// advances the injector's counter — so callers treat it exactly like
    /// memory pressure (back off / preempt / retry), never as fatal.
    FaultInjected { model: ModelId },
    /// A model load failed after exhausting its retry budget
    /// (`fault::FaultPlan::load_fail_attempts`); surfaced by
    /// `Cluster::activate`, not by this manager.
    LoadFailed { model: ModelId },
}

impl std::fmt::Display for KvError {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        match self {
            KvError::OutOfPages(e) => write!(f, "{e}"),
            KvError::LimitReached { model, limit_pages } => {
                write!(f, "{model} at kv limit ({limit_pages} pages)")
            }
            KvError::UnknownModel(m) => write!(f, "unknown model {m}"),
            KvError::FaultInjected { model } => {
                write!(f, "injected transient alloc fault for {model}")
            }
            KvError::LoadFailed { model } => {
                write!(f, "load of {model} failed after retries")
            }
        }
    }
}

impl std::error::Error for KvError {}

impl Kvcached {
    pub fn new(capacity_bytes: u64, page_bytes: u64, prealloc_target: u32) -> Self {
        Kvcached {
            pool: PagePool::new(capacity_bytes, page_bytes, prealloc_target),
            weights: BTreeMap::new(),
            kv: BTreeMap::new(),
            accrued_cost_us: 0.0,
            fault_every: 0,
            fault_counter: 0,
            faults_injected: 0,
        }
    }

    // ----------------------------------------------------- fault injection

    /// Arm the deterministic transient-fault injector: every `every`-th
    /// block allocation (counted from now) fails with
    /// [`KvError::FaultInjected`] until [`Kvcached::disarm_alloc_faults`].
    /// `every` is clamped to >= 2 so progress is always possible between
    /// consecutive faults. Re-arming resets the attempt counter.
    pub fn arm_alloc_faults(&mut self, every: u32) {
        self.fault_every = every.max(2);
        self.fault_counter = 0;
    }

    pub fn disarm_alloc_faults(&mut self) {
        self.fault_every = 0;
    }

    /// Total transient faults injected over this manager's lifetime.
    pub fn alloc_faults_injected(&self) -> u64 {
        self.faults_injected
    }

    /// One injector step: count the attempt, report whether it must fail.
    fn injected_fault(&mut self) -> bool {
        if self.fault_every == 0 {
            return false;
        }
        self.fault_counter += 1;
        if self.fault_counter % self.fault_every as u64 == 0 {
            self.faults_injected += 1;
            true
        } else {
            false
        }
    }

    pub fn page_bytes(&self) -> u64 {
        self.pool.page_bytes()
    }

    pub fn pool_counters(&self) -> &crate::kvcached::pool::PoolCounters {
        &self.pool.counters
    }

    fn pages_for(&self, bytes: u64) -> u32 {
        bytes.div_ceil(self.pool.page_bytes()) as u32
    }

    // ------------------------------------------------------------- weights

    /// Map a model's weights (on activation). D1: weights and KV share the pool.
    /// Re-loading an already-resident model first releases the old mapping
    /// (weights may be a different size after a quantization/variant switch).
    pub fn load_weights(&mut self, model: ModelId, bytes: u64) -> Result<(), KvError> {
        if self.weights.contains_key(&model) {
            self.unload_weights(model);
        }
        let need = self.pages_for(bytes);
        if (self.pool.free_pages()) < need {
            // Weights may also cannibalize the prealloc buffer.
            self.pool.drain_prealloc();
        }
        let (pages, cost) = self.pool.alloc(need).map_err(KvError::OutOfPages)?;
        self.accrued_cost_us += cost;
        self.weights.insert(model, pages);
        Ok(())
    }

    /// Unmap a model's weights (on eviction); frees pages for other tenants.
    pub fn unload_weights(&mut self, model: ModelId) -> u64 {
        if let Some(pages) = self.weights.remove(&model) {
            let n = pages.len() as u64;
            self.accrued_cost_us += self.pool.free(&pages);
            n * self.pool.page_bytes()
        } else {
            0
        }
    }

    pub fn has_weights(&self, model: ModelId) -> bool {
        self.weights.contains_key(&model)
    }

    // ------------------------------------------------------------------ kv

    /// Register a model's KV geometry. `block_bytes` = token_size x block_tokens
    /// across ALL layers (contiguous-layer layout, D3). `limit_pages` = u32::MAX
    /// means unlimited (bounded by the pool).
    pub fn register_kv(&mut self, model: ModelId, block_bytes: u64, limit_pages: u32) {
        let slots = (self.pool.page_bytes() / block_bytes).max(1) as u32;
        self.kv.insert(
            model,
            ModelKv {
                block_bytes,
                slots_per_page: slots,
                pages: Vec::new(),
                free_page_indices: Vec::new(),
                partial: Vec::new(),
                partial_pos: Vec::new(),
                limit_pages,
                mapped_pages: 0,
                used_blocks: 0,
            },
        );
    }

    pub fn unregister_kv(&mut self, model: ModelId) {
        if let Some(mk) = self.kv.remove(&model) {
            let pages: Vec<PhysPage> =
                mk.pages.iter().flatten().map(|p| p.phys).collect();
            self.accrued_cost_us += self.pool.free(&pages);
        }
    }

    /// Allocate one token block for `model`. Prefers partially-filled pages
    /// (D3); maps a new physical page only when no partial page has room and
    /// the model is under its limit. O(1), no heap allocation.
    pub fn alloc_block(&mut self, model: ModelId) -> Result<BlockRef, KvError> {
        if self.injected_fault() {
            return Err(KvError::FaultInjected { model });
        }
        let mk = self.kv.get_mut(&model).ok_or(KvError::UnknownModel(model))?;
        let (r, cost) = alloc_block_in(&mut self.pool, mk, model)?;
        self.accrued_cost_us += cost;
        Ok(r)
    }

    /// Batched allocation: `n` blocks for `model`, appended to `out`, with
    /// the model lookup amortized over the whole batch (one engine iteration
    /// allocates all of its demand through a single call). On `Err`, blocks
    /// allocated before the failure REMAIN in `out` — callers keep partial
    /// progress across preemption retries, exactly as repeated `alloc_block`
    /// calls always did.
    pub fn alloc_blocks(
        &mut self,
        model: ModelId,
        n: u32,
        out: &mut Vec<BlockRef>,
    ) -> Result<(), KvError> {
        // The injector counts per-block attempts (identical to repeated
        // `alloc_block` calls); its state lives in locals for the duration
        // of the loop because `mk` exclusively borrows `self.kv`.
        let every = self.fault_every as u64;
        let mut counter = self.fault_counter;
        let mut injected = 0u64;
        let mk = self.kv.get_mut(&model).ok_or(KvError::UnknownModel(model))?;
        let mut cost = 0.0;
        let mut err = None;
        for _ in 0..n {
            if every != 0 {
                counter += 1;
                if counter % every == 0 {
                    injected += 1;
                    err = Some(KvError::FaultInjected { model });
                    break;
                }
            }
            match alloc_block_in(&mut self.pool, mk, model) {
                Ok((r, c)) => {
                    cost += c;
                    out.push(r);
                }
                Err(e) => {
                    err = Some(e);
                    break;
                }
            }
        }
        self.fault_counter = counter;
        self.faults_injected += injected;
        self.accrued_cost_us += cost;
        match err {
            Some(e) => Err(e),
            None => Ok(()),
        }
    }

    /// Free one token block; a page whose last block is freed is unmapped
    /// immediately only if the model is over its limit, otherwise kept mapped
    /// (and preferred for reuse) to avoid map churn. O(1), no heap allocation.
    pub fn free_block(&mut self, r: BlockRef) -> Result<(), KvError> {
        let mk = self.kv.get_mut(&r.model).ok_or(KvError::UnknownModel(r.model))?;
        let page = mk.pages[r.page_idx as usize]
            .as_mut()
            .ok_or(KvError::UnknownModel(r.model))?;
        // Invariant (deliberate panic, kept through the panic audit): a
        // double free means a caller holds a forged or stale BlockRef.
        // That is memory-accounting corruption, not a recoverable input
        // error, and `double_free_detected` pins this behavior.
        assert!(page.bits.get(r.slot), "double free of {r:?}");
        page.bits.clear(r.slot);
        let was_full = page.used_count == mk.slots_per_page;
        page.used_count -= 1;
        mk.used_blocks -= 1;
        if page.used_count == 0 {
            // Unmap empty pages eagerly when over limit; else keep for reuse.
            if mk.mapped_pages > mk.limit_pages {
                let phys = page.phys;
                mk.pages[r.page_idx as usize] = None;
                mk.free_page_indices.push(r.page_idx);
                mk.partial_remove(r.page_idx);
                mk.mapped_pages -= 1;
                self.accrued_cost_us += self.pool.free(&[phys]);
                return Ok(());
            }
        }
        if was_full {
            mk.partial_push(r.page_idx);
        }
        Ok(())
    }

    /// Balloon: bound a model's mapped KV pages. Frees empty pages now;
    /// returns how many pages are still over target (engine must shed load).
    pub fn set_kv_limit(&mut self, model: ModelId, limit_pages: u32) -> Result<u32, KvError> {
        let mk = self.kv.get_mut(&model).ok_or(KvError::UnknownModel(model))?;
        mk.limit_pages = limit_pages;
        // Release empty pages until at/below the limit.
        let mut to_free: Vec<PhysPage> = Vec::new();
        if mk.mapped_pages > limit_pages {
            for i in 0..mk.pages.len() {
                if mk.mapped_pages.saturating_sub(to_free.len() as u32) <= limit_pages {
                    break;
                }
                if let Some(p) = &mk.pages[i] {
                    if p.used_count == 0 {
                        to_free.push(p.phys);
                        mk.pages[i] = None;
                        mk.free_page_indices.push(i as u32);
                        mk.partial_remove(i as u32);
                    }
                }
            }
            mk.mapped_pages -= to_free.len() as u32;
        }
        let over = mk.mapped_pages.saturating_sub(limit_pages);
        if !to_free.is_empty() {
            self.accrued_cost_us += self.pool.free(&to_free);
        }
        Ok(over)
    }

    pub fn kv_limit(&self, model: ModelId) -> Option<u32> {
        self.kv.get(&model).map(|m| m.limit_pages)
    }

    pub fn kv_mapped_pages(&self, model: ModelId) -> u32 {
        self.kv.get(&model).map(|m| m.mapped_pages).unwrap_or(0)
    }

    pub fn kv_used_blocks(&self, model: ModelId) -> u64 {
        self.kv.get(&model).map(|m| m.used_blocks).unwrap_or(0)
    }

    /// Background prealloc refill; returns pages prepared.
    pub fn tick_prealloc(&mut self) -> u32 {
        self.pool.refill_prealloc()
    }

    // --------------------------------------------------------------- stats

    pub fn stats(&self) -> MemStats {
        let pb = self.pool.page_bytes();
        let weight_pages: u64 = self.weights.values().map(|v| v.len() as u64).sum();
        let kv_mapped: u64 = self.kv.values().map(|m| m.mapped_pages as u64).sum();
        let kv_used: u64 = self
            .kv
            .values()
            .map(|m| m.used_blocks * m.block_bytes)
            .sum();
        let total = self.pool.total_pages() as u64 * pb;
        MemStats {
            total_bytes: total,
            weight_bytes: weight_pages * pb,
            kv_mapped_bytes: kv_mapped * pb,
            kv_used_bytes: kv_used,
            free_bytes: self.pool.free_bytes(),
            kv_fragmented_bytes: kv_mapped * pb - kv_used,
        }
    }

    /// Memory available for KV growth on this GPU - the paper's `shared_kv`:
    /// free pool pages plus mapped-but-unused KV capacity.
    pub fn shared_kv_bytes(&self) -> u64 {
        let s = self.stats();
        s.free_bytes + s.kv_fragmented_bytes
    }

    /// Invariant check used by tests and debug assertions.
    pub fn check_conservation(&self) -> bool {
        let s = self.stats();
        s.weight_bytes + s.kv_mapped_bytes + s.free_bytes == s.total_bytes
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::kvcached::pool::DEFAULT_PAGE_BYTES;

    const MB: u64 = 1024 * 1024;

    fn kvc() -> Kvcached {
        // 128 MiB / 2 MiB pages = 64 pages, prealloc 4.
        Kvcached::new(128 * MB, DEFAULT_PAGE_BYTES, 4)
    }

    #[test]
    fn weights_and_kv_share_pool_d1() {
        let mut k = kvc();
        let m1 = ModelId(1);
        let m2 = ModelId(2);
        k.load_weights(m1, 60 * MB).unwrap(); // 30 pages
        k.register_kv(m2, 512 * 1024, u32::MAX); // 4 blocks/page
        // Fill KV until pool exhausted.
        let mut blocks = Vec::new();
        loop {
            match k.alloc_block(m2) {
                Ok(b) => blocks.push(b),
                Err(KvError::OutOfPages(_)) => break,
                Err(e) => panic!("{e}"),
            }
        }
        assert_eq!(k.kv_mapped_pages(m2), 34);
        // Evicting m1's weights immediately funds more KV.
        assert!(k.unload_weights(m1) > 0);
        assert!(k.alloc_block(m2).is_ok());
        assert!(k.check_conservation());
    }

    #[test]
    fn per_model_page_segregation_d2() {
        let mut k = kvc();
        let (a, b) = (ModelId(1), ModelId(2));
        k.register_kv(a, 512 * 1024, u32::MAX);
        k.register_kv(b, 256 * 1024, u32::MAX);
        let ba = k.alloc_block(a).unwrap();
        let bb = k.alloc_block(b).unwrap();
        // Different models never share a page: each gets its own page 0.
        assert_eq!(ba.page_idx, 0);
        assert_eq!(bb.page_idx, 0);
        assert_eq!(k.kv_mapped_pages(a), 1);
        assert_eq!(k.kv_mapped_pages(b), 1);
        assert!(k.check_conservation());
    }

    #[test]
    fn partial_page_priority_d3() {
        let mut k = kvc();
        let m = ModelId(1);
        k.register_kv(m, 512 * 1024, u32::MAX); // 4 slots/page
        let blocks: Vec<BlockRef> = (0..6).map(|_| k.alloc_block(m).unwrap()).collect();
        assert_eq!(k.kv_mapped_pages(m), 2);
        // Free one block on page 0 -> next alloc must reuse page 0, not map page 2.
        k.free_block(blocks[1]).unwrap();
        let nb = k.alloc_block(m).unwrap();
        assert_eq!(nb.page_idx, 0);
        assert_eq!(k.kv_mapped_pages(m), 2);
    }

    #[test]
    fn limit_enforced_and_ballooning() {
        let mut k = kvc();
        let m = ModelId(1);
        k.register_kv(m, DEFAULT_PAGE_BYTES, 2); // 1 slot/page, limit 2 pages
        let b1 = k.alloc_block(m).unwrap();
        let _b2 = k.alloc_block(m).unwrap();
        match k.alloc_block(m) {
            Err(KvError::LimitReached { limit_pages: 2, .. }) => {}
            other => panic!("expected limit, got {other:?}"),
        }
        // Raise the limit -> allocation proceeds.
        k.set_kv_limit(m, 3).unwrap();
        let _b3 = k.alloc_block(m).unwrap();
        // Shrink below mapped: empty pages freed, over-target reported.
        k.free_block(b1).unwrap();
        let over = k.set_kv_limit(m, 1).unwrap();
        assert_eq!(k.kv_mapped_pages(m), 2); // freed the empty one
        assert_eq!(over, 1); // one used page still over target
    }

    #[test]
    fn free_block_over_limit_unmaps_eagerly() {
        let mut k = kvc();
        let m = ModelId(1);
        k.register_kv(m, DEFAULT_PAGE_BYTES, u32::MAX);
        let blocks: Vec<BlockRef> = (0..4).map(|_| k.alloc_block(m).unwrap()).collect();
        k.set_kv_limit(m, 1).unwrap();
        // All 4 pages used; freeing now unmaps because mapped > limit.
        for b in blocks {
            k.free_block(b).unwrap();
        }
        assert_eq!(k.kv_mapped_pages(m), 1); // kept at most limit
        assert!(k.check_conservation());
    }

    #[test]
    fn stats_and_shared_kv() {
        let mut k = kvc();
        let m = ModelId(1);
        k.load_weights(m, 20 * MB).unwrap(); // 10 pages
        k.register_kv(m, MB, u32::MAX); // 2 slots/page
        let _b = k.alloc_block(m).unwrap();
        let s = k.stats();
        assert_eq!(s.weight_bytes, 20 * MB);
        assert_eq!(s.kv_mapped_bytes, 2 * MB);
        assert_eq!(s.kv_used_bytes, MB);
        assert_eq!(s.kv_fragmented_bytes, MB);
        assert_eq!(s.total_bytes, 128 * MB);
        assert_eq!(k.shared_kv_bytes(), s.free_bytes + MB);
        assert!(k.check_conservation());
    }

    #[test]
    fn unregister_returns_pages() {
        let mut k = kvc();
        let m = ModelId(1);
        k.register_kv(m, MB, u32::MAX);
        for _ in 0..8 {
            k.alloc_block(m).unwrap();
        }
        let free_before = k.stats().free_bytes;
        k.unregister_kv(m);
        assert!(k.stats().free_bytes > free_before);
        assert_eq!(k.kv_mapped_pages(m), 0);
        assert!(k.check_conservation());
    }

    #[test]
    fn batched_alloc_keeps_partial_progress_on_failure() {
        let mut k = kvc();
        let m = ModelId(1);
        k.register_kv(m, DEFAULT_PAGE_BYTES, 3); // 1 slot/page, limit 3
        let mut out = Vec::new();
        match k.alloc_blocks(m, 5, &mut out) {
            Err(KvError::LimitReached { limit_pages: 3, .. }) => {}
            other => panic!("expected limit, got {other:?}"),
        }
        assert_eq!(out.len(), 3, "blocks before the failure stay allocated");
        assert_eq!(k.kv_used_blocks(m), 3);
        assert_eq!(k.kv_mapped_pages(m), 3);
        for b in out {
            k.free_block(b).unwrap();
        }
        assert!(k.check_conservation());
    }

    #[test]
    fn batched_alloc_matches_repeated_single_allocs() {
        let script = |k: &mut Kvcached, batched: bool| -> Vec<BlockRef> {
            let m = ModelId(1);
            k.register_kv(m, 512 * 1024, u32::MAX); // 4 slots/page
            let mut out = Vec::new();
            if batched {
                k.alloc_blocks(m, 11, &mut out).unwrap();
            } else {
                for _ in 0..11 {
                    out.push(k.alloc_block(m).unwrap());
                }
            }
            out
        };
        let (mut a, mut b) = (kvc(), kvc());
        let ra = script(&mut a, true);
        let rb = script(&mut b, false);
        assert_eq!(ra, rb, "batched and single-block allocation pick the same slots");
        assert_eq!(a.kv_mapped_pages(ModelId(1)), b.kv_mapped_pages(ModelId(1)));
        assert_eq!(a.accrued_cost_us, b.accrued_cost_us);
    }

    #[test]
    fn spill_bitmap_geometry_over_64_slots() {
        // 16 KiB blocks on 2 MiB pages = 128 slots/page: exercises the
        // boxed-word spill path of the slot bitmap.
        let mut k = kvc();
        let m = ModelId(1);
        k.register_kv(m, 16 * 1024, u32::MAX);
        let blocks: Vec<BlockRef> = (0..130).map(|_| k.alloc_block(m).unwrap()).collect();
        assert_eq!(k.kv_mapped_pages(m), 2);
        assert_eq!(blocks[127], BlockRef { model: m, page_idx: 0, slot: 127 });
        assert_eq!(blocks[128].page_idx, 1);
        // Freeing a low slot on page 0 makes it the preferred partial page.
        k.free_block(blocks[70]).unwrap();
        let nb = k.alloc_block(m).unwrap();
        assert_eq!(nb, BlockRef { model: m, page_idx: 0, slot: 70 });
        let (partial_len, free_slots) = k.debug_partial(m);
        assert_eq!(free_slots, 2 * 128 - 130);
        assert!(partial_len >= 1);
        assert!(k.check_conservation());
    }

    #[test]
    fn injected_alloc_faults_are_transient_and_keep_partial_progress() {
        let mut k = kvc();
        let m = ModelId(1);
        k.register_kv(m, 512 * 1024, u32::MAX); // 4 slots/page
        k.arm_alloc_faults(3);
        let mut out = Vec::new();
        match k.alloc_blocks(m, 5, &mut out) {
            Err(KvError::FaultInjected { model }) => assert_eq!(model, m),
            other => panic!("expected injected fault, got {other:?}"),
        }
        assert_eq!(out.len(), 2, "blocks before the injected fault stay allocated");
        assert_eq!(k.kv_used_blocks(m), 2);
        assert_eq!(k.alloc_faults_injected(), 1);
        // Transient: retrying advances the injector past the fault, so a
        // bounded number of retries always reaches the full batch.
        while out.len() < 5 {
            let _ = k.alloc_blocks(m, (5 - out.len()) as u32, &mut out);
        }
        assert_eq!(k.kv_used_blocks(m), 5);
        k.disarm_alloc_faults();
        assert!(k.alloc_block(m).is_ok());
        assert!(k.check_conservation());
    }

    #[test]
    fn single_alloc_injector_counts_attempts() {
        let mut k = kvc();
        let m = ModelId(1);
        k.register_kv(m, MB, u32::MAX);
        k.arm_alloc_faults(2);
        assert!(k.alloc_block(m).is_ok()); // attempt 1
        assert!(matches!(k.alloc_block(m), Err(KvError::FaultInjected { .. }))); // attempt 2
        assert!(k.alloc_block(m).is_ok()); // attempt 3
        assert_eq!(k.alloc_faults_injected(), 1);
        // Re-arming resets the attempt counter deterministically.
        k.arm_alloc_faults(2);
        assert!(k.alloc_block(m).is_ok());
        assert!(matches!(k.alloc_block(m), Err(KvError::FaultInjected { .. })));
        assert!(k.check_conservation());
    }

    #[test]
    #[should_panic(expected = "double free")]
    fn double_free_detected() {
        let mut k = kvc();
        let m = ModelId(1);
        k.register_kv(m, MB, u32::MAX);
        let b = k.alloc_block(m).unwrap();
        k.free_block(b).unwrap();
        let _ = k.free_block(b);
    }
}

impl Kvcached {
    /// Debug: (partial-stack length, free slots actually present) for a model.
    pub fn debug_partial(&self, model: ModelId) -> (usize, u64) {
        match self.kv.get(&model) {
            Some(mk) => {
                let free_slots: u64 = mk
                    .pages
                    .iter()
                    .flatten()
                    .map(|p| (mk.slots_per_page - p.used_count) as u64)
                    .sum();
                (mk.partial.len(), free_slots)
            }
            None => (0, 0),
        }
    }
}
