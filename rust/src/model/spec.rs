//! Model architecture catalog.
//!
//! Mirrors the paper's Table 3 evaluation mix (58 LLMs: 43x 1B-3B, 8x 4B-8B,
//! 3x 9B-30B, 4x 31B-70B) with realistic per-architecture KV geometry, plus
//! the PrismNano family actually executed through PJRT. The simulator only
//! needs the quantities the paper's mechanisms act on: weight bytes, KV bytes
//! per token (`token_size`), layer count, and TP degree.

use std::fmt;

pub const GB: u64 = 1 << 30;
pub const MB: u64 = 1 << 20;

#[derive(Debug, Clone, Copy, PartialEq, Eq, PartialOrd, Ord, Hash)]
pub struct ModelId(pub u32);

impl fmt::Display for ModelId {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        write!(f, "m{}", self.0)
    }
}

/// Size class buckets from Table 3.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum SizeClass {
    B1to3,
    B4to8,
    B9to30,
    B31to70,
    Nano, // real-execution PrismNano family
}

#[derive(Debug, Clone)]
pub struct ModelSpec {
    pub id: ModelId,
    pub name: String,
    pub class: SizeClass,
    /// Total parameters.
    pub params: u64,
    pub n_layers: u32,
    pub n_heads: u32,
    pub n_kv_heads: u32,
    pub d_head: u32,
    /// Bytes per element for weights and KV (2 = fp16/bf16, 4 = fp32).
    pub dtype_bytes: u32,
    /// Tensor-parallel degree (1 for single-GPU models).
    pub tp: u32,
}

impl ModelSpec {
    /// Total weight bytes (all TP shards combined).
    pub fn weight_bytes(&self) -> u64 {
        self.params * self.dtype_bytes as u64
    }

    /// Weight bytes resident on ONE GPU of the TP group.
    pub fn weight_bytes_per_gpu(&self) -> u64 {
        self.weight_bytes() / self.tp as u64
    }

    /// KV-cache bytes per token per GPU - the paper's `token_size`.
    /// K+V over all layers: L * 2 * Hkv * Dh * dtype, divided across TP.
    pub fn kv_bytes_per_token(&self) -> u64 {
        let full = self.n_layers as u64
            * 2
            * self.n_kv_heads as u64
            * self.d_head as u64
            * self.dtype_bytes as u64;
        full / self.tp as u64
    }

    pub fn is_tp(&self) -> bool {
        self.tp > 1
    }
}

/// Canonical architecture for a given parameter count (Llama/Qwen-like).
fn arch_for(params_b: f64) -> (u32, u32, u32, u32) {
    // (layers, heads, kv_heads, head_dim)
    if params_b <= 1.5 {
        (16, 32, 8, 64)
    } else if params_b <= 3.5 {
        (28, 24, 8, 128)
    } else if params_b <= 8.5 {
        (32, 32, 8, 128)
    } else if params_b <= 15.0 {
        (40, 40, 8, 128)
    } else if params_b <= 34.0 {
        (64, 40, 8, 128)
    } else {
        (80, 64, 8, 128)
    }
}

fn mk(id: u32, name: &str, params_b: f64, tp: u32, class: SizeClass) -> ModelSpec {
    let (l, h, kv, dh) = arch_for(params_b);
    ModelSpec {
        id: ModelId(id),
        name: name.to_string(),
        class,
        params: (params_b * 1e9) as u64,
        n_layers: l,
        n_heads: h,
        n_kv_heads: kv,
        d_head: dh,
        dtype_bytes: 2,
        tp,
    }
}

/// The 58-model Table 3 mix. Names are synthetic but size-faithful: a few
/// popular base models plus many fine-tuned/distilled variants, matching the
/// paper's observation that providers host long tails of low-volume models.
pub fn table3_catalog() -> Vec<ModelSpec> {
    let mut v = Vec::new();
    let mut id = 0;
    let mut push = |v: &mut Vec<ModelSpec>, name: String, p: f64, tp: u32, c: SizeClass| {
        v.push(mk(id, &name, p, tp, c));
        id += 1;
    };

    // 43 models in 1B-3B: fine-tuned/LoRA-merged small agents.
    for i in 0..22 {
        push(&mut v, format!("llama-3.2-1b-ft{i:02}"), 1.2, 1, SizeClass::B1to3);
    }
    for i in 0..13 {
        push(&mut v, format!("qwen-2.5-1.5b-ft{i:02}"), 1.5, 1, SizeClass::B1to3);
    }
    for i in 0..8 {
        push(&mut v, format!("llama-3.2-3b-ft{i:02}"), 3.0, 1, SizeClass::B1to3);
    }
    // 8 models in 4B-8B.
    for i in 0..5 {
        push(&mut v, format!("llama-3.1-8b-ft{i:02}"), 8.0, 1, SizeClass::B4to8);
    }
    for i in 0..3 {
        push(&mut v, format!("qwen-2.5-7b-ft{i:02}"), 7.0, 1, SizeClass::B4to8);
    }
    // 3 models in 9B-30B.
    push(&mut v, "ds-r1-distill-qwen-14b".into(), 14.0, 1, SizeClass::B9to30);
    push(&mut v, "qwen-2.5-14b-inst".into(), 14.0, 1, SizeClass::B9to30);
    push(&mut v, "gemma-2-27b".into(), 27.0, 1, SizeClass::B9to30);
    // 4 models in 31B-70B (TP per the paper: TP=4 for 32B, TP=4/8 for 70B).
    push(&mut v, "qwen-2.5-32b".into(), 32.0, 4, SizeClass::B31to70);
    push(&mut v, "qwq-32b".into(), 32.0, 4, SizeClass::B31to70);
    push(&mut v, "llama-3.3-70b".into(), 70.0, 8, SizeClass::B31to70);
    push(&mut v, "llama-3.1-70b-ft00".into(), 70.0, 4, SizeClass::B31to70);

    assert_eq!(v.len(), 58);
    v
}

/// The PrismNano family actually executed via PJRT (see python/compile/model.py).
pub fn nano_catalog() -> Vec<ModelSpec> {
    vec![
        ModelSpec {
            id: ModelId(1000),
            name: "prism-nano".into(),
            class: SizeClass::Nano,
            params: 100_000,
            n_layers: 2,
            n_heads: 4,
            n_kv_heads: 2,
            d_head: 16,
            dtype_bytes: 4,
            tp: 1,
        },
        ModelSpec {
            id: ModelId(1001),
            name: "prism-micro".into(),
            class: SizeClass::Nano,
            params: 600_000,
            n_layers: 4,
            n_heads: 8,
            n_kv_heads: 4,
            d_head: 16,
            dtype_bytes: 4,
            tp: 1,
        },
    ]
}

/// Subset selector used by experiments: `n` models with the same popularity
/// mix shape as Table 3 (small models dominate).
pub fn catalog_subset(n: usize) -> Vec<ModelSpec> {
    let all = table3_catalog();
    assert!(n <= all.len());
    // Spread over classes: keep ordering stable but take proportionally.
    let mut picked: Vec<ModelSpec> = Vec::new();
    // Always include one large and one mid model when room allows.
    let mut rest: Vec<ModelSpec> = all.clone();
    if n >= 8 {
        // one 70B (TP), one 14B, one 8B first
        for name in ["llama-3.1-70b-ft00", "ds-r1-distill-qwen-14b", "llama-3.1-8b-ft00"] {
            if let Some(pos) = rest.iter().position(|m| m.name == name) {
                picked.push(rest.remove(pos));
            }
        }
    }
    for m in rest {
        if picked.len() >= n {
            break;
        }
        picked.push(m);
    }
    picked.truncate(n);
    picked
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn table3_matches_paper_counts() {
        let cat = table3_catalog();
        assert_eq!(cat.len(), 58);
        let count = |c: SizeClass| cat.iter().filter(|m| m.class == c).count();
        assert_eq!(count(SizeClass::B1to3), 43);
        assert_eq!(count(SizeClass::B4to8), 8);
        assert_eq!(count(SizeClass::B9to30), 3);
        assert_eq!(count(SizeClass::B31to70), 4);
        // Unique ids and names.
        let mut ids: Vec<u32> = cat.iter().map(|m| m.id.0).collect();
        ids.sort_unstable();
        ids.dedup();
        assert_eq!(ids.len(), 58);
    }

    #[test]
    fn weight_sizes_realistic() {
        let cat = table3_catalog();
        let b70 = cat.iter().find(|m| m.name == "llama-3.3-70b").unwrap();
        // ~140 GB fp16, paper SS2.
        assert!((b70.weight_bytes() as f64 / GB as f64 - 130.4).abs() < 5.0);
        assert_eq!(b70.weight_bytes_per_gpu() * 8, b70.weight_bytes());
        let b1 = &cat[0];
        assert!(b1.weight_bytes() < 3 * GB);
    }

    #[test]
    fn kv_token_size_realistic() {
        // Llama-3-8B-like: 32 layers, 8 kv heads, 128 dh, fp16
        let m = mk(0, "x", 8.0, 1, SizeClass::B4to8);
        assert_eq!(m.kv_bytes_per_token(), 32 * 2 * 8 * 128 * 2); // 131072 = 128 KiB/token
        // TP divides per-GPU token size: 8 shards recombine to the full size.
        let t = mk(1, "y", 70.0, 8, SizeClass::B31to70);
        assert_eq!(t.kv_bytes_per_token() * 8, 80 * 2 * 8 * 128 * 2);
    }

    #[test]
    fn subset_includes_variety() {
        let s = catalog_subset(18);
        assert_eq!(s.len(), 18);
        assert!(s.iter().any(|m| m.is_tp()));
        assert!(s.iter().any(|m| m.class == SizeClass::B1to3));
        let s2 = catalog_subset(8);
        assert_eq!(s2.len(), 8);
    }

    #[test]
    fn nano_matches_python_manifest_geometry() {
        let nano = &nano_catalog()[0];
        // Must agree with python/compile/model.py prism-nano: L=2, Hkv=2, Dh=16, f32.
        assert_eq!(nano.kv_bytes_per_token(), 2 * 2 * 2 * 16 * 4);
    }
}
