//! Model architecture catalog (Table 3 mix + PrismNano real-execution family).

pub mod spec;

pub use spec::{ModelId, ModelSpec, SizeClass};
