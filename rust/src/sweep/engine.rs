//! The worker pool: pull points from a shared cursor, write results into
//! point-indexed slots (`std::thread::scope`; no external dependencies).

use std::sync::atomic::{AtomicUsize, Ordering};
use std::sync::Mutex;

use crate::metrics::MetricsSink;

/// Worker count used when the caller passes `jobs = 0`: the `PRISM_JOBS`
/// env var if set to a positive integer, else available parallelism.
/// Delegates to the shared [`crate::util::parallelism`] helper so `--jobs 0`
/// and the simulator's `--shards 0` can never resolve "auto" differently.
pub fn default_jobs() -> usize {
    crate::util::parallelism()
}

/// Resolve a user-facing `--jobs` value: 0 → auto, anything else verbatim.
pub fn resolve_jobs(jobs: usize) -> usize {
    if jobs == 0 { default_jobs() } else { jobs }
}

/// Parse the bench binaries' `--jobs N` / `--jobs=N` flag from raw args
/// (absent → 0 = auto); panics on a missing or unparsable value, which is
/// the appropriate failure mode for a bench harness. CLI code with
/// structured errors (`prism exp`) has its own `Result`-based parser.
pub fn parse_jobs_flag(args: &[String]) -> usize {
    let val = args
        .iter()
        .position(|a| a == "--jobs")
        .map(|i| {
            args.get(i + 1)
                .unwrap_or_else(|| panic!("--jobs requires a value"))
                .clone()
        })
        .or_else(|| {
            args.iter().find_map(|a| a.strip_prefix("--jobs=").map(str::to_string))
        });
    match val {
        // INVARIANT: documented panic — this is the bench/CLI-facing parser
        // and a bad --jobs value must abort with the message below.
        Some(v) => v.parse().expect("--jobs expects a non-negative integer (0 = auto)"),
        None => 0,
    }
}

/// Execute `f` over every point on a scoped worker pool and return results
/// in point order: `result[i] == f(i, &points[i])` regardless of which
/// worker ran it or when it finished (see the module docs for the full
/// determinism contract). With `jobs <= 1` the closure runs in a plain
/// sequential loop on the caller's thread - bit-for-bit the pre-engine
/// behavior. A panicking point propagates out of the scope.
pub fn run_points<P, R, F>(points: &[P], jobs: usize, f: F) -> Vec<R>
where
    P: Sync,
    R: Send,
    F: Fn(usize, &P) -> R + Sync,
{
    let jobs = resolve_jobs(jobs).min(points.len().max(1));
    if jobs <= 1 {
        return points.iter().enumerate().map(|(i, p)| f(i, p)).collect();
    }
    let next = AtomicUsize::new(0);
    let slots: Vec<Mutex<Option<R>>> = (0..points.len()).map(|_| Mutex::new(None)).collect();
    let (f, next, slots_ref) = (&f, &next, &slots);
    std::thread::scope(|s| {
        for _ in 0..jobs {
            s.spawn(move || loop {
                let i = next.fetch_add(1, Ordering::Relaxed);
                if i >= points.len() {
                    break;
                }
                let r = f(i, &points[i]);
                // INVARIANT: a poisoned slot means another worker panicked;
                // propagating the panic is exactly what we want.
                *slots_ref[i].lock().expect("result slot poisoned") = Some(r);
            });
        }
    });
    slots
        .into_iter()
        .map(|m| {
            // INVARIANT: the scope above joined every worker, so each slot
            // was filled exactly once and no lock is poisoned.
            m.into_inner()
                .expect("result slot poisoned")
                .expect("every point produces exactly one result")
        })
        .collect()
}

/// Fold per-point sink results (e.g. `RunMetrics` from worker threads) into
/// one aggregate. Merging happens on the caller's thread, in point order,
/// so sketch/counter aggregation is deterministic. The aggregate is seeded
/// from the first part, so uniform full-dump parts keep their raw records
/// (folding into a `Default` target would silently downgrade them to
/// streaming).
pub fn merge_all<S: MetricsSink + Default>(parts: Vec<S>) -> S {
    let mut it = parts.into_iter();
    let Some(mut out) = it.next() else {
        return S::default();
    };
    for p in it {
        out.merge(p);
    }
    out
}

#[cfg(test)]
mod tests {
    use super::*;
    use std::sync::atomic::AtomicUsize;

    #[test]
    fn results_keyed_to_points_not_completion_order() {
        // Later points finish first (they spin less), yet results line up.
        let points: Vec<usize> = (0..64).collect();
        let out = run_points(&points, 8, |i, &p| {
            assert_eq!(i, p);
            // Reverse-proportional busy work so completion order inverts.
            let spins = (64 - p) * 500;
            let mut acc = 0u64;
            for k in 0..spins {
                acc = acc.wrapping_add(std::hint::black_box(k as u64));
            }
            (p * 2, acc)
        });
        for (i, (r, _)) in out.iter().enumerate() {
            assert_eq!(*r, i * 2);
        }
    }

    #[test]
    fn sequential_and_parallel_identical() {
        let points: Vec<u64> = (0..40).collect();
        let f = |_: usize, &p: &u64| p.wrapping_mul(2654435761) ^ (p << 7);
        let seq = run_points(&points, 1, f);
        for jobs in [2, 4, 8, 64] {
            assert_eq!(seq, run_points(&points, jobs, f), "jobs={jobs}");
        }
        // jobs=0 resolves to auto and must still match.
        assert_eq!(seq, run_points(&points, 0, f));
    }

    #[test]
    fn each_point_runs_exactly_once() {
        let calls = AtomicUsize::new(0);
        let points: Vec<usize> = (0..100).collect();
        let out = run_points(&points, 7, |_, &p| {
            calls.fetch_add(1, Ordering::Relaxed);
            p
        });
        assert_eq!(calls.load(Ordering::Relaxed), 100);
        assert_eq!(out, points);
    }

    #[test]
    fn empty_and_oversubscribed_grids() {
        let none: Vec<u32> = Vec::new();
        assert!(run_points(&none, 8, |_, &p| p).is_empty());
        // More workers than points: pool clamps to the point count.
        let two = [10u32, 20];
        assert_eq!(run_points(&two, 64, |_, &p| p + 1), vec![11, 21]);
    }

    #[test]
    fn merge_all_folds_sinks() {
        use crate::request::Completion;
        let mk = |n: usize| -> Vec<Completion> {
            (0..n)
                .map(|i| Completion {
                    id: crate::request::RequestId(i as u64),
                    model: crate::model::spec::ModelId(0),
                    arrival: 0.0,
                    finish: 1.0,
                    prompt_tokens: 1,
                    output_tokens: 1,
                    ttft: 0.1,
                    tpot: 0.01,
                    ttft_slo: 1.0,
                    tpot_slo: 0.1,
                    dropped: false,
                    preemptions: 0,
                })
                .collect()
        };
        let merged: Vec<Completion> = merge_all(vec![mk(2), mk(3)]);
        assert_eq!(merged.len(), 5);
    }

    #[test]
    fn resolve_jobs_semantics() {
        assert!(resolve_jobs(0) >= 1);
        assert_eq!(resolve_jobs(3), 3);
    }
}
