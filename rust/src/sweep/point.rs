//! Sweep points and grids: the (policy, trace, rate/SLO/GPU scale, seed,
//! fault spec, fleet spec) coordinates of one simulation run, plus a
//! cartesian-product builder.

use crate::cluster::FleetSpec;
use crate::metrics::RunMetrics;
use crate::model::spec::ModelSpec;
use crate::sim::{registry, SimConfig, Simulator};
use crate::trace::Trace;

/// One independent simulation run in an experiment grid. `trace` indexes
/// the experiment's trace list (traces are shared read-only across points);
/// `seed` is carried for labeling/keying - trace generation consumes it
/// before the sweep starts.
#[derive(Debug, Clone, Copy, PartialEq)]
pub struct SweepPoint {
    /// Registry name of the policy (see `sim/policies`): points stay
    /// `Copy` + comparable, and resolve to the policy object only when run.
    pub policy: &'static str,
    pub trace: usize,
    pub n_gpus: u32,
    pub rate_scale: f64,
    pub slo_scale: f64,
    pub seed: u64,
    /// Fault-spec axis (see `crate::fault::resolve`): `None` is a
    /// fault-free run and leaves the point's key unchanged, so pre-existing
    /// grids keep their historical keys byte-for-byte. Resolved to a
    /// `FaultPlan` when the point runs (deterministically - faults are
    /// data, so the `--jobs 1` ≡ `--jobs N` identity holds per point).
    pub faults: Option<&'static str>,
    /// Fleet-spec axis (see `crate::cluster::FleetSpec::parse`, grammar
    /// `4xh100+8xl4`): `None` keeps the uniform cluster sized by `n_gpus`
    /// and leaves the key unchanged. A fleet **overrides the GPU axis** —
    /// its own GPU count is authoritative. Kind profiles are static data,
    /// so the spec fully determines the cluster and the `--jobs 1` ≡
    /// `--jobs N` identity holds per point.
    pub fleet: Option<&'static str>,
    /// Intra-run shard axis (`SimConfig::shards`): `1` — the default —
    /// leaves the config untouched (so the process-wide default set by
    /// `prism exp --shards` still applies) and keeps the point's key
    /// unchanged; any other value overrides the config and stamps a `-shN`
    /// key segment (`0` = auto). Sharded runs keep metric-fingerprint
    /// identity to `shards = 1` (`tests/shard_identity.rs`), but full-dump
    /// f64 means can differ in the last ulp (summation order), so tables
    /// are byte-stable per shard count, not across the axis.
    pub shards: u32,
}

impl SweepPoint {
    /// Stable human-readable key identifying this point, independent of the
    /// run order - result rows are attributed by key, never by completion
    /// order.
    pub fn key(&self) -> String {
        let fault_seg = match self.faults {
            // ','/';' would collide with CSV cells and spec separators.
            Some(spec) => format!("-f{}", spec.replace([',', ';'], "+")),
            None => String::new(),
        };
        let fleet_seg = match self.fleet {
            // Grammar is already CSV-safe; sanitize defensively anyway.
            Some(spec) => format!("-F{}", spec.replace([',', ';'], "+")),
            None => String::new(),
        };
        let shard_seg = if self.shards != 1 {
            format!("-sh{}", self.shards)
        } else {
            // The sequential default keeps historical keys byte-for-byte.
            String::new()
        };
        format!(
            "t{}-g{}-rs{}-ss{}-s{}{}{}{}-{}",
            self.trace,
            self.n_gpus,
            self.rate_scale,
            self.slo_scale,
            self.seed,
            fault_seg,
            fleet_seg,
            shard_seg,
            self.policy
        )
    }

    /// Resolve the point's fleet spec (if any) into the config. Like fault
    /// specs, grid fleet specs are programmatic: an invalid one is a bug in
    /// the experiment definition, surfaced loudly (documented panic). Must
    /// run before [`apply_faults`](Self::apply_faults), which validates
    /// against the effective GPU count.
    fn apply_fleet(&self, cfg: &mut SimConfig) {
        if let Some(spec) = self.fleet {
            let f = FleetSpec::parse(spec)
                .unwrap_or_else(|e| panic!("invalid fleet spec {spec:?}: {e}"));
            *cfg = cfg.clone().fleet(f);
        }
    }

    /// Resolve the point's fault spec (if any) into `cfg.faults`.
    /// Fault specs in grids are programmatic, so an invalid one is a bug in
    /// the experiment definition - surfaced loudly (documented panic), not
    /// folded into a best-effort run.
    fn apply_faults(&self, cfg: &mut SimConfig, trace: &Trace) {
        if let Some(spec) = self.faults {
            // A fleet overrides the GPU axis, so fault GPU indices resolve
            // against the fleet's own count.
            let n_gpus = cfg.fleet.as_ref().map_or(self.n_gpus, |f| f.n_gpus());
            cfg.faults = crate::fault::resolve(spec, n_gpus, trace.duration)
                .unwrap_or_else(|e| panic!("invalid fault spec {spec:?}: {e}"));
        }
    }

    /// Run this point: policy + GPU count + SLO scale from the point, rate
    /// scaling applied to `trace` lazily at the simulator's arrival cursor
    /// (`Simulator::run_scaled` — bit-identical to materializing
    /// `trace.scale_rate(..)`, without the per-point event-vector copy).
    /// Pure: identical inputs give bitwise identical metrics, which is what
    /// makes the parallel sweep safe.
    pub fn run(&self, specs: &[ModelSpec], trace: &Trace) -> RunMetrics {
        let mut cfg = SimConfig::new(self.policy, self.n_gpus);
        cfg.slo_scale = self.slo_scale;
        self.run_with(cfg, specs, trace)
    }

    /// As [`run`](Self::run) but with a caller-tuned `SimConfig` (tau,
    /// sampling, eviction knobs); the point's rate scale is still applied
    /// (lazily, at the arrival cursor).
    pub fn run_with(&self, mut cfg: SimConfig, specs: &[ModelSpec], trace: &Trace) -> RunMetrics {
        self.apply_fleet(&mut cfg);
        self.apply_faults(&mut cfg, trace);
        self.apply_shards(&mut cfg);
        Simulator::new(cfg, specs.to_vec()).run_scaled(trace, self.rate_scale).0
    }

    /// Resolve the point's shard axis into the config. The default (`1`)
    /// leaves the config alone so a process-wide `set_default_shards` (the
    /// `prism exp --shards` path) still applies to grid points.
    fn apply_shards(&self, cfg: &mut SimConfig) {
        if self.shards != 1 {
            *cfg = cfg.clone().shards(self.shards);
        }
    }

    /// Run against a trace the caller has already rate-scaled (shared
    /// read-only across every point of that (trace, rate) pair); only the
    /// point's policy/GPU/SLO coordinates apply. `rate_scale` then merely
    /// labels what the caller applied.
    pub fn run_prescaled(&self, specs: &[ModelSpec], trace: &Trace) -> RunMetrics {
        let mut cfg = SimConfig::new(self.policy, self.n_gpus);
        cfg.slo_scale = self.slo_scale;
        self.apply_fleet(&mut cfg);
        self.apply_faults(&mut cfg, trace);
        self.apply_shards(&mut cfg);
        Simulator::new(cfg, specs.to_vec()).run(trace).0
    }
}

/// Cartesian-product builder over sweep axes. Enumeration order is part of
/// the contract (see module docs in `sweep`): trace → rate scale → SLO
/// scale → GPU count → seed → fault spec → fleet spec → shard count →
/// policy, policies innermost so each table row group compares systems side
/// by side exactly like the hand-rolled loops this replaced. The fault,
/// fleet, and shard axes default to their single inert entry (fault-free,
/// uniform cluster, sequential loop), leaving existing grids unchanged.
#[derive(Debug, Clone)]
pub struct SweepGrid {
    policies: Vec<&'static str>,
    traces: Vec<usize>,
    gpus: Vec<u32>,
    rate_scales: Vec<f64>,
    slo_scales: Vec<f64>,
    seeds: Vec<u64>,
    faults: Vec<Option<&'static str>>,
    fleets: Vec<Option<&'static str>>,
    shards: Vec<u32>,
}

impl Default for SweepGrid {
    fn default() -> Self {
        Self::new()
    }
}

impl SweepGrid {
    /// A single-point grid: every registered policy (sourced from the
    /// global registry, in registration order) over trace 0, 2 GPUs, unit
    /// rate scale, SLO scale 8 (the SS7.2 default), seed 0. Override axes
    /// with the builder methods.
    pub fn new() -> Self {
        SweepGrid {
            policies: registry().names(),
            traces: vec![0],
            gpus: vec![2],
            rate_scales: vec![1.0],
            slo_scales: vec![8.0],
            seeds: vec![0],
            faults: vec![None],
            fleets: vec![None],
            shards: vec![1],
        }
    }

    /// Restrict the policy axis to the given registry names.
    pub fn policies(mut self, ps: &[&'static str]) -> Self {
        self.policies = ps.to_vec();
        self
    }

    /// Sweep over trace indices `0..n` (into the experiment's trace list).
    pub fn traces(mut self, n: usize) -> Self {
        self.traces = (0..n).collect();
        self
    }

    pub fn gpus(mut self, gs: &[u32]) -> Self {
        self.gpus = gs.to_vec();
        self
    }

    pub fn rate_scales(mut self, rs: &[f64]) -> Self {
        self.rate_scales = rs.to_vec();
        self
    }

    pub fn slo_scales(mut self, ss: &[f64]) -> Self {
        self.slo_scales = ss.to_vec();
        self
    }

    /// Seed axis for point labels/keys only: simulation is deterministic
    /// given a trace, and trace generation consumes its seed *before* the
    /// sweep starts - so distinct seeds over the same trace list run
    /// identical simulations. Pair each seed with its own generated trace
    /// (via the `traces` axis) to get actual variance.
    pub fn seeds(mut self, seeds: &[u64]) -> Self {
        self.seeds = seeds.to_vec();
        self
    }

    /// Fault-spec axis (`crate::fault::resolve` grammar, including the
    /// `churn:<seed>` shorthand, which expands against each point's GPU
    /// count and trace duration). Replaces the default fault-free entry;
    /// include `""` (the empty spec) to keep a healthy-cluster column next
    /// to the faulty ones.
    pub fn faults(mut self, fs: &[&'static str]) -> Self {
        self.faults = fs.iter().map(|&f| Some(f)).collect();
        self
    }

    /// Fleet-spec axis (`FleetSpec::parse` grammar, e.g. `4xh100+8xl4`).
    /// Replaces the default uniform-cluster entry; each fleet overrides the
    /// GPU axis for its points (the fleet's own GPU count is authoritative,
    /// and `n_gpus` merely labels the key). Mix heterogeneous and uniform
    /// specs (`2xh100`) to compare fleets at matching key shapes.
    pub fn fleets(mut self, fs: &[&'static str]) -> Self {
        self.fleets = fs.iter().map(|&f| Some(f)).collect();
        self
    }

    /// Intra-run shard axis (`SimConfig::shards` values; `0` = auto).
    /// Replaces the default sequential entry — include `1` to keep the
    /// historical single-threaded loop next to the sharded columns.
    pub fn shards(mut self, ss: &[u32]) -> Self {
        self.shards = ss.to_vec();
        self
    }

    /// Number of points the grid enumerates.
    pub fn len(&self) -> usize {
        self.traces.len()
            * self.rate_scales.len()
            * self.slo_scales.len()
            * self.gpus.len()
            * self.seeds.len()
            * self.faults.len()
            * self.fleets.len()
            * self.shards.len()
            * self.policies.len()
    }

    pub fn is_empty(&self) -> bool {
        self.len() == 0
    }

    /// Enumerate every point in the fixed nesting order (see type docs).
    pub fn points(&self) -> Vec<SweepPoint> {
        let mut out = Vec::with_capacity(self.len());
        for &trace in &self.traces {
            for &rate_scale in &self.rate_scales {
                for &slo_scale in &self.slo_scales {
                    for &n_gpus in &self.gpus {
                        for &seed in &self.seeds {
                            for &faults in &self.faults {
                                for &fleet in &self.fleets {
                                    for &shards in &self.shards {
                                        for &policy in &self.policies {
                                            out.push(SweepPoint {
                                                policy,
                                                trace,
                                                n_gpus,
                                                rate_scale,
                                                slo_scale,
                                                seed,
                                                faults,
                                                fleet,
                                                shards,
                                            });
                                        }
                                    }
                                }
                            }
                        }
                    }
                }
            }
        }
        out
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn grid_enumerates_full_product_in_fixed_order() {
        let g = SweepGrid::new().policies(&["prism", "qlm"]).traces(2).rate_scales(&[1.0, 4.0]);
        assert_eq!(g.len(), 2 * 2 * 2);
        let pts = g.points();
        assert_eq!(pts.len(), 8);
        // Policies innermost, then seeds/gpus/slo (singletons), rate, trace.
        assert_eq!(pts[0].policy, "prism");
        assert_eq!(pts[1].policy, "qlm");
        assert_eq!(pts[0].trace, 0);
        assert_eq!(pts[0].rate_scale, 1.0);
        assert_eq!(pts[2].rate_scale, 4.0);
        assert_eq!(pts[4].trace, 1);
        // Enumeration is deterministic.
        assert_eq!(pts, g.points());
    }

    #[test]
    fn fault_axis_multiplies_grid_and_keys_stay_csv_safe() {
        // Default axis: fault-free points whose keys match the historical
        // format exactly (no `-f` segment).
        let base = SweepGrid::new().policies(&["prism"]);
        let p0 = base.points()[0];
        assert_eq!(p0.faults, None);
        assert!(!p0.key().contains("-f"), "fault-free key changed: {}", p0.key());

        let g = SweepGrid::new().policies(&["prism", "qlm"]).faults(&["", "loadfail@0,1"]);
        assert_eq!(g.len(), 4);
        let pts = g.points();
        // Fault specs nest outside the policy axis.
        assert_eq!((pts[0].faults, pts[0].policy), (Some(""), "prism"));
        assert_eq!((pts[1].faults, pts[1].policy), (Some(""), "qlm"));
        assert_eq!(pts[2].faults, Some("loadfail@0,1"));
        let k = pts[2].key();
        assert!(k.contains("-floadfail@0+1"), "sanitized spec in key: {k}");
        assert!(!k.contains(','), "keys must stay CSV-safe: {k}");
        let mut keys: Vec<String> = pts.iter().map(|p| p.key()).collect();
        keys.sort();
        keys.dedup();
        assert_eq!(keys.len(), 4, "fault axis must keep keys unique");
    }

    #[test]
    fn fleet_axis_multiplies_grid_and_keys_stay_distinct() {
        // Default axis: uniform-cluster points whose keys match the
        // historical format exactly (no `-F` segment).
        let base = SweepGrid::new().policies(&["prism"]);
        let p0 = base.points()[0];
        assert_eq!(p0.fleet, None);
        assert!(!p0.key().contains("-F"), "fleet-free key changed: {}", p0.key());

        let g = SweepGrid::new().policies(&["prism", "melange"]).fleets(&["2xa100", "1xh100+1xl4"]);
        assert_eq!(g.len(), 4);
        let pts = g.points();
        // Fleet specs nest outside the policy axis, inside faults.
        assert_eq!((pts[0].fleet, pts[0].policy), (Some("2xa100"), "prism"));
        assert_eq!((pts[1].fleet, pts[1].policy), (Some("2xa100"), "melange"));
        assert_eq!(pts[2].fleet, Some("1xh100+1xl4"));
        let k = pts[2].key();
        assert!(k.contains("-F1xh100+1xl4"), "fleet spec in key: {k}");
        assert!(!k.contains(','), "keys must stay CSV-safe: {k}");
        let mut keys: Vec<String> = pts.iter().map(|p| p.key()).collect();
        keys.sort();
        keys.dedup();
        assert_eq!(keys.len(), 4, "fleet axis must keep keys unique");
    }

    #[test]
    fn het_fleet_point_runs_and_prices_the_ledger() {
        use crate::experiments::e2e::assign_ids;
        use crate::model::spec::catalog_subset;
        use crate::trace::gen::{generate, TraceGenConfig};
        let g = SweepGrid::new()
            .policies(&["melange"])
            .gpus(&[4]) // overridden by the fleet (2 GPUs); labels the key only
            .slo_scales(&[10.0])
            .fleets(&["1xa100+1xl4"]);
        let pts = g.points();
        assert_eq!(pts.len(), 1);
        let trace = generate(&TraceGenConfig::novita_like(4, 180.0, 11));
        let specs = assign_ids(
            catalog_subset(30).into_iter().filter(|m| !m.is_tp()).take(4).collect(),
        );
        let m = pts[0].run(&specs, &trace);
        assert!(m.total() > 0, "melange het-fleet point produced no completions");
        assert!(m.completed() > 0, "melange het-fleet point finished nothing");
        let want = crate::cluster::FleetSpec::parse("1xa100+1xl4").unwrap().cost_per_hour();
        assert_eq!(m.cost.fleet_cost_per_hour.to_bits(), want.to_bits());
        assert!(m.cost.cost_dollars > 0.0);
    }

    #[test]
    fn shard_axis_multiplies_grid_and_default_keys_unchanged() {
        // Default axis: sequential points whose keys match the historical
        // format exactly (no `-sh` segment).
        let base = SweepGrid::new().policies(&["prism"]);
        let p0 = base.points()[0];
        assert_eq!(p0.shards, 1);
        assert!(!p0.key().contains("-sh"), "shard-free key changed: {}", p0.key());

        let g = SweepGrid::new().policies(&["prism", "qlm"]).shards(&[1, 4]);
        assert_eq!(g.len(), 4);
        let pts = g.points();
        // Shard counts nest outside the policy axis, inside fleets.
        assert_eq!((pts[0].shards, pts[0].policy), (1, "prism"));
        assert_eq!((pts[1].shards, pts[1].policy), (1, "qlm"));
        assert_eq!(pts[2].shards, 4);
        let k = pts[2].key();
        assert!(k.ends_with("-sh4-prism"), "shard segment in key: {k}");
        let mut keys: Vec<String> = pts.iter().map(|p| p.key()).collect();
        keys.sort();
        keys.dedup();
        assert_eq!(keys.len(), 4, "shard axis must keep keys unique");
    }

    #[test]
    fn point_keys_unique_across_grid() {
        let g = SweepGrid::new().traces(2).gpus(&[1, 2, 4]).slo_scales(&[2.0, 8.0]);
        let keys: Vec<String> = g.points().iter().map(|p| p.key()).collect();
        let mut dedup = keys.clone();
        dedup.sort();
        dedup.dedup();
        assert_eq!(dedup.len(), keys.len(), "point keys must be unique");
    }

    #[test]
    fn default_grid_policy_axis_comes_from_the_registry() {
        // One point per registered policy, in registration order — the
        // default list can never drift from the registry.
        let g = SweepGrid::new();
        assert_eq!(g.len(), registry().len());
        let pts = g.points();
        let names: Vec<&str> = pts.iter().map(|p| p.policy).collect();
        assert_eq!(names, registry().names());
        assert!(!g.is_empty());
    }

    #[test]
    fn registry_registered_sixth_policy_runs_in_a_sweep_grid() {
        // The new trait-API policy (seallm) is a first-class sweep citizen:
        // enumerate it through a grid and run its point end to end.
        use crate::experiments::e2e::assign_ids;
        use crate::model::spec::catalog_subset;
        use crate::trace::gen::{generate, TraceGenConfig};
        let g = SweepGrid::new().policies(&["seallm"]).slo_scales(&[10.0]);
        let pts = g.points();
        assert_eq!(pts.len(), 1);
        assert_eq!(pts[0].policy, "seallm");
        let trace = generate(&TraceGenConfig::novita_like(4, 180.0, 11));
        let specs = assign_ids(
            catalog_subset(30).into_iter().filter(|m| !m.is_tp()).take(4).collect(),
        );
        let m = pts[0].run(&specs, &trace);
        assert!(m.total() > 0, "seallm produced no completions");
        assert!(m.completed() > 0, "seallm finished nothing");
    }
}
