//! Parallel sweep engine: policy × trace × scale experiment grids executed
//! on a scoped worker pool (Figs 5, 7-9, Tab 2, and the bench sweeps).
//!
//! Every simulation run is independent and deterministic, so sweeps scale
//! near-linearly with cores. An experiment enumerates its grid as a flat
//! list of [`SweepPoint`]s (or any custom point type) and hands it to
//! [`run_points`]; workers pull points from a shared cursor and write each
//! result into the slot indexed by its point.
//!
//! # Ordering and determinism contract
//!
//! * **Results are keyed to points, not to completion order.** `run_points`
//!   returns `results[i]` for `points[i]`, whatever order the worker pool
//!   finished them in. Callers build tables by iterating `points` in
//!   enumeration order, so output layout never depends on scheduling.
//! * **Point execution must be pure.** The closure may only depend on its
//!   point (and shared read-only inputs like specs/traces); it must not
//!   mutate shared state. The simulator satisfies this: same config + trace
//!   → bitwise-identical `RunMetrics`.
//! * **Consequence:** `--jobs 1` and `--jobs N` produce byte-identical
//!   tables (enforced by the fig5 regression test), and `--jobs 1`
//!   reproduces the historical sequential behavior exactly - the sequential
//!   path literally runs the same closure in a plain loop on the caller's
//!   thread.
//! * **Grid enumeration is fixed**: [`SweepGrid::points`] nests
//!   trace → rate scale → SLO scale → GPU count → seed → fault spec →
//!   fleet spec → policy, matching the hand-rolled loops it replaced, so
//!   tables keep their historical row order (the fault and fleet axes
//!   default to a single inert entry each). The default policy axis is the
//!   registry's registration order (`crate::sim::registry()`), and
//!   policies are keyed by name, so the same determinism contract extends
//!   to any registered `SchedulingPolicy` — policy hooks must be pure
//!   w.r.t. their `PolicyCtx` (see `sim/policies`).
//! * **Faults are data.** A point's fault spec resolves to a
//!   `crate::fault::FaultPlan` before its simulator is constructed; all
//!   randomness (the `churn:<seed>` shorthand) is consumed at resolution
//!   time, never inside the event loop, so faulty points satisfy the same
//!   purity requirement and the `--jobs` identity extends to fault sweeps.
//! * **Fleets are data too.** A point's fleet spec
//!   (`crate::cluster::FleetSpec`, grammar `4xh100+8xl4`) expands to
//!   static per-kind GPU profiles before the simulator is constructed —
//!   kind tables are compile-time constants, never runtime-configured
//!   per-GPU mutation — so heterogeneous points satisfy the same purity
//!   requirement and the `--jobs` identity extends to fleet sweeps
//!   (enforced by the integration fleet-sweep regression test).
//!
//! `jobs = 0` means "auto": the `PRISM_JOBS` env var if set, else
//! `std::thread::available_parallelism()`.

mod engine;
mod point;

pub use engine::{default_jobs, merge_all, parse_jobs_flag, resolve_jobs, run_points};
pub use point::{SweepGrid, SweepPoint};
