//! Algorithm 1: load-aware model placement minimizing the maximum KVPR.
//!
//! Greedy: sort models by descending SLO-weighted token usage rate, place
//! each on the GPU that minimizes the resulting KVPR, migrate only when the
//! improvement over the current GPU exceeds a threshold tau. TP models are
//! decomposed into tp_size parts with 1/tp of the weight and rate each;
//! anti-affinity forces parts of one model onto distinct GPUs (Appendix A.2).

use std::collections::BTreeMap;

use crate::model::spec::ModelId;
use crate::sched::kvpr::ModelDemand;

#[derive(Debug, Clone)]
pub struct PlacementInput {
    pub demand: ModelDemand,
    /// Current GPU indices of this model's shards (empty = not resident).
    pub current: Vec<usize>,
}

#[derive(Debug, Clone, PartialEq)]
pub struct Placement {
    pub model: ModelId,
    /// Target GPU index per shard (len = tp).
    pub gpus: Vec<usize>,
    /// True if this differs from the model's current assignment.
    pub migrated: bool,
}

#[derive(Debug, Clone)]
pub struct PlacementResult {
    pub placements: Vec<Placement>,
    /// Final per-GPU KVPR after assignment.
    pub kvpr: Vec<f64>,
    /// Final per-GPU shared_kv (bytes) after subtracting placed weights.
    pub shared_kv: Vec<f64>,
}

/// Algorithm 1. `gpu_capacity_bytes[i]` is the KV-usable capacity of GPU i
/// (total minus framework reserves). `tau` is the migration threshold on the
/// KVPR improvement.
pub fn place(
    inputs: &[PlacementInput],
    gpu_capacity_bytes: &[f64],
    tau: f64,
) -> PlacementResult {
    let n = gpu_capacity_bytes.len();
    assert!(n > 0);
    // Line 1: sort by w_token_rate descending; TP models are decomposed into
    // tp parts which, sharing identical keys, stay adjacent after sorting.
    #[derive(Clone)]
    struct Part {
        input_idx: usize,
        shard_idx: usize,
        w_rate: f64,     // per-shard SLO-weighted rate
        weight: f64,     // per-shard weight bytes
        current: Option<usize>,
    }
    let mut parts: Vec<Part> = Vec::new();
    for (ii, inp) in inputs.iter().enumerate() {
        let tp = inp.demand.tp.max(1) as usize;
        let w_rate = inp.demand.w_token_rate() / tp as f64;
        for s in 0..tp {
            parts.push(Part {
                input_idx: ii,
                shard_idx: s,
                w_rate,
                weight: inp.demand.weight_bytes_per_gpu as f64,
                current: inp.current.get(s).copied(),
            });
        }
    }
    parts.sort_by(|a, b| {
        // INVARIANT: w_rate is finite (tp >= 1 and demand rates come from
        // finite trace/SLO inputs), so partial_cmp is total.
        b.w_rate
            .partial_cmp(&a.w_rate)
            .unwrap()
            .then(a.input_idx.cmp(&b.input_idx))
            .then(a.shard_idx.cmp(&b.shard_idx))
    });

    // Lines 2-3: initialize GPU state.
    let mut shared_kv: Vec<f64> = gpu_capacity_bytes.to_vec();
    let mut w_rate: Vec<f64> = vec![0.0; n];
    let ratio = |w: f64, s: f64| if s <= 0.0 { f64::INFINITY } else { w / s };

    // Track per-model shard targets for anti-affinity.
    let mut assigned: BTreeMap<usize, Vec<usize>> = BTreeMap::new();

    // Lines 4-11.
    for p in &parts {
        let taken = assigned.entry(p.input_idx).or_default().clone();
        // Find best (and second-best) GPU by resulting KVPR, excluding GPUs
        // already holding a shard of this model (anti-affinity, A.2.2).
        let mut best: Option<(f64, usize)> = None;
        for g in 0..n {
            if taken.contains(&g) {
                continue;
            }
            let r = ratio(w_rate[g] + p.w_rate, shared_kv[g] - p.weight);
            if best.map(|(br, _)| r < br).unwrap_or(true) {
                best = Some((r, g));
            }
        }
        // INVARIANT: callers validate tp <= n, so at least one GPU is not in
        // `taken` and the loop above always sets `best`.
        let (best_r, best_idx) = best.expect("more GPUs than TP degree required");

        // Line 7-8: keep the current GPU unless improvement exceeds tau.
        let target = match p.current {
            Some(cur) if !taken.contains(&cur) => {
                let cur_r = ratio(w_rate[cur] + p.w_rate, shared_kv[cur] - p.weight);
                if cur_r - best_r > tau {
                    best_idx
                } else {
                    cur
                }
            }
            _ => best_idx,
        };

        // Lines 9-11: assign and update state.
        // INVARIANT: the entry() call at the top of this loop iteration
        // created the key if it was missing.
        assigned.get_mut(&p.input_idx).unwrap().push(target);
        w_rate[target] += p.w_rate;
        shared_kv[target] -= p.weight;
    }

    let placements = inputs
        .iter()
        .enumerate()
        .map(|(ii, inp)| {
            let gpus = assigned.remove(&ii).unwrap_or_default();
            let migrated = !inp.current.is_empty() && gpus != inp.current;
            Placement { model: inp.demand.model, gpus, migrated }
        })
        .collect();
    let kvpr: Vec<f64> = (0..n).map(|g| ratio(w_rate[g], shared_kv[g])).collect();
    PlacementResult { placements, kvpr, shared_kv }
}

/// Eviction policy (paper SS6.1): a model is evicted when idle longer than
/// the threshold AND GPU resources are constrained for others.
#[derive(Debug, Clone)]
pub struct EvictionPolicy {
    /// Idle threshold in seconds (Fig 15a: ~45 s is the sweet spot).
    pub idle_threshold: f64,
    /// Free-memory fraction under which a GPU counts as constrained.
    pub pressure_free_frac: f64,
}

impl Default for EvictionPolicy {
    fn default() -> Self {
        EvictionPolicy { idle_threshold: 45.0, pressure_free_frac: 0.05 }
    }
}

impl EvictionPolicy {
    /// Should `model` (idle since `last_active`) be evicted at `now` given
    /// the free fraction of its least-free GPU?
    pub fn should_evict(&self, now: f64, last_active: f64, min_free_frac: f64) -> bool {
        now - last_active > self.idle_threshold && min_free_frac < self.pressure_free_frac
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::model::spec::ModelId;

    fn demand(id: u32, rate: f64, slo: f64, weight_gb: f64, tp: u32) -> ModelDemand {
        ModelDemand {
            model: ModelId(id),
            token_rate: rate,
            token_size: 1e5,
            slo,
            weight_bytes_per_gpu: (weight_gb * 1e9) as u64,
            tp,
        }
    }

    fn caps(n: usize) -> Vec<f64> {
        vec![80e9; n]
    }

    #[test]
    fn high_demand_models_spread_across_gpus() {
        // Two hot models must not be colocated when two GPUs are available.
        let inputs = vec![
            PlacementInput { demand: demand(0, 5000.0, 0.02, 16.0, 1), current: vec![] },
            PlacementInput { demand: demand(1, 5000.0, 0.02, 16.0, 1), current: vec![] },
            PlacementInput { demand: demand(2, 10.0, 0.05, 2.0, 1), current: vec![] },
            PlacementInput { demand: demand(3, 10.0, 0.05, 2.0, 1), current: vec![] },
        ];
        let r = place(&inputs, &caps(2), 0.1);
        assert_ne!(r.placements[0].gpus, r.placements[1].gpus);
        // Low-demand models fill in complementarily - every GPU hosts one hot
        // and one cold model.
        let g0: Vec<_> = r.placements.iter().filter(|p| p.gpus == vec![0]).collect();
        let g1: Vec<_> = r.placements.iter().filter(|p| p.gpus == vec![1]).collect();
        assert_eq!(g0.len(), 2);
        assert_eq!(g1.len(), 2);
    }

    #[test]
    fn migration_threshold_respected() {
        // Model resident on gpu1 with slightly worse KVPR than gpu0: stays.
        let inputs = vec![
            PlacementInput { demand: demand(0, 100.0, 0.05, 4.0, 1), current: vec![1] },
        ];
        let mut capacities = caps(2);
        capacities[1] = 75e9; // gpu1 marginally worse
        let r = place(&inputs, &capacities, 0.5);
        assert_eq!(r.placements[0].gpus, vec![1]);
        assert!(!r.placements[0].migrated);
        // With tau = 0 the better GPU wins.
        let r2 = place(&inputs, &capacities, 0.0);
        assert_eq!(r2.placements[0].gpus, vec![0]);
        assert!(r2.placements[0].migrated);
    }

    #[test]
    fn tp_anti_affinity() {
        let inputs = vec![
            PlacementInput { demand: demand(0, 2000.0, 0.03, 17.5, 4), current: vec![] },
            PlacementInput { demand: demand(1, 500.0, 0.03, 2.0, 1), current: vec![] },
        ];
        let r = place(&inputs, &caps(4), 0.1);
        let mut gpus = r.placements[0].gpus.clone();
        assert_eq!(gpus.len(), 4);
        gpus.sort_unstable();
        gpus.dedup();
        assert_eq!(gpus.len(), 4, "TP shards must land on distinct GPUs");
    }

    #[test]
    fn kvpr_balanced_beats_naive_stacking() {
        // 8 equal models on 4 GPUs -> 2 per GPU, max KVPR near min KVPR.
        let inputs: Vec<PlacementInput> = (0..8)
            .map(|i| PlacementInput { demand: demand(i, 1000.0, 0.03, 8.0, 1), current: vec![] })
            .collect();
        let r = place(&inputs, &caps(4), 0.1);
        let max = r.kvpr.iter().cloned().fold(0.0, f64::max);
        let min = r.kvpr.iter().cloned().fold(f64::INFINITY, f64::min);
        assert!(max / min < 1.25, "kvpr spread too wide: {:?}", r.kvpr);
        for g in 0..4 {
            let cnt = r.placements.iter().filter(|p| p.gpus.contains(&g)).count();
            assert_eq!(cnt, 2);
        }
    }

    #[test]
    fn weights_reduce_shared_kv() {
        let inputs = vec![
            PlacementInput { demand: demand(0, 100.0, 0.05, 40.0, 1), current: vec![] },
        ];
        let r = place(&inputs, &caps(1), 0.1);
        assert!((r.shared_kv[0] - 40e9).abs() < 1e6);
    }

    #[test]
    fn eviction_policy_requires_both_conditions() {
        let p = EvictionPolicy::default();
        // Idle long but no memory pressure -> keep resident (space sharing).
        assert!(!p.should_evict(100.0, 0.0, 0.9));
        // Pressure but recently active -> keep.
        assert!(!p.should_evict(30.0, 0.0, 0.01));
        // Idle + pressure -> evict.
        assert!(p.should_evict(100.0, 0.0, 0.01));
    }
}
