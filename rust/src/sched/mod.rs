//! The memory-centric control plane (paper SS6): KVPR monitoring, global
//! load-aware placement (Algorithm 1), and GPU-local slack-aware request
//! arbitration (Algorithm 2, Moore-Hodgson).

pub mod arbitration;
pub mod kvpr;
pub mod placement;

pub use arbitration::{moore_hodgson, Candidate, Schedule};
pub use kvpr::{kvpr, ModelDemand, RateMonitor};
pub use placement::{place, EvictionPolicy, Placement, PlacementInput, PlacementResult};
