//! Algorithm 2: GPU-local slack-aware request arbitration (Moore-Hodgson).
//!
//! A shared per-GPU queue arbitrates admission across all models resident on
//! the GPU. Given each request's prefill deadline d = arrival + TTFT_SLO and
//! execution estimate e = prompt_len / chunked_prefill_speed, Moore-Hodgson
//! selects a maximum-cardinality subset that can all meet their deadlines
//! when run in EDF order; over-deadline candidates with the longest
//! execution time are deferred (not dropped - they are admitted later or
//! reported late). Optimality follows from the classic 1||sum U_j result
//! [Moore'68, Cheriyan et al.'21].

use crate::request::RequestId;

/// One admission candidate.
#[derive(Debug, Clone)]
pub struct Candidate {
    pub id: RequestId,
    pub arrival: f64,
    /// Prefill deadline = arrival + TTFT SLO.
    pub deadline: f64,
    /// Estimated prefill execution seconds (p_r / c_r).
    pub exec: f64,
}

/// Result of one arbitration round.
#[derive(Debug, Clone, Default)]
pub struct Schedule {
    /// Admitted ids in EDF execution order.
    pub admitted: Vec<RequestId>,
    /// Deferred ids (would cause deadline misses; retried next round).
    pub deferred: Vec<RequestId>,
}

/// Moore-Hodgson over the candidate set, starting execution at `now`.
pub fn moore_hodgson(now: f64, candidates: &[Candidate]) -> Schedule {
    let mut sorted: Vec<&Candidate> = candidates.iter().collect();
    // Line 1: ascending deadlines (EDF), stable tie-break by arrival then id.
    sorted.sort_by(|a, b| {
        // INVARIANT: deadlines are finite by construction (derived from
        // trace timestamps and SLO scales), so partial_cmp is total.
        a.deadline
            .partial_cmp(&b.deadline)
            .unwrap()
            // INVARIANT: arrivals are finite too (same construction).
            .then(a.arrival.partial_cmp(&b.arrival).unwrap())
            .then(a.id.cmp(&b.id))
    });

    // Lines 2-11: greedy insert, evict the longest job on deadline miss.
    // Track (exec, id) of scheduled jobs in a max-heap by exec.
    let mut schedule: Vec<&Candidate> = Vec::new();
    let mut deferred: Vec<RequestId> = Vec::new();
    let mut t = now;
    for c in sorted {
        schedule.push(c);
        t += c.exec;
        if t > c.deadline + 1e-12 {
            // Remove the scheduled job with the longest execution time.
            let (imax, _) = schedule
                .iter()
                .enumerate()
                // INVARIANT: schedule is non-empty (c was just pushed) and
                // finite exec times keep partial_cmp total.
                .max_by(|(_, a), (_, b)| a.exec.partial_cmp(&b.exec).unwrap())
                .unwrap();
            let evicted = schedule.remove(imax);
            t -= evicted.exec;
            deferred.push(evicted.id);
        }
    }
    Schedule {
        admitted: schedule.iter().map(|c| c.id).collect(),
        deferred,
    }
}

/// Convenience: count how many of `candidates` meet their deadline when run
/// in the given order starting at `now` (used by tests and benches).
pub fn on_time_count(now: f64, order: &[RequestId], candidates: &[Candidate]) -> usize {
    let mut t = now;
    let mut ok = 0;
    for id in order {
        // INVARIANT: `order` is a permutation of candidate ids (it came from
        // a Schedule built over the same set).
        let c = candidates.iter().find(|c| c.id == *id).unwrap();
        t += c.exec;
        if t <= c.deadline + 1e-12 {
            ok += 1;
        }
    }
    ok
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::prop::check;
    use crate::util::rng::Rng;

    fn cand(id: u64, deadline: f64, exec: f64) -> Candidate {
        Candidate { id: RequestId(id), arrival: 0.0, deadline, exec }
    }

    #[test]
    fn all_feasible_all_admitted() {
        let cs = vec![cand(1, 1.0, 0.2), cand(2, 2.0, 0.5), cand(3, 3.0, 0.5)];
        let s = moore_hodgson(0.0, &cs);
        assert_eq!(s.admitted.len(), 3);
        assert!(s.deferred.is_empty());
        // EDF order.
        assert_eq!(s.admitted, vec![RequestId(1), RequestId(2), RequestId(3)]);
    }

    #[test]
    fn textbook_example_evicts_longest() {
        // Jobs: (exec, deadline): A(4,5) B(3,6) C(2,7). EDF: A,B,C.
        // After B: t=7 > 6 -> evict A (longest). Final: B,C both on time.
        let cs = vec![cand(1, 5.0, 4.0), cand(2, 6.0, 3.0), cand(3, 7.0, 2.0)];
        let s = moore_hodgson(0.0, &cs);
        assert_eq!(s.deferred, vec![RequestId(1)]);
        assert_eq!(s.admitted, vec![RequestId(2), RequestId(3)]);
        assert_eq!(on_time_count(0.0, &s.admitted, &cs), 2);
    }

    #[test]
    fn respects_start_time() {
        let cs = vec![cand(1, 1.0, 0.9)];
        assert_eq!(moore_hodgson(0.0, &cs).admitted.len(), 1);
        assert_eq!(moore_hodgson(0.5, &cs).admitted.len(), 0);
    }

    #[test]
    fn strict_slo_short_job_preferred_over_long_relaxed() {
        // The Fig 8 scenario: model2's short strict-SLO requests must win
        // over model1's long relaxed ones.
        let cs = vec![
            cand(1, 10.0, 5.0), // long, relaxed
            cand(2, 0.5, 0.2),  // short, strict
            cand(3, 0.8, 0.2),  // short, strict
        ];
        let s = moore_hodgson(0.0, &cs);
        assert!(s.admitted.contains(&RequestId(2)));
        assert!(s.admitted.contains(&RequestId(3)));
    }

    /// Property: Moore-Hodgson admits at least as many on-time jobs as EDF
    /// over the full set, and every admitted job is on time.
    #[test]
    fn prop_admitted_all_on_time_and_beats_edf() {
        check(
            120,
            0xA1B2,
            |r: &mut Rng| {
                let n = r.range_usize(1, 25);
                (0..n)
                    .map(|i| {
                        (
                            i as u64,
                            r.range_f64(0.1, 20.0), // deadline
                            r.range_f64(0.05, 5.0), // exec
                        )
                    })
                    .collect::<Vec<(u64, f64, f64)>>()
            },
            |jobs| {
                let cs: Vec<Candidate> =
                    jobs.iter().map(|&(id, d, e)| cand(id, d, e)).collect();
                let s = moore_hodgson(0.0, &cs);
                // 1. admitted + deferred = all.
                if s.admitted.len() + s.deferred.len() != cs.len() {
                    return Err("partition violated".into());
                }
                // 2. every admitted job is on time in schedule order.
                if on_time_count(0.0, &s.admitted, &cs) != s.admitted.len() {
                    return Err(format!(
                        "admitted set has late jobs: {:?}",
                        s.admitted
                    ));
                }
                // 3. at least as good as plain EDF on the full set.
                let mut edf: Vec<&Candidate> = cs.iter().collect();
                edf.sort_by(|a, b| a.deadline.partial_cmp(&b.deadline).unwrap());
                let edf_ids: Vec<RequestId> = edf.iter().map(|c| c.id).collect();
                let edf_ok = on_time_count(0.0, &edf_ids, &cs);
                if s.admitted.len() < edf_ok {
                    return Err(format!(
                        "MH admitted {} < EDF on-time {}",
                        s.admitted.len(),
                        edf_ok
                    ));
                }
                Ok(())
            },
        );
    }

    /// Property: brute-force optimality for small instances - no subset of
    /// jobs larger than the admitted set can all be on time.
    #[test]
    fn prop_optimal_vs_bruteforce() {
        check(
            80,
            0xC3D4,
            |r: &mut Rng| {
                let n = r.range_usize(1, 9);
                (0..n)
                    .map(|i| (i as u64, r.range_f64(0.1, 4.0), r.range_f64(0.1, 2.0)))
                    .collect::<Vec<(u64, f64, f64)>>()
            },
            |jobs| {
                let cs: Vec<Candidate> =
                    jobs.iter().map(|&(id, d, e)| cand(id, d, e)).collect();
                let s = moore_hodgson(0.0, &cs);
                // Brute force: max feasible subset size (EDF order within a
                // subset is optimal for feasibility).
                let n = cs.len();
                let mut best = 0usize;
                for mask in 0u32..(1 << n) {
                    let mut subset: Vec<&Candidate> = (0..n)
                        .filter(|i| mask & (1 << i) != 0)
                        .map(|i| &cs[i])
                        .collect();
                    subset.sort_by(|a, b| a.deadline.partial_cmp(&b.deadline).unwrap());
                    let mut t = 0.0;
                    let mut feasible = true;
                    for c in &subset {
                        t += c.exec;
                        if t > c.deadline + 1e-12 {
                            feasible = false;
                            break;
                        }
                    }
                    if feasible {
                        best = best.max(subset.len());
                    }
                }
                if s.admitted.len() != best {
                    return Err(format!(
                        "MH={} but optimal={} for {:?}",
                        s.admitted.len(),
                        best,
                        jobs
                    ));
                }
                Ok(())
            },
        );
    }
}
