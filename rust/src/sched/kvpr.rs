//! KV Pressure Ratio (paper SS6.1) and the sliding-window token-rate monitor.
//!
//! KVPR = w_token_rate / shared_kv, where
//!   w_token_rate = token_rate * token_size / SLO  (bytes of KV demand per
//!   second, weighted by TPOT urgency - decoding dominates and is the
//!   memory-sensitive phase), and shared_kv is the memory available for KV
//!   on the GPU. High KVPR = ballooning headroom is likely to be stifled.

use std::collections::VecDeque;

/// Sliding-window token-rate estimator (Fig 15b: ~60 s window is robust).
#[derive(Debug, Clone)]
pub struct RateMonitor {
    window: f64,
    /// (time, tokens) events: input tokens of admitted requests + decode
    /// tokens produced - both drive KV growth (paper SS6.1).
    events: VecDeque<(f64, u64)>,
    total: u64,
}

impl RateMonitor {
    pub fn new(window_seconds: f64) -> Self {
        RateMonitor { window: window_seconds, events: VecDeque::new(), total: 0 }
    }

    pub fn record(&mut self, now: f64, tokens: u64) {
        self.events.push_back((now, tokens));
        self.total += tokens;
        self.expire(now);
    }

    fn expire(&mut self, now: f64) {
        while let Some(&(t, n)) = self.events.front() {
            if now - t > self.window {
                self.events.pop_front();
                self.total -= n;
            } else {
                break;
            }
        }
    }

    /// Drop events older than the window. Periodic housekeeping so queries
    /// between records stay cheap; `rate_at` skips expired events either way.
    pub fn expire_to(&mut self, now: f64) {
        self.expire(now);
    }

    /// Tokens per second over the window ending at `now`, without mutating
    /// state (the simulator's hot path reads rates per event; cloning or
    /// expiring the VecDeque there would be per-GPU x per-model work).
    pub fn rate_at(&self, now: f64) -> f64 {
        let mut total = self.total;
        let mut live_front: Option<f64> = None;
        for &(t, n) in &self.events {
            if now - t > self.window {
                total -= n;
            } else {
                live_front = Some(t);
                break;
            }
        }
        let Some(t0) = live_front else { return 0.0 };
        let span = (now - t0).max(1e-9).min(self.window);
        // Use the configured window once enough history exists: smoother and
        // matches a plain moving average.
        let denom = if now - t0 >= self.window * 0.5 { span } else { self.window * 0.5 };
        total as f64 / denom
    }

    /// Tokens per second over the window ending at `now` (expires as it goes).
    pub fn rate(&mut self, now: f64) -> f64 {
        self.expire(now);
        self.rate_at(now)
    }

    pub fn window_seconds(&self) -> f64 {
        self.window
    }
}

/// Per-model demand snapshot used by the placement algorithm.
#[derive(Debug, Clone)]
pub struct ModelDemand {
    pub model: crate::model::spec::ModelId,
    /// tokens/s over the monitoring window.
    pub token_rate: f64,
    /// bytes of KV per token (the paper's token_size), full model (all shards).
    pub token_size: f64,
    /// TPOT SLO seconds (the urgency weight).
    pub slo: f64,
    /// weight bytes per GPU shard.
    pub weight_bytes_per_gpu: u64,
    pub tp: u32,
}

impl ModelDemand {
    /// The paper's w_token_rate = token_rate * token_size / SLO.
    pub fn w_token_rate(&self) -> f64 {
        self.token_rate * self.token_size / self.slo.max(1e-6)
    }
}

/// KVPR of a GPU state.
pub fn kvpr(w_token_rate_sum: f64, shared_kv_bytes: f64) -> f64 {
    if shared_kv_bytes <= 0.0 {
        return f64::INFINITY;
    }
    w_token_rate_sum / shared_kv_bytes
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::model::spec::ModelId;

    #[test]
    fn rate_monitor_windows_correctly() {
        let mut m = RateMonitor::new(60.0);
        for i in 0..60 {
            m.record(i as f64, 100);
        }
        let r = m.rate(59.0);
        assert!((r - 100.0).abs() < 5.0, "r={r}");
        // Old events expire: after 120 s of silence the rate collapses.
        assert_eq!(m.rate(200.0), 0.0);
    }

    #[test]
    fn rate_at_matches_mutating_rate() {
        let mut a = RateMonitor::new(60.0);
        let mut b = RateMonitor::new(60.0);
        for i in 0..200u64 {
            let t = i as f64 * 0.7;
            a.record(t, (i % 17) * 3);
            b.record(t, (i % 17) * 3);
        }
        // `a` is only read via the non-mutating path; `b` expires as it goes.
        for &now in &[10.0, 80.0, 139.3, 200.0, 400.0] {
            let ra = a.rate_at(now);
            assert_eq!(ra.to_bits(), b.rate(now).to_bits(), "now={now}");
        }
        a.expire_to(400.0);
        assert_eq!(a.rate_at(400.0), 0.0);
    }

    #[test]
    fn rate_monitor_early_estimates_not_inflated() {
        let mut m = RateMonitor::new(60.0);
        m.record(0.0, 3000);
        // One burst at t=0 must not read as 3000 tok/s.
        assert!(m.rate(0.1) <= 3000.0 / 30.0 + 1e-9);
    }

    #[test]
    fn w_token_rate_weights_by_slo() {
        let strict = ModelDemand {
            model: ModelId(0),
            token_rate: 100.0,
            token_size: 1e5,
            slo: 0.01,
            weight_bytes_per_gpu: 0,
            tp: 1,
        };
        let relaxed = ModelDemand { slo: 0.1, ..strict.clone() };
        assert!((strict.w_token_rate() / relaxed.w_token_rate() - 10.0).abs() < 1e-9);
    }

    #[test]
    fn kvpr_edge_cases() {
        assert_eq!(kvpr(10.0, 0.0), f64::INFINITY);
        assert!((kvpr(10.0, 100.0) - 0.1).abs() < 1e-12);
        assert_eq!(kvpr(0.0, 100.0), 0.0);
    }
}
