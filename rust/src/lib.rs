//! Prism: cost-efficient multi-LLM serving via GPU memory ballooning.
//!
//! Reproduction of Yu et al. 2025. Three-layer architecture: this Rust crate
//! is Layer 3 (the coordinator: kvcached balloon driver, KVPR placement,
//! slack-aware arbitration, cluster simulator, real PJRT serving path);
//! Layer 2/1 (JAX model + Pallas kernels) live under python/ and are AOT
//! compiled to HLO-text artifacts that `runtime` loads via PJRT.

// The print lints (Cargo.toml `lints.clippy`) keep stdout/stderr noise out
// of the deterministic core; the modules allowed below are the reporting /
// serving shell, where printing is the job.
#[allow(clippy::print_stdout, clippy::print_stderr)]
pub mod bench;
pub mod util;

pub mod kvcached;
pub mod model;

pub mod cluster;
pub mod engine;
pub mod fault;
pub mod request;

pub mod sched;

pub mod trace;

pub mod lint;
pub mod metrics;
pub mod sim;
pub mod sweep;

#[allow(clippy::print_stdout, clippy::print_stderr)]
pub mod runtime;

#[allow(clippy::print_stdout, clippy::print_stderr)]
pub mod serve;

#[allow(clippy::print_stdout, clippy::print_stderr)]
pub mod experiments;
