//! PJRT execution: compile HLO-text artifacts once, upload weights once as
//! device buffers, then run prefill/decode with per-call data arguments.
//!
//! Static shapes per bucket (CUDA-graph-style): decode is compiled for batch
//! sizes {1,2,4,8} and prefill for a few prompt lengths; the runtime picks
//! the smallest bucket that fits and pads. Padding slots use seq_len=0,
//! which the kernel + merge treat as "attend to nothing".

use std::collections::BTreeMap;
use std::path::Path;

use anyhow::{anyhow, Context, Result};

use crate::runtime::artifact::Manifest;

pub struct ModelRuntime {
    pub manifest: Manifest,
    client: xla::PjRtClient,
    weight_bufs: Vec<xla::PjRtBuffer>,
    prefill: BTreeMap<usize, xla::PjRtLoadedExecutable>, // key: token bucket
    decode: BTreeMap<usize, xla::PjRtLoadedExecutable>,  // key: batch bucket
    /// Wall-clock seconds spent uploading weights (activation cost, SS5.3).
    pub weight_upload_seconds: f64,
}

pub struct PrefillOut {
    /// Logits at the last valid token, [vocab].
    pub logits: Vec<f32>,
    /// KV for the prompt: [T_bucket, L, 2, Hkv, Dh] flattened (only the
    /// first `len` tokens are meaningful).
    pub kv: Vec<f32>,
    pub bucket_tokens: usize,
}

pub struct DecodeOut {
    /// [B_bucket, vocab] flattened.
    pub logits: Vec<f32>,
    /// [B_bucket, L, 2, Hkv, Dh] flattened.
    pub new_kv: Vec<f32>,
    pub bucket_batch: usize,
}

impl ModelRuntime {
    pub fn load(client: &xla::PjRtClient, dir: &Path) -> Result<Self> {
        let manifest = Manifest::load(dir)?;
        let t0 = std::time::Instant::now();
        // Upload weights once (the activation path: host DRAM -> device).
        let weights = manifest.load_weights()?;
        let mut weight_bufs = Vec::with_capacity(weights.len());
        for (w, e) in weights.iter().zip(&manifest.weights) {
            weight_bufs.push(
                client
                    .buffer_from_host_buffer::<f32>(w, &e.shape, None)
                    .with_context(|| format!("uploading {}", e.name))?,
            );
        }
        let weight_upload_seconds = t0.elapsed().as_secs_f64();

        let compile = |file: &str| -> Result<xla::PjRtLoadedExecutable> {
            let proto = xla::HloModuleProto::from_text_file(
                dir.join(file).to_str().ok_or_else(|| anyhow!("bad path"))?,
            )?;
            let comp = xla::XlaComputation::from_proto(&proto);
            Ok(client.compile(&comp)?)
        };
        let mut prefill = BTreeMap::new();
        for b in &manifest.prefill {
            prefill.insert(b.tokens, compile(&b.file)?);
        }
        let mut decode = BTreeMap::new();
        for b in &manifest.decode {
            decode.insert(b.batch, compile(&b.file)?);
        }
        Ok(ModelRuntime {
            manifest,
            client: client.clone(),
            weight_bufs,
            prefill,
            decode,
            weight_upload_seconds,
        })
    }

    pub fn prefill_buckets(&self) -> Vec<usize> {
        self.prefill.keys().copied().collect()
    }

    pub fn decode_buckets(&self) -> Vec<usize> {
        self.decode.keys().copied().collect()
    }

    fn buf_i32(&self, data: &[i32], dims: &[usize]) -> Result<xla::PjRtBuffer> {
        Ok(self.client.buffer_from_host_buffer::<i32>(data, dims, None)?)
    }

    fn buf_f32(&self, data: &[f32], dims: &[usize]) -> Result<xla::PjRtBuffer> {
        Ok(self.client.buffer_from_host_buffer::<f32>(data, dims, None)?)
    }

    /// Run prefill for one prompt (batch 1). Picks the smallest bucket with
    /// tokens >= prompt length (error if the prompt exceeds all buckets).
    pub fn prefill(&self, prompt: &[i32]) -> Result<PrefillOut> {
        let len = prompt.len();
        let (&bucket, exe) = self
            .prefill
            .range(len..)
            .next()
            .ok_or_else(|| anyhow!("prompt of {len} tokens exceeds largest prefill bucket"))?;
        let mut toks = vec![0i32; bucket];
        toks[..len].copy_from_slice(prompt);
        let mut args: Vec<&xla::PjRtBuffer> = self.weight_bufs.iter().collect();
        let tok_buf = self.buf_i32(&toks, &[1, bucket])?;
        let len_buf = self.buf_i32(&[len as i32], &[1])?;
        args.push(&tok_buf);
        args.push(&len_buf);
        let result = exe.execute_b(&args)?[0][0].to_literal_sync()?;
        let (logits, kv) = result.to_tuple2()?;
        Ok(PrefillOut {
            logits: logits.to_vec::<f32>()?,
            kv: kv.to_vec::<f32>()?,
            bucket_tokens: bucket,
        })
    }

    /// Run one decode step for up to `batch` requests. Inputs are padded to
    /// the bucket; padding rows use seq_len 0 and token/pos 0.
    #[allow(clippy::too_many_arguments)]
    pub fn decode(
        &self,
        tokens: &[i32],
        positions: &[i32],
        pool: &[f32],
        block_tables: &[i32], // [b, max_pages] flattened
        seq_lens: &[i32],
    ) -> Result<DecodeOut> {
        let b = tokens.len();
        let (&bucket, exe) = self
            .decode
            .range(b..)
            .next()
            .ok_or_else(|| anyhow!("batch {b} exceeds largest decode bucket"))?;
        let m = &self.manifest;
        assert_eq!(positions.len(), b);
        assert_eq!(seq_lens.len(), b);
        assert_eq!(block_tables.len(), b * m.max_pages);
        assert_eq!(pool.len(), m.pool_pages * m.slot_elems());

        let mut toks = vec![0i32; bucket];
        toks[..b].copy_from_slice(tokens);
        let mut pos = vec![0i32; bucket];
        pos[..b].copy_from_slice(positions);
        let mut bt = vec![0i32; bucket * m.max_pages];
        bt[..b * m.max_pages].copy_from_slice(block_tables);
        let mut lens = vec![0i32; bucket];
        lens[..b].copy_from_slice(seq_lens);

        let pool_dims = [
            m.pool_pages,
            m.page_tokens,
            m.n_layers,
            2,
            m.n_kv_heads,
            m.d_head,
        ];
        let tok_buf = self.buf_i32(&toks, &[bucket])?;
        let pos_buf = self.buf_i32(&pos, &[bucket])?;
        let pool_buf = self.buf_f32(pool, &pool_dims)?;
        let bt_buf = self.buf_i32(&bt, &[bucket, m.max_pages])?;
        let len_buf = self.buf_i32(&lens, &[bucket])?;
        let mut args: Vec<&xla::PjRtBuffer> = self.weight_bufs.iter().collect();
        args.push(&tok_buf);
        args.push(&pos_buf);
        args.push(&pool_buf);
        args.push(&bt_buf);
        args.push(&len_buf);
        let result = exe.execute_b(&args)?[0][0].to_literal_sync()?;
        let (logits, new_kv) = result.to_tuple2()?;
        Ok(DecodeOut {
            logits: logits.to_vec::<f32>()?,
            new_kv: new_kv.to_vec::<f32>()?,
            bucket_batch: bucket,
        })
    }
}

/// Argmax over a logits row (greedy sampling; deterministic serving).
pub fn argmax(row: &[f32]) -> usize {
    let mut best = 0;
    for (i, &v) in row.iter().enumerate() {
        if v > row[best] {
            best = i;
        }
    }
    best
}

#[cfg(test)]
mod tests {
    use super::*;
    use std::path::PathBuf;

    fn nano_dir() -> Option<PathBuf> {
        let d = PathBuf::from(env!("CARGO_MANIFEST_DIR")).join("artifacts/prism-nano");
        d.join("manifest.json").is_file().then_some(d)
    }

    #[test]
    fn prefill_then_decode_roundtrip() {
        let Some(dir) = nano_dir() else {
            eprintln!("skipping: run `make artifacts`");
            return;
        };
        let client = xla::PjRtClient::cpu().unwrap();
        let rt = ModelRuntime::load(&client, &dir).unwrap();
        let m = &rt.manifest;

        // Prefill a 10-token prompt.
        let prompt: Vec<i32> = (1..=10).collect();
        let out = rt.prefill(&prompt).unwrap();
        assert_eq!(out.logits.len(), m.vocab);
        assert!(out.logits.iter().all(|x| x.is_finite()));

        // Scatter prompt KV into a pool (slots 1.. hold the prompt pages).
        let slot_elems = m.slot_elems();
        let tok_elems = m.token_kv_elems();
        let mut pool = vec![0f32; m.pool_pages * slot_elems];
        let n_pages = prompt.len().div_ceil(m.page_tokens);
        let mut bt = vec![0i32; m.max_pages];
        for p in 0..n_pages {
            let slot = p + 1;
            bt[p] = slot as i32;
            let lo_tok = p * m.page_tokens;
            let hi_tok = (lo_tok + m.page_tokens).min(prompt.len());
            for t in lo_tok..hi_tok {
                let src = t * tok_elems..(t + 1) * tok_elems;
                let dst_base = slot * slot_elems + (t - lo_tok) * tok_elems;
                pool[dst_base..dst_base + tok_elems].copy_from_slice(&out.kv[src]);
            }
        }

        // Decode one token; batch of 1 padded into bucket.
        let next = argmax(&out.logits) as i32;
        let dec = rt
            .decode(&[next], &[10], &pool, &bt, &[10])
            .unwrap();
        assert!(dec.bucket_batch >= 1);
        assert_eq!(dec.logits.len(), dec.bucket_batch * m.vocab);
        assert!(dec.logits[..m.vocab].iter().all(|x| x.is_finite()));
        assert_eq!(
            dec.new_kv.len(),
            dec.bucket_batch * m.token_kv_elems()
        );

        // Teacher-forcing check against an 11-token prefill: decoding token
        // `next` at position 10 must equal prefilling [prompt..next].
        let mut prompt2 = prompt.clone();
        prompt2.push(next);
        let out2 = rt.prefill(&prompt2).unwrap();
        let row = &dec.logits[..m.vocab];
        for (a, b) in row.iter().zip(out2.logits.iter()) {
            assert!((a - b).abs() < 2e-3, "decode logits diverge: {a} vs {b}");
        }
    }

    #[test]
    fn decode_bucket_padding_is_inert() {
        let Some(dir) = nano_dir() else {
            return;
        };
        let client = xla::PjRtClient::cpu().unwrap();
        let rt = ModelRuntime::load(&client, &dir).unwrap();
        let m = &rt.manifest;
        let pool = vec![0f32; m.pool_pages * m.slot_elems()];
        let bt = vec![0i32; m.max_pages];
        // seq_len 0: the merge path must still produce finite logits.
        let dec = rt.decode(&[5], &[0], &pool, &bt, &[0]).unwrap();
        assert!(dec.logits[..m.vocab].iter().all(|x| x.is_finite()));
    }
}
