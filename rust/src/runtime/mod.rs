//! PJRT runtime: loads the AOT artifacts (HLO text + weights) produced by
//! `make artifacts` and executes prefill/decode on the PJRT CPU client.
//! Python never runs here - this is the request path.

pub mod artifact;
pub mod exec;

pub use artifact::{Manifest, WeightEntry};
pub use exec::ModelRuntime;
