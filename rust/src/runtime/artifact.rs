//! Artifact manifest + weight blob loading.
//!
//! Layout produced by python/compile/aot.py under artifacts/<model>/:
//!   manifest.json, weights.bin (little-endian f32, manifest order),
//!   prefill_b{B}_t{T}.hlo.txt, decode_b{B}.hlo.txt.

use std::path::{Path, PathBuf};

use anyhow::{anyhow, Context, Result};

use crate::util::json::{parse_file, Json};

#[derive(Debug, Clone)]
pub struct WeightEntry {
    pub name: String,
    pub shape: Vec<usize>,
    pub offset: usize,
    pub bytes: usize,
}

#[derive(Debug, Clone)]
pub struct PrefillBucket {
    pub batch: usize,
    pub tokens: usize,
    pub file: String,
}

#[derive(Debug, Clone)]
pub struct DecodeBucket {
    pub batch: usize,
    pub file: String,
}

#[derive(Debug, Clone)]
pub struct Manifest {
    pub dir: PathBuf,
    pub name: String,
    pub vocab: usize,
    pub d_model: usize,
    pub n_layers: usize,
    pub n_heads: usize,
    pub n_kv_heads: usize,
    pub d_head: usize,
    pub max_seq: usize,
    pub page_tokens: usize,
    pub max_pages: usize,
    pub pool_pages: usize,
    pub kv_bytes_per_token: usize,
    pub weights: Vec<WeightEntry>,
    pub prefill: Vec<PrefillBucket>,
    pub decode: Vec<DecodeBucket>,
}

impl Manifest {
    pub fn load(dir: &Path) -> Result<Self> {
        let j = parse_file(&dir.join("manifest.json"))?;
        let u = |k: &str| -> Result<usize> {
            j.get(k).as_usize().ok_or_else(|| anyhow!("manifest missing {k}"))
        };
        let weights = j
            .get("weights")
            .as_arr()
            .ok_or_else(|| anyhow!("manifest missing weights"))?
            .iter()
            .map(|w| {
                Ok(WeightEntry {
                    name: w.get("name").as_str().unwrap_or_default().to_string(),
                    shape: w
                        .get("shape")
                        .as_arr()
                        .unwrap_or(&[])
                        .iter()
                        .map(|v| v.as_usize().unwrap_or(0))
                        .collect(),
                    offset: w.get("offset").as_usize().ok_or_else(|| anyhow!("offset"))?,
                    bytes: w.get("bytes").as_usize().ok_or_else(|| anyhow!("bytes"))?,
                })
            })
            .collect::<Result<Vec<_>>>()?;
        let parse_buckets = |key: &str| -> Vec<&Json> {
            j.at(&["artifacts", key]).as_arr().map(|a| a.iter().collect()).unwrap_or_default()
        };
        let prefill = parse_buckets("prefill")
            .into_iter()
            .map(|a| PrefillBucket {
                batch: a.get("batch").as_usize().unwrap_or(1),
                tokens: a.get("tokens").as_usize().unwrap_or(0),
                file: a.get("file").as_str().unwrap_or_default().to_string(),
            })
            .collect();
        let decode = parse_buckets("decode")
            .into_iter()
            .map(|a| DecodeBucket {
                batch: a.get("batch").as_usize().unwrap_or(1),
                file: a.get("file").as_str().unwrap_or_default().to_string(),
            })
            .collect();
        Ok(Manifest {
            dir: dir.to_path_buf(),
            name: j.get("name").as_str().unwrap_or_default().to_string(),
            vocab: u("vocab")?,
            d_model: u("d_model")?,
            n_layers: u("n_layers")?,
            n_heads: u("n_heads")?,
            n_kv_heads: u("n_kv_heads")?,
            d_head: u("d_head")?,
            max_seq: u("max_seq")?,
            page_tokens: u("page_tokens")?,
            max_pages: u("max_pages")?,
            pool_pages: u("pool_pages")?,
            kv_bytes_per_token: u("kv_bytes_per_token")?,
            weights,
            prefill,
            decode,
        })
    }

    /// Read weights.bin into per-tensor f32 vectors (manifest order).
    pub fn load_weights(&self) -> Result<Vec<Vec<f32>>> {
        let blob = std::fs::read(self.dir.join("weights.bin"))
            .with_context(|| format!("reading weights for {}", self.name))?;
        let mut out = Vec::with_capacity(self.weights.len());
        for w in &self.weights {
            let lo = w.offset;
            let hi = w.offset + w.bytes;
            if hi > blob.len() {
                return Err(anyhow!("weight {} out of range", w.name));
            }
            let mut v = Vec::with_capacity(w.bytes / 4);
            for chunk in blob[lo..hi].chunks_exact(4) {
                v.push(f32::from_le_bytes(chunk.try_into().unwrap()));
            }
            out.push(v);
        }
        Ok(out)
    }

    /// Elements per pool slot ([Tp, L, 2, Hkv, Dh]) - one kvcached block.
    pub fn slot_elems(&self) -> usize {
        self.page_tokens * self.n_layers * 2 * self.n_kv_heads * self.d_head
    }

    /// Elements of one token's KV across layers ([L, 2, Hkv, Dh]).
    pub fn token_kv_elems(&self) -> usize {
        self.n_layers * 2 * self.n_kv_heads * self.d_head
    }
}

/// Discover all model artifact dirs under the artifacts root.
pub fn discover(root: &Path) -> Vec<PathBuf> {
    let mut out = Vec::new();
    if let Ok(entries) = std::fs::read_dir(root) {
        for e in entries.flatten() {
            let p = e.path();
            if p.is_dir() && p.join("manifest.json").is_file() {
                out.push(p);
            }
        }
    }
    out.sort();
    out
}

#[cfg(test)]
mod tests {
    use super::*;

    fn artifacts_root() -> PathBuf {
        PathBuf::from(env!("CARGO_MANIFEST_DIR")).join("artifacts")
    }

    #[test]
    fn manifest_loads_and_is_consistent() {
        let root = artifacts_root();
        if !root.join("prism-nano").is_dir() {
            eprintln!("skipping: run `make artifacts` first");
            return;
        }
        let m = Manifest::load(&root.join("prism-nano")).unwrap();
        assert_eq!(m.name, "prism-nano");
        assert_eq!(m.n_layers, 2);
        assert_eq!(m.kv_bytes_per_token, m.token_kv_elems() * 4);
        assert!(!m.prefill.is_empty() && !m.decode.is_empty());
        for b in &m.prefill {
            assert!(m.dir.join(&b.file).is_file());
        }
        let w = m.load_weights().unwrap();
        assert_eq!(w.len(), m.weights.len());
        for (v, e) in w.iter().zip(&m.weights) {
            assert_eq!(v.len() * 4, e.bytes);
            assert_eq!(v.len(), e.shape.iter().product::<usize>());
        }
    }

    #[test]
    fn discover_finds_models() {
        let root = artifacts_root();
        if !root.is_dir() {
            return;
        }
        let dirs = discover(&root);
        assert!(dirs.len() >= 2, "expected nano+micro, got {dirs:?}");
    }
}
