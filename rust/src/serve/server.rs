//! Multi-model real server over one PJRT device.
//!
//! Memory: one `Kvcached` instance models the device's physical memory; each
//! model gets an `ElasticTensor` (full virtual pool, physically committed per
//! slot). Ballooning works exactly as in the paper: shrinking one model's
//! limit frees slots another model can map.
//!
//! Scheduling: a shared router queue; admission via Moore-Hodgson on TTFT
//! slack (Algorithm 2); per-model continuous batching with decode priority.
//! The loop is single-threaded over the PJRT client (CPU plugin), but
//! requests are submitted with arrival timestamps so queueing is measured
//! exactly as a threaded frontend would.

use std::collections::BTreeMap;
use std::path::Path;
use std::time::Instant;

use anyhow::{anyhow, Result};

use crate::kvcached::{ElasticTensor, Kvcached, KvError};
use crate::model::spec::ModelId;
use crate::runtime::exec::{argmax, ModelRuntime};
use crate::sched::arbitration::{moore_hodgson, Candidate};
use crate::request::RequestId;

#[derive(Debug, Clone)]
pub struct ServerConfig {
    /// Simulated device memory for kvcached (bytes).
    pub device_bytes: u64,
    /// kvcached page size (bytes); small pages suit nano-scale pools.
    pub page_bytes: u64,
    /// Max decode batch per model per step.
    pub max_batch: usize,
    /// Use slack-aware (Moore-Hodgson) admission; false = FCFS.
    pub slack_aware: bool,
    /// TTFT SLO (s) applied to requests that don't specify one.
    pub default_ttft_slo: f64,
}

impl Default for ServerConfig {
    fn default() -> Self {
        ServerConfig {
            device_bytes: 8 << 20,
            page_bytes: 32 * 1024,
            max_batch: 8,
            slack_aware: true,
            default_ttft_slo: 2.0,
        }
    }
}

#[derive(Debug, Clone)]
pub struct ServeRequest {
    pub model: String,
    pub prompt: Vec<i32>,
    pub max_new_tokens: usize,
    /// Arrival offset (s) relative to serve() start; 0 = immediately.
    pub arrival: f64,
    pub ttft_slo: Option<f64>,
}

#[derive(Debug, Clone)]
pub struct ServeResult {
    pub model: String,
    pub generated: Vec<i32>,
    pub ttft: f64,
    pub tpot: f64,
    pub e2e: f64,
    pub ttft_slo: f64,
    pub preempted: bool,
}

struct Active {
    idx: usize, // index into requests
    slots: Vec<u32>,
    seq_len: usize,
    generated: Vec<i32>,
    first_token_at: f64,
    last_token_at: f64,
    decode_gaps: f64,
}

struct ModelState {
    rt: ModelRuntime,
    et: ElasticTensor,
    model_id: ModelId,
    active: Vec<Active>,
}

pub struct RealServer {
    cfg: ServerConfig,
    kvc: Kvcached,
    models: BTreeMap<String, ModelState>,
}

impl RealServer {
    /// Load models from artifact dirs. `limits` optionally caps each model's
    /// physically mapped slots (the balloon).
    pub fn new(cfg: ServerConfig, dirs: &[&Path], limits: &[u32]) -> Result<Self> {
        let client = xla::PjRtClient::cpu()?;
        let mut kvc = Kvcached::new(cfg.device_bytes, cfg.page_bytes, 4);
        let mut models = BTreeMap::new();
        for (i, dir) in dirs.iter().enumerate() {
            let rt = ModelRuntime::load(&client, dir)?;
            let m = &rt.manifest;
            let model_id = ModelId(2000 + i as u32);
            // Weights "on device": account them in kvcached (D1).
            let weight_bytes: u64 = m.weights.iter().map(|w| w.bytes as u64).sum();
            kvc.load_weights(model_id, weight_bytes)
                .map_err(|e| anyhow!("weights of {} don't fit: {e}", m.name))?;
            let limit = limits.get(i).copied().unwrap_or(u32::MAX);
            let et = ElasticTensor::reserve(
                &mut kvc,
                model_id,
                m.pool_pages as u32,
                m.slot_elems(),
                limit,
            );
            models.insert(m.name.clone(), ModelState { rt, et, model_id, active: Vec::new() });
        }
        Ok(RealServer { cfg, kvc, models })
    }

    pub fn kv_stats(&self) -> crate::kvcached::MemStats {
        self.kvc.stats()
    }

    /// Balloon: change a model's physical slot limit at runtime.
    pub fn set_limit(&mut self, model: &str, limit_slots: u32) -> Result<()> {
        let st = self.models.get(model).ok_or_else(|| anyhow!("unknown model {model}"))?;
        self.kvc.set_kv_limit(st.model_id, limit_slots).map_err(|e| anyhow!("{e}"))?;
        Ok(())
    }

    /// Serve a batch of timestamped requests to completion; returns per-
    /// request results in input order.
    pub fn serve(&mut self, requests: &[ServeRequest]) -> Result<Vec<Option<ServeResult>>> {
        let t0 = Instant::now();
        let mut results: Vec<Option<ServeResult>> = (0..requests.len()).map(|_| None).collect();
        let mut queued: Vec<usize> = Vec::new(); // indices not yet admitted
        let mut not_arrived: Vec<usize> = (0..requests.len()).collect();
        not_arrived
            .sort_by(|&a, &b| requests[a].arrival.partial_cmp(&requests[b].arrival).unwrap());
        not_arrived.reverse(); // pop smallest arrival from the back

        loop {
            let now = t0.elapsed().as_secs_f64();
            // Move arrived requests into the router queue.
            while let Some(&i) = not_arrived.last() {
                if requests[i].arrival <= now {
                    queued.push(i);
                    not_arrived.pop();
                } else {
                    break;
                }
            }

            let any_active = self.models.values().any(|m| !m.active.is_empty());
            if queued.is_empty() && not_arrived.is_empty() && !any_active {
                break;
            }

            // ---- Admission (Algorithm 2 over the shared queue) ----------
            let admit_order: Vec<usize> = if self.cfg.slack_aware {
                let cands: Vec<Candidate> = queued
                    .iter()
                    .map(|&i| {
                        let r = &requests[i];
                        // Execution estimate: measured-prefill proxy of
                        // ~1ms/token on this CPU path.
                        Candidate {
                            id: RequestId(i as u64),
                            arrival: r.arrival,
                            deadline: r.arrival
                                + r.ttft_slo.unwrap_or(self.cfg.default_ttft_slo),
                            exec: r.prompt.len() as f64 * 1e-3,
                        }
                    })
                    .collect();
                let sched = moore_hodgson(now, &cands);
                let mut order: Vec<usize> =
                    sched.admitted.iter().map(|id| id.0 as usize).collect();
                // Deferred requests still get admitted afterwards (no drops).
                order.extend(sched.deferred.iter().map(|id| id.0 as usize));
                order
            } else {
                queued.clone()
            };

            // ---- Prefill admitted heads (one per loop pass) -------------
            let mut admitted_this_round = Vec::new();
            for &i in admit_order.iter() {
                let model_name = requests[i].model.clone();
                let has_room = {
                    let st = self.models.get(&model_name).ok_or_else(|| anyhow!("unknown model"))?;
                    st.active.len() < self.cfg.max_batch
                };
                if !has_room {
                    continue;
                }
                match self.try_prefill(i, requests, t0) {
                    Ok(true) => admitted_this_round.push(i),
                    Ok(false) => {} // out of memory: stays queued
                    Err(e) => return Err(e),
                }
                // One prefill per pass keeps decode latency bounded
                // (chunked-prefill spirit).
                if !admitted_this_round.is_empty() {
                    break;
                }
            }
            queued.retain(|i| !admitted_this_round.contains(i));

            // ---- One decode step per model with active requests ---------
            let names: Vec<String> = self.models.keys().cloned().collect();
            for name in names {
                self.decode_step(&name, requests, &mut results, t0)?;
            }

            // Nothing active and nothing admissible: spin-wait for arrivals.
            if !self.models.values().any(|m| !m.active.is_empty())
                && queued.iter().all(|&i| {
                    self.models
                        .get(&requests[i].model)
                        .map(|m| m.active.len() >= self.cfg.max_batch)
                        .unwrap_or(true)
                })
                && !not_arrived.is_empty()
            {
                std::thread::sleep(std::time::Duration::from_millis(1));
            }
        }
        Ok(results)
    }

    /// Attempt to prefill request `i`; false if KV memory is unavailable.
    fn try_prefill(
        &mut self,
        i: usize,
        requests: &[ServeRequest],
        t0: Instant,
    ) -> Result<bool> {
        let r = &requests[i];
        let (out, pages_needed, tok_elems, page_tokens) = {
            let st = self.models.get(&r.model).ok_or_else(|| anyhow!("unknown model"))?;
            let m = &st.rt.manifest;
            if r.prompt.len() + r.max_new_tokens > m.max_seq {
                return Err(anyhow!("request exceeds max_seq"));
            }
            let total = r.prompt.len() + r.max_new_tokens;
            (
                st.rt.prefill(&r.prompt)?,
                total.div_ceil(m.page_tokens),
                m.token_kv_elems(),
                m.page_tokens,
            )
        };
        // Commit pool slots for the full request span (prompt + generation)
        // in one batched, atomic kvcached call.
        let st = self.models.get_mut(&r.model).unwrap();
        let mut slots = Vec::with_capacity(pages_needed);
        match st.et.alloc_slots(&mut self.kvc, pages_needed, &mut slots) {
            Ok(()) => {}
            Err(KvError::OutOfPages(_)) | Err(KvError::LimitReached { .. }) => {
                return Ok(false); // out of memory: stays queued
            }
            Err(e) => return Err(anyhow!("{e}")),
        }
        // Scatter prompt KV into the committed slots.
        for t in 0..r.prompt.len() {
            let page = t / page_tokens;
            let within = t % page_tokens;
            let kv_row = &out.kv[t * tok_elems..(t + 1) * tok_elems];
            st.et.write_token(slots[page], within, page_tokens, kv_row);
        }
        let now = t0.elapsed().as_secs_f64();
        let first = argmax(&out.logits) as i32;
        st.active.push(Active {
            idx: i,
            slots,
            seq_len: r.prompt.len(),
            generated: vec![first],
            first_token_at: now,
            last_token_at: now,
            decode_gaps: 0.0,
        });
        Ok(true)
    }

    /// One batched decode step for `model`.
    fn decode_step(
        &mut self,
        model: &str,
        requests: &[ServeRequest],
        results: &mut [Option<ServeResult>],
        t0: Instant,
    ) -> Result<()> {
        let st = self.models.get_mut(model).unwrap();
        if st.active.is_empty() {
            return Ok(());
        }
        let m = &st.rt.manifest;
        let b = st.active.len().min(self.cfg.max_batch);
        let tok_elems = m.token_kv_elems();
        let page_tokens = m.page_tokens;
        let max_pages = m.max_pages;

        let mut tokens = Vec::with_capacity(b);
        let mut positions = Vec::with_capacity(b);
        let mut bts = vec![0i32; b * max_pages];
        let mut lens = Vec::with_capacity(b);
        for (j, a) in st.active.iter().take(b).enumerate() {
            tokens.push(*a.generated.last().unwrap());
            positions.push(a.seq_len as i32);
            for (p, &slot) in a.slots.iter().enumerate() {
                bts[j * max_pages + p] = slot as i32;
            }
            lens.push(a.seq_len as i32);
        }
        let dec = st.rt.decode(&tokens, &positions, st.et.as_slice(), &bts, &lens)?;
        let now = t0.elapsed().as_secs_f64();
        let vocab = m.vocab;

        // Write each request's new token KV and append the sampled token.
        let mut finished: Vec<usize> = Vec::new();
        for j in 0..b {
            let a = &mut st.active[j];
            let kv_row = &dec.new_kv[j * tok_elems..(j + 1) * tok_elems];
            let page = a.seq_len / page_tokens;
            let within = a.seq_len % page_tokens;
            st.et.write_token(a.slots[page], within, page_tokens, kv_row);
            a.seq_len += 1;
            a.decode_gaps += now - a.last_token_at;
            a.last_token_at = now;
            let next = argmax(&dec.logits[j * vocab..(j + 1) * vocab]) as i32;
            a.generated.push(next);
            if a.generated.len() >= requests[a.idx].max_new_tokens {
                finished.push(j);
            }
        }
        for j in finished.into_iter().rev() {
            let a = st.active.remove(j);
            let r = &requests[a.idx];
            for s in &a.slots {
                st.et.free_slot(&mut self.kvc, *s).ok();
            }
            let n_gaps = (a.generated.len().saturating_sub(1)).max(1);
            results[a.idx] = Some(ServeResult {
                model: model.to_string(),
                generated: a.generated,
                ttft: a.first_token_at - r.arrival,
                tpot: a.decode_gaps / n_gaps as f64,
                e2e: now - r.arrival,
                ttft_slo: r.ttft_slo.unwrap_or(self.cfg.default_ttft_slo),
                preempted: false,
            });
        }
        Ok(())
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use std::path::PathBuf;

    fn dirs() -> Option<(PathBuf, PathBuf)> {
        let root = PathBuf::from(env!("CARGO_MANIFEST_DIR")).join("artifacts");
        let a = root.join("prism-nano");
        let b = root.join("prism-micro");
        (a.join("manifest.json").is_file() && b.join("manifest.json").is_file())
            .then_some((a, b))
    }

    #[test]
    fn serves_two_models_end_to_end() {
        let Some((a, b)) = dirs() else {
            eprintln!("skipping: run `make artifacts`");
            return;
        };
        let mut srv = RealServer::new(
            ServerConfig::default(),
            &[a.as_path(), b.as_path()],
            &[u32::MAX, u32::MAX],
        )
        .unwrap();
        let reqs: Vec<ServeRequest> = (0..6)
            .map(|i| ServeRequest {
                model: if i % 2 == 0 { "prism-nano" } else { "prism-micro" }.into(),
                prompt: (1..=(8 + i as i32)).collect(),
                max_new_tokens: 6,
                arrival: 0.0,
                ttft_slo: None,
            })
            .collect();
        let results = srv.serve(&reqs).unwrap();
        for (i, r) in results.iter().enumerate() {
            let r = r.as_ref().unwrap_or_else(|| panic!("request {i} unfinished"));
            assert_eq!(r.generated.len(), 6);
            assert!(r.ttft >= 0.0 && r.e2e >= r.ttft);
        }
        // All KV returned.
        let st = srv.kv_stats();
        assert_eq!(st.kv_used_bytes, 0, "leaked KV: {st:?}");
    }

    #[test]
    fn greedy_decode_is_deterministic() {
        let Some((a, _)) = dirs() else {
            return;
        };
        let run = || {
            let mut srv = RealServer::new(
                ServerConfig::default(),
                &[a.as_path()],
                &[u32::MAX],
            )
            .unwrap();
            let reqs = vec![ServeRequest {
                model: "prism-nano".into(),
                prompt: vec![3, 1, 4, 1, 5, 9, 2, 6],
                max_new_tokens: 8,
                arrival: 0.0,
                ttft_slo: None,
            }];
            srv.serve(&reqs).unwrap()[0].as_ref().unwrap().generated.clone()
        };
        assert_eq!(run(), run());
    }

    #[test]
    fn balloon_limit_gates_admission_then_release_unblocks() {
        let Some((a, _)) = dirs() else {
            return;
        };
        // Tiny limit: 1 slot - a request needing 2 pages cannot start.
        let mut srv =
            RealServer::new(ServerConfig::default(), &[a.as_path()], &[1]).unwrap();
        let reqs = vec![ServeRequest {
            model: "prism-nano".into(),
            prompt: (1..=20).collect(), // 20 tokens + 4 new > 1 page (16 tok)
            max_new_tokens: 4,
            arrival: 0.0,
            ttft_slo: Some(0.05),
        }];
        // Raise the limit from another "tenant" after a moment - here we just
        // pre-raise and check both paths work.
        srv.set_limit("prism-nano", 8).unwrap();
        let results = srv.serve(&reqs).unwrap();
        assert!(results[0].is_some());
    }
}
