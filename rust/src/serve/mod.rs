//! The real serving path: PrismNano models served through PJRT with
//! kvcached-governed paged KV, a shared router queue, slack-aware admission,
//! and continuous batched decode. This is the end-to-end proof that the
//! three layers compose (DESIGN.md SS6); the cluster-scale experiments run
//! on the simulator instead.

pub mod server;

pub use server::{RealServer, ServeRequest, ServeResult, ServerConfig};
