//! Streaming quantile sketch (DDSketch-style log-spaced buckets).
//!
//! `RunMetrics`' streaming sink keeps one sketch per latency kind (TTFT,
//! TPOT, end-to-end) globally and per model, so hour-long 100-model sweep
//! points no longer hold every `Completion` in memory. Properties:
//!
//! * **Bounded relative error**: bucket boundaries grow geometrically by
//!   `GAMMA = 1.01`, so any quantile estimate is within ~0.5% relative
//!   error of the exact sample quantile (well inside the 1% budget the
//!   regression test enforces).
//! * **Order-independent and mergeable**: buckets hold integer counts, so
//!   insertion order never changes the result and merging two sketches is
//!   exact bucket-wise addition - the property the parallel sweep engine
//!   relies on for run-order-independent aggregation.
//! * **Sparse**: buckets live in a `BTreeMap`, so memory is proportional to
//!   the number of *distinct* latency scales observed (typically a few
//!   hundred entries), not the full index range.

use std::collections::BTreeMap;

/// Smallest resolvable sample (1 µs); everything at or below lands in
/// bucket 0 and is reported via the tracked minimum.
const LO: f64 = 1e-6;
/// Geometric bucket growth; relative error is ~(GAMMA - 1) / 2.
const GAMMA: f64 = 1.01;
/// Bucket index cap: LO * GAMMA^MAX_BUCKET ≈ 5e11 s, far beyond any latency.
const MAX_BUCKET: u32 = 4096;

/// Fixed-memory quantile sketch over non-negative f64 samples.
#[derive(Debug, Clone, Default, PartialEq)]
pub struct QuantileSketch {
    counts: BTreeMap<u32, u64>,
    count: u64,
    sum: f64,
    min: f64,
    max: f64,
}

impl QuantileSketch {
    /// Absorb one sample. Non-finite samples are ignored (dropped requests
    /// carry infinite latencies and are tracked by counters instead).
    pub fn add(&mut self, x: f64) {
        if !x.is_finite() {
            return;
        }
        if self.count == 0 {
            self.min = x;
            self.max = x;
        } else {
            self.min = self.min.min(x);
            self.max = self.max.max(x);
        }
        self.count += 1;
        self.sum += x;
        *self.counts.entry(bucket_of(x)).or_insert(0) += 1;
    }

    /// Exact bucket-wise merge: `a.merge(&b)` is equivalent to replaying
    /// all of `b`'s samples into `a`, in any order.
    pub fn merge(&mut self, other: &QuantileSketch) {
        if other.count == 0 {
            return;
        }
        if self.count == 0 {
            *self = other.clone();
            return;
        }
        self.min = self.min.min(other.min);
        self.max = self.max.max(other.max);
        self.count += other.count;
        self.sum += other.sum;
        for (&b, &c) in &other.counts {
            *self.counts.entry(b).or_insert(0) += c;
        }
    }

    pub fn count(&self) -> u64 {
        self.count
    }

    pub fn is_empty(&self) -> bool {
        self.count == 0
    }

    /// Mean of all absorbed samples (exact; 0.0 when empty).
    pub fn mean(&self) -> f64 {
        if self.count == 0 {
            0.0
        } else {
            self.sum / self.count as f64
        }
    }

    pub fn min(&self) -> f64 {
        if self.count == 0 { 0.0 } else { self.min }
    }

    pub fn max(&self) -> f64 {
        if self.count == 0 { 0.0 } else { self.max }
    }

    /// Quantile estimate for `pct` in [0, 100], using the same
    /// `(pct/100)·(n-1)` rank convention *and* linear interpolation between
    /// adjacent order statistics as `util::stats::percentile_sorted`, so
    /// streaming and full-dump modes agree up to bucket resolution. The
    /// interpolation weights match the exact formula's, so the ≤0.5%
    /// per-endpoint bucket error bounds the relative error of the result.
    pub fn quantile(&self, pct: f64) -> f64 {
        if self.count == 0 {
            return 0.0;
        }
        let rank = (pct / 100.0).clamp(0.0, 1.0) * (self.count - 1) as f64;
        let lo = rank.floor() as u64;
        let hi = rank.ceil() as u64;
        let v_lo = self.value_at(lo);
        if lo == hi {
            return v_lo;
        }
        let w = rank - lo as f64;
        v_lo * (1.0 - w) + self.value_at(hi) * w
    }

    /// Representative value of the 0-based `k`-th order statistic.
    fn value_at(&self, k: u64) -> f64 {
        let mut cum = 0u64;
        for (&b, &c) in &self.counts {
            cum += c;
            if cum > k {
                return value_of(b, self.min).clamp(self.min, self.max);
            }
        }
        self.max
    }
}

fn bucket_of(x: f64) -> u32 {
    if x <= LO {
        return 0;
    }
    let idx = ((x / LO).ln() / GAMMA.ln()).ceil();
    (idx as u32).clamp(1, MAX_BUCKET)
}

/// Representative value for a bucket: the geometric midpoint of its bounds.
fn value_of(b: u32, min: f64) -> f64 {
    if b == 0 {
        // Bucket 0 holds everything at or below LO; the global minimum is
        // the best available representative.
        return min.min(LO);
    }
    LO * GAMMA.powf(b as f64 - 0.5)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::rng::Rng;
    use crate::util::stats::percentile;

    #[test]
    fn empty_sketch_is_zero() {
        let s = QuantileSketch::default();
        assert_eq!(s.count(), 0);
        assert!(s.is_empty());
        assert_eq!(s.mean(), 0.0);
        assert_eq!(s.quantile(95.0), 0.0);
        assert_eq!(s.min(), 0.0);
        assert_eq!(s.max(), 0.0);
    }

    #[test]
    fn single_sample_exact() {
        let mut s = QuantileSketch::default();
        s.add(0.25);
        assert_eq!(s.count(), 1);
        assert!((s.mean() - 0.25).abs() < 1e-12);
        // Clamped to [min, max] = [0.25, 0.25]: exact.
        assert!((s.quantile(0.0) - 0.25).abs() < 1e-12);
        assert!((s.quantile(100.0) - 0.25).abs() < 1e-12);
    }

    #[test]
    fn ignores_non_finite() {
        let mut s = QuantileSketch::default();
        s.add(f64::INFINITY);
        s.add(f64::NAN);
        s.add(1.0);
        assert_eq!(s.count(), 1);
        assert!((s.quantile(50.0) - 1.0).abs() < 1e-12);
    }

    /// The satellite regression test: p95/p99 within 1% relative error of
    /// the exact percentile on a 100k-sample latency trace.
    #[test]
    fn accuracy_within_one_percent_on_100k_samples() {
        let mut rng = Rng::new(42);
        let mut s = QuantileSketch::default();
        let mut exact: Vec<f64> = Vec::with_capacity(100_000);
        for _ in 0..100_000 {
            // Exponential latencies around 0.8 s with a heavy-ish tail, the
            // shape TTFT distributions take under queueing.
            let x = 0.05 + rng.exp(1.25);
            s.add(x);
            exact.push(x);
        }
        assert_eq!(s.count(), 100_000);
        for pct in [50.0, 95.0, 99.0] {
            let e = percentile(&exact, pct);
            let q = s.quantile(pct);
            let rel = (q - e).abs() / e;
            assert!(rel < 0.01, "p{pct}: sketch {q} vs exact {e} (rel err {rel})");
        }
        assert!((s.mean() - exact.iter().sum::<f64>() / 1e5).abs() < 1e-9);
    }

    #[test]
    fn merge_matches_single_stream() {
        let mut rng = Rng::new(7);
        let (mut a, mut b, mut whole) =
            (QuantileSketch::default(), QuantileSketch::default(), QuantileSketch::default());
        for i in 0..20_000 {
            let x = rng.exp(2.0);
            whole.add(x);
            if i % 2 == 0 { a.add(x) } else { b.add(x) }
        }
        a.merge(&b);
        // Counts, extrema, and therefore every quantile are exactly
        // order-independent; the mean differs only by float summation order.
        assert_eq!(a.count(), whole.count());
        assert_eq!(a.min().to_bits(), whole.min().to_bits());
        assert_eq!(a.max().to_bits(), whole.max().to_bits());
        for pct in [0.0, 10.0, 50.0, 90.0, 95.0, 99.0, 100.0] {
            assert_eq!(
                a.quantile(pct).to_bits(),
                whole.quantile(pct).to_bits(),
                "p{pct} must be bitwise order-independent"
            );
        }
        assert!((a.mean() - whole.mean()).abs() < 1e-9);
        // Merging into an empty sketch copies; merging an empty is a no-op.
        let mut empty = QuantileSketch::default();
        empty.merge(&whole);
        assert_eq!(empty, whole);
        whole.merge(&QuantileSketch::default());
        assert_eq!(empty, whole);
    }

    /// Small-n regression: percentile_sorted interpolates rank 1.9 of
    /// {0.1, 0.2, 0.6} to 0.56; the sketch must do the same, not return the
    /// 2nd order statistic (~0.2).
    #[test]
    fn interpolates_between_order_statistics() {
        let mut s = QuantileSketch::default();
        for x in [0.1, 0.6, 0.2] {
            s.add(x);
        }
        let q = s.quantile(95.0);
        assert!((q - 0.56).abs() < 0.01, "p95 {q} (want ~0.56)");
        assert!((s.quantile(50.0) - 0.2).abs() < 0.003);
    }

    #[test]
    fn zero_and_tiny_samples_land_in_bucket_zero() {
        let mut s = QuantileSketch::default();
        s.add(0.0);
        s.add(1e-9);
        assert_eq!(s.count(), 2);
        // Estimates clamp into [min, max] = [0, 1e-9].
        assert!(s.quantile(100.0) <= 1e-9);
    }
}
