//! Metrics: SLO attainment, latency summaries, throughput (idle-excluded),
//! and sampled timelines for the memory/queue plots (Figs 2, 6, 7, 8).
//!
//! # Sinks
//!
//! Completion records flow through the [`MetricsSink`] trait. The default
//! [`RunMetrics`] sink is *streaming*: it folds every `Completion` into
//! counters plus per-model and global [`QuantileSketch`]es, so hour-long
//! 100-model sweep points hold O(models) state instead of every completion.
//! Tests and figures that need exact percentiles opt into the full-dump
//! sink (`RunMetrics::full()`, or `SimConfig::metrics_full_dump`), which
//! additionally retains the raw `Vec<Completion>` and serves percentile
//! queries from exact sorted views.
//!
//! # Thread-safety audit of the lazy percentile cache
//!
//! `invalidate_latency_cache` takes `&self` through a `RefCell`. That is
//! safe against the "sink written from a worker thread while another thread
//! queries percentiles" hazard *by construction*: `RefCell` makes
//! `RunMetrics` `!Sync`, so the compiler rejects sharing one instance
//! across threads. The sweep engine therefore gives every worker its own
//! `RunMetrics` and folds them on one thread via [`RunMetrics::merge`],
//! which invalidates the cache unconditionally (growth-based staleness
//! detection alone would miss a merge that only updates sketches). The
//! remaining same-length in-place edit window applies only to single-thread
//! full-dump mutation through `completions_mut`, which is documented to
//! require the explicit invalidation call.

pub mod sketch;

use std::cell::RefCell;

pub use sketch::QuantileSketch;

use crate::model::spec::ModelId;
use crate::request::Completion;
use crate::util::stats::percentile_sorted;

/// Destination for finished (or dropped) request records.
///
/// Defines the record/merge contract shared by [`RunMetrics`] (what the
/// simulator feeds) and the raw `Vec<Completion>` dump, and what
/// `sweep::merge_all` folds over. Implementations must be order-insensitive
/// up to their documented precision so the parallel sweep engine can merge
/// per-point results deterministically. (The simulator itself is wired to
/// `RunMetrics` concretely; making it generic over this trait is future
/// work, not a current extension point.)
pub trait MetricsSink {
    /// Absorb one completion record.
    fn record(&mut self, c: Completion);
    /// Fold another sink of the same type into `self`.
    fn merge(&mut self, other: Self)
    where
        Self: Sized;
}

/// The trivially-exact full-dump primitive: keep everything.
impl MetricsSink for Vec<Completion> {
    fn record(&mut self, c: Completion) {
        self.push(c);
    }

    fn merge(&mut self, other: Self) {
        self.extend(other);
    }
}

/// Per-model streaming statistics: counters + p50/p95/p99-capable sketches.
#[derive(Debug, Clone, Default)]
pub struct ModelStats {
    pub total: u64,
    pub dropped: u64,
    pub ttft_ok: u64,
    pub tpot_ok: u64,
    pub ttft: QuantileSketch,
    pub tpot: QuantileSketch,
    pub e2e: QuantileSketch,
}

impl ModelStats {
    fn record(&mut self, c: &Completion) {
        self.total += 1;
        if c.dropped {
            self.dropped += 1;
        }
        if c.ttft_ok() {
            self.ttft_ok += 1;
        }
        if c.tpot_ok() {
            self.tpot_ok += 1;
        }
        self.ttft.add(c.ttft);
        self.tpot.add(c.tpot);
        if c.finish.is_finite() {
            self.e2e.add(c.finish - c.arrival);
        }
    }

    fn merge(&mut self, other: &ModelStats) {
        self.total += other.total;
        self.dropped += other.dropped;
        self.ttft_ok += other.ttft_ok;
        self.tpot_ok += other.tpot_ok;
        self.ttft.merge(&other.ttft);
        self.tpot.merge(&other.tpot);
        self.e2e.merge(&other.e2e);
    }

    pub fn ttft_attainment(&self) -> f64 {
        if self.total == 0 {
            1.0
        } else {
            self.ttft_ok as f64 / self.total as f64
        }
    }

    pub fn tpot_attainment(&self) -> f64 {
        if self.total == 0 {
            1.0
        } else {
            self.tpot_ok as f64 / self.total as f64
        }
    }
}

/// Fault-injection and recovery accounting (all zero on fault-free runs).
///
/// Populated by the simulator from the run's `FaultPlan`: crash/recovery
/// event counts, how crashed GPUs' in-flight requests were handled
/// (restarted elsewhere vs dropped), load retry/failure totals, injected
/// transient allocation faults, and how long evicted models took to regain
/// residency after a crash. Merging (sweep aggregation) is plain addition,
/// so fault counters stay order-independent like every other counter.
#[derive(Debug, Clone, Default, PartialEq)]
pub struct FaultStats {
    /// GPU crash events applied.
    pub gpu_crashes: u64,
    /// GPU recovery events applied.
    pub gpu_recoveries: u64,
    /// In-flight requests re-queued for a fresh prefill after a crash.
    pub requests_restarted: u64,
    /// In-flight requests dropped by a crash (plan `on_crash = Drop`).
    pub requests_dropped: u64,
    /// Model-load attempts that failed and were retried with backoff.
    pub load_retries: u64,
    /// Model loads that exhausted their retry budget.
    pub load_failures: u64,
    /// Transient KV-allocation faults injected.
    pub alloc_faults_injected: u64,
    /// Crash-evicted models that regained residency.
    pub models_recovered: u64,
    /// Total crash-to-reresidency time across recovered models.
    pub recovery_seconds: f64,
}

impl FaultStats {
    fn merge(&mut self, other: &FaultStats) {
        self.gpu_crashes += other.gpu_crashes;
        self.gpu_recoveries += other.gpu_recoveries;
        self.requests_restarted += other.requests_restarted;
        self.requests_dropped += other.requests_dropped;
        self.load_retries += other.load_retries;
        self.load_failures += other.load_failures;
        self.alloc_faults_injected += other.alloc_faults_injected;
        self.models_recovered += other.models_recovered;
        self.recovery_seconds += other.recovery_seconds;
    }

    /// True when any fault machinery fired during the run.
    pub fn any(&self) -> bool {
        *self != FaultStats::default()
    }
}

/// First-class cost accounting over a (possibly heterogeneous) fleet.
///
/// Populated by the simulator from the cluster's static per-GPU $/hour
/// rates (`GpuKind` tables; kind-less positional clusters price at the H100
/// rate). Merge semantics keep sweep aggregation associative and
/// order-independent: accrued dollars add (total spend across shards /
/// points), while the fleet *rate* folds by max — shards of one run share a
/// fleet, so max is idempotent there, mirroring `wall_seconds`. Derived
/// quantities ($/1k requests at SLO, $/attainment-point) live on
/// [`RunMetrics`], computed from merged counters so they stay consistent
/// under any merge order.
#[derive(Debug, Clone, Copy, Default, PartialEq)]
pub struct CostLedger {
    /// Fleet rate, $/hour: sum of per-GPU kind rates.
    pub fleet_cost_per_hour: f64,
    /// Accrued spend, $: rate x wall-clock hours of the run.
    pub cost_dollars: f64,
}

impl CostLedger {
    fn merge(&mut self, other: &CostLedger) {
        self.fleet_cost_per_hour = self.fleet_cost_per_hour.max(other.fleet_cost_per_hour);
        self.cost_dollars += other.cost_dollars;
    }

    /// True when the run carried pricing (fleet rate known).
    pub fn is_priced(&self) -> bool {
        self.fleet_cost_per_hour > 0.0
    }
}

/// Aggregated results of one serving run (the default streaming sink).
#[derive(Debug, Default)]
pub struct RunMetrics {
    /// Retain raw completions + exact percentile views (opt-in).
    full_dump: bool,
    /// Raw records; populated only in full-dump mode.
    completions: Vec<Completion>,
    /// Cross-model aggregate: the same counter/sketch fold as each
    /// per-model slot, so recording semantics live in one place
    /// (`ModelStats::record`).
    global: ModelStats,
    /// Prompt/output token totals over non-dropped completions.
    prompt_tokens: u64,
    output_tokens: u64,
    /// Indexed by `ModelId.0` (dense ids, like the simulator's own index
    /// map - an O(1) slot instead of a per-completion tree lookup on the
    /// hot path); entries with `total == 0` mean "model never completed".
    per_model: Vec<ModelStats>,
    /// Sum of engine busy seconds (for idle-excluded throughput).
    pub busy_seconds: f64,
    pub wall_seconds: f64,
    pub activations: u64,
    pub evictions: u64,
    pub migrations: u64,
    pub preemptions: u64,
    /// Total simulator events processed (hot-path events/sec benchmarking).
    pub sim_events: u64,
    /// Fault-injection and recovery accounting (zero on fault-free runs).
    pub faults: FaultStats,
    /// Fleet pricing and accrued spend (see `CostLedger` merge semantics).
    pub cost: CostLedger,
    /// Exact sorted latency views (full-dump mode only), built lazily on the
    /// first percentile query and rebuilt if `completions` grew since.
    sorted: RefCell<Option<SortedCache>>,
}

impl Clone for RunMetrics {
    fn clone(&self) -> Self {
        RunMetrics {
            full_dump: self.full_dump,
            completions: self.completions.clone(),
            global: self.global.clone(),
            prompt_tokens: self.prompt_tokens,
            output_tokens: self.output_tokens,
            per_model: self.per_model.clone(),
            busy_seconds: self.busy_seconds,
            wall_seconds: self.wall_seconds,
            activations: self.activations,
            evictions: self.evictions,
            migrations: self.migrations,
            preemptions: self.preemptions,
            sim_events: self.sim_events,
            faults: self.faults.clone(),
            cost: self.cost,
            // The lazy sorted views are not carried over: clones are
            // typically mutated further and a stale cache must not survive.
            sorted: RefCell::new(None),
        }
    }
}

#[derive(Debug, Clone)]
struct SortedCache {
    /// Completion count the views were built from (staleness check).
    n: usize,
    ttft: Vec<f64>,
    tpot: Vec<f64>,
    e2e: Vec<f64>,
}

impl SortedCache {
    fn build(cs: &[Completion]) -> Self {
        let mut ttft: Vec<f64> = cs.iter().map(|c| c.ttft).filter(|x| x.is_finite()).collect();
        let mut tpot: Vec<f64> = cs.iter().map(|c| c.tpot).filter(|x| x.is_finite()).collect();
        let mut e2e: Vec<f64> = cs
            .iter()
            .filter(|c| c.finish.is_finite())
            .map(|c| c.finish - c.arrival)
            .collect();
        // INVARIANT: every vec was filtered to finite values just above, so
        // partial_cmp is total here.
        ttft.sort_by(|a, b| a.partial_cmp(b).unwrap());
        tpot.sort_by(|a, b| a.partial_cmp(b).unwrap());
        e2e.sort_by(|a, b| a.partial_cmp(b).unwrap());
        SortedCache { n: cs.len(), ttft, tpot, e2e }
    }
}

impl RunMetrics {
    /// Streaming sink (counters + sketches, no raw completion storage).
    pub fn streaming() -> Self {
        Self::with_full_dump(false)
    }

    /// Full-dump sink: streaming aggregates plus the raw completion list
    /// and exact percentile views.
    pub fn full() -> Self {
        Self::with_full_dump(true)
    }

    pub fn with_full_dump(full_dump: bool) -> Self {
        RunMetrics { full_dump, ..Default::default() }
    }

    pub fn is_full_dump(&self) -> bool {
        self.full_dump
    }

    // ------------------------------------------------------------ recording

    /// Absorb one completion into counters, sketches, per-model stats, and
    /// (in full-dump mode) the raw list.
    pub fn record(&mut self, c: Completion) {
        if !c.dropped {
            self.prompt_tokens += c.prompt_tokens as u64;
            self.output_tokens += c.output_tokens as u64;
        }
        self.global.record(&c);
        self.stats_slot(c.model).record(&c);
        if self.full_dump {
            self.completions.push(c);
        }
    }

    fn stats_slot(&mut self, m: ModelId) -> &mut ModelStats {
        let i = m.0 as usize;
        if i >= self.per_model.len() {
            self.per_model.resize_with(i + 1, ModelStats::default);
        }
        &mut self.per_model[i]
    }

    /// Fold another run's metrics into this one (sweep aggregation, merging
    /// per-point results produced on worker threads). Counter and sketch
    /// merging is exact and order-independent; the exact percentile cache is
    /// invalidated unconditionally so queries after a merge always see fresh
    /// data. Mode mismatch: folding a non-full sink into a full-dump one
    /// downgrades `self` to streaming (raw records would otherwise cover
    /// only part of the counters and the exact percentile path would
    /// silently disagree with them); a streaming target always stays
    /// streaming.
    pub fn merge(&mut self, other: RunMetrics) {
        if self.full_dump && !other.full_dump && other.global.total > 0 {
            self.full_dump = false;
            self.completions = Vec::new();
        }
        self.global.merge(&other.global);
        self.prompt_tokens += other.prompt_tokens;
        self.output_tokens += other.output_tokens;
        for (i, s) in other.per_model.iter().enumerate() {
            if s.total > 0 {
                self.stats_slot(ModelId(i as u32)).merge(s);
            }
        }
        self.busy_seconds += other.busy_seconds;
        self.wall_seconds = self.wall_seconds.max(other.wall_seconds);
        self.activations += other.activations;
        self.evictions += other.evictions;
        self.migrations += other.migrations;
        self.preemptions += other.preemptions;
        self.sim_events += other.sim_events;
        self.faults.merge(&other.faults);
        self.cost.merge(&other.cost);
        if self.full_dump {
            self.completions.extend(other.completions);
        }
        self.invalidate_latency_cache();
    }

    // ------------------------------------------------------------- counters

    /// Total completion records absorbed (finished + dropped).
    pub fn total(&self) -> usize {
        self.global.total as usize
    }

    /// Records that finished (were not dropped).
    pub fn completed(&self) -> usize {
        (self.global.total - self.global.dropped) as usize
    }

    pub fn dropped(&self) -> usize {
        self.global.dropped as usize
    }

    /// The cross-model aggregate (same shape as each per-model entry).
    pub fn global_stats(&self) -> &ModelStats {
        &self.global
    }

    /// Raw completion records; empty unless this is a full-dump sink.
    pub fn completions(&self) -> &[Completion] {
        &self.completions
    }

    /// Mutable access to the raw records (full-dump tests only). After an
    /// in-place, same-length edit, call `invalidate_latency_cache`; note the
    /// streaming counters and sketches intentionally do NOT track such edits.
    pub fn completions_mut(&mut self) -> &mut Vec<Completion> {
        &mut self.completions
    }

    /// Per-model streaming statistics (counters + quantile sketches);
    /// `None` for models with no completion records.
    pub fn model_stats(&self, m: ModelId) -> Option<&ModelStats> {
        self.per_model.get(m.0 as usize).filter(|s| s.total > 0)
    }

    /// Iterate models with at least one record, in id order.
    pub fn per_model(&self) -> impl Iterator<Item = (ModelId, &ModelStats)> {
        self.per_model
            .iter()
            .enumerate()
            .filter(|(_, s)| s.total > 0)
            .map(|(i, s)| (ModelId(i as u32), s))
    }

    // ---------------------------------------------------------- percentiles

    /// Run `f` against the exact sorted latency views, (re)building them if
    /// `completions` grew since the last query.
    fn with_sorted<R>(&self, f: impl FnOnce(&SortedCache) -> R) -> R {
        let mut cache = self.sorted.borrow_mut();
        let stale = match cache.as_ref() {
            Some(c) => c.n != self.completions.len(),
            None => true,
        };
        if stale {
            *cache = Some(SortedCache::build(&self.completions));
        }
        // INVARIANT: the stale arm above filled the None case.
        f(cache.as_ref().expect("cache just built"))
    }

    /// Drop the cached exact sorted views. Called automatically by `merge`;
    /// needed manually only after an in-place, same-length edit through
    /// `completions_mut` (growth is detected automatically).
    pub fn invalidate_latency_cache(&self) {
        *self.sorted.borrow_mut() = None;
    }

    pub fn ttft_attainment(&self) -> f64 {
        self.global.ttft_attainment()
    }

    pub fn tpot_attainment(&self) -> f64 {
        self.global.tpot_attainment()
    }

    pub fn ttft_attainment_for(&self, m: ModelId) -> f64 {
        self.model_stats(m).map_or(1.0, |s| s.ttft_attainment())
    }

    pub fn mean_ttft(&self) -> f64 {
        self.global.ttft.mean()
    }

    pub fn p95_ttft(&self) -> f64 {
        self.p_ttft(95.0)
    }

    /// Arbitrary TTFT percentile over finite samples: exact (sorted once,
    /// cached) in full-dump mode, sketch-estimated (≤1% relative error) in
    /// streaming mode.
    pub fn p_ttft(&self, pct: f64) -> f64 {
        if self.full_dump {
            self.with_sorted(|c| percentile_sorted(&c.ttft, pct))
        } else {
            self.global.ttft.quantile(pct)
        }
    }

    pub fn mean_tpot(&self) -> f64 {
        self.global.tpot.mean()
    }

    pub fn p95_tpot(&self) -> f64 {
        self.p_tpot(95.0)
    }

    /// Arbitrary TPOT percentile (exact in full-dump mode, else sketch).
    pub fn p_tpot(&self, pct: f64) -> f64 {
        if self.full_dump {
            self.with_sorted(|c| percentile_sorted(&c.tpot, pct))
        } else {
            self.global.tpot.quantile(pct)
        }
    }

    pub fn mean_e2e(&self) -> f64 {
        self.global.e2e.mean()
    }

    pub fn p95_e2e(&self) -> f64 {
        self.p_e2e(95.0)
    }

    /// Arbitrary end-to-end percentile (exact in full-dump mode, else sketch).
    pub fn p_e2e(&self, pct: f64) -> f64 {
        if self.full_dump {
            self.with_sorted(|c| percentile_sorted(&c.e2e, pct))
        } else {
            self.global.e2e.quantile(pct)
        }
    }

    // ----------------------------------------------------------- throughput

    /// Requests per second of engine-busy time (the paper's idle-excluded
    /// throughput accounting, SS7.1).
    pub fn req_throughput(&self) -> f64 {
        if self.busy_seconds <= 0.0 {
            return 0.0;
        }
        self.completed() as f64 / self.busy_seconds
    }

    /// Tokens per second of engine-busy time (prefill + decode).
    pub fn token_throughput(&self) -> f64 {
        if self.busy_seconds <= 0.0 {
            return 0.0;
        }
        (self.prompt_tokens + self.output_tokens) as f64 / self.busy_seconds
    }

    /// Revenue proxy (Fig 11b): prefill + decode tokens priced per 1k tokens,
    /// normalized by GPU count.
    ///
    /// Uniform-fleet shim kept for the historical call sites: it treats
    /// every GPU as one interchangeable denominator unit, which is wrong on
    /// heterogeneous fleets (an L4 and an H100 are not the same dollar).
    /// Prefer [`RunMetrics::revenue_per_dollar`], which consumes the
    /// [`CostLedger`].
    pub fn revenue_per_gpu(&self, in_price: f64, out_price: f64, n_gpus: usize) -> f64 {
        let rev = self.prompt_tokens as f64 / 1000.0 * in_price
            + self.output_tokens as f64 / 1000.0 * out_price;
        rev / n_gpus.max(1) as f64
    }

    // ----------------------------------------------------------------- cost

    /// Token revenue per dollar of fleet spend — the `CostLedger`
    /// generalization of [`RunMetrics::revenue_per_gpu`]; fleet-composition
    /// sweeps compare on this. `INFINITY` when the run accrued no cost.
    pub fn revenue_per_dollar(&self, in_price: f64, out_price: f64) -> f64 {
        let rev = self.prompt_tokens as f64 / 1000.0 * in_price
            + self.output_tokens as f64 / 1000.0 * out_price;
        if self.cost.cost_dollars <= 0.0 {
            return f64::INFINITY;
        }
        rev / self.cost.cost_dollars
    }

    /// Dollars per 1k requests served within their TTFT SLO (the paper's
    /// "cost savings" headline as a measured quantity). `INFINITY` when no
    /// request met its SLO — serving nothing well is infinitely expensive.
    pub fn cost_per_1k_requests_at_slo(&self) -> f64 {
        let ok = self.global.ttft_ok as f64;
        if ok <= 0.0 {
            return f64::INFINITY;
        }
        self.cost.cost_dollars / (ok / 1000.0)
    }

    /// Dollars per TTFT-attainment percentage point: what each point of SLO
    /// attainment cost on this fleet. `INFINITY` at zero attainment.
    pub fn cost_per_attainment_point(&self) -> f64 {
        let pts = 100.0 * self.ttft_attainment();
        if pts <= 0.0 {
            return f64::INFINITY;
        }
        self.cost.cost_dollars / pts
    }
}

impl MetricsSink for RunMetrics {
    fn record(&mut self, c: Completion) {
        RunMetrics::record(self, c);
    }

    fn merge(&mut self, other: Self) {
        RunMetrics::merge(self, other);
    }
}

/// One timeline sample (memory/queue plots).
#[derive(Debug, Clone)]
pub struct TimelineSample {
    pub t: f64,
    /// Per-GPU: (weight_bytes, kv_mapped, kv_used, free).
    pub gpus: Vec<(u64, u64, u64, u64)>,
    /// Per-GPU queue length.
    pub queue_lens: Vec<usize>,
    /// Cumulative TTFT SLO violations so far.
    pub cum_violations: usize,
    /// Completed-token throughput since the previous sample (tok/s).
    pub inst_token_tput: f64,
}

/// A shard worker's contribution to one timeline sample, taken at a
/// batch-internal sample *pause* without recomposing the window. Each
/// worker fills only the slots for GPUs its window plan owns (everything
/// else stays zero), plus its shard-local cumulative violation/token
/// counts at pause time; [`merge_partial_samples`] folds the per-shard
/// parts — disjoint by construction — into one [`TimelineSample`].
#[derive(Debug, Clone, Default)]
pub struct PartialSample {
    pub t: f64,
    /// Per-GPU kvcached stats for owned GPUs; `(0, 0, 0, 0)` elsewhere.
    pub gpus: Vec<(u64, u64, u64, u64)>,
    /// Per-GPU queue depth (shared queue + resident-engine queue/running
    /// for leads) for owned GPUs; `0` elsewhere.
    pub queue_lens: Vec<usize>,
    /// This shard's TTFT violations since the window opened.
    pub window_violations: usize,
    /// This shard's completed tokens since the window opened.
    pub window_tokens: u64,
}

impl PartialSample {
    /// Reset to the all-zero state for `n_gpus`, reusing the buffers.
    pub fn reset(&mut self, t: f64, n_gpus: usize) {
        self.t = t;
        self.gpus.clear();
        self.gpus.resize(n_gpus, (0, 0, 0, 0));
        self.queue_lens.clear();
        self.queue_lens.resize(n_gpus, 0);
        self.window_violations = 0;
        self.window_tokens = 0;
    }
}

/// Fold per-shard [`PartialSample`]s into one [`TimelineSample`]. GPU slots
/// are owned by exactly one shard per window, so element-wise addition over
/// the zero-initialised parts reconstructs the sequential sample exactly
/// (all quantities are integers; no float summation-order issues).
/// `cum_violations` and `inst_token_tput` carry window-base offsets the
/// master owns, so they are passed in pre-combined.
pub fn merge_partial_samples<'a>(
    t: f64,
    n_gpus: usize,
    parts: impl IntoIterator<Item = &'a PartialSample>,
    cum_violations: usize,
    inst_token_tput: f64,
) -> TimelineSample {
    let mut gpus = vec![(0u64, 0u64, 0u64, 0u64); n_gpus];
    let mut queue_lens = vec![0usize; n_gpus];
    for p in parts {
        for (g, src) in p.gpus.iter().enumerate() {
            let dst = &mut gpus[g];
            dst.0 += src.0;
            dst.1 += src.1;
            dst.2 += src.2;
            dst.3 += src.3;
        }
        for (g, q) in p.queue_lens.iter().enumerate() {
            queue_lens[g] += q;
        }
    }
    TimelineSample { t, gpus, queue_lens, cum_violations, inst_token_tput }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::request::RequestId;

    fn comp(ttft: f64, slo: f64, tpot: f64, tpot_slo: f64) -> Completion {
        Completion {
            id: RequestId(0),
            model: ModelId(0),
            arrival: 0.0,
            finish: 10.0,
            prompt_tokens: 100,
            output_tokens: 50,
            ttft,
            tpot,
            ttft_slo: slo,
            tpot_slo,
            dropped: false,
            preemptions: 0,
        }
    }

    #[test]
    fn attainment_counts() {
        let mut m = RunMetrics::streaming();
        for c in [
            comp(0.1, 0.5, 0.01, 0.05),
            comp(0.6, 0.5, 0.01, 0.05),
            comp(0.2, 0.5, 0.10, 0.05),
            comp(0.3, 0.5, 0.02, 0.05),
        ] {
            m.record(c);
        }
        m.busy_seconds = 10.0;
        m.wall_seconds = 20.0;
        assert!((m.ttft_attainment() - 0.75).abs() < 1e-12);
        assert!((m.tpot_attainment() - 0.75).abs() < 1e-12);
        assert!((m.req_throughput() - 0.4).abs() < 1e-12);
        assert!((m.token_throughput() - 60.0).abs() < 1e-12);
        assert_eq!(m.total(), 4);
        assert_eq!(m.completed(), 4);
        assert!(m.completions().is_empty(), "streaming sink keeps no raw records");
    }

    #[test]
    fn empty_run_is_vacuously_perfect() {
        let m = RunMetrics::default();
        assert_eq!(m.ttft_attainment(), 1.0);
        assert_eq!(m.req_throughput(), 0.0);
        assert_eq!(m.p95_ttft(), 0.0);
        assert_eq!(m.ttft_attainment_for(ModelId(9)), 1.0);
    }

    #[test]
    fn full_dump_percentile_cache_rebuilds_after_growth() {
        let mut m = RunMetrics::full();
        m.record(comp(0.1, 0.5, 0.01, 0.05));
        assert!((m.p95_ttft() - 0.1).abs() < 1e-12);
        // Growing `completions` invalidates the cached sorted view.
        m.record(comp(0.9, 0.5, 0.01, 0.05));
        assert!((m.p95_ttft() - 0.86).abs() < 1e-9, "p95 {}", m.p95_ttft());
        assert!((m.p_ttft(0.0) - 0.1).abs() < 1e-12);
        assert!((m.p95_e2e() - 10.0).abs() < 1e-12);
        // Infinite latencies (dropped/unfinished) are excluded from views.
        let mut d = comp(f64::INFINITY, 0.5, f64::INFINITY, 0.05);
        d.finish = f64::INFINITY;
        d.dropped = true;
        m.record(d);
        assert!((m.p_ttft(100.0) - 0.9).abs() < 1e-12);
        assert_eq!(m.completed(), 2);
        assert_eq!(m.dropped(), 1);
        // Same-length in-place edits need the explicit invalidation hook;
        // clones never carry a stale cache.
        m.completions_mut()[1].ttft = 0.5;
        m.invalidate_latency_cache();
        assert!((m.p_ttft(100.0) - 0.5).abs() < 1e-12);
        let m2 = m.clone();
        assert!((m2.p_ttft(100.0) - 0.5).abs() < 1e-12); // rebuilds, never stale
    }

    /// Satellite regression: percentile queries after `merge` must see fresh
    /// data even when the exact cache was already built, in both modes.
    #[test]
    fn merge_refreshes_percentiles() {
        let mut a = RunMetrics::full();
        a.record(comp(0.1, 0.5, 0.01, 0.05));
        assert!((a.p_ttft(100.0) - 0.1).abs() < 1e-12); // cache now built
        let mut b = RunMetrics::full();
        b.record(comp(0.9, 0.5, 0.01, 0.05));
        b.record(comp(0.7, 0.5, 0.01, 0.05));
        a.merge(b);
        assert_eq!(a.total(), 3);
        assert!((a.p_ttft(100.0) - 0.9).abs() < 1e-12, "stale cache after merge");

        let mut s = RunMetrics::streaming();
        s.record(comp(0.1, 0.5, 0.01, 0.05));
        let before = s.p_ttft(100.0);
        let mut t = RunMetrics::streaming();
        t.record(comp(0.9, 0.5, 0.01, 0.05));
        s.merge(t);
        assert!(s.p_ttft(100.0) > before, "sketch must reflect merged samples");
        assert_eq!(s.total(), 2);
    }

    /// Folding a streaming sink into a full-dump one must not leave exact
    /// percentile views covering only part of the counters: the target
    /// downgrades to streaming and answers from sketches instead.
    #[test]
    fn merge_mode_mismatch_downgrades_to_streaming() {
        let mut a = RunMetrics::full();
        a.record(comp(0.1, 0.5, 0.01, 0.05));
        let mut b = RunMetrics::streaming();
        b.record(comp(0.9, 0.5, 0.01, 0.05));
        a.merge(b);
        assert!(!a.is_full_dump());
        assert!(a.completions().is_empty());
        assert_eq!(a.total(), 2);
        // Percentiles cover all samples via the sketch (0.9 ± 0.5%).
        assert!(a.p_ttft(100.0) > 0.85);
        // An empty streaming other must NOT downgrade a full-dump target.
        let mut c = RunMetrics::full();
        c.record(comp(0.2, 0.5, 0.01, 0.05));
        c.merge(RunMetrics::streaming());
        assert!(c.is_full_dump());
        assert_eq!(c.completions().len(), 1);
        // A streaming target absorbing full-dump parts stays streaming.
        let mut d = RunMetrics::streaming();
        let mut e = RunMetrics::full();
        e.record(comp(0.3, 0.5, 0.01, 0.05));
        d.merge(e);
        assert!(!d.is_full_dump());
        assert_eq!(d.total(), 1);
        assert!(d.completions().is_empty());
    }

    #[test]
    fn streaming_and_full_dump_agree_on_exact_stats() {
        let records = [
            comp(0.1, 0.5, 0.01, 0.05),
            comp(0.6, 0.5, 0.01, 0.05),
            comp(0.2, 0.5, 0.10, 0.05),
        ];
        let mut s = RunMetrics::streaming();
        let mut f = RunMetrics::full();
        for c in &records {
            s.record(c.clone());
            f.record(c.clone());
        }
        assert_eq!(s.ttft_attainment().to_bits(), f.ttft_attainment().to_bits());
        assert_eq!(s.tpot_attainment().to_bits(), f.tpot_attainment().to_bits());
        assert_eq!(s.mean_ttft().to_bits(), f.mean_ttft().to_bits());
        assert_eq!(s.total(), f.total());
        // Percentiles agree to sketch resolution.
        assert!((s.p95_ttft() - f.p95_ttft()).abs() <= 0.01 * f.p95_ttft());
    }

    #[test]
    fn per_model_stats_track_counts_and_quantiles() {
        let mut m = RunMetrics::streaming();
        for i in 0..10 {
            let mut c = comp(0.1 * (i + 1) as f64, 0.5, 0.01, 0.05);
            c.model = ModelId((i % 2) as u32);
            m.record(c);
        }
        let s0 = m.model_stats(ModelId(0)).unwrap();
        let s1 = m.model_stats(ModelId(1)).unwrap();
        assert_eq!(s0.total + s1.total, 10);
        assert_eq!(s0.total, 5);
        assert!(s0.ttft.quantile(50.0) > 0.0);
        assert!(s1.ttft_attainment() <= 1.0);
        assert!(m.model_stats(ModelId(7)).is_none());
    }

    #[test]
    fn vec_sink_keeps_everything() {
        let mut v: Vec<Completion> = Vec::new();
        MetricsSink::record(&mut v, comp(0.1, 0.5, 0.01, 0.05));
        let mut w: Vec<Completion> = vec![comp(0.2, 0.5, 0.01, 0.05)];
        MetricsSink::merge(&mut w, v);
        assert_eq!(w.len(), 2);
    }

    #[test]
    fn fault_stats_merge_and_clone() {
        let mut a = RunMetrics::streaming();
        a.faults.gpu_crashes = 1;
        a.faults.recovery_seconds = 2.5;
        let mut b = RunMetrics::streaming();
        b.faults.gpu_crashes = 2;
        b.faults.requests_restarted = 7;
        b.faults.recovery_seconds = 0.5;
        assert!(b.faults.any());
        assert!(!RunMetrics::streaming().faults.any());
        let c = b.clone();
        assert_eq!(c.faults, b.faults);
        a.merge(b);
        assert_eq!(a.faults.gpu_crashes, 3);
        assert_eq!(a.faults.requests_restarted, 7);
        assert!((a.faults.recovery_seconds - 3.0).abs() < 1e-12);
    }

    /// Sweep shards can merge in any association order; the ledger (and the
    /// metrics derived from it) must not care.
    #[test]
    fn cost_ledger_merge_is_associative() {
        let shard = |rate: f64, dollars: f64, n_ok: usize| {
            let mut m = RunMetrics::streaming();
            m.cost = CostLedger { fleet_cost_per_hour: rate, cost_dollars: dollars };
            for _ in 0..n_ok {
                m.record(comp(0.1, 0.5, 0.01, 0.05));
            }
            m
        };
        // (a ⊔ b) ⊔ c  vs  a ⊔ (b ⊔ c), bitwise.
        let mut left = shard(12.6, 0.50, 3);
        left.merge(shard(4.8, 0.25, 1));
        left.merge(shard(12.6, 1.00, 6));
        let mut right_tail = shard(4.8, 0.25, 1);
        right_tail.merge(shard(12.6, 1.00, 6));
        let mut right = shard(12.6, 0.50, 3);
        right.merge(right_tail);
        assert_eq!(left.cost, right.cost);
        assert_eq!(
            left.cost.cost_dollars.to_bits(),
            right.cost.cost_dollars.to_bits(),
            "dollar accumulation must be bitwise order-independent"
        );
        assert_eq!(
            left.cost_per_1k_requests_at_slo().to_bits(),
            right.cost_per_1k_requests_at_slo().to_bits()
        );
        assert_eq!(
            left.cost_per_attainment_point().to_bits(),
            right.cost_per_attainment_point().to_bits()
        );
        assert!((left.cost.fleet_cost_per_hour - 12.6).abs() < 1e-12, "rate folds by max");
        assert!((left.cost.cost_dollars - 1.75).abs() < 1e-12);
        assert!(left.cost.is_priced());
        assert!(!RunMetrics::streaming().cost.is_priced());
        // Clone carries the ledger.
        assert_eq!(left.clone().cost, left.cost);
    }

    #[test]
    fn cost_derived_metrics_guard_empty_denominators() {
        let mut m = RunMetrics::streaming();
        m.cost = CostLedger { fleet_cost_per_hour: 9.6, cost_dollars: 2.0 };
        // No request at SLO yet: infinitely expensive, not NaN or panic.
        assert!(m.cost_per_1k_requests_at_slo().is_infinite());
        m.record(comp(0.1, 0.5, 0.01, 0.05));
        assert!((m.cost_per_1k_requests_at_slo() - 2000.0).abs() < 1e-9);
        // One request, 100% attainment: $2 / 100 points.
        assert!((m.cost_per_attainment_point() - 0.02).abs() < 1e-12);
        // Revenue per dollar consumes the ledger, not a GPU count.
        let rev = 0.1 * 1.0 + 0.05 * 3.0; // 100 in-tokens, 50 out-tokens
        assert!((m.revenue_per_dollar(1.0, 3.0) - rev / 2.0).abs() < 1e-12);
        assert!(RunMetrics::streaming().revenue_per_dollar(1.0, 3.0).is_infinite());
    }

    #[test]
    fn revenue_normalizes_by_gpu() {
        let mut m = RunMetrics::streaming();
        m.record(comp(0.1, 0.5, 0.01, 0.05));
        let r1 = m.revenue_per_gpu(1.0, 3.0, 1);
        let r2 = m.revenue_per_gpu(1.0, 3.0, 2);
        assert!((r1 - (0.1 + 0.15)).abs() < 1e-12);
        assert!((r1 / r2 - 2.0).abs() < 1e-12);
    }
}
