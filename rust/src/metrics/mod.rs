//! Metrics: SLO attainment, latency summaries, throughput (idle-excluded),
//! and sampled timelines for the memory/queue plots (Figs 2, 6, 7, 8).

use crate::model::spec::ModelId;
use crate::request::Completion;
use crate::util::stats::Summary;

/// Aggregated results of one serving run.
#[derive(Debug, Clone, Default)]
pub struct RunMetrics {
    pub completions: Vec<Completion>,
    /// Sum of engine busy seconds (for idle-excluded throughput).
    pub busy_seconds: f64,
    pub wall_seconds: f64,
    pub activations: u64,
    pub evictions: u64,
    pub migrations: u64,
    pub preemptions: u64,
}

impl RunMetrics {
    pub fn ttft_attainment(&self) -> f64 {
        frac(&self.completions, |c| c.ttft_ok())
    }

    pub fn tpot_attainment(&self) -> f64 {
        frac(&self.completions, |c| c.tpot_ok())
    }

    pub fn ttft_attainment_for(&self, m: ModelId) -> f64 {
        let v: Vec<&Completion> = self.completions.iter().filter(|c| c.model == m).collect();
        if v.is_empty() {
            return 1.0;
        }
        v.iter().filter(|c| c.ttft_ok()).count() as f64 / v.len() as f64
    }

    pub fn mean_ttft(&self) -> f64 {
        finite_mean(self.completions.iter().map(|c| c.ttft))
    }

    pub fn p95_ttft(&self) -> f64 {
        let mut s = Summary::new();
        for c in &self.completions {
            if c.ttft.is_finite() {
                s.add(c.ttft);
            }
        }
        s.p(95.0)
    }

    pub fn mean_tpot(&self) -> f64 {
        finite_mean(self.completions.iter().map(|c| c.tpot))
    }

    pub fn p95_tpot(&self) -> f64 {
        let mut s = Summary::new();
        for c in &self.completions {
            if c.tpot.is_finite() {
                s.add(c.tpot);
            }
        }
        s.p(95.0)
    }

    pub fn mean_e2e(&self) -> f64 {
        finite_mean(self.completions.iter().map(|c| c.finish - c.arrival))
    }

    pub fn p95_e2e(&self) -> f64 {
        let mut s = Summary::new();
        for c in &self.completions {
            if c.finish.is_finite() {
                s.add(c.finish - c.arrival);
            }
        }
        s.p(95.0)
    }

    /// Requests per second of engine-busy time (the paper's idle-excluded
    /// throughput accounting, SS7.1).
    pub fn req_throughput(&self) -> f64 {
        if self.busy_seconds <= 0.0 {
            return 0.0;
        }
        self.completions.iter().filter(|c| !c.dropped).count() as f64 / self.busy_seconds
    }

    /// Tokens per second of engine-busy time (prefill + decode).
    pub fn token_throughput(&self) -> f64 {
        if self.busy_seconds <= 0.0 {
            return 0.0;
        }
        let tokens: u64 = self
            .completions
            .iter()
            .filter(|c| !c.dropped)
            .map(|c| (c.prompt_tokens + c.output_tokens) as u64)
            .sum();
        tokens as f64 / self.busy_seconds
    }

    /// Revenue proxy (Fig 11b): prefill + decode tokens priced per 1k tokens,
    /// normalized by GPU count.
    pub fn revenue_per_gpu(&self, in_price: f64, out_price: f64, n_gpus: usize) -> f64 {
        let rev: f64 = self
            .completions
            .iter()
            .filter(|c| !c.dropped)
            .map(|c| {
                c.prompt_tokens as f64 / 1000.0 * in_price
                    + c.output_tokens as f64 / 1000.0 * out_price
            })
            .sum();
        rev / n_gpus.max(1) as f64
    }
}

fn frac<F: Fn(&Completion) -> bool>(cs: &[Completion], f: F) -> f64 {
    if cs.is_empty() {
        return 1.0;
    }
    cs.iter().filter(|c| f(c)).count() as f64 / cs.len() as f64
}

fn finite_mean<I: Iterator<Item = f64>>(it: I) -> f64 {
    let v: Vec<f64> = it.filter(|x| x.is_finite()).collect();
    crate::util::stats::mean(&v)
}

/// One timeline sample (memory/queue plots).
#[derive(Debug, Clone)]
pub struct TimelineSample {
    pub t: f64,
    /// Per-GPU: (weight_bytes, kv_mapped, kv_used, free).
    pub gpus: Vec<(u64, u64, u64, u64)>,
    /// Per-GPU queue length.
    pub queue_lens: Vec<usize>,
    /// Cumulative TTFT SLO violations so far.
    pub cum_violations: usize,
    /// Completed-token throughput since the previous sample (tok/s).
    pub inst_token_tput: f64,
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::request::RequestId;

    fn comp(ttft: f64, slo: f64, tpot: f64, tpot_slo: f64) -> Completion {
        Completion {
            id: RequestId(0),
            model: ModelId(0),
            arrival: 0.0,
            finish: 10.0,
            prompt_tokens: 100,
            output_tokens: 50,
            ttft,
            tpot,
            ttft_slo: slo,
            tpot_slo,
            dropped: false,
            preemptions: 0,
        }
    }

    #[test]
    fn attainment_counts() {
        let m = RunMetrics {
            completions: vec![
                comp(0.1, 0.5, 0.01, 0.05),
                comp(0.6, 0.5, 0.01, 0.05),
                comp(0.2, 0.5, 0.10, 0.05),
                comp(0.3, 0.5, 0.02, 0.05),
            ],
            busy_seconds: 10.0,
            wall_seconds: 20.0,
            ..Default::default()
        };
        assert!((m.ttft_attainment() - 0.75).abs() < 1e-12);
        assert!((m.tpot_attainment() - 0.75).abs() < 1e-12);
        assert!((m.req_throughput() - 0.4).abs() < 1e-12);
        assert!((m.token_throughput() - 60.0).abs() < 1e-12);
    }

    #[test]
    fn empty_run_is_vacuously_perfect() {
        let m = RunMetrics::default();
        assert_eq!(m.ttft_attainment(), 1.0);
        assert_eq!(m.req_throughput(), 0.0);
    }

    #[test]
    fn revenue_normalizes_by_gpu() {
        let m = RunMetrics {
            completions: vec![comp(0.1, 0.5, 0.01, 0.05)],
            ..Default::default()
        };
        let r1 = m.revenue_per_gpu(1.0, 3.0, 1);
        let r2 = m.revenue_per_gpu(1.0, 3.0, 2);
        assert!((r1 - (0.1 + 0.15)).abs() < 1e-12);
        assert!((r1 / r2 - 2.0).abs() < 1e-12);
    }
}
