//! Metrics: SLO attainment, latency summaries, throughput (idle-excluded),
//! and sampled timelines for the memory/queue plots (Figs 2, 6, 7, 8).

use std::cell::RefCell;

use crate::model::spec::ModelId;
use crate::request::Completion;
use crate::util::stats::percentile_sorted;

/// Aggregated results of one serving run.
#[derive(Debug, Default)]
pub struct RunMetrics {
    /// Every completion record. Public for iteration; the sorted percentile
    /// cache below auto-rebuilds when this grows or shrinks — after an
    /// in-place, same-length edit call `invalidate_latency_cache`.
    pub completions: Vec<Completion>,
    /// Sum of engine busy seconds (for idle-excluded throughput).
    pub busy_seconds: f64,
    pub wall_seconds: f64,
    pub activations: u64,
    pub evictions: u64,
    pub migrations: u64,
    pub preemptions: u64,
    /// Total simulator events processed (hot-path events/sec benchmarking).
    pub sim_events: u64,
    /// Sorted latency views, built lazily on the first percentile query and
    /// rebuilt if `completions` grew since. Figure drivers query many
    /// percentiles per run; re-collecting and re-sorting per query was
    /// O(n log n) each time.
    sorted: RefCell<Option<SortedCache>>,
}

impl Clone for RunMetrics {
    fn clone(&self) -> Self {
        RunMetrics {
            completions: self.completions.clone(),
            busy_seconds: self.busy_seconds,
            wall_seconds: self.wall_seconds,
            activations: self.activations,
            evictions: self.evictions,
            migrations: self.migrations,
            preemptions: self.preemptions,
            sim_events: self.sim_events,
            // The lazy sorted views are not carried over: clones are
            // typically mutated further and a stale cache must not survive.
            sorted: RefCell::new(None),
        }
    }
}

#[derive(Debug, Clone)]
struct SortedCache {
    /// Completion count the views were built from (staleness check).
    n: usize,
    ttft: Vec<f64>,
    tpot: Vec<f64>,
    e2e: Vec<f64>,
}

impl SortedCache {
    fn build(cs: &[Completion]) -> Self {
        let mut ttft: Vec<f64> = cs.iter().map(|c| c.ttft).filter(|x| x.is_finite()).collect();
        let mut tpot: Vec<f64> = cs.iter().map(|c| c.tpot).filter(|x| x.is_finite()).collect();
        let mut e2e: Vec<f64> = cs
            .iter()
            .filter(|c| c.finish.is_finite())
            .map(|c| c.finish - c.arrival)
            .collect();
        ttft.sort_by(|a, b| a.partial_cmp(b).unwrap());
        tpot.sort_by(|a, b| a.partial_cmp(b).unwrap());
        e2e.sort_by(|a, b| a.partial_cmp(b).unwrap());
        SortedCache { n: cs.len(), ttft, tpot, e2e }
    }
}

impl RunMetrics {
    /// Run `f` against the sorted latency views, (re)building them if
    /// `completions` grew since the last query.
    fn with_sorted<R>(&self, f: impl FnOnce(&SortedCache) -> R) -> R {
        let mut cache = self.sorted.borrow_mut();
        let stale = match cache.as_ref() {
            Some(c) => c.n != self.completions.len(),
            None => true,
        };
        if stale {
            *cache = Some(SortedCache::build(&self.completions));
        }
        f(cache.as_ref().expect("cache just built"))
    }

    /// Drop the cached sorted views. Needed only after an in-place,
    /// same-length edit of `completions` (growth is detected automatically).
    pub fn invalidate_latency_cache(&self) {
        *self.sorted.borrow_mut() = None;
    }

    pub fn ttft_attainment(&self) -> f64 {
        frac(&self.completions, |c| c.ttft_ok())
    }

    pub fn tpot_attainment(&self) -> f64 {
        frac(&self.completions, |c| c.tpot_ok())
    }

    pub fn ttft_attainment_for(&self, m: ModelId) -> f64 {
        let v: Vec<&Completion> = self.completions.iter().filter(|c| c.model == m).collect();
        if v.is_empty() {
            return 1.0;
        }
        v.iter().filter(|c| c.ttft_ok()).count() as f64 / v.len() as f64
    }

    pub fn mean_ttft(&self) -> f64 {
        finite_mean(self.completions.iter().map(|c| c.ttft))
    }

    pub fn p95_ttft(&self) -> f64 {
        self.p_ttft(95.0)
    }

    /// Arbitrary TTFT percentile over finite samples (sorted once, cached).
    pub fn p_ttft(&self, pct: f64) -> f64 {
        self.with_sorted(|c| percentile_sorted(&c.ttft, pct))
    }

    pub fn mean_tpot(&self) -> f64 {
        finite_mean(self.completions.iter().map(|c| c.tpot))
    }

    pub fn p95_tpot(&self) -> f64 {
        self.p_tpot(95.0)
    }

    /// Arbitrary TPOT percentile over finite samples (sorted once, cached).
    pub fn p_tpot(&self, pct: f64) -> f64 {
        self.with_sorted(|c| percentile_sorted(&c.tpot, pct))
    }

    pub fn mean_e2e(&self) -> f64 {
        finite_mean(self.completions.iter().map(|c| c.finish - c.arrival))
    }

    pub fn p95_e2e(&self) -> f64 {
        self.p_e2e(95.0)
    }

    /// Arbitrary end-to-end latency percentile (sorted once, cached).
    pub fn p_e2e(&self, pct: f64) -> f64 {
        self.with_sorted(|c| percentile_sorted(&c.e2e, pct))
    }

    /// Requests per second of engine-busy time (the paper's idle-excluded
    /// throughput accounting, SS7.1).
    pub fn req_throughput(&self) -> f64 {
        if self.busy_seconds <= 0.0 {
            return 0.0;
        }
        self.completions.iter().filter(|c| !c.dropped).count() as f64 / self.busy_seconds
    }

    /// Tokens per second of engine-busy time (prefill + decode).
    pub fn token_throughput(&self) -> f64 {
        if self.busy_seconds <= 0.0 {
            return 0.0;
        }
        let tokens: u64 = self
            .completions
            .iter()
            .filter(|c| !c.dropped)
            .map(|c| (c.prompt_tokens + c.output_tokens) as u64)
            .sum();
        tokens as f64 / self.busy_seconds
    }

    /// Revenue proxy (Fig 11b): prefill + decode tokens priced per 1k tokens,
    /// normalized by GPU count.
    pub fn revenue_per_gpu(&self, in_price: f64, out_price: f64, n_gpus: usize) -> f64 {
        let rev: f64 = self
            .completions
            .iter()
            .filter(|c| !c.dropped)
            .map(|c| {
                c.prompt_tokens as f64 / 1000.0 * in_price
                    + c.output_tokens as f64 / 1000.0 * out_price
            })
            .sum();
        rev / n_gpus.max(1) as f64
    }
}

fn frac<F: Fn(&Completion) -> bool>(cs: &[Completion], f: F) -> f64 {
    if cs.is_empty() {
        return 1.0;
    }
    cs.iter().filter(|c| f(c)).count() as f64 / cs.len() as f64
}

fn finite_mean<I: Iterator<Item = f64>>(it: I) -> f64 {
    let (mut sum, mut n) = (0.0, 0usize);
    for x in it {
        if x.is_finite() {
            sum += x;
            n += 1;
        }
    }
    if n == 0 { 0.0 } else { sum / n as f64 }
}

/// One timeline sample (memory/queue plots).
#[derive(Debug, Clone)]
pub struct TimelineSample {
    pub t: f64,
    /// Per-GPU: (weight_bytes, kv_mapped, kv_used, free).
    pub gpus: Vec<(u64, u64, u64, u64)>,
    /// Per-GPU queue length.
    pub queue_lens: Vec<usize>,
    /// Cumulative TTFT SLO violations so far.
    pub cum_violations: usize,
    /// Completed-token throughput since the previous sample (tok/s).
    pub inst_token_tput: f64,
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::request::RequestId;

    fn comp(ttft: f64, slo: f64, tpot: f64, tpot_slo: f64) -> Completion {
        Completion {
            id: RequestId(0),
            model: ModelId(0),
            arrival: 0.0,
            finish: 10.0,
            prompt_tokens: 100,
            output_tokens: 50,
            ttft,
            tpot,
            ttft_slo: slo,
            tpot_slo,
            dropped: false,
            preemptions: 0,
        }
    }

    #[test]
    fn attainment_counts() {
        let m = RunMetrics {
            completions: vec![
                comp(0.1, 0.5, 0.01, 0.05),
                comp(0.6, 0.5, 0.01, 0.05),
                comp(0.2, 0.5, 0.10, 0.05),
                comp(0.3, 0.5, 0.02, 0.05),
            ],
            busy_seconds: 10.0,
            wall_seconds: 20.0,
            ..Default::default()
        };
        assert!((m.ttft_attainment() - 0.75).abs() < 1e-12);
        assert!((m.tpot_attainment() - 0.75).abs() < 1e-12);
        assert!((m.req_throughput() - 0.4).abs() < 1e-12);
        assert!((m.token_throughput() - 60.0).abs() < 1e-12);
    }

    #[test]
    fn empty_run_is_vacuously_perfect() {
        let m = RunMetrics::default();
        assert_eq!(m.ttft_attainment(), 1.0);
        assert_eq!(m.req_throughput(), 0.0);
        assert_eq!(m.p95_ttft(), 0.0);
    }

    #[test]
    fn percentile_cache_rebuilds_after_growth() {
        let mut m = RunMetrics::default();
        m.completions.push(comp(0.1, 0.5, 0.01, 0.05));
        assert!((m.p95_ttft() - 0.1).abs() < 1e-12);
        // Growing `completions` invalidates the cached sorted view.
        m.completions.push(comp(0.9, 0.5, 0.01, 0.05));
        assert!((m.p95_ttft() - 0.86).abs() < 1e-9, "p95 {}", m.p95_ttft());
        assert!((m.p_ttft(0.0) - 0.1).abs() < 1e-12);
        assert!((m.p95_e2e() - 10.0).abs() < 1e-12);
        // Infinite latencies (dropped/unfinished) are excluded from views.
        let mut d = comp(f64::INFINITY, 0.5, f64::INFINITY, 0.05);
        d.finish = f64::INFINITY;
        m.completions.push(d);
        assert!((m.p_ttft(100.0) - 0.9).abs() < 1e-12);
        // Same-length in-place edits need the explicit invalidation hook;
        // clones never carry a stale cache.
        m.completions[1].ttft = 0.5;
        m.invalidate_latency_cache();
        assert!((m.p_ttft(100.0) - 0.5).abs() < 1e-12);
        let m2 = m.clone();
        assert!((m2.p_ttft(100.0) - 0.5).abs() < 1e-12); // rebuilds, never stale
    }

    #[test]
    fn revenue_normalizes_by_gpu() {
        let m = RunMetrics {
            completions: vec![comp(0.1, 0.5, 0.01, 0.05)],
            ..Default::default()
        };
        let r1 = m.revenue_per_gpu(1.0, 3.0, 1);
        let r2 = m.revenue_per_gpu(1.0, 3.0, 2);
        assert!((r1 - (0.1 + 0.15)).abs() < 1e-12);
        assert!((r1 / r2 - 2.0).abs() < 1e-12);
    }
}
