//! Benchmark infrastructure: timing harness + paper-style result tables.

pub mod harness;
