//! Benchmark harness (no criterion in the offline vendor set).
//!
//! Two layers:
//!  * micro: `Bench::run(name, iters, f)` - wall-clock timing with warmup,
//!    reporting mean/p50/p95/min per iteration.
//!  * macro: `Table` - paper-style result tables (rows = sweep points,
//!    columns = systems/metrics), printed aligned and optionally dumped as
//!    CSV under results/.

use std::fmt::Write as _;
use std::time::Instant;

use crate::util::stats::Summary;

/// One timed micro-benchmark result.
#[derive(Debug, Clone)]
pub struct BenchResult {
    pub name: String,
    pub iters: usize,
    pub mean_ns: f64,
    pub p50_ns: f64,
    pub p95_ns: f64,
    pub min_ns: f64,
}

impl BenchResult {
    pub fn line(&self) -> String {
        format!(
            "{:<44} {:>10} iters  mean {:>12}  p50 {:>12}  p95 {:>12}  min {:>12}",
            self.name,
            self.iters,
            fmt_ns(self.mean_ns),
            fmt_ns(self.p50_ns),
            fmt_ns(self.p95_ns),
            fmt_ns(self.min_ns),
        )
    }
}

pub fn fmt_ns(ns: f64) -> String {
    if ns < 1_000.0 {
        format!("{ns:.1} ns")
    } else if ns < 1_000_000.0 {
        format!("{:.2} µs", ns / 1e3)
    } else if ns < 1e9 {
        format!("{:.2} ms", ns / 1e6)
    } else {
        format!("{:.3} s", ns / 1e9)
    }
}

/// Run `f` for `iters` timed iterations after `warmup` untimed ones.
/// `f` receives the iteration index and returns a value that is black-boxed.
pub fn run<T, F: FnMut(usize) -> T>(
    name: &str,
    warmup: usize,
    iters: usize,
    mut f: F,
) -> BenchResult {
    for i in 0..warmup {
        black_box(f(i));
    }
    let mut s = Summary::new();
    for i in 0..iters {
        let t0 = Instant::now();
        black_box(f(i));
        s.add(t0.elapsed().as_nanos() as f64);
    }
    let mut s2 = s.clone();
    let r = BenchResult {
        name: name.to_string(),
        iters,
        mean_ns: s.mean(),
        p50_ns: s2.p(50.0),
        p95_ns: s2.p(95.0),
        min_ns: s2.min(),
    };
    println!("{}", r.line());
    r
}

/// Prevent the optimizer from eliding the computation.
#[inline]
pub fn black_box<T>(x: T) -> T {
    std::hint::black_box(x)
}

/// Paper-style result table: named columns, push rows, aligned print + CSV.
#[derive(Debug, Clone)]
pub struct Table {
    pub title: String,
    pub columns: Vec<String>,
    pub rows: Vec<Vec<String>>,
}

impl Table {
    pub fn new(title: &str, columns: &[&str]) -> Self {
        Table {
            title: title.to_string(),
            columns: columns.iter().map(|s| s.to_string()).collect(),
            rows: Vec::new(),
        }
    }

    pub fn row(&mut self, cells: Vec<String>) {
        assert_eq!(cells.len(), self.columns.len(), "row arity mismatch");
        self.rows.push(cells);
    }

    pub fn rowf(&mut self, cells: &[f64], fmt_digits: usize) {
        self.row(cells.iter().map(|v| format!("{v:.*}", fmt_digits)).collect());
    }

    pub fn render(&self) -> String {
        let mut widths: Vec<usize> = self.columns.iter().map(|c| c.len()).collect();
        for r in &self.rows {
            for (i, c) in r.iter().enumerate() {
                widths[i] = widths[i].max(c.len());
            }
        }
        let mut out = String::new();
        let _ = writeln!(out, "## {}", self.title);
        let header: Vec<String> = self
            .columns
            .iter()
            .enumerate()
            .map(|(i, c)| format!("{:>w$}", c, w = widths[i]))
            .collect();
        let _ = writeln!(out, "{}", header.join("  "));
        let dashes: Vec<String> = widths.iter().map(|w| "-".repeat(*w)).collect();
        let _ = writeln!(out, "{}", dashes.join("  "));
        for r in &self.rows {
            let line: Vec<String> = r
                .iter()
                .enumerate()
                .map(|(i, c)| format!("{:>w$}", c, w = widths[i]))
                .collect();
            let _ = writeln!(out, "{}", line.join("  "));
        }
        out
    }

    pub fn print(&self) {
        println!("{}", self.render());
    }

    pub fn to_csv(&self) -> String {
        let mut out = String::new();
        let esc = |s: &str| {
            if s.contains(',') || s.contains('"') {
                format!("\"{}\"", s.replace('"', "\"\""))
            } else {
                s.to_string()
            }
        };
        let _ =
            writeln!(out, "{}", self.columns.iter().map(|c| esc(c)).collect::<Vec<_>>().join(","));
        for r in &self.rows {
            let _ = writeln!(out, "{}", r.iter().map(|c| esc(c)).collect::<Vec<_>>().join(","));
        }
        out
    }

    /// Write CSV under results/<file>; creates the directory.
    pub fn save_csv(&self, file: &str) -> std::io::Result<std::path::PathBuf> {
        let dir = std::path::Path::new("results");
        std::fs::create_dir_all(dir)?;
        let path = dir.join(file);
        std::fs::write(&path, self.to_csv())?;
        Ok(path)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn bench_run_reports_sane_numbers() {
        let r = run("noop-sum", 2, 20, |i| (0..100).map(|x| x * i).sum::<usize>());
        assert_eq!(r.iters, 20);
        assert!(r.min_ns <= r.p50_ns && r.p50_ns <= r.p95_ns);
        assert!(r.mean_ns > 0.0);
    }

    #[test]
    fn table_render_and_csv() {
        let mut t = Table::new("Demo", &["x", "prism", "baseline"]);
        t.row(vec!["1".into(), "0.99".into(), "0.50".into()]);
        t.rowf(&[2.0, 0.98, 0.40], 2);
        let s = t.render();
        assert!(s.contains("Demo") && s.contains("prism"));
        let csv = t.to_csv();
        assert_eq!(csv.lines().count(), 3);
        assert!(csv.starts_with("x,prism,baseline"));
    }

    #[test]
    fn csv_escaping() {
        let mut t = Table::new("q", &["a,b"]);
        t.row(vec!["x\"y".into()]);
        let csv = t.to_csv();
        assert!(csv.contains("\"a,b\""));
        assert!(csv.contains("\"x\"\"y\""));
    }

    #[test]
    #[should_panic(expected = "row arity")]
    fn arity_checked() {
        let mut t = Table::new("t", &["a", "b"]);
        t.row(vec!["1".into()]);
    }

    #[test]
    fn fmt_ns_units() {
        assert!(fmt_ns(5.0).ends_with("ns"));
        assert!(fmt_ns(5_000.0).ends_with("µs"));
        assert!(fmt_ns(5_000_000.0).ends_with("ms"));
        assert!(fmt_ns(5e9).ends_with("s"));
    }
}
