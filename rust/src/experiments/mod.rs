//! Paper experiment drivers: one function per table/figure (DESIGN.md SS5).
//! Each returns `Table`s (printed + saved as CSV under results/) so benches,
//! the CLI, and EXPERIMENTS.md all regenerate the same artifacts.

pub mod e2e;
pub mod figures;
pub mod micro;

use crate::bench::harness::Table;

/// Run an experiment by id ("tab1", "fig5", ... or "all") with automatic
/// sweep parallelism; returns tables.
pub fn run(id: &str, quick: bool) -> anyhow::Result<Vec<Table>> {
    run_jobs(id, quick, 0)
}

/// As [`run`], with an explicit sweep worker count: `jobs = 0` resolves to
/// `sweep::default_jobs()` (env `PRISM_JOBS` or available parallelism);
/// `jobs = 1` reproduces the historical sequential behavior bit-for-bit.
/// Tables are byte-identical for any `jobs` value (results are keyed to
/// sweep points, never to completion order).
pub fn run_jobs(id: &str, quick: bool, jobs: usize) -> anyhow::Result<Vec<Table>> {
    let mut out = Vec::new();
    let all = id == "all";
    let mut hit = false;
    macro_rules! exp {
        ($name:expr, $f:expr) => {
            if all || id == $name {
                hit = true;
                eprintln!("== running {} {}", $name, if quick { "(quick)" } else { "" });
                let tables: Vec<Table> = $f;
                for t in &tables {
                    t.print();
                    let fname = format!("{}_{}.csv", $name, slug(&t.title));
                    if let Ok(p) = t.save_csv(&fname) {
                        eprintln!("   saved {}", p.display());
                    }
                }
                out.extend(tables);
            }
        };
    }
    exp!("tab1", figures::tab1_trace_summary(quick, jobs));
    exp!("fig1", figures::fig1_dynamics(quick));
    exp!("fig2", figures::fig2_pure_sharing(quick, jobs));
    exp!("tab2", e2e::tab2_muxserve(quick, jobs));
    exp!("fig5", e2e::fig5_end_to_end(quick, jobs));
    exp!("fig6", figures::fig6_memory_coordination(quick, jobs));
    exp!("fig7", e2e::fig7_placement_ablation(quick, jobs));
    exp!("fig8", e2e::fig8_arbitration_ablation(quick, jobs));
    exp!("fig9", e2e::fig9_large_scale(quick, jobs));
    exp!("fig10", micro::fig10_activation_latency());
    exp!("fig11", e2e::fig11_production(quick, jobs));
    exp!("fig12", figures::fig12_switches_pearson(quick, jobs));
    exp!("fig13", figures::fig13_volatility(quick, jobs));
    exp!("fig14", micro::fig14_elastic_overhead(quick));
    exp!("fig15", e2e::fig15_sensitivity(quick, jobs));
    exp!("overhead", e2e::overhead_frequency(quick));
    if !hit {
        anyhow::bail!("unknown experiment id '{id}'");
    }
    Ok(out)
}

pub fn ids() -> &'static [&'static str] {
    &[
        "tab1", "fig1", "fig2", "tab2", "fig5", "fig6", "fig7", "fig8", "fig9",
        "fig10", "fig11", "fig12", "fig13", "fig14", "fig15", "overhead",
    ]
}

fn slug(s: &str) -> String {
    s.chars()
        .map(|c| if c.is_ascii_alphanumeric() { c.to_ascii_lowercase() } else { '_' })
        .collect::<String>()
        .split('_')
        .filter(|p| !p.is_empty())
        .collect::<Vec<_>>()
        .join("_")
        .chars()
        .take(48)
        .collect()
}
