//! Micro experiments: Fig 10 (activation latency) and Fig 14 (elastic
//! memory worst-case overhead).

use crate::bench::harness::Table;
use crate::cluster::{FleetSpec, GpuKind};
use crate::engine::loading::{activation_seconds, LoadStrategy};
use crate::engine::perf::GpuPerf;
use crate::experiments::e2e::assign_ids;
use crate::model::spec::table3_catalog;
use crate::sim::{SimConfig, Simulator};
use crate::trace::Trace;

/// Fig 10: model activation latency by size, for the three loading paths.
pub fn fig10_activation_latency() -> Vec<Table> {
    let perf = GpuPerf::default();
    let cat = table3_catalog();
    let picks = [
        ("1B", "llama-3.2-1b-ft00"),
        ("3B", "llama-3.2-3b-ft00"),
        ("8B", "llama-3.1-8b-ft00"),
        ("14B", "ds-r1-distill-qwen-14b"),
        ("32B", "qwen-2.5-32b"),
        ("70B", "llama-3.3-70b"),
    ];
    let mut t = Table::new(
        "Fig 10: activation latency (s) vs model size",
        &["model", "naive_cold", "engine_pool", "prism_parallel"],
    );
    for (label, name) in picks {
        let m = cat.iter().find(|m| m.name == name).unwrap();
        let w = m.weight_bytes();
        t.row(vec![
            label.into(),
            format!("{:.2}", activation_seconds(&perf, LoadStrategy::Naive, w, 8)),
            format!("{:.2}", activation_seconds(&perf, LoadStrategy::PooledNaive, w, 8)),
            format!("{:.2}", activation_seconds(&perf, LoadStrategy::Parallel, w, 8)),
        ]);
    }
    vec![t]
}

/// Fig 14: elastic memory overhead in the worst case - constant request
/// rate, two 3B models on an A100-40G, Prism vs static partitioning. The
/// only Prism cost here is kvcached map/unmap churn.
pub fn fig14_elastic_overhead(quick: bool) -> Vec<Table> {
    let cat = table3_catalog();
    let m3b: Vec<_> = cat.iter().filter(|m| m.name.contains("3b")).take(2).cloned().collect();
    let specs = assign_ids(m3b);
    let dur = if quick { 120.0 } else { 600.0 };

    let mut tables = Vec::new();
    let mut t = Table::new(
        "Fig 14: worst-case elastic overhead, 2x3B on A100-40G, constant load",
        &["req_per_s", "system", "mean_ttft_ms", "mean_tpot_ms", "kvcached_map_ms_total"],
    );
    for rate in [28.0, 32.0] {
        // Constant-rate trace, equal split.
        let mut rng = crate::util::rng::Rng::new(rate as u64);
        let mut events = Vec::new();
        let mut time = 0.0;
        loop {
            time += 1.0 / rate;
            if time >= dur {
                break;
            }
            events.push(crate::trace::TraceEvent {
                t: time,
                model_idx: (rng.below(2)) as usize,
                prompt_tokens: 200,
                output_tokens: 100,
            });
        }
        let trace = Trace { name: "fig14".into(), n_models: 2, events, duration: dur };
        for name in ["prism", "s-partition"] {
            // The A100 kind carries the 40 GiB + `GpuPerf::a100_40g()`
            // profile this experiment used to poke in by hand.
            let cfg = SimConfig::from_fleet(name, FleetSpec::uniform(1, GpuKind::A100))
                .slo_scale(10.0);
            let sim = Simulator::new(cfg, specs.clone());
            let (m, _) = sim.run(&trace);
            t.row(vec![
                format!("{rate}"),
                name.into(),
                format!("{:.1}", m.mean_ttft() * 1e3),
                format!("{:.2}", m.mean_tpot() * 1e3),
                // kvcached cost is recorded inside the engines' iteration
                // time already; report preemptions as the churn proxy.
                m.preemptions.to_string(),
            ]);
        }
    }
    tables.push(t);
    tables
}
