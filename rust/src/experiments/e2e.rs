//! End-to-end simulator experiments (Tab 2, Fig 5, 7, 8, 9, 11, 15, SS7.5).
//!
//! Every multi-run experiment enumerates its grid into the `sweep` engine
//! instead of hand-rolled nested loops: points run on a worker pool
//! (`jobs` workers; 0 = auto, 1 = sequential) and results are keyed to
//! points, so tables are byte-identical whatever the worker count.

use crate::bench::harness::Table;
use crate::metrics::RunMetrics;
use crate::model::spec::{catalog_subset, table3_catalog, ModelId, ModelSpec};
use crate::sim::{registry, SimConfig, Simulator};
use crate::sweep::{run_points, SweepGrid};
use crate::trace::gen::{generate, TraceGenConfig};
use crate::trace::Trace;

/// Remap a spec list so ids align with trace model indices.
pub fn assign_ids(mut specs: Vec<ModelSpec>) -> Vec<ModelSpec> {
    for (i, s) in specs.iter_mut().enumerate() {
        s.id = ModelId(i as u32);
    }
    specs
}

/// The 8-models-on-2-GPUs setup of SS7.2. All 7-8B models (the paper's
/// contended regime): 8 x ~15 GB of weights against 160 GB of GPU memory
/// leaves real KV pressure, which is what differentiates the policies.
fn eight_models() -> Vec<ModelSpec> {
    let cat = table3_catalog();
    let v: Vec<ModelSpec> = cat
        .iter()
        .filter(|m| m.name.contains("8b") || m.name.contains("7b"))
        .take(8)
        .cloned()
        .collect();
    assign_ids(v)
}

fn traces_for_e2e(quick: bool, n_models: usize) -> Vec<(&'static str, Trace)> {
    let dur = if quick { 240.0 } else { 900.0 };
    vec![
        ("hyperbolic", generate(&TraceGenConfig::hyperbolic_like(n_models, dur, 21))),
        ("arena-chat", generate(&TraceGenConfig::arena_chat_like(n_models, dur, 22))),
    ]
}

fn att_row(prefix: Vec<String>, policy: &str, m: &RunMetrics) -> Vec<String> {
    let mut row = prefix;
    row.push(policy.into());
    row.push(format!("{:.3}", m.ttft_attainment()));
    row.push(format!("{:.3}", m.tpot_attainment()));
    row
}

/// Table 2: MuxServe vs MuxServe++ - the kvcached delta. "MuxServe" is
/// modelled as space sharing with static per-model KV quotas (no elastic
/// memory); MuxServe++ shares the KV pool through kvcached.
pub fn tab2_muxserve(quick: bool, jobs: usize) -> Vec<Table> {
    let cat = table3_catalog();
    let specs = assign_ids(
        cat.iter().filter(|m| m.name.contains("8b")).take(3).cloned().collect(),
    );
    // Three 8B models at 199/262/22 req/min for 10 minutes (paper setup);
    // long generations make the KV quota the binding constraint.
    let dur = if quick { 120.0 } else { 600.0 };
    let rates = [199.0 / 60.0, 262.0 / 60.0, 22.0 / 60.0];
    let mut rng = crate::util::rng::Rng::new(5);
    let mut events = Vec::new();
    for (m, &rate) in rates.iter().enumerate() {
        let mut t = 0.0;
        loop {
            t += rng.exp(rate);
            if t >= dur {
                break;
            }
            events.push(crate::trace::TraceEvent {
                t,
                model_idx: m,
                prompt_tokens: 600 + rng.below(1400) as u32,
                output_tokens: 300 + rng.below(900) as u32,
            });
        }
    }
    events.sort_by(|a, b| a.t.partial_cmp(&b.t).unwrap());
    let trace = Trace { name: "tab2".into(), n_models: 3, events, duration: dur };

    let mut t = Table::new(
        "Table 2: MuxServe (static quotas) vs MuxServe++ (kvcached)",
        &["system", "mean_e2e_s", "p95_e2e_s", "req_tput", "tok_tput",
          "mean_ttft_s", "p95_ttft_s", "mean_tpot_ms", "p95_tpot_ms"],
    );
    let points = [("muxserve", "s-partition"), ("muxserve++", "muxserve++")];
    let results = run_points(&points, jobs, |_, &(_, policy)| {
        // Tab 2 is percentile-heavy (p95 e2e/ttft/tpot columns): full dump
        // keeps those columns exact, not sketch estimates.
        let cfg = SimConfig::for_policy(policy).slo_scale(8.0).full_dump(true);
        Simulator::new(cfg, specs.clone()).run(&trace).0
    });
    for ((name, _), m) in points.iter().zip(&results) {
        t.row(vec![
            (*name).into(),
            format!("{:.2}", m.mean_e2e()),
            format!("{:.2}", m.p95_e2e()),
            format!("{:.2}", m.req_throughput()),
            format!("{:.0}", m.token_throughput()),
            format!("{:.3}", m.mean_ttft()),
            format!("{:.3}", m.p95_ttft()),
            format!("{:.1}", m.mean_tpot() * 1e3),
            format!("{:.1}", m.p95_tpot() * 1e3),
        ]);
    }
    vec![t]
}

/// Fig 5: SLO attainment vs rate scale / SLO scale / #GPUs, 2 traces,
/// every registered policy. Each row of the figure is one sweep grid.
pub fn fig5_end_to_end(quick: bool, jobs: usize) -> Vec<Table> {
    let specs = eight_models();
    let mut out = Vec::new();

    // Row 1: attainment vs rate scale (8 models, 2 GPUs). Scaled traces are
    // materialized once per (trace, rate) pair; the policies sharing a
    // pair read the same copy instead of re-scaling per point.
    let rate_scales: &[f64] = if quick { &[1.0, 4.0] } else { &[0.5, 1.0, 2.0, 4.0, 8.0] };
    let traces = traces_for_e2e(quick, specs.len());
    let scaled: Vec<Vec<Trace>> = traces
        .iter()
        .map(|(_, tr)| rate_scales.iter().map(|&rs| tr.scale_rate(rs)).collect())
        .collect();
    let points = SweepGrid::new().traces(traces.len()).rate_scales(rate_scales).points();
    let results = run_points(&points, jobs, |_, pt| {
        // The grid copies rates verbatim, so the position lookup is exact;
        // fall back to per-point scaling (bit-identical output) rather than
        // panicking a worker if the axes ever drift apart.
        match rate_scales.iter().position(|&r| r == pt.rate_scale) {
            Some(ri) => pt.run_prescaled(&specs, &scaled[pt.trace][ri]),
            None => pt.run(&specs, &traces[pt.trace].1),
        }
    });
    let mut tables: Vec<Table> = traces
        .iter()
        .map(|(tname, _)| {
            Table::new(
                &format!("Fig 5 row1 ({tname}): attainment vs rate scale, 8 models / 2 GPUs"),
                &["rate_scale", "system", "ttft_att", "tpot_att"],
            )
        })
        .collect();
    for (pt, m) in points.iter().zip(&results) {
        tables[pt.trace].row(att_row(vec![format!("{}", pt.rate_scale)], pt.policy, m));
    }
    out.extend(tables);

    // Row 2: attainment vs SLO scale (rate fixed at 2x, scaled once per
    // trace; the grid's rate axis only labels the point keys).
    let slo_scales: &[f64] = if quick { &[2.0, 16.0] } else { &[1.0, 2.0, 4.0, 8.0, 16.0, 32.0] };
    let scaled2: Vec<Trace> = traces.iter().map(|(_, tr)| tr.scale_rate(2.0)).collect();
    let points = SweepGrid::new()
        .traces(traces.len())
        .rate_scales(&[2.0])
        .slo_scales(slo_scales)
        .points();
    let results =
        run_points(&points, jobs, |_, pt| pt.run_prescaled(&specs, &scaled2[pt.trace]));
    let mut tables: Vec<Table> = traces
        .iter()
        .map(|(tname, _)| {
            Table::new(
                &format!("Fig 5 row2 ({tname}): attainment vs SLO scale, 8 models / 2 GPUs"),
                &["slo_scale", "system", "ttft_att", "tpot_att"],
            )
        })
        .collect();
    for (pt, m) in points.iter().zip(&results) {
        tables[pt.trace].row(att_row(vec![format!("{}", pt.slo_scale)], pt.policy, m));
    }
    out.extend(tables);

    // Row 3: attainment vs #GPUs (18 models, 1B-8B).
    let specs18 = assign_ids(
        catalog_subset(30)
            .into_iter()
            .filter(|m| !m.is_tp() && m.params < 9_000_000_000)
            .take(18)
            .collect(),
    );
    let gpu_counts: &[u32] = if quick { &[2, 4] } else { &[1, 2, 3, 4, 5, 6, 7, 8] };
    let traces18 = traces_for_e2e(quick, specs18.len());
    let points = SweepGrid::new().traces(traces18.len()).gpus(gpu_counts).points();
    let results =
        run_points(&points, jobs, |_, pt| pt.run_prescaled(&specs18, &traces18[pt.trace].1));
    let mut tables: Vec<Table> = traces18
        .iter()
        .map(|(tname, _)| {
            Table::new(
                &format!("Fig 5 row3 ({tname}): attainment vs #GPUs, 18 models"),
                &["gpus", "system", "ttft_att", "tpot_att"],
            )
        })
        .collect();
    for (pt, m) in points.iter().zip(&results) {
        tables[pt.trace].row(att_row(vec![pt.n_gpus.to_string()], pt.policy, m));
    }
    out.extend(tables);
    out
}

/// Fig 7: global placement ablation (8 models / 2 GPUs).
pub fn fig7_placement_ablation(quick: bool, jobs: usize) -> Vec<Table> {
    let specs = eight_models();
    let dur = if quick { 240.0 } else { 900.0 };
    let trace = generate(&TraceGenConfig::arena_chat_like(specs.len(), dur, 33)).scale_rate(2.0);
    let mut t = Table::new(
        "Fig 7a: global placement scheduler on/off",
        &["config", "ttft_att", "tpot_att", "migrations"],
    );
    // infinite tau = never migrate = no global scheduling
    let points = [("global-sched-on", 0.2), ("global-sched-off", f64::INFINITY)];
    let results = run_points(&points, jobs, |_, &(_, tau)| {
        let mut cfg = SimConfig::for_policy("prism").gpus(2).slo_scale(8.0).sample_dt(10.0);
        cfg.tau = tau;
        Simulator::new(cfg, specs.clone()).run(&trace)
    });
    let mut tl_tables = Vec::new();
    for ((name, _), (m, tl)) in points.iter().zip(&results) {
        t.row(vec![
            (*name).into(),
            format!("{:.3}", m.ttft_attainment()),
            format!("{:.3}", m.tpot_attainment()),
            m.migrations.to_string(),
        ]);
        let mut tt = Table::new(
            &format!("Fig 7b ({name}): per-GPU free KV over time"),
            &["t", "gpu0_free_gb", "gpu1_free_gb"],
        );
        for s in tl {
            tt.row(vec![
                format!("{:.0}", s.t),
                format!("{:.1}", s.gpus[0].3 as f64 / 1e9),
                format!("{:.1}", s.gpus.get(1).map(|g| g.3).unwrap_or(0) as f64 / 1e9),
            ]);
        }
        tl_tables.push(tt);
    }
    let mut out = vec![t];
    out.extend(tl_tables);
    out
}

/// Fig 8: GPU-local arbitration ablation - two models, model1 SLO scale
/// fixed at 8, model2's scale swept; local scheduling on/off.
pub fn fig8_arbitration_ablation(quick: bool, jobs: usize) -> Vec<Table> {
    let cat = table3_catalog();
    // Model 0: an 8B with long prompts; model 1: a small 1B with strict SLOs.
    let m0 = cat.iter().find(|m| m.name.contains("8b")).unwrap().clone();
    let m1 = cat[0].clone();
    let specs = assign_ids(vec![m0, m1]);
    let dur = if quick { 180.0 } else { 600.0 };
    // Model 0: long prompts, relaxed SLO. Model 1: short prompts, strict SLO.
    let mut rng = crate::util::rng::Rng::new(9);
    let mut events = Vec::new();
    let mut t = 0.0;
    while t < dur {
        t += rng.exp(2.0);
        events.push(crate::trace::TraceEvent {
            t,
            model_idx: 0,
            prompt_tokens: 800 + rng.below(800) as u32,
            output_tokens: 150 + rng.below(150) as u32,
        });
    }
    t = 0.0;
    while t < dur {
        t += rng.exp(3.0);
        events.push(crate::trace::TraceEvent {
            t,
            model_idx: 1,
            prompt_tokens: 60 + rng.below(100) as u32,
            output_tokens: 30 + rng.below(60) as u32,
        });
    }
    events.sort_by(|a, b| a.t.partial_cmp(&b.t).unwrap());
    let trace = Trace { name: "fig8".into(), n_models: 2, events, duration: dur };

    let scales: &[f64] = if quick { &[1.0, 4.0] } else { &[1.0, 2.0, 4.0, 6.0, 8.0] };
    let mut points = Vec::new();
    for &s2 in scales {
        for (name, policy) in [
            ("local-on", "prism"),
            ("local-off", "muxserve++"), // FCFS, no slack awareness
        ] {
            points.push((s2, name, policy));
        }
    }
    let results = run_points(&points, jobs, |_, &(s2, _, policy)| {
        let cfg = SimConfig::for_policy(policy).slo_scale(1.0); // per-model scales set below
        let mut sim = Simulator::new(cfg, specs.clone());
        // Override SLOs: model0 scale 8, model1 scale s2.
        let (t0, p0) = sim.slo_of(0);
        let (t1, p1) = sim.slo_of(1);
        sim.set_slos(vec![(t0 * 8.0, p0 * 8.0), (t1 * s2, p1 * s2)]);
        sim.run(&trace).0
    });
    let mut table = Table::new(
        "Fig 8a: TTFT attainment vs model2 SLO scale (local sched on/off)",
        &["m2_slo_scale", "config", "m1_ttft_att", "m2_ttft_att"],
    );
    for ((s2, name, _), m) in points.iter().zip(&results) {
        table.row(vec![
            format!("{s2}"),
            (*name).into(),
            format!("{:.3}", m.ttft_attainment_for(ModelId(0))),
            format!("{:.3}", m.ttft_attainment_for(ModelId(1))),
        ]);
    }
    vec![table]
}

/// Fig 9: large scale - 58 models, TP for big ones, up to 32 GPUs.
pub fn fig9_large_scale(quick: bool, jobs: usize) -> Vec<Table> {
    let specs = assign_ids(if quick {
        catalog_subset(16)
    } else {
        table3_catalog()
    });
    let dur = if quick { 180.0 } else { 600.0 };
    let trace = generate(&TraceGenConfig::arena_chat_like(specs.len(), dur, 55));
    let gpus: &[u32] = if quick { &[8] } else { &[8, 16, 24, 32] };

    let points = SweepGrid::new().gpus(gpus).slo_scales(&[5.0]).points();
    let results = run_points(&points, jobs, |_, pt| pt.run(&specs, &trace));
    let mut a = Table::new(
        "Fig 9a: attainment vs #GPUs (58 models, TP 32B/70B)",
        &["gpus", "system", "ttft_att", "tpot_att"],
    );
    let mut best: std::collections::BTreeMap<&str, u32> = Default::default();
    for (pt, m) in points.iter().zip(&results) {
        let ta = m.ttft_attainment();
        a.row(vec![
            pt.n_gpus.to_string(),
            pt.policy.into(),
            format!("{:.3}", ta),
            format!("{:.3}", m.tpot_attainment()),
        ]);
        if ta >= 0.99 && !best.contains_key(pt.policy) {
            best.insert(pt.policy, pt.n_gpus);
        }
    }
    let mut b = Table::new(
        "Fig 9b: GPUs needed for 99% TTFT attainment",
        &["system", "gpus_for_99pct"],
    );
    for p in registry().names() {
        b.row(vec![
            p.into(),
            best.get(p)
                .map(|g| g.to_string())
                .unwrap_or_else(|| format!(">{}", gpus.last().unwrap())),
        ]);
    }
    vec![a, b]
}

/// Fig 11: production shadow replay - throughput and revenue per GPU,
/// before (static partition) vs after (Prism).
pub fn fig11_production(quick: bool, jobs: usize) -> Vec<Table> {
    let specs = assign_ids(
        catalog_subset(30)
            .into_iter()
            .filter(|m| !m.is_tp())
            .take(12)
            .collect(),
    );
    let dur = if quick { 240.0 } else { 1200.0 };
    let n_gpus = 4;
    let companies = [("A", 61u64, 2.0), ("B", 62, 1.0)];
    // Shadow traces are independent too: generate them through the engine.
    let traces = run_points(&companies, jobs, |_, &(_, seed, scale)| {
        generate(&TraceGenConfig::hyperbolic_like(specs.len(), dur, seed)).scale_rate(scale)
    });
    let mut points = Vec::new();
    for ci in 0..companies.len() {
        for (label, p) in [("before", "s-partition"), ("after", "prism")] {
            points.push((ci, label, p));
        }
    }
    let results = run_points(&points, jobs, |_, &(ci, _, p)| {
        let cfg = SimConfig::for_policy(p).gpus(n_gpus).slo_scale(10.0);
        Simulator::new(cfg, specs.clone()).run(&traces[ci]).0
    });
    let mut t = Table::new(
        "Fig 11: shadow replay - per-GPU throughput and revenue, before/after Prism",
        &["company", "system", "tok_tput_per_gpu", "revenue_per_gpu", "ttft_att"],
    );
    for ((ci, label, _), m) in points.iter().zip(&results) {
        t.row(vec![
            companies[*ci].0.into(),
            (*label).into(),
            format!("{:.0}", m.token_throughput() / n_gpus as f64),
            // $0.5 in / $2 out per 1M tokens (typical published rates).
            format!("{:.4}", m.revenue_per_gpu(0.0005, 0.002, n_gpus as usize)),
            format!("{:.3}", m.ttft_attainment()),
        ]);
    }
    vec![t]
}

/// Fig 15: sensitivity to the idle-eviction threshold and monitor window.
pub fn fig15_sensitivity(quick: bool, jobs: usize) -> Vec<Table> {
    let specs = eight_models();
    let dur = if quick { 240.0 } else { 900.0 };
    let trace = generate(&TraceGenConfig::hyperbolic_like(specs.len(), dur, 71)).scale_rate(2.0);

    let thresholds: &[f64] =
        if quick { &[10.0, 45.0, 120.0] } else { &[10.0, 20.0, 45.0, 60.0, 80.0, 120.0] };
    let th_results = run_points(thresholds, jobs, |_, &th| {
        let mut cfg = SimConfig::for_policy("prism").gpus(2).slo_scale(8.0);
        cfg.eviction.idle_threshold = th;
        Simulator::new(cfg, specs.clone()).run(&trace).0
    });
    let mut a = Table::new(
        "Fig 15a: mean TTFT vs idle eviction threshold",
        &["threshold_s", "mean_ttft_s", "evictions"],
    );
    for (th, m) in thresholds.iter().zip(&th_results) {
        a.row(vec![
            format!("{th}"),
            format!("{:.3}", m.mean_ttft()),
            m.evictions.to_string(),
        ]);
    }

    let windows: &[f64] =
        if quick { &[10.0, 60.0, 300.0] } else { &[10.0, 30.0, 60.0, 120.0, 300.0] };
    let w_results = run_points(windows, jobs, |_, &w| {
        let mut cfg = SimConfig::for_policy("prism").gpus(2).slo_scale(8.0);
        cfg.monitor_window = w;
        Simulator::new(cfg, specs.clone()).run(&trace).0
    });
    let mut b = Table::new(
        "Fig 15b: mean TTFT vs monitoring window",
        &["window_s", "mean_ttft_s", "migrations"],
    );
    for (w, m) in windows.iter().zip(&w_results) {
        b.row(vec![
            format!("{w}"),
            format!("{:.3}", m.mean_ttft()),
            m.migrations.to_string(),
        ]);
    }
    vec![a, b]
}

/// SS7.5: activation and migration frequency over a 10-minute window.
pub fn overhead_frequency(quick: bool) -> Vec<Table> {
    let specs = eight_models();
    let dur = if quick { 240.0 } else { 600.0 };
    let trace = generate(&TraceGenConfig::novita_like(specs.len(), dur, 81)).scale_rate(2.0);
    let cfg = SimConfig::for_policy("prism").gpus(2).slo_scale(8.0);
    let sim = Simulator::new(cfg, specs.clone());
    let (m, _) = sim.run(&trace);
    let mut t = Table::new(
        "SS7.5: activation/migration frequency (8 models / 2 GPUs)",
        &["metric", "value"],
    );
    t.row(vec!["window_s".into(), format!("{dur}")]);
    t.row(vec!["activations".into(), m.activations.to_string()]);
    t.row(vec!["evictions".into(), m.evictions.to_string()]);
    t.row(vec!["migrations".into(), m.migrations.to_string()]);
    t.row(vec!["preemptions".into(), m.preemptions.to_string()]);
    t.row(vec!["ttft_att".into(), format!("{:.3}", m.ttft_attainment())]);
    vec![t]
}
