//! Trace-analysis figures (Tab 1, Fig 1, 2, 6, 12, 13).

use crate::bench::harness::Table;
use crate::model::spec::{ModelId, ModelSpec};
use crate::sim::{SimConfig, Simulator};
use crate::sweep::run_points;
use crate::trace::gen::{generate, TraceGenConfig};
use crate::trace::{stats, Trace};
use crate::util::stats::{mean, percentile};

/// The four reference traces; generation is independent and deterministic,
/// so it fans out over the sweep pool like any other point grid.
pub fn four_traces(quick: bool, jobs: usize) -> Vec<(TraceGenConfig, Trace)> {
    let dur = if quick { 1800.0 } else { 6.0 * 3600.0 };
    let cfgs = vec![
        TraceGenConfig::hyperbolic_like(24, dur, 10),
        TraceGenConfig::novita_like(16, dur, 11),
        TraceGenConfig::arena_battle_like(if quick { 32 } else { 129 }, dur, 12),
        TraceGenConfig::arena_chat_like(if quick { 32 } else { 84 }, dur, 13),
    ];
    let traces = run_points(&cfgs, jobs, |_, c| generate(c));
    cfgs.into_iter().zip(traces).collect()
}

/// Table 1: trace summary (+ measured bursty-group statistics).
pub fn tab1_trace_summary(quick: bool, jobs: usize) -> Vec<Table> {
    let mut t = Table::new(
        "Table 1: synthetic production traces (paper: Hyperbolic/Novita/Arena)",
        &["trace", "models", "hours", "requests", "active%", "switches/hr"],
    );
    for (cfg, tr) in four_traces(quick, jobs) {
        t.row(vec![
            cfg.name.clone(),
            tr.n_models.to_string(),
            format!("{:.1}", tr.duration / 3600.0),
            tr.events.len().to_string(),
            format!("{:.0}", 100.0 * stats::mean_active_fraction(&tr, 120.0)),
            format!("{:.0}", stats::switches_per_hour(&tr, 120.0)),
        ]);
    }
    vec![t]
}

/// Fig 1: model-level activity heatmap + request-level dynamics (data rows).
pub fn fig1_dynamics(quick: bool) -> Vec<Table> {
    let dur = if quick { 3600.0 } else { 6.0 * 3600.0 };
    let tr = generate(&TraceGenConfig::novita_like(16, dur, 42));

    // (a) activity matrix, 3-minute cells.
    let cells = stats::activity_matrix(&tr, 180.0);
    let mut a = Table::new(
        "Fig 1a: active-model cells (3-min, 1=active)",
        &["model", "cells"],
    );
    for (m, row) in cells.iter().enumerate() {
        a.row(vec![
            format!("m{m}"),
            row.iter().map(|&b| if b { '1' } else { '0' }).collect(),
        ]);
    }

    // (b) normalized per-model rates over a 2-hour window, 2-min buckets.
    let zoom = tr.window(0.0, dur.min(7200.0));
    let rows = stats::normalized_rate_rows(&zoom, 120.0);
    let mut b = Table::new(
        "Fig 1b: normalized request rates (2-min buckets)",
        &["model", "series"],
    );
    for (m, row) in rows.iter().enumerate() {
        b.row(vec![
            format!("m{m}"),
            row.iter().map(|v| format!("{v:.2}")).collect::<Vec<_>>().join("|"),
        ]);
    }

    // (c) 5-minute zoom of the two most bursty models.
    let cvs = stats::per_model_rate_cv(&tr, 60.0);
    let mut order: Vec<usize> = (0..cvs.len()).collect();
    order.sort_by(|&x, &y| cvs[y].partial_cmp(&cvs[x]).unwrap());
    let m1 = order.first().copied().unwrap_or(0);
    let m2 = order.get(1).copied().unwrap_or(1);
    let mut c = Table::new(
        "Fig 1c: 5-min zoom, two bursty models (10-s buckets, shared norm)",
        &["bucket_t", "model_a", "model_b"],
    );
    let z = tr.window(0.0, f64::min(300.0, dur));
    let mut ra = vec![0.0; 30];
    let mut rb = vec![0.0; 30];
    for e in &z.events {
        let b_ = ((e.t / 10.0) as usize).min(29);
        if e.model_idx == m1 {
            ra[b_] += 1.0;
        } else if e.model_idx == m2 {
            rb[b_] += 1.0;
        }
    }
    let mx = ra.iter().chain(rb.iter()).cloned().fold(1.0, f64::max);
    for i in 0..30 {
        c.row(vec![
            format!("{}", i * 10),
            format!("{:.2}", ra[i] / mx),
            format!("{:.2}", rb[i] / mx),
        ]);
    }
    vec![a, b, c]
}

/// Two-model burst/interleave segment used by Fig 2 and Fig 6.
pub fn two_model_segment(quick: bool) -> (Trace, Vec<ModelSpec>) {
    let dur = if quick { 120.0 } else { 300.0 };
    // Interleaved phase then a concentrated burst from model 0 (Fig 1c shape).
    let mut events = Vec::new();
    let mut rng = crate::util::rng::Rng::new(77);
    let mut t = 0.0;
    while t < dur * 0.6 {
        t += rng.exp(1.2);
        let m = if rng.bool(0.5) { 0 } else { 1 };
        events.push(crate::trace::TraceEvent {
            t,
            model_idx: m,
            prompt_tokens: 150 + rng.below(400) as u32,
            output_tokens: 60 + rng.below(200) as u32,
        });
    }
    while t < dur {
        t += rng.exp(6.0); // model-0 burst
        events.push(crate::trace::TraceEvent {
            t,
            model_idx: 0,
            prompt_tokens: 200 + rng.below(600) as u32,
            output_tokens: 100 + rng.below(300) as u32,
        });
    }
    events.retain(|e| e.t < dur);
    let trace = Trace { name: "fig1c-seg".into(), n_models: 2, events, duration: dur };
    let cat = crate::model::spec::table3_catalog();
    let eights: Vec<ModelSpec> =
        cat.iter().filter(|m| m.name.contains("8b")).take(2).cloned().collect();
    let mut specs: Vec<ModelSpec> = eights; // two 8B models on one GPU
    specs[0].id = ModelId(0);
    specs[1].id = ModelId(1);
    (trace, specs)
}

/// Fig 2: pure time sharing vs pure space sharing on the Fig 1(c) segment -
/// memory usage and cumulative SLO violations over time.
pub fn fig2_pure_sharing(quick: bool, jobs: usize) -> Vec<Table> {
    let (trace, specs) = two_model_segment(quick);
    let mut out = Vec::new();
    let policies = ["qlm", "s-partition"];
    let results = run_points(&policies, jobs, |_, &policy| {
        let mut cfg = SimConfig::for_policy(policy).sample_dt(2.0).slo_scale(5.0);
        cfg.control_epoch = 1.0;
        Simulator::new(cfg, specs.clone()).run(&trace)
    });
    for (policy, (m, tl)) in policies.iter().zip(&results) {
        let mut t = Table::new(
            &format!(
                "Fig 2 ({}): memory + cumulative TTFT violations (final attainment {:.2})",
                policy,
                m.ttft_attainment()
            ),
            &["t", "weights_gb", "kv_used_gb", "cum_violations"],
        );
        for s in tl {
            let (w, _, used, _) = s.gpus[0];
            t.row(vec![
                format!("{:.0}", s.t),
                format!("{:.1}", w as f64 / 1e9),
                format!("{:.2}", used as f64 / 1e9),
                s.cum_violations.to_string(),
            ]);
        }
        out.push(t);
    }
    out
}

/// Fig 6: cross-model memory coordination - total KV and throughput under
/// Prism vs static partition.
pub fn fig6_memory_coordination(quick: bool, jobs: usize) -> Vec<Table> {
    let (trace, specs) = two_model_segment(quick);
    let mut out = Vec::new();
    let policies = ["prism", "s-partition"];
    let results = run_points(&policies, jobs, |_, &policy| {
        let mut cfg = SimConfig::for_policy(policy).sample_dt(2.0).slo_scale(6.0);
        cfg.control_epoch = 1.0;
        Simulator::new(cfg, specs.clone()).run(&trace)
    });
    for (policy, (m, tl)) in policies.iter().zip(&results) {
        let mut t = Table::new(
            &format!(
                "Fig 6 ({}): KV memory + throughput (token tput {:.0} tok/s busy)",
                policy,
                m.token_throughput()
            ),
            &["t", "kv_used_gb", "inst_tok_tput"],
        );
        for s in tl {
            let used: u64 = s.gpus.iter().map(|g| g.2).sum();
            t.row(vec![
                format!("{:.0}", s.t),
                format!("{:.2}", used as f64 / 1e9),
                format!("{:.0}", s.inst_token_tput),
            ]);
        }
        out.push(t);
    }
    out
}

/// Fig 12: switches/hour + day-over-day Pearson for the four traces.
pub fn fig12_switches_pearson(quick: bool, jobs: usize) -> Vec<Table> {
    let mut a = Table::new("Fig 12a: model switches per hour", &["trace", "switches/hr"]);
    let mut b = Table::new(
        "Fig 12b: day-over-day Pearson correlation",
        &["trace", "mean_r", "p90_|r|"],
    );
    let traces = four_traces(quick, jobs);
    // Per-trace analysis (including the "next day" regeneration) is
    // independent: one sweep point per trace.
    let rows = run_points(&traces, jobs, |_, (cfg, tr)| {
        let switches = stats::switches_per_hour(tr, 120.0);
        let mut cfg2 = cfg.clone();
        cfg2.seed += 1000; // "next day"
        let tr2 = generate(&cfg2);
        let cors = stats::day_over_day_pearson(tr, &tr2, 600.0);
        let abs: Vec<f64> = cors.iter().map(|c| c.abs()).collect();
        (switches, mean(&cors), percentile(&abs, 90.0))
    });
    for ((cfg, _), (switches, mean_r, p90_abs)) in traces.iter().zip(&rows) {
        a.row(vec![cfg.name.clone(), format!("{switches:.0}")]);
        b.row(vec![
            cfg.name.clone(),
            format!("{mean_r:.3}"),
            format!("{p90_abs:.3}"),
        ]);
    }
    vec![a, b]
}

/// Fig 13: idle intervals/hour and request-rate CV per trace.
pub fn fig13_volatility(quick: bool, jobs: usize) -> Vec<Table> {
    let mut a = Table::new(
        "Fig 13a: idle intervals per hour (>10s), per-model distribution",
        &["trace", "p50", "p90", "max"],
    );
    let mut b = Table::new(
        "Fig 13b: CV of requests/min, per-model distribution",
        &["trace", "p50", "p90", "frac_cv>1"],
    );
    for (cfg, tr) in four_traces(quick, jobs) {
        let idles = stats::per_model_idle_intervals_per_hour(&tr, 10.0);
        a.row(vec![
            cfg.name.clone(),
            format!("{:.1}", percentile(&idles, 50.0)),
            format!("{:.1}", percentile(&idles, 90.0)),
            format!("{:.1}", idles.iter().cloned().fold(0.0, f64::max)),
        ]);
        let cvs = stats::per_model_rate_cv(&tr, 60.0);
        let frac = cvs.iter().filter(|&&c| c > 1.0).count() as f64 / cvs.len().max(1) as f64;
        b.row(vec![
            cfg.name.clone(),
            format!("{:.2}", percentile(&cvs, 50.0)),
            format!("{:.2}", percentile(&cvs, 90.0)),
            format!("{:.2}", frac),
        ]);
    }
    vec![a, b]
}
